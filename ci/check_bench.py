#!/usr/bin/env python3
"""Schema/append-only check for BENCH_kernels.json.

The bench harness (rust/benches/bench_kernels.rs) appends runs to the
perf-trajectory file with a suffix splice, which only works while the
file keeps the exact layout the writer emits. This check pins that
contract in CI — run it before AND after the quick bench so both the
committed file and a freshly appended one validate:

  * top level: schema tag, unit string, append-only "runs" list
  * every run: created_unix / quick / source ("measured" | "estimate",
    estimates carry a "note"), non-empty entries
  * every entry: required keys with the right types, positive rates
  * created_unix is non-decreasing across runs (append-only ordering)
  * the raw text ends with the splice tail the harness matches on

Exit code 0 = valid; 1 = any violation (all are listed).
"""

import json
import sys
from pathlib import Path

SCHEMA = "comet-bench-kernels/v1"
TAIL = "\n  ]\n}\n"
RUN_KEYS = {"created_unix": int, "quick": bool, "source": str, "entries": list}
ENTRY_KEYS = {
    "metric": str,
    "repr": str,
    "kernel": str,
    "threads": int,
    "nf": int,
    "nv": int,
    "iters": int,
    "secs_median": (int, float),
    "comparisons_per_sec": (int, float),
}
METRICS = {"czekanowski", "ccc", "sorenson"}
REPRS = {"float", "packed", "packed2"}
KERNELS = {
    "full",
    "tri",
    "session-oneshot",
    "session-reused",
    "session-ooc",
    "session-faulted",
    "ingest-bed",
}


def check(path: Path) -> list:
    errs = []
    text = path.read_text()
    if not text.endswith(TAIL):
        errs.append(f"file must end with the splice tail {TAIL!r} (append contract)")
    try:
        doc = json.loads(text)
    except json.JSONDecodeError as e:
        return errs + [f"not valid JSON: {e}"]
    if doc.get("schema") != SCHEMA:
        errs.append(f"schema is {doc.get('schema')!r}, want {SCHEMA!r}")
    if not isinstance(doc.get("unit"), str):
        errs.append("missing/invalid 'unit'")
    runs = doc.get("runs")
    if not isinstance(runs, list) or not runs:
        return errs + ["'runs' must be a non-empty list"]
    prev_created = 0
    for i, run in enumerate(runs):
        where = f"runs[{i}]"
        for key, typ in RUN_KEYS.items():
            if not isinstance(run.get(key), typ):
                errs.append(f"{where}.{key}: missing or not {typ}")
        src = run.get("source")
        if src not in ("measured", "estimate"):
            errs.append(f"{where}.source: {src!r} not in measured|estimate")
        if src == "estimate" and not isinstance(run.get("note"), str):
            errs.append(f"{where}: estimate runs must carry a 'note' explaining provenance")
        created = run.get("created_unix", 0)
        if isinstance(created, int):
            if created < prev_created:
                errs.append(f"{where}.created_unix went backwards (append-only ordering)")
            prev_created = created
        entries = run.get("entries") or []
        if not entries:
            errs.append(f"{where}.entries is empty")
        for j, e in enumerate(entries):
            ew = f"{where}.entries[{j}]"
            for key, typ in ENTRY_KEYS.items():
                if not isinstance(e.get(key), typ) or isinstance(e.get(key), bool):
                    errs.append(f"{ew}.{key}: missing or not {typ}")
                    break
            else:
                if e["metric"] not in METRICS:
                    errs.append(f"{ew}.metric {e['metric']!r} unknown")
                if e["repr"] not in REPRS:
                    errs.append(f"{ew}.repr {e['repr']!r} unknown")
                if e["kernel"] not in KERNELS:
                    errs.append(f"{ew}.kernel {e['kernel']!r} unknown")
                if e["secs_median"] <= 0 or e["comparisons_per_sec"] <= 0:
                    errs.append(f"{ew}: non-positive timing/rate")
    return errs


def main() -> int:
    path = Path(sys.argv[1] if len(sys.argv) > 1 else "BENCH_kernels.json")
    if not path.exists():
        print(f"check_bench: {path} not found", file=sys.stderr)
        return 1
    errs = check(path)
    if errs:
        for e in errs:
            print(f"check_bench: {path}: {e}", file=sys.stderr)
        return 1
    doc = json.loads(path.read_text())
    n_runs = len(doc["runs"])
    n_entries = sum(len(r["entries"]) for r in doc["runs"])
    print(f"check_bench: {path} OK — {n_runs} run(s), {n_entries} entries")
    return 0


if __name__ == "__main__":
    sys.exit(main())
