"""Pytest bootstrap: make `compile.*` importable when the suite is run
from the repository root (`python -m pytest python/tests -q`, as CI
does) as well as from inside `python/`."""

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))
