"""Hypothesis sweeps: Pallas kernel shape/dtype/value space vs. the oracle.

The paper's correctness story rests on the mGEMM being *exactly* a GEMM
with the scalar op swapped; these sweeps probe the places that can break
that equivalence — tile-boundary arithmetic, accumulation order, dtype
edge values (zeros, denormal-adjacent, equal elements where ternary vs.
min lowering could diverge).
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed")
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import mgemm as mgemm_kernels
from compile.kernels import ref

SETTINGS = dict(max_examples=25, deadline=None)


def np_mgemm2(w, v):
    return np.minimum(w[:, :, None], v[:, None, :]).sum(axis=0)


@st.composite
def matrices_2way(draw, max_mult=3):
    """Tile-multiple shapes with values including exact ties and zeros."""
    bk = 64
    km = draw(st.integers(1, max_mult))
    mm = draw(st.integers(1, 2))
    nm = draw(st.integers(1, 2))
    nf, m, n = bk * km, 64 * mm, 64 * nm
    seed = draw(st.integers(0, 2**32 - 1))
    rng = np.random.default_rng(seed)
    w = rng.random((nf, m))
    v = rng.random((nf, n))
    # Inject structured edge values: exact zeros, exact ties across operands.
    w[rng.random((nf, m)) < 0.05] = 0.0
    v[rng.random((nf, n)) < 0.05] = 0.0
    tie_rows = rng.integers(0, nf, size=nf // 8)
    v[tie_rows, : min(m, n)] = w[tie_rows, : min(m, n)]
    return w, v


@given(matrices_2way(), st.sampled_from(["f32", "f64"]))
@settings(**SETTINGS)
def test_mgemm2_pallas_sweep(wv, dtag):
    w, v = wv
    dt = jnp.float32 if dtag == "f32" else jnp.float64
    wj, vj = jnp.asarray(w, dt), jnp.asarray(v, dt)
    got = np.asarray(mgemm_kernels.mgemm2_pallas(wj, vj))
    want = np_mgemm2(np.asarray(wj), np.asarray(vj))
    rtol = 2e-5 if dtag == "f32" else 1e-12
    np.testing.assert_allclose(got, want, rtol=rtol)


@given(matrices_2way(max_mult=2), st.sampled_from(["minimum", "ternary"]))
@settings(**SETTINGS)
def test_mgemm2_xla_min_impls_sweep(wv, impl):
    w, v = wv
    wj, vj = jnp.asarray(w), jnp.asarray(v)
    fn = model.mgemm2_xla if impl == "minimum" else model.mgemm2_ternary_xla
    got = np.asarray(fn(wj, vj, chunk=64))
    np.testing.assert_allclose(got, np_mgemm2(w, v), rtol=1e-12)


@given(st.integers(0, 2**32 - 1), st.integers(1, 2), st.sampled_from([4, 8]))
@settings(**SETTINGS)
def test_mgemm3_pallas_sweep(seed, kmult, jt):
    rng = np.random.default_rng(seed)
    nf = 64 * kmult
    vi = rng.random((nf, 32))
    vj = rng.random((nf, jt))
    vk = rng.random((nf, 64))
    got = np.asarray(
        mgemm_kernels.mgemm3_pallas(
            jnp.asarray(vi), jnp.asarray(vj), jnp.asarray(vk), bm=32, bn=32, bk=64
        )
    )
    want = np.minimum(
        np.minimum(vj[:, :, None, None], vi[:, None, :, None]), vk[:, None, None, :]
    ).sum(axis=0)
    np.testing.assert_allclose(got, want, rtol=1e-12)


@given(st.integers(0, 2**32 - 1))
@settings(**SETTINGS)
def test_grid_valued_bitwise_agreement(seed):
    """On the k/64 grid all lowerings must agree BIT-FOR-BIT (paper §5)."""
    rng = np.random.default_rng(seed)
    w = np.floor(rng.random((128, 64)) * 64.0) / 64.0
    v = np.floor(rng.random((128, 64)) * 64.0) / 64.0
    wj, vj = jnp.asarray(w, jnp.float32), jnp.asarray(v, jnp.float32)
    outs = [
        np.asarray(model.mgemm2_xla(wj, vj, chunk=64)),
        np.asarray(model.mgemm2_ternary_xla(wj, vj, chunk=64)),
        np.asarray(mgemm_kernels.mgemm2_pallas(wj, vj)),
        np.asarray(mgemm_kernels.mgemm2_pallas(wj, vj, min_impl="ternary")),
        np_mgemm2(np.asarray(wj), np.asarray(vj)).astype(np.float32),
    ]
    for o in outs[1:]:
        np.testing.assert_array_equal(outs[0], o)


@given(st.integers(0, 2**32 - 1))
@settings(**SETTINGS)
def test_c2_bounds_sweep(seed):
    rng = np.random.default_rng(seed)
    v = jnp.asarray(rng.random((64, 12)) + 1e-6)
    c = np.asarray(ref.czekanowski2(v))
    assert (c >= 0.0).all() and (c <= 1.0 + 1e-12).all()
    np.testing.assert_allclose(np.diag(c), 1.0, rtol=1e-12)
