"""AOT pipeline tests: artifact table consistency and HLO lowering sanity."""

import os

import jax

jax.config.update("jax_enable_x64", True)

import pytest

from compile import aot


def test_artifact_table_well_formed():
    table = aot.build_artifact_table()
    names = [row[0] for row in table]
    assert len(names) == len(set(names)), "duplicate artifact names"
    for name, kind, dtag, nf, nv, jt, fn, specs in table:
        assert dtag in ("f32", "f64", "u32")
        if dtag == "u32":
            assert nf % 32 == 0, f"{name}: bit depth must pack into words"
            continue
        assert nf % aot.XLA_CHUNK == 0, f"{name}: chunk must divide nf"
        if "pallas" in kind and jt == 0:
            assert nf % aot.PALLAS_2WAY["bk"] == 0
            assert nv % aot.PALLAS_2WAY["bm"] == 0
        if jt > 0:
            assert nv % aot.PALLAS_3WAY["bm"] == 0


def test_table_covers_all_required_kinds():
    kinds = {row[1] for row in aot.build_artifact_table()}
    required = {
        "mgemm2", "mgemm2ternary", "mgemm2pallas", "mgemm2pallasternary",
        "gemm", "gemmpallas", "block2", "rowsum", "mgemm3", "mgemm3pallas",
        "sorenson2", "sorenson2pallas",
    }
    assert required <= kinds


@pytest.mark.parametrize("prefix", ["mgemm2_f32_s", "gemm_f64_s", "mgemm3_f32_s"])
def test_lowering_produces_hlo_text(prefix):
    table = aot.build_artifact_table()
    row = next(r for r in table if r[0] == prefix)
    name, kind, dtag, nf, nv, jt, fn, specs = row
    text = aot.lower_artifact(fn, specs)
    assert text.startswith("HloModule"), text[:80]
    assert "ROOT" in text
    # Tuple-rooted (return_tuple=True) — the Rust side unwraps with to_tuple*.
    assert "tuple" in text.lower()


def test_manifest_written(tmp_path):
    rc = aot.main(["--out", str(tmp_path), "--only", "rowsum_f32_s"])
    assert rc == 0
    manifest = (tmp_path / "manifest.txt").read_text().strip().splitlines()
    body = [l for l in manifest if not l.startswith("#")]
    assert len(body) == len(aot.build_artifact_table())
    cols = body[0].split()
    assert len(cols) == 7
    assert os.path.exists(tmp_path / "rowsum_f32_s.hlo.txt")
    assert os.path.exists(tmp_path / "kernel_report.txt")
