"""Kernel correctness: every Pallas kernel and XLA graph vs. the jnp oracle.

This is the CORE correctness signal for Layers 1 and 2: the Rust runtime
executes AOT lowerings of exactly these functions, so agreement here plus
the Rust-side HLO round-trip test pins the whole accelerator path.
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import gemm as gemm_kernels
from compile.kernels import mgemm as mgemm_kernels
from compile.kernels import ref

RNG = np.random.default_rng(20180326)  # paper acceptance date


def rand_v(nf, nv, dtype, grid=False):
    """Non-negative test vectors; grid=True snaps to k/64 (exact-sum grid)."""
    x = RNG.random((nf, nv))
    if grid:
        x = np.floor(x * 64.0) / 64.0
    return jnp.asarray(x, dtype=dtype)


TOL = {jnp.float32: 1e-5, jnp.float64: 1e-12}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
@pytest.mark.parametrize("nf,m,n", [(64, 64, 64), (128, 64, 128), (192, 128, 64)])
def test_mgemm2_pallas_vs_ref(dtype, nf, m, n):
    w, v = rand_v(nf, m, dtype), rand_v(nf, n, dtype)
    got = mgemm_kernels.mgemm2_pallas(w, v, bm=64, bn=64, bk=64)
    want = ref.mgemm2(w, v)
    np.testing.assert_allclose(got, want, rtol=TOL[dtype])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_mgemm2_pallas_ternary_matches_minimum(dtype):
    w, v = rand_v(128, 64, dtype), rand_v(128, 64, dtype)
    a = mgemm_kernels.mgemm2_pallas(w, v, min_impl="minimum")
    b = mgemm_kernels.mgemm2_pallas(w, v, min_impl="ternary")
    # The two min lowerings are bit-identical on non-NaN data.
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
@pytest.mark.parametrize("chunk", [32, 64, 128])
def test_mgemm2_xla_vs_ref(dtype, chunk):
    w, v = rand_v(128, 96, dtype), rand_v(128, 32, dtype)
    got = model.mgemm2_xla(w, v, chunk=chunk)
    want = ref.mgemm2(w, v)
    np.testing.assert_allclose(got, want, rtol=TOL[dtype])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_mgemm2_ternary_xla_vs_ref(dtype):
    w, v = rand_v(128, 64, dtype), rand_v(128, 64, dtype)
    got = model.mgemm2_ternary_xla(w, v, chunk=64)
    np.testing.assert_allclose(got, ref.mgemm2(w, v), rtol=TOL[dtype])


def test_mgemm2_grid_inputs_exact_f32():
    """On the k/64 value grid every partial sum is exact in f32, so all
    variants agree bit-for-bit — the basis of the paper's bit-identical
    checksum across decompositions (§5)."""
    w = rand_v(384, 64, jnp.float32, grid=True)
    v = rand_v(384, 64, jnp.float32, grid=True)
    a = np.asarray(model.mgemm2_xla(w, v, chunk=64))
    b = np.asarray(mgemm_kernels.mgemm2_pallas(w, v))
    c = np.asarray(ref.mgemm2(w, v))
    np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(a, c)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_gemm_pallas_vs_ref(dtype):
    w, v = rand_v(128, 64, dtype), rand_v(128, 64, dtype)
    got = gemm_kernels.gemm_pallas(w, v)
    tol = 1e-4 if dtype == jnp.float32 else 1e-10
    np.testing.assert_allclose(got, ref.gemm(w, v), rtol=tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
@pytest.mark.parametrize("jt", [4, 8])
def test_mgemm3_pallas_vs_ref(dtype, jt):
    vi, vj, vk = rand_v(128, 32, dtype), rand_v(128, jt, dtype), rand_v(128, 64, dtype)
    got = mgemm_kernels.mgemm3_pallas(vi, vj, vk, bm=32, bn=32, bk=64)
    want = ref.mgemm3(vi, vj, vk)
    np.testing.assert_allclose(got, want, rtol=TOL[dtype])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_mgemm3_xla_vs_ref(dtype):
    vi, vj, vk = rand_v(128, 32, dtype), rand_v(128, 8, dtype), rand_v(128, 32, dtype)
    got = model.mgemm3_xla(vi, vj, vk, chunk=64)
    np.testing.assert_allclose(got, ref.mgemm3(vi, vj, vk), rtol=TOL[dtype])


def test_mgemm3_symmetry():
    """n3' is symmetric under any permutation of its three vectors."""
    v = rand_v(64, 8, jnp.float64)
    full = np.asarray(ref.mgemm3(v, v, v))  # [t, i, k]
    for perm in [(0, 2, 1), (1, 0, 2), (2, 1, 0), (1, 2, 0), (2, 0, 1)]:
        np.testing.assert_allclose(full, full.transpose(perm), rtol=1e-12)


def test_block2_xla_parts():
    w, v = rand_v(128, 64, jnp.float64), rand_v(128, 64, jnp.float64)
    n, sw, sv = model.block2_xla(w, v, chunk=64)
    np.testing.assert_allclose(n, ref.mgemm2(w, v), rtol=1e-12)
    np.testing.assert_allclose(sw, ref.rowsums(w), rtol=1e-12)
    np.testing.assert_allclose(sv, ref.rowsums(v), rtol=1e-12)


def test_rowsum():
    v = rand_v(100, 10, jnp.float64)
    np.testing.assert_allclose(model.rowsum_xla(v), np.asarray(v).sum(0), rtol=1e-12)


class TestMetricProperties:
    """Paper §2 mathematical properties of the metrics themselves."""

    def test_c2_range_and_symmetry(self):
        v = rand_v(64, 16, jnp.float64)
        c = np.asarray(ref.czekanowski2(v))
        assert (c >= -1e-12).all() and (c <= 1.0 + 1e-12).all()
        np.testing.assert_allclose(c, c.T, rtol=1e-12)
        # Self-similarity is exactly 1: c2(v, v) = 2*sum(v)/(2*sum(v)).
        np.testing.assert_allclose(np.diag(c), 1.0, rtol=1e-12)

    def test_c2_identical_vectors(self):
        u = np.abs(RNG.random(64))
        v = jnp.asarray(np.stack([u, u], axis=1))
        c = np.asarray(ref.czekanowski2(v))
        np.testing.assert_allclose(c, 1.0, rtol=1e-12)

    def test_c2_disjoint_support_is_zero(self):
        a = np.zeros(64)
        b = np.zeros(64)
        a[:32] = 1.0
        b[32:] = 1.0
        v = jnp.asarray(np.stack([a, b], axis=1))
        c = np.asarray(ref.czekanowski2(v))
        assert c[0, 1] == 0.0

    def test_c3_range_and_total_symmetry(self):
        v = rand_v(48, 8, jnp.float64)
        c = np.asarray(ref.czekanowski3(v))
        assert (c >= -1e-12).all() and (c <= 1.5 + 1e-9).all()
        for perm in [(0, 2, 1), (1, 0, 2), (2, 1, 0)]:
            np.testing.assert_allclose(c, c.transpose(perm), rtol=1e-12)

    def test_c3_identical_triple(self):
        u = np.abs(RNG.random(32)) + 0.1
        v = jnp.asarray(np.stack([u, u, u], axis=1))
        c = np.asarray(ref.czekanowski3(v))
        # n3 = 3 n2 - n3' = 3 s - s = 2 s ; d3 = 3 s ; c3 = 1.5 * 2/3 = 1.
        np.testing.assert_allclose(c[0, 1, 2], 1.0, rtol=1e-12)

    def test_n3_inclusion_exclusion_identity(self):
        """Eq. (1): n3 = n2(ij) + n2(ik) + n2(jk) - n3'."""
        v = rand_v(64, 6, jnp.float64)
        n2 = np.asarray(ref.mgemm2(v, v))
        n3p = np.asarray(ref.mgemm3(v, v, v))  # [t=j, i, k]
        s = np.asarray(ref.rowsums(v))
        c3 = np.asarray(ref.czekanowski3(v))
        i, j, k = 1, 3, 5
        n3 = n2[i, j] + n2[i, k] + n2[j, k] - n3p[j, i, k]
        d3 = s[i] + s[j] + s[k]
        np.testing.assert_allclose(c3[i, j, k], 1.5 * n3 / d3, rtol=1e-12)

    def test_sorenson_equals_czekanowski_on_binary(self):
        """§2.3: Sorenson == Proportional Similarity when entries ∈ {0,1}."""
        bits = (RNG.random((96, 12)) < 0.4).astype(np.float64)
        v = jnp.asarray(bits)
        n_ps = np.asarray(ref.mgemm2(v, v))
        n_sor = np.asarray(ref.sorenson2(v))
        np.testing.assert_array_equal(n_ps, n_sor)
