"""Bitwise Sorenson kernels (§2.3) vs. the float oracle: the packed
AND+popcount lowering must agree exactly with the min-product mGEMM on
the unpacked 0/1 data."""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed")
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref, sorenson

RNG = np.random.default_rng(23)


def pack_bits(bits):
    """[nf, nv] 0/1 -> [ceil(nf/32), nv] uint32 (little-endian bit order)."""
    nf, nv = bits.shape
    nw = -(-nf // 32)
    padded = np.zeros((nw * 32, nv), dtype=np.uint32)
    padded[:nf] = bits.astype(np.uint32)
    words = np.zeros((nw, nv), dtype=np.uint32)
    for b in range(32):
        words |= padded[b::32][:nw] << np.uint32(b)
    return jnp.asarray(words)


def case(nf, nv, density=0.4, seed=None):
    rng = RNG if seed is None else np.random.default_rng(seed)
    bits = (rng.random((nf, nv)) < density).astype(np.float64)
    return bits, pack_bits(bits)


@pytest.mark.parametrize("nf,nv", [(512, 128), (96, 64), (512, 64)])
def test_sorenson_xla_vs_float_oracle(nf, nv):
    bits, words = case(nf, nv)
    want = np.asarray(ref.mgemm2(jnp.asarray(bits), jnp.asarray(bits)))
    got = np.asarray(model.sorenson2_xla(words, words, chunk=words.shape[0], jtile=8))
    np.testing.assert_array_equal(got.astype(np.float64), want)


def test_sorenson_pallas_vs_float_oracle():
    bits, words = case(512, 128)
    want = np.asarray(ref.mgemm2(jnp.asarray(bits), jnp.asarray(bits)))
    got = np.asarray(sorenson.sorenson2_pallas(words, words, bm=64, bn=64, bk=16))
    np.testing.assert_array_equal(got.astype(np.float64), want)


def test_pack_bits_roundtrip():
    bits, words = case(70, 8)  # non-multiple of 32: tail padding
    w = np.asarray(words)
    assert w.shape == (3, 8)
    for v in range(8):
        for q in range(70):
            assert ((w[q // 32, v] >> (q % 32)) & 1) == int(bits[q, v])
        # tail bits clear
        for q in range(70, 96):
            assert ((w[q // 32, v] >> (q % 32)) & 1) == 0


@given(st.integers(0, 2**32 - 1), st.floats(0.05, 0.9))
@settings(max_examples=15, deadline=None)
def test_sorenson_sweep(seed, density):
    bits, words = case(512, 64, density=density, seed=seed)
    want = np.asarray(ref.sorenson2(jnp.asarray(bits)))
    got = np.asarray(model.sorenson2_xla(words, words, chunk=16, jtile=8))
    np.testing.assert_array_equal(got.astype(np.float64), want)


def test_sorenson_diag_is_popcount():
    bits, words = case(512, 32)
    got = np.asarray(sorenson.sorenson2_pallas(words, words, bm=32, bn=32, bk=16))
    pops = bits.sum(axis=0)
    np.testing.assert_array_equal(np.diag(got).astype(np.float64), pops)
