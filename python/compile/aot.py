"""AOT lowering: Layer-2 graphs (+ Layer-1 Pallas kernels) → HLO text artifacts.

This is the only Python that ever runs; it runs ONCE at build time
(`make artifacts`) and writes:

  artifacts/<name>.hlo.txt   one per artifact (HLO TEXT — see below)
  artifacts/manifest.txt     whitespace table the Rust runtime parses
  artifacts/kernel_report.txt VMEM/working-set estimates per kernel shape
                              (the TPU occupancy analysis, DESIGN.md §Perf)

Interchange format is HLO *text*, not a serialized HloModuleProto: jax
≥ 0.5 emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts are shape-specialized. The Rust runtime pads blocks up to the
nearest artifact tier: zero-padding features/vectors is exact for the
min-product over non-negative data (min(0, x) = 0 contributes nothing),
and padded output rows/columns are sliced off on the Rust side.

Usage (from the python/ directory, as `make artifacts` does):
    python -m compile.aot --out ../artifacts [--only PREFIX] [--list]
"""

import argparse
import functools
import os
import sys

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
from jax._src.lib import xla_client as xc  # noqa: E402

from compile import model  # noqa: E402
from compile.kernels import mgemm as mgemm_kernels  # noqa: E402


# ---------------------------------------------------------------------------
# Artifact specification table
# ---------------------------------------------------------------------------

# Shape tiers. "s" is the quick correctness tier, "m" the bench tier,
# "p" the PheWAS tier (n_f = 385 pads to 512 instead of 1536 — §Perf).
TIERS_2WAY = [
    # (tag, n_f, n_v)
    ("s", 384, 128),
    ("p", 512, 256),
    ("n", 1536, 128),  # deep-narrow: small blocks of deep vectors (§Perf)
    ("m", 1536, 256),
]
TIERS_3WAY = [
    # (tag, n_f, n_v, jt)
    ("s", 384, 64, 8),
    ("p", 512, 64, 8),
    ("n", 1536, 64, 8),  # deep-narrow (§Perf: avoids 4× nv padding)
    ("m", 1536, 128, 16),
]
DTYPES = [("f32", jnp.float32), ("f64", jnp.float64)]

# Pallas tile sizes (shared across tiers; all tiers divide evenly).
PALLAS_2WAY = dict(bm=64, bn=64, bk=64)
PALLAS_3WAY = dict(bm=32, bn=32, bk=64)
# XLA-graph tile schedule: §Perf-swept winners through the actual
# PJRT runtime (xla_extension 0.5.1 codegen — NOT the jax-jit runtime,
# whose optimum differs; see EXPERIMENTS.md §Perf).
XLA_CHUNK = 128
XLA_JTILE = 8


def _specs_2way(nf, nv, dt):
    s = jax.ShapeDtypeStruct((nf, nv), dt)
    return (s, s)


def _specs_3way(nf, nv, jt, dt):
    return (
        jax.ShapeDtypeStruct((nf, nv), dt),
        jax.ShapeDtypeStruct((nf, jt), dt),
        jax.ShapeDtypeStruct((nf, nv), dt),
    )


def build_artifact_table():
    """Return [(name, kind, dtype, nf, nv, jt, fn, arg_specs)]."""
    table = []
    for dtag, dt in DTYPES:
        for tag, nf, nv in TIERS_2WAY:
            specs = _specs_2way(nf, nv, dt)
            two_way = [
                # (kind, fn) — all share the contract N = W^T ∘min V
                ("mgemm2", functools.partial(model.mgemm2_xla, chunk=XLA_CHUNK, jtile=XLA_JTILE)),
                ("mgemm2ternary",
                 functools.partial(model.mgemm2_ternary_xla, chunk=XLA_CHUNK, jtile=XLA_JTILE)),
                ("mgemm2pallas", functools.partial(model.mgemm2_pallas, **PALLAS_2WAY)),
                ("mgemm2pallasternary",
                 functools.partial(model.mgemm2_pallas, min_impl="ternary", **PALLAS_2WAY)),
                ("gemm", model.gemm_xla),
                ("gemmpallas", functools.partial(model.gemm_pallas, **PALLAS_2WAY)),
                ("block2",
                 functools.partial(model.block2_xla, chunk=XLA_CHUNK, jtile=XLA_JTILE)),
            ]
            for kind, fn in two_way:
                name = f"{kind}_{dtag}_{tag}"
                table.append((name, kind, dtag, nf, nv, 0, fn, specs))
            name = f"rowsum_{dtag}_{tag}"
            table.append((name, "rowsum", dtag, nf, nv, 0, model.rowsum_xla, specs[:1]))
        for tag, nf, nv, jt in TIERS_3WAY:
            specs = _specs_3way(nf, nv, jt, dt)
            # §Perf sweep through the PJRT runtime: f32 peaks at ktile=8
            # (5.05 vs 4.62 Gop/s), f64 at ktile=4 (3.72 vs 3.30).
            ktile = 8 if dtag == "f32" else 4
            m3 = functools.partial(model.mgemm3_xla, chunk=XLA_CHUNK, ktile=ktile)
            three_way = [
                ("mgemm3", m3),
                ("mgemm3pallas", functools.partial(model.mgemm3_pallas, **PALLAS_3WAY)),
            ]
            for kind, fn in three_way:
                name = f"{kind}_{dtag}_{tag}"
                table.append((name, kind, dtag, nf, nv, jt, fn, specs))
    # Bitwise Sorenson tiers (§2.3): packed uint32 words, n_f = 32·n_w.
    for tag, nw, nv in [("s", 16, 128), ("m", 128, 256)]:
        spec = jax.ShapeDtypeStruct((nw, nv), jnp.uint32)
        table.append((
            f"sorenson2_u32_{tag}", "sorenson2", "u32", nw * 32, nv, 0,
            functools.partial(model.sorenson2_xla, chunk=16, jtile=8), (spec, spec),
        ))
        table.append((
            f"sorenson2pallas_u32_{tag}", "sorenson2pallas", "u32", nw * 32, nv, 0,
            functools.partial(model.sorenson2_pallas, bk=16), (spec, spec),
        ))
    return table


# ---------------------------------------------------------------------------
# Lowering
# ---------------------------------------------------------------------------


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_artifact(fn, arg_specs) -> str:
    wrapped = lambda *args: fn(*args)  # noqa: E731 — normalize partials
    return to_hlo_text(jax.jit(wrapped).lower(*arg_specs))


def write_kernel_report(outdir):
    lines = ["# Pallas kernel working-set estimates (bytes per grid step)", ""]
    for dtag, nbytes in (("f32", 4), ("f64", 8)):
        est2 = mgemm_kernels.vmem_estimate_2way(
            PALLAS_2WAY["bm"], PALLAS_2WAY["bn"], PALLAS_2WAY["bk"], nbytes
        )
        lines.append(f"mgemm2 {dtag} tiles bm={PALLAS_2WAY['bm']} bn={PALLAS_2WAY['bn']} "
                     f"bk={PALLAS_2WAY['bk']}: {est2}")
        for tag, nf, nv, jt in TIERS_3WAY:
            est3 = mgemm_kernels.vmem_estimate_3way(
                PALLAS_3WAY["bm"], PALLAS_3WAY["bn"], PALLAS_3WAY["bk"], jt, nbytes
            )
            lines.append(f"mgemm3 {dtag} tier={tag} jt={jt} tiles bm={PALLAS_3WAY['bm']} "
                         f"bn={PALLAS_3WAY['bn']} bk={PALLAS_3WAY['bk']}: {est3}")
    lines.append("")
    lines.append("# 'panels'+'out_tile' must fit the ~16 MiB VMEM budget on real TPU;")
    lines.append("# 'interpret_bcast_temp' is an interpret-mode artifact only (Mosaic")
    lines.append("# keeps the q-loop in vector registers).")
    with open(os.path.join(outdir, "kernel_report.txt"), "w") as f:
        f.write("\n".join(lines) + "\n")


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out", default="../artifacts", help="artifact output directory")
    p.add_argument("--only", default=None, help="only build artifacts whose name starts with this")
    p.add_argument("--list", action="store_true", help="list artifact names and exit")
    args = p.parse_args(argv)

    table = build_artifact_table()
    if args.list:
        for name, kind, dtag, nf, nv, jt, _, _ in table:
            print(f"{name:32s} kind={kind:14s} dtype={dtag} nf={nf} nv={nv} jt={jt}")
        return 0

    outdir = args.out
    os.makedirs(outdir, exist_ok=True)
    manifest_rows = []
    built = 0
    for name, kind, dtag, nf, nv, jt, fn, specs in table:
        fname = f"{name}.hlo.txt"
        manifest_rows.append(f"{name} {kind} {dtag} {nf} {nv} {jt} {fname}")
        if args.only and not name.startswith(args.only):
            continue
        text = lower_artifact(fn, specs)
        with open(os.path.join(outdir, fname), "w") as f:
            f.write(text)
        built += 1
        print(f"  lowered {name:32s} ({len(text)} chars)", flush=True)

    # Manifest always lists the full table so the Rust registry knows the
    # complete tier set (files built with --only filters may be absent;
    # the registry reports missing files with a remediation hint).
    with open(os.path.join(outdir, "manifest.txt"), "w") as f:
        f.write("# name kind dtype nf nv jt file\n")
        f.write("\n".join(manifest_rows) + "\n")
    write_kernel_report(outdir)
    print(f"built {built}/{len(table)} artifacts -> {outdir}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
