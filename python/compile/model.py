"""Layer-2 compute graphs: blocked Proportional-Similarity building blocks.

These are the jax functions that aot.py lowers to HLO text artifacts for
the Rust coordinator. Each corresponds to one accelerator offload in the
paper's node-level algorithm:

  mgemm2_xla / mgemm2_ternary_xla : N = W^T ∘min V        (§3.1, the GPU kernel)
  gemm_xla                        : W^T V                 (Table 1 comparator)
  mgemm3_xla                      : B_j slabs             (§3.2, Algorithm 3 body)
  rowsum_xla                      : column sums           (denominator ingredient)
  block2_xla                      : fused N + both rowsum (hot-path variant)

Denominator combination and the final quotient stay on the Rust side,
matching the paper's CPU/GPU split ("all other computations are performed
on the CPU", §3.1).

The Pallas kernels from kernels/ are alternative lowerings of the same
contracts; pytest asserts all variants agree with kernels/ref.py.
"""

import functools

import jax
import jax.numpy as jnp
from jax import lax

from compile.kernels import mgemm as mgemm_kernels


def _min_tiled_accum(w, v, chunk, jtile, combine):
    """Shared tiled accumulation: output column tiles of width `jtile`,
    each summed over feature panels of depth `chunk`.

    This is the XLA-graph analogue of the Pallas kernel's VMEM schedule,
    and the §Perf winner on the CPU backend: the [chunk, m, jtile]
    broadcast temporary stays L2-resident (a [chunk, m, n] panel does
    not), which measured 2–2.5× faster than feature-chunking alone
    (EXPERIMENTS.md §Perf). `chunk` must divide n_f and `jtile` n_v
    (artifact shapes guarantee both).
    """
    nf, m = w.shape
    _, n = v.shape
    assert nf % chunk == 0, (nf, chunk)
    assert n % jtile == 0, (n, jtile)

    def jbody(c, acc):
        vc = lax.dynamic_slice_in_dim(v, c * jtile, jtile, axis=1)

        def fbody(k, a):
            wc = lax.dynamic_slice_in_dim(w, k * chunk, chunk, axis=0)
            vcc = lax.dynamic_slice_in_dim(vc, k * chunk, chunk, axis=0)
            return a + combine(wc, vcc)

        blk = lax.fori_loop(0, nf // chunk, fbody, jnp.zeros((m, jtile), w.dtype))
        return lax.dynamic_update_slice(acc, blk, (0, c * jtile))

    return lax.fori_loop(0, n // jtile, jbody, jnp.zeros((m, n), w.dtype))


def mgemm2_xla(w, v, *, chunk=128, jtile=4):
    """N[i, j] = sum_q min(w[q, i], v[q, j]) — hardware-min lowering."""

    def combine(wc, vc):
        return jnp.minimum(wc[:, :, None], vc[:, None, :]).sum(axis=0)

    return _min_tiled_accum(w, v, chunk, jtile, combine)


def mgemm2_ternary_xla(w, v, *, chunk=128, jtile=4):
    """Same contract with the select/ternary min (paper Table 1 row 1)."""

    def combine(wc, vc):
        a = wc[:, :, None]
        b = vc[:, None, :]
        return jnp.where(a <= b, a, b).sum(axis=0)

    return _min_tiled_accum(w, v, chunk, jtile, combine)


def gemm_xla(w, v):
    """True GEMM W^T V via the platform-native dot (the "cuBLAS" row)."""
    return w.T @ v


def rowsum_xla(v):
    """s_j = sum_q v[q, j]."""
    return v.sum(axis=0)


def block2_xla(w, v, *, chunk=128, jtile=4):
    """Fused 2-way block: (N, rowsums(W), rowsums(V)) in one offload.

    One execute() call per off-diagonal block instead of three; the Rust
    driver combines s_i + s_j and forms the quotient.
    """
    n = mgemm2_xla(w, v, chunk=chunk, jtile=jtile)
    return n, rowsum_xla(w), rowsum_xla(v)


def mgemm3_xla(vi, vj, vk, *, chunk=128, ktile=4):
    """B[t, i, k] = sum_q min(vj[q, t], vi[q, i], vk[q, k]).

    Mirrors the paper's Algorithm 3 inner pipeline: for each pivot column
    t, build X_t = vj[:, t] ∘min Vi, then a 2-way mGEMM X_t^T ∘min Vk.
    scan over t keeps the lowered module compact; the inner mGEMM uses
    the tiled schedule of [`_min_tiled_accum`] (`ktile` columns of Vk at
    a time) except when ktile is None (plain feature chunking — measured
    faster for the f32 small tier, EXPERIMENTS.md §Perf).
    """
    nf, m = vi.shape
    _, jt = vj.shape
    _, n = vk.shape

    def combine(xc, vc):
        return jnp.minimum(xc[:, :, None], vc[:, None, :]).sum(axis=0)

    def per_pivot(_, t):
        xt = jnp.minimum(vj[:, t][:, None], vi)  # [nf, m] — the X_j columns
        if ktile is None:
            def body(c, acc):
                xc = lax.dynamic_slice_in_dim(xt, c * chunk, chunk, axis=0)
                vc = lax.dynamic_slice_in_dim(vk, c * chunk, chunk, axis=0)
                return acc + combine(xc, vc)

            plane = lax.fori_loop(0, nf // chunk, body, jnp.zeros((m, n), vi.dtype))
        else:
            plane = _min_tiled_accum(xt, vk, chunk, ktile, combine)
        return None, plane

    _, slabs = lax.scan(per_pivot, None, jnp.arange(jt))
    return slabs  # [jt, m, n]


# ---------------------------------------------------------------------------
# Pallas-backed variants (Layer 1 inside the Layer 2 graph): same contracts,
# lowered through the tiled kernels so the identical HLO pipeline the TPU
# path would use is exercised end-to-end from Rust.
# ---------------------------------------------------------------------------


def mgemm2_pallas(w, v, *, bm=64, bn=64, bk=64, min_impl="minimum"):
    return mgemm_kernels.mgemm2_pallas(w, v, bm=bm, bn=bn, bk=bk, min_impl=min_impl)


def mgemm3_pallas(vi, vj, vk, *, bm=32, bn=32, bk=64, min_impl="minimum"):
    return mgemm_kernels.mgemm3_pallas(vi, vj, vk, bm=bm, bn=bn, bk=bk, min_impl=min_impl)


def gemm_pallas(w, v, *, bm=64, bn=64, bk=64):
    from compile.kernels import gemm as gemm_kernels

    return gemm_kernels.gemm_pallas(w, v, bm=bm, bn=bn, bk=bk)


def sorenson2_pallas(w, v, *, bm=64, bn=64, bk=16):
    from compile.kernels import sorenson as sorenson_kernels

    return sorenson_kernels.sorenson2_pallas(w, v, bm=bm, bn=bn, bk=bk)


def sorenson2_xla(w, v, *, chunk=16, jtile=8):
    """Bitwise Sorenson numerators as an XLA graph (§2.3): the 2-way
    mGEMM schedule with AND+popcount as the scalar contraction over
    packed uint32 words [n_w, n_v]."""

    def combine(wc, vc):
        conj = jnp.bitwise_and(wc[:, :, None], vc[:, None, :])
        return lax.population_count(conj).sum(axis=0, dtype=jnp.uint32)

    nw, m = w.shape
    _, n = v.shape
    assert nw % chunk == 0 and n % jtile == 0, (nw, chunk, n, jtile)

    def jbody(c, acc):
        vc = lax.dynamic_slice_in_dim(v, c * jtile, jtile, axis=1)

        def fbody(k, a):
            wc = lax.dynamic_slice_in_dim(w, k * chunk, chunk, axis=0)
            vcc = lax.dynamic_slice_in_dim(vc, k * chunk, chunk, axis=0)
            return a + combine(wc, vcc)

        blk = lax.fori_loop(0, nw // chunk, fbody, jnp.zeros((m, jtile), jnp.uint32))
        return lax.dynamic_update_slice(acc, blk, (0, c * jtile))

    return lax.fori_loop(0, n // jtile, jbody, jnp.zeros((m, n), jnp.uint32))
