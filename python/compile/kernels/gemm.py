"""Layer-1 Pallas true-GEMM comparator kernel.

Paper Table 1 measures the mGEMM against the true GEMM it was derived
from (MAGMA's) and against the vendor GEMM (cuBLAS). This kernel is the
"MAGMA GEMM" analogue: the *same* tiling and grid structure as
mgemm.mgemm2_pallas, with the broadcast-min inner loop replaced by an
MXU-shaped dot — so the pair isolates exactly the cost of min+add vs.
fused multiply-add, which is the paper's Table 1 comparison. The
"cuBLAS" analogue is the platform-native `jnp.matmul` graph in model.py.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gemm_kernel(w_ref, v_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # MXU-shaped contraction over the feature panel: [bm, bk] @ [bk, bn].
    o_ref[...] += jnp.dot(w_ref[...].T, v_ref[...])


def gemm_pallas(w, v, *, bm=64, bn=64, bk=64):
    """W^T V with the same BlockSpec schedule as the mGEMM kernel."""
    nf, m = w.shape
    nf2, n = v.shape
    assert nf == nf2, (nf, nf2)
    assert m % bm == 0 and n % bn == 0 and nf % bk == 0, (nf, m, n, bm, bn, bk)
    grid = (m // bm, n // bn, nf // bk)
    return pl.pallas_call(
        _gemm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bk, bm), lambda i, j, k: (k, i)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), w.dtype),
        interpret=True,
    )(w, v)
