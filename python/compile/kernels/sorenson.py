"""Layer-1 Pallas kernel: bitwise Sorenson numerators (paper §2.3).

On 0/1 data the min-product coincides with logical AND, so the mGEMM
becomes an AND+popcount contraction over words of packed bits — each
32-bit word op performs 32 elementwise comparisons, the trick behind
the very high comparison rates of Table 6's 1-bit codes.

Layout: packed uint32 words, shape [n_w, n_v] with n_w = ⌈n_f/32⌉,
vectors as columns (same convention as the float path). Output counts
are uint32 — exact for any realistic n_f.

The kernel is the mGEMM kernel with the scalar op swapped a second
time: FMA → min (paper §3.1) → AND+popcount (§2.3); the BlockSpec
schedule is identical, which is the point — the memory-hierarchy work
transfers across metric families.
"""

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl


def _sorenson_kernel(w_ref, v_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    wt = w_ref[...]  # [bk, bm] uint32 words
    vt = v_ref[...]  # [bk, bn]
    conj = jnp.bitwise_and(wt[:, :, None], vt[:, None, :])  # [bk, bm, bn]
    o_ref[...] += lax.population_count(conj).sum(axis=0, dtype=jnp.uint32)


def sorenson2_pallas(w, v, *, bm=64, bn=64, bk=16):
    """N[i, j] = Σ_w popcount(w[:, i] & v[:, j]) over packed words."""
    nw, m = w.shape
    nw2, n = v.shape
    assert nw == nw2, (nw, nw2)
    assert w.dtype == jnp.uint32 and v.dtype == jnp.uint32
    assert m % bm == 0 and n % bn == 0 and nw % bk == 0, (nw, m, n, bm, bn, bk)
    grid = (m // bm, n // bn, nw // bk)
    return pl.pallas_call(
        _sorenson_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bk, bm), lambda i, j, k: (k, i)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.uint32),
        interpret=True,
    )(w, v)
