"""Layer-1 Pallas kernels: the min-product "modified GEMM" (mGEMM).

The paper's core kernel insight (§3.1): the 2-way numerator computation
N = W^T ∘min V has the exact computational pattern of a BLAS-3 GEMM with
the scalar multiply replaced by scalar min, so it inherits a GEMM's whole
memory-hierarchy optimization stack. The authors patched MAGMA's
`gemm_stencil.cuh` FMA macro; here the same idea is expressed natively as
a tiled Pallas kernel.

TPU adaptation (DESIGN.md §Hardware-Adaptation): MAGMA's shared-memory
panel tiling becomes BlockSpec VMEM tiling; the grid's k-axis streams
feature panels HBM→VMEM while the (i, j) output tile stays resident
(Pallas keeps the output block in VMEM across grid steps whose index map
ignores k — the declarative form of the paper's double buffering). The
min+add inner loop runs on the VPU, not the MXU — the TPU analogue of the
paper's "min is not FMA" headroom observation; the true-GEMM comparator in
gemm.py uses the MXU and bounds the achievable rate from above (Table 1).

All kernels are lowered with interpret=True: real TPU lowering emits
Mosaic custom-calls the CPU PJRT plugin cannot execute, while interpret
mode lowers the identical kernel to plain HLO that the Rust runtime runs
bit-for-bit (see /opt/xla-example/README.md).

Two scalar-min implementations are provided, mirroring the paper's
Table 1 comparison of the CUDA `fmin` intrinsic against the C ternary
operator:

  min_impl="minimum"  -> jnp.minimum       (the hardware-min lowering)
  min_impl="ternary"  -> where(a <= b, a, b) (the select/branch lowering)
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _scalar_min(a, b, min_impl):
    if min_impl == "minimum":
        return jnp.minimum(a, b)
    if min_impl == "ternary":
        return jnp.where(a <= b, a, b)
    raise ValueError(f"unknown min_impl: {min_impl!r}")


def _mgemm2_kernel(w_ref, v_ref, o_ref, *, min_impl):
    """One (i, j, k) grid step: o[i, j] += sum over the k-th feature panel.

    w_ref: [bk, bm] panel of W; v_ref: [bk, bn] panel of V;
    o_ref: [bm, bn] resident output tile.
    """

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    wt = w_ref[...]  # [bk, bm]
    vt = v_ref[...]  # [bk, bn]
    # Broadcast-min over the panel, then add-reduce over q. On real TPU
    # hardware Mosaic would keep the q loop in vector registers; interpret
    # mode materializes the [bk, bm, bn] temporary (documented in the
    # kernel report emitted by aot.py).
    acc = _scalar_min(wt[:, :, None], vt[:, None, :], min_impl).sum(axis=0)
    o_ref[...] += acc


def mgemm2_pallas(w, v, *, bm=64, bn=64, bk=64, min_impl="minimum"):
    """N = W^T ∘min V via the tiled Pallas kernel.

    w: [n_f, m], v: [n_f, n] -> [m, n] with
    N[i, j] = sum_q min(w[q, i], v[q, j]).

    Tile sizes must divide the respective dimensions (artifact shapes are
    chosen to satisfy this; the Rust runtime pads blocks to artifact
    shapes — zero-padding is exact for the min-product since inputs are
    non-negative and min(0, x) = 0 contributes nothing).
    """
    nf, m = w.shape
    nf2, n = v.shape
    assert nf == nf2, (nf, nf2)
    assert m % bm == 0 and n % bn == 0 and nf % bk == 0, (nf, m, n, bm, bn, bk)
    grid = (m // bm, n // bn, nf // bk)
    kernel = functools.partial(_mgemm2_kernel, min_impl=min_impl)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bk, bm), lambda i, j, k: (k, i)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), w.dtype),
        interpret=True,
    )(w, v)


def _mgemm3_kernel(vj_ref, vi_ref, vk_ref, o_ref, *, min_impl):
    """One (i, j, k) grid step of the 3-way slab.

    vj_ref: [bk, jt] panel of the pivot columns (jt is small and kept
    whole); vi_ref: [bk, bm]; vk_ref: [bk, bn];
    o_ref: [jt, bm, bn] resident output slab.
    """

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    vjt = vj_ref[...]  # [bk, jt]
    vit = vi_ref[...]  # [bk, bm]
    vkt = vk_ref[...]  # [bk, bn]
    # X-panel: min of pivot column t with each column of Vi -> the paper's
    # X_j construction, fused with the subsequent B_j mGEMM by min
    # associativity (§3.2).
    x = _scalar_min(vjt[:, :, None], vit[:, None, :], min_impl)  # [bk, jt, bm]
    acc = _scalar_min(x[:, :, :, None], vkt[:, None, None, :], min_impl).sum(axis=0)
    o_ref[...] += acc


def mgemm3_pallas(vi, vj, vk, *, bm=32, bn=32, bk=64, min_impl="minimum"):
    """3-way min-product slab via the tiled Pallas kernel.

    vi: [n_f, m], vj: [n_f, jt], vk: [n_f, n] -> [jt, m, n] with
    out[t, i, k] = sum_q min(vj[q, t], vi[q, i], vk[q, k]).

    These are the paper's B_j entries n3'(v_i, v_j, v_k) for a batch of jt
    pivot columns (Algorithm 3's GPU-pipeline body).
    """
    nf, m = vi.shape
    nfj, jt = vj.shape
    nfk, n = vk.shape
    assert nf == nfj == nfk, (nf, nfj, nfk)
    assert m % bm == 0 and n % bn == 0 and nf % bk == 0, (nf, m, n, bm, bn, bk)
    grid = (m // bm, n // bn, nf // bk)
    kernel = functools.partial(_mgemm3_kernel, min_impl=min_impl)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bk, jt), lambda i, j, k: (k, 0)),
            pl.BlockSpec((bk, bm), lambda i, j, k: (k, i)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((jt, bm, bn), lambda i, j, k: (0, i, j)),
        out_shape=jax.ShapeDtypeStruct((jt, m, n), vi.dtype),
        interpret=True,
    )(vj, vi, vk)


def vmem_estimate_2way(bm, bn, bk, dtype_bytes):
    """VMEM working-set estimate for one 2-way grid step, in bytes.

    Panels + resident output tile; the broadcast temporary is listed
    separately because a real Mosaic lowering keeps the q-loop in vector
    registers rather than materializing it.
    """
    panels = (bk * bm + bk * bn) * dtype_bytes
    out_tile = bm * bn * dtype_bytes
    bcast_temp = bk * bm * bn * dtype_bytes
    return {"panels": panels, "out_tile": out_tile, "interpret_bcast_temp": bcast_temp}


def vmem_estimate_3way(bm, bn, bk, jt, dtype_bytes):
    """VMEM working-set estimate for one 3-way grid step, in bytes."""
    panels = (bk * jt + bk * bm + bk * bn) * dtype_bytes
    out_tile = jt * bm * bn * dtype_bytes
    bcast_temp = bk * jt * bm * bn * dtype_bytes
    return {"panels": panels, "out_tile": out_tile, "interpret_bcast_temp": bcast_temp}
