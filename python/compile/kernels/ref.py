"""Pure-jnp oracles for the CoMet-RS kernels.

These are the correctness ground truth for every kernel and compute-graph
variant in this package (Layer 1 Pallas kernels and Layer 2 XLA graphs),
and — via the AOT artifacts — transitively for the Rust runtime path.

All functions are direct, unoptimized transcriptions of the paper's
definitions (Joubert et al., Parallel Computing 2018, §2):

  n2(u, v)      = sum_q min(u_q, v_q)                  ("min-product")
  d2(u, v)      = sum_q u_q + sum_q v_q
  c2(u, v)      = 2 n2 / d2                            (2-way metric)

  n3'(u, v, w)  = sum_q min(u_q, v_q, w_q)
  n3(u, v, w)   = n2(u,v) + n2(u,w) + n2(v,w) - n3'
  d3(u, v, w)   = sum_q u_q + sum_q v_q + sum_q w_q
  c3(u, v, w)   = (3/2) n3 / d3                        (3-way metric)

Matrices hold vectors as COLUMNS: V is [n_f, n_v] (paper's layout).
"""

import jax.numpy as jnp


def mgemm2(w, v):
    """Min-product GEMM: out[i, j] = sum_q min(w[q, i], v[q, j]).

    This is M = W^T ∘min V from paper §3.1 — the BLAS-3-like kernel whose
    optimized forms live in mgemm.py (Pallas) and model.py (XLA graph).
    O(n_f · m · n) memory; small shapes only.
    """
    return jnp.minimum(w[:, :, None], v[:, None, :]).sum(axis=0)


def gemm(w, v):
    """True GEMM comparator: out = W^T V (paper Table 1 reference rows)."""
    return w.T @ v


def mgemm3(vi, vj, vk):
    """3-way min-product slab: out[t, i, k] = sum_q min(vj[q,t], vi[q,i], vk[q,k]).

    vi: [n_f, m], vj: [n_f, jt], vk: [n_f, n] -> out [jt, m, n].
    These are the paper's B_j entries n3'(v_i, v_j, v_k) for each column t
    of vj (§3.2: X_j, then B_j = X_j^T ∘min V; associativity of min folds
    the two stages into one triple min). Small shapes only.
    """
    trip = jnp.minimum(
        jnp.minimum(
            vj[:, :, None, None],  # [nf, jt, 1, 1]
            vi[:, None, :, None],  # [nf, 1,  m, 1]
        ),
        vk[:, None, None, :],  # [nf, 1, 1, n]
    )  # [nf, jt, m, n]
    return trip.sum(axis=0)


def rowsums(v):
    """Column sums s_j = sum_q v[q, j] — the d2/d3 denominator ingredient."""
    return v.sum(axis=0)


def czekanowski2(v):
    """Full 2-way Proportional Similarity matrix C[i, j] = c2(v_i, v_j)."""
    n = mgemm2(v, v)
    s = rowsums(v)
    d = s[:, None] + s[None, :]
    return 2.0 * n / d


def czekanowski3(v):
    """Full 3-way Proportional Similarity tensor C[i, j, k] = c3(v_i, v_j, v_k).

    Small n_v only (O(n_v^3) output).
    """
    n2 = mgemm2(v, v)
    n3p = jnp.minimum(
        jnp.minimum(v[:, :, None, None], v[:, None, :, None]), v[:, None, None, :]
    ).sum(axis=0)
    n3 = n2[:, :, None] + n2[:, None, :] + n2[None, :, :] - n3p
    s = rowsums(v)
    d = s[:, None, None] + s[None, :, None] + s[None, None, :]
    return 1.5 * n3 / d


def sorenson2(vbits):
    """2-way Sorenson metric numerators for 0/1 vectors (paper §2.3).

    vbits: [n_f, n_v] with entries in {0, 1}. For binary data the
    min-product coincides with logical AND, so n2 is the co-occurrence
    count. The Rust popcount baseline reproduces this from packed words.
    """
    return (vbits[:, :, None] * vbits[:, None, :]).sum(axis=0)
