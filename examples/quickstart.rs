//! Quickstart: compute all 2-way Proportional Similarity metrics for a
//! small synthetic GWAS-profile set and print the most similar pairs.
//!
//!   cargo run --release --example quickstart
//!
//! Uses the PJRT (AOT artifact) backend when artifacts are built,
//! falling back to the native optimized CPU backend otherwise.

use std::path::Path;

use comet::config::{BackendKind, InputSource, Precision, RunConfig};
use comet::coordinator::run_with_artifacts;
use comet::decomp::Grid;
use comet::util::fmt;
use comet::vecdata::SyntheticKind;

fn main() -> anyhow::Result<()> {
    let artifacts = Path::new("artifacts");
    let have_artifacts = artifacts.join("manifest.txt").exists();
    let backend = if have_artifacts {
        BackendKind::Pjrt
    } else {
        eprintln!("note: artifacts/ not built (run `make artifacts`); using native CPU backend");
        BackendKind::CpuOptimized
    };

    // 512 synthetic profile vectors of 384 features on 2 virtual nodes.
    let cfg = RunConfig {
        num_way: 2,
        nv: 512,
        nf: 384,
        precision: Precision::F32,
        backend,
        grid: Grid::new(1, 2, 1),
        input: InputSource::Synthetic { kind: SyntheticKind::PhewasLike, seed: 2018 },
        store_metrics: true,
        ..Default::default()
    };

    println!(
        "quickstart: {} vectors × {} features, 2-way Proportional Similarity, backend={}",
        cfg.nv,
        cfg.nf,
        cfg.backend.name()
    );
    let out = run_with_artifacts(&cfg, artifacts)?;
    println!(
        "computed {} unique pair metrics in {} ({} mGEMM blocks, checksum {})",
        out.stats.metrics,
        fmt::secs(out.stats.t_total),
        out.stats.mgemm2_calls,
        out.checksum.digest()
    );

    let pairs = out.pairs.expect("store_metrics was set");
    println!("\nmost similar profile pairs:");
    let mut t = fmt::Table::new(&["rank", "i", "j", "c2"]);
    for (r, e) in pairs.top_k(10).iter().enumerate() {
        t.row(&[
            (r + 1).to_string(),
            e.i.to_string(),
            e.j.to_string(),
            format!("{:.4}", e.value),
        ]);
    }
    t.print();

    let cmps = comet::metrics::counts::cmp_2way(cfg.nf, cfg.nv);
    println!(
        "\ncomparison rate: {}",
        fmt::cmp_rate(cmps as f64 / out.stats.t_total)
    );
    Ok(())
}
