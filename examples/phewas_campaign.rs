//! End-to-end PheWAS campaign — the §6.8 "realistic sample problem"
//! scaled to this testbed, exercising every layer of the stack:
//!
//!   1. generate a synthetic poplar-metabolite PheWAS dataset and write
//!      it as the paper's column-major binary input file,
//!   2. run the 2-way campaign from that file across virtual nodes with
//!      PJRT-executed AOT artifacts, writing per-node 1-byte metric
//!      files (input / compute / output phases timed separately, like
//!      Table 5),
//!   3. run the 3-way campaign for one stage of a staged pipeline,
//!   4. verify the 2-way output files round-trip, and report rates.
//!
//!   cargo run --release --example phewas_campaign [-- --nv 4096]

use std::path::Path;

use comet::config::{BackendKind, InputSource, Precision, RunConfig};
use comet::coordinator::run_with_artifacts;
use comet::decomp::Grid;
use comet::metrics::counts;
use comet::util::fmt;
use comet::vecdata::{io as vio, SyntheticKind, VectorSet};

fn main() -> anyhow::Result<()> {
    let args = comet::cli::parse(std::env::args().skip(1))?;
    // Paper: n_v = 189,625, n_f = 385 on 30–14,880 Titan nodes. Scaled
    // default: 4096 vectors on 4 virtual nodes (override with --nv).
    let nv: usize = args.parse_or("nv", 4096)?;
    let nf: usize = args.parse_or("nf", 385)?;
    let nv3: usize = args.parse_or("nv3", 256)?;
    let artifacts = Path::new("artifacts");
    anyhow::ensure!(
        artifacts.join("manifest.txt").exists(),
        "artifacts required: run `make artifacts`"
    );

    let workdir = std::env::temp_dir().join(format!("comet-phewas-{}", std::process::id()));
    std::fs::create_dir_all(&workdir)?;
    let input_path = workdir.join("phewas.bin");
    let outdir_2way = workdir.join("metrics2");

    // --- 1. Dataset generation + input file (the GWAS/EMMAX output
    //        stand-in: significant SNP↔metabolite association profiles).
    let t0 = std::time::Instant::now();
    let set: VectorSet<f32> = VectorSet::generate(SyntheticKind::PhewasLike, 20180326, nf, nv, 0);
    vio::write_raw(&input_path, &set)?;
    println!(
        "dataset: {} vectors × {} features ({}) written to {} in {}",
        nv,
        nf,
        fmt::bytes((nv * nf * 4) as u64),
        input_path.display(),
        fmt::secs(t0.elapsed().as_secs_f64())
    );

    // --- 2. 2-way campaign from file, per-node output files.
    let cfg2 = RunConfig {
        num_way: 2,
        nv,
        nf,
        precision: Precision::F32, // §6.8 runs in single precision
        backend: BackendKind::Pjrt,
        grid: Grid::new(1, 4, 1),
        input: InputSource::File { path: input_path.to_string_lossy().into_owned() },
        store_metrics: false, // stream to files, like the real campaign
        output_dir: Some(outdir_2way.to_string_lossy().into_owned()),
        ..Default::default()
    };
    println!("\n2-way campaign: grid (1,4,1), single precision, PJRT backend");
    let out2 = run_with_artifacts(&cfg2, artifacts)?;
    let np = cfg2.grid.np();
    let mut table = fmt::Table::new(&["num way", "n_f", "input", "metrics comp", "output", "cmp rate/node"]);
    let cmp2 = counts::cmp_2way(nf, nv) as f64;
    table.row(&[
        "2".into(),
        nf.to_string(),
        fmt::secs(out2.stats.t_input),
        fmt::secs(out2.stats.t_compute),
        fmt::secs(out2.stats.t_output),
        fmt::cmp_rate(cmp2 / out2.stats.t_total / np as f64),
    ]);

    // --- 3. 3-way campaign, final stage only (the paper computes the
    //        last of 220 stages; we compute the last of 4 on a smaller
    //        vector subset — O(n³) output). The subset is its own input
    //        file (the raw format is headerless, so dims must match).
    let input3_path = workdir.join("phewas3.bin");
    let set3: VectorSet<f32> =
        VectorSet::generate(SyntheticKind::PhewasLike, 20180326, nf, nv3, 0);
    vio::write_raw(&input3_path, &set3)?;
    let cfg3 = RunConfig {
        num_way: 3,
        nv: nv3,
        nf,
        precision: Precision::F32,
        backend: BackendKind::Pjrt,
        grid: Grid::new(1, 4, 3),
        num_stage: 4,
        stage: Some(3),
        input: InputSource::File { path: input3_path.to_string_lossy().into_owned() },
        store_metrics: false,
        ..Default::default()
    };
    println!("3-way campaign: grid (1,4,3), final stage of 4");
    let out3 = run_with_artifacts(&cfg3, artifacts)?;
    let frac3 = out3.stats.metrics as f64 / comet::metrics::indexing::num_triples(nv3) as f64;
    let cmp3 = counts::cmp_3way(nf, nv3) as f64 * frac3;
    table.row(&[
        "3".into(),
        nf.to_string(),
        fmt::secs(out3.stats.t_input),
        fmt::secs(out3.stats.t_compute),
        "-".into(),
        fmt::cmp_rate(cmp3 / out3.stats.t_total / cfg3.grid.np() as f64),
    ]);
    println!("\nTable-5-style summary (this testbed):");
    table.print();

    // --- 4. Validate the output files (formulaic indexing, §6.8).
    let mut total_bytes = 0usize;
    for rank in 0..np {
        let p = outdir_2way.join(format!("metrics_{rank}.bin"));
        total_bytes += comet::output::read_dense(&p)?.len();
    }
    anyhow::ensure!(
        total_bytes as u64 == out2.stats.metrics,
        "output files hold {total_bytes} metrics, expected {}",
        out2.stats.metrics
    );
    println!(
        "\noutput verified: {} metric bytes across {np} node files == {} computed metrics",
        total_bytes, out2.stats.metrics
    );
    println!(
        "accelerator time: 2-way {} | 3-way {} (of {} / {} total)",
        fmt::secs(out2.stats.t_accel),
        fmt::secs(out3.stats.t_accel),
        fmt::secs(out2.stats.t_total),
        fmt::secs(out3.stats.t_total),
    );
    println!("comm: 2-way {} | 3-way {}", fmt::bytes(out2.stats.comm_bytes), fmt::bytes(out3.stats.comm_bytes));
    std::fs::remove_dir_all(&workdir).ok();
    Ok(())
}
