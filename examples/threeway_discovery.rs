//! 3-way discovery: the paper's motivating science case (Weighill &
//! Jacobson's hypergraph networks — reference [6]) on synthetic data:
//! find vector triples whose 3-way Proportional Similarity is high but
//! which no single 2-way edge would surface.
//!
//!   cargo run --release --example threeway_discovery

use std::path::Path;
use std::sync::Arc;

use comet::config::{BackendKind, Precision};
use comet::coordinator::backend::{make_backend, Backend};
use comet::coordinator::serial;
use comet::runtime::PjrtService;
use comet::util::fmt;
use comet::vecdata::{SyntheticKind, VectorSet};

fn main() -> anyhow::Result<()> {
    let artifacts = Path::new("artifacts");
    let (service, backend): (Option<PjrtService>, Arc<dyn Backend<f32>>) =
        if artifacts.join("manifest.txt").exists() {
            let svc = PjrtService::start(artifacts)?;
            let be = make_backend::<f32>(BackendKind::Pjrt, Precision::F32, Some(svc.client()), 1)?;
            (Some(svc), be)
        } else {
            eprintln!("note: artifacts not built; using native CPU backend");
            (None, make_backend::<f32>(BackendKind::CpuOptimized, Precision::F32, None, 1)?)
        };

    // 160 sparse profiles; sparse supports make 3-way structure likely.
    let v: VectorSet<f32> = VectorSet::generate(SyntheticKind::PhewasLike, 6, 256, 160, 0);
    println!(
        "3-way discovery over {} vectors × {} features (backend {})",
        v.nv,
        v.nf,
        backend.name()
    );

    let t0 = std::time::Instant::now();
    let pairs = serial::all_pairs(&backend, &v)?;
    let triples = serial::all_triples(&backend, &v)?;
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "computed {} pairs + {} triples in {}",
        pairs.len(),
        triples.len(),
        fmt::secs(dt)
    );

    // 2-way lookup for the "hidden triple" analysis.
    let dense2 = pairs.to_dense(v.nv);
    let pair_val = |a: usize, b: usize| -> f64 {
        dense2[comet::metrics::indexing::pair_offset(a.min(b), a.max(b))].unwrap()
    };

    println!("\ntop triples by c3:");
    let mut t = fmt::Table::new(&["rank", "(i, j, k)", "c3", "max pairwise c2", "lift"]);
    for (r, e) in triples.top_k(12).iter().enumerate() {
        let (i, j, k) = (e.i as usize, e.j as usize, e.k as usize);
        let best2 = pair_val(i, j).max(pair_val(i, k)).max(pair_val(j, k));
        t.row(&[
            (r + 1).to_string(),
            format!("({i}, {j}, {k})"),
            format!("{:.4}", e.value),
            format!("{best2:.4}"),
            format!("{:.2}", e.value / best2.max(1e-9)),
        ]);
    }
    t.print();

    // Triples that 2-way analysis would MISS: high c3, all pairwise c2
    // below a screening threshold — the paper's case for 3-way methods
    // ("relationships not discoverable by means of 2-way methods alone").
    // Screen at the 99.9th percentile of the pairwise distribution — a
    // realistic "edges kept in the 2-way network" cutoff.
    let screen = {
        let mut vals: Vec<f64> = pairs.iter().map(|e| e.value).collect();
        vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        vals[(vals.len() as f64 * 0.999) as usize]
    };
    let mut hidden: Vec<_> = triples
        .iter()
        .filter(|e| {
            let (i, j, k) = (e.i as usize, e.j as usize, e.k as usize);
            pair_val(i, j) < screen && pair_val(i, k) < screen && pair_val(j, k) < screen
        })
        .collect();
    hidden.sort_by(|a, b| b.value.partial_cmp(&a.value).unwrap());
    let strong3: Vec<_> = hidden
        .iter()
        .filter(|e| e.value > screen)
        .collect();
    println!(
        "\n2-way screen at c2 ≥ {screen:.4} (99.9th pct): {} triples have NO screened edge;",
        hidden.len()
    );
    println!(
        "of those, {} still exceed the screen in c3 — discoverable only 3-way (paper ref [6]):",
        strong3.len()
    );
    for e in strong3.iter().take(5) {
        println!("  ({}, {}, {})  c3 = {:.4}", e.i, e.j, e.k, e.value);
    }
    drop(service);
    Ok(())
}
