//! Virtual-cluster scaling study: weak-scale a 2-way campaign across
//! growing virtual node counts and report per-node comparison rates —
//! the shape of the paper's Figure 7/8 experiment at simulation scale.
//!
//!   cargo run --release --example scaling_study [-- --max-np 8]

use comet::config::{BackendKind, InputSource, Precision, RunConfig};
use comet::coordinator::run;
use comet::decomp::{two_way, Grid};
use comet::metrics::counts;
use comet::util::fmt;
use comet::vecdata::SyntheticKind;

fn main() -> anyhow::Result<()> {
    let args = comet::cli::parse(std::env::args().skip(1))?;
    let max_np: usize = args.parse_or("max-np", 8)?;
    let nvp: usize = args.parse_or("nvp", 192)?; // vectors per node
    let nf: usize = args.parse_or("nf", 384)?;

    // Fixed per-node load ℓ, npr scaled per §6.6: npr = ⌈(npv/2+1)/ℓ⌉.
    let load = 2;
    println!("weak scaling: {nvp} vectors/node × {nf} features, load ℓ = {load}, native backend");
    let mut table = fmt::Table::new(&[
        "npv", "npr", "np", "nv", "time", "agg cmp/s", "agg ops/s", "comm",
    ]);
    for npv in 1..=max_np {
        let npr = two_way::npr_for_load(npv, load);
        let np = npv * npr;
        let nv = nvp * npv;
        let cfg = RunConfig {
            num_way: 2,
            nv,
            nf,
            precision: Precision::F64,
            backend: BackendKind::CpuOptimized,
            grid: Grid::new(1, npv, npr),
            input: InputSource::Synthetic { kind: SyntheticKind::RandomGrid, seed: 9 },
            store_metrics: false,
            ..Default::default()
        };
        let out = run(&cfg)?;
        let cmps = counts::cmp_2way(nf, nv) as f64;
        let ops = counts::ops_2way_numerators(nf, nv) as f64;
        table.row(&[
            npv.to_string(),
            npr.to_string(),
            np.to_string(),
            nv.to_string(),
            fmt::secs(out.stats.t_total),
            fmt::cmp_rate(cmps / out.stats.t_total),
            fmt::rate(ops / out.stats.t_total),
            fmt::bytes(out.stats.comm_bytes),
        ]);
    }
    table.print();
    println!(
        "\nNB: all virtual nodes share one physical core, so total work grows with np\n\
         while the core's throughput is fixed — the weak-scaling figure of merit here\n\
         is the AGGREGATE rate staying flat (no coordination overhead as np grows);\n\
         on real hardware flat-aggregate ⇔ flat per-node rate, the paper's Fig. 7."
    );
    Ok(())
}
