//! Session-first API tour: one long-lived [`Session`] serving a
//! multi-metric campaign over a single ingested dataset — the
//! ingest-once amortization the production paper runs rely on.
//!
//!   cargo run --release --example session_campaign [-- --nv 1024]
//!
//! What it shows:
//!   1. a [`Dataset`] handle whose per-node blocks are ingested once
//!      per representation and shared by every request that follows,
//!   2. typed [`RunRequest`]s replacing ad-hoc RunConfig mutation,
//!   3. a streaming [`ForwardSink`] consuming result tiles with memory
//!      bounded by one tile (the serving path),
//!   4. the amortization ledger: block ingests vs what one-shot runs
//!      would have loaded.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use comet::decomp::Grid;
use comet::metrics::MetricId;
use comet::output::sink::ForwardSink;
use comet::session::{DatasetSpec, RunRequest, Session};
use comet::util::fmt;
use comet::vecdata::SyntheticKind;

fn main() -> anyhow::Result<()> {
    let args = comet::cli::parse(std::env::args().skip(1))?;
    let nv: usize = args.parse_or("nv", 1024)?;
    let nf: usize = args.parse_or("nf", 384)?;
    args.reject_unknown()?;

    let session = Session::new();
    // Allele-count vectors serve all three metric families: CCC reads
    // them natively, Czekanowski treats them as non-negative profiles,
    // Sorensen binarizes them.
    let ds = session.dataset(DatasetSpec::synthetic(SyntheticKind::Alleles, 2018, nf, nv));
    println!(
        "session campaign: {} vectors × {} features, one dataset handle, native CPU backend\n",
        nv, nf
    );

    let grid = Grid::new(1, 4, 1);
    let mut fresh_loads = 0u64;
    let mut table = fmt::Table::new(&["request", "metrics", "t_input", "t_total", "new ingests"]);
    let mut run_collect = |name: &str, req: &RunRequest| -> anyhow::Result<()> {
        let before = ds.ingest_count();
        let out = session.run_collect(req)?;
        fresh_loads += req.config().grid.np() as u64;
        table.row(&[
            name.to_string(),
            out.stats.metrics.to_string(),
            fmt::secs(out.stats.t_input),
            fmt::secs(out.stats.t_total),
            (ds.ingest_count() - before).to_string(),
        ]);
        Ok(())
    };

    // 1) CCC ingests the float blocks …
    let ccc = RunRequest::builder(ds.clone(), MetricId::Ccc).grid(grid).build()?;
    run_collect("ccc (ingests float blocks)", &ccc)?;
    // 2) … which Czekanowski then reuses (same repr, zero new ingests),
    //    across repeated runs.
    let cz = RunRequest::builder(ds.clone(), MetricId::Czekanowski)
        .grid(grid)
        .threads(2)
        .build()?;
    run_collect("czekanowski (reuses them)", &cz)?;
    run_collect("czekanowski (again)", &cz)?;
    // 3) Sorensen packs its own bit-planes — once.
    let sor = RunRequest::builder(ds.clone(), MetricId::Sorenson).grid(grid).build()?;
    run_collect("sorenson (packs once)", &sor)?;
    run_collect("sorenson (again)", &sor)?;
    drop(run_collect); // release the table/ledger borrows
    table.print();

    // 4) The serving path: stream tiles through a ForwardSink — no
    //    store, memory bounded by one tile.
    let tiles = Arc::new(AtomicU64::new(0));
    let best_bits = Arc::new(AtomicU64::new(0));
    let (t2, b2) = (Arc::clone(&tiles), Arc::clone(&best_bits));
    let forward = ForwardSink::new(move |_rank, tile| {
        t2.fetch_add(1, Ordering::Relaxed);
        if let comet::output::sink::Tile::Pairs { entries, .. } = &tile {
            for e in entries {
                b2.fetch_max(e.value.to_bits(), Ordering::Relaxed);
            }
        }
        Ok(())
    });
    let out = session.run(&cz, &forward)?;
    println!(
        "\nstreamed run: {} metrics in {} tiles, max c2 = {:.4}, stores materialized: {}",
        out.stats.metrics,
        tiles.load(Ordering::Relaxed),
        f64::from_bits(best_bits.load(Ordering::Relaxed)),
        out.pairs.is_some(),
    );
    fresh_loads += cz.config().grid.np() as u64;

    println!(
        "\namortization: {} block ingests served {} runs (one-shot would have loaded {} blocks)",
        ds.ingest_count(),
        6,
        fresh_loads,
    );
    Ok(())
}
