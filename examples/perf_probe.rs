//! §Perf probe: time artifacts through the real PJRT runtime.
//! Usage: perf_probe [artifact_dir] — times every mgemm2-kind artifact
//! found in the manifest at the (384, 128) probe shape.
use comet::config::Precision;
use comet::runtime::{ops::BlockOps, PjrtService};
use comet::vecdata::{SyntheticKind, VectorSet};

fn main() {
    let dir = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    let svc = PjrtService::start(std::path::Path::new(&dir)).unwrap();
    let client = svc.client();
    let v32: VectorSet<f32> = VectorSet::generate(SyntheticKind::RandomGrid, 1, 384, 128, 0);
    let v64: VectorSet<f64> = VectorSet::generate(SyntheticKind::RandomGrid, 1, 384, 128, 0);
    let gops = comet::metrics::counts::ops_mgemm_block(384, 128, 128) as f64 / 1e9;
    let names: Vec<(String, comet::runtime::ElemKind)> = client
        .manifest()
        .entries
        .iter()
        .filter(|e| e.kind == "mgemm2" && e.nf == 384)
        .map(|e| (e.name.clone(), e.precision))
        .collect();
    for (name, prec) in names {
        let prec = match prec {
            comet::runtime::ElemKind::F32 => Precision::F32,
            comet::runtime::ElemKind::F64 => Precision::F64,
            comet::runtime::ElemKind::U32 => continue,
        };
        let ops = BlockOps::new(client.clone(), prec);
        let iters = 10;
        let time = match prec {
            Precision::F32 => {
                let _ = ops.mgemm2_named(&name, &v32, &v32).unwrap();
                let t0 = std::time::Instant::now();
                for _ in 0..iters {
                    std::hint::black_box(ops.mgemm2_named(&name, &v32, &v32).unwrap());
                }
                t0.elapsed().as_secs_f64() / iters as f64
            }
            Precision::F64 => {
                let _ = ops.mgemm2_named(&name, &v64, &v64).unwrap();
                let t0 = std::time::Instant::now();
                for _ in 0..iters {
                    std::hint::black_box(ops.mgemm2_named(&name, &v64, &v64).unwrap());
                }
                t0.elapsed().as_secs_f64() / iters as f64
            }
        };
        println!("{name:<28} {:.2} ms  {:.2} Gop/s", time * 1e3, gops / time);
    }
}
