//! Sorenson metric on bit-packed binary data (paper §2.3): the
//! min-product coincides with logical AND on 0/1 vectors, so packed
//! words + popcount run the same metric orders of magnitude faster —
//! the trick behind the 1-bit codes of Table 6.
//!
//!   cargo run --release --example sorenson_bits

use comet::linalg::sorenson;
use comet::util::fmt;
use comet::util::timer::bench_run;
use comet::vecdata::bits::BitVectorSet;

fn main() -> anyhow::Result<()> {
    let (nf, nv) = (4096, 256); // matches the m-tier sorenson artifact exactly
    let bits = BitVectorSet::generate(31, nf, nv, 0.25);
    println!("Sorenson 2-way over {nv} binary vectors × {nf} features (packed u64 words)");

    // Bitwise popcount path.
    let stats_bits = bench_run("sorenson-popcount", 1, 3, || {
        let s = sorenson::sorenson_all_pairs(&bits);
        std::hint::black_box(s.len());
    });

    // Same metric through the float mGEMM (the §2.3 equivalence).
    let floats = bits.to_floats();
    let stats_float = bench_run("float-mgemm", 1, 3, || {
        let n = comet::linalg::optimized::mgemm2(&floats, &floats);
        std::hint::black_box(n.data.len());
    });

    // And through the FULL three-layer stack: the packed-u32 AND+popcount
    // artifact (Pallas/XLA lowering) executed via PJRT.
    let artifacts = std::path::Path::new("artifacts");
    let pjrt = if artifacts.join("manifest.txt").exists() {
        let svc = comet::runtime::PjrtService::start(artifacts)?;
        let ops = comet::runtime::ops::BlockOps::new(
            svc.client(),
            comet::config::Precision::F32,
        );
        let _ = ops.sorenson2("sorenson2", &bits, &bits)?; // warm/compile
        let t = bench_run("sorenson-pjrt", 1, 3, || {
            std::hint::black_box(ops.sorenson2("sorenson2", &bits, &bits).unwrap().data.len());
        })
        .median();
        // Exactness check vs the native popcount path.
        let a = ops.sorenson2("sorenson2", &bits, &bits)?;
        let b = sorenson::sorenson_mgemm(&bits, &bits);
        assert_eq!(a.max_abs_diff(&b), 0.0, "PJRT vs popcount must be exact");
        Some(t)
    } else {
        None
    };

    let cmps = sorenson::cmp_count(nf, nv) as f64;
    let mut t = fmt::Table::new(&["path", "time", "cmp/s", "speedup"]);
    let tb = stats_bits.median();
    let tf = stats_float.median();
    t.row(&[
        "bit-packed popcount (native)".into(),
        fmt::secs(tb),
        fmt::cmp_rate(cmps / tb),
        format!("{:.1}×", tf / tb),
    ]);
    if let Some(tp) = pjrt {
        t.row(&[
            "bit-packed AND+popcount (PJRT artifact)".into(),
            fmt::secs(tp),
            fmt::cmp_rate(cmps / tp),
            format!("{:.1}×", tf / tp),
        ]);
    }
    t.row(&[
        "float mGEMM (native)".into(),
        fmt::secs(tf),
        fmt::cmp_rate(cmps / tf),
        "1.0×".into(),
    ]);
    t.print();

    // Verify the §2.3 coincidence on a sample.
    let store = sorenson::sorenson_all_pairs(&bits);
    let mut checked = 0;
    for e in store.iter().take(500) {
        let c2 = comet::metrics::czekanowski2(
            floats.col(e.i as usize),
            floats.col(e.j as usize),
        );
        assert!((e.value - c2).abs() < 1e-12);
        checked += 1;
    }
    println!("\nverified Sorenson == Proportional Similarity on {checked} binary pairs (§2.3)");
    Ok(())
}
