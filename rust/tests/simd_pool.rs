//! The ISSUE 6 kernel-escalation contracts: SIMD-shaped inner loops and
//! the persistent worker pool.
//!
//! 1. The wide-lane popcount sweeps ([`comet::linalg::simd`]) are
//!    **bit-identical** to naive scalar sweeps on packed sets whose
//!    feature counts straddle 64/128-bit word boundaries — including
//!    partial trailing words (property test).
//! 2. Checksums are invariant across thread counts, metrics, and
//!    backends now that the multi-threaded drivers dispatch to the
//!    pool instead of per-call `std::thread::scope` spawns — the
//!    pool-vs-scoped replacement must be observationally identical.
//! 3. Steady state does **zero per-kernel-call thread spawns**: once
//!    warm, many kernel calls grow `scopes`/`tasks` but never
//!    `threads_spawned` (the amortization contract).
//! 4. `coordinator::RunStats` surfaces the per-run pool deltas, so a
//!    session's second run reports zero spawns.
//!
//! Pool counters are process-global, so every test here serializes on
//! [`lock`] — cargo's in-process test threads would otherwise pollute
//! the deltas.

use std::sync::{Mutex, MutexGuard, OnceLock};

use comet::config::{BackendKind, InputSource, Precision, RunConfig};
use comet::coordinator::run;
use comet::decomp::Grid;
use comet::linalg::{optimized, pool, simd, sorenson};
use comet::metrics::MetricId;
use comet::output::sink::DiscardSink;
use comet::session::Session;
use comet::testkit::forall;
use comet::vecdata::bits::BitVectorSet;
use comet::vecdata::{SyntheticKind, VectorSet};

fn lock() -> MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(|p| p.into_inner())
}

fn cfg_for(metric: MetricId, nf: usize, nv: usize, seed: u64) -> RunConfig {
    let kind = match metric {
        MetricId::Ccc => SyntheticKind::Alleles,
        _ => SyntheticKind::RandomGrid,
    };
    RunConfig {
        metric,
        num_way: 2,
        nv,
        nf,
        precision: Precision::F64,
        backend: BackendKind::CpuOptimized,
        grid: Grid::new(1, 1, 1),
        input: InputSource::Synthetic { kind, seed },
        store_metrics: false,
        ..Default::default()
    }
}

/// Naive one-accumulator oracle for the wide-lane sweeps.
fn scalar_popcount(words: &[u64]) -> u64 {
    words.iter().map(|w| w.count_ones() as u64).sum()
}

#[test]
fn prop_simd_popcounts_bit_identical_across_word_boundaries() {
    let _g = lock();
    // nf in 1..=300 crosses the 64/128/192/256-bit word boundaries, so
    // packed vectors exercise every partial-trailing-word shape the
    // LANES-chunked sweep can see (word counts 1..=5: below, at, and
    // above the LANES stride).
    forall(
        "simd-popcount-vs-scalar",
        40,
        |g| {
            let nf = g.usize_in(1, 300);
            let nv = g.usize_in(1, 12);
            let density = *g.pick(&[0.0, 0.15, 0.5, 1.0]);
            let seed = g.stream.next_u64();
            (nf, nv, density, seed)
        },
        |&(nf, nv, density, seed)| {
            let bits = BitVectorSet::generate(seed, nf, nv, density);
            for u in 0..nv {
                let w = bits.words(u);
                let direct = (0..nf).filter(|&q| bits.get_bit(u, q)).count() as u64;
                if simd::popcount(w) != scalar_popcount(w) {
                    return Err(format!("popcount lanes diverge at nf={nf} u={u}"));
                }
                if bits.popcount(u) != direct {
                    return Err(format!(
                        "popcount {} != per-bit {direct} at nf={nf} u={u}",
                        bits.popcount(u)
                    ));
                }
                for v in 0..nv {
                    let and_direct = (0..nf)
                        .filter(|&q| bits.get_bit(u, q) && bits.get_bit(v, q))
                        .count() as u64;
                    if simd::and_popcount(w, bits.words(v)) != and_direct {
                        return Err(format!("and_popcount diverges at nf={nf} ({u},{v})"));
                    }
                }
            }
            // The ingest-time cache serves the same values.
            let expect: Vec<f64> = (0..nv).map(|v| scalar_popcount(bits.words(v)) as f64).collect();
            if bits.popcounts_cached() != expect.as_slice() {
                return Err("cached popcounts diverge from scalar sweep".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_pool_dispatch_matches_serial_bitwise() {
    let _g = lock();
    // The pooled multi-thread drivers must reproduce the serial kernels
    // bit-for-bit — same contract the scoped-spawn drivers had, now
    // pinned against the pool executor (shapes straddle JT/BI tiles and
    // packed word boundaries).
    forall(
        "pool-vs-serial-bitwise",
        20,
        |g| {
            let nf = g.usize_in(1, 140);
            let nv = g.usize_in(2, 70);
            let threads = *g.pick(&[2usize, 4, 8]);
            let seed = g.stream.next_u64();
            (nf, nv, threads, seed)
        },
        |&(nf, nv, threads, seed)| {
            let v: VectorSet<f64> = VectorSet::generate(SyntheticKind::RandomGrid, seed, nf, nv, 0);
            let bits = BitVectorSet::from_threshold(&v, 0.5);
            for (what, serial, pooled) in [
                ("mgemm2", optimized::mgemm2(&v, &v), optimized::mgemm2_mt(&v, &v, threads)),
                ("mgemm2-tri", optimized::mgemm2_tri(&v), optimized::mgemm2_tri_mt(&v, threads)),
                ("gemm", optimized::gemm(&v, &v), optimized::gemm_mt(&v, &v, threads)),
                ("gemm-tri", optimized::gemm_tri(&v), optimized::gemm_tri_mt(&v, threads)),
                (
                    "sorenson",
                    sorenson::sorenson_mgemm(&bits, &bits),
                    sorenson::sorenson_mgemm_mt(&bits, &bits, threads),
                ),
                (
                    "sorenson-tri",
                    sorenson::sorenson_mgemm_tri(&bits),
                    sorenson::sorenson_mgemm_tri_mt(&bits, threads),
                ),
            ] {
                for i in 0..nv {
                    for j in 0..nv {
                        let (a, b) = (serial.at(i, j), pooled.at(i, j));
                        if a.to_bits() != b.to_bits() {
                            return Err(format!("{what} threads={threads} ({i},{j}): {a} != {b}"));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn checksums_invariant_across_threads_metrics_backends_on_pool() {
    let _g = lock();
    let (nf, nv) = (60, 26);
    for metric in MetricId::ALL {
        let mut digests = Vec::new();
        for threads in [1usize, 2, 4, 8] {
            let mut cfg = cfg_for(metric, nf, nv, 17);
            cfg.threads = threads;
            digests.push(run(&cfg).unwrap().checksum.digest());
        }
        let mut cfg = cfg_for(metric, nf, nv, 17);
        cfg.backend = BackendKind::CpuReference;
        digests.push(run(&cfg).unwrap().checksum.digest());
        assert!(
            digests.iter().all(|d| *d == digests[0]),
            "{}: digests diverge across pool thread counts/backends: {digests:?}",
            metric.name()
        );
    }
}

#[test]
fn warm_pool_steady_state_spawns_zero_threads() {
    let _g = lock();
    let (nf, nv) = (80, 64);
    let v: VectorSet<f64> = VectorSet::generate(SyntheticKind::RandomGrid, 5, nf, nv, 0);
    let bits = BitVectorSet::from_threshold(&v, 0.5);
    // Warm to the largest parallelism this binary uses, then snapshot.
    pool::warm(8);
    let before = pool::stats();
    assert!(before.workers >= 8);
    // Many kernel calls across every family and thread count — the
    // serving-layer steady state the pool exists for.
    for _ in 0..4 {
        for threads in [2usize, 4, 8] {
            std::hint::black_box(optimized::mgemm2_mt(&v, &v, threads));
            std::hint::black_box(optimized::mgemm2_tri_mt(&v, threads));
            std::hint::black_box(optimized::gemm_tri_mt(&v, threads));
            std::hint::black_box(sorenson::sorenson_mgemm_mt(&bits, &bits, threads));
            std::hint::black_box(sorenson::sorenson_mgemm_tri_mt(&bits, threads));
        }
    }
    let after = pool::stats();
    assert_eq!(
        after.threads_spawned, before.threads_spawned,
        "steady state must not spawn threads per kernel call"
    );
    assert!(after.scopes >= before.scopes + 60, "every MT call dispatches a scope");
    assert!(after.tasks > before.tasks, "scopes carry tasks");
    assert_eq!(after.workers, before.workers);
}

#[test]
fn run_stats_surface_pool_deltas_and_second_run_spawns_nothing() {
    let _g = lock();
    let mut cfg = cfg_for(MetricId::Czekanowski, 64, 48, 23);
    cfg.threads = 4;
    let session = Session::new();
    let req = session.request_from_config(&cfg).unwrap();
    // First run: Session::run warms the pool before compute, so even
    // run #1 does its kernel calls spawn-free; counters must register
    // the dispatch activity either way.
    let first = session.run(&req, &DiscardSink).unwrap();
    assert!(first.stats.pool_scopes > 0, "threads=4 run must dispatch to the pool");
    assert!(first.stats.pool_tasks >= first.stats.pool_scopes);
    // Second run against the warm pool: zero spawns, same dispatch.
    let second = session.run(&req, &DiscardSink).unwrap();
    assert_eq!(
        second.stats.pool_threads_spawned, 0,
        "second run of a session must reuse parked workers"
    );
    assert!(second.stats.pool_scopes > 0);
    // Single-threaded runs never touch the pool.
    let mut serial_cfg = cfg_for(MetricId::Czekanowski, 64, 48, 23);
    serial_cfg.threads = 1;
    let sreq = session.request_from_config(&serial_cfg).unwrap();
    let serial = session.run(&sreq, &DiscardSink).unwrap();
    assert_eq!(serial.stats.pool_scopes, 0);
    assert_eq!(serial.stats.pool_tasks, 0);
    assert_eq!(serial.stats.pool_threads_spawned, 0);
}
