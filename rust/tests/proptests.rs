//! Property tests (hand-rolled harness — `comet::testkit`): randomized
//! sweeps of the decomposition, checksum, indexing, and coordinator
//! invariants that the paper's correctness story depends on.

use std::collections::HashMap;

use comet::config::{BackendKind, InputSource, Precision, RunConfig};
use comet::coordinator::run;
use comet::decomp::partition::Partition;
use comet::decomp::{three_way, two_way, Grid};
use comet::metrics::{self, indexing};
use comet::testkit::{assert_close, forall};
use comet::vecdata::{SyntheticKind, VectorSet};

#[test]
fn prop_partition_covers_and_balances() {
    forall(
        "partition-coverage",
        200,
        |g| (g.usize_in(0, 200), g.usize_in(1, 17)),
        |&(n, parts)| {
            let p = Partition::new(n, parts);
            let mut seen = vec![0u8; n];
            let mut min = usize::MAX;
            let mut max = 0;
            for part in 0..parts {
                let len = p.len(part);
                min = min.min(len);
                max = max.max(len);
                for i in p.range(part) {
                    seen[i] += 1;
                }
            }
            if seen.iter().any(|&c| c != 1) {
                return Err("not a partition".into());
            }
            if max - min > 1 {
                return Err(format!("imbalance {min}..{max}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_2way_plan_unique_coverage() {
    forall(
        "2way-circulant-coverage",
        100,
        |g| (g.usize_in(1, 20), g.usize_in(1, 5)),
        |&(npv, npr)| {
            let mut seen: HashMap<(usize, usize), usize> = HashMap::new();
            for pv in 0..npv {
                for pr in 0..npr {
                    for s in two_way::plan(npv, npr, pv, pr) {
                        if let Some(b) = s.compute {
                            let key =
                                (b.row_block.min(b.col_block), b.row_block.max(b.col_block));
                            *seen.entry(key).or_insert(0) += 1;
                        }
                    }
                }
            }
            let expect = npv + npv * (npv - 1) / 2;
            if seen.len() != expect {
                return Err(format!("{} blocks, want {expect}", seen.len()));
            }
            if let Some((k, c)) = seen.iter().find(|(_, &c)| c != 1) {
                return Err(format!("block {k:?} computed {c} times"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_3way_slices_unique_triple_coverage() {
    forall(
        "3way-slice-coverage",
        25,
        |g| {
            let npv = g.usize_in(1, 6);
            let nvb = g.usize_in(1, 4);
            let npr = g.usize_in(1, 3);
            let nst = g.usize_in(1, 3);
            (npv * nvb.max(3), npv, npr, nst)
        },
        |&(nv, npv, npr, nst)| {
            let blocks = Partition::new(nv, npv);
            let mut counts: HashMap<(usize, usize, usize), usize> = HashMap::new();
            for pv in 0..npv {
                for pr in 0..npr {
                    for slice in three_way::slices_for_node(npv, npr, pv, pr) {
                        for stage in 0..nst {
                            for t in three_way::slice_triples(&slice, &blocks, nst, stage) {
                                *counts.entry(t).or_insert(0) += 1;
                            }
                        }
                    }
                }
            }
            let expect = nv * (nv - 1) * (nv - 2) / 6;
            if counts.len() != expect {
                return Err(format!("{} triples, want {expect}", counts.len()));
            }
            if let Some((t, c)) = counts.iter().find(|(_, &c)| c != 1) {
                return Err(format!("triple {t:?} seen {c}×"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_pair_triple_offset_bijection() {
    forall(
        "offset-bijection",
        300,
        |g| g.usize_in(0, 5_000_000),
        |&off| {
            let (i, j) = indexing::pair_from_offset(off);
            if !(i < j && indexing::pair_offset(i, j) == off) {
                return Err(format!("pair offset {off} -> ({i},{j})"));
            }
            let (a, b, c) = indexing::triple_from_offset(off);
            if !(a < b && b < c && indexing::triple_offset(a, b, c) == off) {
                return Err(format!("triple offset {off} -> ({a},{b},{c})"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_metric_bounds_and_symmetry() {
    forall(
        "metric-bounds",
        60,
        |g| {
            let nf = g.usize_in(4, 64);
            let seed = g.stream.next_u64();
            let v: VectorSet<f64> = VectorSet::generate(SyntheticKind::RandomGrid, seed, nf, 6, 0);
            v
        },
        |v| {
            for (i, j) in indexing::pairs(v.nv) {
                let c = metrics::czekanowski2(v.col(i), v.col(j));
                if !(0.0..=1.0 + 1e-12).contains(&c) {
                    return Err(format!("c2({i},{j}) = {c} out of range"));
                }
                if c != metrics::czekanowski2(v.col(j), v.col(i)) {
                    return Err("c2 asymmetric".into());
                }
            }
            for (i, j, k) in indexing::triples(v.nv) {
                let c = metrics::czekanowski3(v.col(i), v.col(j), v.col(k));
                if !(0.0..=1.0 + 1e-12).contains(&c) {
                    return Err(format!("c3({i},{j},{k}) = {c} out of range"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_min_product_gemm_identity_on_self() {
    // n2(v, v) = Σv and the mGEMM matrix is symmetric for W = V.
    forall(
        "mgemm-self",
        40,
        |g| {
            let nf = g.usize_in(2, 48);
            let nv = g.usize_in(2, 10);
            let seed = g.stream.next_u64();
            VectorSet::<f64>::generate(SyntheticKind::RandomGrid, seed, nf, nv, 0)
        },
        |v| {
            let n = comet::linalg::optimized::mgemm2(v, v);
            let sums = v.col_sums();
            for i in 0..v.nv {
                assert_close(n.at(i, i), sums[i], 1e-12, "diag")?;
                for j in 0..v.nv {
                    if n.at(i, j) != n.at(j, i) {
                        return Err(format!("asymmetric at ({i},{j})"));
                    }
                    // n2 ≤ min(Σv_i, Σv_j) — min-product domination.
                    if n.at(i, j) > sums[i].min(sums[j]) + 1e-12 {
                        return Err(format!("n2({i},{j}) exceeds bound"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_coordinator_checksum_decomposition_invariant() {
    // The headline §5 property, randomized over grids: checksums are
    // bit-identical for every decomposition of the same problem.
    forall(
        "coordinator-invariance",
        8,
        |g| {
            let nv = g.usize_in(12, 36);
            let nf = g.usize_in(8, 48);
            let npv = g.usize_in(1, 4.min(nv));
            let npr = g.usize_in(1, 3);
            let seed = g.stream.next_u64();
            (nv, nf, npv, npr, seed)
        },
        |&(nv, nf, npv, npr, seed)| {
            let mut cfg = RunConfig {
                num_way: 2,
                nv,
                nf,
                precision: Precision::F64,
                backend: BackendKind::CpuOptimized,
                grid: Grid::new(1, 1, 1),
                input: InputSource::Synthetic { kind: SyntheticKind::RandomGrid, seed },
                store_metrics: false,
                ..Default::default()
            };
            let a = run(&cfg).map_err(|e| e.to_string())?.checksum;
            cfg.grid = Grid::new(1, npv, npr);
            let b = run(&cfg).map_err(|e| e.to_string())?.checksum;
            if a != b {
                return Err(format!("checksum differs for grid (1,{npv},{npr})"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_sorenson_popcount_equals_float_path() {
    forall(
        "sorenson-bits",
        30,
        |g| {
            let nf = g.usize_in(1, 200);
            let nv = g.usize_in(2, 10);
            let seed = g.stream.next_u64();
            comet::vecdata::bits::BitVectorSet::generate(seed, nf, nv, 0.3)
        },
        |bits| {
            let floats = bits.to_floats();
            let a = comet::linalg::sorenson::sorenson_mgemm(bits, bits);
            let b = comet::linalg::reference::mgemm2(&floats, &floats);
            if a.max_abs_diff(&b) != 0.0 {
                return Err("popcount vs float mismatch".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_bitpacked_sorenson_matches_reference_mgemm2_on_01_vectors() {
    // Satellite: the bit-packed popcount numerators must equal the
    // float min-product mGEMM on 0/1-valued f64 vectors, across widths
    // that exercise partial trailing words (nf not a multiple of 64).
    forall(
        "sorenson-01-float-agreement",
        60,
        |g| {
            // Half the cases pin nf to a word-boundary neighborhood;
            // the rest roam freely.
            let nf = if g.bool() {
                *g.pick(&[1usize, 63, 64, 65, 127, 128, 129, 191, 192, 193])
            } else {
                g.usize_in(1, 200)
            };
            let nv = g.usize_in(2, 9);
            let density = 0.2 + 0.6 * g.f64_unit();
            let mut v = VectorSet::<f64>::zeros(nf, nv);
            for c in 0..nv {
                for q in 0..nf {
                    if g.f64_unit() < density {
                        v.col_mut(c)[q] = 1.0;
                    }
                }
            }
            v
        },
        |v| {
            let bits = comet::vecdata::bits::BitVectorSet::from_threshold(v, 0.5);
            let a = comet::linalg::sorenson::sorenson_mgemm(&bits, &bits);
            let b = comet::linalg::reference::mgemm2(v, v);
            if a.max_abs_diff(&b) != 0.0 {
                return Err(format!(
                    "popcount numerators diverge from float mGEMM at nf={}",
                    v.nf
                ));
            }
            let c = comet::linalg::sorenson::sorenson_mgemm_ref(&bits, &bits);
            if a.max_abs_diff(&c) != 0.0 {
                return Err("packed kernel diverges from bitwise reference".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_ccc_engine_matches_scalar_oracle() {
    use comet::coordinator::backend::{Backend, CpuOptimized};
    use std::sync::Arc;
    forall(
        "ccc-engine-oracle",
        30,
        |g| {
            let nf = g.usize_in(2, 96);
            let nv = g.usize_in(2, 10);
            let seed = g.stream.next_u64();
            VectorSet::<f64>::generate(SyntheticKind::Alleles, seed, nf, nv, 0)
        },
        |v| {
            let backend: Arc<dyn Backend<f64>> = Arc::new(CpuOptimized::default());
            let metric = comet::metrics::engine::Ccc::new(v.nf);
            let store =
                comet::coordinator::serial::all_pairs_with(&backend, &metric, v)
                    .map_err(|e| e.to_string())?;
            for e in store.iter() {
                let want = metrics::ccc2(v.col(e.i as usize), v.col(e.j as usize));
                if e.value != want {
                    return Err(format!("ccc({}, {}) = {} want {}", e.i, e.j, e.value, want));
                }
                if !(0.0..=1.0 + 1e-12).contains(&e.value) {
                    return Err(format!("ccc({}, {}) = {} out of range", e.i, e.j, e.value));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_pack_once_coordinator_matches_repack_per_call_oracle() {
    // Satellite: the pack-once cached path (pack at ingest, packed
    // words on the wire) must be bit-for-bit identical — values AND
    // checksum — to the old repack-per-call semantics (freshly pack
    // both operands for every pair), across random 0/1 matrices, rank
    // counts (grids), and partial trailing-word widths.
    forall(
        "pack-once-vs-repack",
        10,
        |g| {
            let nf = if g.bool() {
                *g.pick(&[1usize, 63, 64, 65, 127, 128, 129, 190])
            } else {
                g.usize_in(2, 200)
            };
            let nv = g.usize_in(6, 24);
            let npv = g.usize_in(1, 4.min(nv));
            let npr = g.usize_in(1, 3);
            let npf = g.usize_in(1, 2.min(nf));
            let seed = g.stream.next_u64();
            (nf, nv, npf, npv, npr, seed)
        },
        |&(nf, nv, npf, npv, npr, seed)| {
            let cfg = RunConfig {
                metric: metrics::MetricId::Sorenson,
                num_way: 2,
                nv,
                nf,
                precision: Precision::F64,
                backend: BackendKind::CpuOptimized,
                grid: Grid::new(npf, npv, npr),
                input: InputSource::Synthetic { kind: SyntheticKind::RandomGrid, seed },
                store_metrics: true,
                ..Default::default()
            };
            let out = run(&cfg).map_err(|e| e.to_string())?;
            let pairs = out.pairs.as_ref().ok_or("no pairs stored")?;
            if pairs.len() != nv * (nv - 1) / 2 {
                return Err(format!("{} pairs, want {}", pairs.len(), nv * (nv - 1) / 2));
            }
            // Old repack-per-call path: pack both operands freshly for
            // every single pair, straight from the float matrix.
            let v: VectorSet<f64> = VectorSet::generate(SyntheticKind::RandomGrid, seed, nf, nv, 0);
            let mut want_cs = comet::checksum::Checksum::with_salt(
                metrics::MetricId::Sorenson.checksum_salt(),
            );
            for e in pairs.iter() {
                let (i, j) = (e.i as usize, e.j as usize);
                let bi = comet::vecdata::bits::BitVectorSet::from_threshold(&v.select_cols(&[i]), 0.5);
                let bj = comet::vecdata::bits::BitVectorSet::from_threshold(&v.select_cols(&[j]), 0.5);
                let n = comet::linalg::sorenson::sorenson_mgemm(&bi, &bj).at(0, 0);
                let d = (bi.popcount(0) + bj.popcount(0)) as f64;
                let want = if d == 0.0 { 0.0 } else { 2.0 * n / d };
                if e.value.to_bits() != want.to_bits() {
                    return Err(format!(
                        "pair ({i},{j}): cached {} vs repack {} at nf={nf}",
                        e.value, want
                    ));
                }
                want_cs.add_pair(i, j, want);
            }
            if out.checksum != want_cs {
                return Err("checksum differs from repack-per-call oracle".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_packed2_spill_codec_roundtrips_bit_identically() {
    // Satellite: genotype blocks must survive the out-of-core spill
    // codec byte for byte — across partial trailing words (nf % 64),
    // padded .bed rows (nf % 4), odd spans, all-missing columns, and
    // spans with no missing calls at all (mask plane omitted).
    use comet::vecdata::block::Block;
    use comet::vecdata::{geno, oocstore};
    use std::sync::Arc;
    let dir = std::env::temp_dir().join("comet-tests");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join(format!("prop-packed2-spill-{}.bed", std::process::id()));
    forall(
        "packed2-spill-roundtrip",
        40,
        |g| {
            let nf = if g.bool() {
                *g.pick(&[1usize, 3, 63, 64, 65, 127, 128, 129])
            } else {
                g.usize_in(1, 150)
            };
            let nv = g.usize_in(1, 11);
            let missing_rate = *g.pick(&[0.0, 0.0, 0.15]);
            let mut codes = vec![0u8; nf * nv];
            for c in codes.iter_mut() {
                *c = if g.f64_unit() < missing_rate {
                    geno::MISSING
                } else {
                    *g.pick(&[0u8, 0, 1, 2])
                };
            }
            // Sometimes blank out a whole variant to all-missing.
            if nv > 1 && g.bool() {
                let victim = g.usize_in(0, nv - 1);
                codes[victim * nf..(victim + 1) * nf].fill(geno::MISSING);
            }
            let first_col = g.usize_in(0, nv - 1);
            let ncols = g.usize_in(1, nv - first_col);
            (nf, nv, first_col, ncols, codes)
        },
        |(nf, nv, first_col, ncols, codes)| {
            geno::write_bed_codes(&path, *nf, codes).map_err(|e| e.to_string())?;
            let span = geno::read_bed_cols(&path, *nf, *nv, *first_col, *ncols)
                .map_err(|e| e.to_string())?;
            let packed = span.pack2();
            let has_mask = packed.missing.is_some();
            if has_mask != (span.missing > 0) {
                return Err("mask plane presence disagrees with missing count".into());
            }
            let block: Block<f64> = Block::Packed2(Arc::new(packed));
            let blob = oocstore::encode(&block);
            let back = oocstore::decode::<f64>(&blob).map_err(|e| e.to_string())?;
            // Byte-identity: re-encoding the reload reproduces the blob.
            if oocstore::encode(&back) != blob {
                return Err(format!("re-encoded blob differs at nf={nf} ncols={ncols}"));
            }
            let g2 = back.as_packed2().ok_or("reload is not a packed2 block")?;
            if g2.first_id() != *first_col || g2.nf() != *nf || g2.nv() != *ncols {
                return Err("reload dims/first_id differ".into());
            }
            if g2.missing_calls != span.missing {
                return Err(format!(
                    "reload counts {} missing calls, span had {}",
                    g2.missing_calls, span.missing
                ));
            }
            for v in 0..*ncols {
                for q in 0..*nf {
                    let code = codes[(first_col + v) * nf + q];
                    let want = if code == geno::MISSING { 0 } else { code };
                    if g2.dosage(v, q) != want {
                        return Err(format!("dosage({v},{q}) wrong after reload"));
                    }
                }
            }
            // A payload flip is always a typed Corrupt error, never a
            // silent wrong reload (the last byte is payload: every
            // packed2 blob carries ≥ 2 planes of ≥ 8 B each).
            let mut evil = blob.clone();
            *evil.last_mut().unwrap() ^= 0x40;
            match oocstore::decode::<f64>(&evil) {
                Err(e) if e.kind == oocstore::StoreErrorKind::Corrupt => Ok(()),
                Err(e) => Err(format!("payload flip gave {:?}, want Corrupt", e.kind)),
                Ok(_) => Err("payload flip decoded silently".into()),
            }
        },
    );
    std::fs::remove_file(path).ok();
}

#[test]
fn prop_checksum_detects_any_single_mutation() {
    forall(
        "checksum-sensitivity",
        50,
        |g| {
            let n = g.usize_in(2, 30);
            let vals: Vec<f64> = (0..n).map(|_| g.f64_unit()).collect();
            let victim = g.usize_in(0, n - 1);
            (vals, victim)
        },
        |(vals, victim)| {
            let mut a = comet::checksum::Checksum::new();
            let mut b = comet::checksum::Checksum::new();
            for (idx, &v) in vals.iter().enumerate() {
                a.add_pair(idx, idx + 1, v);
                let v2 = if idx == *victim { v + 1e-9 } else { v };
                b.add_pair(idx, idx + 1, v2);
            }
            if a == b {
                return Err("mutation not detected".into());
            }
            Ok(())
        },
    );
}
