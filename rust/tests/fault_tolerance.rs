//! Fault-tolerance acceptance suite (ISSUE 9):
//!
//! 1. **Zero-overhead when healthy** — attaching the fault-injection
//!    seam (an empty `FaultPlan`) and a checkpoint store to a run adds
//!    ZERO wire messages/bytes over the `tests/comm_accounting.rs`
//!    pinned baselines, and every resilience counter stays 0.
//! 2. **Transient-fault recovery** — scripted drops/corrupts/delays
//!    across metrics × backends × 2/3-way × thread counts recover
//!    bit-identically (link-layer retransmits under the shared retry
//!    policy; per-envelope checksum catches corruption).
//! 3. **Typed abort + resume** — a killed rank surfaces a typed
//!    [`RunError`] naming the rank within a bounded deadline (no hung
//!    ring), and rerunning against the same checkpoint store finishes
//!    the campaign bit-identically, skipping persisted units.
//! 4. **Full resume** — rerunning a completed, checkpointed campaign
//!    recomputes nothing (zero kernel calls) while keeping the comm
//!    schedule in lockstep and the results bit-identical.
//! 5. **Serve worker respawn** — a sink panic on a shard worker's own
//!    thread kills the worker; the in-flight ticket gets the typed
//!    `WorkerDied`, the shard respawns on its next submission, and
//!    concurrent follow-up requests complete bit-identically.

use std::sync::Arc;
use std::time::{Duration, Instant};

use comet::config::{BackendKind, InputSource, Precision, RunConfig};
use comet::coordinator::{self, checkpoint::CheckpointStore, FreshIngest, RunError, RunOpts};
use comet::decomp::Grid;
use comet::metrics::MetricId;
use comet::output::sink::{CollectSink, DiscardSink, ResultSink};
use comet::serve::{ServeConfig, ServeError, Server};
use comet::session::Session;
use comet::testkit::faults::{scripted_comm_plan, FaultKind, FaultPlan, PanicSink};
use comet::vecdata::SyntheticKind;

fn cfg_for(metric: MetricId, num_way: usize, nv: usize, nf: usize, grid: Grid) -> RunConfig {
    let kind = match metric {
        MetricId::Ccc => SyntheticKind::Alleles,
        _ => SyntheticKind::RandomGrid,
    };
    RunConfig {
        metric,
        num_way,
        nv,
        nf,
        backend: BackendKind::CpuOptimized,
        grid,
        input: InputSource::Synthetic { kind, seed: 29 },
        store_metrics: false,
        ..Default::default()
    }
}

fn run_opts(cfg: &RunConfig, sink: &dyn ResultSink, opts: &RunOpts) -> comet::Result<coordinator::RunOutcome> {
    coordinator::run_streamed_opts(cfg, None, Arc::new(FreshIngest), sink, opts)
}

// The tests/comm_accounting.rs pinned shape and its exact wire totals:
// nv=64, nf=4096 over (1,4,1); steps Δ ∈ {1,2} → 8 block + 8 sums
// sends. The fault-tolerance machinery must not move these numbers.
const PINNED_MESSAGES: u64 = 16;
const PINNED_SORENSON_BYTES: u64 = 66_560;
const PINNED_FLOAT_BYTES: u64 = 4_195_328;

fn pinned_cfg(metric: MetricId) -> RunConfig {
    RunConfig {
        metric,
        num_way: 2,
        nv: 64,
        nf: 4096,
        precision: Precision::F64,
        backend: BackendKind::CpuOptimized,
        grid: Grid::new(1, 4, 1),
        input: InputSource::Synthetic { kind: SyntheticKind::RandomGrid, seed: 7 },
        store_metrics: false,
        ..Default::default()
    }
}

#[test]
fn fault_free_runs_add_zero_wire_overhead_and_zero_counters() {
    for (metric, bytes) in [
        (MetricId::Czekanowski, PINNED_FLOAT_BYTES),
        (MetricId::Sorenson, PINNED_SORENSON_BYTES),
    ] {
        let cfg = pinned_cfg(metric);
        let baseline = coordinator::run(&cfg).unwrap();
        assert_eq!(baseline.stats.comm_messages, PINNED_MESSAGES);
        assert_eq!(baseline.stats.comm_bytes, bytes);
        assert_eq!(baseline.stats.comm_retries, 0);
        assert_eq!(baseline.stats.comm_corrupt, 0);
        assert_eq!(baseline.stats.faults_injected, 0);
        assert_eq!(baseline.stats.ckpt_writes + baseline.stats.ckpt_skipped, 0);

        // Same run with the whole robustness apparatus attached but
        // idle (empty plan) or off the wire (checkpoint writes go to
        // the store, not the fabric): wire accounting must be
        // bit-identical to the bare run — the zero-overhead pin.
        let opts = RunOpts {
            faults: Some(Arc::new(FaultPlan::new())),
            checkpoint: Some(Arc::new(CheckpointStore::mem())),
        };
        let armed = run_opts(&cfg, &DiscardSink, &opts).unwrap();
        assert_eq!(armed.checksum, baseline.checksum, "{metric:?}");
        assert_eq!(armed.stats.comm_messages, PINNED_MESSAGES, "{metric:?}");
        assert_eq!(armed.stats.comm_bytes, bytes, "{metric:?}");
        assert_eq!(armed.stats.comm_retries, 0);
        assert_eq!(armed.stats.comm_corrupt, 0);
        assert_eq!(armed.stats.faults_injected, 0);
        assert!(armed.stats.ckpt_writes > 0, "checkpointing must actually persist");
        assert_eq!(armed.stats.ckpt_errors, 0);
    }
}

#[test]
fn scripted_drops_and_corrupts_recover_bit_identically() {
    // The recovery matrix: every metric family × both native backends
    // × 2-way and 3-way × serial/threaded kernels. Each combination is
    // run clean, then under scripted drops, then scripted corruption;
    // all three checksums must agree and the counters must show the
    // faults actually fired and were retransmitted around.
    let mut combos: Vec<RunConfig> = Vec::new();
    for metric in [MetricId::Czekanowski, MetricId::Sorenson, MetricId::Ccc] {
        for backend in [BackendKind::CpuReference, BackendKind::CpuOptimized] {
            for threads in [1usize, 2] {
                let mut cfg = cfg_for(metric, 2, 24, 48, Grid::new(1, 3, 1));
                cfg.backend = backend;
                cfg.threads = threads;
                combos.push(cfg);
            }
        }
    }
    for backend in [BackendKind::CpuReference, BackendKind::CpuOptimized] {
        for threads in [1usize, 2] {
            let mut cfg = cfg_for(MetricId::Czekanowski, 3, 16, 24, Grid::new(1, 2, 1));
            cfg.backend = backend;
            cfg.threads = threads;
            combos.push(cfg);
        }
    }

    for (i, cfg) in combos.iter().enumerate() {
        let clean = coordinator::run(cfg).unwrap();
        let np = cfg.grid.np();
        // Slots (rank, k ∈ {0, 1}) are all real send ops for these
        // shapes: every rank sends at least a block + a sums payload.
        for kind in [FaultKind::Drop, FaultKind::Corrupt] {
            let plan = scripted_comm_plan(41 + i as u64, np, 2, np, kind);
            let opts = RunOpts { faults: Some(plan), checkpoint: None };
            let out = run_opts(cfg, &DiscardSink, &opts).unwrap();
            let what = format!(
                "combo {i} ({:?} {}-way {:?} t{}) {}",
                cfg.metric, cfg.num_way, cfg.backend, cfg.threads,
                kind.name()
            );
            assert_eq!(out.checksum, clean.checksum, "{what}");
            assert_eq!(out.stats.metrics, clean.stats.metrics, "{what}");
            assert!(out.stats.faults_injected > 0, "{what}: no fault fired");
            assert!(out.stats.comm_retries > 0, "{what}: recovery must retransmit");
            if kind == FaultKind::Corrupt {
                assert!(out.stats.comm_corrupt > 0, "{what}: corruption must be detected");
            }
        }
    }

    // Delays stall but never retransmit: bit-identical with zero
    // retries — the accounting separates slow links from lossy ones.
    let cfg = cfg_for(MetricId::Czekanowski, 2, 24, 48, Grid::new(1, 3, 1));
    let clean = coordinator::run(&cfg).unwrap();
    let plan =
        scripted_comm_plan(7, cfg.grid.np(), 2, 2, FaultKind::Delay(Duration::from_millis(1)));
    let out = run_opts(&cfg, &DiscardSink, &RunOpts { faults: Some(plan), checkpoint: None })
        .unwrap();
    assert_eq!(out.checksum, clean.checksum);
    assert!(out.stats.faults_injected > 0);
    assert_eq!(out.stats.comm_retries, 0);
}

#[test]
fn exhausted_retransmit_budget_is_a_typed_bounded_abort() {
    let cfg = cfg_for(MetricId::Czekanowski, 2, 24, 48, Grid::new(1, 3, 1));
    let plan = Arc::new(FaultPlan::new());
    plan.drop_at_times(1, 0, u32::MAX); // every retransmit of rank 1's first send is lost
    plan.set_recv_deadline(Duration::from_millis(100));
    let t0 = Instant::now();
    let err = run_opts(&cfg, &DiscardSink, &RunOpts { faults: Some(plan), checkpoint: None })
        .unwrap_err();
    assert!(t0.elapsed() < Duration::from_secs(30), "abort must be bounded, not a hang");
    let run_err = err.downcast_ref::<RunError>().expect("typed RunError");
    assert!(!run_err.ranks.is_empty());
    assert!(
        run_err.ranks.iter().any(|(r, _)| *r == 1),
        "the failing sender must be diagnosed: {run_err}"
    );
}

#[test]
fn killed_rank_aborts_typed_then_resume_is_bit_identical() {
    let mut cfg = cfg_for(MetricId::Czekanowski, 2, 32, 48, Grid::new(1, 4, 1));
    cfg.store_metrics = true;
    let baseline = coordinator::run(&cfg).unwrap();

    let store = Arc::new(CheckpointStore::mem());

    // Kill rank 2 at its 4th send — after the first circulant step's
    // units have been computed and persisted, mid-ring in the second.
    let plan = Arc::new(FaultPlan::new());
    plan.kill_at(2, 3);
    plan.set_recv_deadline(Duration::from_millis(100));
    let t0 = Instant::now();
    let err = run_opts(
        &cfg,
        &DiscardSink,
        &RunOpts { faults: Some(plan), checkpoint: Some(Arc::clone(&store)) },
    )
    .unwrap_err();
    assert!(t0.elapsed() < Duration::from_secs(30), "abort must be bounded");
    let run_err = err.downcast_ref::<RunError>().expect("typed RunError");
    assert!(
        run_err.ranks.iter().any(|(r, _)| *r == 2),
        "the killed rank must be diagnosed: {run_err}"
    );

    // Resume against the same store: the campaign completes, skipping
    // the units the doomed run persisted, and every metric value is
    // bit-identical to the never-faulted baseline.
    let sink = CollectSink::for_metric(cfg.metric);
    let resumed = run_opts(
        &cfg,
        &sink,
        &RunOpts { faults: None, checkpoint: Some(Arc::clone(&store)) },
    )
    .unwrap();
    assert_eq!(resumed.checksum, baseline.checksum);
    assert!(resumed.stats.ckpt_skipped > 0, "resume must reuse persisted units");
    assert!(resumed.stats.ckpt_replayed > 0, "skipped units must replay their tiles");
    let (pairs, _) = sink.take();
    let want = baseline.pairs.as_ref().unwrap().to_dense(cfg.nv);
    let got = pairs.to_dense(cfg.nv);
    assert_eq!(want.len(), got.len());
    for (off, (x, y)) in want.iter().zip(&got).enumerate() {
        assert_eq!(x.unwrap().to_bits(), y.unwrap().to_bits(), "offset {off}");
    }
}

#[test]
fn completed_campaign_resumes_without_recomputing() {
    // 2-way: a second run over a fully-persisted store recomputes no
    // numerators at all — the comm schedule still runs in lockstep
    // (identical wire accounting), but every unit skips its kernel.
    let cfg = cfg_for(MetricId::Czekanowski, 2, 32, 48, Grid::new(1, 4, 1));
    let store = Arc::new(CheckpointStore::mem());
    let opts = RunOpts { faults: None, checkpoint: Some(Arc::clone(&store)) };
    let first = run_opts(&cfg, &DiscardSink, &opts).unwrap();
    assert!(first.stats.ckpt_writes > 0);
    assert_eq!(first.stats.ckpt_skipped, 0);

    let second = run_opts(&cfg, &DiscardSink, &opts).unwrap();
    assert_eq!(second.checksum, first.checksum);
    assert_eq!(second.stats.ckpt_writes, 0, "nothing new to persist");
    assert!(second.stats.ckpt_skipped > 0);
    assert_eq!(second.stats.mgemm2_calls, 0, "full resume must skip every kernel");
    assert_eq!(second.stats.comm_messages, first.stats.comm_messages, "lockstep schedule");
    assert_eq!(second.stats.comm_bytes, first.stats.comm_bytes);

    // 3-way: same contract at the slice/stage granularity.
    let cfg3 = cfg_for(MetricId::Czekanowski, 3, 16, 24, Grid::new(1, 2, 1));
    let store3 = Arc::new(CheckpointStore::mem());
    let opts3 = RunOpts { faults: None, checkpoint: Some(Arc::clone(&store3)) };
    let first3 = run_opts(&cfg3, &DiscardSink, &opts3).unwrap();
    assert!(first3.stats.ckpt_writes > 0);
    let second3 = run_opts(&cfg3, &DiscardSink, &opts3).unwrap();
    assert_eq!(second3.checksum, first3.checksum);
    assert_eq!(second3.stats.ckpt_writes, 0);
    assert!(second3.stats.ckpt_skipped > 0);
}

#[test]
fn serve_worker_panic_surfaces_typed_and_respawns() {
    let cfg = cfg_for(MetricId::Czekanowski, 2, 24, 32, Grid::new(1, 2, 1));
    let baseline = coordinator::run(&cfg).unwrap();

    let session = Arc::new(Session::new());
    let server = Server::start(
        Arc::clone(&session),
        ServeConfig { workers: 2, queue_capacity: 8, max_request_bytes: None },
    )
    .unwrap();
    let shard = server.shard_of(&cfg);

    // A sink that panics on the shard worker's own thread (node sinks
    // are created before node threads spawn) — the worker genuinely
    // dies; the coordinator supervisor never gets to catch this one.
    let ticket = server.submit(&cfg, Arc::new(PanicSink)).unwrap();
    let err = ticket.wait().unwrap_err();
    match err.downcast_ref::<ServeError>() {
        Some(ServeError::WorkerDied { shard: s }) => assert_eq!(*s, shard),
        other => panic!("expected WorkerDied, got {other:?}: {err:#}"),
    }

    // The dead shard respawns lazily on its next submission; ≥ 3
    // concurrent follow-up clients all complete bit-identically.
    std::thread::scope(|s| {
        for _ in 0..3 {
            let server = &server;
            let cfg = &cfg;
            let baseline = &baseline;
            s.spawn(move || {
                let out = server.submit(cfg, Arc::new(DiscardSink)).unwrap().wait().unwrap();
                assert_eq!(out.checksum, baseline.checksum);
            });
        }
    });

    let stats = server.stats();
    assert!(stats.respawns >= 1, "the dead shard worker must have been respawned");
    assert_eq!(stats.submitted, 4);
    assert_eq!(server.queue_depth(shard), 0);
}
