//! Genotype-ingest lock-down suite: real-data CCC end to end.
//!
//! The `vecdata::geno` subsystem feeds PLINK `.bed` / VCF cohorts into
//! the same engine the synthetic runs use, and the CCC metric rides a
//! two-plane packed representation from ingest to wire to kernel. All
//! of that must be invisible at the result level:
//!
//! * `.bed`- and VCF-ingested CCC runs are bit-identical — values AND
//!   checksums — to the float path and its scalar oracle, across
//!   backends, decompositions, and thread counts;
//! * packed allele planes travel on the wire (comm volume drops ≥16×
//!   vs the float exchange, pinned to exact byte counts for one shape);
//! * plane packing happens exactly once per node block, at ingest;
//! * decode/missing-call counters round-trip into `RunStats`.
//!
//! Tests in this binary share a lock: the geno ingest counters are
//! process-global, so counter tests must not interleave.

use std::path::PathBuf;
use std::sync::Mutex;

use comet::checksum::Checksum;
use comet::config::{BackendKind, InputSource, Precision, RunConfig};
use comet::coordinator::run;
use comet::decomp::Grid;
use comet::metrics::{self, indexing, MetricId};
use comet::vecdata::geno;
use comet::vecdata::{SyntheticKind, VectorSet};

static SERIAL: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|p| p.into_inner())
}

fn tmp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join("comet-tests")
        .join(format!("{name}-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn ccc_cfg(input: InputSource, nv: usize, nf: usize) -> RunConfig {
    RunConfig {
        metric: MetricId::Ccc,
        num_way: 2,
        nv,
        nf,
        precision: Precision::F64,
        backend: BackendKind::CpuOptimized,
        grid: Grid::new(1, 1, 1),
        input,
        store_metrics: false,
        ..Default::default()
    }
}

/// Salted bit-level oracle: scalar `ccc2` over every pair.
fn oracle_checksum(v: &VectorSet<f64>) -> Checksum {
    let mut want = Checksum::with_salt(MetricId::Ccc.checksum_salt());
    for (i, j) in indexing::pairs(v.nv) {
        want.add_pair(i, j, metrics::ccc2(v.col(i), v.col(j)));
    }
    want
}

#[test]
fn bed_and_vcf_ingest_match_the_float_path_bitwise() {
    let _g = lock();
    let (nv, nf, seed) = (24usize, 130usize, 41u64); // partial trailing word
    let cohort: VectorSet<f64> = VectorSet::generate(SyntheticKind::Alleles, seed, nf, nv, 0);
    let want = oracle_checksum(&cohort);

    let dir = tmp_dir("geno-bitident");
    let bed = geno::write_plink_fixture(&dir, "cohort", &cohort).unwrap();
    let vcf = dir.join("cohort.vcf");
    geno::write_vcf_fixture(&vcf, &cohort).unwrap();

    let inputs = [
        InputSource::Synthetic { kind: SyntheticKind::Alleles, seed },
        InputSource::Bed { path: bed.to_str().unwrap().to_string() },
        InputSource::Vcf { path: vcf.to_str().unwrap().to_string() },
    ];
    for input in &inputs {
        for backend in [BackendKind::CpuReference, BackendKind::CpuOptimized] {
            for (npf, npv, npr) in [(1, 1, 1), (1, 3, 1), (1, 4, 2), (2, 2, 1)] {
                for threads in [1usize, 3] {
                    let mut cfg = ccc_cfg(input.clone(), nv, nf);
                    cfg.backend = backend;
                    cfg.grid = Grid::new(npf, npv, npr);
                    cfg.threads = threads;
                    let out = run(&cfg).unwrap();
                    assert_eq!(
                        out.checksum,
                        want,
                        "checksum drift: input {:?}, backend {backend:?}, \
                         grid ({npf},{npv},{npr}), threads {threads}",
                        cfg.input.format_name()
                    );
                }
            }
        }
    }
    std::fs::remove_dir_all(dir).ok();
}

// Exact wire accounting for the pinned shape (nv=64, nf=4096, grid
// (1,4,1)): steps Δ ∈ {1, 2} each make every node send one block +
// one sums payload → 8 block sends + 8 sums sends = 16 messages.
//
// Packed2 block: ⌈4096/64⌉ × 16 words × 8 B × 2 planes =  16384 B
// Float block:   4096 × 16 elements × 8 B (f64)        = 524288 B
// Sums payload:  16 f64 × 8 B                          =    128 B
const PINNED_MESSAGES: u64 = 16;
const PINNED_CCC_BYTES: u64 = 8 * 16_384 + 8 * 128; // = 132_096
const PINNED_FLOAT_BYTES: u64 = 8 * 524_288 + 8 * 128; // = 4_195_328

#[test]
fn packed2_wire_cuts_ccc_comm_bytes_at_least_16x() {
    let _g = lock();
    let input = InputSource::Synthetic { kind: SyntheticKind::Alleles, seed: 7 };
    let mut cfg = ccc_cfg(input, 64, 4096);
    cfg.grid = Grid::new(1, 4, 1);
    let ccc = run(&cfg).unwrap();
    cfg.metric = MetricId::Czekanowski;
    let cz = run(&cfg).unwrap();

    // Identical schedule, identical message count — only the block
    // representation differs.
    assert_eq!(ccc.stats.comm_messages, PINNED_MESSAGES);
    assert_eq!(cz.stats.comm_messages, PINNED_MESSAGES);

    // Pin the exact byte counts so any accounting regression is loud.
    assert_eq!(ccc.stats.comm_bytes, PINNED_CCC_BYTES);
    assert_eq!(cz.stats.comm_bytes, PINNED_FLOAT_BYTES);

    let ratio = cz.stats.comm_bytes as f64 / ccc.stats.comm_bytes as f64;
    assert!(ratio >= 16.0, "packed2 wire saves only {ratio:.1}× (< 16×)");
}

#[test]
fn ccc_packs_planes_once_per_node_block_never_in_the_step_loop() {
    let _g = lock();
    let input = InputSource::Synthetic { kind: SyntheticKind::Alleles, seed: 9 };
    let mut cfg = ccc_cfg(input, 36, 130);
    cfg.grid = Grid::new(1, 3, 2); // 6 nodes, multi-step schedule
    let before = geno::pack2_calls();
    let out = run(&cfg).unwrap();
    let packs = geno::pack2_calls() - before;
    // Exactly one plane-packing conversion per node block (at ingest).
    // Any per-step or per-kernel re-packing would at least double this.
    assert_eq!(packs, 6, "expected 6 ingest-time packs, saw {packs}");
    assert_eq!(out.stats.pack2_calls, 6);
    assert!(out.stats.metrics > 0);

    // Same problem, serial grid: one pack for the one node block.
    cfg.grid = Grid::new(1, 1, 1);
    let before = geno::pack2_calls();
    let solo = run(&cfg).unwrap();
    assert_eq!(geno::pack2_calls() - before, 1);
    assert_eq!(solo.stats.pack2_calls, 1);
}

#[test]
fn bed_ingest_counters_reach_run_stats_and_missing_imputes_to_zero() {
    let _g = lock();
    let (nf, nv) = (9usize, 8usize);
    // Deterministic codes with a sprinkle of missing calls; no .bim or
    // .fam companions — the reader accepts a bare .bed.
    let codes: Vec<u8> = (0..nf * nv)
        .map(|k| match k % 7 {
            0 | 3 => 0,
            1 | 4 => 1,
            2 | 5 => 2,
            _ => geno::MISSING,
        })
        .collect();
    let n_missing = codes.iter().filter(|&&c| c == geno::MISSING).count() as u64;
    assert!(n_missing > 0);
    let dir = tmp_dir("geno-counters");
    let bed = dir.join("sparse.bed");
    geno::write_bed_codes(&bed, nf, &codes).unwrap();

    let input = InputSource::Bed { path: bed.to_str().unwrap().to_string() };
    let out = run(&ccc_cfg(input, nv, nf)).unwrap();
    // One node decodes the whole file once.
    assert_eq!(out.stats.geno_calls, (nf * nv) as u64);
    assert_eq!(out.stats.geno_missing, n_missing);
    assert_eq!(out.stats.pack2_calls, 1);

    // Missing imputes to dosage 0 on both paths: the run's checksum is
    // the scalar oracle over the imputed float expansion.
    let floats: VectorSet<f64> = geno::read_bed_cols(&bed, nf, nv, 0, nv).unwrap().to_floats();
    assert_eq!(out.checksum, oracle_checksum(&floats));
    std::fs::remove_dir_all(dir).ok();
}
