//! PJRT runtime integration: every artifact kind executed through the
//! real HLO-load → compile → execute path and checked against the
//! native reference kernels. Requires `make artifacts`; each test
//! skips (passes vacuously, with a note) when no artifacts are built,
//! so artifact-less CI still runs the rest of the suite.

use std::path::Path;

use comet::config::Precision;
use comet::coordinator::backend::{Backend, CpuReference, PjrtBackend};
use comet::linalg::reference;
use comet::runtime::ops::BlockOps;
use comet::runtime::PjrtService;
use comet::vecdata::{SyntheticKind, VectorSet};

fn artifacts_dir() -> &'static Path {
    Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))
}

/// None (with a skip note) when artifacts are not built.
fn service() -> Option<PjrtService> {
    if !artifacts_dir().join("manifest.txt").exists() {
        eprintln!("skipping: artifacts missing — run `make artifacts` first");
        return None;
    }
    Some(PjrtService::start(artifacts_dir()).expect("start PJRT service"))
}

fn gen64(nf: usize, nv: usize, seed: u64, first: usize) -> VectorSet<f64> {
    VectorSet::generate(SyntheticKind::RandomGrid, seed, nf, nv, first)
}

fn gen32(nf: usize, nv: usize, seed: u64, first: usize) -> VectorSet<f32> {
    VectorSet::generate(SyntheticKind::RandomGrid, seed, nf, nv, first)
}

#[test]
fn mgemm2_xla_matches_reference_f64_exact() {
    let Some(svc) = service() else { return };
    let ops = BlockOps::new(svc.client(), Precision::F64);
    // Off-tier shape: exercises feature and vector padding.
    let w = gen64(100, 48, 1, 0);
    let v = gen64(100, 32, 1, 100);
    let got = ops.mgemm2("mgemm2", &w, &v).unwrap();
    let want = reference::mgemm2(&w, &v);
    // Grid-valued data -> exact sums -> bit-identical across paths.
    assert_eq!(got.max_abs_diff(&want), 0.0);
}

#[test]
fn mgemm2_variants_agree_bitwise_f32() {
    let Some(svc) = service() else { return };
    let ops = BlockOps::new(svc.client(), Precision::F32);
    let w = gen32(384, 64, 2, 0);
    let v = gen32(384, 64, 2, 64);
    let want = reference::mgemm2(&w, &v);
    for kind in ["mgemm2", "mgemm2ternary", "mgemm2pallas", "mgemm2pallasternary"] {
        let got = ops.mgemm2(kind, &w, &v).unwrap();
        assert_eq!(got.max_abs_diff(&want), 0.0, "kind={kind}");
    }
}

#[test]
fn pallas_tier_exact_shape_f64() {
    // Exact tier shape (no padding) through the Pallas kernel lowering.
    let Some(svc) = service() else { return };
    let ops = BlockOps::new(svc.client(), Precision::F64);
    let w = gen64(384, 128, 3, 0);
    let v = gen64(384, 128, 3, 128);
    let got = ops.mgemm2("mgemm2pallas", &w, &v).unwrap();
    let want = reference::mgemm2(&w, &v);
    assert_eq!(got.max_abs_diff(&want), 0.0);
}

#[test]
fn gemm_artifacts_match_reference() {
    let Some(svc) = service() else { return };
    let ops = BlockOps::new(svc.client(), Precision::F64);
    let w = gen64(128, 32, 4, 0);
    let v = gen64(128, 32, 4, 32);
    let want = reference::gemm(&w, &v);
    for kind in ["gemm", "gemmpallas"] {
        let got = ops.mgemm2(kind, &w, &v).unwrap();
        assert!(got.max_abs_diff(&want) < 1e-9, "kind={kind}");
    }
}

#[test]
fn mgemm3_artifacts_match_reference() {
    let Some(svc) = service() else { return };
    let ops = BlockOps::new(svc.client(), Precision::F64);
    let vi = gen64(96, 24, 5, 0);
    let pivots = gen64(96, 6, 5, 24);
    let vk = gen64(96, 30, 5, 60);
    let want = reference::mgemm3(&vi, &pivots, &vk);
    for kind in ["mgemm3", "mgemm3pallas"] {
        let got = ops.mgemm3(kind, &vi, &pivots, &vk).unwrap();
        assert_eq!(got.max_abs_diff(&want), 0.0, "kind={kind}");
    }
}

#[test]
fn rowsum_artifact() {
    let Some(svc) = service() else { return };
    let ops = BlockOps::new(svc.client(), Precision::F64);
    let v = gen64(200, 40, 6, 0);
    let got = ops.rowsum(&v).unwrap();
    let want = v.col_sums();
    assert_eq!(got, want);
}

fn raw_bytes(v: &[f64]) -> Vec<u8> {
    v.iter().flat_map(|x| x.to_le_bytes()).collect()
}

#[test]
fn block2_fused_artifact() {
    let Some(svc) = service() else { return };
    let client = svc.client();
    // block2 returns (N, sums_w, sums_v); exercise via raw execute.
    let entry = client
        .manifest()
        .select("block2", Precision::F64, 100, 50)
        .unwrap()
        .clone();
    let w = gen64(100, 50, 7, 0);
    let v = gen64(100, 50, 7, 50);
    let inputs = vec![
        comet::runtime::InputBuf {
            dims: vec![entry.nf, entry.nv],
            bytes: raw_bytes(&w.to_rowmajor_padded(entry.nf, entry.nv)),
            precision: Precision::F64.into(),
        },
        comet::runtime::InputBuf {
            dims: vec![entry.nf, entry.nv],
            bytes: raw_bytes(&v.to_rowmajor_padded(entry.nf, entry.nv)),
            precision: Precision::F64.into(),
        },
    ];
    let out = client.execute(&entry.name, inputs).unwrap();
    assert_eq!(out.len(), 3, "block2 is a fused 3-output artifact");
    let want_n = reference::mgemm2(&w, &v);
    for i in 0..w.nv {
        for j in 0..v.nv {
            assert_eq!(out[0].values[i * entry.nv + j], want_n.at(i, j));
        }
    }
    assert_eq!(&out[1].values[..w.nv], w.col_sums().as_slice());
    assert_eq!(&out[2].values[..v.nv], v.col_sums().as_slice());
}

#[test]
fn pjrt_backend_trait_paths() {
    let Some(svc) = service() else { return };
    let be = PjrtBackend::new(svc.client(), Precision::F32);
    let w = gen32(64, 16, 8, 0);
    let v = gen32(64, 16, 8, 16);
    let got = Backend::<f32>::mgemm2(&be, &w, &v).unwrap();
    let want = Backend::<f32>::mgemm2(&CpuReference, &w, &v).unwrap();
    assert_eq!(got.max_abs_diff(&want), 0.0);
    assert!(Backend::<f32>::pivot_batch(&be) >= 8);
}

#[test]
fn service_shared_across_threads() {
    let Some(svc) = service() else { return };
    let client = svc.client();
    let handles: Vec<_> = (0..4)
        .map(|t| {
            let ops = BlockOps::new(client.clone(), Precision::F64);
            std::thread::spawn(move || {
                let w = gen64(64, 16, 100 + t, 0);
                let v = gen64(64, 16, 200 + t, 16);
                let got = ops.mgemm2("mgemm2", &w, &v).unwrap();
                let want = reference::mgemm2(&w, &v);
                assert_eq!(got.max_abs_diff(&want), 0.0);
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let (execs, secs) = client.stats();
    assert_eq!(execs, 4);
    assert!(secs > 0.0);
}

#[test]
fn sorenson_artifacts_match_popcount_reference() {
    // §2.3 through all three layers: packed-u32 AND+popcount artifact
    // vs the native popcount kernel, exact.
    use comet::vecdata::bits::BitVectorSet;
    let Some(svc) = service() else { return };
    let ops = BlockOps::new(svc.client(), Precision::F32); // precision unused for u32 path
    for (nf, nv) in [(512usize, 128usize), (100, 40), (512, 64)] {
        let bits = BitVectorSet::generate(17, nf, nv, 0.35);
        let want = comet::linalg::sorenson::sorenson_mgemm(&bits, &bits);
        for kind in ["sorenson2", "sorenson2pallas"] {
            let got = ops.sorenson2(kind, &bits, &bits).unwrap();
            assert_eq!(got.max_abs_diff(&want), 0.0, "kind={kind} nf={nf} nv={nv}");
        }
    }
}

#[test]
fn missing_artifact_errors_helpfully() {
    let Some(svc) = service() else { return };
    let ops = BlockOps::new(svc.client(), Precision::F64);
    let w = gen64(64, 16, 9, 0);
    let err = ops.mgemm2("nonexistent-kind", &w, &w).unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("artifact") || msg.contains("tier"), "{msg}");
}

#[test]
fn oversized_feature_depth_tiles_and_accumulates() {
    // Deeper than any tier (max 1536): feature panels must accumulate.
    let Some(svc) = service() else { return };
    let ops = BlockOps::new(svc.client(), Precision::F64);
    let w = gen64(2000, 16, 9, 0);
    let v = gen64(2000, 12, 9, 16);
    let got = ops.mgemm2("mgemm2", &w, &v).unwrap();
    let want = reference::mgemm2(&w, &v);
    assert_eq!(got.max_abs_diff(&want), 0.0);
}

#[test]
fn oversized_vector_count_tiles() {
    // Wider than any tier (max 256): vector panels must concatenate.
    let Some(svc) = service() else { return };
    let ops = BlockOps::new(svc.client(), Precision::F32);
    let w = gen32(100, 300, 10, 0);
    let v = gen32(100, 280, 10, 300);
    let got = ops.mgemm2("mgemm2", &w, &v).unwrap();
    let want = reference::mgemm2(&w, &v);
    assert_eq!(got.max_abs_diff(&want), 0.0);
}

#[test]
fn oversized_mgemm3_tiles() {
    let Some(svc) = service() else { return };
    let ops = BlockOps::new(svc.client(), Precision::F64);
    let vi = gen64(1600, 20, 11, 0); // deeper than the 1536 tier
    let pivots = gen64(1600, 20, 11, 20); // more pivots than jt=16
    let vk = gen64(1600, 18, 11, 60);
    let got = ops.mgemm3("mgemm3", &vi, &pivots, &vk).unwrap();
    let want = reference::mgemm3(&vi, &pivots, &vk);
    assert_eq!(got.max_abs_diff(&want), 0.0);
}
