//! Comm/accounting lock-down suite for pack-once Sorensen.
//!
//! The pack-once representation work (cached bit-planes + packed-word
//! wire exchange) is invisible to result-level tests by design — the
//! whole point is bit-identical output. These tests pin the *resource*
//! contract instead:
//!
//! * packed u64 words travel on the wire (comm volume drops ≥32× vs
//!   the float exchange, pinned to the exact byte count for one shape);
//! * packing happens exactly once per node block, at ingest — never
//!   inside the parallel step loop;
//! * per-node comm/accel stats round-trip through `RunStats::absorb`
//!   into the run outcome (the PR 1 absorb fix, guarded end-to-end);
//! * results and checksums stay bit-identical across backends and
//!   parallel decompositions while all of the above holds.
//!
//! Tests in this binary share a lock: the pack-call counter is
//! process-global, so packing tests must not interleave.

use std::sync::Mutex;

use comet::checksum::Checksum;
use comet::config::{BackendKind, InputSource, Precision, RunConfig};
use comet::coordinator::run;
use comet::decomp::Grid;
use comet::metrics::{indexing, MetricId};
use comet::vecdata::bits::{pack_calls, BitVectorSet};
use comet::vecdata::{SyntheticKind, VectorSet};

static SERIAL: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|p| p.into_inner())
}

/// The pinned shape: nv=64, nf=4096 over a (1,4,1) grid. Each of the
/// 4 nodes holds 16 vectors × 4096 features = 64 packed words/vector.
fn pinned_cfg(metric: MetricId) -> RunConfig {
    RunConfig {
        metric,
        num_way: 2,
        nv: 64,
        nf: 4096,
        precision: Precision::F64,
        backend: BackendKind::CpuOptimized,
        grid: Grid::new(1, 4, 1),
        input: InputSource::Synthetic { kind: SyntheticKind::RandomGrid, seed: 7 },
        store_metrics: false,
        ..Default::default()
    }
}

// Exact wire accounting for the pinned shape (npv=4, npr=1):
// steps Δ ∈ {1, 2} each make every node send one block + one sums
// payload → 8 block sends + 8 sums sends = 16 messages.
//
// Packed block: ⌈4096/64⌉ × 16 = 1024 words × 8 B   =   8192 B
// Float block:  4096 × 16 elements × 8 B (f64)      = 524288 B
// Sums payload: 16 f64 × 8 B                        =    128 B
const PINNED_MESSAGES: u64 = 16;
const PINNED_SORENSON_BYTES: u64 = 8 * 8192 + 8 * 128; // = 66_560
const PINNED_FLOAT_BYTES: u64 = 8 * 524_288 + 8 * 128; // = 4_195_328

#[test]
fn sorenson_packed_wire_cuts_comm_bytes_at_least_32x() {
    let _g = lock();
    let sor = run(&pinned_cfg(MetricId::Sorenson)).unwrap();
    let cz = run(&pinned_cfg(MetricId::Czekanowski)).unwrap();

    // Identical schedule, identical message count — only the block
    // representation differs.
    assert_eq!(sor.stats.comm_messages, PINNED_MESSAGES);
    assert_eq!(cz.stats.comm_messages, PINNED_MESSAGES);

    // Pin the exact byte counts so any accounting regression is loud.
    assert_eq!(sor.stats.comm_bytes, PINNED_SORENSON_BYTES);
    assert_eq!(cz.stats.comm_bytes, PINNED_FLOAT_BYTES);

    let ratio = cz.stats.comm_bytes as f64 / sor.stats.comm_bytes as f64;
    assert!(ratio >= 32.0, "packed wire saves only {ratio:.1}× (< 32×)");
}

#[test]
fn sorenson_packs_once_per_node_block_never_in_the_step_loop() {
    let _g = lock();
    let mut cfg = pinned_cfg(MetricId::Sorenson);
    cfg.nv = 36;
    cfg.nf = 130; // partial trailing word
    cfg.grid = Grid::new(1, 3, 2); // 6 nodes, multi-step schedule
    let before = pack_calls();
    let out = run(&cfg).unwrap();
    let packs = pack_calls() - before;
    // Exactly one packing conversion per node block (at ingest). The
    // (1,3,2) grid runs 2 circulant steps per pr plane; any per-step or
    // per-kernel re-packing would at least double this count.
    assert_eq!(packs, 6, "expected 6 ingest-time packs, saw {packs}");
    assert!(out.stats.metrics > 0);

    // Same problem, serial grid: still exactly one pack per node block.
    cfg.grid = Grid::new(1, 1, 1);
    let before = pack_calls();
    let _ = run(&cfg).unwrap();
    assert_eq!(pack_calls() - before, 1);
}

#[test]
fn absorb_roundtrips_comm_and_accel_stats_end_to_end() {
    let _g = lock();
    // RunStats::absorb is the only path from per-node endpoint counts
    // to the outcome now (the cluster-level counters are a debug-only
    // cross-check), so these equalities guard the PR 1 absorb fix
    // end-to-end: dropping comm_* or t_accel in the merge would zero
    // them here.
    let out = run(&pinned_cfg(MetricId::Sorenson)).unwrap();
    assert_eq!(out.stats.comm_messages, PINNED_MESSAGES);
    assert_eq!(out.stats.comm_bytes, PINNED_SORENSON_BYTES);
    assert_eq!(out.stats.t_accel, 0.0, "native backends spend no accel time");

    // Single node: nothing on the wire, and absorb must preserve that.
    let mut cfg = pinned_cfg(MetricId::Sorenson);
    cfg.grid = Grid::new(1, 1, 1);
    let solo = run(&cfg).unwrap();
    assert_eq!(solo.stats.comm_messages, 0);
    assert_eq!(solo.stats.comm_bytes, 0);
}

#[test]
fn packed_runs_stay_bit_identical_across_backends_and_decompositions() {
    let _g = lock();
    let (nv, nf, seed) = (36, 130, 23);
    // Bit-level oracle checksum, salted like the engine's.
    let v: VectorSet<f64> = VectorSet::generate(SyntheticKind::RandomGrid, seed, nf, nv, 0);
    let bits = BitVectorSet::from_threshold(&v, 0.5);
    let mut want = Checksum::with_salt(MetricId::Sorenson.checksum_salt());
    for (i, j) in indexing::pairs(nv) {
        want.add_pair(i, j, bits.sorenson2(i, j));
    }

    let mut cfg = pinned_cfg(MetricId::Sorenson);
    cfg.nv = nv;
    cfg.nf = nf;
    cfg.input = InputSource::Synthetic { kind: SyntheticKind::RandomGrid, seed };
    for backend in [BackendKind::CpuReference, BackendKind::CpuOptimized] {
        for (npf, npv, npr) in [(1, 1, 1), (1, 3, 1), (1, 4, 2), (2, 2, 1), (1, 6, 1)] {
            cfg.backend = backend;
            cfg.grid = Grid::new(npf, npv, npr);
            let out = run(&cfg).unwrap();
            assert_eq!(
                out.checksum, want,
                "checksum drift: backend {backend:?}, grid ({npf},{npv},{npr})"
            );
        }
    }
}

#[test]
fn float_metrics_keep_the_float_wire_untouched() {
    let _g = lock();
    // preferred_repr() gates the representation: czekanowski must
    // still move f64 elements (its kernels consume floats), and its
    // byte accounting must still scale with the precision width.
    // (CCC's packed2 wire is pinned in `tests/geno_ingest.rs`.)
    let mut cfg = pinned_cfg(MetricId::Czekanowski);
    let f64_run = run(&cfg).unwrap();
    assert_eq!(f64_run.stats.comm_bytes, PINNED_FLOAT_BYTES);
    cfg.precision = Precision::F32;
    let f32_run = run(&cfg).unwrap();
    assert_eq!(f32_run.stats.comm_bytes, PINNED_FLOAT_BYTES / 2);
}
