//! Serving-layer contracts (`comet serve` acceptance):
//!
//! 1. **Concurrent bit-identity** — ≥ 8 client threads driving mixed
//!    metrics/grids (2-way, 3-way, f32, packed) through one
//!    [`serve::Server`] each get values bit-identical to a serial
//!    one-shot `coordinator::run` of the same spec, while the session
//!    block cache stays under its byte budget and the ingest counters
//!    pin sharded reuse (one ingest per block, however many requests).
//! 2. **Run-level eviction** — filling the block cache past its budget
//!    evicts LRU victims; a request whose blocks were evicted
//!    re-ingests exactly them and still reproduces its cold-run bits.
//! 3. **Admission control** — a saturated shard queue rejects with
//!    typed `Busy` (not deadlock), an oversized request with
//!    `TooLarge`; after draining, the server accepts again.
//! 4. **Wire round-trip** — a request over a Unix socket pair decodes
//!    to the same values/checksum as a one-shot run, and a bad request
//!    line yields an `Error` frame without poisoning the connection.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use comet::config::{BackendKind, InputSource, RunConfig};
use comet::coordinator::{self, RunOutcome};
use comet::decomp::Grid;
use comet::metrics::indexing;
use comet::metrics::MetricId;
use comet::output::sink::{CollectSink, DiscardSink, NodeSink, ResultSink, Tile};
use comet::serve::{self, ServeConfig, ServeError, Server};
use comet::session::{Session, SessionLimits};
use comet::vecdata::SyntheticKind;

fn cfg_for(metric: MetricId, num_way: usize, nv: usize, nf: usize, grid: Grid) -> RunConfig {
    let kind = match metric {
        MetricId::Ccc => SyntheticKind::Alleles,
        _ => SyntheticKind::RandomGrid,
    };
    RunConfig {
        metric,
        num_way,
        nv,
        nf,
        backend: BackendKind::CpuOptimized,
        grid,
        input: InputSource::Synthetic { kind, seed: 29 },
        store_metrics: true,
        ..Default::default()
    }
}

/// Assert every value of `(pairs, triples)` is bit-identical to the
/// baseline outcome's stores.
fn assert_bit_identical(
    what: &str,
    cfg: &RunConfig,
    baseline: &RunOutcome,
    pairs: &comet::metrics::store::PairStore,
    triples: &comet::metrics::store::TripleStore,
) {
    if cfg.num_way == 2 {
        let a = baseline.pairs.as_ref().unwrap().to_dense(cfg.nv);
        let b = pairs.to_dense(cfg.nv);
        assert_eq!(a.len(), b.len(), "{what}");
        for (off, (x, y)) in a.iter().zip(&b).enumerate() {
            assert_eq!(x.unwrap().to_bits(), y.unwrap().to_bits(), "{what} offset {off}");
        }
    } else {
        let a = baseline.triples.as_ref().unwrap().to_dense(cfg.nv);
        let b = triples.to_dense(cfg.nv);
        assert_eq!(a.len(), b.len(), "{what}");
        for (off, (x, y)) in a.iter().zip(&b).enumerate() {
            assert_eq!(x.unwrap().to_bits(), y.unwrap().to_bits(), "{what} offset {off}");
        }
    }
}

#[test]
fn concurrent_mixed_requests_are_bit_identical_and_share_ingests() {
    // Five distinct datasets × mixed metric families, each requested
    // twice → 10 concurrent submissions (acceptance floor: ≥ 8).
    let cfgs = vec![
        cfg_for(MetricId::Czekanowski, 2, 30, 48, Grid::new(1, 3, 1)),
        cfg_for(MetricId::Sorenson, 2, 32, 70, Grid::new(1, 4, 1)),
        cfg_for(MetricId::Ccc, 2, 24, 40, Grid::new(1, 2, 1)),
        cfg_for(MetricId::Czekanowski, 3, 16, 24, Grid::new(1, 2, 1)),
        {
            let mut f32_cfg = cfg_for(MetricId::Czekanowski, 2, 28, 36, Grid::new(1, 2, 1));
            f32_cfg.precision = comet::config::Precision::F32;
            f32_cfg
        },
    ];
    // Serial one-shot baselines — the pre-serving ground truth.
    let baselines: Vec<RunOutcome> =
        cfgs.iter().map(|c| coordinator::run(c).unwrap()).collect();

    // Resident bytes if everything stays cached: blocks of all five
    // datasets fit the budget, so the test pins "no evictions" AND
    // "bytes under budget" at once.
    let budget: u64 = 32 * 1024;
    let session = Arc::new(Session::with_limits(
        "artifacts",
        SessionLimits { block_cache_bytes: Some(budget), ..Default::default() },
    ));
    let server = Server::start(
        Arc::clone(&session),
        ServeConfig { workers: 3, queue_capacity: 16, max_request_bytes: None },
    )
    .unwrap();

    std::thread::scope(|s| {
        for round in 0..2 {
            for (i, cfg) in cfgs.iter().enumerate() {
                let server = &server;
                let baseline = &baselines[i];
                s.spawn(move || {
                    let sink = Arc::new(CollectSink::for_metric(cfg.metric));
                    let ticket = server.submit(cfg, Arc::clone(&sink) as Arc<dyn ResultSink>);
                    let out = ticket.unwrap().wait().unwrap();
                    let what = format!("cfg {i} round {round}");
                    assert_eq!(out.checksum, baseline.checksum, "{what}");
                    assert_eq!(out.stats.metrics, baseline.stats.metrics, "{what}");
                    let (pairs, triples) = sink.take();
                    assert_bit_identical(&what, cfg, baseline, &pairs, &triples);
                });
            }
        }
    });

    // Sharded reuse: the same dataset always lands on the same shard,
    // so its second request found every block cached — one ingest per
    // (dataset, block), total = Σ npv, however many requests ran.
    let mut total_ingests = 0u64;
    for cfg in &cfgs {
        let ds = session.request_from_config(cfg).unwrap().dataset().clone();
        assert_eq!(
            ds.ingest_count(),
            cfg.grid.npv as u64,
            "{} ingested more than once per block",
            cfg.metric.name()
        );
        total_ingests += ds.ingest_count();
    }
    assert_eq!(total_ingests, 3 + 4 + 2 + 2 + 2);

    let cache = session.cache_stats();
    assert_eq!(cache.misses, total_ingests, "every miss is exactly one ingest");
    assert!(cache.hits >= total_ingests, "second round must be served from cache");
    assert_eq!(cache.evictions, 0, "everything fits the budget");
    assert!(cache.bytes <= budget, "resident {} over budget {budget}", cache.bytes);
    assert!(cache.bytes > 0);

    let stats = server.stats();
    assert_eq!(stats.submitted, 10);
    drop(server); // joins the shard workers
    // completed is counted by the workers; all tickets resolved above.
    assert_eq!(stats.rejected_busy + stats.rejected_too_large, 0);
}

#[test]
fn eviction_refills_blocks_and_reproduces_cold_run_bits() {
    // One dataset, two grids over it. Budget holds either grid's
    // blocks but not both: running B evicts A's LRU blocks, and
    // re-running A must re-ingest exactly them, bit-identically.
    let cfg_a = cfg_for(MetricId::Czekanowski, 2, 24, 32, Grid::new(1, 2, 1)); // 2 × 3072 B
    let cfg_b = cfg_for(MetricId::Czekanowski, 2, 24, 32, Grid::new(1, 3, 1)); // 3 × 2048 B
    let budget: u64 = 6144;
    let one_shot_a = coordinator::run(&cfg_a).unwrap();

    let session = Session::with_limits(
        "artifacts",
        SessionLimits { block_cache_bytes: Some(budget), ..Default::default() },
    );
    let req_a = session.request_from_config(&cfg_a).unwrap();
    let req_b = session.request_from_config(&cfg_b).unwrap();
    let ds = req_a.dataset().clone();

    let cold_a = session.run_collect(&req_a).unwrap();
    assert_eq!(cold_a.stats.cache_misses, 2);
    assert_eq!(cold_a.stats.cache_evictions, 0);
    assert_eq!(cold_a.stats.cache_bytes, 6144, "both A blocks resident");
    assert_eq!(ds.ingest_count(), 2);

    // B's three blocks don't fit next to A's two: the two A blocks
    // (the coldest entries) are the LRU victims, in order.
    let run_b = session.run_collect(&req_b).unwrap();
    assert_eq!(run_b.stats.cache_misses, 3);
    assert_eq!(run_b.stats.cache_evictions, 2, "exactly the two A blocks evicted");
    assert_eq!(run_b.stats.cache_bytes, 6144, "three B blocks resident");
    assert_eq!(ds.ingest_count(), 5);

    // Re-running A: its blocks were evicted, so the ingest counter
    // moves by exactly the evicted block count — and the refilled
    // blocks reproduce the cold run bit-for-bit.
    let warm_a = session.run_collect(&req_a).unwrap();
    assert_eq!(warm_a.stats.cache_misses, 2, "evicted blocks re-ingest");
    assert_eq!(warm_a.stats.cache_evictions, 3, "B's blocks evicted in turn");
    assert_eq!(warm_a.stats.cache_bytes, 6144);
    assert_eq!(ds.ingest_count(), 7);

    assert_eq!(warm_a.checksum, one_shot_a.checksum);
    assert_eq!(warm_a.checksum, cold_a.checksum);
    let a = one_shot_a.pairs.as_ref().unwrap().to_dense(cfg_a.nv);
    let b = warm_a.pairs.as_ref().unwrap().to_dense(cfg_a.nv);
    for (off, (x, y)) in a.iter().zip(&b).enumerate() {
        assert_eq!(x.unwrap().to_bits(), y.unwrap().to_bits(), "offset {off}");
    }

    let cache = session.cache_stats();
    assert_eq!(cache.hits, 0, "every touch in this schedule is a miss");
    assert_eq!((cache.misses, cache.evictions), (7, 5));
    assert!(cache.bytes <= budget);
}

/// A sink whose node sinks block until the gate opens — pins a worker
/// inside a run so the test can saturate its shard queue.
struct GateSink {
    gate: Arc<(Mutex<bool>, Condvar)>,
}

struct DropTiles;

impl NodeSink for DropTiles {
    fn tile(&mut self, _tile: Tile) -> comet::Result<()> {
        Ok(())
    }
}

impl ResultSink for GateSink {
    fn node_sink(&self, _rank: usize) -> comet::Result<Box<dyn NodeSink>> {
        let (flag, cv) = &*self.gate;
        let mut open = flag.lock().unwrap();
        while !*open {
            open = cv.wait(open).unwrap();
        }
        Ok(Box::new(DropTiles))
    }
}

#[test]
fn saturated_queue_rejects_typed_then_recovers() {
    let session = Arc::new(Session::new());
    let server = Server::start(
        Arc::clone(&session),
        ServeConfig { workers: 1, queue_capacity: 2, max_request_bytes: Some(100_000) },
    )
    .unwrap();
    let cfg = cfg_for(MetricId::Czekanowski, 2, 12, 16, Grid::new(1, 1, 1));
    let shard = server.shard_of(&cfg);

    // Job 1 runs immediately but blocks inside its sink, pinning the
    // single worker mid-run.
    let gate = Arc::new((Mutex::new(false), Condvar::new()));
    let t1 = server
        .submit(&cfg, Arc::new(GateSink { gate: Arc::clone(&gate) }))
        .unwrap();
    let deadline = Instant::now() + Duration::from_secs(30);
    while server.queue_depth(shard) > 0 {
        assert!(Instant::now() < deadline, "worker never picked up the gated job");
        std::thread::sleep(Duration::from_millis(2));
    }

    // Jobs 2 and 3 fill the bounded queue; job 4 must be rejected
    // *immediately* with the typed Busy — not block, not deadlock.
    let t2 = server.submit(&cfg, Arc::new(DiscardSink)).unwrap();
    let t3 = server.submit(&cfg, Arc::new(DiscardSink)).unwrap();
    match server.submit(&cfg, Arc::new(DiscardSink)) {
        Err(ServeError::Busy { shard: s, capacity }) => {
            assert_eq!((s, capacity), (shard, 2));
        }
        other => panic!("expected Busy, got {:?}", other.map(|_| ())),
    }

    // Size admission is independent of queue state: an estimated-bytes
    // blowout is rejected typed even while the shard is saturated.
    let huge = cfg_for(MetricId::Czekanowski, 2, 4096, 1024, Grid::new(1, 1, 1));
    match server.submit(&huge, Arc::new(DiscardSink)) {
        Err(ServeError::TooLarge { estimated_bytes, limit }) => {
            assert_eq!(limit, 100_000);
            assert_eq!(estimated_bytes, 4096 * 1024 * 8);
        }
        other => panic!("expected TooLarge, got {:?}", other.map(|_| ())),
    }

    // Open the gate: the queue drains and every accepted job completes.
    {
        let (flag, cv) = &*gate;
        *flag.lock().unwrap() = true;
        cv.notify_all();
    }
    t1.wait().unwrap();
    t2.wait().unwrap();
    t3.wait().unwrap();

    // Recovery: the drained shard accepts again.
    let t5 = server.submit(&cfg, Arc::new(DiscardSink)).unwrap();
    t5.wait().unwrap();

    let stats = server.stats();
    assert_eq!(stats.submitted, 4);
    assert_eq!(stats.completed, 4);
    assert_eq!(stats.rejected_busy, 1);
    assert_eq!(stats.rejected_too_large, 1);
    assert_eq!(server.queue_depth(shard), 0);
}

#[test]
fn socket_round_trip_matches_one_shot_and_survives_bad_requests() {
    let line = "metric=sorenson nv=32 nf=70 npv=2 seed=11";
    let mut baseline_cfg = RunConfig::from_kv_line(line).unwrap();
    baseline_cfg.store_metrics = true;
    let baseline = coordinator::run(&baseline_cfg).unwrap();

    let session = Arc::new(Session::new());
    let server = Server::start(Arc::clone(&session), ServeConfig::default()).unwrap();

    let (mut client, server_end) = std::os::unix::net::UnixStream::pair().unwrap();
    let requests_done = Arc::new(AtomicUsize::new(0));
    std::thread::scope(|s| {
        let server = &server;
        let conn = s.spawn(move || {
            let reader = server_end.try_clone().unwrap();
            serve::serve_connection(server, reader, server_end)
        });

        for attempt in 0..2 {
            let reply = serve::request_over_stream(&mut client, line).unwrap();
            assert_eq!(reply.checksum, baseline.checksum.digest(), "attempt {attempt}");
            assert_eq!(reply.metrics, baseline.stats.metrics, "attempt {attempt}");
            assert_eq!(reply.values, baseline.stats.metrics, "attempt {attempt}");
            // Bit-identity of every streamed value, not just the digest.
            let dense = baseline.pairs.as_ref().unwrap().to_dense(baseline_cfg.nv);
            let mut got = vec![None; dense.len()];
            for tile in &reply.tiles {
                match tile {
                    Tile::Pairs { entries, .. } => {
                        for e in entries {
                            got[indexing::pair_offset(e.i as usize, e.j as usize)] =
                                Some(e.value);
                        }
                    }
                    Tile::Triples { .. } => panic!("2-way run emitted a triples tile"),
                }
            }
            for (off, (x, y)) in dense.iter().zip(&got).enumerate() {
                assert_eq!(
                    x.unwrap().to_bits(),
                    y.unwrap().to_bits(),
                    "attempt {attempt} offset {off}"
                );
            }

            // A bad request line is an Error frame, and the connection
            // stays usable for the next (good) request of this loop.
            let err = serve::request_over_stream(&mut client, "metric=bogus nv=8").unwrap_err();
            assert!(format!("{err:#}").contains("server error"), "{err:#}");
            requests_done.fetch_add(1, Ordering::Relaxed);
        }

        drop(client); // EOF ends the connection loop cleanly
        conn.join().unwrap().unwrap();
    });
    assert_eq!(requests_done.load(Ordering::Relaxed), 2);

    let stats = server.stats();
    assert_eq!(stats.submitted, 2, "bad lines never reach the scheduler");
    assert_eq!(stats.completed, 2);
}
