//! Session-reuse contracts (ISSUE 5 acceptance):
//!
//! 1. A `Session` running the same request N times produces checksums
//!    (and per-pair values) **bit-identical** to the pre-redesign
//!    one-shot `coordinator::run`, for all three metrics in 2-way runs
//!    and for Czekanowski in 3-way runs, on both native backends.
//! 2. Dataset blocks are ingested **once per (repr, grid slice)**
//!    across N runs — pinned by both `bits::pack_calls()` (the
//!    process-global packing counter) and the dataset's own ingest
//!    counter.
//! 3. The sink-forwarding path streams bounded tiles and materializes
//!    no store.
//! 4. Session file output is byte-identical to one-shot file output.
//!
//! `bits::pack_calls()` is process-global, so every test in this
//! binary serializes on [`lock`] (the `tests/comm_accounting.rs`
//! pattern).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock};

use comet::config::{BackendKind, InputSource, RunConfig};
use comet::coordinator;
use comet::decomp::Grid;
use comet::metrics::MetricId;
use comet::output::sink::{ForwardSink, StatsOnlySink};
use comet::session::Session;
use comet::vecdata::{bits, SyntheticKind};

fn lock() -> MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(|p| p.into_inner())
}

fn cfg_for(
    metric: MetricId,
    num_way: usize,
    nv: usize,
    nf: usize,
    grid: Grid,
    backend: BackendKind,
) -> RunConfig {
    let kind = match metric {
        MetricId::Ccc => SyntheticKind::Alleles,
        _ => SyntheticKind::RandomGrid,
    };
    RunConfig {
        metric,
        num_way,
        nv,
        nf,
        backend,
        grid,
        input: InputSource::Synthetic { kind, seed: 29 },
        store_metrics: true,
        ..Default::default()
    }
}

#[test]
fn session_runs_bit_identical_to_one_shot_across_metrics_and_backends() {
    let _g = lock();
    for backend in [BackendKind::CpuOptimized, BackendKind::CpuReference] {
        for metric in MetricId::ALL {
            let cfg = cfg_for(metric, 2, 30, 48, Grid::new(1, 3, 1), backend);
            let one_shot = coordinator::run(&cfg).unwrap();
            let session = Session::new();
            let req = session.request_from_config(&cfg).unwrap();
            let first = session.run_collect(&req).unwrap();
            let second = session.run_collect(&req).unwrap();
            let what = format!("{} on {:?}", metric.name(), backend);
            assert_eq!(first.checksum, one_shot.checksum, "{what} (first)");
            assert_eq!(second.checksum, one_shot.checksum, "{what} (reused)");
            assert_eq!(second.stats.metrics, one_shot.stats.metrics, "{what}");
            // Values, not just digests: dense offset-keyed equality.
            let a = one_shot.pairs.as_ref().unwrap().to_dense(cfg.nv);
            let b = second.pairs.as_ref().unwrap().to_dense(cfg.nv);
            for (off, (x, y)) in a.iter().zip(&b).enumerate() {
                assert_eq!(
                    x.unwrap().to_bits(),
                    y.unwrap().to_bits(),
                    "{what} offset {off}"
                );
            }
        }

        // 3-way (Czekanowski is the only registered 3-way family).
        let cfg = cfg_for(MetricId::Czekanowski, 3, 16, 24, Grid::new(1, 2, 1), backend);
        let one_shot = coordinator::run(&cfg).unwrap();
        let session = Session::new();
        let req = session.request_from_config(&cfg).unwrap();
        let first = session.run_collect(&req).unwrap();
        let second = session.run_collect(&req).unwrap();
        assert_eq!(first.checksum, one_shot.checksum, "3-way on {backend:?}");
        assert_eq!(second.checksum, one_shot.checksum, "3-way reused on {backend:?}");
        let a = one_shot.triples.as_ref().unwrap().to_dense(cfg.nv);
        let b = second.triples.as_ref().unwrap().to_dense(cfg.nv);
        for (off, (x, y)) in a.iter().zip(&b).enumerate() {
            assert_eq!(
                x.unwrap().to_bits(),
                y.unwrap().to_bits(),
                "3-way {backend:?} offset {off}"
            );
        }
    }
}

#[test]
fn blocks_ingest_once_per_repr_across_n_runs() {
    let _g = lock();
    let cfg =
        cfg_for(MetricId::Sorenson, 2, 32, 70, Grid::new(1, 4, 1), BackendKind::CpuOptimized);

    // One-shot baseline: every run re-packs every node block.
    let before = bits::pack_calls();
    let baseline = coordinator::run(&cfg).unwrap();
    assert_eq!(
        bits::pack_calls() - before,
        4,
        "a one-shot run packs once per node block (npv=4)"
    );

    // Session: N runs, one pack per block total.
    let session = Session::new();
    let req = session.request_from_config(&cfg).unwrap();
    let ds = req.dataset().clone();
    let before = bits::pack_calls();
    for round in 0..3 {
        let out = session.run_collect(&req).unwrap();
        assert_eq!(out.checksum, baseline.checksum, "round {round}");
    }
    assert_eq!(
        bits::pack_calls() - before,
        4,
        "3 session runs pack each block exactly once"
    );
    assert_eq!(ds.ingest_count(), 4);

    // A float metric over the same dataset handle: a second
    // representation ingests its own blocks, with zero packing.
    let cz_cfg = RunConfig { metric: MetricId::Czekanowski, ..cfg.clone() };
    let cz_req = session.request_from_config(&cz_cfg).unwrap();
    let before = bits::pack_calls();
    session.run_collect(&cz_req).unwrap();
    session.run_collect(&cz_req).unwrap();
    assert_eq!(bits::pack_calls() - before, 0, "float runs never pack");
    assert_eq!(ds.ingest_count(), 8, "4 packed + 4 float blocks, each once");
}

#[test]
fn replicated_ranks_share_ingests_deterministically() {
    let _g = lock();
    // npr = 2: ranks replicated along the replication axis ask for the
    // SAME (pv, pf) block. The per-key slot serializes the racing
    // fills, so even the first session run ingests npv blocks where a
    // one-shot run loads one per rank — and the counters stay exact.
    let cfg =
        cfg_for(MetricId::Sorenson, 2, 24, 64, Grid::new(1, 2, 2), BackendKind::CpuOptimized);
    let one_shot = coordinator::run(&cfg).unwrap();

    let session = Session::new();
    let req = session.request_from_config(&cfg).unwrap();
    let before = bits::pack_calls();
    let a = session.run_collect(&req).unwrap();
    let b = session.run_collect(&req).unwrap();
    assert_eq!(a.checksum, one_shot.checksum);
    assert_eq!(b.checksum, one_shot.checksum);
    assert_eq!(
        bits::pack_calls() - before,
        2,
        "2 distinct (pv, pf) blocks packed once each across 2 runs × 4 ranks"
    );
    assert_eq!(req.dataset().ingest_count(), 2);
}

#[test]
fn sink_forwarding_streams_tiles_without_store() {
    let _g = lock();
    let session = Session::new();
    let cfg =
        cfg_for(MetricId::Czekanowski, 2, 40, 32, Grid::new(1, 4, 1), BackendKind::CpuOptimized);
    let req = session.request_from_config(&cfg).unwrap();

    let values = Arc::new(AtomicU64::new(0));
    let max_tile = Arc::new(AtomicU64::new(0));
    let (v2, m2) = (Arc::clone(&values), Arc::clone(&max_tile));
    let forward = ForwardSink::new(move |_rank, tile| {
        v2.fetch_add(tile.len() as u64, Ordering::Relaxed);
        m2.fetch_max(tile.len() as u64, Ordering::Relaxed);
        Ok(())
    });
    let out = session.run(&req, &forward).unwrap();

    let total = (cfg.nv * (cfg.nv - 1) / 2) as u64;
    assert!(
        out.pairs.is_none() && out.triples.is_none(),
        "forwarding path must not materialize a store"
    );
    assert_eq!(values.load(Ordering::Relaxed), total, "every value streamed");
    assert_eq!(out.stats.metrics, total);
    assert_eq!(out.stats.tiles, 10, "npv=4 → 10 computed blocks → 10 tiles");
    assert!(
        max_tile.load(Ordering::Relaxed) < total,
        "every tile strictly smaller than the campaign ({} vs {total})",
        max_tile.load(Ordering::Relaxed)
    );

    // Same contract on the 3-way path.
    let cfg3 =
        cfg_for(MetricId::Czekanowski, 3, 18, 24, Grid::new(1, 3, 1), BackendKind::CpuOptimized);
    let req3 = session.request_from_config(&cfg3).unwrap();
    let stats = StatsOnlySink::new();
    let out3 = session.run(&req3, &stats).unwrap();
    assert!(out3.triples.is_none());
    assert_eq!(stats.values(), out3.stats.metrics);
    assert!(out3.stats.tiles > 1);
    assert!(stats.max_tile_len() < stats.values());
}

#[test]
fn session_file_output_matches_one_shot_bytes() {
    let _g = lock();
    let base = std::env::temp_dir().join(format!("comet-session-files-{}", std::process::id()));
    let mut cfg =
        cfg_for(MetricId::Sorenson, 2, 24, 64, Grid::new(1, 2, 1), BackendKind::CpuOptimized);
    cfg.store_metrics = false;
    cfg.output_dir = Some(base.join("oneshot").to_string_lossy().into_owned());
    coordinator::run(&cfg).unwrap();

    let session = Session::new();
    let mut cfg2 = cfg.clone();
    cfg2.output_dir = Some(base.join("session").to_string_lossy().into_owned());
    let req = session.request_from_config(&cfg2).unwrap();
    // Two runs: the second rewrites the same bytes from cached blocks.
    session.run_collect(&req).unwrap();
    session.run_collect(&req).unwrap();

    for rank in 0..cfg.grid.np() {
        let a = comet::output::read_dense(&base.join("oneshot").join(format!("metrics_{rank}.bin")))
            .unwrap();
        let b = comet::output::read_dense(&base.join("session").join(format!("metrics_{rank}.bin")))
            .unwrap();
        assert!(!a.is_empty());
        assert_eq!(a, b, "rank {rank}");
    }
    assert!(base.join("session").join("run.meta").exists());
    std::fs::remove_dir_all(&base).ok();
}
