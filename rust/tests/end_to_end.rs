//! End-to-end tests over the FULL three-layer stack: synthetic data →
//! virtual cluster → Algorithm 1/2+3 schedules → PJRT-executed AOT
//! artifacts (Pallas/XLA lowerings) → denominators/quotients →
//! checksums. Requires `make artifacts`.

use std::path::Path;

use comet::config::{BackendKind, InputSource, Precision, RunConfig};
use comet::coordinator::run_with_artifacts;
use comet::decomp::Grid;
use comet::vecdata::SyntheticKind;

/// None (with a skip note) when artifacts are not built, so the rest
/// of the suite still runs on artifact-less hosts/CI.
fn artifacts() -> Option<&'static Path> {
    let p = Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"));
    if !p.join("manifest.txt").exists() {
        eprintln!("skipping: artifacts missing — run `make artifacts`");
        return None;
    }
    Some(p)
}

fn cfg(num_way: usize, nv: usize, nf: usize, precision: Precision) -> RunConfig {
    RunConfig {
        num_way,
        nv,
        nf,
        precision,
        backend: BackendKind::Pjrt,
        grid: Grid::new(1, 1, 1),
        input: InputSource::Synthetic { kind: SyntheticKind::RandomGrid, seed: 11 },
        ..Default::default()
    }
}

/// PJRT coordinator run must equal the native-backend coordinator run
/// bit-for-bit (grid-valued data ⇒ exact sums everywhere).
#[test]
fn e2e_2way_pjrt_equals_native_f64() {
    let Some(arts) = artifacts() else { return };
    let mut c = cfg(2, 48, 64, Precision::F64);
    c.grid = Grid::new(1, 3, 1);
    let pjrt = run_with_artifacts(&c, arts).unwrap();
    c.backend = BackendKind::CpuOptimized;
    let native = run_with_artifacts(&c, arts).unwrap();
    assert_eq!(pjrt.checksum, native.checksum);
    assert!(pjrt.stats.t_accel > 0.0, "accelerator time must be recorded");
}

#[test]
fn e2e_2way_pjrt_f32_multinode() {
    let Some(arts) = artifacts() else { return };
    let mut c = cfg(2, 64, 96, Precision::F32);
    c.grid = Grid::new(1, 4, 2);
    let pjrt = run_with_artifacts(&c, arts).unwrap();
    c.backend = BackendKind::CpuOptimized;
    let native = run_with_artifacts(&c, arts).unwrap();
    assert_eq!(pjrt.checksum, native.checksum);
}

#[test]
fn e2e_3way_pjrt_equals_native() {
    let Some(arts) = artifacts() else { return };
    let mut c = cfg(3, 24, 48, Precision::F64);
    c.grid = Grid::new(1, 2, 1);
    let pjrt = run_with_artifacts(&c, arts).unwrap();
    c.backend = BackendKind::CpuOptimized;
    let native = run_with_artifacts(&c, arts).unwrap();
    assert_eq!(pjrt.checksum, native.checksum);
    assert!(pjrt.stats.mgemm3_calls > 0);
}

#[test]
fn e2e_3way_staged_pjrt() {
    let Some(arts) = artifacts() else { return };
    // Single computed stage of a staged campaign (the §6.8 pattern:
    // "only the last stage of n_st = 220 stages is computed").
    let mut c = cfg(3, 18, 32, Precision::F64);
    c.grid = Grid::new(1, 3, 1);
    c.num_stage = 3;
    c.stage = Some(2);
    let part = run_with_artifacts(&c, arts).unwrap();
    // Against native, same stage.
    c.backend = BackendKind::CpuOptimized;
    let native = run_with_artifacts(&c, arts).unwrap();
    assert_eq!(part.checksum, native.checksum);
    assert!(part.stats.metrics < 18 * 17 * 16 / 6, "a stage is a strict subset");
}

#[test]
fn e2e_pallas_kernel_lowering_through_coordinator() {
    let Some(arts) = artifacts() else { return };
    // Force the coordinator's PJRT backend onto the Pallas-kernel
    // artifacts: full L1→L2→L3 compose check.
    use comet::coordinator::backend::{Backend, PjrtBackend};
    use comet::runtime::PjrtService;
    use comet::vecdata::VectorSet;
    let svc = PjrtService::start(arts).unwrap();
    let be = PjrtBackend::new(svc.client(), Precision::F32).with_kinds("mgemm2pallas", "mgemm3pallas");
    let v: VectorSet<f32> = VectorSet::generate(SyntheticKind::RandomGrid, 13, 64, 20, 0);
    let backend: std::sync::Arc<dyn Backend<f32>> = std::sync::Arc::new(be);
    let pairs = comet::coordinator::serial::all_pairs(&backend, &v).unwrap();
    let triples = comet::coordinator::serial::all_triples(&backend, &v).unwrap();
    // Scalar oracle comparison.
    for e in pairs.iter() {
        let want = comet::metrics::czekanowski2(v.col(e.i as usize), v.col(e.j as usize));
        assert!((e.value - want).abs() < 1e-6, "pair ({},{})", e.i, e.j);
    }
    for e in triples.iter().take(200) {
        let want = comet::metrics::czekanowski3(
            v.col(e.i as usize),
            v.col(e.j as usize),
            v.col(e.k as usize),
        );
        assert!((e.value - want).abs() < 1e-6, "triple ({},{},{})", e.i, e.j, e.k);
    }
}

#[test]
fn e2e_ccc_pjrt_equals_native() {
    let Some(arts) = artifacts() else { return };
    // CCC numerators route to the "gemm"-kind artifacts (the metric
    // engine's Dot2 kernel family); integer-valued allele data keeps
    // every path exact, so PJRT must equal native bit-for-bit.
    let mut c = cfg(2, 40, 64, Precision::F64);
    c.metric = comet::metrics::MetricId::Ccc;
    c.input = InputSource::Synthetic { kind: SyntheticKind::Alleles, seed: 19 };
    c.grid = Grid::new(1, 2, 1);
    let pjrt = run_with_artifacts(&c, arts).unwrap();
    c.backend = BackendKind::CpuOptimized;
    let native = run_with_artifacts(&c, arts).unwrap();
    assert_eq!(pjrt.checksum, native.checksum);
}

#[test]
fn e2e_sorenson_pjrt_equals_native() {
    let Some(arts) = artifacts() else { return };
    // Bit-packed Sorensen routes to the packed-u32 AND+popcount
    // artifacts; popcounts are integers, so PJRT equals native exactly.
    let mut c = cfg(2, 48, 96, Precision::F32);
    c.metric = comet::metrics::MetricId::Sorenson;
    c.grid = Grid::new(1, 3, 1);
    let pjrt = run_with_artifacts(&c, arts).unwrap();
    c.backend = BackendKind::CpuOptimized;
    let native = run_with_artifacts(&c, arts).unwrap();
    assert_eq!(pjrt.checksum, native.checksum);
}

#[test]
fn e2e_output_campaign_with_pjrt() {
    let Some(arts) = artifacts() else { return };
    let dir = std::env::temp_dir().join(format!("comet-e2e-out-{}", std::process::id()));
    let mut c = cfg(2, 32, 48, Precision::F32);
    c.grid = Grid::new(1, 2, 1);
    c.output_dir = Some(dir.to_string_lossy().into_owned());
    let out = run_with_artifacts(&c, arts).unwrap();
    let mut total = 0usize;
    for rank in 0..c.grid.np() {
        total += comet::output::read_dense(&dir.join(format!("metrics_{rank}.bin")))
            .unwrap()
            .len();
    }
    assert_eq!(total as u64, out.stats.metrics);
    assert_eq!(total, 32 * 31 / 2);
    std::fs::remove_dir_all(&dir).ok();
}
