//! The ISSUE 8 out-of-core streaming-ingest contracts:
//!
//! 1. **Codec bit-identity** — the spill codec round-trips every block
//!    representation (f64/f32 float panels, packed u64 words including
//!    partial trailing words) byte-for-byte (property test).
//! 2. **Out-of-core runs are bit-identical** — a session squeezed under
//!    a tiny `block_cache_bytes` budget (forcing spill → reload cycles)
//!    reproduces the unbudgeted one-shot run's checksum *and* every
//!    streamed value, across metrics × backends × decompositions ×
//!    thread counts — with ≥ 1 spill and ≥ 1 reload pinned by
//!    `RunStats` and zero extra ingests (reload ≠ re-ingest).
//! 3. **Fault injection** — scripted transient reload faults retry with
//!    backoff and recover with zero checksum drift; permanent faults
//!    surface as typed [`StoreError`]s through `Session::run` and as an
//!    `Error` wire frame through `comet serve` (connection survives);
//!    a poisoned spill file is detected by the per-block checksum.
//! 4. **Prefetch scheduler** — the read-ahead task fetches blocks in
//!    step-schedule order ([`prefetch_order`]), never holds more than
//!    its in-flight budget, and makes progress at budget = 1 (the
//!    pool's submit head-room guarantees a worker even when kernels
//!    saturate it).
//!
//! Pool counters and the prefetch task share process-global state, so
//! every test serializes on [`lock`] like `tests/simd_pool.rs`.

use std::sync::{Arc, Mutex, MutexGuard, OnceLock};

use comet::config::{BackendKind, InputSource, Precision, RunConfig};
use comet::coordinator::prefetch::{prefetch_order, ReadAhead};
use comet::coordinator::{self, BlockProvider, RunOutcome};
use comet::decomp::Grid;
use comet::metrics::{make_metric, MetricId};
use comet::serve::{self, ServeConfig, Server};
use comet::session::{Session, SessionLimits};
use comet::testkit::faults::FailingStore;
use comet::testkit::forall;
use comet::vecdata::bits::BitVectorSet;
use comet::vecdata::block::Block;
use comet::vecdata::oocstore::{self, MemStore, StoreError, StoreErrorKind, RETRY_ATTEMPTS};
use comet::vecdata::{SyntheticKind, VectorSet};

fn lock() -> MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(|p| p.into_inner())
}

fn sweep_cfg(
    metric: MetricId,
    num_way: usize,
    backend: BackendKind,
    threads: usize,
    grid: Grid,
    precision: Precision,
) -> RunConfig {
    let kind = match metric {
        MetricId::Ccc => SyntheticKind::Alleles,
        _ => SyntheticKind::RandomGrid,
    };
    RunConfig {
        metric,
        num_way,
        nv: 16,
        nf: 40,
        precision,
        backend,
        threads,
        grid,
        input: InputSource::Synthetic { kind, seed: 31 },
        store_metrics: true,
        ..Default::default()
    }
}

/// Resident bytes of one of `cfg`'s blocks — measured through a
/// throwaway unbudgeted session, so budget tests can size
/// `block_cache_bytes` exactly (packed Sorensen blocks are ~64× smaller
/// than the float panels of the same slice).
fn block_bytes(cfg: &RunConfig) -> u64 {
    let probe = Session::new();
    let ds = probe.request_from_config(cfg).unwrap().dataset().clone();
    match cfg.precision {
        Precision::F64 => {
            let m = make_metric::<f64>(cfg.metric, cfg);
            ds.block_f64(cfg, m.as_ref(), 0, 0).unwrap().resident_bytes()
        }
        Precision::F32 => {
            let m = make_metric::<f32>(cfg.metric, cfg);
            ds.block_f32(cfg, m.as_ref(), 0, 0).unwrap().resident_bytes()
        }
    }
}

/// Every streamed value of `b` is bit-identical to `a`'s.
fn assert_same_values(what: &str, cfg: &RunConfig, a: &RunOutcome, b: &RunOutcome) {
    assert_eq!(a.checksum, b.checksum, "{what}: checksum");
    if cfg.num_way == 2 {
        let x = a.pairs.as_ref().unwrap().to_dense(cfg.nv);
        let y = b.pairs.as_ref().unwrap().to_dense(cfg.nv);
        assert_eq!(x.len(), y.len(), "{what}");
        for (off, (p, q)) in x.iter().zip(&y).enumerate() {
            assert_eq!(p.unwrap().to_bits(), q.unwrap().to_bits(), "{what} offset {off}");
        }
    } else {
        let x = a.triples.as_ref().unwrap().to_dense(cfg.nv);
        let y = b.triples.as_ref().unwrap().to_dense(cfg.nv);
        assert_eq!(x.len(), y.len(), "{what}");
        for (off, (p, q)) in x.iter().zip(&y).enumerate() {
            assert_eq!(p.unwrap().to_bits(), q.unwrap().to_bits(), "{what} offset {off}");
        }
    }
}

#[test]
fn prop_spill_codec_roundtrips_every_repr_bit_exactly() {
    let _g = lock();
    // nf in 1..=300 crosses the 64-bit word boundaries, so packed
    // blocks exercise every partial-trailing-word shape; first_id and
    // nv vary so shape metadata is pinned too. repr 0/1/2 = f64 panel,
    // f32 panel, packed words.
    forall(
        "spill-codec-roundtrip",
        60,
        |g| {
            let nf = g.usize_in(1, 300);
            let nv = g.usize_in(1, 10);
            let first = g.usize_in(0, 900);
            let repr = g.usize_in(0, 2);
            let density = *g.pick(&[0.0, 0.3, 1.0]);
            let seed = g.stream.next_u64();
            (nf, nv, first, repr, density, seed)
        },
        |&(nf, nv, first, repr, density, seed)| {
            match repr {
                0 => {
                    let v: VectorSet<f64> =
                        VectorSet::generate(SyntheticKind::RandomGrid, seed, nf, nv, first);
                    let block = Block::Float(Arc::new(v));
                    let back = oocstore::decode::<f64>(&oocstore::encode(&block))
                        .map_err(|e| format!("f64 decode: {e}"))?;
                    if (back.nf(), back.nv(), back.first_id()) != (nf, nv, first) {
                        return Err("f64 shape metadata drifted".into());
                    }
                    let (a, b) = (block.as_float().unwrap(), back.as_float().unwrap());
                    for (x, y) in a.raw().iter().zip(b.raw()) {
                        if x.to_bits() != y.to_bits() {
                            return Err(format!("f64 payload drifted at nf={nf} nv={nv}"));
                        }
                    }
                }
                1 => {
                    let v: VectorSet<f32> =
                        VectorSet::generate(SyntheticKind::RandomGrid, seed, nf, nv, first);
                    let block = Block::Float(Arc::new(v));
                    let back = oocstore::decode::<f32>(&oocstore::encode(&block))
                        .map_err(|e| format!("f32 decode: {e}"))?;
                    if (back.nf(), back.nv(), back.first_id()) != (nf, nv, first) {
                        return Err("f32 shape metadata drifted".into());
                    }
                    let (a, b) = (block.as_float().unwrap(), back.as_float().unwrap());
                    for (x, y) in a.raw().iter().zip(b.raw()) {
                        if x.to_bits() != y.to_bits() {
                            return Err(format!("f32 payload drifted at nf={nf} nv={nv}"));
                        }
                    }
                }
                _ => {
                    let mut bits = BitVectorSet::generate(seed, nf, nv, density);
                    bits.first_id = first;
                    let block: Block<f64> = Block::Packed(Arc::new(bits.clone()));
                    let back = oocstore::decode::<f64>(&oocstore::encode(&block))
                        .map_err(|e| format!("packed decode: {e}"))?;
                    let rb = back.as_packed().unwrap();
                    if (rb.nf, rb.nv, rb.first_id) != (nf, nv, first) {
                        return Err("packed shape metadata drifted".into());
                    }
                    if rb.raw_words() != bits.raw_words() {
                        return Err(format!("packed words drifted at nf={nf} nv={nv}"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn out_of_core_runs_are_bit_identical_across_metrics_backends_grids_threads() {
    let _g = lock();
    let combos: Vec<RunConfig> = [
        (MetricId::Czekanowski, 2, BackendKind::CpuOptimized, 1, (1, 4, 1), Precision::F64),
        (MetricId::Czekanowski, 2, BackendKind::CpuReference, 2, (1, 4, 1), Precision::F64),
        (MetricId::Czekanowski, 2, BackendKind::CpuOptimized, 4, (2, 2, 1), Precision::F64),
        (MetricId::Czekanowski, 2, BackendKind::CpuOptimized, 2, (1, 2, 2), Precision::F64),
        (MetricId::Czekanowski, 3, BackendKind::CpuOptimized, 2, (1, 2, 1), Precision::F64),
        (MetricId::Czekanowski, 2, BackendKind::CpuOptimized, 2, (1, 4, 1), Precision::F32),
        (MetricId::Ccc, 2, BackendKind::CpuOptimized, 1, (1, 4, 1), Precision::F64),
        (MetricId::Ccc, 2, BackendKind::CpuReference, 4, (1, 2, 1), Precision::F64),
        (MetricId::Sorenson, 2, BackendKind::CpuOptimized, 2, (1, 4, 1), Precision::F64),
        (MetricId::Sorenson, 2, BackendKind::CpuReference, 1, (1, 4, 1), Precision::F64),
    ]
    .into_iter()
    .map(|(m, w, b, t, (gf, gv, gr), p)| sweep_cfg(m, w, b, t, Grid::new(gf, gv, gr), p))
    .collect();
    for cfg in &combos {
        let what = format!(
            "{} {}-way {:?} t={} grid={}x{}x{} {:?}",
            cfg.metric.name(),
            cfg.num_way,
            cfg.backend,
            cfg.threads,
            cfg.grid.npf,
            cfg.grid.npv,
            cfg.grid.npr,
            cfg.precision
        );
        let baseline = coordinator::run(cfg).unwrap();
        // Budget = 1.5 blocks: every fill past the first evicts, so the
        // cold run spills and any rerun reloads — the out-of-core path
        // is exercised on every combo, not just the float ones.
        let budget = block_bytes(cfg) * 3 / 2;
        let session = Session::with_limits(
            "artifacts",
            SessionLimits { block_cache_bytes: Some(budget), ..Default::default() },
        );
        let req = session.request_from_config(cfg).unwrap();
        let ds = req.dataset().clone();
        let cold = session.run_collect(&req).unwrap();
        assert!(cold.stats.spills >= 1, "{what}: cold run must spill ({:?})", cold.stats.spills);
        let ingests_after_cold = ds.ingest_count();
        let warm = session.run_collect(&req).unwrap();
        assert!(warm.stats.reloads >= 1, "{what}: warm run must reload");
        assert_eq!(ds.ingest_count(), ingests_after_cold, "{what}: a reload must never re-ingest");
        assert_same_values(&format!("{what} cold"), cfg, &baseline, &cold);
        assert_same_values(&format!("{what} warm"), cfg, &baseline, &warm);
        assert!(session.cache_stats().bytes <= budget, "{what}: resident bytes over budget");
        assert_eq!(session.cache_stats().spill_errors, 0, "{what}");
    }
}

/// The shared fault-rig shape: Czekanowski over 4 blocks, budget 1.5
/// blocks, spilling through a [`FailingStore`] over a [`MemStore`].
fn fault_rig() -> (RunConfig, Arc<MemStore>, Arc<FailingStore>, Session) {
    let cfg = sweep_cfg(
        MetricId::Czekanowski,
        2,
        BackendKind::CpuOptimized,
        2,
        Grid::new(1, 4, 1),
        Precision::F64,
    );
    let budget = block_bytes(&cfg) * 3 / 2;
    let mem = Arc::new(MemStore::new());
    let failing = Arc::new(FailingStore::new(mem.clone()));
    let session = Session::with_spill_store(
        "artifacts",
        SessionLimits { block_cache_bytes: Some(budget), ..Default::default() },
        failing.clone(),
    );
    (cfg, mem, failing, session)
}

#[test]
fn transient_reload_faults_retry_with_backoff_and_recover_without_drift() {
    let _g = lock();
    let (cfg, mem, failing, session) = fault_rig();
    let baseline = coordinator::run(&cfg).unwrap();
    let req = session.request_from_config(&cfg).unwrap();
    let cold = session.run_collect(&req).unwrap();
    assert!(cold.stats.spills >= 1);
    assert!(!mem.keys().is_empty(), "spills must land in the inner store");
    // One fewer transient than the retry budget: however the faults
    // split across reload calls, every reload recovers on a retry.
    let gets_before = failing.get_attempts();
    failing.fail_next_gets(RETRY_ATTEMPTS as usize - 1, StoreError::transient("cable wiggle"));
    let warm = session.run_collect(&req).unwrap();
    assert!(warm.stats.reloads >= 1, "warm run must reload through the faults");
    assert!(
        failing.get_attempts() >= gets_before + RETRY_ATTEMPTS as u64,
        "faulted attempts plus the recovering reads must all be observed"
    );
    assert_same_values("transient recovery", &cfg, &baseline, &warm);
}

#[test]
fn permanent_store_faults_surface_typed_and_clear_on_repair() {
    let _g = lock();
    let (cfg, _mem, failing, session) = fault_rig();
    let baseline = coordinator::run(&cfg).unwrap();
    let req = session.request_from_config(&cfg).unwrap();
    session.run_collect(&req).unwrap();
    // Every read fails permanently: the run must fail with the typed
    // StoreError in its anyhow chain — downcastable, never a panic,
    // never a silently wrong block.
    failing.fail_next_gets(1000, StoreError::permanent("array offline"));
    let err = session.run_collect(&req).unwrap_err();
    let store_err = err
        .chain()
        .find_map(|c| c.downcast_ref::<StoreError>())
        .unwrap_or_else(|| panic!("no typed StoreError in chain: {err:#}"));
    assert_eq!(store_err.kind, StoreErrorKind::Permanent);
    // Repair the store: the same session recovers, bit-identically.
    failing.clear_faults();
    let recovered = session.run_collect(&req).unwrap();
    assert!(recovered.stats.reloads >= 1);
    assert_same_values("post-repair", &cfg, &baseline, &recovered);
}

#[test]
fn poisoned_spill_files_are_detected_by_the_block_checksum() {
    let _g = lock();
    let (cfg, mem, failing, session) = fault_rig();
    let req = session.request_from_config(&cfg).unwrap();
    session.run_collect(&req).unwrap();
    let keys = mem.keys();
    assert!(!keys.is_empty());
    for key in &keys {
        assert!(failing.poison(key), "poisoning {key}");
        assert!(failing.contains_inner(key));
    }
    let err = session.run_collect(&req).unwrap_err();
    let store_err = err
        .chain()
        .find_map(|c| c.downcast_ref::<StoreError>())
        .unwrap_or_else(|| panic!("no typed StoreError in chain: {err:#}"));
    assert_eq!(store_err.kind, StoreErrorKind::Corrupt);
    assert!(store_err.message.contains("checksum"), "{store_err}");
}

#[test]
fn serve_surfaces_store_faults_as_error_frames_and_recovers() {
    let _g = lock();
    let line = "metric=czekanowski nv=16 nf=40 npv=4 seed=7";
    let baseline_cfg = RunConfig::from_kv_line(line).unwrap();
    let baseline = coordinator::run(&baseline_cfg).unwrap();
    let budget = block_bytes(&baseline_cfg) * 3 / 2;
    let mem = Arc::new(MemStore::new());
    let failing = Arc::new(FailingStore::new(mem.clone()));
    let session = Arc::new(Session::with_spill_store(
        "artifacts",
        SessionLimits { block_cache_bytes: Some(budget), ..Default::default() },
        failing.clone(),
    ));
    let server = Server::start(Arc::clone(&session), ServeConfig::default()).unwrap();

    let (mut client, server_end) = std::os::unix::net::UnixStream::pair().unwrap();
    std::thread::scope(|s| {
        let server = &server;
        let conn = s.spawn(move || {
            let reader = server_end.try_clone().unwrap();
            serve::serve_connection(server, reader, server_end)
        });

        // Request 1 fills and spills; the reply matches the one-shot.
        let r1 = serve::request_over_stream(&mut client, line).unwrap();
        assert_eq!(r1.checksum, baseline.checksum.digest());

        // Request 2 needs reloads and every read fails permanently: the
        // client sees a typed Error frame naming the store fault — and
        // the connection survives it.
        failing.fail_next_gets(1000, StoreError::permanent("array offline"));
        let err = serve::request_over_stream(&mut client, line).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("server error"), "{msg}");
        assert!(msg.contains("permanent"), "{msg}");

        // Request 3 after the repair: same connection, same bits.
        failing.clear_faults();
        let r3 = serve::request_over_stream(&mut client, line).unwrap();
        assert_eq!(r3.checksum, baseline.checksum.digest());

        drop(client); // EOF ends the connection loop cleanly
        conn.join().unwrap().unwrap();
    });

    let cache = session.cache_stats();
    assert!(cache.spills >= 1, "serve runs must have spilled: {cache:?}");
    assert!(cache.reloads >= 1, "serve runs must have reloaded: {cache:?}");
}

#[test]
fn prefetch_fetches_in_step_schedule_order_and_rehints_are_idempotent() {
    let _g = lock();
    // npf=2 × npv=3: six ranks over six distinct (pv, pf) keys — the
    // schedule order is the rank order's dedup, which is what the
    // fetch log must reproduce exactly when the budget never binds.
    let cfg = sweep_cfg(
        MetricId::Czekanowski,
        2,
        BackendKind::CpuOptimized,
        1,
        Grid::new(2, 3, 1),
        Precision::F64,
    );
    let session = Session::new();
    let req = session.request_from_config(&cfg).unwrap();
    let inner = Arc::new(req.dataset().clone()) as Arc<dyn BlockProvider>;
    let order = prefetch_order(&cfg);
    assert_eq!(order.len(), 6, "every (pv, pf) slice appears once");
    let ra = ReadAhead::with_budget(inner, order.len());
    ra.prefetch(&cfg, &order);
    ra.drain();
    assert_eq!(ra.fetch_log(), order, "fetch order must match the step schedule");
    assert_eq!(ra.prefetched(), order.len() as u64);
    assert!(ra.max_ahead() <= order.len() as u64);
    // Re-hinting the same schedule (what node programs do per-slice) is
    // idempotent: no new fetches.
    ra.prefetch(&cfg, &order);
    ra.drain();
    assert_eq!(ra.fetch_log().len(), order.len());
    ra.finish();
}

#[test]
fn in_flight_budget_is_never_exceeded_and_budget_one_makes_progress() {
    let _g = lock();
    let cfg = sweep_cfg(
        MetricId::Czekanowski,
        2,
        BackendKind::CpuOptimized,
        1,
        Grid::new(1, 4, 1),
        Precision::F64,
    );
    let session = Session::new();
    let req = session.request_from_config(&cfg).unwrap();
    let inner = Arc::new(req.dataset().clone()) as Arc<dyn BlockProvider>;
    let metric = make_metric::<f64>(cfg.metric, &cfg);
    let order = prefetch_order(&cfg);
    // Budget 1: single buffering. The task parks after each fetch until
    // the consumer drains it — consuming in schedule order must always
    // unblock it (progress), and the high-water mark stays at 1.
    let ra = ReadAhead::with_budget(Arc::clone(&inner), 1);
    ra.prefetch(&cfg, &order);
    for &(pv, pf) in &order {
        let block = ra.block_f64(&cfg, metric.as_ref(), pv, pf).unwrap();
        assert_eq!(block.nv(), cfg.nv / cfg.grid.npv);
    }
    ra.drain();
    assert!(ra.max_ahead() <= 1, "budget 1 exceeded: max_ahead {}", ra.max_ahead());
    // Consumers race the task, so the log is a prefix-free subsequence
    // of the schedule — but never out of schedule order.
    let log = ra.fetch_log();
    assert!(log.len() <= order.len());
    let mut tail = order.iter();
    for k in &log {
        assert!(
            tail.any(|o| o == k),
            "fetch log {log:?} is not a schedule-order subsequence of {order:?}"
        );
    }
    ra.finish();
    // An unhinted provider (no prefetch call) still serves fetches —
    // and counts no stalls, because nothing was promised.
    let ra2 = ReadAhead::with_budget(inner, 1);
    let block = ra2.block_f64(&cfg, metric.as_ref(), 0, 0).unwrap();
    assert_eq!(block.nv(), cfg.nv / cfg.grid.npv);
    assert_eq!(ra2.stalls(), 0);
    assert_eq!(ra2.stall_secs(), 0.0);
    ra2.finish();
}
