//! The symmetry-halving + thread-parallelism contracts:
//!
//! 1. Triangular kernels reproduce the full kernels' strict upper
//!    triangle **bit-for-bit** across shapes straddling the JT/BI
//!    blocking boundaries (property test).
//! 2. Grid-valued checksums are **bit-identical** across
//!    `--threads {1, 2, 4}`, across backends, and between the serial
//!    driver and the coordinated node programs — the §5 invariance
//!    property PR 1/3 established must survive the kernel rework.
//! 3. The elementwise-op counter proves diagonal blocks cost ≤ ~55% of
//!    the full-square kernel (the ISSUE 4 acceptance bound).
//!
//! Op-counter assertions read a process-global total, so every test in
//! this binary serializes on [`lock`] — cargo's in-process test threads
//! would otherwise pollute the deltas.

use std::sync::{Arc, Mutex, MutexGuard, OnceLock};

use comet::config::{BackendKind, InputSource, Precision, RunConfig};
use comet::coordinator::backend::{Backend, CpuOptimized, CpuReference};
use comet::coordinator::{run, serial};
use comet::decomp::Grid;
use comet::linalg::{opcount, optimized, reference, sorenson};
use comet::metrics::{self, MetricId};
use comet::testkit::forall;
use comet::vecdata::bits::BitVectorSet;
use comet::vecdata::{SyntheticKind, VectorSet};

fn lock() -> MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(|p| p.into_inner())
}

fn cfg_for(metric: MetricId, nf: usize, nv: usize, seed: u64) -> RunConfig {
    let kind = match metric {
        MetricId::Ccc => SyntheticKind::Alleles,
        _ => SyntheticKind::RandomGrid,
    };
    RunConfig {
        metric,
        num_way: 2,
        nv,
        nf,
        precision: Precision::F64,
        backend: BackendKind::CpuOptimized,
        grid: Grid::new(1, 1, 1),
        input: InputSource::Synthetic { kind, seed },
        store_metrics: true,
        ..Default::default()
    }
}

#[test]
fn prop_triangular_kernels_match_full_upper_triangle_bitwise() {
    let _g = lock();
    // Shapes deliberately straddle JT = 8 (register tile) and BI = 32
    // (cache block): nv in 1..=70 crosses both boundaries, nf crosses
    // word widths for the packed kernel.
    forall(
        "tri-vs-full-upper-triangle",
        25,
        |g| {
            let nf = g.usize_in(1, 140);
            let nv = g.usize_in(1, 70);
            let threads = *g.pick(&[1usize, 2, 4]);
            let seed = g.stream.next_u64();
            (nf, nv, threads, seed)
        },
        |&(nf, nv, threads, seed)| {
            let v: VectorSet<f64> = VectorSet::generate(SyntheticKind::RandomGrid, seed, nf, nv, 0);
            let full = optimized::mgemm2_mt(&v, &v, threads);
            let tri = optimized::mgemm2_tri_mt(&v, threads);
            let gfull = optimized::gemm_mt(&v, &v, threads);
            let gtri = optimized::gemm_tri_mt(&v, threads);
            let rtri = reference::mgemm2_tri(&v);
            let bits = BitVectorSet::from_threshold(&v, 0.5);
            let bfull = sorenson::sorenson_mgemm_mt(&bits, &bits, threads);
            let btri = sorenson::sorenson_mgemm_tri_mt(&bits, threads);
            for i in 0..nv {
                for j in 0..nv {
                    if j > i {
                        for (what, a, b) in [
                            ("mgemm2", tri.at(i, j), full.at(i, j)),
                            ("gemm", gtri.at(i, j), gfull.at(i, j)),
                            ("mgemm2-ref", rtri.at(i, j), full.at(i, j)),
                            ("sorenson", btri.at(i, j), bfull.at(i, j)),
                        ] {
                            if a.to_bits() != b.to_bits() {
                                return Err(format!("{what} ({i},{j}): {a} != {b}"));
                            }
                        }
                    } else if tri.at(i, j) != 0.0 || gtri.at(i, j) != 0.0 || btri.at(i, j) != 0.0 {
                        return Err(format!("lower triangle written at ({i},{j})"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_checksums_invariant_across_threads_grids_and_backends() {
    let _g = lock();
    forall(
        "threads-decomp-backend-invariance",
        6,
        |g| {
            let nv = g.usize_in(8, 24);
            let nf = g.usize_in(4, 60);
            let npv = g.usize_in(1, 4.min(nv));
            let npr = g.usize_in(1, 2);
            let seed = g.stream.next_u64();
            (nv, nf, npv, npr, seed)
        },
        |&(nv, nf, npv, npr, seed)| {
            for metric in MetricId::ALL {
                let mut digests = Vec::new();
                for threads in [1usize, 2, 4] {
                    for grid in [Grid::new(1, 1, 1), Grid::new(1, npv, npr)] {
                        let mut cfg = cfg_for(metric, nf, nv, seed);
                        cfg.threads = threads;
                        cfg.grid = grid;
                        cfg.store_metrics = false;
                        let out = run(&cfg).map_err(|e| e.to_string())?;
                        digests.push(out.checksum.digest());
                    }
                }
                // The reference backend (single-core, triangular diag)
                // must land on the same digest.
                let mut cfg = cfg_for(metric, nf, nv, seed);
                cfg.backend = BackendKind::CpuReference;
                cfg.store_metrics = false;
                digests.push(run(&cfg).map_err(|e| e.to_string())?.checksum.digest());
                if digests.iter().any(|d| *d != digests[0]) {
                    return Err(format!("{}: digests diverge: {digests:?}", metric.name()));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn serial_driver_matches_coordinated_run_at_every_thread_count() {
    let _g = lock();
    let (nf, nv) = (52, 21);
    for metric in MetricId::ALL {
        let cfg = cfg_for(metric, nf, nv, 11);
        let v: VectorSet<f64> = match &cfg.input {
            InputSource::Synthetic { kind, seed } => {
                VectorSet::generate(*kind, *seed, nf, nv, 0)
            }
            _ => unreachable!(),
        };
        let coord = run(&cfg).unwrap();
        let dense_coord = coord.pairs.as_ref().unwrap().to_dense(nv);
        for threads in [1usize, 2, 4] {
            let backend: Arc<dyn Backend<f64>> = Arc::new(CpuOptimized::with_threads(threads));
            let m = metrics::make_metric::<f64>(metric, &cfg);
            let store = serial::all_pairs_with(&backend, m.as_ref(), &v).unwrap();
            let dense = store.to_dense(nv);
            assert_eq!(dense.len(), dense_coord.len());
            for (off, (a, b)) in dense.iter().zip(&dense_coord).enumerate() {
                let (a, b) = (a.expect("serial value"), b.expect("coordinated value"));
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "{} offset {off} threads {threads}",
                    metric.name()
                );
            }
        }
    }
}

#[test]
fn diag_blocks_cost_at_most_55_percent_of_full_square() {
    let _g = lock();
    let (nf, nv) = (44, 40);
    let v: VectorSet<f64> = VectorSet::generate(SyntheticKind::RandomGrid, 3, nf, nv, 0);
    let bits = BitVectorSet::from_threshold(&v, 0.5);

    // Kernel level, all three families, exact counts.
    for (what, full, tri) in [
        (
            "mgemm2",
            {
                let before = opcount::elem_ops();
                let _ = optimized::mgemm2(&v, &v);
                opcount::elem_ops() - before
            },
            {
                let before = opcount::elem_ops();
                let _ = optimized::mgemm2_tri(&v);
                opcount::elem_ops() - before
            },
        ),
        (
            "gemm",
            {
                let before = opcount::elem_ops();
                let _ = optimized::gemm(&v, &v);
                opcount::elem_ops() - before
            },
            {
                let before = opcount::elem_ops();
                let _ = optimized::gemm_tri(&v);
                opcount::elem_ops() - before
            },
        ),
        (
            "sorenson",
            {
                let before = opcount::elem_ops();
                let _ = sorenson::sorenson_mgemm(&bits, &bits);
                opcount::elem_ops() - before
            },
            {
                let before = opcount::elem_ops();
                let _ = sorenson::sorenson_mgemm_tri(&bits);
                opcount::elem_ops() - before
            },
        ),
    ] {
        assert_eq!(full, opcount::ops_full(nf, nv, nv), "{what} full count");
        assert_eq!(tri, opcount::ops_tri(nf, nv), "{what} tri count");
        assert!(
            (tri as f64) <= 0.55 * full as f64,
            "{what}: tri {tri} vs full {full}"
        );
    }

    // Multithreaded panels record the same total.
    let before = opcount::elem_ops();
    let _ = optimized::mgemm2_tri_mt(&v, 4);
    assert_eq!(opcount::elem_ops() - before, opcount::ops_tri(nf, nv));

    // Coordinator level: a single-node 2-way run has exactly one
    // (diagonal) block — the whole run's kernel ops are the triangular
    // count, ≤ 55% of the full-square block it used to compute.
    let cfg = cfg_for(MetricId::Czekanowski, nf, nv, 3);
    let before = opcount::elem_ops();
    let _ = run(&cfg).unwrap();
    let run_ops = opcount::elem_ops() - before;
    assert_eq!(run_ops, opcount::ops_tri(nf, nv));
    assert!((run_ops as f64) <= 0.55 * opcount::ops_full(nf, nv, nv) as f64);
}

#[test]
fn balanced_tri_partition_pins_per_thread_ops() {
    let _g = lock();
    let (nf, nv, threads) = (44usize, 64usize, 4usize);
    // Analytic per-worker deltas (opcount::ops_tri_rows) over the
    // partition the triangular kernels actually run
    // (linalg::tri_partition low+high band pairing).
    let parts = comet::linalg::tri_partition(nv, threads);
    assert_eq!(parts.len(), threads);
    let per_worker: Vec<u64> = parts
        .iter()
        .map(|ranges| ranges.iter().map(|r| opcount::ops_tri_rows(nf, r.clone(), nv)).sum())
        .collect();
    // The workers partition the triangle exactly …
    assert_eq!(per_worker.iter().sum::<u64>(), opcount::ops_tri(nf, nv));
    // … and each carries its fair share (the contiguous split's first
    // chunk would carry ~1.75× ideal at 4 threads).
    let ideal = opcount::ops_tri(nf, nv) as f64 / threads as f64;
    for (w, &ops) in per_worker.iter().enumerate() {
        assert!(
            (ops as f64) >= 0.85 * ideal && (ops as f64) <= 1.15 * ideal,
            "worker {w}: {ops} vs ideal {ideal}"
        );
    }
    // Empirical cross-check: the threaded kernel records exactly the
    // analytic triangle total (so the per-range deltas above are the
    // deltas its workers record), and values stay bit-identical.
    let v: VectorSet<f64> = VectorSet::generate(SyntheticKind::RandomGrid, 21, nf, nv, 0);
    let serial = optimized::mgemm2_tri(&v);
    let before = opcount::elem_ops();
    let mt = optimized::mgemm2_tri_mt(&v, threads);
    assert_eq!(opcount::elem_ops() - before, opcount::ops_tri(nf, nv));
    assert_eq!(serial, mt);
}

#[test]
fn three_way_checksums_invariant_across_threads() {
    let _g = lock();
    let mut digests = Vec::new();
    for threads in [1usize, 2, 4] {
        let cfg = RunConfig {
            num_way: 3,
            nv: 18,
            nf: 24,
            threads,
            grid: Grid::new(1, 3, 1),
            input: InputSource::Synthetic { kind: SyntheticKind::RandomGrid, seed: 8 },
            store_metrics: false,
            ..Default::default()
        };
        digests.push(run(&cfg).unwrap().checksum.digest());
    }
    assert!(digests.iter().all(|d| *d == digests[0]), "{digests:?}");

    // And the diag-aware slab path agrees with the reference backend.
    let mut cfg = RunConfig {
        num_way: 3,
        nv: 14,
        nf: 20,
        grid: Grid::new(1, 2, 1),
        input: InputSource::Synthetic { kind: SyntheticKind::RandomGrid, seed: 9 },
        store_metrics: false,
        ..Default::default()
    };
    let opt = run(&cfg).unwrap().checksum;
    cfg.backend = BackendKind::CpuReference;
    let refr = run(&cfg).unwrap().checksum;
    assert_eq!(opt, refr);
}

#[test]
fn reference_backend_diag_dispatch_matches_optimized() {
    let _g = lock();
    // Direct backend-level agreement on the diag kernels (the engine
    // dispatch path), all three families.
    let v: VectorSet<f64> = VectorSet::generate(SyntheticKind::RandomGrid, 12, 33, 17, 0);
    let a = Backend::<f64>::mgemm2_diag(&CpuReference, &v).unwrap();
    let b = Backend::<f64>::mgemm2_diag(&CpuOptimized::with_threads(2), &v).unwrap();
    assert_eq!(a.max_abs_diff(&b), 0.0);
    let ga = Backend::<f64>::gemm2_diag(&CpuReference, &v).unwrap();
    let gb = Backend::<f64>::gemm2_diag(&CpuOptimized::with_threads(3), &v).unwrap();
    assert_eq!(ga.max_abs_diff(&gb), 0.0);
}
