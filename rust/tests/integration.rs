//! Coordinator integration tests (native backends — fast): Algorithm 1
//! and Algorithms 2+3 against the scalar oracles (all three metric
//! families), decomposition invariance of the checksum, staging,
//! output files, file input, and the analytically-verifiable synthetic
//! problem (paper §5).

use comet::checksum::Checksum;
use comet::config::{BackendKind, InputSource, Precision, RunConfig};
use comet::coordinator::{self, run};
use comet::decomp::Grid;
use comet::metrics::{self, MetricId};
use comet::vecdata::bits::BitVectorSet;
use comet::vecdata::{io as vio, SyntheticKind, VectorSet};

fn base_cfg(num_way: usize, nv: usize, nf: usize) -> RunConfig {
    RunConfig {
        num_way,
        nv,
        nf,
        precision: Precision::F64,
        backend: BackendKind::CpuOptimized,
        grid: Grid::new(1, 1, 1),
        input: InputSource::Synthetic { kind: SyntheticKind::RandomGrid, seed: 7 },
        ..Default::default()
    }
}

/// Oracle checksum: direct scalar evaluation of every unique pair.
fn oracle_checksum_2way(cfg: &RunConfig) -> (Checksum, usize) {
    let (kind, seed) = match cfg.input {
        InputSource::Synthetic { kind, seed } => (kind, seed),
        _ => unreachable!(),
    };
    let v: VectorSet<f64> = VectorSet::generate(kind, seed, cfg.nf, cfg.nv, 0);
    let mut cs = Checksum::new();
    let mut n = 0;
    for (i, j) in metrics::indexing::pairs(cfg.nv) {
        cs.add_pair(i, j, metrics::czekanowski2(v.col(i), v.col(j)));
        n += 1;
    }
    (cs, n)
}

fn oracle_checksum_3way(cfg: &RunConfig) -> (Checksum, usize) {
    let (kind, seed) = match cfg.input {
        InputSource::Synthetic { kind, seed } => (kind, seed),
        _ => unreachable!(),
    };
    let v: VectorSet<f64> = VectorSet::generate(kind, seed, cfg.nf, cfg.nv, 0);
    let mut cs = Checksum::new();
    let mut n = 0;
    for (i, j, k) in metrics::indexing::triples(cfg.nv) {
        cs.add_triple(i, j, k, metrics::czekanowski3(v.col(i), v.col(j), v.col(k)));
        n += 1;
    }
    (cs, n)
}

#[test]
fn two_way_single_node_matches_oracle() {
    let cfg = base_cfg(2, 40, 32);
    let out = run(&cfg).unwrap();
    let (want, n) = oracle_checksum_2way(&cfg);
    assert_eq!(out.checksum, want);
    assert_eq!(out.stats.metrics as usize, n);
    let pairs = out.pairs.unwrap();
    assert_eq!(pairs.len(), n);
}

#[test]
fn two_way_checksum_invariant_across_decompositions() {
    // The paper's §5 bit-for-bit claim: same results for every parallel
    // decomposition. Grid-valued inputs make f64 sums exact, so the
    // checksums must be *identical*.
    let mut cfg = base_cfg(2, 48, 40);
    let reference = run(&cfg).unwrap().checksum;
    for (npf, npv, npr) in [(1, 2, 1), (1, 3, 2), (1, 4, 3), (2, 2, 1), (2, 3, 2), (1, 6, 4)] {
        cfg.grid = Grid::new(npf, npv, npr);
        let got = run(&cfg).unwrap();
        assert_eq!(
            got.checksum, reference,
            "checksum mismatch at grid ({npf},{npv},{npr})"
        );
    }
}

#[test]
fn two_way_all_backends_agree() {
    let mut cfg = base_cfg(2, 36, 24);
    cfg.grid = Grid::new(1, 3, 1);
    cfg.backend = BackendKind::CpuReference;
    let a = run(&cfg).unwrap().checksum;
    cfg.backend = BackendKind::CpuOptimized;
    let b = run(&cfg).unwrap().checksum;
    assert_eq!(a, b);
}

#[test]
fn two_way_f32_grid_inputs_still_decomposition_invariant() {
    let mut cfg = base_cfg(2, 32, 64);
    cfg.precision = Precision::F32;
    let a = run(&cfg).unwrap().checksum;
    cfg.grid = Grid::new(1, 4, 2);
    let b = run(&cfg).unwrap().checksum;
    assert_eq!(a, b);
}

#[test]
fn three_way_single_node_matches_oracle() {
    let cfg = base_cfg(3, 18, 24);
    let out = run(&cfg).unwrap();
    let (want, n) = oracle_checksum_3way(&cfg);
    assert_eq!(out.checksum, want);
    assert_eq!(out.stats.metrics as usize, n);
}

#[test]
fn three_way_checksum_invariant_across_decompositions() {
    let mut cfg = base_cfg(3, 24, 20);
    let reference = run(&cfg).unwrap().checksum;
    for (npv, npr) in [(2, 1), (3, 2), (4, 3), (4, 6)] {
        cfg.grid = Grid::new(1, npv, npr);
        let got = run(&cfg).unwrap();
        assert_eq!(got.checksum, reference, "grid npv={npv} npr={npr}");
    }
}

#[test]
fn three_way_staging_partitions_the_campaign() {
    // Union of all stages == unstaged run; stages are disjoint.
    let mut cfg = base_cfg(3, 18, 16);
    cfg.grid = Grid::new(1, 3, 1);
    let whole = run(&cfg).unwrap();
    cfg.num_stage = 4;
    let mut merged = Checksum::new();
    let mut total = 0u64;
    for s in 0..4 {
        cfg.stage = Some(s);
        let part = run(&cfg).unwrap();
        merged.merge(part.checksum);
        total += part.stats.metrics;
    }
    assert_eq!(merged, whole.checksum);
    assert_eq!(total, whole.stats.metrics);
}

#[test]
fn three_way_all_stages_at_once_equals_unstaged() {
    let mut cfg = base_cfg(3, 15, 16);
    cfg.grid = Grid::new(1, 3, 2);
    let whole = run(&cfg).unwrap();
    cfg.num_stage = 5;
    cfg.stage = None; // run all stages in one go
    let staged = run(&cfg).unwrap();
    assert_eq!(staged.checksum, whole.checksum);
}

#[test]
fn verifiable_synthetic_analytic_2way() {
    // Paper §5's second synthetic type: every value checkable exactly.
    let mut cfg = base_cfg(2, 30, 10);
    cfg.input = InputSource::Synthetic { kind: SyntheticKind::Verifiable, seed: 3 };
    cfg.grid = Grid::new(1, 3, 2);
    let out = run(&cfg).unwrap();
    let pairs = out.pairs.unwrap();
    for e in pairs.iter() {
        let bi = VectorSet::<f64>::verifiable_bucket(3, 10, e.i as usize);
        let bj = VectorSet::<f64>::verifiable_bucket(3, 10, e.j as usize);
        let expect = if bi == bj { 1.0 } else { 0.0 };
        assert_eq!(e.value, expect, "pair ({}, {})", e.i, e.j);
    }
}

#[test]
fn verifiable_synthetic_analytic_3way() {
    let mut cfg = base_cfg(3, 20, 6);
    cfg.input = InputSource::Synthetic { kind: SyntheticKind::Verifiable, seed: 5 };
    cfg.grid = Grid::new(1, 4, 1);
    let out = run(&cfg).unwrap();
    let triples = out.triples.unwrap();
    let b: Vec<usize> = (0..20)
        .map(|g| VectorSet::<f64>::verifiable_bucket(5, 6, g))
        .collect();
    for e in triples.iter() {
        let (i, j, k) = (e.i as usize, e.j as usize, e.k as usize);
        let m = (b[i] == b[j]) as usize + (b[i] == b[k]) as usize + (b[j] == b[k]) as usize;
        let expect = match m {
            3 => 1.0,
            1 => 0.5,
            _ => 0.0,
        };
        assert_eq!(e.value, expect, "triple ({i},{j},{k})");
    }
}

#[test]
fn file_input_equals_synthetic_run() {
    // gen-data → file-driven run must equal the synthetic-driven run.
    let dir = std::env::temp_dir().join(format!("comet-it-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("v.bin");
    let set: VectorSet<f64> = VectorSet::generate(SyntheticKind::RandomGrid, 7, 32, 40, 0);
    vio::write_raw(&path, &set).unwrap();

    let mut cfg = base_cfg(2, 40, 32);
    cfg.grid = Grid::new(1, 4, 1);
    let synth = run(&cfg).unwrap();
    cfg.input = InputSource::File { path: path.to_string_lossy().into_owned() };
    let filed = run(&cfg).unwrap();
    assert_eq!(synth.checksum, filed.checksum);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn output_files_roundtrip_quantized() {
    let dir = std::env::temp_dir().join(format!("comet-out-it-{}", std::process::id()));
    let mut cfg = base_cfg(2, 24, 16);
    cfg.grid = Grid::new(1, 2, 1);
    cfg.output_dir = Some(dir.to_string_lossy().into_owned());
    let out = run(&cfg).unwrap();
    // Every node wrote a file; total bytes == total metrics (1B each).
    let mut total = 0usize;
    for rank in 0..cfg.grid.np() {
        let p = dir.join(format!("metrics_{rank}.bin"));
        total += comet::output::read_dense(&p).unwrap().len();
    }
    assert_eq!(total as u64, out.stats.metrics);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn thresholded_output_keeps_only_strong_metrics() {
    // §6.8 discussion: thresholding cuts the output-data burden; the
    // file format switches to (offset, byte) records.
    let dir = std::env::temp_dir().join(format!("comet-thresh-{}", std::process::id()));
    let mut cfg = base_cfg(2, 24, 16);
    cfg.grid = Grid::new(1, 2, 1);
    cfg.output_dir = Some(dir.to_string_lossy().into_owned());
    cfg.output_threshold = Some(0.8);
    let out = run(&cfg).unwrap();
    let pairs = out.pairs.unwrap();
    let strong: Vec<_> = pairs.iter().filter(|e| e.value >= 0.8).collect();
    let mut records = Vec::new();
    for rank in 0..cfg.grid.np() {
        records.extend(
            comet::output::read_thresholded(&dir.join(format!("metrics_{rank}.bin"))).unwrap(),
        );
    }
    assert_eq!(records.len(), strong.len());
    for (off, qb) in records {
        let (i, j) = comet::metrics::indexing::pair_from_offset(off as usize);
        let e = strong
            .iter()
            .find(|e| (e.i as usize, e.j as usize) == (i, j))
            .unwrap_or_else(|| panic!("unexpected record for pair ({i},{j})"));
        assert!((comet::output::dequantize(qb) - e.value).abs() <= 0.5 / 255.0 + 1e-12);
    }
    std::fs::remove_dir_all(&dir).ok();
}

// --- Metric engine: CCC and bit-packed Sorensen through the SAME
// two-way coordinator (no metric-specific branches in the node
// program — only the Metric implementation differs) -------------------

fn ccc_cfg(nv: usize, nf: usize) -> RunConfig {
    RunConfig {
        metric: MetricId::Ccc,
        nv,
        nf,
        backend: BackendKind::CpuOptimized,
        input: InputSource::Synthetic { kind: SyntheticKind::Alleles, seed: 17 },
        ..Default::default()
    }
}

fn sorenson_cfg(nv: usize, nf: usize) -> RunConfig {
    RunConfig {
        metric: MetricId::Sorenson,
        nv,
        nf,
        backend: BackendKind::CpuOptimized,
        // RandomGrid values are in (0, 1]; the metric binarizes at 0.5.
        input: InputSource::Synthetic { kind: SyntheticKind::RandomGrid, seed: 23 },
        ..Default::default()
    }
}

#[test]
fn ccc_two_way_matches_scalar_oracle() {
    let mut cfg = ccc_cfg(30, 24);
    cfg.grid = Grid::new(1, 3, 2);
    let out = run(&cfg).unwrap();
    let pairs = out.pairs.unwrap();
    assert_eq!(pairs.metric, MetricId::Ccc);
    assert_eq!(pairs.len(), 30 * 29 / 2);
    let v: VectorSet<f64> = VectorSet::generate(SyntheticKind::Alleles, 17, 24, 30, 0);
    for e in pairs.iter() {
        let want = metrics::ccc2(v.col(e.i as usize), v.col(e.j as usize));
        // Integer-valued numerators/sums: exact in f64 on every path.
        assert_eq!(e.value, want, "pair ({}, {})", e.i, e.j);
    }
}

#[test]
fn ccc_checksum_invariant_across_decompositions() {
    let mut cfg = ccc_cfg(36, 32);
    let reference = run(&cfg).unwrap().checksum;
    for (npf, npv, npr) in [(1, 2, 1), (1, 4, 3), (2, 3, 2)] {
        cfg.grid = Grid::new(npf, npv, npr);
        let got = run(&cfg).unwrap();
        assert_eq!(got.checksum, reference, "grid ({npf},{npv},{npr})");
    }
}

#[test]
fn ccc_backends_agree() {
    let mut cfg = ccc_cfg(24, 40);
    cfg.grid = Grid::new(1, 2, 1);
    cfg.backend = BackendKind::CpuReference;
    let a = run(&cfg).unwrap().checksum;
    cfg.backend = BackendKind::CpuOptimized;
    let b = run(&cfg).unwrap().checksum;
    assert_eq!(a, b);
}

#[test]
fn sorenson_two_way_matches_bit_oracle() {
    let mut cfg = sorenson_cfg(28, 70); // 70 features: partial packed word
    cfg.grid = Grid::new(1, 4, 1);
    let out = run(&cfg).unwrap();
    let pairs = out.pairs.unwrap();
    assert_eq!(pairs.metric, MetricId::Sorenson);
    assert_eq!(pairs.len(), 28 * 27 / 2);
    let v: VectorSet<f64> = VectorSet::generate(SyntheticKind::RandomGrid, 23, 70, 28, 0);
    let bits = BitVectorSet::from_threshold(&v, 0.5);
    for e in pairs.iter() {
        let want = bits.sorenson2(e.i as usize, e.j as usize);
        assert_eq!(e.value, want, "pair ({}, {})", e.i, e.j);
    }
}

#[test]
fn sorenson_checksum_invariant_across_decompositions() {
    let mut cfg = sorenson_cfg(32, 96);
    let reference = run(&cfg).unwrap().checksum;
    for (npf, npv, npr) in [(1, 3, 1), (1, 4, 2), (2, 2, 1)] {
        cfg.grid = Grid::new(npf, npv, npr);
        let got = run(&cfg).unwrap();
        assert_eq!(got.checksum, reference, "grid ({npf},{npv},{npr})");
    }
}

#[test]
fn sorenson_backends_agree() {
    let mut cfg = sorenson_cfg(20, 130);
    cfg.grid = Grid::new(1, 2, 1);
    cfg.backend = BackendKind::CpuReference;
    let a = run(&cfg).unwrap().checksum;
    cfg.backend = BackendKind::CpuOptimized;
    let b = run(&cfg).unwrap().checksum;
    assert_eq!(a, b);
}

#[test]
fn different_metrics_never_collide_in_checksum() {
    // Same problem, three metrics: the per-metric checksum salt keeps
    // even identical value multisets apart, and the value streams
    // differ anyway.
    let mut cfg = sorenson_cfg(20, 48);
    let sor = run(&cfg).unwrap().checksum;
    cfg.metric = MetricId::Czekanowski;
    let cz = run(&cfg).unwrap().checksum;
    assert_ne!(sor, cz);
    assert_eq!(sor.count, cz.count);
}

#[test]
fn output_dir_gets_metric_tagged_run_meta() {
    let dir = std::env::temp_dir().join(format!("comet-meta-{}", std::process::id()));
    let mut cfg = ccc_cfg(16, 20);
    cfg.grid = Grid::new(1, 2, 1);
    cfg.output_dir = Some(dir.to_string_lossy().into_owned());
    let out = run(&cfg).unwrap();
    let doc = comet::output::read_run_meta(&dir).unwrap();
    assert_eq!(doc.get("run", "metric").unwrap().as_str().unwrap(), "ccc");
    assert_eq!(doc.get("run", "num_way").unwrap().as_int().unwrap(), 2);
    // The sidecar reports the compute-thread count and which kernel
    // served diagonal blocks (cpu-optimized → triangular).
    assert_eq!(
        doc.get("run", "threads").unwrap().as_int().unwrap() as usize,
        cfg.threads
    );
    assert_eq!(doc.get("run", "kernel").unwrap().as_str().unwrap(), "triangular");
    assert_eq!(
        doc.get("run", "metrics").unwrap().as_int().unwrap() as u64,
        out.stats.metrics
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn comm_accounting_scales_with_grid() {
    let mut cfg = base_cfg(2, 48, 32);
    cfg.grid = Grid::new(1, 1, 1);
    let single = run(&cfg).unwrap();
    assert_eq!(single.stats.comm_bytes, 0, "single node sends nothing");
    cfg.grid = Grid::new(1, 4, 1);
    let multi = run(&cfg).unwrap();
    assert!(multi.stats.comm_bytes > 0);
    assert!(multi.stats.comm_messages > 0);
}

#[test]
fn no_store_suppresses_memory_results() {
    let mut cfg = base_cfg(2, 30, 16);
    cfg.store_metrics = false;
    let out = run(&cfg).unwrap();
    assert!(out.pairs.is_none());
    assert!(out.stats.metrics > 0);
}

#[test]
fn run_stats_load_matches_decomp() {
    let cfg = {
        let mut c = base_cfg(2, 64, 16);
        c.grid = Grid::new(1, 4, 1);
        c
    };
    let out = run(&cfg).unwrap();
    // Total mGEMM block calls == unique block count of the circulant plan.
    let expected: usize = (0..4)
        .map(|pv| coordinator::two_way::load_for(&cfg, pv, 0))
        .sum();
    assert_eq!(out.stats.mgemm2_calls as usize, expected);
}

#[test]
fn rejects_3way_with_npf() {
    let mut cfg = base_cfg(3, 12, 16);
    cfg.grid = Grid::new(2, 2, 1);
    let err = run(&cfg).unwrap_err();
    assert!(err.to_string().contains("npf"));
}
