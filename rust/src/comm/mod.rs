//! The simulated interconnect: an in-process message-passing fabric
//! with MPI-like semantics, plus the analytic latency/bandwidth cost
//! model used for scaling projections.
//!
//! The paper ran MPI over Titan's Gemini torus; no network exists here
//! (DESIGN.md §1), so [`VirtualCluster`] gives each virtual node a
//! mailbox and tagged point-to-point send/recv over channels, with the
//! same pairing discipline as Algorithm 1/2's ring exchanges. Message
//! and byte counts are accounted per node so benches can report the
//! communication volumes the paper's model (§6.3) prices.
//!
//! ## Fault model
//!
//! Leadership-scale fabrics drop, delay, corrupt, and lose nodes; the
//! simulated fabric mirrors that failure surface so the layers above
//! can be exercised against it:
//!
//! * every comm operation returns a typed [`CommError`] instead of
//!   blocking forever or panicking — [`Endpoint::recv`] bounds its wait
//!   with a deadline ([`DEFAULT_RECV_DEADLINE`], shrinkable per plan),
//!   and a send to a torn-down peer surfaces as
//!   [`CommErrorKind::PeerDead`];
//! * every envelope carries an FNV-64 checksum over its canonical
//!   payload bytes, validated on receive — a bit-flip on the simulated
//!   wire is **detected** ([`CommErrorKind::Corrupt`]), never decoded
//!   into wrong results;
//! * the link layer retransmits dropped/corrupted envelopes under the
//!   shared [`crate::util::retry::Policy`] backoff (the same policy as
//!   `oocstore::with_retry`), so transient faults recover bit-identically
//!   while permanent ones (a killed node, an exhausted retry budget)
//!   surface as typed errors within a bounded deadline;
//! * [`faults::FaultPlan`] is the injection seam: scripted drop / delay /
//!   corrupt / kill faults at the *k*-th send of a rank (in the spirit of
//!   `testkit::faults::FailingStore`), installed via
//!   [`VirtualCluster::with_faults`]. Fault-free clusters pay zero extra
//!   messages or bytes — counters tick only on successful delivery.

pub mod cost;
pub mod faults;

use std::collections::HashMap;
use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::util::retry::Policy;
use crate::vecdata::block::BlockData;
use faults::{FaultKind, FaultPlan};

/// How long a blocking [`Endpoint::recv`] waits before surfacing
/// [`CommErrorKind::Timeout`]. Generous — healthy runs never come close;
/// fault rigs shorten it via [`faults::FaultPlan::set_recv_deadline`].
pub const DEFAULT_RECV_DEADLINE: Duration = Duration::from_secs(30);

/// How a comm operation failed — the axis the retry layer and the node
/// supervisor key on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CommErrorKind {
    /// No matching envelope arrived within the recv deadline.
    Timeout,
    /// The peer's endpoint is gone (its mailbox was torn down).
    PeerDead,
    /// An envelope failed its payload checksum (or the protocol saw an
    /// unexpected payload variant) and no clean copy arrived in budget.
    Corrupt,
    /// This rank was killed by the fault plan; every subsequent comm
    /// operation on it fails permanently.
    Killed,
}

impl CommErrorKind {
    pub fn name(self) -> &'static str {
        match self {
            CommErrorKind::Timeout => "timeout",
            CommErrorKind::PeerDead => "peer-dead",
            CommErrorKind::Corrupt => "corrupt",
            CommErrorKind::Killed => "killed",
        }
    }
}

/// Typed comm-fabric error. Travels through `anyhow` chains without
/// losing its type — supervisors `downcast_ref::<CommError>()` to tell
/// a timeout from a kill.
#[derive(Debug, Clone)]
pub struct CommError {
    pub kind: CommErrorKind,
    /// Rank that observed the failure.
    pub rank: usize,
    pub message: String,
}

impl CommError {
    pub fn new(kind: CommErrorKind, rank: usize, message: impl Into<String>) -> Self {
        CommError { kind, rank, message: message.into() }
    }
}

impl fmt::Display for CommError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "comm {} error at rank {}: {}", self.kind.name(), self.rank, self.message)
    }
}

impl std::error::Error for CommError {}

/// Message payload: a block of vector data or a small control value.
/// Blocks travel in their metric-preferred representation
/// ([`BlockData`]): f64 elements for float metrics (charged at the run
/// precision's width) or packed u64 words for bit-domain metrics
/// (charged at 8 B/word — the ~64× wire saving of pack-once Sorensen).
#[derive(Debug, Clone)]
pub enum Payload {
    /// Vector block: (nf, nv, first_id, representation-tagged data).
    Block {
        nf: usize,
        nv: usize,
        first_id: usize,
        data: BlockData,
    },
    /// Partial result row for reductions (npf axis).
    Partial(Arc<Vec<f64>>),
    /// Small scalar vector (denominators etc.).
    Sums(Arc<Vec<f64>>),
    /// Bare control/ack.
    Token(u64),
}

impl Payload {
    /// Simulated wire size in bytes. `elem_bytes` is the run
    /// precision's element width; it applies to float payloads
    /// (blocks, partials, sums), while packed block words are always
    /// 8 B/word and tokens 8 B flat.
    pub fn bytes(&self, elem_bytes: usize) -> u64 {
        match self {
            Payload::Block { data, .. } => data.wire_bytes(elem_bytes),
            Payload::Partial(d) | Payload::Sums(d) => (d.len() * elem_bytes) as u64,
            Payload::Token(_) => 8,
        }
    }
}

/// Streaming FNV-1a 64 over an envelope's canonical payload bytes
/// (variant tag, shape, then data at its bit-exact LE encoding) —
/// computed at send, validated at receive, so a wire bit-flip is
/// caught before the payload reaches a node program.
struct Fnv(u64);

impl Fnv {
    fn new() -> Self {
        Fnv(0xcbf2_9ce4_8422_2325)
    }
    fn update(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    fn u64(&mut self, x: u64) {
        self.update(&x.to_le_bytes());
    }
}

/// Canonical checksum of a payload (pure — same payload, same value).
pub fn payload_checksum(p: &Payload) -> u64 {
    let mut h = Fnv::new();
    match p {
        Payload::Block { nf, nv, first_id, data } => {
            h.u64(1);
            h.u64(*nf as u64);
            h.u64(*nv as u64);
            h.u64(*first_id as u64);
            match data {
                BlockData::F64(d) => {
                    h.u64(0);
                    for x in d.iter() {
                        h.u64(x.to_bits());
                    }
                }
                BlockData::Packed(pb) => {
                    h.u64(1);
                    h.u64(pb.words_per_vec as u64);
                    for w in pb.words.iter() {
                        h.u64(*w);
                    }
                }
                BlockData::Packed2(pb) => {
                    h.u64(2);
                    h.u64(pb.words_per_vec as u64);
                    h.u64(pb.missing.is_some() as u64);
                    for w in pb.lo.iter().chain(pb.hi.iter()) {
                        h.u64(*w);
                    }
                    if let Some(m) = &pb.missing {
                        for w in m.iter() {
                            h.u64(*w);
                        }
                    }
                }
            }
        }
        Payload::Partial(d) => {
            h.u64(2);
            for x in d.iter() {
                h.u64(x.to_bits());
            }
        }
        Payload::Sums(d) => {
            h.u64(3);
            for x in d.iter() {
                h.u64(x.to_bits());
            }
        }
        Payload::Token(t) => {
            h.u64(4);
            h.u64(*t);
        }
    }
    h.0
}

/// A wire bit-flip: the payload with one data bit inverted (the
/// checksum in the envelope still describes the clean payload, so the
/// receiver's validation fires). Used only by the fault injector.
fn bitflip(p: &Payload) -> Payload {
    match p {
        Payload::Block { nf, nv, first_id, data } => {
            let data = match data {
                BlockData::F64(d) => {
                    let mut v = (**d).clone();
                    if let Some(x) = v.first_mut() {
                        *x = f64::from_bits(x.to_bits() ^ 1);
                    }
                    BlockData::F64(Arc::new(v))
                }
                BlockData::Packed(pb) => {
                    let mut words = (*pb.words).clone();
                    if let Some(w) = words.first_mut() {
                        *w ^= 1;
                    }
                    BlockData::Packed(crate::vecdata::block::PackedBlock {
                        words_per_vec: pb.words_per_vec,
                        words: Arc::new(words),
                    })
                }
                BlockData::Packed2(pb) => {
                    let mut lo = (*pb.lo).clone();
                    if let Some(w) = lo.first_mut() {
                        *w ^= 1;
                    }
                    BlockData::Packed2(crate::vecdata::block::Packed2Block {
                        words_per_vec: pb.words_per_vec,
                        lo: Arc::new(lo),
                        hi: Arc::clone(&pb.hi),
                        missing: pb.missing.clone(),
                    })
                }
            };
            Payload::Block { nf: *nf, nv: *nv, first_id: *first_id, data }
        }
        Payload::Partial(d) => {
            let mut v = (**d).clone();
            if let Some(x) = v.first_mut() {
                *x = f64::from_bits(x.to_bits() ^ 1);
            }
            Payload::Partial(Arc::new(v))
        }
        Payload::Sums(d) => {
            let mut v = (**d).clone();
            if let Some(x) = v.first_mut() {
                *x = f64::from_bits(x.to_bits() ^ 1);
            }
            Payload::Sums(Arc::new(v))
        }
        Payload::Token(t) => Payload::Token(t ^ 1),
    }
}

#[derive(Debug)]
struct Envelope {
    from: usize,
    tag: u64,
    checksum: u64,
    payload: Payload,
}

/// Shared per-cluster counters (the §6.3 accounting inputs). Only
/// successfully delivered envelopes tick these — retransmits of dropped
/// or corrupted envelopes are the link layer's business, so fault-free
/// and fault-recovered runs account identically.
#[derive(Debug, Default)]
pub struct CommCounters {
    pub messages: AtomicU64,
    pub bytes: AtomicU64,
}

/// The fabric: construct once, then [`VirtualCluster::endpoints`] yields
/// one [`Endpoint`] per rank to move into each node's thread.
pub struct VirtualCluster {
    senders: Vec<Sender<Envelope>>,
    receivers: Vec<Option<Receiver<Envelope>>>,
    counters: Arc<CommCounters>,
    elem_bytes: usize,
    faults: Option<Arc<FaultPlan>>,
}

impl VirtualCluster {
    /// `elem_bytes`: precision width used for wire-byte accounting.
    pub fn new(np: usize, elem_bytes: usize) -> Self {
        Self::build(np, elem_bytes, None)
    }

    /// A cluster whose link layer runs under a scripted
    /// [`faults::FaultPlan`] — the fault-injection seam for rigs.
    pub fn with_faults(np: usize, elem_bytes: usize, plan: Arc<FaultPlan>) -> Self {
        Self::build(np, elem_bytes, Some(plan))
    }

    fn build(np: usize, elem_bytes: usize, faults: Option<Arc<FaultPlan>>) -> Self {
        let mut senders = Vec::with_capacity(np);
        let mut receivers = Vec::with_capacity(np);
        for _ in 0..np {
            let (tx, rx) = channel();
            senders.push(tx);
            receivers.push(Some(rx));
        }
        VirtualCluster {
            senders,
            receivers,
            counters: Arc::new(CommCounters::default()),
            elem_bytes,
            faults,
        }
    }

    pub fn np(&self) -> usize {
        self.senders.len()
    }

    pub fn counters(&self) -> Arc<CommCounters> {
        Arc::clone(&self.counters)
    }

    /// Take all endpoints (consumes the receivers; call once).
    pub fn endpoints(&mut self) -> Vec<Endpoint> {
        let deadline = self
            .faults
            .as_ref()
            .map(|f| f.recv_deadline())
            .unwrap_or(DEFAULT_RECV_DEADLINE);
        (0..self.np())
            .map(|rank| Endpoint {
                rank,
                np: self.np(),
                senders: self.senders.clone(),
                rx: self.receivers[rank].take().expect("endpoints() called twice"),
                stash: HashMap::new(),
                counters: Arc::clone(&self.counters),
                elem_bytes: self.elem_bytes,
                deadline,
                faults: self.faults.clone(),
                sent_messages: 0,
                sent_bytes: 0,
                retransmits: 0,
                corrupt_detected: 0,
            })
            .collect()
    }
}

/// One rank's communication handle (moved into its node thread).
pub struct Endpoint {
    pub rank: usize,
    pub np: usize,
    senders: Vec<Sender<Envelope>>,
    rx: Receiver<Envelope>,
    /// Out-of-order arrivals parked until a matching recv posts
    /// (the MPI unexpected-message queue).
    stash: HashMap<(usize, u64), Vec<Payload>>,
    counters: Arc<CommCounters>,
    elem_bytes: usize,
    deadline: Duration,
    faults: Option<Arc<FaultPlan>>,
    /// This rank's own sent totals (mirrored into `RunStats` by the
    /// node programs so `RunStats::absorb` sums match cluster totals).
    sent_messages: u64,
    sent_bytes: u64,
    /// Link-layer retransmits this rank performed recovering from
    /// scripted drops/corruptions (0 on a healthy fabric).
    retransmits: u64,
    /// Envelopes this rank discarded on checksum mismatch.
    corrupt_detected: u64,
}

impl Endpoint {
    fn err(&self, kind: CommErrorKind, message: impl Into<String>) -> CommError {
        CommError::new(kind, self.rank, message)
    }

    fn check_alive(&self) -> Result<(), CommError> {
        if let Some(f) = &self.faults {
            if f.is_killed(self.rank) {
                return Err(self.err(CommErrorKind::Killed, "node killed by fault plan"));
            }
        }
        Ok(())
    }

    /// Non-blocking tagged send (buffered — never deadlocks on unpaired
    /// sends, like MPI_Isend with ample buffering). The link layer
    /// retransmits scripted drops/corruptions under the shared backoff
    /// policy; only the successful delivery is accounted.
    pub fn send(&mut self, to: usize, tag: u64, payload: Payload) -> Result<(), CommError> {
        self.check_alive()?;
        let bytes = payload.bytes(self.elem_bytes);
        let checksum = payload_checksum(&payload);
        let op = self.faults.as_ref().map(|f| f.begin_send(self.rank));
        let policy = Policy::seeded(self.rank as u64);
        let mut attempt: u32 = 0;
        loop {
            let fault = match (&self.faults, op) {
                (Some(f), Some(op)) => f.take_send_fault(self.rank, op),
                _ => None,
            };
            match fault {
                Some(FaultKind::Kill) => {
                    // The plan marked this rank dead; surface permanently.
                    return Err(self.err(CommErrorKind::Killed, "node killed by fault plan"));
                }
                Some(FaultKind::Drop) => {
                    // Envelope lost on the wire; the ack timeout fires
                    // and the link layer retransmits after backoff.
                    if attempt + 1 >= policy.attempts {
                        return Err(self.err(
                            CommErrorKind::Timeout,
                            format!("send to {to} tag {tag}: retransmit budget exhausted"),
                        ));
                    }
                    std::thread::sleep(policy.delay(attempt));
                    attempt += 1;
                    self.retransmits += 1;
                    continue;
                }
                Some(FaultKind::Corrupt) => {
                    // Deliver a bit-flipped copy under the clean
                    // checksum: the receiver's validation fires, the
                    // nack comes back, and the link layer retransmits.
                    let _ = self.senders[to].send(Envelope {
                        from: self.rank,
                        tag,
                        checksum,
                        payload: bitflip(&payload),
                    });
                    if attempt + 1 >= policy.attempts {
                        return Err(self.err(
                            CommErrorKind::Corrupt,
                            format!("send to {to} tag {tag}: retransmit budget exhausted"),
                        ));
                    }
                    std::thread::sleep(policy.delay(attempt));
                    attempt += 1;
                    self.retransmits += 1;
                    continue;
                }
                Some(FaultKind::Delay(d)) => {
                    std::thread::sleep(d);
                }
                None => {}
            }
            self.counters.messages.fetch_add(1, Ordering::Relaxed);
            self.counters.bytes.fetch_add(bytes, Ordering::Relaxed);
            self.sent_messages += 1;
            self.sent_bytes += bytes;
            return self.senders[to]
                .send(Envelope { from: self.rank, tag, checksum, payload })
                .map_err(|_| {
                    self.err(CommErrorKind::PeerDead, format!("peer {to} endpoint dropped"))
                });
        }
    }

    /// (messages, bytes) this endpoint has sent so far.
    pub fn sent(&self) -> (u64, u64) {
        (self.sent_messages, self.sent_bytes)
    }

    /// Link-layer retransmits performed recovering from scripted
    /// drops/corruptions (0 on a healthy fabric).
    pub fn retransmits(&self) -> u64 {
        self.retransmits
    }

    /// Envelopes discarded on checksum mismatch.
    pub fn corrupt_detected(&self) -> u64 {
        self.corrupt_detected
    }

    /// Validate-and-sort one arrived envelope; returns the payload when
    /// it matches (from, tag), stashes it otherwise. Corrupt envelopes
    /// are discarded (the sender's link layer retransmits).
    fn accept(
        &mut self,
        env: Envelope,
        from: usize,
        tag: u64,
    ) -> Option<Payload> {
        if payload_checksum(&env.payload) != env.checksum {
            self.corrupt_detected += 1;
            if let Some(f) = &self.faults {
                f.note_corrupt_detected();
            }
            return None;
        }
        if env.from == from && env.tag == tag {
            return Some(env.payload);
        }
        self.stash.entry((env.from, env.tag)).or_default().push(env.payload);
        None
    }

    /// Tagged receive bounded by an explicit deadline. Out-of-order
    /// arrivals for other (source, tag) pairs are stashed; envelopes
    /// failing their checksum are discarded (the link layer's
    /// retransmit supplies the clean copy).
    pub fn recv_deadline(
        &mut self,
        from: usize,
        tag: u64,
        deadline: Duration,
    ) -> Result<Payload, CommError> {
        self.check_alive()?;
        if let Some(q) = self.stash.get_mut(&(from, tag)) {
            if !q.is_empty() {
                return Ok(q.remove(0));
            }
        }
        let expires = Instant::now() + deadline;
        loop {
            let remaining = expires.saturating_duration_since(Instant::now());
            if remaining.is_zero() {
                return Err(self.err(
                    CommErrorKind::Timeout,
                    format!("recv from {from} tag {tag}: no envelope within {deadline:?}"),
                ));
            }
            match self.rx.recv_timeout(remaining) {
                Ok(env) => {
                    if let Some(p) = self.accept(env, from, tag) {
                        return Ok(p);
                    }
                }
                Err(RecvTimeoutError::Timeout) => {
                    return Err(self.err(
                        CommErrorKind::Timeout,
                        format!("recv from {from} tag {tag}: no envelope within {deadline:?}"),
                    ));
                }
                Err(RecvTimeoutError::Disconnected) => {
                    return Err(self.err(
                        CommErrorKind::PeerDead,
                        format!("recv from {from} tag {tag}: fabric torn down"),
                    ));
                }
            }
        }
    }

    /// Blocking tagged receive from a specific source, bounded by the
    /// endpoint's default deadline (never blocks forever: a dead peer
    /// surfaces as a typed [`CommErrorKind::Timeout`]).
    pub fn recv(&mut self, from: usize, tag: u64) -> Result<Payload, CommError> {
        self.recv_deadline(from, tag, self.deadline)
    }

    /// Non-blocking tagged receive: `Ok(None)` when no matching
    /// envelope has arrived yet.
    pub fn try_recv(&mut self, from: usize, tag: u64) -> Result<Option<Payload>, CommError> {
        self.check_alive()?;
        if let Some(q) = self.stash.get_mut(&(from, tag)) {
            if !q.is_empty() {
                return Ok(Some(q.remove(0)));
            }
        }
        loop {
            match self.rx.try_recv() {
                Ok(env) => {
                    if let Some(p) = self.accept(env, from, tag) {
                        return Ok(Some(p));
                    }
                }
                Err(TryRecvError::Empty) => return Ok(None),
                Err(TryRecvError::Disconnected) => {
                    return Err(self.err(
                        CommErrorKind::PeerDead,
                        format!("try_recv from {from} tag {tag}: fabric torn down"),
                    ));
                }
            }
        }
    }

    /// Ring send-and-receive (the Algorithm 1 exchange step): send own
    /// payload to `to`, receive the matching payload from `from`.
    pub fn sendrecv(
        &mut self,
        to: usize,
        from: usize,
        tag: u64,
        payload: Payload,
    ) -> Result<Payload, CommError> {
        if to == self.rank && from == self.rank {
            self.check_alive()?;
            return Ok(payload); // self-exchange is the identity
        }
        self.send(to, tag, payload)?;
        self.recv(from, tag)
    }

    /// Sum-allreduce of equal-length f64 vectors across `group` (which
    /// must contain this rank). Gather-to-root + broadcast: O(2·|g|)
    /// messages — fine at simulation scale, same byte volume as a tree
    /// for the accounting's purposes.
    pub fn allreduce_sum(
        &mut self,
        group: &[usize],
        tag: u64,
        mut data: Vec<f64>,
    ) -> Result<Vec<f64>, CommError> {
        if group.len() <= 1 {
            self.check_alive()?;
            return Ok(data);
        }
        let root = group[0];
        if self.rank == root {
            for &peer in &group[1..] {
                match self.recv(peer, tag)? {
                    Payload::Partial(d) => {
                        for (a, b) in data.iter_mut().zip(d.iter()) {
                            *a += b;
                        }
                    }
                    other => {
                        return Err(self.err(
                            CommErrorKind::Corrupt,
                            format!("allreduce expected Partial, got {other:?}"),
                        ))
                    }
                }
            }
            let out = Arc::new(data);
            for &peer in &group[1..] {
                self.send(peer, tag + 1, Payload::Partial(Arc::clone(&out)))?;
            }
            Ok(Arc::try_unwrap(out).unwrap_or_else(|a| (*a).clone()))
        } else {
            self.send(root, tag, Payload::Partial(Arc::new(data)))?;
            match self.recv(root, tag + 1)? {
                Payload::Partial(d) => Ok((*d).clone()),
                other => Err(self.err(
                    CommErrorKind::Corrupt,
                    format!("allreduce expected Partial, got {other:?}"),
                )),
            }
        }
    }

    /// Barrier over `group` (gather tokens at root, release).
    pub fn barrier(&mut self, group: &[usize], tag: u64) -> Result<(), CommError> {
        if group.len() <= 1 {
            self.check_alive()?;
            return Ok(());
        }
        let root = group[0];
        if self.rank == root {
            for &peer in &group[1..] {
                let _ = self.recv(peer, tag)?;
            }
            for &peer in &group[1..] {
                self.send(peer, tag + 1, Payload::Token(0))?;
            }
        } else {
            self.send(root, tag, Payload::Token(0))?;
            let _ = self.recv(root, tag + 1)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn point_to_point_with_tags() {
        let mut cluster = VirtualCluster::new(2, 8);
        let mut eps = cluster.endpoints();
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        // Send two tags out of order; recv must match by tag.
        e0.send(1, 7, Payload::Token(77)).unwrap();
        e0.send(1, 5, Payload::Token(55)).unwrap();
        match e1.recv(0, 5).unwrap() {
            Payload::Token(t) => assert_eq!(t, 55),
            _ => panic!(),
        }
        match e1.recv(0, 7).unwrap() {
            Payload::Token(t) => assert_eq!(t, 77),
            _ => panic!(),
        }
    }

    #[test]
    fn ring_sendrecv_rotates_blocks() {
        let np = 4;
        let mut cluster = VirtualCluster::new(np, 8);
        let eps = cluster.endpoints();
        let handles: Vec<_> = eps
            .into_iter()
            .map(|mut ep| {
                thread::spawn(move || {
                    let rank = ep.rank;
                    let own = Payload::Partial(Arc::new(vec![rank as f64]));
                    // shift by 1: send to rank-1, receive from rank+1.
                    let to = (rank + np - 1) % np;
                    let from = (rank + 1) % np;
                    match ep.sendrecv(to, from, 1, own).unwrap() {
                        Payload::Partial(d) => d[0] as usize,
                        _ => panic!(),
                    }
                })
            })
            .collect();
        let got: Vec<usize> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        assert_eq!(got, vec![1, 2, 3, 0]);
    }

    #[test]
    fn self_sendrecv_is_identity() {
        let mut cluster = VirtualCluster::new(1, 8);
        let mut ep = cluster.endpoints().pop().unwrap();
        match ep.sendrecv(0, 0, 1, Payload::Token(9)).unwrap() {
            Payload::Token(t) => assert_eq!(t, 9),
            _ => panic!(),
        }
    }

    #[test]
    fn allreduce_sums_across_group() {
        let np = 3;
        let mut cluster = VirtualCluster::new(np, 8);
        let eps = cluster.endpoints();
        let handles: Vec<_> = eps
            .into_iter()
            .map(|mut ep| {
                thread::spawn(move || {
                    let group = [0, 1, 2];
                    let data = vec![ep.rank as f64, 1.0];
                    ep.allreduce_sum(&group, 10, data).unwrap()
                })
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), vec![3.0, 3.0]);
        }
    }

    #[test]
    fn counters_account_bytes() {
        let mut cluster = VirtualCluster::new(2, 4); // f32 accounting
        let counters = cluster.counters();
        let mut eps = cluster.endpoints();
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        e0.send(
            1,
            1,
            Payload::Block {
                nf: 10,
                nv: 2,
                first_id: 0,
                data: BlockData::F64(Arc::new(vec![0.0; 20])),
            },
        )
        .unwrap();
        let _ = e1.recv(0, 1).unwrap();
        assert_eq!(counters.messages.load(Ordering::Relaxed), 1);
        assert_eq!(counters.bytes.load(Ordering::Relaxed), 80); // 20 × 4B
        assert_eq!(e0.sent(), (1, 80));
        assert_eq!(e1.sent(), (0, 0));
    }

    #[test]
    fn payload_bytes_all_variants() {
        // Float blocks charge at the caller-supplied element width …
        let f64_block = Payload::Block {
            nf: 10,
            nv: 2,
            first_id: 0,
            data: BlockData::F64(Arc::new(vec![0.0; 20])),
        };
        assert_eq!(f64_block.bytes(8), 160);
        assert_eq!(f64_block.bytes(4), 80);
        // … packed blocks always charge 8 B per u64 word, regardless of
        // the run precision (the pack-once wire saving must not be
        // silently inflated or shrunk by a precision switch).
        let packed = Payload::Block {
            nf: 130,
            nv: 2,
            first_id: 0,
            data: BlockData::Packed(crate::vecdata::block::PackedBlock {
                words_per_vec: 3,
                words: Arc::new(vec![0; 6]),
            }),
        };
        assert_eq!(packed.bytes(8), 48);
        assert_eq!(packed.bytes(4), 48);
        // Two-plane genotype blocks likewise charge 8 B per word across
        // every plane present (the mask plane only when it travels).
        let packed2 = Payload::Block {
            nf: 130,
            nv: 2,
            first_id: 0,
            data: BlockData::Packed2(crate::vecdata::block::Packed2Block {
                words_per_vec: 3,
                lo: Arc::new(vec![0; 6]),
                hi: Arc::new(vec![0; 6]),
                missing: None,
            }),
        };
        assert_eq!(packed2.bytes(8), 96);
        assert_eq!(packed2.bytes(4), 96);
        let masked = Payload::Block {
            nf: 130,
            nv: 2,
            first_id: 0,
            data: BlockData::Packed2(crate::vecdata::block::Packed2Block {
                words_per_vec: 3,
                lo: Arc::new(vec![0; 6]),
                hi: Arc::new(vec![0; 6]),
                missing: Some(Arc::new(vec![0; 6])),
            }),
        };
        assert_eq!(masked.bytes(8), 144);
        // Partials and sums are float vectors at element width.
        assert_eq!(Payload::Partial(Arc::new(vec![0.0; 5])).bytes(8), 40);
        assert_eq!(Payload::Sums(Arc::new(vec![0.0; 5])).bytes(4), 20);
        // Tokens are a flat 8 bytes.
        assert_eq!(Payload::Token(0).bytes(4), 8);
        assert_eq!(Payload::Token(u64::MAX).bytes(8), 8);
    }

    #[test]
    fn packed_block_counted_at_word_width_on_the_wire() {
        // End-to-end through a send: an f32-precision cluster must still
        // account packed words at 8 B each.
        let mut cluster = VirtualCluster::new(2, 4);
        let counters = cluster.counters();
        let mut eps = cluster.endpoints();
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        e0.send(
            1,
            3,
            Payload::Block {
                nf: 64,
                nv: 4,
                first_id: 0,
                data: BlockData::Packed(crate::vecdata::block::PackedBlock {
                    words_per_vec: 1,
                    words: Arc::new(vec![0; 4]),
                }),
            },
        )
        .unwrap();
        let _ = e1.recv(0, 3).unwrap();
        assert_eq!(counters.bytes.load(Ordering::Relaxed), 32);
        assert_eq!(e0.sent(), (1, 32));
    }

    #[test]
    fn barrier_releases_all() {
        let np = 4;
        let mut cluster = VirtualCluster::new(np, 8);
        let eps = cluster.endpoints();
        let flag = Arc::new(AtomicU64::new(0));
        let handles: Vec<_> = eps
            .into_iter()
            .map(|mut ep| {
                let flag = Arc::clone(&flag);
                thread::spawn(move || {
                    let group: Vec<usize> = (0..np).collect();
                    flag.fetch_add(1, Ordering::SeqCst);
                    ep.barrier(&group, 100).unwrap();
                    // After the barrier everyone must have incremented.
                    assert_eq!(flag.load(Ordering::SeqCst), np as u64);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn recv_times_out_instead_of_blocking_forever() {
        let plan = Arc::new(FaultPlan::new());
        plan.set_recv_deadline(Duration::from_millis(20));
        let mut cluster = VirtualCluster::with_faults(2, 8, plan);
        let mut ep = cluster.endpoints().remove(1);
        let t0 = Instant::now();
        let err = ep.recv(0, 1).unwrap_err();
        assert_eq!(err.kind, CommErrorKind::Timeout);
        assert!(t0.elapsed() < Duration::from_secs(5), "deadline must bound the wait");
        // Explicit deadlines work without a plan too.
        let mut cluster = VirtualCluster::new(2, 8);
        let mut ep = cluster.endpoints().remove(1);
        let err = ep.recv_deadline(0, 1, Duration::from_millis(10)).unwrap_err();
        assert_eq!(err.kind, CommErrorKind::Timeout);
    }

    #[test]
    fn try_recv_is_nonblocking_and_tag_matched() {
        let mut cluster = VirtualCluster::new(2, 8);
        let mut eps = cluster.endpoints();
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        assert!(e1.try_recv(0, 1).unwrap().is_none());
        e0.send(1, 2, Payload::Token(2)).unwrap();
        e0.send(1, 1, Payload::Token(1)).unwrap();
        // Drain until the tag-1 envelope is visible (send is async).
        let p = loop {
            if let Some(p) = e1.try_recv(0, 1).unwrap() {
                break p;
            }
        };
        match p {
            Payload::Token(t) => assert_eq!(t, 1),
            _ => panic!(),
        }
        // The out-of-order tag-2 envelope was stashed, not lost.
        match e1.recv(0, 2).unwrap() {
            Payload::Token(t) => assert_eq!(t, 2),
            _ => panic!(),
        }
    }

    #[test]
    fn send_to_dropped_peer_is_peer_dead() {
        let mut cluster = VirtualCluster::new(2, 8);
        let mut eps = cluster.endpoints();
        let e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        drop(e1);
        let err = e0.send(1, 1, Payload::Token(0)).unwrap_err();
        assert_eq!(err.kind, CommErrorKind::PeerDead);
    }

    #[test]
    fn checksum_covers_every_payload_variant() {
        let a = Payload::Partial(Arc::new(vec![1.0, 2.0]));
        let b = Payload::Partial(Arc::new(vec![1.0, 2.0]));
        assert_eq!(payload_checksum(&a), payload_checksum(&b));
        // A single flipped bit changes the checksum.
        assert_ne!(payload_checksum(&a), payload_checksum(&bitflip(&a)));
        let t = Payload::Token(7);
        assert_ne!(payload_checksum(&t), payload_checksum(&bitflip(&t)));
        // Variant confusion is caught: same bytes, different tag.
        let s = Payload::Sums(Arc::new(vec![1.0, 2.0]));
        assert_ne!(payload_checksum(&a), payload_checksum(&s));
        let blk = Payload::Block {
            nf: 2,
            nv: 1,
            first_id: 0,
            data: BlockData::F64(Arc::new(vec![1.0, 2.0])),
        };
        assert_ne!(payload_checksum(&blk), payload_checksum(&bitflip(&blk)));
        let packed = Payload::Block {
            nf: 64,
            nv: 1,
            first_id: 0,
            data: BlockData::Packed(crate::vecdata::block::PackedBlock {
                words_per_vec: 1,
                words: Arc::new(vec![0xFF]),
            }),
        };
        assert_ne!(payload_checksum(&packed), payload_checksum(&bitflip(&packed)));
    }
}
