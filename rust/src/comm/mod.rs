//! The simulated interconnect: an in-process message-passing fabric
//! with MPI-like semantics, plus the analytic latency/bandwidth cost
//! model used for scaling projections.
//!
//! The paper ran MPI over Titan's Gemini torus; no network exists here
//! (DESIGN.md §1), so [`VirtualCluster`] gives each virtual node a
//! mailbox and tagged point-to-point send/recv over channels, with the
//! same pairing discipline as Algorithm 1/2's ring exchanges. Message
//! and byte counts are accounted per node so benches can report the
//! communication volumes the paper's model (§6.3) prices.

pub mod cost;

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

use crate::vecdata::block::BlockData;

/// Message payload: a block of vector data or a small control value.
/// Blocks travel in their metric-preferred representation
/// ([`BlockData`]): f64 elements for float metrics (charged at the run
/// precision's width) or packed u64 words for bit-domain metrics
/// (charged at 8 B/word — the ~64× wire saving of pack-once Sorensen).
#[derive(Debug, Clone)]
pub enum Payload {
    /// Vector block: (nf, nv, first_id, representation-tagged data).
    Block {
        nf: usize,
        nv: usize,
        first_id: usize,
        data: BlockData,
    },
    /// Partial result row for reductions (npf axis).
    Partial(Arc<Vec<f64>>),
    /// Small scalar vector (denominators etc.).
    Sums(Arc<Vec<f64>>),
    /// Bare control/ack.
    Token(u64),
}

impl Payload {
    /// Simulated wire size in bytes. `elem_bytes` is the run
    /// precision's element width; it applies to float payloads
    /// (blocks, partials, sums), while packed block words are always
    /// 8 B/word and tokens 8 B flat.
    pub fn bytes(&self, elem_bytes: usize) -> u64 {
        match self {
            Payload::Block { data, .. } => data.wire_bytes(elem_bytes),
            Payload::Partial(d) | Payload::Sums(d) => (d.len() * elem_bytes) as u64,
            Payload::Token(_) => 8,
        }
    }
}

#[derive(Debug)]
struct Envelope {
    from: usize,
    tag: u64,
    payload: Payload,
}

/// Shared per-cluster counters (the §6.3 accounting inputs).
#[derive(Debug, Default)]
pub struct CommCounters {
    pub messages: AtomicU64,
    pub bytes: AtomicU64,
}

/// The fabric: construct once, then [`VirtualCluster::endpoints`] yields
/// one [`Endpoint`] per rank to move into each node's thread.
pub struct VirtualCluster {
    senders: Vec<Sender<Envelope>>,
    receivers: Vec<Option<Receiver<Envelope>>>,
    counters: Arc<CommCounters>,
    elem_bytes: usize,
}

impl VirtualCluster {
    /// `elem_bytes`: precision width used for wire-byte accounting.
    pub fn new(np: usize, elem_bytes: usize) -> Self {
        let mut senders = Vec::with_capacity(np);
        let mut receivers = Vec::with_capacity(np);
        for _ in 0..np {
            let (tx, rx) = channel();
            senders.push(tx);
            receivers.push(Some(rx));
        }
        VirtualCluster {
            senders,
            receivers,
            counters: Arc::new(CommCounters::default()),
            elem_bytes,
        }
    }

    pub fn np(&self) -> usize {
        self.senders.len()
    }

    pub fn counters(&self) -> Arc<CommCounters> {
        Arc::clone(&self.counters)
    }

    /// Take all endpoints (consumes the receivers; call once).
    pub fn endpoints(&mut self) -> Vec<Endpoint> {
        (0..self.np())
            .map(|rank| Endpoint {
                rank,
                np: self.np(),
                senders: self.senders.clone(),
                rx: self.receivers[rank].take().expect("endpoints() called twice"),
                stash: HashMap::new(),
                counters: Arc::clone(&self.counters),
                elem_bytes: self.elem_bytes,
                sent_messages: 0,
                sent_bytes: 0,
            })
            .collect()
    }
}

/// One rank's communication handle (moved into its node thread).
pub struct Endpoint {
    pub rank: usize,
    pub np: usize,
    senders: Vec<Sender<Envelope>>,
    rx: Receiver<Envelope>,
    /// Out-of-order arrivals parked until a matching recv posts
    /// (the MPI unexpected-message queue).
    stash: HashMap<(usize, u64), Vec<Payload>>,
    counters: Arc<CommCounters>,
    elem_bytes: usize,
    /// This rank's own sent totals (mirrored into `RunStats` by the
    /// node programs so `RunStats::absorb` sums match cluster totals).
    sent_messages: u64,
    sent_bytes: u64,
}

impl Endpoint {
    /// Non-blocking tagged send (buffered — never deadlocks on unpaired
    /// sends, like MPI_Isend with ample buffering).
    pub fn send(&mut self, to: usize, tag: u64, payload: Payload) {
        let bytes = payload.bytes(self.elem_bytes);
        self.counters.messages.fetch_add(1, Ordering::Relaxed);
        self.counters.bytes.fetch_add(bytes, Ordering::Relaxed);
        self.sent_messages += 1;
        self.sent_bytes += bytes;
        self.senders[to]
            .send(Envelope {
                from: self.rank,
                tag,
                payload,
            })
            .expect("peer endpoint dropped");
    }

    /// (messages, bytes) this endpoint has sent so far.
    pub fn sent(&self) -> (u64, u64) {
        (self.sent_messages, self.sent_bytes)
    }

    /// Blocking tagged receive from a specific source.
    pub fn recv(&mut self, from: usize, tag: u64) -> Payload {
        if let Some(q) = self.stash.get_mut(&(from, tag)) {
            if !q.is_empty() {
                return q.remove(0);
            }
        }
        loop {
            let env = self.rx.recv().expect("cluster torn down mid-recv");
            if env.from == from && env.tag == tag {
                return env.payload;
            }
            self.stash
                .entry((env.from, env.tag))
                .or_default()
                .push(env.payload);
        }
    }

    /// Ring send-and-receive (the Algorithm 1 exchange step): send own
    /// payload to `to`, receive the matching payload from `from`.
    pub fn sendrecv(&mut self, to: usize, from: usize, tag: u64, payload: Payload) -> Payload {
        if to == self.rank && from == self.rank {
            return payload; // self-exchange is the identity
        }
        self.send(to, tag, payload);
        self.recv(from, tag)
    }

    /// Sum-allreduce of equal-length f64 vectors across `group` (which
    /// must contain this rank). Gather-to-root + broadcast: O(2·|g|)
    /// messages — fine at simulation scale, same byte volume as a tree
    /// for the accounting's purposes.
    pub fn allreduce_sum(&mut self, group: &[usize], tag: u64, mut data: Vec<f64>) -> Vec<f64> {
        if group.len() <= 1 {
            return data;
        }
        let root = group[0];
        if self.rank == root {
            for &peer in &group[1..] {
                match self.recv(peer, tag) {
                    Payload::Partial(d) => {
                        for (a, b) in data.iter_mut().zip(d.iter()) {
                            *a += b;
                        }
                    }
                    other => panic!("allreduce expected Partial, got {other:?}"),
                }
            }
            let out = Arc::new(data);
            for &peer in &group[1..] {
                self.send(peer, tag + 1, Payload::Partial(Arc::clone(&out)));
            }
            Arc::try_unwrap(out).unwrap_or_else(|a| (*a).clone())
        } else {
            self.send(root, tag, Payload::Partial(Arc::new(data)));
            match self.recv(root, tag + 1) {
                Payload::Partial(d) => (*d).clone(),
                other => panic!("allreduce expected Partial, got {other:?}"),
            }
        }
    }

    /// Barrier over `group` (gather tokens at root, release).
    pub fn barrier(&mut self, group: &[usize], tag: u64) {
        if group.len() <= 1 {
            return;
        }
        let root = group[0];
        if self.rank == root {
            for &peer in &group[1..] {
                let _ = self.recv(peer, tag);
            }
            for &peer in &group[1..] {
                self.send(peer, tag + 1, Payload::Token(0));
            }
        } else {
            self.send(root, tag, Payload::Token(0));
            let _ = self.recv(root, tag + 1);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn point_to_point_with_tags() {
        let mut cluster = VirtualCluster::new(2, 8);
        let mut eps = cluster.endpoints();
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        // Send two tags out of order; recv must match by tag.
        e0.send(1, 7, Payload::Token(77));
        e0.send(1, 5, Payload::Token(55));
        match e1.recv(0, 5) {
            Payload::Token(t) => assert_eq!(t, 55),
            _ => panic!(),
        }
        match e1.recv(0, 7) {
            Payload::Token(t) => assert_eq!(t, 77),
            _ => panic!(),
        }
    }

    #[test]
    fn ring_sendrecv_rotates_blocks() {
        let np = 4;
        let mut cluster = VirtualCluster::new(np, 8);
        let eps = cluster.endpoints();
        let handles: Vec<_> = eps
            .into_iter()
            .map(|mut ep| {
                thread::spawn(move || {
                    let rank = ep.rank;
                    let own = Payload::Partial(Arc::new(vec![rank as f64]));
                    // shift by 1: send to rank-1, receive from rank+1.
                    let to = (rank + np - 1) % np;
                    let from = (rank + 1) % np;
                    match ep.sendrecv(to, from, 1, own) {
                        Payload::Partial(d) => d[0] as usize,
                        _ => panic!(),
                    }
                })
            })
            .collect();
        let got: Vec<usize> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        assert_eq!(got, vec![1, 2, 3, 0]);
    }

    #[test]
    fn self_sendrecv_is_identity() {
        let mut cluster = VirtualCluster::new(1, 8);
        let mut ep = cluster.endpoints().pop().unwrap();
        match ep.sendrecv(0, 0, 1, Payload::Token(9)) {
            Payload::Token(t) => assert_eq!(t, 9),
            _ => panic!(),
        }
    }

    #[test]
    fn allreduce_sums_across_group() {
        let np = 3;
        let mut cluster = VirtualCluster::new(np, 8);
        let eps = cluster.endpoints();
        let handles: Vec<_> = eps
            .into_iter()
            .map(|mut ep| {
                thread::spawn(move || {
                    let group = [0, 1, 2];
                    let data = vec![ep.rank as f64, 1.0];
                    ep.allreduce_sum(&group, 10, data)
                })
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), vec![3.0, 3.0]);
        }
    }

    #[test]
    fn counters_account_bytes() {
        let mut cluster = VirtualCluster::new(2, 4); // f32 accounting
        let counters = cluster.counters();
        let mut eps = cluster.endpoints();
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        e0.send(
            1,
            1,
            Payload::Block {
                nf: 10,
                nv: 2,
                first_id: 0,
                data: BlockData::F64(Arc::new(vec![0.0; 20])),
            },
        );
        let _ = e1.recv(0, 1);
        assert_eq!(counters.messages.load(Ordering::Relaxed), 1);
        assert_eq!(counters.bytes.load(Ordering::Relaxed), 80); // 20 × 4B
        assert_eq!(e0.sent(), (1, 80));
        assert_eq!(e1.sent(), (0, 0));
    }

    #[test]
    fn payload_bytes_all_variants() {
        // Float blocks charge at the caller-supplied element width …
        let f64_block = Payload::Block {
            nf: 10,
            nv: 2,
            first_id: 0,
            data: BlockData::F64(Arc::new(vec![0.0; 20])),
        };
        assert_eq!(f64_block.bytes(8), 160);
        assert_eq!(f64_block.bytes(4), 80);
        // … packed blocks always charge 8 B per u64 word, regardless of
        // the run precision (the pack-once wire saving must not be
        // silently inflated or shrunk by a precision switch).
        let packed = Payload::Block {
            nf: 130,
            nv: 2,
            first_id: 0,
            data: BlockData::Packed(crate::vecdata::block::PackedBlock {
                words_per_vec: 3,
                words: Arc::new(vec![0; 6]),
            }),
        };
        assert_eq!(packed.bytes(8), 48);
        assert_eq!(packed.bytes(4), 48);
        // Partials and sums are float vectors at element width.
        assert_eq!(Payload::Partial(Arc::new(vec![0.0; 5])).bytes(8), 40);
        assert_eq!(Payload::Sums(Arc::new(vec![0.0; 5])).bytes(4), 20);
        // Tokens are a flat 8 bytes.
        assert_eq!(Payload::Token(0).bytes(4), 8);
        assert_eq!(Payload::Token(u64::MAX).bytes(8), 8);
    }

    #[test]
    fn packed_block_counted_at_word_width_on_the_wire() {
        // End-to-end through a send: an f32-precision cluster must still
        // account packed words at 8 B each.
        let mut cluster = VirtualCluster::new(2, 4);
        let counters = cluster.counters();
        let mut eps = cluster.endpoints();
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        e0.send(
            1,
            3,
            Payload::Block {
                nf: 64,
                nv: 4,
                first_id: 0,
                data: BlockData::Packed(crate::vecdata::block::PackedBlock {
                    words_per_vec: 1,
                    words: Arc::new(vec![0; 4]),
                }),
            },
        );
        let _ = e1.recv(0, 3);
        assert_eq!(counters.bytes.load(Ordering::Relaxed), 32);
        assert_eq!(e0.sent(), (1, 32));
    }

    #[test]
    fn barrier_releases_all() {
        let np = 4;
        let mut cluster = VirtualCluster::new(np, 8);
        let eps = cluster.endpoints();
        let flag = Arc::new(AtomicU64::new(0));
        let handles: Vec<_> = eps
            .into_iter()
            .map(|mut ep| {
                let flag = Arc::clone(&flag);
                thread::spawn(move || {
                    let group: Vec<usize> = (0..np).collect();
                    flag.fetch_add(1, Ordering::SeqCst);
                    ep.barrier(&group, 100);
                    // After the barrier everyone must have incremented.
                    assert_eq!(flag.load(Ordering::SeqCst), np as u64);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }
}
