//! Analytic communication cost model — the latency/bandwidth pricing
//! behind the §6.3 performance model and the scaling projections.
//!
//! Calibrated to a Gemini-class interconnect by default (the paper's
//! Titan: ~1.5 µs latency, ~6 GB/s effective per-node bandwidth under
//! the balanced-injection settings of §6.6); construct with other
//! numbers to model different fabrics.

/// α–β model: t(msg) = α + bytes/β.
#[derive(Debug, Clone, Copy)]
pub struct CostModel {
    /// Per-message latency α, seconds.
    pub latency_s: f64,
    /// Effective bandwidth β, bytes/second.
    pub bandwidth_bps: f64,
}

impl CostModel {
    /// Titan Gemini-class defaults (see module docs).
    pub fn gemini() -> Self {
        CostModel {
            latency_s: 1.5e-6,
            bandwidth_bps: 6.0e9,
        }
    }

    /// PCIe-2 x16 host↔accelerator link (the K20X's bus): ~8 GB/s peak,
    /// ~6 GB/s effective.
    pub fn pcie2() -> Self {
        CostModel {
            latency_s: 10e-6,
            bandwidth_bps: 6.0e9,
        }
    }

    /// Time for one point-to-point message of `bytes`.
    pub fn msg_time(&self, bytes: u64) -> f64 {
        self.latency_s + bytes as f64 / self.bandwidth_bps
    }

    /// Time for a reduction of `bytes` across `n` nodes (log-tree, the
    /// paper's "log(npf) communication steps" for the vector-elements
    /// axis, §4.1).
    pub fn reduce_time(&self, bytes: u64, n: usize) -> f64 {
        if n <= 1 {
            return 0.0;
        }
        (n as f64).log2().ceil() * self.msg_time(bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn msg_time_scales_linearly() {
        let m = CostModel::gemini();
        let t1 = m.msg_time(1_000_000);
        let t2 = m.msg_time(2_000_000);
        assert!(t2 > t1);
        assert!((t2 - t1 - 1_000_000.0 / m.bandwidth_bps).abs() < 1e-12);
    }

    #[test]
    fn latency_dominates_small_messages() {
        let m = CostModel::gemini();
        assert!(m.msg_time(8) < 2.0 * m.latency_s);
    }

    #[test]
    fn reduce_time_log_steps() {
        let m = CostModel::gemini();
        assert_eq!(m.reduce_time(100, 1), 0.0);
        let t2 = m.reduce_time(100, 2);
        let t8 = m.reduce_time(100, 8);
        assert!((t8 / t2 - 3.0).abs() < 1e-9); // log2(8) = 3 steps
    }

    #[test]
    fn paper_half_gb_message_time_plausible() {
        // §6.6: 2-way weak scaling sends ~1/2 GB messages; at Gemini
        // rates that is ~80 ms per step — the scale the paper hides
        // under mGEMM compute.
        let m = CostModel::gemini();
        let t = m.msg_time(500_000_000);
        assert!((0.05..0.2).contains(&t), "t={t}");
    }
}
