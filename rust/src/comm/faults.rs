//! Scripted comm-fabric fault injection — the interconnect's
//! counterpart to `testkit::faults::FailingStore`.
//!
//! A [`FaultPlan`] holds a deterministic schedule of link faults keyed
//! by `(rank, k)`: *the k-th send operation of that rank* (0-based,
//! counted across every `send`/`sendrecv`/`allreduce_sum`/`barrier`
//! the rank performs). Install it with
//! [`super::VirtualCluster::with_faults`]; the endpoints consult it on
//! every operation:
//!
//! * [`FaultKind::Drop`] — the envelope is lost on the wire; the link
//!   layer's ack timeout fires and it **retransmits** under the shared
//!   [`crate::util::retry::Policy`] backoff. Transient: the run
//!   recovers bit-identically (only the successful delivery is
//!   accounted). Script it more times than the retry budget and the
//!   send surfaces a typed timeout.
//! * [`FaultKind::Corrupt`] — a bit-flipped copy is delivered under the
//!   clean checksum; the receiver **detects** the mismatch, discards
//!   the envelope, and the sender retransmits. Exercises the
//!   per-envelope FNV-64 validation end to end.
//! * [`FaultKind::Delay`] — the envelope is delivered after a scripted
//!   stall (a slow link, not a lost one). No retransmit, no error.
//! * [`FaultKind::Kill`] — the rank dies at step *k*: this and every
//!   later comm operation on it fails permanently
//!   ([`super::CommErrorKind::Killed`]); peers waiting on it surface
//!   typed timeouts within their recv deadline.
//!
//! Like `FailingStore`, the plan counts what it injects (and what the
//! receive side detects) so rigs can assert the faults actually fired.
//! Schedules built from a PRNG seed (`testkit::faults` has builders)
//! are fully deterministic — no wall clock anywhere in the schedule.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// One scripted link fault.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Lose the envelope (link retransmits after backoff).
    Drop,
    /// Deliver after a scripted stall.
    Delay(Duration),
    /// Deliver a bit-flipped copy (caught by the envelope checksum,
    /// then retransmitted clean).
    Corrupt,
    /// Kill the rank permanently at this step.
    Kill,
}

impl FaultKind {
    pub fn name(&self) -> &'static str {
        match self {
            FaultKind::Drop => "drop",
            FaultKind::Delay(_) => "delay",
            FaultKind::Corrupt => "corrupt",
            FaultKind::Kill => "kill",
        }
    }
}

#[derive(Debug, Clone, Copy)]
struct Scheduled {
    kind: FaultKind,
    /// How many attempts (first try + retransmits) the fault fires on —
    /// schedule ≥ the retry budget to pin exhaustion.
    times: u32,
}

#[derive(Debug, Default)]
struct RankState {
    /// Send operations this rank has started (the step counter `k`).
    ops: u64,
    scheduled: HashMap<u64, Scheduled>,
    killed: bool,
}

/// A scripted, thread-safe fault schedule shared by every endpoint of
/// one cluster. All methods take `&self` (interior mutability) so the
/// plan can be consulted concurrently from every node thread.
#[derive(Debug, Default)]
pub struct FaultPlan {
    ranks: Mutex<HashMap<usize, RankState>>,
    /// Recv deadline override for the cluster (None → the fabric
    /// default). Stored as nanos; 0 = unset.
    recv_deadline_nanos: AtomicU64,
    drops: AtomicU64,
    delays: AtomicU64,
    corrupts: AtomicU64,
    kills: AtomicU64,
    corrupt_detected: AtomicU64,
}

impl FaultPlan {
    pub fn new() -> Self {
        Self::default()
    }

    fn schedule(&self, rank: usize, k: u64, kind: FaultKind, times: u32) {
        let mut ranks = self.ranks.lock().unwrap_or_else(|p| p.into_inner());
        ranks
            .entry(rank)
            .or_default()
            .scheduled
            .insert(k, Scheduled { kind, times: times.max(1) });
    }

    /// Drop the k-th send of `rank` once (the retransmit delivers).
    pub fn drop_at(&self, rank: usize, k: u64) {
        self.drop_at_times(rank, k, 1);
    }

    /// Drop the k-th send of `rank` on `times` consecutive attempts —
    /// schedule ≥ the retry budget to force typed exhaustion.
    pub fn drop_at_times(&self, rank: usize, k: u64, times: u32) {
        self.schedule(rank, k, FaultKind::Drop, times);
    }

    /// Corrupt the k-th send of `rank` once (checksum catches it, the
    /// retransmit delivers clean).
    pub fn corrupt_at(&self, rank: usize, k: u64) {
        self.corrupt_at_times(rank, k, 1);
    }

    /// Corrupt the k-th send of `rank` on `times` consecutive attempts.
    pub fn corrupt_at_times(&self, rank: usize, k: u64, times: u32) {
        self.schedule(rank, k, FaultKind::Corrupt, times);
    }

    /// Stall the k-th send of `rank` by `delay` before delivering.
    pub fn delay_at(&self, rank: usize, k: u64, delay: Duration) {
        self.schedule(rank, k, FaultKind::Delay(delay), 1);
    }

    /// Kill `rank` at its k-th send: that operation and every later
    /// comm operation on the rank fail permanently.
    pub fn kill_at(&self, rank: usize, k: u64) {
        self.schedule(rank, k, FaultKind::Kill, u32::MAX);
    }

    /// Shrink the cluster's blocking-recv deadline (rigs use ~hundreds
    /// of ms so a killed peer surfaces fast; production keeps the
    /// generous fabric default).
    pub fn set_recv_deadline(&self, d: Duration) {
        self.recv_deadline_nanos.store(d.as_nanos().min(u64::MAX as u128) as u64, Ordering::Relaxed);
    }

    /// The recv deadline endpoints of this plan's cluster should use.
    pub fn recv_deadline(&self) -> Duration {
        match self.recv_deadline_nanos.load(Ordering::Relaxed) {
            0 => super::DEFAULT_RECV_DEADLINE,
            n => Duration::from_nanos(n),
        }
    }

    /// Whether `rank` has been killed (checked by every comm op).
    pub fn is_killed(&self, rank: usize) -> bool {
        self.ranks
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .get(&rank)
            .map(|r| r.killed)
            .unwrap_or(false)
    }

    /// Start one logical send operation of `rank`; returns its step
    /// index `k`. Retransmit attempts belong to the same `k`.
    pub fn begin_send(&self, rank: usize) -> u64 {
        let mut ranks = self.ranks.lock().unwrap_or_else(|p| p.into_inner());
        let st = ranks.entry(rank).or_default();
        let op = st.ops;
        st.ops += 1;
        op
    }

    /// Consume (one firing of) the fault scheduled for `(rank, k)`, if
    /// any remains; counts the injection. A `Kill` marks the rank dead.
    pub fn take_send_fault(&self, rank: usize, k: u64) -> Option<FaultKind> {
        let mut ranks = self.ranks.lock().unwrap_or_else(|p| p.into_inner());
        let st = ranks.entry(rank).or_default();
        let sched = st.scheduled.get_mut(&k)?;
        if sched.times == 0 {
            return None;
        }
        sched.times = sched.times.saturating_sub(1);
        let kind = sched.kind;
        match kind {
            FaultKind::Drop => self.drops.fetch_add(1, Ordering::Relaxed),
            FaultKind::Delay(_) => self.delays.fetch_add(1, Ordering::Relaxed),
            FaultKind::Corrupt => self.corrupts.fetch_add(1, Ordering::Relaxed),
            FaultKind::Kill => {
                st.killed = true;
                self.kills.fetch_add(1, Ordering::Relaxed)
            }
        };
        Some(kind)
    }

    /// Send operations `rank` has started so far (faulted attempts and
    /// clean sends alike) — the mirror of `FailingStore`'s attempt
    /// counters.
    pub fn send_ops(&self, rank: usize) -> u64 {
        self.ranks
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .get(&rank)
            .map(|r| r.ops)
            .unwrap_or(0)
    }

    /// Record a receive-side checksum rejection.
    pub fn note_corrupt_detected(&self) {
        self.corrupt_detected.fetch_add(1, Ordering::Relaxed);
    }

    pub fn drops_injected(&self) -> u64 {
        self.drops.load(Ordering::Relaxed)
    }
    pub fn delays_injected(&self) -> u64 {
        self.delays.load(Ordering::Relaxed)
    }
    pub fn corrupts_injected(&self) -> u64 {
        self.corrupts.load(Ordering::Relaxed)
    }
    pub fn kills_injected(&self) -> u64 {
        self.kills.load(Ordering::Relaxed)
    }
    pub fn corrupts_detected(&self) -> u64 {
        self.corrupt_detected.load(Ordering::Relaxed)
    }

    /// Total faults injected across every class.
    pub fn injected(&self) -> u64 {
        self.drops_injected()
            + self.delays_injected()
            + self.corrupts_injected()
            + self.kills_injected()
    }

    /// The remaining (not-yet-fired) schedule as sorted
    /// `(rank, k, kind)` triples — introspection for determinism tests.
    pub fn remaining_schedule(&self) -> Vec<(usize, u64, FaultKind)> {
        let ranks = self.ranks.lock().unwrap_or_else(|p| p.into_inner());
        let mut out: Vec<_> = ranks
            .iter()
            .flat_map(|(&rank, st)| {
                st.scheduled
                    .iter()
                    .filter(|(_, s)| s.times > 0)
                    .map(move |(&k, s)| (rank, k, s.kind))
            })
            .collect();
        out.sort_by_key(|&(r, k, _)| (r, k));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{CommErrorKind, Payload, VirtualCluster};
    use std::sync::Arc;

    fn token(ep_payload: Payload) -> u64 {
        match ep_payload {
            Payload::Token(t) => t,
            other => panic!("expected Token, got {other:?}"),
        }
    }

    #[test]
    fn dropped_envelope_is_retransmitted_and_counted_once() {
        let plan = Arc::new(FaultPlan::new());
        plan.drop_at(0, 0);
        let mut cluster = VirtualCluster::with_faults(2, 8, Arc::clone(&plan));
        let counters = cluster.counters();
        let mut eps = cluster.endpoints();
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        e0.send(1, 1, Payload::Token(42)).unwrap();
        assert_eq!(token(e1.recv(0, 1).unwrap()), 42);
        // One retransmit recovered the drop; accounting saw ONE message.
        assert_eq!(e0.retransmits(), 1);
        assert_eq!(plan.drops_injected(), 1);
        assert_eq!(counters.messages.load(std::sync::atomic::Ordering::Relaxed), 1);
        assert_eq!(e0.sent(), (1, 8));
        // The step counter advanced once per logical send.
        assert_eq!(plan.send_ops(0), 1);
    }

    #[test]
    fn corrupted_envelope_is_detected_then_replaced_clean() {
        let plan = Arc::new(FaultPlan::new());
        plan.corrupt_at(0, 0);
        let mut cluster = VirtualCluster::with_faults(2, 8, Arc::clone(&plan));
        let mut eps = cluster.endpoints();
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        let payload = Payload::Partial(Arc::new(vec![1.25, -3.5]));
        e0.send(1, 9, payload).unwrap();
        // The receiver sees the CLEAN payload — bit-identical.
        match e1.recv(0, 9).unwrap() {
            Payload::Partial(d) => assert_eq!(*d, vec![1.25, -3.5]),
            other => panic!("{other:?}"),
        }
        assert_eq!(e1.corrupt_detected(), 1);
        assert_eq!(plan.corrupts_injected(), 1);
        assert_eq!(plan.corrupts_detected(), 1);
        assert_eq!(e0.retransmits(), 1);
    }

    #[test]
    fn delay_stalls_but_delivers_without_retry() {
        let plan = Arc::new(FaultPlan::new());
        plan.delay_at(0, 0, Duration::from_millis(15));
        let mut cluster = VirtualCluster::with_faults(2, 8, Arc::clone(&plan));
        let mut eps = cluster.endpoints();
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        let t0 = std::time::Instant::now();
        e0.send(1, 1, Payload::Token(5)).unwrap();
        assert!(t0.elapsed() >= Duration::from_millis(15));
        assert_eq!(token(e1.recv(0, 1).unwrap()), 5);
        assert_eq!(e0.retransmits(), 0);
        assert_eq!(plan.delays_injected(), 1);
    }

    #[test]
    fn persistent_drop_exhausts_the_retry_budget_with_typed_error() {
        let plan = Arc::new(FaultPlan::new());
        plan.drop_at_times(0, 0, u32::MAX);
        let mut cluster = VirtualCluster::with_faults(2, 8, Arc::clone(&plan));
        let mut eps = cluster.endpoints();
        let _e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        let err = e0.send(1, 1, Payload::Token(0)).unwrap_err();
        assert_eq!(err.kind, CommErrorKind::Timeout);
        // Exactly the policy budget's worth of drops fired.
        assert_eq!(plan.drops_injected() as u32, crate::util::retry::DEFAULT_ATTEMPTS);
        assert_eq!(e0.sent(), (0, 0), "no successful delivery may be accounted");
    }

    #[test]
    fn killed_rank_fails_permanently_and_peers_time_out() {
        let plan = Arc::new(FaultPlan::new());
        plan.kill_at(0, 1);
        plan.set_recv_deadline(Duration::from_millis(30));
        let mut cluster = VirtualCluster::with_faults(2, 8, Arc::clone(&plan));
        let mut eps = cluster.endpoints();
        let mut e1 = eps.pop().unwrap();
        let mut e0 = eps.pop().unwrap();
        // Send 0 is clean; send 1 is the kill point.
        e0.send(1, 1, Payload::Token(0)).unwrap();
        let err = e0.send(1, 2, Payload::Token(1)).unwrap_err();
        assert_eq!(err.kind, CommErrorKind::Killed);
        // Every later op on the killed rank fails the same way …
        assert_eq!(e0.send(1, 3, Payload::Token(2)).unwrap_err().kind, CommErrorKind::Killed);
        assert_eq!(e0.recv(1, 1).unwrap_err().kind, CommErrorKind::Killed);
        assert!(plan.is_killed(0));
        assert_eq!(plan.kills_injected(), 1);
        // … and the waiting peer gets a bounded typed timeout, not a hang.
        assert_eq!(token(e1.recv(0, 1).unwrap()), 0);
        assert_eq!(e1.recv(0, 2).unwrap_err().kind, CommErrorKind::Timeout);
    }

    #[test]
    fn schedules_are_introspectable_and_deterministic() {
        let build = || {
            let plan = FaultPlan::new();
            plan.drop_at(2, 7);
            plan.corrupt_at(0, 3);
            plan.delay_at(1, 5, Duration::from_millis(1));
            plan.kill_at(3, 11);
            plan
        };
        let a = build();
        let b = build();
        assert_eq!(a.remaining_schedule(), b.remaining_schedule());
        assert_eq!(a.remaining_schedule().len(), 4);
        // Consuming a fault removes it from the remaining schedule.
        assert_eq!(a.begin_send(0), 0);
        for _ in 0..3 {
            assert!(a.begin_send(0) > 0);
        }
        assert_eq!(a.take_send_fault(0, 3), Some(FaultKind::Corrupt));
        assert_eq!(a.take_send_fault(0, 3), None);
        assert_eq!(a.remaining_schedule().len(), 3);
        // Unscheduled steps yield no fault.
        assert_eq!(a.take_send_fault(2, 0), None);
    }
}
