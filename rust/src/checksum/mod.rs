//! Order-independent extended-precision checksums over metric results.
//!
//! Paper §5: "A checksum feature using extended precision integer
//! arithmetic computes a bit-for-bit exact checksum of computed results
//! to check for errors when using synthetic inputs."
//!
//! Each metric value is hashed together with its *global* indices and
//! accumulated with wrapping 128-bit addition — a commutative monoid, so
//! the checksum is independent of computation order, node assignment,
//! and parallel decomposition. Combined with grid-valued synthetic
//! inputs (whose float sums are exact, hence bit-identical across all
//! code paths), this reproduces the paper's cross-decomposition
//! bit-for-bit validation.

use crate::util::prng::mix64;

/// Accumulating checksum over a multiset of indexed metric values.
///
/// A per-run `salt` (the metric's checksum contribution — see
/// `metrics::engine::MetricId::checksum_salt`) is folded into every
/// item hash, so equal value multisets computed under *different*
/// metrics can never produce colliding checksums. Equality compares
/// only the accumulated (sum, count): a merged checksum matches an
/// oracle built with the same salt regardless of which instance the
/// salt was set on.
#[derive(Debug, Clone, Copy, Default)]
pub struct Checksum {
    /// 128-bit wrapping sum of item hashes.
    pub sum: u128,
    /// Item count (guards against silently missing values).
    pub count: u64,
    /// Hash salt applied to items added *through this instance*.
    salt: u64,
}

impl PartialEq for Checksum {
    fn eq(&self, other: &Self) -> bool {
        (self.sum, self.count) == (other.sum, other.count)
    }
}

impl Eq for Checksum {}

impl Checksum {
    pub fn new() -> Self {
        Self::default()
    }

    /// A checksum whose item hashes are salted (salt 0 ≡ [`Self::new`],
    /// hash-compatible with the unsalted historical digests).
    pub fn with_salt(salt: u64) -> Self {
        Checksum { salt, ..Self::default() }
    }

    fn add_item(&mut self, h: u128) {
        self.sum = self.sum.wrapping_add(h);
        self.count += 1;
    }

    /// Add a 2-way metric value for global pair (i, j), i < j.
    pub fn add_pair(&mut self, i: usize, j: usize, value: f64) {
        debug_assert!(i < j);
        let hi = mix64(mix64(i as u64) ^ mix64((j as u64) << 1) ^ self.salt);
        let hv = mix64(value.to_bits());
        self.add_item(((hi as u128) << 64) | hv as u128);
    }

    /// Add a 3-way metric value for global triple (i, j, k), i < j < k.
    pub fn add_triple(&mut self, i: usize, j: usize, k: usize, value: f64) {
        debug_assert!(i < j && j < k);
        let hi =
            mix64(mix64(i as u64) ^ mix64((j as u64) << 1) ^ mix64((k as u64) << 2) ^ self.salt);
        let hv = mix64(value.to_bits());
        self.add_item(((hi as u128) << 64) | hv as u128);
    }

    /// Merge a partial checksum from another node (commutative).
    pub fn merge(&mut self, other: Checksum) {
        self.sum = self.sum.wrapping_add(other.sum);
        self.count += other.count;
    }

    /// Short printable digest.
    pub fn digest(&self) -> String {
        format!("{:032x}:{}", self.sum, self.count)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn order_independent() {
        let mut a = Checksum::new();
        a.add_pair(0, 1, 0.5);
        a.add_pair(2, 3, 0.25);
        a.add_pair(1, 7, 0.125);
        let mut b = Checksum::new();
        b.add_pair(1, 7, 0.125);
        b.add_pair(0, 1, 0.5);
        b.add_pair(2, 3, 0.25);
        assert_eq!(a, b);
    }

    #[test]
    fn merge_equals_sequential() {
        let mut whole = Checksum::new();
        whole.add_pair(0, 1, 0.5);
        whole.add_pair(0, 2, 0.75);
        let mut p1 = Checksum::new();
        p1.add_pair(0, 1, 0.5);
        let mut p2 = Checksum::new();
        p2.add_pair(0, 2, 0.75);
        p1.merge(p2);
        assert_eq!(whole, p1);
    }

    #[test]
    fn value_sensitivity() {
        let mut a = Checksum::new();
        a.add_pair(0, 1, 0.5);
        let mut b = Checksum::new();
        b.add_pair(0, 1, 0.5 + f64::EPSILON);
        assert_ne!(a, b, "single-ulp changes must be detected");
    }

    #[test]
    fn index_sensitivity() {
        let mut a = Checksum::new();
        a.add_pair(0, 1, 0.5);
        let mut b = Checksum::new();
        b.add_pair(0, 2, 0.5);
        assert_ne!(a, b);
        // Swapped roles across pair/triple must differ too.
        let mut c = Checksum::new();
        c.add_triple(0, 1, 2, 0.5);
        assert_ne!(a.sum, c.sum);
    }

    #[test]
    fn salt_separates_metrics_but_not_equal_runs() {
        // Same items, same salt → equal (even if one side was merged
        // into an unsalted accumulator).
        let mut a = Checksum::with_salt(7);
        a.add_pair(0, 1, 0.5);
        let mut merged = Checksum::new();
        merged.merge(a);
        assert_eq!(a, merged);
        // Same items, different salt → different checksum.
        let mut b = Checksum::with_salt(8);
        b.add_pair(0, 1, 0.5);
        assert_ne!(a, b);
        // Salt 0 is hash-compatible with the historical unsalted form.
        let mut c = Checksum::with_salt(0);
        c.add_pair(0, 1, 0.5);
        let mut d = Checksum::new();
        d.add_pair(0, 1, 0.5);
        assert_eq!(c, d);
    }

    #[test]
    fn count_detects_missing_values() {
        let mut a = Checksum::new();
        a.add_pair(0, 1, 0.0);
        let b = Checksum::new();
        assert_ne!(a, b); // even a zero-hash-sum style collision is caught by count
        assert_eq!(a.count, 1);
    }

    #[test]
    fn triple_order_canonicalization_is_callers_job() {
        // Same canonical triple -> same checksum regardless of when added.
        let mut a = Checksum::new();
        a.add_triple(1, 2, 3, 0.5);
        a.add_triple(4, 5, 6, 0.5);
        let mut b = Checksum::new();
        b.add_triple(4, 5, 6, 0.5);
        b.add_triple(1, 2, 3, 0.5);
        assert_eq!(a, b);
    }
}
