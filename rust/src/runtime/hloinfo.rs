//! HLO-text analysis: op histograms and fusion statistics for the AOT
//! artifacts — the Layer-2 profiling hook (DESIGN.md §6: "HLO cost
//! analysis on the lowered module"). Used by `comet artifacts --analyze`
//! and the §Perf workflow to verify that a lowering change did what it
//! claimed (fusion counts, loop counts, elementwise-op mix).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

/// Parsed summary of one HLO module.
#[derive(Debug, Clone, Default)]
pub struct HloSummary {
    /// Module name from the `HloModule` header.
    pub module: String,
    /// Instruction count per opcode.
    pub op_counts: BTreeMap<String, usize>,
    /// Number of computations (fusion bodies, loop bodies, …).
    pub computations: usize,
    /// Total instruction count.
    pub instructions: usize,
}

impl HloSummary {
    pub fn count(&self, op: &str) -> usize {
        self.op_counts.get(op).copied().unwrap_or(0)
    }

    /// Ops that indicate the accumulation structure we care about.
    pub fn loops(&self) -> usize {
        self.count("while")
    }

    pub fn fusions(&self) -> usize {
        self.count("fusion")
    }
}

/// Parse HLO text into a summary. The text grammar (one instruction per
/// line, `%name = type opcode(args)`) is stable across the XLA versions
/// we target; unknown lines are skipped.
pub fn parse(text: &str) -> HloSummary {
    let mut s = HloSummary::default();
    for line in text.lines() {
        let trimmed = line.trim();
        if let Some(rest) = trimmed.strip_prefix("HloModule ") {
            s.module = rest
                .split(|c: char| c == ',' || c.is_whitespace())
                .next()
                .unwrap_or("")
                .to_string();
            continue;
        }
        // Computation headers: `region_2.1 {`, `ENTRY main.42 {`.
        if trimmed.ends_with('{') && !trimmed.starts_with("HloModule") {
            s.computations += 1;
            continue;
        }
        // Instruction lines: `name.id = shape opcode(args)`, optionally
        // prefixed with ROOT (both `%name` and bare-name HLO dialects).
        let body = trimmed.strip_prefix("ROOT ").unwrap_or(trimmed);
        let Some(eq) = body.find(" = ") else { continue };
        let lhs = body[..eq].trim();
        if lhs.is_empty() || lhs.contains(' ') {
            continue;
        }
        let rhs = body[eq + 3..].trim();
        // rhs = "f32[128,128]{1,0} minimum(...)" — opcode is the first
        // token after the shape.
        let mut tokens = rhs.split_whitespace();
        let Some(first) = tokens.next() else { continue };
        // Tuple shapes contain spaces: `(s32[], f32[2,2]{1,0})` — consume
        // tokens until the closing paren before reading the opcode.
        if first.starts_with('(') && !first.ends_with(')') {
            for t in tokens.by_ref() {
                if t.ends_with(')') {
                    break;
                }
            }
        }
        let Some(op_tok) = tokens.next() else { continue };
        let opcode: String = op_tok
            .chars()
            .take_while(|c| c.is_ascii_alphanumeric() || *c == '-' || *c == '_')
            .collect();
        if opcode.is_empty() {
            continue;
        }
        *s.op_counts.entry(opcode).or_insert(0) += 1;
        s.instructions += 1;
    }
    s
}

/// Parse an artifact file.
pub fn parse_file(path: &Path) -> Result<HloSummary> {
    let text =
        std::fs::read_to_string(path).with_context(|| format!("read {}", path.display()))?;
    Ok(parse(&text))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
HloModule jit_fn, entry_computation_layout={...}

body.1 {
  p.1 = (s32[], f32[128,128]) parameter(0)
  i.1 = s32[] get-tuple-element(p.1), index=0
  one = s32[] constant(1)
  next = s32[] add(i.1, one)
  ROOT out = (s32[], f32[128,128]) tuple(next, acc)
}

ENTRY main.9 {
  a = f32[384,128]{1,0} parameter(0)
  b = f32[384,128]{1,0} parameter(1)
  m = f32[384,128,128]{2,1,0} minimum(ba, bb)
  w = (s32[], f32[128,128]) while(init), condition=c, body=body.1
  ROOT t = (f32[128,128]) tuple(r)
}
";

    #[test]
    fn parses_module_name() {
        let s = parse(SAMPLE);
        assert_eq!(s.module, "jit_fn");
    }

    #[test]
    fn counts_opcodes() {
        let s = parse(SAMPLE);
        assert_eq!(s.count("parameter"), 3);
        assert_eq!(s.count("minimum"), 1);
        assert_eq!(s.count("while"), 1);
        assert_eq!(s.count("add"), 1);
        assert_eq!(s.count("tuple"), 2, "tuple-shaped results must parse");
        assert_eq!(s.loops(), 1);
        assert!(s.instructions >= 8);
    }

    #[test]
    fn computation_count() {
        let s = parse(SAMPLE);
        assert!(s.computations >= 2, "{}", s.computations); // %body + ENTRY
    }

    #[test]
    fn real_artifacts_parse_when_built() {
        // Opportunistic: analyze the real manifest if artifacts exist.
        let dir = Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"));
        if !dir.join("manifest.txt").exists() {
            return;
        }
        let m = crate::runtime::Manifest::load(dir).unwrap();
        let entry = m.entries.iter().find(|e| e.kind == "mgemm2").unwrap();
        let s = parse_file(&m.dir.join(&entry.file)).unwrap();
        assert!(s.instructions > 10);
        // The tiled lowering is loop-structured with a min inside.
        assert!(s.loops() >= 1, "expected while loops, got ops {:?}", s.op_counts);
        assert!(s.count("minimum") >= 1);
    }
}
