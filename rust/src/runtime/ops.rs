//! Typed block operations over the PJRT service: padding, literal
//! packing, output slicing. These run on the node threads (cheap CPU
//! work); only the execute itself serializes through the service.
//!
//! Padding contract (DESIGN.md): artifacts are shape-specialized; blocks
//! are zero-padded up to the artifact tier. Zero features contribute
//! nothing to a min-product over non-negative data, and padded vector
//! columns produce output rows/columns that are sliced off here.

use anyhow::{ensure, Result};

use crate::config::Precision;
use crate::linalg::{MatF64, SlabF64};
use crate::runtime::{ArtifactEntry, ElemKind, InputBuf, RuntimeClient};
use crate::util::Scalar;
use crate::vecdata::VectorSet;

/// Numerator kernel families the metric engine dispatches over. Each
/// family names the artifact kind its accelerator lowering carries in
/// the manifest, so artifact selection is keyed by the metric (via
/// `Metric::numerators*` → `Backend` → here), not hard-coded per call
/// site.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelFamily {
    /// Min-product mGEMM, 2-way block (Czekanowski numerators).
    MinProduct2,
    /// Min-product 3-way slab (Czekanowski n3' numerators).
    MinProduct3,
    /// True GEMM, 2-way block (CCC numerators).
    Dot2,
    /// AND+popcount over packed u32 words (bit-packed Sorensen).
    BitAnd2,
}

impl KernelFamily {
    /// Default artifact kind of this family ("mgemm2pallas"-style
    /// overrides stay available through `PjrtBackend::with_kinds`).
    pub fn artifact_kind(self) -> &'static str {
        match self {
            KernelFamily::MinProduct2 => "mgemm2",
            KernelFamily::MinProduct3 => "mgemm3",
            KernelFamily::Dot2 => "gemm",
            KernelFamily::BitAnd2 => "sorenson2",
        }
    }
}

/// Block-level accelerator operations at a fixed precision.
#[derive(Clone)]
pub struct BlockOps {
    pub client: RuntimeClient,
    pub precision: Precision,
}

fn precision_of<T: Scalar>() -> Precision {
    match T::BYTES {
        4 => Precision::F32,
        8 => Precision::F64,
        _ => unreachable!("Scalar is f32 or f64"),
    }
}

fn to_bytes<T: Scalar>(v: &[T]) -> Vec<u8> {
    let mut out = vec![0u8; std::mem::size_of_val(v)];
    unsafe {
        std::ptr::copy_nonoverlapping(v.as_ptr() as *const u8, out.as_mut_ptr(), out.len());
    }
    out
}

impl BlockOps {
    pub fn new(client: RuntimeClient, precision: Precision) -> Self {
        BlockOps { client, precision }
    }

    fn input<T: Scalar>(&self, set: &VectorSet<T>, nf_pad: usize, nv_pad: usize) -> InputBuf {
        let padded = set.to_rowmajor_padded(nf_pad, nv_pad);
        InputBuf {
            dims: vec![nf_pad, nv_pad],
            bytes: to_bytes(&padded),
            precision: self.precision.into(),
        }
    }

    fn pick(&self, kind: &str, nf: usize, nv: usize) -> Result<ArtifactEntry> {
        Ok(self
            .client
            .manifest()
            .select(kind, self.precision, nf, nv)?
            .clone())
    }

    /// Largest artifact tier of a kind (the tiling unit when a block
    /// exceeds every tier).
    fn largest(&self, kind: &str) -> Result<ArtifactEntry> {
        self.client
            .manifest()
            .entries
            .iter()
            .filter(|e| {
                e.kind == kind
                    && e.precision == ElemKind::from(self.precision)
                    && self.client.manifest().dir.join(&e.file).exists()
            })
            .max_by_key(|e| (e.nf, e.nv))
            .cloned()
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "no {kind} artifacts built for {} — run `make artifacts`",
                    self.precision.tag()
                )
            })
    }

    /// N = W^T ∘min V through an mGEMM artifact (`kind` selects the
    /// lowering: "mgemm2", "mgemm2pallas", "mgemm2ternary", "gemm", …).
    ///
    /// Blocks larger than every artifact tier are tiled over the largest
    /// tier — feature panels accumulate (Σ_q is additive over feature
    /// chunks) and vector panels concatenate — the "mGEMM broken into
    /// blocks" pipeline of paper §3.1.
    pub fn mgemm2<T: Scalar>(
        &self,
        kind: &str,
        w: &VectorSet<T>,
        v: &VectorSet<T>,
    ) -> Result<MatF64> {
        ensure!(precision_of::<T>() == self.precision, "precision mismatch");
        ensure!(w.nf == v.nf, "feature depth mismatch");
        if self
            .client
            .manifest()
            .select(kind, self.precision, w.nf, w.nv.max(v.nv))
            .is_err()
        {
            return self.mgemm2_tiled(kind, w, v);
        }
        let entry = self.pick(kind, w.nf, w.nv.max(v.nv))?;
        let inputs = vec![
            self.input(w, entry.nf, entry.nv),
            self.input(v, entry.nf, entry.nv),
        ];
        let out = self.client.execute(&entry.name, inputs)?;
        ensure!(out.len() == 1, "{kind}: want 1 output, got {}", out.len());
        ensure!(
            out[0].dims == vec![entry.nv, entry.nv],
            "{kind}: bad output dims {:?}",
            out[0].dims
        );
        // Slice the padded [entry.nv, entry.nv] down to [w.nv, v.nv].
        let mut mat = MatF64::zeros(w.nv, v.nv);
        for i in 0..w.nv {
            let row = &out[0].values[i * entry.nv..i * entry.nv + v.nv];
            mat.data[i * v.nv..(i + 1) * v.nv].copy_from_slice(row);
        }
        Ok(mat)
    }

    /// As [`Self::mgemm2`] but against one specific artifact by name
    /// (kernel benches / lowering sweeps).
    pub fn mgemm2_named<T: Scalar>(
        &self,
        name: &str,
        w: &VectorSet<T>,
        v: &VectorSet<T>,
    ) -> Result<MatF64> {
        ensure!(precision_of::<T>() == self.precision, "precision mismatch");
        let entry = self
            .client
            .manifest()
            .entries
            .iter()
            .find(|e| e.name == name)
            .ok_or_else(|| anyhow::anyhow!("unknown artifact {name:?}"))?
            .clone();
        ensure!(entry.nf >= w.nf && entry.nv >= w.nv.max(v.nv), "block exceeds {name}");
        let inputs = vec![
            self.input(w, entry.nf, entry.nv),
            self.input(v, entry.nf, entry.nv),
        ];
        let out = self.client.execute(&entry.name, inputs)?;
        ensure!(out.len() == 1 && out[0].dims == vec![entry.nv, entry.nv]);
        let mut mat = MatF64::zeros(w.nv, v.nv);
        for i in 0..w.nv {
            let row = &out[0].values[i * entry.nv..i * entry.nv + v.nv];
            mat.data[i * v.nv..(i + 1) * v.nv].copy_from_slice(row);
        }
        Ok(mat)
    }

    /// Tiled mGEMM2 over the largest artifact tier (see [`Self::mgemm2`]).
    fn mgemm2_tiled<T: Scalar>(
        &self,
        kind: &str,
        w: &VectorSet<T>,
        v: &VectorSet<T>,
    ) -> Result<MatF64> {
        let tier = self.largest(kind)?;
        let (tf, tv) = (tier.nf, tier.nv);
        let mut out = MatF64::zeros(w.nv, v.nv);
        let mut f0 = 0;
        while f0 < w.nf {
            let flen = tf.min(w.nf - f0);
            let wf = w.feature_slice(f0, flen);
            let vf = v.feature_slice(f0, flen);
            for i0 in (0..w.nv).step_by(tv) {
                let ilen = tv.min(w.nv - i0);
                let wi = wf.select_cols(&(i0..i0 + ilen).collect::<Vec<_>>());
                for j0 in (0..v.nv).step_by(tv) {
                    let jlen = tv.min(v.nv - j0);
                    let vj = vf.select_cols(&(j0..j0 + jlen).collect::<Vec<_>>());
                    let part = self.mgemm2(kind, &wi, &vj)?;
                    for i in 0..ilen {
                        for j in 0..jlen {
                            out.data[(i0 + i) * v.nv + (j0 + j)] += part.at(i, j);
                        }
                    }
                }
            }
            f0 += flen;
        }
        Ok(out)
    }

    /// 3-way slab B[t, i, k] = Σ_q min(pivot_t, w_i, v_k) via an
    /// "mgemm3"-kind artifact. `pivots.nv` ≤ the artifact's jt tier.
    pub fn mgemm3<T: Scalar>(
        &self,
        kind: &str,
        w: &VectorSet<T>,
        pivots: &VectorSet<T>,
        v: &VectorSet<T>,
    ) -> Result<SlabF64> {
        ensure!(precision_of::<T>() == self.precision, "precision mismatch");
        ensure!(w.nf == v.nf && w.nf == pivots.nf, "feature depth mismatch");
        let manifest = self.client.manifest();
        let fits = manifest.entries.iter().any(|e| {
            e.kind == kind
                && e.precision == ElemKind::from(self.precision)
                && e.nf >= w.nf
                && e.nv >= w.nv.max(v.nv)
                && e.jt >= pivots.nv
                && manifest.dir.join(&e.file).exists()
        });
        if !fits {
            return self.mgemm3_tiled(kind, w, pivots, v);
        }
        let entry = manifest
            .entries
            .iter()
            .filter(|e| {
                e.kind == kind
                    && e.precision == ElemKind::from(self.precision)
                    && e.nf >= w.nf
                    && e.nv >= w.nv.max(v.nv)
                    && e.jt >= pivots.nv
                    && manifest.dir.join(&e.file).exists()
            })
            .min_by_key(|e| (e.nf, e.nv, e.jt))
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "no {kind} artifact for nf={} nv={} jt={} at {} — run `make artifacts`",
                    w.nf,
                    w.nv.max(v.nv),
                    pivots.nv,
                    self.precision.tag()
                )
            })?
            .clone();
        let inputs = vec![
            self.input(w, entry.nf, entry.nv),
            InputBuf {
                dims: vec![entry.nf, entry.jt],
                bytes: to_bytes(&pivots.to_rowmajor_padded(entry.nf, entry.jt)),
                precision: self.precision.into(),
            },
            self.input(v, entry.nf, entry.nv),
        ];
        let out = self.client.execute(&entry.name, inputs)?;
        ensure!(out.len() == 1, "{kind}: want 1 output, got {}", out.len());
        ensure!(
            out[0].dims == vec![entry.jt, entry.nv, entry.nv],
            "{kind}: bad output dims {:?}",
            out[0].dims
        );
        let mut slab = SlabF64::zeros(pivots.nv, w.nv, v.nv);
        for t in 0..pivots.nv {
            for i in 0..w.nv {
                let base = (t * entry.nv + i) * entry.nv;
                let row = &out[0].values[base..base + v.nv];
                let dst = (t * w.nv + i) * v.nv;
                slab.data[dst..dst + v.nv].copy_from_slice(row);
            }
        }
        Ok(slab)
    }

    /// Tiled 3-way slab over the largest artifact tier: pivot chunks by
    /// the tier's jt, vector panels by its nv, feature panels accumulate.
    fn mgemm3_tiled<T: Scalar>(
        &self,
        kind: &str,
        w: &VectorSet<T>,
        pivots: &VectorSet<T>,
        v: &VectorSet<T>,
    ) -> Result<SlabF64> {
        let tier = self.largest(kind)?;
        let (tf, tv, tj) = (tier.nf, tier.nv, tier.jt.max(1));
        let mut out = SlabF64::zeros(pivots.nv, w.nv, v.nv);
        let mut f0 = 0;
        while f0 < w.nf {
            let flen = tf.min(w.nf - f0);
            let wf = w.feature_slice(f0, flen);
            let pf = pivots.feature_slice(f0, flen);
            let vf = v.feature_slice(f0, flen);
            for t0 in (0..pivots.nv).step_by(tj) {
                let tlen = tj.min(pivots.nv - t0);
                let pt = pf.select_cols(&(t0..t0 + tlen).collect::<Vec<_>>());
                for i0 in (0..w.nv).step_by(tv) {
                    let ilen = tv.min(w.nv - i0);
                    let wi = wf.select_cols(&(i0..i0 + ilen).collect::<Vec<_>>());
                    for k0 in (0..v.nv).step_by(tv) {
                        let klen = tv.min(v.nv - k0);
                        let vk = vf.select_cols(&(k0..k0 + klen).collect::<Vec<_>>());
                        let part = self.mgemm3(kind, &wi, &pt, &vk)?;
                        for t in 0..tlen {
                            for i in 0..ilen {
                                for k in 0..klen {
                                    let idx = ((t0 + t) * w.nv + i0 + i) * v.nv + k0 + k;
                                    out.data[idx] += part.at(t, i, k);
                                }
                            }
                        }
                    }
                }
            }
            f0 += flen;
        }
        Ok(out)
    }

    /// Bitwise Sorenson numerators (§2.3): N[i, j] = popcount(b_i & b_j)
    /// through a packed-uint32 artifact ("sorenson2" or
    /// "sorenson2pallas"). Zero-padding words/columns is exact (AND with
    /// 0 contributes no bits).
    pub fn sorenson2(
        &self,
        kind: &str,
        w: &crate::vecdata::bits::BitVectorSet,
        v: &crate::vecdata::bits::BitVectorSet,
    ) -> Result<MatF64> {
        ensure!(w.nf == v.nf, "feature depth mismatch");
        let manifest = self.client.manifest();
        let entry = manifest
            .entries
            .iter()
            .filter(|e| {
                e.kind == kind
                    && e.precision == ElemKind::U32
                    && e.nf >= w.nf
                    && e.nv >= w.nv.max(v.nv)
                    && manifest.dir.join(&e.file).exists()
            })
            .min_by_key(|e| (e.nf, e.nv))
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "no {kind} artifact for nf={} nv={} — run `make artifacts`",
                    w.nf,
                    w.nv.max(v.nv)
                )
            })?
            .clone();
        let nw_pad = entry.nf / 32; // artifact word depth
        // Popcount audit: unlike the native path (which popcounts on
        // the host and now sweeps `linalg::simd::and_popcount` lanes),
        // this op never popcounts host-side — the AND+popcount runs
        // inside the artifact over u32 words. The only per-word host
        // loop is this layout shuffle: u64 words split into u32 halves
        // (`linalg::simd::word_halves`) and scattered column-major for
        // the artifact's [nw_pad, nv] operand shape.
        let pack = |set: &crate::vecdata::bits::BitVectorSet| -> InputBuf {
            // u64 words -> row-major padded [nw_pad, entry.nv] of u32.
            let mut data = vec![0u32; nw_pad * entry.nv];
            for col in 0..set.nv {
                for (wi, &word) in set.words(col).iter().enumerate() {
                    let (lo, hi) = crate::linalg::simd::word_halves(word);
                    if 2 * wi < nw_pad {
                        data[(2 * wi) * entry.nv + col] = lo;
                    }
                    if 2 * wi + 1 < nw_pad {
                        data[(2 * wi + 1) * entry.nv + col] = hi;
                    }
                }
            }
            let mut bytes = vec![0u8; data.len() * 4];
            for (i, x) in data.iter().enumerate() {
                bytes[i * 4..(i + 1) * 4].copy_from_slice(&x.to_le_bytes());
            }
            InputBuf {
                dims: vec![nw_pad, entry.nv],
                bytes,
                precision: ElemKind::U32,
            }
        };
        // Any u32 word beyond nw_pad holds only bits ≥ entry.nf ≥ n_f,
        // which are never set (tail bits stay clear) — safe to drop.
        let out = self.client.execute(&entry.name, vec![pack(w), pack(v)])?;
        ensure!(out.len() == 1 && out[0].dims == vec![entry.nv, entry.nv]);
        let mut mat = MatF64::zeros(w.nv, v.nv);
        for i in 0..w.nv {
            let row = &out[0].values[i * entry.nv..i * entry.nv + v.nv];
            mat.data[i * v.nv..(i + 1) * v.nv].copy_from_slice(row);
        }
        Ok(mat)
    }

    /// Column sums via the "rowsum" artifact (the denominator offload —
    /// normally done natively, exposed for artifact validation).
    pub fn rowsum<T: Scalar>(&self, v: &VectorSet<T>) -> Result<Vec<f64>> {
        ensure!(precision_of::<T>() == self.precision, "precision mismatch");
        let entry = self.pick("rowsum", v.nf, v.nv)?;
        let inputs = vec![self.input(v, entry.nf, entry.nv)];
        let out = self.client.execute(&entry.name, inputs)?;
        ensure!(out.len() == 1 && out[0].dims == vec![entry.nv]);
        Ok(out[0].values[..v.nv].to_vec())
    }
}

#[cfg(test)]
mod tests {
    // Execution tests live in rust/tests/runtime_pjrt.rs (they need the
    // built artifacts); here we only test the pure packing helpers.
    use super::*;

    #[test]
    fn to_bytes_le_layout() {
        let b = to_bytes(&[1.0f32, 2.0f32]);
        assert_eq!(b.len(), 8);
        assert_eq!(&b[0..4], &1.0f32.to_le_bytes());
        assert_eq!(&b[4..8], &2.0f32.to_le_bytes());
    }

    #[test]
    fn precision_of_widths() {
        assert_eq!(precision_of::<f32>(), Precision::F32);
        assert_eq!(precision_of::<f64>(), Precision::F64);
    }

    #[test]
    fn kernel_families_name_manifest_kinds() {
        assert_eq!(KernelFamily::MinProduct2.artifact_kind(), "mgemm2");
        assert_eq!(KernelFamily::MinProduct3.artifact_kind(), "mgemm3");
        assert_eq!(KernelFamily::Dot2.artifact_kind(), "gemm");
        assert_eq!(KernelFamily::BitAnd2.artifact_kind(), "sorenson2");
    }
}
