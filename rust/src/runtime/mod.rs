//! The PJRT runtime: loads AOT HLO-text artifacts and executes them —
//! the Rust side of the accelerator boundary.
//!
//! Pattern (from /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. HLO *text* is the interchange format
//! (xla_extension 0.5.1 rejects jax ≥ 0.5 serialized protos; see
//! python/compile/aot.py).
//!
//! The `xla` crate's handles wrap raw C pointers and are not `Send`, so
//! a single **service thread** owns the client and the compiled-
//! executable cache; virtual-node threads submit [`ExecRequest`]s over a
//! channel and block on a reply. This mirrors the paper's topology — one
//! accelerator shared per node, kernels serialized on its stream — and
//! on this one-core testbed sacrifices nothing.

pub mod hloinfo;
pub mod ops;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::{anyhow, bail, Context, Result};

use crate::config::Precision;

/// Element type of an artifact's inputs/outputs. Superset of the run
/// [`Precision`]: the bitwise Sorenson path (§2.3) moves packed uint32
/// words across the accelerator boundary.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElemKind {
    F32,
    F64,
    U32,
}

impl ElemKind {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "f32" => Ok(ElemKind::F32),
            "f64" => Ok(ElemKind::F64),
            "u32" => Ok(ElemKind::U32),
            other => bail!("unknown element kind {other:?} (want f32|f64|u32)"),
        }
    }
    pub fn tag(self) -> &'static str {
        match self {
            ElemKind::F32 => "f32",
            ElemKind::F64 => "f64",
            ElemKind::U32 => "u32",
        }
    }
    pub fn bytes(self) -> usize {
        match self {
            ElemKind::F32 | ElemKind::U32 => 4,
            ElemKind::F64 => 8,
        }
    }
    fn xla(self) -> xla::ElementType {
        match self {
            ElemKind::F32 => xla::ElementType::F32,
            ElemKind::F64 => xla::ElementType::F64,
            ElemKind::U32 => xla::ElementType::U32,
        }
    }
}

impl From<Precision> for ElemKind {
    fn from(p: Precision) -> Self {
        match p {
            Precision::F32 => ElemKind::F32,
            Precision::F64 => ElemKind::F64,
        }
    }
}

/// One artifact from the manifest.
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub name: String,
    pub kind: String,
    pub precision: ElemKind,
    pub nf: usize,
    pub nv: usize,
    pub jt: usize,
    pub file: String,
}

/// Parsed `artifacts/manifest.txt`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: Vec<ArtifactEntry>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!(
                "read {} — run `make artifacts` to build the AOT artifacts",
                path.display()
            )
        })?;
        let mut entries = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let cols: Vec<&str> = line.split_whitespace().collect();
            if cols.len() != 7 {
                bail!("{}:{}: want 7 columns, got {}", path.display(), lineno + 1, cols.len());
            }
            entries.push(ArtifactEntry {
                name: cols[0].to_string(),
                kind: cols[1].to_string(),
                precision: ElemKind::parse(cols[2])?,
                nf: cols[3].parse().context("nf")?,
                nv: cols[4].parse().context("nv")?,
                jt: cols[5].parse().context("jt")?,
                file: cols[6].to_string(),
            });
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            entries,
        })
    }

    /// Smallest artifact of `kind`/`precision` that fits an (nf, nv)
    /// block (inputs are zero-padded up to the artifact's tier shape).
    pub fn select(
        &self,
        kind: &str,
        precision: impl Into<ElemKind>,
        nf: usize,
        nv: usize,
    ) -> Result<&ArtifactEntry> {
        let precision: ElemKind = precision.into();
        self.entries
            .iter()
            .filter(|e| {
                e.kind == kind
                    && e.precision == precision
                    && e.nf >= nf
                    && e.nv >= nv
                    && self.dir.join(&e.file).exists()
            })
            .min_by_key(|e| (e.nf, e.nv))
            .ok_or_else(|| {
                anyhow!(
                    "no artifact kind={kind} precision={} covering nf={nf}, nv={nv}; \
                     built tiers: {:?} — adjust block size or add a tier in \
                     python/compile/aot.py",
                    precision.tag(),
                    self.entries
                        .iter()
                        .filter(|e| e.kind == kind && e.precision == precision)
                        .map(|e| (e.nf, e.nv))
                        .collect::<Vec<_>>()
                )
            })
    }
}

/// One raw input buffer for an execution: dims + row-major bytes.
pub struct InputBuf {
    pub dims: Vec<usize>,
    pub bytes: Vec<u8>,
    pub precision: ElemKind,
}

/// One output tensor: dims + values widened to f64.
#[derive(Debug, Clone)]
pub struct OutputBuf {
    pub dims: Vec<usize>,
    pub values: Vec<f64>,
}

/// A request to the service thread.
struct ExecRequest {
    artifact: String,
    inputs: Vec<InputBuf>,
    reply: Sender<Result<Vec<OutputBuf>>>,
}

enum Msg {
    Exec(ExecRequest),
    /// Compile (warm the cache) without executing.
    Warm(String, Sender<Result<()>>),
    Quit,
}

/// Shared handle to the PJRT service. Cheap to clone; all methods are
/// callable from any thread.
#[derive(Clone)]
pub struct RuntimeClient {
    tx: Sender<Msg>,
    manifest: Arc<Manifest>,
    /// Cumulative executions + accelerator-side wall time (profiling).
    stats: Arc<RuntimeStats>,
}

#[derive(Debug, Default)]
pub struct RuntimeStats {
    pub executions: std::sync::atomic::AtomicU64,
    pub exec_nanos: std::sync::atomic::AtomicU64,
    /// Actual artifact compilations (executable-cache misses). Flat
    /// across repeated session runs — the cache-reuse signal the batch
    /// driver reports.
    pub compiles: std::sync::atomic::AtomicU64,
    /// Executable-cache hits (artifact already compiled and resident).
    pub exec_hits: std::sync::atomic::AtomicU64,
    /// Executables dropped to stay under the service's slot budget
    /// ([`PjrtService::start_with_limits`]); a re-used evicted artifact
    /// recompiles (another `compiles` tick).
    pub exec_evictions: std::sync::atomic::AtomicU64,
}

impl RuntimeClient {
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn stats(&self) -> (u64, f64) {
        use std::sync::atomic::Ordering::Relaxed;
        (
            self.stats.executions.load(Relaxed),
            self.stats.exec_nanos.load(Relaxed) as f64 * 1e-9,
        )
    }

    /// Artifact compilations so far (executable-cache misses; repeat
    /// executions of a cached artifact do not count).
    pub fn compiles(&self) -> u64 {
        self.stats
            .compiles
            .load(std::sync::atomic::Ordering::Relaxed)
    }

    /// Executable-cache pressure: (compiles, hits, evictions).
    pub fn exec_cache_stats(&self) -> (u64, u64, u64) {
        use std::sync::atomic::Ordering::Relaxed;
        (
            self.stats.compiles.load(Relaxed),
            self.stats.exec_hits.load(Relaxed),
            self.stats.exec_evictions.load(Relaxed),
        )
    }

    /// Execute an artifact by name. Blocks until the service replies.
    pub fn execute(&self, artifact: &str, inputs: Vec<InputBuf>) -> Result<Vec<OutputBuf>> {
        let (reply, rx) = channel();
        self.tx
            .send(Msg::Exec(ExecRequest {
                artifact: artifact.to_string(),
                inputs,
                reply,
            }))
            .map_err(|_| anyhow!("PJRT service thread is gone"))?;
        rx.recv().map_err(|_| anyhow!("PJRT service dropped reply"))?
    }

    /// Pre-compile an artifact (pipeline warmup).
    pub fn warm(&self, artifact: &str) -> Result<()> {
        let (reply, rx) = channel();
        self.tx
            .send(Msg::Warm(artifact.to_string(), reply))
            .map_err(|_| anyhow!("PJRT service thread is gone"))?;
        rx.recv().map_err(|_| anyhow!("PJRT service dropped reply"))?
    }
}

/// The owning service: spawns the thread; dropping shuts it down.
pub struct PjrtService {
    tx: Sender<Msg>,
    join: Option<JoinHandle<()>>,
    manifest: Arc<Manifest>,
    stats: Arc<RuntimeStats>,
}

impl PjrtService {
    /// Start the service over an artifact directory (unbounded
    /// executable cache — the pre-serving behavior).
    pub fn start(artifact_dir: &Path) -> Result<PjrtService> {
        Self::start_with_limits(artifact_dir, None)
    }

    /// Start the service with an executable-cache slot budget: past
    /// `exec_slots` compiled artifacts, the least-recently-executed
    /// one is dropped (a budget of 0 behaves as 1 — the executing
    /// artifact always stays resident).
    pub fn start_with_limits(
        artifact_dir: &Path,
        exec_slots: Option<usize>,
    ) -> Result<PjrtService> {
        let manifest = Arc::new(Manifest::load(artifact_dir)?);
        let stats = Arc::new(RuntimeStats::default());
        let (tx, rx) = channel();
        let m = Arc::clone(&manifest);
        let s = Arc::clone(&stats);
        let join = std::thread::Builder::new()
            .name("pjrt-service".into())
            .spawn(move || service_main(rx, m, s, exec_slots))
            .context("spawn pjrt service")?;
        Ok(PjrtService {
            tx,
            join: Some(join),
            manifest,
            stats,
        })
    }

    pub fn client(&self) -> RuntimeClient {
        RuntimeClient {
            tx: self.tx.clone(),
            manifest: Arc::clone(&self.manifest),
            stats: Arc::clone(&self.stats),
        }
    }
}

impl Drop for PjrtService {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Quit);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// Recency bookkeeping for the executable cache: artifact names
/// ordered cold → hot. Pure over names (no PJRT types), so the policy
/// is unit-testable without a client.
#[derive(Default)]
struct LruOrder {
    order: std::collections::VecDeque<String>,
}

impl LruOrder {
    /// Mark `name` most-recently-used (inserting it if new).
    fn note_use(&mut self, name: &str) {
        if let Some(pos) = self.order.iter().position(|n| n == name) {
            if let Some(n) = self.order.remove(pos) {
                self.order.push_back(n);
            }
        } else {
            self.order.push_back(name.to_string());
        }
    }

    /// Pop the names to evict so at most `max(cap, 1)` entries remain
    /// — never the hottest (just-used) one, so a budget of 0 still
    /// keeps the executing artifact resident.
    fn evict_to(&mut self, cap: usize) -> Vec<String> {
        let keep = cap.max(1);
        let mut out = Vec::new();
        while self.order.len() > keep {
            out.push(self.order.pop_front().expect("len > keep >= 1"));
        }
        out
    }
}

fn service_main(
    rx: Receiver<Msg>,
    manifest: Arc<Manifest>,
    stats: Arc<RuntimeStats>,
    exec_slots: Option<usize>,
) {
    use std::sync::atomic::Ordering::Relaxed;
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => c,
        Err(e) => {
            // Poison all future requests with a clear message.
            let err = format!("PjRtClient::cpu failed: {e}");
            for msg in rx {
                match msg {
                    Msg::Exec(req) => {
                        let _ = req.reply.send(Err(anyhow!("{err}")));
                    }
                    Msg::Warm(_, reply) => {
                        let _ = reply.send(Err(anyhow!("{err}")));
                    }
                    Msg::Quit => break,
                }
            }
            return;
        }
    };
    let mut cache: HashMap<String, xla::PjRtLoadedExecutable> = HashMap::new();
    let mut lru = LruOrder::default();
    let compile = |cache: &mut HashMap<String, xla::PjRtLoadedExecutable>,
                   lru: &mut LruOrder,
                   name: &str|
     -> Result<()> {
        if cache.contains_key(name) {
            stats.exec_hits.fetch_add(1, Relaxed);
            lru.note_use(name);
            return Ok(());
        }
        let entry = manifest
            .entries
            .iter()
            .find(|e| e.name == name)
            .ok_or_else(|| anyhow!("unknown artifact {name:?}"))?;
        let path = manifest.dir.join(&entry.file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parse {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {name}: {e}"))?;
        stats.compiles.fetch_add(1, Relaxed);
        cache.insert(name.to_string(), exe);
        lru.note_use(name);
        if let Some(cap) = exec_slots {
            for victim in lru.evict_to(cap) {
                cache.remove(&victim);
                stats.exec_evictions.fetch_add(1, Relaxed);
            }
        }
        Ok(())
    };

    for msg in rx {
        match msg {
            Msg::Quit => break,
            Msg::Warm(name, reply) => {
                let _ = reply.send(compile(&mut cache, &mut lru, &name));
            }
            Msg::Exec(req) => {
                let result = (|| -> Result<Vec<OutputBuf>> {
                    compile(&mut cache, &mut lru, &req.artifact)?;
                    let exe = cache.get(&req.artifact).unwrap();
                    let literals: Vec<xla::Literal> = req
                        .inputs
                        .iter()
                        .map(|inp| {
                            let ty = inp.precision.xla();
                            xla::Literal::create_from_shape_and_untyped_data(
                                ty, &inp.dims, &inp.bytes,
                            )
                            .map_err(|e| anyhow!("literal: {e}"))
                        })
                        .collect::<Result<_>>()?;
                    let t0 = std::time::Instant::now();
                    let out = exe
                        .execute::<xla::Literal>(&literals)
                        .map_err(|e| anyhow!("execute {}: {e}", req.artifact))?;
                    let root = out[0][0]
                        .to_literal_sync()
                        .map_err(|e| anyhow!("fetch result: {e}"))?;
                    stats.executions.fetch_add(1, Relaxed);
                    stats
                        .exec_nanos
                        .fetch_add(t0.elapsed().as_nanos() as u64, Relaxed);
                    let parts = root
                        .to_tuple()
                        .map_err(|e| anyhow!("untuple: {e}"))?;
                    parts
                        .into_iter()
                        .map(|lit| {
                            let shape = lit
                                .array_shape()
                                .map_err(|e| anyhow!("shape: {e}"))?;
                            let dims: Vec<usize> =
                                shape.dims().iter().map(|&d| d as usize).collect();
                            let values = match lit.ty().map_err(|e| anyhow!("ty: {e}"))? {
                                xla::ElementType::F32 => lit
                                    .to_vec::<f32>()
                                    .map_err(|e| anyhow!("to_vec f32: {e}"))?
                                    .into_iter()
                                    .map(|x| x as f64)
                                    .collect(),
                                xla::ElementType::F64 => lit
                                    .to_vec::<f64>()
                                    .map_err(|e| anyhow!("to_vec f64: {e}"))?,
                                xla::ElementType::U32 => lit
                                    .to_vec::<u32>()
                                    .map_err(|e| anyhow!("to_vec u32: {e}"))?
                                    .into_iter()
                                    .map(|x| x as f64)
                                    .collect(),
                                other => bail!("unsupported output element type {other:?}"),
                            };
                            Ok(OutputBuf { dims, values })
                        })
                        .collect()
                })();
                let _ = req.reply.send(result);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parsing() {
        let dir = std::env::temp_dir().join(format!("comet-manifest-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.txt"),
            "# name kind dtype nf nv jt file\n\
             mgemm2_f32_s mgemm2 f32 384 128 0 mgemm2_f32_s.hlo.txt\n\
             mgemm2_f64_m mgemm2 f64 1536 256 0 mgemm2_f64_m.hlo.txt\n",
        )
        .unwrap();
        // Only the f32 artifact file "exists".
        std::fs::write(dir.join("mgemm2_f32_s.hlo.txt"), "HloModule x").unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.entries.len(), 2);
        let e = m.select("mgemm2", Precision::F32, 100, 100).unwrap();
        assert_eq!(e.name, "mgemm2_f32_s");
        // f64 file missing -> select must fail with a hint.
        let err = m.select("mgemm2", Precision::F64, 100, 100).unwrap_err();
        assert!(err.to_string().contains("make artifacts") || err.to_string().contains("tier"));
        // Block too large for any tier.
        assert!(m.select("mgemm2", Precision::F32, 9999, 128).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn lru_order_evicts_coldest_never_hottest() {
        let mut lru = LruOrder::default();
        for name in ["a", "b", "c"] {
            lru.note_use(name);
        }
        assert!(lru.evict_to(3).is_empty());
        // Re-using "a" rescues it; capacity 2 drops the coldest ("b").
        lru.note_use("a");
        assert_eq!(lru.evict_to(2), vec!["b".to_string()]);
        // Capacity 0 behaves as 1: everything but the hottest goes.
        lru.note_use("d");
        assert_eq!(lru.evict_to(0), vec!["c".to_string(), "a".to_string()]);
        assert!(lru.evict_to(0).is_empty());
    }

    #[test]
    fn manifest_rejects_malformed_rows() {
        let dir = std::env::temp_dir().join(format!("comet-manifest-bad-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.txt"), "too few columns\n").unwrap();
        assert!(Manifest::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
