//! # CoMet-RS — Parallel Accelerated Vector Similarity for Genomics
//!
//! A three-layer Rust + JAX + Pallas reproduction of
//! *"Parallel Accelerated Vector Similarity Calculations for Genomics
//! Applications"* (Joubert, Nance, Weighill, Jacobson — Parallel
//! Computing, 2018; DOI 10.1016/j.parco.2018.03.009) and its companion
//! *"Parallel Accelerated Custom Correlation Coefficient Calculations
//! for Genomics Applications"* (arXiv 1705.08213): similarity metrics
//! computed through accelerator-offloaded block kernels, with
//! block-circulant (2-way) and tetrahedral (3-way) parallel
//! decompositions, redundancy elimination, staging, and pipelined
//! communication.
//!
//! ## The session-first API
//!
//! The public entry point is [`session::Session`] — a long-lived
//! object owning everything worth amortizing across requests, shaped
//! for the ROADMAP north star of serving many runs over one genomic
//! dataset:
//!
//! * the **PJRT service** and its compiled-executable cache (started
//!   lazily, reused by every accelerator run);
//! * **[`session::Dataset`] handles**: per-node blocks are loaded and
//!   ingested into a metric's preferred representation **once per
//!   (dataset, repr, grid slice)** and then served from cache — a
//!   Sorensen campaign packs its bit-planes exactly once, however many
//!   runs follow;
//! * typed **[`session::RunRequest`]s** (builder-validated) instead of
//!   ad-hoc `RunConfig` field mutation — [`config::RunConfig`] remains
//!   the serialized TOML/CLI form and lowers into a request via
//!   [`session::Session::request_from_config`].
//!
//! Results **stream**: node programs emit finished metric tiles
//! through an [`output::sink::ResultSink`] ([`output::sink::Tile`]s
//! bounded by block size, never campaign size). Built-in sinks
//! reproduce the historical modes — collect into stores, write §6.8
//! per-node byte files, discard (`--no-store`) — and
//! [`output::sink::ForwardSink`] is the serving seam: push tiles
//! onward without ever materializing a full result set.
//!
//! The `comet batch` subcommand drives a multi-request TOML campaign
//! ([`config::batch_from_toml_str`]) against one session,
//! demonstrating ingest-once amortization end-to-end.
//!
//! ## The serving layer
//!
//! [`serve`] turns one session into a server: `comet serve` runs a
//! [`serve::Server`] — per-dataset **shard queues** drained by worker
//! threads (same dataset → same shard → one ingest, different datasets
//! → true parallelism), **bounded admission** (typed
//! [`serve::ServeError::Busy`]/[`serve::ServeError::TooLarge`]
//! rejections instead of unbounded queueing or OOM), and **bounded
//! caches** ([`session::SessionLimits`]: a block-cache byte budget and
//! an executable-cache slot cap, both LRU, with hit/miss/eviction
//! counters in [`coordinator::RunStats`]). Results cross the wire as
//! versioned, length-prefixed [`output::wire::Frame`]s
//! ([`output::sink::Tile`] gains `encode`/`decode`;
//! [`output::wire::SocketSink`] streams them from node threads), and
//! requests arrive as one-line key=value specs
//! ([`config::RunConfig::from_kv_line`]) over a Unix socket or stdin.
//! Every served response is bit-identical to a one-shot
//! [`coordinator::run`] of the same spec
//! (`tests/serve_concurrency.rs`).
//!
//! ## Out-of-core streaming ingest
//!
//! Datasets larger than the block budget stream. When
//! [`session::SessionLimits::block_cache_bytes`] evicts an ingested
//! block, the session **spills** it to a per-dataset
//! [`vecdata::oocstore::BlockStore`] (a repr-preserving codec — elem
//! width, payload length, and an FNV-64 checksum validated on every
//! decode; [`vecdata::oocstore::DirStore`] writes
//! temp-file-then-rename) instead of dropping it, and the next touch
//! **reloads** the exact bytes — never a re-ingest — so out-of-core
//! runs are bit-identical to in-RAM runs. A prefetching
//! [`coordinator::prefetch::ReadAhead`] provider, hinted with the
//! 2-way/3-way step schedules, reloads upcoming blocks on
//! [`linalg::pool`] workers under a bounded in-flight budget so the
//! kernels never starve (the double-buffered pipeline of Beyer &
//! Bientinesi, arXiv 1302.4332). Transient store faults are retried
//! with exponential backoff ([`vecdata::oocstore::with_retry`]);
//! permanent faults surface as typed
//! [`vecdata::oocstore::StoreError`]s (and as an `Error` wire frame
//! through `comet serve`); a corrupted spill file is caught by the
//! codec checksum, never silently decoded. Spill/reload/stall counters
//! flow through [`coordinator::RunStats`] into the `comet
//! run`/`batch`/`serve` ledgers, [`perfmodel`] prices the spill-store
//! round trip, and `--block-cache-bytes` turns the whole path on from
//! the CLI. `tests/ooc_ingest.rs` pins the codec round-trip per repr,
//! forced-spill bit-identity across metrics × backends ×
//! decompositions × threads, fault recovery
//! ([`testkit::faults::FailingStore`] scripts the failures), and the
//! prefetch order/budget contracts.
//!
//! ## Fault tolerance
//!
//! Campaigns survive the fabric, the nodes, and the clock. The comm
//! layer validates every envelope with an FNV-64 checksum and
//! retransmits dropped or corrupted deliveries under the shared
//! [`util::retry::Policy`] backoff (deterministic, no wall clock in
//! any schedule); blocking receives carry a bounded deadline, so a
//! dead peer is a typed [`comm::CommError`] — never a hang.
//! [`comm::faults::FaultPlan`] scripts per-`(rank, send-op)`
//! drop/delay/corrupt/kill faults into a run
//! ([`coordinator::RunOpts::faults`];
//! [`testkit::faults::script_comm_faults`] places them from a PRNG
//! seed), and the node supervisor in
//! [`coordinator::run_streamed_opts`] joins **every** node thread
//! before judging the run, converting panics and comm timeouts into a
//! typed [`coordinator::RunError`] with per-rank diagnostics. The
//! serve layer respawns a shard worker that dies mid-request: the
//! in-flight ticket surfaces [`serve::ServeError::WorkerDied`] and the
//! next submission to the shard re-arms it. Checkpoint/resume rides
//! the same spill codec: a [`coordinator::checkpoint::CheckpointStore`]
//! ([`coordinator::RunOpts::checkpoint`], CLI `--checkpoint-dir`)
//! persists each completed work unit's tiles keyed by a
//! config-derived, cross-process-stable run prefix; rerunning the same
//! config skips persisted units (the comm schedule still runs in
//! lockstep), replays their tiles through the sink, and finishes
//! bit-identically — `comet batch --halt-after N` is the scripted
//! interruption rig. A corrupt checkpoint blob is a typed error, never
//! a silent recompute; retry/corrupt/fault and
//! write/skip/replay counters flow through [`coordinator::RunStats`]
//! into the run/batch ledgers, and [`perfmodel`] prices retransmits
//! (`retry_rate`/`t_backoff`) and checkpoint writes
//! (`ckpt_frac`/`ckpt_bw`). `tests/fault_tolerance.rs` pins the
//! zero-overhead-when-healthy wire counts, recovery bit-identity
//! across the fault matrix, bounded typed aborts, resume, and worker
//! respawn.
//!
//! **Migration note:** `coordinator::run` / `run_with_artifacts` /
//! `run_with_client` remain as one-shot shims (fresh ingest, legacy
//! `store_metrics`/`output_dir` semantics, unchanged checksums — a
//! session run of the same config is bit-identical). Long-lived
//! callers should construct a `Session` once and reuse requests; the
//! coordinator core they share is `coordinator::run_streamed`.
//!
//! ## The metric engine
//!
//! Every run is parameterized by a [`metrics::Metric`] — the bundle of
//! numerator kernel family, denominator precomputation, quotient
//! combination, element domain, and checksum contribution. Three
//! families are registered (`--metric` on the CLI):
//!
//! * **czekanowski** — Proportional Similarity via the min-product
//!   "modified GEMM" (mGEMM), 2-way and 3-way (the source paper).
//! * **ccc** — the companion paper's Custom Correlation Coefficient:
//!   plain-GEMM numerators over allele-count vectors, 2-way.
//! * **sorenson** — bit-packed Sorensen (§2.3 / Table 6): vectors are
//!   binarized into words, numerators are AND+popcount, 2-way.
//!
//! The coordinator layers are generic over the metric — the node
//! programs contain no metric-specific branches, so a new metric is
//! one `Metric` impl plus (optionally) a backend kernel.
//!
//! ## Block representations (pack-once)
//!
//! Each metric declares a preferred block representation
//! ([`metrics::Metric::preferred_repr`]): float metrics keep dense
//! [`vecdata::VectorSet`]s, bit-domain metrics cache packed bit-planes
//! ([`vecdata::bits::BitVectorSet`]). Conversion happens **once per
//! node block** at ingest ([`metrics::Metric::ingest`]); the
//! coordinator then circulates blocks as [`vecdata::block::Block`] and
//! ships them over the simulated wire as
//! [`vecdata::block::BlockData`] — packed u64 words for Sorensen
//! (~64× less comm volume than f64 elements, accounted per variant by
//! `comm::Payload::bytes`), two packed allele planes for CCC
//! ([`vecdata::geno::GenoBlock`], [`vecdata::block::Repr::Packed2`]),
//! f64 elements for the float families. The step loops never re-pack
//! (`tests/comm_accounting.rs` and `tests/geno_ingest.rs` pin this).
//!
//! ## Real-data ingest (`vecdata::geno`)
//!
//! Genomics cohorts come from files, not synthesis: `--input-format
//! raw|bed|vcf` (config `input.format`, serve key `format`) selects
//! the reader behind [`config::InputSource`]. The PLINK `.bed` reader
//! ([`vecdata::geno::read_bed_cols`]) validates the variant-major
//! magic, the exact byte size, and `.bim`/`.fam` companion dimensions,
//! then reads each node's column span straight out of the 2-bit codes;
//! the VCF reader ([`vecdata::geno::read_vcf_cols`]) streams the text
//! once and fans GT-field chunk decodes out over the [`linalg::pool`]
//! workers. Both yield [`vecdata::geno::GenoCodes`] (0/1/2 dosage +
//! missing), which expands to the float path or packs once into the
//! two-plane [`vecdata::geno::GenoBlock`] — dosage = lo + 2·hi, with a
//! missing-genotype mask plane that travels and spills only when the
//! span actually has missing calls (missing imputes to dosage 0 on
//! every path, so results stay bit-identical to the float oracle). CCC
//! composes its plain-GEMM numerators from four Sorensen plane kernels
//! over these blocks — exact small-integer arithmetic, so `.bed`- and
//! VCF-ingested runs are checksum-identical to the synthetic float
//! path across backends × decompositions × threads
//! (`tests/geno_ingest.rs`), with wire volume pinned ≥16× below the
//! float exchange. The packed planes ride the oocstore spill codec
//! byte-identically (elem-width tag 2 + mask flag), decode/missing
//! counters flow through [`coordinator::RunStats`] into the ledgers,
//! [`perfmodel`] prices the one-time decode
//! (`ingest_bytes`/`ingest_bw` → `t_ingest`), and `comet gen-data
//! --format bed|vcf` writes seeded fixture filesets
//! ([`vecdata::geno::write_plink_fixture`]) so no binary blobs live
//! in-tree.
//!
//! ## Symmetry-halved + thread-parallel compute core
//!
//! Diagonal blocks (a vector block paired with itself) go through
//! triangular kernels ([`linalg::optimized::mgemm2_tri`] and friends;
//! `Metric::numerators2_diag` → `Backend::*_diag`): only the strict
//! upper triangle is computed, ~2× fewer elementwise ops, with entries
//! bit-identical to the full kernel ([`linalg::opcount`] proves the
//! reduction; `tests/triangular_threads.rs` pins it). The 3-way diag
//! slices use a diag-aware slab kernel that skips redundant sub-slices
//! and writes planes directly into the slab. `--threads N` (config
//! `run.threads`, reported in `run.meta`) drives row-panel-parallel
//! variants of every kernel family — output tiles are disjoint per
//! thread, so grid-valued sums stay **bit-identical across thread
//! counts, backends, and decompositions**. Triangular row panels are
//! **load-balanced**: each thread owns a low+high band pair
//! ([`linalg::tri_partition`]), since row i of a strict upper triangle
//! computes n−1−i entries and contiguous chunks would leave the first
//! thread ~2× the average load. `cargo bench --bench bench_kernels`
//! appends comparisons/sec trajectory points to `BENCH_kernels.json`
//! at the repo root (including session-amortization points: one-shot
//! runs vs a reused `Session` vs a spill-bound out-of-core session).
//!
//! ## SIMD inner kernels + persistent worker pool
//!
//! The inner loops are SIMD-shaped ([`linalg::simd`], safe Rust the
//! autovectorizer turns into vector instructions): packed popcount
//! sweeps run [`linalg::simd::LANES`] independent accumulator chains
//! per iteration (bit-exact — integer sums are order-free), and the
//! float panel kernels repack each register tile **q-major**
//! ([`linalg::simd::pack_tile_qmajor`]) so the tile loop reads
//! contiguous unit-stride rows instead of gathering across column
//! slices. Accumulation order per output element is unchanged (and no
//! FMA is used), so results stay bit-identical to the reference
//! backend. Multi-threaded drivers dispatch to a **persistent worker
//! pool** ([`linalg::pool`]): threads spawn once per process and park,
//! a kernel call enqueues its row-panel closures and blocks until they
//! drain — zero per-kernel-call thread spawns in steady state.
//! [`session::Session::run`] warms the pool before compute;
//! [`coordinator::RunStats`] surfaces per-run dispatch deltas
//! (`pool_scopes`/`pool_tasks`/`pool_threads_spawned`), and the `comet
//! batch` ledger reports the spawns-amortized total.
//! `tests/simd_pool.rs` pins the bit-identity and zero-spawn
//! contracts; [`perfmodel`] prices both effects (`lane_width` scales
//! the mGEMM term with `threads`, `t_spawn`/`pool_warm` price cold
//! per-call dispatch).
//!
//! ## Layer map (see DESIGN.md)
//!
//! * **Layer 1/2 (build time)** — Pallas kernels + JAX graphs in
//!   `python/compile/` (min-product, GEMM, and packed-u32 popcount
//!   lowerings), AOT-lowered to HLO text artifacts.
//! * **Layer 3 (this crate)** — the coordinator: loads artifacts
//!   through the PJRT CPU client ([`runtime`], with artifact kinds
//!   keyed by the metric's kernel family), runs the paper's
//!   Algorithms 1–3 over a simulated multi-node cluster ([`comm`],
//!   [`decomp`], [`coordinator`]), and owns denominators, quotients,
//!   checksums, and metric-tagged output ([`metrics`], [`checksum`],
//!   [`output`]).
//!
//! Python never runs on the request path: after `make artifacts` the
//! `comet` binary is self-contained.

pub mod checksum;
pub mod cli;
pub mod comm;
pub mod config;
pub mod coordinator;
pub mod decomp;
pub mod linalg;
pub mod metrics;
pub mod output;
pub mod perfmodel;
pub mod runtime;
pub mod serve;
pub mod session;
pub mod testkit;
pub mod util;
pub mod vecdata;

/// Crate-wide result type (anyhow is the only vendored error crate).
pub type Result<T> = anyhow::Result<T>;
