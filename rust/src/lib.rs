//! # CoMet-RS — Parallel Accelerated Vector Similarity for Genomics
//!
//! A three-layer Rust + JAX + Pallas reproduction of
//! *"Parallel Accelerated Vector Similarity Calculations for Genomics
//! Applications"* (Joubert, Nance, Weighill, Jacobson — Parallel
//! Computing, 2018; DOI 10.1016/j.parco.2018.03.009): 2-way and 3-way
//! Proportional Similarity (Czekanowski) metrics computed through a
//! min-product "modified GEMM" (mGEMM) offloaded to an accelerator, with
//! block-circulant (2-way) and tetrahedral (3-way) parallel
//! decompositions, redundancy elimination, staging, and pipelined
//! communication.
//!
//! Layer map (see DESIGN.md):
//! * **Layer 1/2 (build time)** — Pallas kernels + JAX graphs in
//!   `python/compile/`, AOT-lowered to HLO text artifacts.
//! * **Layer 3 (this crate)** — the coordinator: loads artifacts through
//!   the PJRT CPU client ([`runtime`]), runs the paper's Algorithms 1–3
//!   over a simulated multi-node cluster ([`comm`], [`decomp`],
//!   [`coordinator`]), and owns denominators, quotients, checksums, and
//!   output ([`metrics`], [`checksum`], [`output`]).
//!
//! Python never runs on the request path: after `make artifacts` the
//! `comet` binary is self-contained.

pub mod checksum;
pub mod cli;
pub mod comm;
pub mod config;
pub mod coordinator;
pub mod decomp;
pub mod linalg;
pub mod metrics;
pub mod output;
pub mod perfmodel;
pub mod runtime;
pub mod testkit;
pub mod util;
pub mod vecdata;

/// Crate-wide result type (anyhow is the only vendored error crate).
pub type Result<T> = anyhow::Result<T>;
