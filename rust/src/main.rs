//! `comet` — launcher CLI for CoMet-RS.
//!
//! Subcommands:
//!   run        execute a 2-way/3-way metrics campaign (config file or flags)
//!   batch      run a multi-request campaign file against ONE session
//!              (ingest-once dataset blocks, persistent executable cache)
//!   serve      concurrent request scheduler over one session — line-delimited
//!              request specs in (socket or stdin), wire-format tile frames out
//!   plan       print the parallel decomposition schedule for a grid
//!   artifacts  validate the AOT artifact manifest
//!   model      evaluate the §6.3 performance model
//!   gen-data   write a synthetic input file (§6.8 binary format)
//!   info       build/runtime information
//!
//! Examples:
//!   comet run --num-way 2 --nv 1024 --nf 384 --npv 4 --backend pjrt
//!   comet run --config campaign.toml
//!   comet batch --config examples/batch.toml
//!   comet plan --num-way 3 --npv 6 --npr 4
//!   comet model --num-way 2 --nvp 10240 --nfp 5000 --load 13

use anyhow::{bail, Context, Result};
use comet::cli;
use comet::comm::cost::CostModel;
use comet::config::{self, BackendKind, InputSource, Precision, RunConfig};
use comet::coordinator;
use comet::decomp::{three_way, two_way, Grid};
use comet::metrics::counts;
use comet::output::sink::{DiscardSink, StatsOnlySink};
use comet::perfmodel;
use comet::runtime::Manifest;
use comet::serve;
use comet::session::{Session, SessionLimits};
use comet::util::fmt;
use comet::vecdata::{io as vio, SyntheticKind, VectorSet};
use std::sync::Arc;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(argv) {
        eprintln!("comet: error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(argv: Vec<String>) -> Result<()> {
    let args = cli::parse(argv)?;
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "run" => cmd_run(&args),
        "batch" => cmd_batch(&args),
        "serve" => cmd_serve(&args),
        "plan" => cmd_plan(&args),
        "artifacts" => cmd_artifacts(&args),
        "model" => cmd_model(&args),
        "gen-data" => cmd_gen_data(&args),
        "info" => cmd_info(),
        "help" | "--help" => {
            print!("{}", HELP);
            Ok(())
        }
        other => bail!("unknown subcommand {other:?}; see `comet help`"),
    }
}

const HELP: &str = "\
comet — Parallel Accelerated Vector Similarity Calculations (CoMet-RS)

USAGE: comet <run|batch|serve|plan|artifacts|model|gen-data|info|help> [options]

run options:
  --config FILE      TOML run config (flags below override it)
  --metric NAME      metric family (default czekanowski):
                       czekanowski  Proportional Similarity, min-product mGEMM (2/3-way)
                       ccc          Custom Correlation Coefficient, GEMM over
                                    allele counts (2-way; pair with --synthetic alleles)
                       sorenson     bit-packed Sorensen, AND+popcount (2-way)
  --num-way 2|3      metric order (default 2)
  --nv N --nf N      vectors / features
  --precision f32|f64
  --backend pjrt|cpu|reference
  --threads N        host compute threads per node (cpu backend's
                     row-panel-parallel kernels; results bit-identical
                     across thread counts; default 1)
  --npf N --npv N --npr N   processor grid (virtual nodes)
  --num-stage N --stage S   3-way staging
  --synthetic grid|verifiable|phewas|alleles   input generator (default grid)
  --seed N
  --input-file FILE  input file (overrides --synthetic)
  --input-format raw|bed|vcf   how --input-file is read (default raw):
                       raw  column-major binary floats (§6.8)
                       bed  variant-major PLINK .bed (2-bit genotype codes;
                            companion .bim/.fam cross-check --nv/--nf)
                       vcf  GT-field VCF (diploid calls, chunk-parallel decode)
  --output-dir DIR   write per-node metric files + run.meta sidecar
  --output-threshold X  drop metrics below X ((offset, byte) records)
  --no-store         do not keep metrics in memory (big runs)
  --artifacts DIR    artifact directory (default: artifacts)
  --block-cache-bytes N   out-of-core budget: blocks LRU-evicted past it
                     spill to a per-dataset disk store and reload
                     bit-identically (read ahead of the step schedule)
  --no-spill         evicted blocks are dropped and re-ingested instead
                     of spilled (pre-out-of-core behavior)
  --checkpoint-dir DIR   persist per-unit progress; rerunning the same
                     config against the same DIR skips completed units and
                     replays their tiles bit-identically (kill-resume safe;
                     a corrupt checkpoint is a typed error, never silently
                     recomputed)

batch options:
  --config FILE      batch TOML: base [run]/[decomp]/[input] tables plus one
                     [request.<name>] table per run (run+decomp keys accepted
                     flat as overrides). All requests execute against ONE
                     session, so blocks of the shared dataset are ingested
                     once per representation and PJRT executables compile
                     once — see examples/batch.toml
  --artifacts DIR    artifact directory (default: artifacts)
  --block-cache-bytes N / --no-spill   as for run (one budget, whole batch)
  --checkpoint-dir DIR   as for run; every request in the campaign
                     checkpoints its units under DIR
  --halt-after N     stop after N completed request(s) — the deterministic
                     interruption rig for kill-resume drills: rerun the
                     same batch with the same --checkpoint-dir to finish

serve options (server):
  --socket PATH      listen on a Unix socket (one handler thread/connection);
                     clients send one `key=value ...` request spec per line
                     (keys: metric num_way nv nf precision backend threads
                     npf npv npr num_stage stage synthetic seed file format
                     output_threshold) and receive length-prefixed wire
                     frames: result tiles, then Done (metrics + checksum)
                     or Error — bit-identical to `comet run` of the same spec
  --stdin            serve one connection over stdin/stdout instead
  --workers N        shard worker threads (default 2); requests for the same
                     dataset share a shard (one ingest), others run in parallel
  --queue N          bounded per-shard queue depth (default 8); a full shard
                     rejects with a typed busy error instead of queueing forever
  --max-request-bytes N   admission cap on a request's estimated block bytes
  --block-cache-bytes N   session block-cache budget (LRU eviction past it;
                     evicted blocks spill to disk and reload bit-identically)
  --no-spill         drop evicted blocks instead of spilling them
  --exec-cache-slots N    PJRT executable-cache slot cap (LRU)
  --max-conns N      exit after N connections (smoke/CI runs)
  --artifacts DIR    artifact directory (default: artifacts)
serve options (client):
  --connect PATH --request \"key=value ...\"   send one request to a running
                     server, print `tiles= values= metrics= checksum=`
plan options:    --num-way 2|3 --npv N [--npr N]
model options:   --num-way 2|3 --nvp N --nfp N --load L [--nst N]
                 [--tgemm SECS] [--tcpu SECS] [--precision f32|f64]
                 [--threads N] [--diag-load L] [--triangular]
                 [--lane-width W]   SIMD lanes the kernel retires per step
                                    (scales the mGEMM term with threads; use 1
                                    when --tgemm was measured on a vector kernel)
                 [--tspawn SECS]    per-thread spawn cost of a cold kernel call
                 [--cold-pool]      price per-call thread spawns instead of the
                                    warm persistent worker pool (default warm)
                 [--queued N --serve-workers W]  also price serving turnaround:
                                    queue wait for N queued requests over W
                                    shard workers, plus an eviction-refill term
                 [--tingest SECS]   block re-ingest cost after a cache eviction
                 [--miss-rate X]    expected block-cache miss fraction (0..1)
                 [--reload-frac X]  fraction of block fetches served as spill
                                    reloads (out-of-core budget pressure, 0..1)
                 [--disk-bw B]      spill-store read bandwidth, bytes/s
                                    (default 2e9)
                 [--no-prefetch]    price reloads serially instead of
                                    overlapped by the read-ahead pipeline
                 [--retry-rate X]   expected retransmits per block exchange
                                    (comm-fault recovery pressure, 0 healthy)
                 [--tbackoff SECS]  mean retry backoff sleep per retransmit
                 [--ckpt-frac X]    fraction of units checkpointed (0..1;
                                    1 = fresh --checkpoint-dir campaign)
                 [--ckpt-bw B]      checkpoint-store write bandwidth, bytes/s
                 [--ingest-bytes N] input-file bytes decoded per node at ingest
                 [--ingest-bw B]    ingest decode bandwidth, bytes/s (prices
                                    the genotype-reader term; 0 = not modeled)
gen-data options: --nv N --nf N --out FILE [--precision f32|f64]
                 [--synthetic grid|verifiable|phewas|alleles] [--seed N]
                 [--format raw|bed|vcf]   raw floats (default), a PLINK
                                    .bed/.bim/.fam fileset, or a GT-field VCF
                                    (bed/vcf require --synthetic alleles; a
                                    same-seed synthetic run is the fixture's
                                    bit-identical float-path oracle)
";

fn config_from_args(args: &cli::Args) -> Result<RunConfig> {
    let mut cfg = match args.opt_str("config") {
        Some(path) => {
            let text = std::fs::read_to_string(path).with_context(|| format!("read {path}"))?;
            RunConfig::from_toml_str(&text)?
        }
        None => RunConfig::default(),
    };
    if let Some(m) = args.opt_str("metric") {
        cfg.metric = comet::metrics::MetricId::parse(m)?;
    }
    cfg.num_way = args.parse_or("num-way", cfg.num_way)?;
    cfg.nv = args.parse_or("nv", cfg.nv)?;
    cfg.nf = args.parse_or("nf", cfg.nf)?;
    if let Some(p) = args.opt_str("precision") {
        cfg.precision = Precision::parse(p)?;
    }
    if let Some(b) = args.opt_str("backend") {
        cfg.backend = BackendKind::parse(b)?;
    }
    cfg.threads = args.parse_or("threads", cfg.threads)?;
    let npf = args.parse_or("npf", cfg.grid.npf)?;
    let npv = args.parse_or("npv", cfg.grid.npv)?;
    let npr = args.parse_or("npr", cfg.grid.npr)?;
    cfg.grid = Grid::new(npf, npv, npr);
    cfg.num_stage = args.parse_or("num-stage", cfg.num_stage)?;
    if let Some(s) = args.opt_parse::<usize>("stage")? {
        cfg.stage = Some(s);
    }
    let input_format = args.opt_str("input-format").map(str::to_string);
    if let Some(f) = args.opt_str("input-file") {
        cfg.input =
            InputSource::from_format(input_format.as_deref().unwrap_or("raw"), f.to_string())?;
    } else if input_format.is_some() {
        bail!("--input-format requires --input-file");
    } else if args.opt_str("synthetic").is_some() || args.opt_str("seed").is_some() {
        let kind = SyntheticKind::parse(&args.str_or("synthetic", "grid"))?;
        cfg.input = InputSource::Synthetic { kind, seed: args.parse_or("seed", 1u64)? };
    }
    if let Some(dir) = args.opt_str("output-dir") {
        cfg.output_dir = Some(dir.to_string());
    }
    if let Some(t) = args.opt_parse::<f64>("output-threshold")? {
        cfg.output_threshold = Some(t);
    }
    if args.switch("no-store") {
        cfg.store_metrics = false;
    }
    cfg.validate()?;
    Ok(cfg)
}

/// The out-of-core knobs shared by run/batch/serve: a block-cache
/// budget (None = unbounded, never evicts) and whether evictions spill
/// to disk or degrade to drop + re-ingest.
fn limits_from_args(args: &cli::Args) -> Result<SessionLimits> {
    Ok(SessionLimits {
        block_cache_bytes: args.opt_parse::<u64>("block-cache-bytes")?,
        spill: !args.switch("no-spill"),
        ..SessionLimits::default()
    })
}

fn cmd_run(args: &cli::Args) -> Result<()> {
    let cfg = config_from_args(args)?;
    let artifacts = args.str_or("artifacts", "artifacts");
    let limits = limits_from_args(args)?;
    let ckpt_dir = args.opt_str("checkpoint-dir").map(str::to_string);
    args.reject_unknown()?;
    println!(
        "comet run: {}-way {} {} nv={} nf={} grid=({},{},{}) backend={} threads={} kernel={} repr={} stages={}{}",
        cfg.num_way,
        cfg.metric.name(),
        cfg.precision.tag(),
        cfg.nv,
        cfg.nf,
        cfg.grid.npf,
        cfg.grid.npv,
        cfg.grid.npr,
        cfg.backend.name(),
        cfg.threads,
        coordinator::backend::diag_kernel_for(cfg.backend),
        cfg.metric.preferred_repr().name(),
        cfg.num_stage,
        cfg.stage.map(|s| format!(" (stage {s})")).unwrap_or_default(),
    );
    // One-shot CLI runs go through a throwaway session: same code path
    // a server holds long-lived, and values stream through a sink
    // instead of accumulating in memory (the session rides the
    // request's file sink when --output-dir is set; otherwise nothing
    // listens — the CLI only reports stats + checksum).
    let session = Session::with_limits(&artifacts, limits);
    if let Some(dir) = &ckpt_dir {
        session.checkpoint_to_dir(dir);
    }
    let req = session.request_from_config(&cfg)?;
    let outcome = session.run(&req, &DiscardSink)?;
    let s = &outcome.stats;
    println!("  metrics computed : {}", s.metrics);
    println!("  checksum         : {}", outcome.checksum.digest());
    println!(
        "  mGEMM calls      : {} (2-way) + {} (3-way slabs)",
        s.mgemm2_calls, s.mgemm3_calls
    );
    println!(
        "  comm             : {} in {} messages",
        fmt::bytes(s.comm_bytes),
        s.comm_messages
    );
    println!(
        "  time             : total {} | input {} | compute {} | output {}",
        fmt::secs(s.t_total),
        fmt::secs(s.t_input),
        fmt::secs(s.t_compute),
        fmt::secs(s.t_output)
    );
    if s.t_accel > 0.0 {
        println!("  accelerator time : {}", fmt::secs(s.t_accel));
    }
    if s.pool_scopes > 0 {
        println!(
            "  worker pool      : {} task(s) over {} parallel kernel call(s), {} thread spawn(s)",
            s.pool_tasks, s.pool_scopes, s.pool_threads_spawned
        );
    }
    if s.cache_hits + s.cache_misses > 0 {
        println!(
            "  block cache      : {} hit(s) / {} miss(es) / {} eviction(s), {} resident",
            s.cache_hits,
            s.cache_misses,
            s.cache_evictions,
            fmt::bytes(s.cache_bytes)
        );
    }
    if s.spills + s.reloads > 0 {
        println!(
            "  out-of-core      : {} spill(s) ({} written) / {} reload(s) ({} read), stall {}",
            s.spills,
            fmt::bytes(s.spill_bytes),
            s.reloads,
            fmt::bytes(s.reload_bytes),
            fmt::secs(s.t_stall)
        );
    }
    if s.geno_calls + s.pack2_calls > 0 {
        println!(
            "  genotype ingest  : {} call(s) decoded ({} missing), {} plane pack(s)",
            s.geno_calls, s.geno_missing, s.pack2_calls
        );
    }
    if s.comm_retries + s.comm_corrupt + s.faults_injected > 0 {
        println!(
            "  comm recovery    : {} retransmit(s), {} corrupt frame(s) detected, \
             {} fault(s) injected",
            s.comm_retries, s.comm_corrupt, s.faults_injected
        );
    }
    if s.ckpt_writes + s.ckpt_skipped + s.ckpt_replayed + s.ckpt_errors > 0 {
        println!(
            "  checkpoint       : {} unit(s) written ({}) / {} skipped on resume \
             ({} tile(s) replayed), {} write error(s)",
            s.ckpt_writes,
            fmt::bytes(s.ckpt_bytes),
            s.ckpt_skipped,
            s.ckpt_replayed,
            s.ckpt_errors
        );
    }
    let cmps = if cfg.num_way == 2 {
        counts::cmp_2way(cfg.nf, cfg.nv)
    } else {
        counts::cmp_3way(cfg.nf, cfg.nv)
    };
    // Comparisons actually computed this run (a single stage computes a
    // fraction of the campaign).
    let frac = s.metrics as f64
        / if cfg.num_way == 2 {
            comet::metrics::indexing::num_pairs(cfg.nv) as f64
        } else {
            comet::metrics::indexing::num_triples(cfg.nv) as f64
        };
    let rate = cmps as f64 * frac / s.t_total;
    println!("  comparison rate  : {} ({}% of campaign)", fmt::cmp_rate(rate), (frac * 100.0).round());
    Ok(())
}

fn cmd_batch(args: &cli::Args) -> Result<()> {
    let path = args.require_str("config")?;
    let artifacts = args.str_or("artifacts", "artifacts");
    let limits = limits_from_args(args)?;
    let ckpt_dir = args.opt_str("checkpoint-dir").map(str::to_string);
    let halt_after = args.opt_parse::<usize>("halt-after")?;
    args.reject_unknown()?;
    let text = std::fs::read_to_string(&path).with_context(|| format!("read {path}"))?;
    let entries = config::batch_from_toml_str(&text)?;
    let session = Session::with_limits(&artifacts, limits);
    if let Some(dir) = &ckpt_dir {
        session.checkpoint_to_dir(dir);
    }
    println!(
        "comet batch: {} request(s) from {} against one session",
        entries.len(),
        path
    );

    let t0 = std::time::Instant::now();
    // One-shot equivalents would load a block per *rank* per run (ranks
    // replicated along npr re-read the same slice); the session ingests
    // once per (dataset, repr, grid slice).
    let mut fresh_loads: u64 = 0;
    let mut pool_totals = comet::coordinator::RunStats::default();
    let mut datasets: Vec<comet::session::Dataset> = Vec::new();
    let mut table = fmt::Table::new(&[
        "request",
        "metric",
        "way",
        "grid",
        "metrics",
        "tiles",
        "checksum",
        "new ingests",
        "time",
    ]);
    let mut completed = 0usize;
    let mut halted = false;
    for e in &entries {
        if halt_after.is_some_and(|h| completed >= h) {
            halted = true;
            break;
        }
        let req = session.request_from_config(&e.cfg)?;
        let ds = req.dataset().clone();
        let before = ds.ingest_count();
        // Values stream: counted tiles always (the stats sink keeps the
        // run non-null so tiles are assembled); the session rides the
        // request's §6.8 file sink when it names an output directory.
        // Nothing is accumulated.
        let stats_sink = StatsOnlySink::new();
        let out = session.run(&req, &stats_sink)?;
        fresh_loads += e.cfg.grid.np() as u64;
        pool_totals.absorb(&out.stats);
        table.row(&[
            e.name.clone(),
            e.cfg.metric.name().to_string(),
            e.cfg.num_way.to_string(),
            format!("({},{},{})", e.cfg.grid.npf, e.cfg.grid.npv, e.cfg.grid.npr),
            out.stats.metrics.to_string(),
            out.stats.tiles.to_string(),
            out.checksum.digest(),
            (ds.ingest_count() - before).to_string(),
            fmt::secs(out.stats.t_total),
        ]);
        if !datasets.iter().any(|d| d.spec() == ds.spec()) {
            datasets.push(ds);
        }
        completed += 1;
    }
    table.print();
    if halted {
        println!(
            "  halted after {completed} of {} request(s) (--halt-after); rerun the batch \
             with the same --checkpoint-dir to finish bit-identically",
            entries.len()
        );
    }

    let total_ingests: u64 = datasets.iter().map(|d| d.ingest_count()).sum();
    println!(
        "  session amortization: {} block ingest(s) across {} dataset(s) \
         (one-shot runs would have loaded {} blocks) in {}",
        total_ingests,
        datasets.len(),
        fresh_loads,
        fmt::secs(t0.elapsed().as_secs_f64()),
    );
    if pool_totals.pool_scopes > 0 {
        // Per-call scoped spawns would have created one OS thread per
        // task; the persistent pool spawns once and parks.
        println!(
            "  worker-pool amortization: {} thread spawn(s) for {} parallel kernel call(s) / \
             {} task(s) (per-call scoped spawns would have made {})",
            pool_totals.pool_threads_spawned,
            pool_totals.pool_scopes,
            pool_totals.pool_tasks,
            pool_totals.pool_tasks,
        );
    }
    if let Some((compiles, execs, secs)) = session.accel_stats() {
        println!(
            "  accelerator      : {compiles} artifact compile(s) for {execs} execution(s), {}",
            fmt::secs(secs)
        );
    }
    if pool_totals.cache_hits + pool_totals.cache_misses > 0 {
        // Cache-pressure ledger across the campaign: every miss is an
        // ingest a later request avoided repeating (hits), and every
        // eviction is budget pressure the serving layer absorbed.
        println!(
            "  cache ledger     : {} hit(s) / {} miss(es) / {} eviction(s), peak {} resident",
            pool_totals.cache_hits,
            pool_totals.cache_misses,
            pool_totals.cache_evictions,
            fmt::bytes(pool_totals.cache_bytes)
        );
    }
    if pool_totals.spills + pool_totals.reloads > 0 {
        // Out-of-core ledger: evictions the spill store absorbed, and
        // the read-back traffic later requests paid instead of a full
        // re-ingest (bit-identical either way).
        println!(
            "  out-of-core      : {} spill(s) ({} written) / {} reload(s) ({} read), stall {}",
            pool_totals.spills,
            fmt::bytes(pool_totals.spill_bytes),
            pool_totals.reloads,
            fmt::bytes(pool_totals.reload_bytes),
            fmt::secs(pool_totals.t_stall)
        );
    }
    if pool_totals.geno_calls + pool_totals.pack2_calls > 0 {
        // Real-data ledger: decoded genotype calls (and the missing
        // fraction imputed to dosage 0), plus the pack-once conversions
        // into 2-bit planes.
        println!(
            "  genotype ingest  : {} call(s) decoded ({} missing), {} plane pack(s)",
            pool_totals.geno_calls, pool_totals.geno_missing, pool_totals.pack2_calls
        );
    }
    if pool_totals.comm_retries + pool_totals.comm_corrupt + pool_totals.faults_injected > 0 {
        println!(
            "  comm recovery    : {} retransmit(s), {} corrupt frame(s) detected, \
             {} fault(s) injected",
            pool_totals.comm_retries, pool_totals.comm_corrupt, pool_totals.faults_injected
        );
    }
    if pool_totals.ckpt_writes
        + pool_totals.ckpt_skipped
        + pool_totals.ckpt_replayed
        + pool_totals.ckpt_errors
        > 0
    {
        // Restart ledger: what the campaign persisted, and (on a
        // resumed run) how much recompute the checkpoints bought back.
        println!(
            "  checkpoint       : {} unit(s) written ({}) / {} skipped on resume \
             ({} tile(s) replayed), {} write error(s)",
            pool_totals.ckpt_writes,
            fmt::bytes(pool_totals.ckpt_bytes),
            pool_totals.ckpt_skipped,
            pool_totals.ckpt_replayed,
            pool_totals.ckpt_errors
        );
    }
    Ok(())
}

fn cmd_serve(args: &cli::Args) -> Result<()> {
    let artifacts = args.str_or("artifacts", "artifacts");
    let workers: usize = args.parse_or("workers", 2)?;
    let queue: usize = args.parse_or("queue", 8)?;
    let max_request_bytes = args.opt_parse::<u64>("max-request-bytes")?;
    let mut limits = limits_from_args(args)?;
    limits.exec_cache_slots = args.opt_parse::<usize>("exec-cache-slots")?;
    let max_conns = args.opt_parse::<usize>("max-conns")?;
    let socket = args.opt_str("socket").map(str::to_string);
    let connect = args.opt_str("connect").map(str::to_string);
    let request = args.opt_str("request").map(str::to_string);
    let use_stdin = args.switch("stdin");
    args.reject_unknown()?;

    // Client mode: one request against a running server's socket.
    if let Some(path) = connect {
        let line = request.context("--connect requires --request \"key=value ...\"")?;
        let mut stream = std::os::unix::net::UnixStream::connect(&path)
            .with_context(|| format!("connect {path}"))?;
        let reply = serve::request_over_stream(&mut stream, &line)?;
        println!(
            "tiles={} values={} metrics={} checksum={}",
            reply.tiles.len(),
            reply.values,
            reply.metrics,
            reply.checksum
        );
        return Ok(());
    }

    let session = Arc::new(Session::with_limits(&artifacts, limits));
    let server = Arc::new(serve::Server::start(
        Arc::clone(&session),
        serve::ServeConfig { workers, queue_capacity: queue, max_request_bytes },
    )?);

    if let Some(path) = socket {
        // Re-bind cleanly after an unclean exit.
        let _ = std::fs::remove_file(&path);
        let listener = std::os::unix::net::UnixListener::bind(&path)
            .with_context(|| format!("bind {path}"))?;
        eprintln!(
            "comet serve: listening on {path} ({workers} worker(s), queue {queue}{})",
            max_conns
                .map(|m| format!(", exits after {m} connection(s)"))
                .unwrap_or_default()
        );
        serve::serve_unix(Arc::clone(&server), listener, max_conns)?;
    } else if use_stdin {
        eprintln!("comet serve: reading request lines from stdin ({workers} worker(s))");
        serve::serve_connection(&server, std::io::stdin(), std::io::stdout())?;
    } else {
        bail!(
            "serve needs a transport: --socket PATH, --stdin, or \
             --connect PATH --request \"...\""
        );
    }

    let stats = server.stats();
    eprintln!(
        "comet serve: {} submitted / {} completed, rejected {} busy + {} too-large, \
         {} worker respawn(s), queue wait {}",
        stats.submitted,
        stats.completed,
        stats.rejected_busy,
        stats.rejected_too_large,
        stats.respawns,
        fmt::secs(stats.queue_wait_secs)
    );
    let cache = session.cache_stats();
    eprintln!(
        "comet serve: block cache {} hit(s) / {} miss(es) / {} eviction(s), {} resident",
        cache.hits,
        cache.misses,
        cache.evictions,
        fmt::bytes(cache.bytes)
    );
    if cache.spills + cache.reloads > 0 {
        eprintln!(
            "comet serve: out-of-core {} spill(s) ({} written) / {} reload(s) ({} read), \
             {} spill error(s)",
            cache.spills,
            fmt::bytes(cache.spill_bytes),
            cache.reloads,
            fmt::bytes(cache.reload_bytes),
            cache.spill_errors
        );
    }
    Ok(())
}

fn cmd_plan(args: &cli::Args) -> Result<()> {
    let num_way: usize = args.parse_or("num-way", 2)?;
    let npv: usize = args.parse_or("npv", 4)?;
    let npr: usize = args.parse_or("npr", 1)?;
    args.reject_unknown()?;
    let mut table = fmt::Table::new(&["node", "work items", "detail"]);
    match num_way {
        2 => {
            for pv in 0..npv {
                for pr in 0..npr {
                    let steps = two_way::plan(npv, npr, pv, pr);
                    let blocks: Vec<String> = steps
                        .iter()
                        .filter_map(|s| s.compute.map(|b| format!("({},{})", b.row_block, b.col_block)))
                        .collect();
                    table.row(&[
                        format!("(pv={pv},pr={pr})"),
                        blocks.len().to_string(),
                        blocks.join(" "),
                    ]);
                }
            }
        }
        3 => {
            for pv in 0..npv {
                for pr in 0..npr {
                    let slices = three_way::slices_for_node(npv, npr, pv, pr);
                    let mut diag = 0;
                    let mut face = 0;
                    let mut vol = 0;
                    for s in &slices {
                        match s.combo {
                            three_way::Combo3::Diag => diag += 1,
                            three_way::Combo3::Face { .. } => face += 1,
                            three_way::Combo3::Volume { .. } => vol += 1,
                        }
                    }
                    table.row(&[
                        format!("(pv={pv},pr={pr})"),
                        slices.len().to_string(),
                        format!("diag={diag} face={face} volume={vol}"),
                    ]);
                }
            }
        }
        other => bail!("--num-way must be 2 or 3, got {other}"),
    }
    table.print();
    Ok(())
}

fn cmd_artifacts(args: &cli::Args) -> Result<()> {
    let dir = args.str_or("dir", "artifacts");
    let analyze = args.switch("analyze");
    args.reject_unknown()?;
    let manifest = Manifest::load(std::path::Path::new(&dir))?;
    if analyze {
        // L2 cost analysis: op histogram per artifact (DESIGN.md §6).
        let mut table =
            fmt::Table::new(&["artifact", "instrs", "loops", "fusions", "min", "and", "dot"]);
        for e in &manifest.entries {
            let path = manifest.dir.join(&e.file);
            if !path.exists() {
                continue;
            }
            let s = comet::runtime::hloinfo::parse_file(&path)?;
            table.row(&[
                e.name.clone(),
                s.instructions.to_string(),
                s.loops().to_string(),
                s.fusions().to_string(),
                s.count("minimum").to_string(),
                s.count("and").to_string(),
                s.count("dot").to_string(),
            ]);
        }
        table.print();
        return Ok(());
    }
    let mut table = fmt::Table::new(&["artifact", "kind", "prec", "nf", "nv", "jt", "built"]);
    for e in &manifest.entries {
        let built = manifest.dir.join(&e.file).exists();
        table.row(&[
            e.name.clone(),
            e.kind.clone(),
            e.precision.tag().to_string(),
            e.nf.to_string(),
            e.nv.to_string(),
            e.jt.to_string(),
            if built { "yes".into() } else { "MISSING".into() },
        ]);
    }
    table.print();
    Ok(())
}

fn cmd_model(args: &cli::Args) -> Result<()> {
    let num_way: usize = args.parse_or("num-way", 2)?;
    let precision = Precision::parse(&args.str_or("precision", "f64"))?;
    let input = perfmodel::ModelInput {
        nfp: args.parse_or("nfp", 5000)?,
        nvp: args.parse_or("nvp", 10_240)?,
        elem_bytes: precision.bytes(),
        t_gemm: args.parse_or("tgemm", 6.5)?,
        t_cpu: args.parse_or("tcpu", 0.1)?,
        load: args.parse_or("load", 13)?,
        diag_load: args.parse_or("diag-load", 0)?,
        threads: args.parse_or("threads", 1)?,
        lane_width: args.parse_or("lane-width", 1)?,
        t_spawn: args.parse_or("tspawn", 0.0)?,
        pool_warm: !args.switch("cold-pool"),
        triangular: args.switch("triangular"),
        nst: args.parse_or("nst", 16)?,
        reload_frac: args.parse_or("reload-frac", 0.0)?,
        disk_bw: args.parse_or("disk-bw", 2e9)?,
        prefetch: !args.switch("no-prefetch"),
        retry_rate: args.parse_or("retry-rate", 0.0)?,
        t_backoff: args.parse_or("tbackoff", 0.0)?,
        ckpt_frac: args.parse_or("ckpt-frac", 0.0)?,
        ckpt_bw: args.parse_or("ckpt-bw", 0.0)?,
        ingest_bytes: args.parse_or("ingest-bytes", 0)?,
        ingest_bw: args.parse_or("ingest-bw", 0.0)?,
        net: CostModel::gemini(),
        link: CostModel::pcie2(),
    };
    let queued: usize = args.parse_or("queued", 0)?;
    let serve_workers: usize = args.parse_or("serve-workers", 0)?;
    let t_ingest: f64 = args.parse_or("tingest", 0.0)?;
    let miss_rate: f64 = args.parse_or("miss-rate", 0.0)?;
    args.reject_unknown()?;
    let p = match num_way {
        2 => perfmodel::predict_2way(&input),
        3 => perfmodel::predict_3way(&input),
        other => bail!("--num-way must be 2 or 3, got {other}"),
    };
    println!("§6.3 model, {num_way}-way, {} elem bytes:", input.elem_bytes);
    println!("  t_comm      = {}", fmt::secs(p.t_comm));
    println!("  t_transfer_V= {}", fmt::secs(p.t_transfer_v));
    println!("  t_transfer_M= {}", fmt::secs(p.t_transfer_m));
    println!("  t_mGEMM     = {}", fmt::secs(p.t_gemm_total));
    println!("  t_CPU       = {}", fmt::secs(p.t_cpu));
    if p.t_dispatch > 0.0 {
        println!("  t_dispatch  = {} (cold per-call thread spawns)", fmt::secs(p.t_dispatch));
    }
    if p.t_stall > 0.0 {
        println!(
            "  t_stall     = {} (exposed out-of-core reload time{})",
            fmt::secs(p.t_stall),
            if input.prefetch { ", read-ahead overlapped" } else { ", serial reloads" }
        );
    }
    if p.t_retry > 0.0 {
        println!("  t_retry     = {} (comm retransmits + retry backoff)", fmt::secs(p.t_retry));
    }
    if p.t_ckpt > 0.0 {
        println!("  t_ckpt      = {} (checkpoint-unit writes)", fmt::secs(p.t_ckpt));
    }
    if p.t_ingest > 0.0 {
        println!("  t_ingest    = {} (input-file decode bandwidth)", fmt::secs(p.t_ingest));
    }
    println!("  total       = {}", fmt::secs(p.total));
    println!("  mGEMM fraction = {:.1}% (the paper's overlap regime indicator)", 100.0 * p.gemm_fraction());
    if serve_workers > 0 {
        let sp = perfmodel::predict_serve(&perfmodel::ServeInput {
            queued,
            workers: serve_workers,
            t_request: p.total,
            t_ingest,
            miss_rate,
        });
        println!("serving turnaround ({queued} queued, {serve_workers} worker(s)):");
        println!("  t_queue_wait= {}", fmt::secs(sp.t_queue_wait));
        println!("  t_refill    = {} (cache-eviction re-ingest)", fmt::secs(sp.t_refill));
        println!("  t_service   = {}", fmt::secs(sp.t_service));
        println!("  turnaround  = {}", fmt::secs(sp.total));
    }
    Ok(())
}

fn cmd_gen_data(args: &cli::Args) -> Result<()> {
    let nv: usize = args.parse_or("nv", 1024)?;
    let nf: usize = args.parse_or("nf", 385)?;
    let out = args.require_str("out")?;
    let precision = Precision::parse(&args.str_or("precision", "f32"))?;
    let seed: u64 = args.parse_or("seed", 1)?;
    let format = args.str_or("format", "raw");
    let kind = SyntheticKind::parse(&args.str_or(
        "synthetic",
        if format == "raw" { "phewas" } else { "alleles" },
    ))?;
    args.reject_unknown()?;
    let path = std::path::Path::new(&out);
    match format.as_str() {
        "raw" => match precision {
            Precision::F32 => {
                let set: VectorSet<f32> = VectorSet::generate(kind, seed, nf, nv, 0);
                vio::write_raw(path, &set)?;
            }
            Precision::F64 => {
                let set: VectorSet<f64> = VectorSet::generate(kind, seed, nf, nv, 0);
                vio::write_raw(path, &set)?;
            }
        },
        // Genotype containers hold 2-bit codes: the cohort must come
        // from the allele generator so a same-seed synthetic run is the
        // bit-identical float-path oracle for the fixture.
        "bed" | "vcf" => {
            if kind != SyntheticKind::Alleles {
                bail!("--format {format} requires --synthetic alleles (2-bit genotype codes)");
            }
            let set: VectorSet<f64> = VectorSet::generate(kind, seed, nf, nv, 0);
            if format == "bed" {
                let dir = match path.parent() {
                    Some(d) if d != std::path::Path::new("") => d,
                    _ => std::path::Path::new("."),
                };
                let stem = path
                    .file_stem()
                    .and_then(|s| s.to_str())
                    .context("--out needs a file name for --format bed")?;
                comet::vecdata::geno::write_plink_fixture(dir, stem, &set)?;
            } else {
                comet::vecdata::geno::write_vcf_fixture(path, &set)?;
            }
        }
        other => bail!("unknown --format {other:?} (want raw|bed|vcf)"),
    }
    println!(
        "wrote {} ({} vectors × {} features, {})",
        out,
        nv,
        nf,
        if format == "raw" { precision.tag().to_string() } else { format.clone() }
    );
    Ok(())
}

fn cmd_info() -> Result<()> {
    println!("comet {} — CoMet-RS", env!("CARGO_PKG_VERSION"));
    println!("reproduction of Joubert et al., Parallel Computing 2018 (10.1016/j.parco.2018.03.009)");
    println!("three-layer stack: Pallas mGEMM (L1) → JAX AOT HLO (L2) → rust PJRT coordinator (L3)");
    Ok(())
}
