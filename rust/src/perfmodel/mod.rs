//! The §6.3 analytic performance model.
//!
//! 2-way:  t = t_C + t_{T,V} + ℓ·t_G + t_{T,M} + t_CPU
//! 3-way:  t = t_C + t_{T,V} + ℓ·[(3 + (n_vp/6)/n_st)·t_G + 3·t_{T,V} + 4·t_{T,M} + t_CPU]
//!
//! where ℓ is the per-node load (blocks / block slices), t_C the
//! internode communication time per step, t_{T,V} / t_{T,M} the
//! host↔accelerator transfer times for vector blocks / metric blocks,
//! t_G one mGEMM, and t_CPU the denominator+quotient work. The
//! non-mGEMM terms price pipeline startup/drain under the assumption
//! that mGEMMs hide everything else (the paper's operating regime).
//!
//! The model doubles as the *tuning advisor*: it reproduces the paper's
//! guidance that ℓ should be maximized (limit npr) and n_vp, n_fp grown
//! to memory limits, and n_st kept small (§6.3, §6.6–6.7).

use crate::comm::cost::CostModel;

/// Per-node problem/machine description for the model.
#[derive(Debug, Clone, Copy)]
pub struct ModelInput {
    /// Vector elements per node (n_fp).
    pub nfp: usize,
    /// Vectors per node (n_vp).
    pub nvp: usize,
    /// Element width in bytes.
    pub elem_bytes: usize,
    /// Measured (or estimated) time of one n_vp×n_vp mGEMM at depth n_fp.
    pub t_gemm: f64,
    /// Measured per-step CPU (denominator/quotient) time.
    pub t_cpu: f64,
    /// Per-node load ℓ: blocks (2-way) or block slices (3-way).
    pub load: usize,
    /// How many of the `load` blocks are diagonal (2-way: one per node
    /// when Δ = 0 lands on it; the triangular kernel halves those).
    pub diag_load: usize,
    /// Host compute threads driving the kernels (row-panel parallel —
    /// near-linear on the mGEMM term; 1 = serial).
    pub threads: usize,
    /// Elementwise lanes the kernel inner loop retires per step
    /// (1 = scalar; the SIMD-shaped native kernels sweep vector lanes —
    /// e.g. 4 f64 per 256-bit op, `linalg::simd::LANES` u64 popcount
    /// chains on the packed path). Scales the mGEMM term like threads:
    /// both multiply the kernel's comparison rate.
    pub lane_width: usize,
    /// Per-thread dispatch cost of one multi-threaded kernel call when
    /// the worker pool is cold (OS thread spawn + join — what
    /// `std::thread::scope` paid on every call). Zero when
    /// single-threaded.
    pub t_spawn: f64,
    /// Whether kernel calls dispatch to an already-warm persistent
    /// pool (parked threads; per-call dispatch cost ~0) instead of
    /// spawning per call.
    pub pool_warm: bool,
    /// Whether diagonal blocks run the symmetry-halved triangular
    /// kernel (~0.5× the elementwise ops of the full square kernel).
    pub triangular: bool,
    /// Stage count n_st (3-way).
    pub nst: usize,
    /// Fraction of the load's block fetches served as out-of-core
    /// spill reloads (0 = fully resident, 1 = every block reloads —
    /// the `RunStats::reloads / load` ratio of a budgeted session).
    pub reload_frac: f64,
    /// Spill-store read bandwidth in bytes/s (prices one reload as
    /// vector-block bytes / disk_bw).
    pub disk_bw: f64,
    /// Whether the read-ahead pipeline overlaps reloads with compute
    /// (only the un-hidden part of each read is exposed) or reloads
    /// serialize in front of their block's kernel work.
    pub prefetch: bool,
    /// Expected link-layer retransmits per block exchange (0 = healthy
    /// fabric; `RunStats::comm_retries / comm_messages` measured). Each
    /// retransmit repeats the exchange's message time plus a backoff
    /// sleep.
    pub retry_rate: f64,
    /// Mean retry-policy backoff sleep per retransmit (seconds) — the
    /// `util::retry` schedule's expected delay at the observed attempt
    /// depth.
    pub t_backoff: f64,
    /// Fraction of the load's work units persisted to a checkpoint
    /// store (0 = checkpointing off; 1 = every unit written — a fresh
    /// `--checkpoint-dir` run; a resumed run writes only the remainder).
    pub ckpt_frac: f64,
    /// Checkpoint-store write bandwidth in bytes/s (prices one unit's
    /// tile blob as ≈ the metrics block's bytes / ckpt_bw).
    pub ckpt_bw: f64,
    /// Input-file bytes this node decodes before the pipeline starts
    /// (0 = synthetic generation; a `.bed` column span is
    /// n_vp × ⌈n_fp/4⌉ bytes, a VCF span its share of the text).
    pub ingest_bytes: u64,
    /// Input-file decode bandwidth in bytes/s (prices the one-time
    /// genotype ingest as ingest_bytes / ingest_bw; 0 disables the
    /// term).
    pub ingest_bw: f64,
    /// Internode fabric.
    pub net: CostModel,
    /// Host↔accelerator link.
    pub link: CostModel,
}

/// Predicted step-time breakdown.
#[derive(Debug, Clone, Copy)]
pub struct Prediction {
    pub t_comm: f64,
    pub t_transfer_v: f64,
    pub t_transfer_m: f64,
    pub t_gemm_total: f64,
    pub t_cpu: f64,
    /// Thread-dispatch overhead across the load's kernel calls —
    /// (threads−1)·t_spawn per call cold, 0 against a warm pool.
    pub t_dispatch: f64,
    /// Exposed out-of-core reload time: with prefetch, the first read
    /// plus whatever later reads exceed the compute that hides them;
    /// without, every reload serializes (`RunStats::t_stall`'s analytic
    /// counterpart).
    pub t_stall: f64,
    /// Fault-recovery cost: expected retransmits × (message time +
    /// backoff sleep) across the load's exchanges
    /// (`RunStats::comm_retries`' analytic counterpart; 0 healthy).
    pub t_retry: f64,
    /// Checkpoint-write cost: persisted units × blob write time
    /// (`RunStats::ckpt_writes/ckpt_bytes`' analytic counterpart;
    /// 0 with checkpointing off).
    pub t_ckpt: f64,
    /// One-time input-file decode cost: ingest_bytes / ingest_bw
    /// (`RunStats::geno_calls`' analytic counterpart; 0 for synthetic
    /// inputs or when no bandwidth is given).
    pub t_ingest: f64,
    pub total: f64,
}

impl Prediction {
    /// Fraction of the pipeline spent in mGEMM — the paper's "mGEMM
    /// hides everything" regime indicator (→ 1 for large blocks).
    pub fn gemm_fraction(&self) -> f64 {
        self.t_gemm_total / self.total
    }
}

/// Bytes of one vector block (n_fp × n_vp elements).
fn vblock_bytes(m: &ModelInput) -> u64 {
    (m.nfp * m.nvp * m.elem_bytes) as u64
}

/// Bytes of one metrics block (n_vp² values).
fn mblock_bytes(m: &ModelInput) -> u64 {
    (m.nvp * m.nvp * m.elem_bytes) as u64
}

/// Effective per-node mGEMM block count after symmetry halving: the
/// triangular kernel does (n_vp − 1)/(2 n_vp) ≈ 1/2 of a full block's
/// elementwise ops on each diagonal block.
fn effective_blocks(m: &ModelInput) -> f64 {
    let diag = m.diag_load.min(m.load) as f64;
    let tri_factor = if m.triangular { 0.5 } else { 1.0 };
    (m.load as f64 - diag) + diag * tri_factor
}

/// Kernel-time divisor from row-panel thread parallelism × SIMD lane
/// width (the mGEMM term scales; comm/transfer/CPU terms do not).
/// `t_gemm` is the *scalar single-thread* kernel time; a measured
/// time from an already-vectorized kernel should be fed with
/// `lane_width = 1`.
fn kernel_speedup(m: &ModelInput) -> f64 {
    (m.threads.max(1) * m.lane_width.max(1)) as f64
}

/// Per-kernel-call thread dispatch cost: (threads − 1) spawn+joins per
/// call when the pool is cold, ~0 once calls dispatch to the warm
/// persistent pool (the pool-amortization term — it is what turns a
/// per-call overhead into a once-per-process one).
fn dispatch_per_call(m: &ModelInput) -> f64 {
    if m.pool_warm || m.threads <= 1 {
        0.0
    } else {
        m.t_spawn * (m.threads - 1) as f64
    }
}

/// Exposed reload time for `n_reload` spill reads of `t_r` seconds
/// each, when `t_c` seconds of per-block compute are available to hide
/// each one. The double-buffered pipeline exposes the first read fully
/// (nothing computes yet) and later reads only by the amount they
/// outrun compute; without prefetch every read serializes.
fn stall_time(m: &ModelInput, t_c: f64) -> f64 {
    let n_reload = m.reload_frac.clamp(0.0, 1.0) * m.load as f64;
    if n_reload <= 0.0 || m.disk_bw <= 0.0 {
        return 0.0;
    }
    let t_r = vblock_bytes(m) as f64 / m.disk_bw;
    if m.prefetch {
        t_r + (n_reload - 1.0).max(0.0) * (t_r - t_c).max(0.0)
    } else {
        n_reload * t_r
    }
}

/// Expected fault-recovery time over `exchanges` block exchanges of
/// `t_msg` seconds each: each retransmit repeats the message and
/// sleeps the backoff. Comm faults are rare events priced linearly —
/// the healthy-fabric case (`retry_rate = 0`) contributes exactly 0.
fn retry_time(m: &ModelInput, t_msg: f64, exchanges: f64) -> f64 {
    if m.retry_rate <= 0.0 {
        return 0.0;
    }
    m.retry_rate * exchanges * (t_msg + m.t_backoff)
}

/// Checkpoint-write time for `units` persistable work units: each
/// unit's tile blob is ≈ one metrics block, written at `ckpt_bw`.
/// Writes are off the critical kernel path but not free — campaigns
/// trade this term for restartability.
fn ckpt_time(m: &ModelInput, units: f64) -> f64 {
    let frac = m.ckpt_frac.clamp(0.0, 1.0);
    if frac <= 0.0 || m.ckpt_bw <= 0.0 {
        return 0.0;
    }
    frac * units * (mblock_bytes(m) as f64 / m.ckpt_bw)
}

/// One-time genotype-ingest time: the node's input-file span decoded
/// at `ingest_bw`. It is paid before the pipeline starts (nothing
/// hides it) but amortizes over the campaign — a session reusing the
/// cached blocks pays it once, not per run.
fn ingest_time(m: &ModelInput) -> f64 {
    if m.ingest_bytes == 0 || m.ingest_bw <= 0.0 {
        return 0.0;
    }
    m.ingest_bytes as f64 / m.ingest_bw
}

/// 2-way model (§6.3), extended with the triangular-diag,
/// thread-parallel, SIMD-lane, pool-dispatch, and out-of-core reload
/// terms.
pub fn predict_2way(m: &ModelInput) -> Prediction {
    let t_comm = m.net.msg_time(vblock_bytes(m));
    let t_tv = m.link.msg_time(vblock_bytes(m));
    let t_tm = m.link.msg_time(mblock_bytes(m));
    let t_gemm_total = effective_blocks(m) * m.t_gemm / kernel_speedup(m);
    // One kernel call per block in the load: each pays the dispatch
    // overhead until the pool is warm.
    let t_dispatch = m.load as f64 * dispatch_per_call(m);
    // One block's kernel time is the compute window a prefetched
    // reload can hide behind.
    let t_stall = stall_time(m, m.t_gemm / kernel_speedup(m));
    // 2-way: one ring exchange and one checkpointable unit per block.
    let t_retry = retry_time(m, t_comm, m.load as f64);
    let t_ckpt = ckpt_time(m, m.load as f64);
    let t_ingest = ingest_time(m);
    let total = t_comm
        + t_tv
        + t_gemm_total
        + t_tm
        + m.t_cpu
        + t_dispatch
        + t_stall
        + t_retry
        + t_ckpt
        + t_ingest;
    Prediction {
        t_comm,
        t_transfer_v: t_tv,
        t_transfer_m: t_tm,
        t_gemm_total,
        t_cpu: m.t_cpu,
        t_dispatch,
        t_stall,
        t_retry,
        t_ckpt,
        t_ingest,
        total,
    }
}

/// 3-way model (§6.3). Each slice runs a pipeline of
/// (n_vp/6)/n_st mGEMM steps plus 3 startup 2-way mGEMMs. Thread
/// parallelism scales the mGEMM term (diag sub-slice skipping is
/// already part of the tetrahedral slice accounting).
pub fn predict_3way(m: &ModelInput) -> Prediction {
    let t_comm = m.net.msg_time(vblock_bytes(m));
    let t_tv = m.link.msg_time(vblock_bytes(m));
    let t_tm = m.link.msg_time(mblock_bytes(m));
    let t_gemm_eff = m.t_gemm / kernel_speedup(m);
    let steps_per_slice = 3.0 + (m.nvp as f64 / 6.0) / m.nst as f64;
    // Every mGEMM step of every slice is a kernel call — each pays the
    // dispatch overhead until the pool is warm.
    let dispatch_per_slice = steps_per_slice * dispatch_per_call(m);
    let per_slice =
        steps_per_slice * t_gemm_eff + 3.0 * t_tv + 4.0 * t_tm + m.t_cpu + dispatch_per_slice;
    let t_gemm_total = m.load as f64 * steps_per_slice * t_gemm_eff;
    let t_dispatch = m.load as f64 * dispatch_per_slice;
    // A slice's whole mGEMM pipeline is the window hiding its reload.
    let t_stall = stall_time(m, steps_per_slice * t_gemm_eff);
    // 3-way: one slice exchange per load entry; each mGEMM step of
    // each slice is a checkpointable unit.
    let t_retry = retry_time(m, t_comm, m.load as f64);
    let t_ckpt = ckpt_time(m, m.load as f64 * steps_per_slice);
    let t_ingest = ingest_time(m);
    let total = t_comm + t_tv + m.load as f64 * per_slice + t_stall + t_retry + t_ckpt + t_ingest;
    Prediction {
        t_comm,
        t_transfer_v: t_tv,
        t_transfer_m: t_tm,
        t_gemm_total,
        t_cpu: m.t_cpu,
        t_dispatch,
        t_stall,
        t_retry,
        t_ckpt,
        t_ingest,
        total,
    }
}

/// Serving-turnaround inputs: what one queued request experiences in
/// front of a `comet serve` scheduler. `t_request` is the service time
/// of one run (typically a [`predict_2way`]/[`predict_3way`] total),
/// `t_ingest` the cost of re-ingesting a dataset's blocks after a
/// cache eviction, and `miss_rate` the expected block-cache miss
/// fraction (0 = every block resident, 1 = fully cold).
#[derive(Debug, Clone, Copy)]
pub struct ServeInput {
    /// Requests already queued ahead of this one (across shards).
    pub queued: usize,
    /// Shard worker threads draining the queues.
    pub workers: usize,
    /// Service time of one request (seconds).
    pub t_request: f64,
    /// Full block re-ingest time for the request's dataset (seconds).
    pub t_ingest: f64,
    /// Expected block-cache miss fraction in [0, 1].
    pub miss_rate: f64,
}

/// Predicted serving turnaround breakdown.
#[derive(Debug, Clone, Copy)]
pub struct ServePrediction {
    /// Time spent queued behind earlier requests.
    pub t_queue_wait: f64,
    /// Eviction-refill term: expected re-ingest work on cache misses.
    pub t_refill: f64,
    /// Service time including the refill (what the worker spends).
    pub t_service: f64,
    /// Queue wait + service: submit-to-Done turnaround.
    pub total: f64,
}

/// Serving turnaround model: `queued` requests drain `workers`-wide,
/// so a new submission waits ⌈queued/workers⌉ service slots, then pays
/// its own service time plus the expected eviction-refill cost
/// (`miss_rate × t_ingest` — zero against a warm, unevicted cache;
/// the full ingest when budget pressure evicted its blocks).
pub fn predict_serve(m: &ServeInput) -> ServePrediction {
    let workers = m.workers.max(1);
    let slots_ahead = m.queued.div_ceil(workers) as f64;
    let t_refill = m.miss_rate.clamp(0.0, 1.0) * m.t_ingest;
    let t_service = m.t_request + t_refill;
    let t_queue_wait = slots_ahead * t_service;
    ServePrediction { t_queue_wait, t_refill, t_service, total: t_queue_wait + t_service }
}

/// Tuning advice mirroring §6.3: returns (npv, npr, nst) for a target
/// node count and memory budget, maximizing per-node block size then
/// load.
pub fn advise(np: usize, nv: usize, mem_bytes_per_node: u64, elem_bytes: usize, num_way: usize) -> (usize, usize, usize) {
    // Grow npv only until the per-node block fits memory (vectors +
    // metrics block + double buffers ≈ 4 blocks).
    let mut npv = 1;
    while npv < np {
        let nvp = nv.div_ceil(npv);
        let need = 4 * (nvp * nvp * elem_bytes) as u64;
        if need <= mem_bytes_per_node {
            break;
        }
        npv += 1;
    }
    let npv = npv.min(np).max(1);
    let npr = (np / npv).max(1);
    let nst = if num_way == 3 {
        // Keep stages few but big enough that a stage's metrics fit.
        let nvp = nv.div_ceil(npv);
        let stage_bytes = |nst: usize| ((nvp / 6 / nst.max(1)) * nvp * nvp * elem_bytes) as u64;
        let mut nst = 1;
        while stage_bytes(nst) > mem_bytes_per_node && nst < nvp {
            nst *= 2;
        }
        nst
    } else {
        1
    };
    (npv, npr, nst)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base() -> ModelInput {
        ModelInput {
            nfp: 5000,
            nvp: 10_240,
            elem_bytes: 8,
            t_gemm: 6.5, // Table 1 scale: DP mGEMM seconds
            t_cpu: 0.1,
            load: 13,
            diag_load: 0,
            threads: 1,
            lane_width: 1,
            t_spawn: 0.0,
            pool_warm: true,
            triangular: false,
            nst: 16,
            reload_frac: 0.0,
            disk_bw: 2e9,
            prefetch: true,
            retry_rate: 0.0,
            t_backoff: 0.0,
            ckpt_frac: 0.0,
            ckpt_bw: 0.0,
            ingest_bytes: 0,
            ingest_bw: 0.0,
            net: CostModel::gemini(),
            link: CostModel::pcie2(),
        }
    }

    #[test]
    fn two_way_gemm_dominates_at_paper_scale() {
        // §6.6's setting: big blocks, load 13 → mGEMM fraction ≳ 0.9.
        let p = predict_2way(&base());
        assert!(p.gemm_fraction() > 0.9, "fraction={}", p.gemm_fraction());
    }

    #[test]
    fn two_way_small_blocks_lose_efficiency() {
        // §6.8's n_f=385 regime: shallow mGEMMs hide less of the fixed
        // transfer cost (the metrics block is n_vp² regardless of n_f),
        // so the mGEMM fraction must drop vs. the deep-vector setting.
        let deep = predict_2way(&base()).gemm_fraction();
        let mut m = base();
        m.nfp = 385;
        m.t_gemm *= 385.0 / 5000.0; // GEMM time shrinks with depth
        m.load = 1; // §6.8 runs npv = np: one block per node
        let shallow = predict_2way(&m).gemm_fraction();
        assert!(shallow < deep, "shallow={shallow} deep={deep}");
        assert!(shallow < 0.9, "shallow={shallow}");
    }

    #[test]
    fn higher_load_raises_gemm_fraction() {
        let mut m = base();
        m.load = 1;
        let lo = predict_2way(&m).gemm_fraction();
        m.load = 13;
        let hi = predict_2way(&m).gemm_fraction();
        assert!(hi > lo);
    }

    #[test]
    fn three_way_fewer_stages_more_efficient() {
        // §6.3: "The value of n_st should be kept small"; fewer stages →
        // more mGEMM steps per slice → higher mGEMM fraction.
        let mut m = base();
        m.nvp = 2880;
        m.t_gemm = 0.5;
        m.load = 6;
        m.nst = 16;
        let few = predict_3way(&m).gemm_fraction();
        m.nst = 480; // maximally staged
        let many = predict_3way(&m).gemm_fraction();
        assert!(few > many, "few={few} many={many}");
    }

    #[test]
    fn threads_scale_only_the_gemm_term() {
        let m1 = base();
        let m4 = ModelInput { threads: 4, ..base() };
        let p1 = predict_2way(&m1);
        let p4 = predict_2way(&m4);
        assert!((p4.t_gemm_total - p1.t_gemm_total / 4.0).abs() < 1e-12);
        assert_eq!(p4.t_comm, p1.t_comm);
        assert_eq!(p4.t_cpu, p1.t_cpu);
        assert!(p4.total < p1.total);
        let p3_1 = predict_3way(&m1);
        let p3_4 = predict_3way(&ModelInput { threads: 4, ..base() });
        assert!(p3_4.t_gemm_total < p3_1.t_gemm_total);
    }

    #[test]
    fn triangular_halves_diag_blocks_only() {
        // One diag block among 13: triangular saves t_gemm/2.
        let full = predict_2way(&ModelInput { diag_load: 1, ..base() });
        let tri = predict_2way(&ModelInput { diag_load: 1, triangular: true, ..base() });
        assert!((full.t_gemm_total - tri.t_gemm_total - 0.5 * base().t_gemm).abs() < 1e-12);
        // No diag blocks → the flag changes nothing.
        let a = predict_2way(&ModelInput { triangular: true, ..base() });
        assert_eq!(a.t_gemm_total, predict_2way(&base()).t_gemm_total);
    }

    #[test]
    fn totals_are_sums_of_parts_2way() {
        let m = ModelInput {
            threads: 4,
            t_spawn: 1e-4,
            pool_warm: false,
            retry_rate: 0.01,
            t_backoff: 2e-4,
            ckpt_frac: 1.0,
            ckpt_bw: 1e9,
            ingest_bytes: 1 << 30,
            ingest_bw: 5e8,
            ..base()
        };
        let p = predict_2way(&m);
        let sum = p.t_comm
            + p.t_transfer_v
            + p.t_gemm_total
            + p.t_transfer_m
            + p.t_cpu
            + p.t_dispatch
            + p.t_stall
            + p.t_retry
            + p.t_ckpt
            + p.t_ingest;
        assert!((p.total - sum).abs() < 1e-12);
    }

    #[test]
    fn healthy_fabric_and_no_checkpointing_cost_nothing() {
        // The default inputs predict exactly the fault-free, no-ckpt
        // pipeline: both robustness terms are identically zero and the
        // total matches a model without them.
        let p = predict_2way(&base());
        assert_eq!(p.t_retry, 0.0);
        assert_eq!(p.t_ckpt, 0.0);
        let p3 = predict_3way(&base());
        assert_eq!(p3.t_retry, 0.0);
        assert_eq!(p3.t_ckpt, 0.0);
        assert_eq!(p.t_ingest, 0.0);
        assert_eq!(p3.t_ingest, 0.0);
    }

    #[test]
    fn ingest_term_prices_input_bytes_at_decode_bandwidth() {
        // 512 MB of `.bed` columns at 256 MB/s → 2 s, added once to
        // both decompositions' totals.
        let m = ModelInput { ingest_bytes: 512 << 20, ingest_bw: 256e6, ..base() };
        let p0 = predict_2way(&base());
        let p = predict_2way(&m);
        let expect = (512u64 << 20) as f64 / 256e6;
        assert!((p.t_ingest - expect).abs() < 1e-12, "t_ingest={}", p.t_ingest);
        assert!((p.total - p0.total - expect).abs() < 1e-9);
        let p3 = predict_3way(&m);
        assert!((p3.t_ingest - expect).abs() < 1e-12);
        // Bytes without a bandwidth (or vice versa) disable the term.
        assert_eq!(predict_2way(&ModelInput { ingest_bw: 0.0, ..m }).t_ingest, 0.0);
        assert_eq!(predict_2way(&ModelInput { ingest_bytes: 0, ..m }).t_ingest, 0.0);
    }

    #[test]
    fn retry_term_prices_retransmits_linearly() {
        let m = ModelInput { retry_rate: 0.5, t_backoff: 1e-3, ..base() };
        let p0 = predict_2way(&base());
        let p = predict_2way(&m);
        // Each expected retransmit repeats the exchange's message time
        // plus the backoff, over load exchanges.
        let expect = 0.5 * m.load as f64 * (p.t_comm + 1e-3);
        assert!((p.t_retry - expect).abs() < 1e-12, "t_retry={}", p.t_retry);
        assert!((p.total - p0.total - expect).abs() < 1e-12);
        // Doubling the rate doubles the term.
        let p2 = predict_2way(&ModelInput { retry_rate: 1.0, ..m });
        assert!((p2.t_retry - 2.0 * p.t_retry).abs() < 1e-12);
    }

    #[test]
    fn ckpt_term_scales_with_fraction_and_units() {
        let m = ModelInput { ckpt_frac: 1.0, ckpt_bw: 1e9, ..base() };
        let p = predict_2way(&m);
        // load units × one metrics blob each at ckpt_bw.
        let blob = (m.nvp * m.nvp * m.elem_bytes) as f64 / 1e9;
        assert!((p.t_ckpt - m.load as f64 * blob).abs() < 1e-9, "t_ckpt={}", p.t_ckpt);
        // A resumed run rewriting half the units pays half.
        let half = predict_2way(&ModelInput { ckpt_frac: 0.5, ..m });
        assert!((half.t_ckpt - 0.5 * p.t_ckpt).abs() < 1e-12);
        // Out-of-range fractions clamp instead of extrapolating.
        let over = predict_2way(&ModelInput { ckpt_frac: 3.0, ..m });
        assert_eq!(over.t_ckpt, p.t_ckpt);
        // 3-way persists one unit per mGEMM step of every slice.
        let m3 = ModelInput { nvp: 2880, t_gemm: 0.5, load: 6, nst: 16, ..m };
        let p3 = predict_3way(&m3);
        let steps = 3.0 + (2880.0 / 6.0) / 16.0;
        let blob3 = (m3.nvp * m3.nvp * m3.elem_bytes) as f64 / 1e9;
        assert!((p3.t_ckpt - 6.0 * steps * blob3).abs() < 1e-9, "t_ckpt={}", p3.t_ckpt);
    }

    #[test]
    fn reloads_hidden_by_compute_expose_only_the_first_read() {
        // t_r = 409.6 MB / 1e8 B/s = 4.096 s < t_gemm = 6.5 s: every
        // reload after the first hides behind a block's kernel time, so
        // the pipeline exposes exactly one read.
        let m = ModelInput { reload_frac: 1.0, disk_bw: 1e8, ..base() };
        let p = predict_2way(&m);
        assert!((p.t_stall - 4.096).abs() < 1e-9, "t_stall={}", p.t_stall);
        // Without prefetch all 13 reads serialize.
        let serial = predict_2way(&ModelInput { prefetch: false, ..m });
        assert!((serial.t_stall - 13.0 * 4.096).abs() < 1e-9);
        assert!(p.total < serial.total);
        // No reloads → no stall term, totals match the resident model.
        assert_eq!(predict_2way(&base()).t_stall, 0.0);
    }

    #[test]
    fn slow_disk_exposes_the_bandwidth_gap_even_with_prefetch() {
        // t_r = 40.96 s > t_gemm: compute hides only 6.5 s of each
        // later read; the rest is exposed stall.
        let m = ModelInput { reload_frac: 1.0, disk_bw: 1e7, ..base() };
        let p = predict_2way(&m);
        let expect = 40.96 + 12.0 * (40.96 - 6.5);
        assert!((p.t_stall - expect).abs() < 1e-9, "t_stall={}", p.t_stall);
        let serial = predict_2way(&ModelInput { prefetch: false, ..m });
        assert!(p.t_stall < serial.t_stall);
        // 3-way hides behind the whole slice pipeline, which at these
        // parameters exceeds t_r — one exposed read.
        let p3 = predict_3way(&m);
        assert!(p3.t_stall > 0.0);
        assert!(p3.t_stall < p.t_stall);
    }

    #[test]
    fn lane_width_scales_only_the_gemm_term() {
        let p1 = predict_2way(&base());
        let p4 = predict_2way(&ModelInput { lane_width: 4, ..base() });
        assert!((p4.t_gemm_total - p1.t_gemm_total / 4.0).abs() < 1e-12);
        assert_eq!(p4.t_comm, p1.t_comm);
        assert_eq!(p4.t_cpu, p1.t_cpu);
        // Lanes and threads compose multiplicatively.
        let p8 = predict_2way(&ModelInput { lane_width: 4, threads: 2, ..base() });
        assert!((p8.t_gemm_total - p1.t_gemm_total / 8.0).abs() < 1e-12);
    }

    #[test]
    fn cold_pool_pays_dispatch_warm_pool_does_not() {
        let cold = ModelInput { threads: 4, t_spawn: 1e-4, pool_warm: false, ..base() };
        let warm = ModelInput { pool_warm: true, ..cold };
        let pc = predict_2way(&cold);
        let pw = predict_2way(&warm);
        // load calls × (threads−1) spawns each.
        let expect = cold.load as f64 * 1e-4 * 3.0;
        assert!((pc.t_dispatch - expect).abs() < 1e-12);
        assert_eq!(pw.t_dispatch, 0.0);
        assert!((pc.total - pw.total - expect).abs() < 1e-12);
        // Single-threaded never dispatches, warm or cold.
        let serial = ModelInput { threads: 1, ..cold };
        assert_eq!(predict_2way(&serial).t_dispatch, 0.0);
        // 3-way: dispatch accrues per mGEMM step per slice.
        let p3c = predict_3way(&cold);
        let p3w = predict_3way(&warm);
        assert!(p3c.t_dispatch > 0.0);
        assert_eq!(p3w.t_dispatch, 0.0);
        assert!(p3c.total > p3w.total);
    }

    #[test]
    fn serve_empty_queue_waits_nothing() {
        let p = predict_serve(&ServeInput {
            queued: 0,
            workers: 2,
            t_request: 1.5,
            t_ingest: 0.4,
            miss_rate: 0.0,
        });
        assert_eq!(p.t_queue_wait, 0.0);
        assert_eq!(p.t_refill, 0.0);
        assert_eq!(p.total, 1.5);
    }

    #[test]
    fn serve_wait_scales_with_queue_and_shrinks_with_workers() {
        let base =
            ServeInput { queued: 8, workers: 1, t_request: 1.0, t_ingest: 0.0, miss_rate: 0.0 };
        let serial = predict_serve(&base);
        assert_eq!(serial.t_queue_wait, 8.0);
        let wide = predict_serve(&ServeInput { workers: 4, ..base });
        assert_eq!(wide.t_queue_wait, 2.0);
        assert!(wide.total < serial.total);
        // Partial slots round up: 5 queued over 4 workers waits 2 slots.
        let ragged = predict_serve(&ServeInput { queued: 5, workers: 4, ..base });
        assert_eq!(ragged.t_queue_wait, 2.0);
    }

    #[test]
    fn serve_refill_prices_cache_misses_and_clamps() {
        let base =
            ServeInput { queued: 0, workers: 2, t_request: 1.0, t_ingest: 0.5, miss_rate: 0.5 };
        let p = predict_serve(&base);
        assert!((p.t_refill - 0.25).abs() < 1e-12);
        assert!((p.t_service - 1.25).abs() < 1e-12);
        // Out-of-range rates clamp instead of extrapolating.
        let hot = predict_serve(&ServeInput { miss_rate: 7.0, ..base });
        assert!((hot.t_refill - 0.5).abs() < 1e-12);
        let cold = predict_serve(&ServeInput { miss_rate: -1.0, ..base });
        assert_eq!(cold.t_refill, 0.0);
    }

    #[test]
    fn advise_shrinks_blocks_until_memory_fits() {
        // 6 GB GPU memory (Titan) with nv = 200k DP.
        let (npv, npr, nst) = advise(32, 200_000, 6 << 30, 8, 2);
        assert!(npv > 1, "must split vectors to fit");
        assert_eq!(nst, 1);
        assert!(npv * npr <= 32 * 2);
        let nvp = 200_000usize.div_ceil(npv);
        assert!(4 * nvp * nvp * 8 <= (6usize << 30));
    }

    #[test]
    fn advise_3way_stages_when_needed() {
        let (_, _, nst) = advise(4, 50_000, 1 << 30, 8, 3);
        assert!(nst > 1, "3-way at this size must stage");
    }
}
