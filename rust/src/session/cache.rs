//! Cost-based LRU accounting for the session's block caches.
//!
//! A serving deployment cannot let ingested blocks accumulate without
//! bound — [`CostLedger`] is the budget enforcer. Every cached block
//! registers itself with its **resident byte cost** and an eviction
//! closure; when an insert pushes the resident total past the budget,
//! least-recently-used entries are evicted (their closures clear the
//! owning cache slots) until the total fits again. Evicted blocks are
//! not gone from the world — the next request that needs one simply
//! re-ingests it (a counted miss), trading ingest time for bounded
//! memory, which `perfmodel::predict_serve` prices as the
//! eviction-refill term.
//!
//! Counters (hits / misses / evictions / resident bytes) are the
//! cache-pressure signal: [`Session::run`](super::Session::run)
//! captures deltas around each run into
//! [`RunStats`](crate::coordinator::RunStats), so `comet run`, the
//! `comet batch` ledger, and `comet serve` all report the same numbers
//! `tests/serve_concurrency.rs` pins.
//!
//! Lock discipline: the ledger's internal lock is leaf-level — eviction
//! closures (which take block-slot locks) run strictly *after* it is
//! released, and cache code never calls into the ledger while holding a
//! slot lock. This keeps "thread A fills slot X while thread B's insert
//! evicts slot Y" deadlock-free in every interleaving.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Point-in-time view of a ledger's counters. Everything except
/// `bytes` is monotonic; `bytes` is the current resident total.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheSnapshot {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub bytes: u64,
    /// Evictions that landed in the spill store instead of being
    /// dropped (out-of-core sessions; see `vecdata::oocstore`).
    pub spills: u64,
    /// Bytes actually written to the spill store (a re-evicted block
    /// whose bytes are already on disk spills without a write).
    pub spill_bytes: u64,
    /// Misses served byte-identically from the spill store (no load,
    /// no ingest).
    pub reloads: u64,
    /// Bytes read back from the spill store.
    pub reload_bytes: u64,
    /// Spill writes abandoned after retries — the block degrades to
    /// re-ingest-on-next-touch instead of reload (never an error).
    pub spill_errors: u64,
}

/// Clears the cache slot that registered the entry. Must be callable
/// from any thread (runs on whichever thread's insert overflowed the
/// budget).
type Evictor = Box<dyn FnMut() + Send>;

struct Entry {
    id: u64,
    bytes: u64,
    evict: Evictor,
}

#[derive(Default)]
struct LedgerState {
    /// LRU order: front = coldest (next victim), back = hottest.
    entries: VecDeque<Entry>,
    bytes: u64,
}

/// See the module docs. One ledger spans *all* datasets of a session —
/// the budget is a per-process serving limit, not a per-dataset one.
pub struct CostLedger {
    budget: Option<u64>,
    next_id: AtomicU64,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    // Spill-pipeline counters: atomics only, so they are safe to bump
    // from eviction closures and reload paths that hold slot locks
    // (the lock-discipline note below concerns only the state mutex).
    spills: AtomicU64,
    spill_bytes: AtomicU64,
    reloads: AtomicU64,
    reload_bytes: AtomicU64,
    spill_errors: AtomicU64,
    state: Mutex<LedgerState>,
}

impl CostLedger {
    /// `budget = None` disables eviction (the pre-serving behavior):
    /// the ledger still counts, so cache pressure stays observable.
    pub fn new(budget: Option<u64>) -> Self {
        CostLedger {
            budget,
            next_id: AtomicU64::new(0),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            spills: AtomicU64::new(0),
            spill_bytes: AtomicU64::new(0),
            reloads: AtomicU64::new(0),
            reload_bytes: AtomicU64::new(0),
            spill_errors: AtomicU64::new(0),
            state: Mutex::new(LedgerState::default()),
        }
    }

    /// Record an eviction that landed in the spill store.
    /// `bytes_written` is 0 when the key's bytes were already on disk.
    pub fn note_spill(&self, bytes_written: u64) {
        self.spills.fetch_add(1, Ordering::Relaxed);
        self.spill_bytes.fetch_add(bytes_written, Ordering::Relaxed);
    }

    /// Record a miss served byte-identically from the spill store.
    pub fn note_reload(&self, bytes: u64) {
        self.reloads.fetch_add(1, Ordering::Relaxed);
        self.reload_bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    /// Record a spill write abandoned after retries (the block falls
    /// back to plain drop + re-ingest).
    pub fn note_spill_error(&self) {
        self.spill_errors.fetch_add(1, Ordering::Relaxed);
    }

    pub fn budget(&self) -> Option<u64> {
        self.budget
    }

    /// Allocate a ledger id for a slot about to be filled.
    pub fn next_id(&self) -> u64 {
        self.next_id.fetch_add(1, Ordering::Relaxed)
    }

    /// Record a cache hit and mark the entry most-recently-used. An
    /// unknown id (entry already evicted, or the fill's insert hasn't
    /// landed yet) still counts as a hit — the caller did find a
    /// resident block.
    pub fn touch(&self, id: u64) {
        self.hits.fetch_add(1, Ordering::Relaxed);
        let mut st = self.state.lock().unwrap();
        if let Some(pos) = st.entries.iter().position(|e| e.id == id) {
            if let Some(entry) = st.entries.remove(pos) {
                st.entries.push_back(entry);
            }
        }
    }

    /// Record a miss-and-fill: the entry becomes most-recently-used,
    /// then LRU victims are evicted until the resident total is back
    /// under budget. The just-inserted entry is never its own victim —
    /// a single block larger than the whole budget stays resident
    /// while in use (and is evicted by the next insert).
    pub fn insert(&self, id: u64, bytes: u64, evict: Evictor) {
        self.misses.fetch_add(1, Ordering::Relaxed);
        let mut victims = Vec::new();
        {
            let mut st = self.state.lock().unwrap();
            st.entries.push_back(Entry { id, bytes, evict });
            st.bytes += bytes;
            if let Some(budget) = self.budget {
                while st.bytes > budget && st.entries.len() > 1 {
                    let victim = st.entries.pop_front().expect("len > 1");
                    st.bytes -= victim.bytes;
                    victims.push(victim);
                }
            }
        }
        // Run evictors after releasing the ledger lock (see the module
        // docs' lock discipline).
        for mut v in victims {
            (v.evict)();
            self.evictions.fetch_add(1, Ordering::Relaxed);
        }
    }

    pub fn snapshot(&self) -> CacheSnapshot {
        let bytes = self.state.lock().unwrap().bytes;
        CacheSnapshot {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            bytes,
            spills: self.spills.load(Ordering::Relaxed),
            spill_bytes: self.spill_bytes.load(Ordering::Relaxed),
            reloads: self.reloads.load(Ordering::Relaxed),
            reload_bytes: self.reload_bytes.load(Ordering::Relaxed),
            spill_errors: self.spill_errors.load(Ordering::Relaxed),
        }
    }

    /// Current resident ids in LRU order (coldest first) — test
    /// introspection for pinning victim order.
    pub fn resident_ids(&self) -> Vec<u64> {
        self.state.lock().unwrap().entries.iter().map(|e| e.id).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    /// A ledger plus a log of evicted ids, so tests can pin victim
    /// order exactly.
    fn ledger_with_log(budget: u64) -> (CostLedger, Arc<Mutex<Vec<u64>>>) {
        (CostLedger::new(Some(budget)), Arc::new(Mutex::new(Vec::new())))
    }

    fn insert_logged(ledger: &CostLedger, log: &Arc<Mutex<Vec<u64>>>, id: u64, bytes: u64) {
        let log = Arc::clone(log);
        ledger.insert(id, bytes, Box::new(move || log.lock().unwrap().push(id)));
    }

    #[test]
    fn lru_victim_order_is_insertion_order_without_touches() {
        let (ledger, log) = ledger_with_log(300);
        for id in 0..3 {
            insert_logged(&ledger, &log, id, 100);
        }
        assert_eq!(ledger.snapshot().bytes, 300);
        assert!(log.lock().unwrap().is_empty());
        // One more 100-byte entry: exactly the coldest (id 0) goes.
        insert_logged(&ledger, &log, 3, 100);
        assert_eq!(*log.lock().unwrap(), vec![0]);
        assert_eq!(ledger.resident_ids(), vec![1, 2, 3]);
        let snap = ledger.snapshot();
        assert_eq!(snap.bytes, 300);
        assert_eq!(snap.evictions, 1);
        assert_eq!(snap.misses, 4);
    }

    #[test]
    fn touch_rescues_an_entry_from_eviction() {
        let (ledger, log) = ledger_with_log(300);
        for id in 0..3 {
            insert_logged(&ledger, &log, id, 100);
        }
        ledger.touch(0); // id 0 becomes hottest; id 1 is now coldest
        insert_logged(&ledger, &log, 3, 100);
        assert_eq!(*log.lock().unwrap(), vec![1]);
        assert_eq!(ledger.resident_ids(), vec![2, 0, 3]);
        assert_eq!(ledger.snapshot().hits, 1);
    }

    #[test]
    fn oversized_entry_stays_resident_until_the_next_insert() {
        let (ledger, log) = ledger_with_log(100);
        insert_logged(&ledger, &log, 0, 500); // over budget but alone: kept
        assert_eq!(ledger.snapshot().bytes, 500);
        assert!(log.lock().unwrap().is_empty());
        insert_logged(&ledger, &log, 1, 50);
        assert_eq!(*log.lock().unwrap(), vec![0]);
        assert_eq!(ledger.snapshot().bytes, 50);
    }

    #[test]
    fn one_insert_can_evict_many() {
        let (ledger, log) = ledger_with_log(400);
        for id in 0..4 {
            insert_logged(&ledger, &log, id, 100);
        }
        insert_logged(&ledger, &log, 4, 350);
        // 350 + any one survivor would still exceed 400, so every
        // older entry goes, coldest first.
        assert_eq!(*log.lock().unwrap(), vec![0, 1, 2, 3]);
        assert_eq!(ledger.resident_ids(), vec![4]);
        assert_eq!(ledger.snapshot().bytes, 350);
        assert_eq!(ledger.snapshot().evictions, 4);
    }

    #[test]
    fn unbounded_ledger_counts_but_never_evicts() {
        let (ledger, log) = (CostLedger::new(None), Arc::new(Mutex::new(Vec::new())));
        for id in 0..50 {
            insert_logged(&ledger, &log, id, 1 << 20);
        }
        ledger.touch(0);
        let snap = ledger.snapshot();
        assert_eq!(snap.misses, 50);
        assert_eq!(snap.hits, 1);
        assert_eq!(snap.evictions, 0);
        assert_eq!(snap.bytes, 50 << 20);
        assert!(log.lock().unwrap().is_empty());
    }

    #[test]
    fn touch_of_evicted_id_is_a_tolerated_hit() {
        let (ledger, log) = ledger_with_log(100);
        insert_logged(&ledger, &log, 0, 100);
        insert_logged(&ledger, &log, 1, 100); // evicts 0
        ledger.touch(0); // already gone: counted, no panic, no resurrect
        assert_eq!(ledger.resident_ids(), vec![1]);
        assert_eq!(ledger.snapshot().hits, 1);
    }
}
