//! The session-first public API: a long-lived [`Session`] that owns the
//! expensive run setup — the PJRT service (and its compiled-executable
//! cache), and [`Dataset`] handles whose per-node blocks are ingested
//! **once per (dataset, representation)** and shared across every run
//! that touches them — so a server answering many requests over the
//! same genomic dataset pays input + ingest + compile once, not per
//! request.
//!
//! This is the shape of the paper's production campaigns (stage data to
//! the nodes once, keep kernels resident, push many metric sweeps
//! through) and of the large-scale GWAS solvers it cites: amortize
//! prepared operands across related computations, stream results out
//! instead of materializing them.
//!
//! ```text
//! Session ──owns──> PjrtService (lazy; executable cache persists)
//!    │   └─caches─> Dataset (per spec) ──caches──> Block per
//!    │                                             (repr, ingest key,
//!    │                                              grid slice)
//!    ├─ run(&RunRequest, &dyn ResultSink) → RunOutcome (stats+checksum;
//!    │      values stream through the sink as tiles)
//!    └─ run_collect(&RunRequest)          → RunOutcome with stores
//! ```
//!
//! [`RunRequest`] is the typed request builder; [`RunConfig`] remains
//! the serialized (TOML/CLI) form and lowers into a request via
//! [`Session::request_from_config`] — which is also how the
//! `comet batch` campaign driver maps a request file onto one session.
//!
//! Migration from the one-shot API: `coordinator::run(&cfg)` and
//! friends still work (they build a throwaway fresh-ingest provider
//! and legacy sinks internally); a long-lived caller replaces
//!
//! ```ignore
//! let out = coordinator::run(&cfg)?;              // re-ingests, re-compiles
//! ```
//!
//! with
//!
//! ```ignore
//! let session = Session::new();
//! let req = session.request_from_config(&cfg)?;   // dataset handle cached
//! let out = session.run_collect(&req)?;           // ingest-once, cache-warm
//! ```

pub mod cache;

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{ensure, Context, Result};

use self::cache::{CacheSnapshot, CostLedger};

use crate::config::{BackendKind, InputSource, Precision, RunConfig};
use crate::coordinator::{
    self, checkpoint::CheckpointStore, prefetch::ReadAhead, BlockProvider, RunOpts, RunOutcome,
};
use crate::decomp::Grid;
use crate::metrics::{Metric, MetricId};
use crate::output::sink::{FileSink, ResultSink, TeeRef};
use crate::runtime::{PjrtService, RuntimeClient};
use crate::util::Scalar;
use crate::vecdata::block::{Block, Repr};
use crate::vecdata::oocstore::{self, BlockStore, DirStore};
use crate::vecdata::SyntheticKind;

/// Identity of a dataset: where the vectors come from and the campaign
/// shape. Two requests naming equal specs share one [`Dataset`] (and
/// therefore its ingested blocks) within a session.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct DatasetSpec {
    pub input: InputSource,
    /// Total vectors n_v.
    pub nv: usize,
    /// Features per vector n_f.
    pub nf: usize,
}

impl DatasetSpec {
    pub fn synthetic(kind: SyntheticKind, seed: u64, nf: usize, nv: usize) -> Self {
        DatasetSpec { input: InputSource::Synthetic { kind, seed }, nv, nf }
    }

    pub fn file(path: impl Into<String>, nf: usize, nv: usize) -> Self {
        DatasetSpec { input: InputSource::File { path: path.into() }, nv, nf }
    }
}

/// Blocks are cached per metric representation *and* ingest
/// parameters ([`Metric::ingest_key`] — e.g. Sorensen's binarization
/// threshold) *and* grid slice: `load_block` slices by (npv, npf, pv,
/// pf), so different grids produce different block extents.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
struct BlockKey {
    repr: Repr,
    ingest_key: u64,
    npv: usize,
    npf: usize,
    pv: usize,
    pf: usize,
}

/// A resident cached block plus its [`CostLedger`] entry id (the
/// handle the ledger's LRU bookkeeping and eviction closures key on).
struct Cached<T: Scalar> {
    block: Block<T>,
    ledger_id: u64,
}

/// One cached block's slot. The per-key mutex makes concurrent fills
/// deterministic: ranks replicated along the npr axis ask for the
/// *same* (pv, pf) block, and only the first to arrive loads + ingests
/// it — the rest block briefly and reuse it (so even a single session
/// run ingests fewer blocks than a one-shot run, which loads once per
/// rank). Eviction clears the slot back to `None`; the next touch
/// re-ingests (a counted miss).
type BlockSlot<T> = Arc<Mutex<Option<Cached<T>>>>;

#[derive(Debug, Default)]
struct BlockCache<T: Scalar> {
    blocks: Mutex<HashMap<BlockKey, BlockSlot<T>>>,
}

struct DatasetInner {
    spec: DatasetSpec,
    f32_blocks: BlockCache<f32>,
    f64_blocks: BlockCache<f64>,
    /// Load-and-ingest operations actually performed (cache misses
    /// that could not be served from the spill store). The ingest-once
    /// contract: after the first run of a given (repr, ingest key,
    /// grid), this stays flat however many more runs the session
    /// serves over the dataset — a budget eviction in between costs a
    /// reload, not a re-ingest, as long as the spill store holds the
    /// bytes.
    ingests: AtomicU64,
    /// The owning session's byte-budget ledger (shared across all of
    /// the session's datasets).
    ledger: Arc<CostLedger>,
    /// The session's spill store (out-of-core sessions): budget
    /// evictions write the block's resident bytes here instead of
    /// dropping them; misses check here before re-ingesting. `None`
    /// restores the PR 7 drop-on-evict behavior.
    store: Option<Arc<dyn BlockStore>>,
    /// Per-dataset spill-key prefix (a hash of the spec), so datasets
    /// sharing one session store never collide.
    store_prefix: String,
}

/// A cheap, clonable handle to a session-cached dataset. Implements
/// [`BlockProvider`]: the coordinator's node programs pull their
/// ingested blocks straight out of the cache (or fill it on first
/// touch — node threads fill distinct keys, so the input phase stays
/// parallel).
#[derive(Clone)]
pub struct Dataset {
    inner: Arc<DatasetInner>,
}

impl Dataset {
    fn new(spec: DatasetSpec, ledger: Arc<CostLedger>, store: Option<Arc<dyn BlockStore>>) -> Self {
        let store_prefix = {
            use std::hash::{Hash, Hasher};
            let mut h = std::collections::hash_map::DefaultHasher::new();
            spec.hash(&mut h);
            format!("ds{:016x}", h.finish())
        };
        Dataset {
            inner: Arc::new(DatasetInner {
                spec,
                f32_blocks: BlockCache::default(),
                f64_blocks: BlockCache::default(),
                ingests: AtomicU64::new(0),
                ledger,
                store,
                store_prefix,
            }),
        }
    }

    /// The spill-store key of a block: dataset prefix + precision +
    /// representation + ingest parameters + grid slice. Flat and
    /// filename-safe (see [`BlockStore`]'s key contract).
    fn store_key<T: Scalar>(&self, key: &BlockKey) -> String {
        format!(
            "{}-w{}-{}-k{:016x}-{}x{}-{}-{}",
            self.inner.store_prefix,
            T::BYTES,
            key.repr.name(),
            key.ingest_key,
            key.npv,
            key.npf,
            key.pv,
            key.pf
        )
    }

    /// Serve a miss from the spill store, byte-identically, if the key
    /// was ever spilled. Transient store errors retry with backoff;
    /// permanent errors and poisoned files (checksum mismatch) surface
    /// as typed [`oocstore::StoreError`]s in the anyhow chain — never a
    /// silently wrong block.
    fn reload_from_store<T: Scalar>(&self, key: &BlockKey) -> Result<Option<Block<T>>> {
        let Some(store) = &self.inner.store else {
            return Ok(None);
        };
        let skey = self.store_key::<T>(key);
        let Some(bytes) = oocstore::with_retry(|| store.get(&skey))
            .with_context(|| format!("reload spilled block {skey}"))?
        else {
            return Ok(None);
        };
        let block = oocstore::decode::<T>(&bytes)
            .with_context(|| format!("decode spilled block {skey}"))?;
        self.inner.ledger.note_reload(block.resident_bytes());
        Ok(Some(block))
    }

    pub fn spec(&self) -> &DatasetSpec {
        &self.inner.spec
    }

    /// Load-and-ingest operations performed so far (cache misses).
    pub fn ingest_count(&self) -> u64 {
        self.inner.ingests.load(Ordering::Relaxed)
    }

    /// Ingested blocks currently cached (both precisions).
    pub fn cached_blocks(&self) -> usize {
        fn filled<T: Scalar>(m: &Mutex<HashMap<BlockKey, BlockSlot<T>>>) -> usize {
            m.lock().unwrap().values().filter(|s| s.lock().unwrap().is_some()).count()
        }
        filled(&self.inner.f32_blocks.blocks) + filled(&self.inner.f64_blocks.blocks)
    }

    /// Resident bytes of this dataset's cached blocks (both
    /// precisions) — slot counts alone hid actual memory pressure,
    /// since a packed Sorensen block is ~64× smaller than the float
    /// block of the same slice.
    pub fn cached_bytes(&self) -> u64 {
        fn bytes<T: Scalar>(m: &Mutex<HashMap<BlockKey, BlockSlot<T>>>) -> u64 {
            m.lock()
                .unwrap()
                .values()
                .filter_map(|s| s.lock().unwrap().as_ref().map(|c| c.block.resident_bytes()))
                .sum()
        }
        bytes(&self.inner.f32_blocks.blocks) + bytes(&self.inner.f64_blocks.blocks)
    }

    fn cached_block<T: Scalar>(
        &self,
        cache: &BlockCache<T>,
        cfg: &RunConfig,
        metric: &dyn Metric<T>,
        pv: usize,
        pf: usize,
    ) -> Result<Block<T>> {
        let spec = &self.inner.spec;
        ensure!(
            cfg.input == spec.input && cfg.nv == spec.nv && cfg.nf == spec.nf,
            "run config does not match its dataset handle (input/nv/nf differ)"
        );
        let key = BlockKey {
            repr: metric.preferred_repr(),
            ingest_key: metric.ingest_key(),
            npv: cfg.grid.npv,
            npf: cfg.grid.npf,
            pv,
            pf,
        };
        // Two-level locking: the map lock is held only to find/create
        // the key's slot, so node threads filling *different* blocks
        // load in parallel; the slot lock serializes same-key fills
        // (npr-replicated ranks, concurrent runs), guaranteeing exactly
        // one load + ingest per key — the counter-pinned contract.
        // Ledger calls happen strictly outside the slot lock (its
        // eviction closures take *other* slots' locks; see
        // `cache::CostLedger`'s lock discipline).
        let slot = {
            let mut map = cache.blocks.lock().unwrap();
            Arc::clone(map.entry(key).or_default())
        };
        let ledger = &self.inner.ledger;
        let mut guard = slot.lock().unwrap();
        if let Some(c) = guard.as_ref() {
            let (block, id) = (c.block.clone(), c.ledger_id);
            drop(guard);
            ledger.touch(id);
            return Ok(block);
        }
        // Miss: a previously spilled block reloads byte-identically
        // from the store (no load, no ingest); otherwise load + ingest
        // fresh.
        let block = match self.reload_from_store::<T>(&key)? {
            Some(block) => block,
            None => {
                let block = metric.ingest(coordinator::load_block::<T>(cfg, pv, pf)?);
                self.inner.ingests.fetch_add(1, Ordering::Relaxed);
                block
            }
        };
        let ledger_id = ledger.next_id();
        *guard = Some(Cached { block: block.clone(), ledger_id });
        drop(guard);
        let evict_slot = Arc::clone(&slot);
        let evictor: Box<dyn FnMut() + Send> = match &self.inner.store {
            // No spill store: eviction drops the block (re-ingest on
            // next touch — the PR 7 behavior).
            None => Box::new(move || *evict_slot.lock().unwrap() = None),
            // Spill store: eviction moves the resident bytes to disk.
            // Blocks are immutable per key, so a key already on disk
            // skips the write; a write that fails permanently degrades
            // to drop + re-ingest (counted, never an error — eviction
            // runs on whichever thread overflowed the budget and has
            // no caller to report to).
            Some(store) => {
                let store = Arc::clone(store);
                let skey = self.store_key::<T>(&key);
                let spill_ledger = Arc::clone(ledger);
                Box::new(move || {
                    let taken = evict_slot.lock().unwrap().take();
                    if let Some(c) = taken {
                        if store.contains(&skey) {
                            spill_ledger.note_spill(0);
                            return;
                        }
                        let blob = oocstore::encode(&c.block);
                        match oocstore::with_retry(|| store.put(&skey, &blob)) {
                            Ok(()) => spill_ledger.note_spill(blob.len() as u64),
                            Err(_) => spill_ledger.note_spill_error(),
                        }
                    }
                })
            }
        };
        ledger.insert(ledger_id, block.resident_bytes(), evictor);
        Ok(block)
    }
}

impl BlockProvider for Dataset {
    fn block_f32(
        &self,
        cfg: &RunConfig,
        metric: &dyn Metric<f32>,
        pv: usize,
        pf: usize,
    ) -> Result<Block<f32>> {
        self.cached_block(&self.inner.f32_blocks, cfg, metric, pv, pf)
    }

    fn block_f64(
        &self,
        cfg: &RunConfig,
        metric: &dyn Metric<f64>,
        pv: usize,
        pf: usize,
    ) -> Result<Block<f64>> {
        self.cached_block(&self.inner.f64_blocks, cfg, metric, pv, pf)
    }
}

/// A validated, typed run request bound to a session [`Dataset`].
/// Built with [`RunRequest::builder`] or lowered from a [`RunConfig`]
/// via [`Session::request_from_config`]. Internally a request *is* a
/// validated `RunConfig` — the config type stays the single canonical
/// lowered form (TOML, CLI, `run.meta` all speak it).
#[derive(Clone)]
pub struct RunRequest {
    dataset: Dataset,
    cfg: RunConfig,
}

impl RunRequest {
    /// Start a request over `dataset` computing `metric`. Defaults:
    /// 2-way, f64, optimized CPU backend, 1 thread, 1×1×1 grid,
    /// unstaged, no file output.
    pub fn builder(dataset: Dataset, metric: MetricId) -> RunRequestBuilder {
        let spec = dataset.spec().clone();
        let cfg = RunConfig {
            metric,
            nv: spec.nv,
            nf: spec.nf,
            input: spec.input,
            // Result delivery is the sink's business, not the
            // request's; the legacy flag stays false here.
            store_metrics: false,
            ..RunConfig::default()
        };
        RunRequestBuilder { dataset, cfg }
    }

    /// The lowered, validated config this request runs as.
    pub fn config(&self) -> &RunConfig {
        &self.cfg
    }

    pub fn dataset(&self) -> &Dataset {
        &self.dataset
    }
}

/// Builder for [`RunRequest`] — the typed replacement for ad-hoc
/// `RunConfig` field mutation. `build` validates the assembled run
/// (metric/way support, domain-compatible generators, grid bounds).
pub struct RunRequestBuilder {
    dataset: Dataset,
    cfg: RunConfig,
}

impl RunRequestBuilder {
    pub fn num_way(mut self, num_way: usize) -> Self {
        self.cfg.num_way = num_way;
        self
    }

    pub fn precision(mut self, precision: Precision) -> Self {
        self.cfg.precision = precision;
        self
    }

    pub fn backend(mut self, backend: BackendKind) -> Self {
        self.cfg.backend = backend;
        self
    }

    pub fn threads(mut self, threads: usize) -> Self {
        self.cfg.threads = threads;
        self
    }

    pub fn grid(mut self, grid: Grid) -> Self {
        self.cfg.grid = grid;
        self
    }

    pub fn num_stage(mut self, num_stage: usize) -> Self {
        self.cfg.num_stage = num_stage;
        self
    }

    pub fn stage(mut self, stage: usize) -> Self {
        self.cfg.stage = Some(stage);
        self
    }

    pub fn output_dir(mut self, dir: impl Into<String>) -> Self {
        self.cfg.output_dir = Some(dir.into());
        self
    }

    pub fn output_threshold(mut self, threshold: f64) -> Self {
        self.cfg.output_threshold = Some(threshold);
        self
    }

    pub fn build(self) -> Result<RunRequest> {
        self.cfg.validate()?;
        Ok(RunRequest { dataset: self.dataset, cfg: self.cfg })
    }
}

/// Resource budgets a serving deployment sets on a session's caches.
/// The default (`None` everywhere) is the pre-serving behavior: cache
/// forever, never evict.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SessionLimits {
    /// Byte budget for ingested blocks across *every* dataset of the
    /// session. Past it, least-recently-used blocks are evicted —
    /// spilled to the session's on-disk store (when `spill` is on) or
    /// dropped for re-ingest on next touch (bounded memory instead of
    /// OOM either way).
    pub block_cache_bytes: Option<u64>,
    /// Slot budget for the PJRT service's compiled-executable cache
    /// (LRU within the service; see `runtime`).
    pub exec_cache_slots: Option<usize>,
    /// Spill budget-evicted blocks to a per-session on-disk store
    /// (`vecdata::oocstore`) and reload them byte-identically on next
    /// touch, instead of dropping and re-ingesting. On by default; only
    /// meaningful together with `block_cache_bytes` (an unbudgeted
    /// session never evicts, so it never spills).
    pub spill: bool,
}

impl Default for SessionLimits {
    fn default() -> Self {
        SessionLimits { block_cache_bytes: None, exec_cache_slots: None, spill: true }
    }
}

/// The long-lived service object. See the module docs for the shape;
/// thread-safe (`&self` methods throughout), so one session can serve
/// concurrent callers.
pub struct Session {
    artifact_dir: PathBuf,
    limits: SessionLimits,
    /// Block-cache byte accounting + eviction, shared by every dataset
    /// handle this session creates.
    ledger: Arc<CostLedger>,
    /// The out-of-core spill store (budgeted sessions with `spill` on;
    /// `None` otherwise). Shared by every dataset handle; keys are
    /// prefixed per dataset.
    spill_store: Option<Arc<dyn BlockStore>>,
    pjrt: Mutex<Option<PjrtService>>,
    datasets: Mutex<HashMap<DatasetSpec, Dataset>>,
    /// Campaign checkpoint area (`--checkpoint-dir`): when set, every
    /// run this session serves persists completed work units and
    /// resumes past ones bit-identically. `None` (the default) runs
    /// without checkpointing.
    checkpoint: Mutex<Option<Arc<CheckpointStore>>>,
}

impl Default for Session {
    fn default() -> Self {
        Self::new()
    }
}

impl Session {
    /// A session over the default `artifacts` directory (only touched
    /// if a request names the PJRT backend).
    pub fn new() -> Self {
        Self::with_artifacts("artifacts")
    }

    pub fn with_artifacts(artifact_dir: impl Into<PathBuf>) -> Self {
        Self::with_limits(artifact_dir, SessionLimits::default())
    }

    /// A session with cache budgets — the `comet serve` constructor.
    /// A budgeted session with `limits.spill` on (the default) gets a
    /// process-unique temp-dir spill store, removed when the session
    /// drops.
    pub fn with_limits(artifact_dir: impl Into<PathBuf>, limits: SessionLimits) -> Self {
        let store = (limits.spill && limits.block_cache_bytes.is_some())
            .then(|| Arc::new(DirStore::temp("session")) as Arc<dyn BlockStore>);
        Self::assemble(artifact_dir, limits, store)
    }

    /// A session spilling through an explicit [`BlockStore`] — how the
    /// fault-injection rigs wire a scripted failing store in, and how a
    /// deployment points spills at a specific volume.
    pub fn with_spill_store(
        artifact_dir: impl Into<PathBuf>,
        limits: SessionLimits,
        store: Arc<dyn BlockStore>,
    ) -> Self {
        Self::assemble(artifact_dir, limits, Some(store))
    }

    fn assemble(
        artifact_dir: impl Into<PathBuf>,
        limits: SessionLimits,
        spill_store: Option<Arc<dyn BlockStore>>,
    ) -> Self {
        Session {
            artifact_dir: artifact_dir.into(),
            limits,
            ledger: Arc::new(CostLedger::new(limits.block_cache_bytes)),
            spill_store,
            pjrt: Mutex::new(None),
            datasets: Mutex::new(HashMap::new()),
            checkpoint: Mutex::new(None),
        }
    }

    /// Attach (or detach, with `None`) a campaign checkpoint store.
    /// Subsequent runs persist completed work units under it and skip +
    /// replay units a previous run already finished — the
    /// `--checkpoint-dir` resume path. See
    /// [`crate::coordinator::checkpoint`] for the key scheme and the
    /// bit-identity contract.
    pub fn set_checkpoint_store(&self, store: Option<Arc<CheckpointStore>>) {
        *self.checkpoint.lock().unwrap() = store;
    }

    /// Convenience for the CLI: checkpoint into `dir`.
    pub fn checkpoint_to_dir(&self, dir: impl AsRef<std::path::Path>) {
        self.set_checkpoint_store(Some(Arc::new(CheckpointStore::dir(dir))));
    }

    pub fn limits(&self) -> SessionLimits {
        self.limits
    }

    /// Block-cache pressure counters (hits / misses / evictions /
    /// resident bytes) across all of this session's datasets.
    pub fn cache_stats(&self) -> CacheSnapshot {
        self.ledger.snapshot()
    }

    /// Get-or-create the dataset handle for `spec`. Equal specs return
    /// the same handle (and therefore share ingested blocks).
    pub fn dataset(&self, spec: DatasetSpec) -> Dataset {
        let mut map = self.datasets.lock().unwrap();
        map.entry(spec.clone())
            .or_insert_with(|| {
                Dataset::new(spec, Arc::clone(&self.ledger), self.spill_store.clone())
            })
            .clone()
    }

    /// Lower a serialized [`RunConfig`] (TOML file, CLI flags, one
    /// entry of a `comet batch` file) into a request against this
    /// session's dataset cache.
    pub fn request_from_config(&self, cfg: &RunConfig) -> Result<RunRequest> {
        cfg.validate()?;
        let spec = DatasetSpec { input: cfg.input.clone(), nv: cfg.nv, nf: cfg.nf };
        Ok(RunRequest { dataset: self.dataset(spec), cfg: cfg.clone() })
    }

    /// Run a request, streaming result tiles through `sink`. The
    /// outcome carries stats and the §5 checksum — bit-identical to a
    /// one-shot `coordinator::run` of the same config, with the
    /// dataset's ingest and the PJRT executable cache amortized across
    /// every run this session has served.
    ///
    /// A request built with an output directory
    /// ([`RunRequestBuilder::output_dir`]) gets its §6.8 file sink (and
    /// `run.meta`) teed in alongside `sink` — `output_dir` means the
    /// same thing on every path.
    pub fn run(&self, req: &RunRequest, sink: &dyn ResultSink) -> Result<RunOutcome> {
        let client = self.client_for(req.cfg.backend)?;
        // Pre-grow the persistent kernel pool to the request's
        // parallelism: the one-time worker spawns land here, outside
        // the compute phase, and every kernel call in the run (and all
        // later runs) dispatches to already-parked threads.
        crate::linalg::pool::warm(req.cfg.threads);
        // The dataset provider rides behind a read-ahead pipeline:
        // `run_typed` hints the step schedule's block order, a pool
        // task warms each block (RAM hit or spill reload) under a
        // bounded in-flight budget, and the node programs' own fetches
        // block only on a genuinely late read (counted as stall time).
        let inner = Arc::new(req.dataset.clone()) as Arc<dyn BlockProvider>;
        let readahead = Arc::new(ReadAhead::new(inner));
        let provider = Arc::clone(&readahead) as Arc<dyn BlockProvider>;
        let cache_before = self.ledger.snapshot();
        let opts = RunOpts {
            checkpoint: self.checkpoint.lock().unwrap().clone(),
            ..RunOpts::default()
        };
        let result = match &req.cfg.output_dir {
            Some(dir) => {
                let file = FileSink::new(dir, req.cfg.output_threshold);
                let tee = TeeRef::new(vec![sink, &file as &dyn ResultSink]);
                coordinator::run_streamed_opts(&req.cfg, client, provider, &tee, &opts)
            }
            None => coordinator::run_streamed_opts(&req.cfg, client, provider, sink, &opts),
        };
        // Stop the read-ahead task before returning, error or not — a
        // dangling prefetch must never outlive its run.
        readahead.finish();
        let mut outcome = result?;
        // Cache-pressure deltas for this run (ledger counters are
        // session-global; concurrent runs each absorb whatever pressure
        // landed during their window, which sums correctly across a
        // `comet batch`/`comet serve` ledger).
        let cache_after = self.ledger.snapshot();
        outcome.stats.cache_hits = cache_after.hits - cache_before.hits;
        outcome.stats.cache_misses = cache_after.misses - cache_before.misses;
        outcome.stats.cache_evictions = cache_after.evictions - cache_before.evictions;
        outcome.stats.cache_bytes = cache_after.bytes;
        outcome.stats.spills = cache_after.spills - cache_before.spills;
        outcome.stats.spill_bytes = cache_after.spill_bytes - cache_before.spill_bytes;
        outcome.stats.reloads = cache_after.reloads - cache_before.reloads;
        outcome.stats.reload_bytes = cache_after.reload_bytes - cache_before.reload_bytes;
        outcome.stats.t_stall = readahead.stall_secs();
        Ok(outcome)
    }

    /// As [`Session::run`], collecting values into
    /// `RunOutcome::{pairs, triples}` — the convenience shape for
    /// examples, tests, and small campaigns.
    pub fn run_collect(&self, req: &RunRequest) -> Result<RunOutcome> {
        // add_file = false: Session::run already rides the request's
        // file sink when output_dir is set.
        coordinator::run_with_legacy_sinks(&req.cfg, true, false, |sink| self.run(req, sink))
    }

    /// (compiles, executions, accelerator seconds) of the session's
    /// PJRT service, if one has started. Compiles staying flat across
    /// runs is the executable-cache-reuse signal.
    pub fn accel_stats(&self) -> Option<(u64, u64, f64)> {
        let guard = self.pjrt.lock().unwrap();
        guard.as_ref().map(|s| {
            let c = s.client();
            let (execs, secs) = c.stats();
            (c.compiles(), execs, secs)
        })
    }

    fn client_for(&self, backend: BackendKind) -> Result<Option<RuntimeClient>> {
        if backend != BackendKind::Pjrt {
            return Ok(None);
        }
        let mut guard = self.pjrt.lock().unwrap();
        if guard.is_none() {
            *guard = Some(
                PjrtService::start_with_limits(&self.artifact_dir, self.limits.exec_cache_slots)
                    .context("start PJRT service")?,
            );
        }
        Ok(Some(guard.as_ref().unwrap().client()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::engine::{Ccc, Czekanowski, Sorenson};

    fn spec() -> DatasetSpec {
        DatasetSpec::synthetic(SyntheticKind::Alleles, 5, 40, 12)
    }

    #[test]
    fn equal_specs_share_one_dataset_handle() {
        let session = Session::new();
        let a = session.dataset(spec());
        let b = session.dataset(spec());
        assert!(Arc::ptr_eq(&a.inner, &b.inner));
        let c = session.dataset(DatasetSpec::synthetic(SyntheticKind::Alleles, 6, 40, 12));
        assert!(!Arc::ptr_eq(&a.inner, &c.inner));
    }

    #[test]
    fn blocks_ingest_once_per_repr_and_key() {
        let session = Session::new();
        let ds = session.dataset(spec());
        let cfg = RunRequest::builder(ds.clone(), MetricId::Czekanowski)
            .grid(Grid::new(1, 2, 1))
            .build()
            .unwrap()
            .config()
            .clone();
        let cz = Czekanowski;
        // Same (repr, key, slice) twice: one ingest.
        let a = ds.block_f64(&cfg, &cz, 0, 0).unwrap();
        let b = ds.block_f64(&cfg, &cz, 0, 0).unwrap();
        assert_eq!(ds.ingest_count(), 1);
        assert_eq!(a.nv(), b.nv());
        // CCC packs allele planes — a second representation, a second
        // ingest (it no longer shares the float blocks)…
        let ccc = Ccc::new(cfg.nf);
        let g = ds.block_f64(&cfg, &ccc, 0, 0).unwrap();
        assert_eq!(g.repr(), Repr::Packed2);
        assert_eq!(ds.ingest_count(), 2);
        // …though two CCC instances do share packed2 blocks.
        let _ = ds.block_f64(&cfg, &Ccc::new(cfg.nf), 0, 0).unwrap();
        assert_eq!(ds.ingest_count(), 2);
        // Sorensen packs single-plane — a third representation.
        let sor = Sorenson::default();
        let packed = ds.block_f64(&cfg, &sor, 0, 0).unwrap();
        assert_eq!(packed.repr(), Repr::Packed);
        assert_eq!(ds.ingest_count(), 3);
        // A different Sorensen threshold must NOT share packed blocks.
        let sor_lo = Sorenson { threshold: 0.1 };
        let _ = ds.block_f64(&cfg, &sor_lo, 0, 0).unwrap();
        assert_eq!(ds.ingest_count(), 4);
        // Other node/grid slices are distinct blocks.
        let _ = ds.block_f64(&cfg, &cz, 1, 0).unwrap();
        assert_eq!(ds.ingest_count(), 5);
        assert_eq!(ds.cached_blocks(), 5);
        // Precisions cache separately (typed kernels consume them).
        let _ = ds.block_f32(&cfg, &Czekanowski, 0, 0).unwrap();
        assert_eq!(ds.ingest_count(), 6);
    }

    /// The shared shape of the budget tests: nv=16 over npv=4, nf=40,
    /// f64 — each block is 4 × 40 × 8 = 1280 B; a 2560 B budget holds
    /// exactly two.
    const BLOCK_B: u64 = 1280;

    fn budget_cfg(ds: &Dataset) -> RunConfig {
        RunRequest::builder(ds.clone(), MetricId::Czekanowski)
            .grid(Grid::new(1, 4, 1))
            .build()
            .unwrap()
            .config()
            .clone()
    }

    fn budget_spec() -> DatasetSpec {
        DatasetSpec::synthetic(SyntheticKind::Alleles, 5, 40, 16)
    }

    #[test]
    fn block_budget_evicts_lru_and_reloads_bit_identically() {
        // Spill is on by default: an evicted block comes back from the
        // session's spill store byte-identically — no re-ingest.
        let session = Session::with_limits(
            "artifacts",
            SessionLimits { block_cache_bytes: Some(2 * BLOCK_B), ..Default::default() },
        );
        let ds = session.dataset(budget_spec());
        let cfg = budget_cfg(&ds);
        let cz = Czekanowski;
        let first = ds.block_f64(&cfg, &cz, 0, 0).unwrap();
        let _ = ds.block_f64(&cfg, &cz, 1, 0).unwrap();
        assert_eq!(session.cache_stats().bytes, 2560);
        assert_eq!(ds.cached_bytes(), 2560);
        // A third block forces the LRU victim (pv 0) out — resident
        // bytes stay at the budget, and the victim lands in the store.
        let _ = ds.block_f64(&cfg, &cz, 2, 0).unwrap();
        assert_eq!(ds.cached_blocks(), 2);
        let snap = session.cache_stats();
        assert_eq!(snap.evictions, 1);
        assert_eq!(snap.spills, 1);
        assert!(snap.spill_bytes > BLOCK_B, "spill blob = payload + header");
        assert_eq!(snap.bytes, 2560);
        assert_eq!(ds.cached_bytes(), 2560);
        // pv 1 is still resident (pure hit); pv 0 reloads from the
        // store with zero new ingests.
        let before = ds.ingest_count();
        let _ = ds.block_f64(&cfg, &cz, 1, 0).unwrap();
        assert_eq!(ds.ingest_count(), before, "resident block re-ingested");
        let again = ds.block_f64(&cfg, &cz, 0, 0).unwrap();
        assert_eq!(ds.ingest_count(), before, "spilled block re-ingested instead of reloaded");
        // The reloaded block is bit-identical to the original.
        let (a, b) = (first.as_float().unwrap(), again.as_float().unwrap());
        assert_eq!(a.raw().len(), b.raw().len());
        for (x, y) in a.raw().iter().zip(b.raw()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        let snap = session.cache_stats();
        assert_eq!(snap.hits, 1);
        assert_eq!(snap.misses, 4, "a reload is still a counted miss-and-fill");
        assert_eq!(snap.evictions, 2);
        assert_eq!(snap.reloads, 1);
        assert_eq!(snap.reload_bytes, BLOCK_B);
        assert_eq!(snap.spill_errors, 0);
    }

    #[test]
    fn spill_disabled_restores_drop_and_reingest() {
        // `spill: false` is the PR 7 behavior: eviction drops the
        // block, the next touch re-ingests (still bit-identical, paid
        // in ingest time instead of disk reads).
        let session = Session::with_limits(
            "artifacts",
            SessionLimits {
                block_cache_bytes: Some(2 * BLOCK_B),
                spill: false,
                ..Default::default()
            },
        );
        let ds = session.dataset(budget_spec());
        let cfg = budget_cfg(&ds);
        let cz = Czekanowski;
        let first = ds.block_f64(&cfg, &cz, 0, 0).unwrap();
        let _ = ds.block_f64(&cfg, &cz, 1, 0).unwrap();
        let _ = ds.block_f64(&cfg, &cz, 2, 0).unwrap();
        let before = ds.ingest_count();
        let again = ds.block_f64(&cfg, &cz, 0, 0).unwrap();
        assert_eq!(ds.ingest_count(), before + 1, "evicted block must re-ingest");
        let (a, b) = (first.as_float().unwrap(), again.as_float().unwrap());
        for (x, y) in a.raw().iter().zip(b.raw()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        let snap = session.cache_stats();
        assert_eq!((snap.spills, snap.reloads), (0, 0));
    }

    #[test]
    fn resident_byte_accounting_is_exact_across_spill_reload_cycles() {
        // The satellite accounting audit: `Dataset::cached_bytes` (a
        // walk of the actual slots) and the ledger's `bytes` (the
        // budget counter) must agree at every step of a
        // spill → reload → re-evict cycle — no double-count on reload,
        // no leak on eviction.
        let session = Session::with_limits(
            "artifacts",
            SessionLimits { block_cache_bytes: Some(2 * BLOCK_B), ..Default::default() },
        );
        let ds = session.dataset(budget_spec());
        let cfg = budget_cfg(&ds);
        let cz = Czekanowski;
        let audit = |expect: u64, what: &str| {
            let ledger_bytes = session.cache_stats().bytes;
            let slot_bytes = ds.cached_bytes();
            assert_eq!(ledger_bytes, expect, "ledger bytes after {what}");
            assert_eq!(slot_bytes, expect, "slot-walk bytes after {what}");
        };
        let _ = ds.block_f64(&cfg, &cz, 0, 0).unwrap();
        audit(BLOCK_B, "first fill");
        let _ = ds.block_f64(&cfg, &cz, 1, 0).unwrap();
        audit(2 * BLOCK_B, "second fill");
        let _ = ds.block_f64(&cfg, &cz, 2, 0).unwrap();
        audit(2 * BLOCK_B, "eviction (spill pv0)");
        // Reload pv0 (evicts the LRU victim): still exactly budget.
        let _ = ds.block_f64(&cfg, &cz, 0, 0).unwrap();
        audit(2 * BLOCK_B, "reload pv0 (re-evict)");
        // Fill the last slice fresh — another spill on the way out.
        let _ = ds.block_f64(&cfg, &cz, 3, 0).unwrap();
        audit(2 * BLOCK_B, "fourth fill");
        // Touch every block once more: reloads stay in budget, and
        // re-evictions of already-on-disk blocks (write skipped) must
        // not drift the accounting either.
        for pv in 0..4 {
            let _ = ds.block_f64(&cfg, &cz, pv, 0).unwrap();
            audit(2 * BLOCK_B, "sweep");
        }
        let snap = session.cache_stats();
        assert!(snap.reloads >= 3, "sweep must reload spilled blocks: {snap:?}");
        assert_eq!(snap.spill_errors, 0);
        assert_eq!(
            ds.ingest_count(),
            4,
            "every block ingested exactly once; everything after is reload"
        );
    }

    #[test]
    fn cached_blocks_match_fresh_loads() {
        let session = Session::new();
        let ds = session.dataset(spec());
        let cfg = RunRequest::builder(ds.clone(), MetricId::Czekanowski)
            .grid(Grid::new(1, 3, 1))
            .build()
            .unwrap()
            .config()
            .clone();
        for pv in 0..3 {
            let cached = ds.block_f64(&cfg, &Czekanowski, pv, 0).unwrap();
            let fresh = Czekanowski
                .ingest(coordinator::load_block::<f64>(&cfg, pv, 0).unwrap());
            let (c, f) = (cached.as_float().unwrap(), fresh.as_float().unwrap());
            assert_eq!(c.first_id, f.first_id);
            for v in 0..c.nv {
                assert_eq!(c.col(v), f.col(v), "pv={pv} v={v}");
            }
        }
    }

    #[test]
    fn mismatched_config_is_rejected() {
        let session = Session::new();
        let ds = session.dataset(spec());
        let mut cfg = RunRequest::builder(ds.clone(), MetricId::Czekanowski)
            .build()
            .unwrap()
            .config()
            .clone();
        cfg.nv = 99;
        let err = ds.block_f64(&cfg, &Czekanowski, 0, 0).unwrap_err();
        assert!(err.to_string().contains("dataset handle"), "{err}");
    }

    #[test]
    fn builder_validates_requests() {
        let session = Session::new();
        let ds = session.dataset(spec());
        // CCC has no 3-way form.
        let err = RunRequest::builder(ds.clone(), MetricId::Ccc)
            .num_way(3)
            .build()
            .unwrap_err();
        assert!(err.to_string().contains("3-way"), "{err}");
        // CCC over a non-allele generator is rejected.
        let grid_ds =
            session.dataset(DatasetSpec::synthetic(SyntheticKind::RandomGrid, 1, 16, 8));
        let err = RunRequest::builder(grid_ds, MetricId::Ccc).build().unwrap_err();
        assert!(err.to_string().contains("allele"), "{err}");
        // A grid larger than the vector count is rejected.
        let err = RunRequest::builder(ds.clone(), MetricId::Czekanowski)
            .grid(Grid::new(1, 64, 1))
            .build()
            .unwrap_err();
        assert!(err.to_string().contains("npv"), "{err}");
        // And a sane request builds, bound to its dataset.
        let req = RunRequest::builder(ds, MetricId::Sorenson)
            .grid(Grid::new(1, 2, 1))
            .threads(2)
            .build()
            .unwrap();
        assert_eq!(req.config().metric, MetricId::Sorenson);
        assert_eq!(req.config().nv, 12);
        assert!(!req.config().store_metrics);
    }

    #[test]
    fn request_from_config_reuses_session_datasets() {
        let session = Session::new();
        let cfg = RunConfig {
            nv: 12,
            nf: 40,
            input: InputSource::Synthetic { kind: SyntheticKind::Alleles, seed: 5 },
            ..Default::default()
        };
        let req = session.request_from_config(&cfg).unwrap();
        let ds = session.dataset(spec());
        assert!(Arc::ptr_eq(&req.dataset().inner, &ds.inner));
    }
}
