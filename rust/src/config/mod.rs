//! Run configuration: a validated [`RunConfig`] plus a TOML-subset
//! parser (offline environment — no serde/toml crates; see DESIGN.md).
//!
//! The supported TOML subset covers what launcher configs need:
//! `[section]` headers, `key = value` with string / integer / float /
//! boolean values, `#` comments, and blank lines.

pub mod toml;

use crate::decomp::Grid;
use crate::metrics::MetricId;
use crate::vecdata::SyntheticKind;
use anyhow::{bail, Context, Result};

/// Numeric precision of a run (the paper's compile-time SP/DP choice,
/// runtime-selected here).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Precision {
    F32,
    F64,
}

impl Precision {
    pub fn bytes(self) -> usize {
        match self {
            Precision::F32 => 4,
            Precision::F64 => 8,
        }
    }
    pub fn tag(self) -> &'static str {
        match self {
            Precision::F32 => "f32",
            Precision::F64 => "f64",
        }
    }
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "f32" | "single" | "sp" => Ok(Precision::F32),
            "f64" | "double" | "dp" => Ok(Precision::F64),
            other => bail!("unknown precision {other:?} (want f32|f64)"),
        }
    }
}

/// Which engine executes the mGEMM blocks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// AOT artifacts through the PJRT client — the "GPU" path.
    Pjrt,
    /// Native blocked CPU kernels — the paper's optimized CPU version.
    CpuOptimized,
    /// Native naive kernels — the paper's reference version.
    CpuReference,
}

impl BackendKind {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "pjrt" | "gpu" | "accelerator" => Ok(BackendKind::Pjrt),
            "cpu" | "cpu-optimized" => Ok(BackendKind::CpuOptimized),
            "reference" | "cpu-reference" => Ok(BackendKind::CpuReference),
            other => bail!("unknown backend {other:?} (want pjrt|cpu|reference)"),
        }
    }
    pub fn name(self) -> &'static str {
        match self {
            BackendKind::Pjrt => "pjrt",
            BackendKind::CpuOptimized => "cpu-optimized",
            BackendKind::CpuReference => "cpu-reference",
        }
    }
}

/// Where the input vectors come from.
#[derive(Debug, Clone, PartialEq)]
pub enum InputSource {
    /// Generate synthetically (kind, seed).
    Synthetic { kind: SyntheticKind, seed: u64 },
    /// Read the §6.8 column-major binary file.
    File { path: String },
}

/// A fully validated run description.
#[derive(Debug, Clone)]
pub struct RunConfig {
    /// Which metric family the run computes (czekanowski|ccc|sorenson).
    pub metric: MetricId,
    /// 2 or 3 (the paper's `num_way`).
    pub num_way: usize,
    /// Total vectors n_v.
    pub nv: usize,
    /// Features per vector n_f.
    pub nf: usize,
    pub precision: Precision,
    pub backend: BackendKind,
    /// Host compute threads per node for the optimized CPU backend's
    /// row-panel-parallel kernels (1 = serial; grid-valued sums are
    /// bit-identical across any thread count). Ignored by the
    /// reference backend (single-core baseline) and PJRT (the
    /// accelerator owns its parallelism).
    pub threads: usize,
    pub grid: Grid,
    /// Stage count n_st (3-way only; 1 = no staging).
    pub num_stage: usize,
    /// Stage to compute, or None = all stages (§6.8 computes only the
    /// last stage of 220).
    pub stage: Option<usize>,
    pub input: InputSource,
    /// Keep computed metrics in memory (examples/tests) — large runs
    /// set false and stream to output files instead.
    pub store_metrics: bool,
    /// Output directory for per-node metric files (§6.8), if any.
    pub output_dir: Option<String>,
    /// Output threshold (§6.8 discussion: "methods to threshold …
    /// data"): metrics below it are dropped; files switch to
    /// (offset, byte) records since formulaic indexing no longer holds.
    pub output_threshold: Option<f64>,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            metric: MetricId::Czekanowski,
            num_way: 2,
            nv: 256,
            nf: 384,
            precision: Precision::F64,
            backend: BackendKind::CpuOptimized,
            threads: 1,
            grid: Grid::new(1, 1, 1),
            num_stage: 1,
            stage: None,
            input: InputSource::Synthetic {
                kind: SyntheticKind::RandomGrid,
                seed: 1,
            },
            store_metrics: true,
            output_dir: None,
            output_threshold: None,
        }
    }
}

impl RunConfig {
    /// Validate cross-field constraints.
    pub fn validate(&self) -> Result<()> {
        if !(self.num_way == 2 || self.num_way == 3) {
            bail!("num_way must be 2 or 3, got {}", self.num_way);
        }
        if !self.metric.supports_way(self.num_way) {
            bail!(
                "metric {} has no {}-way form",
                self.metric.name(),
                self.num_way
            );
        }
        // Strict element domains: pairing CCC with a non-allele
        // generator would silently compute meaningless frequencies.
        // (File inputs are the user's responsibility; Binary metrics
        // threshold real inputs by design.)
        if self.metric.domain() == crate::metrics::Domain::AlleleCounts {
            if let InputSource::Synthetic { kind, .. } = &self.input {
                if *kind != SyntheticKind::Alleles {
                    bail!(
                        "metric {} expects allele-count vectors (entries in {{0,1,2}}); \
                         use `--synthetic alleles` or a {{0,1,2}}-valued input file",
                        self.metric.name()
                    );
                }
            }
        }
        // Upper bound also catches negative TOML values wrapping
        // through the i64 → usize cast (e.g. threads = -1).
        if self.threads == 0 || self.threads > 1024 {
            bail!("threads must be in 1..=1024, got {}", self.threads);
        }
        if self.nv < self.num_way {
            bail!("nv={} too small for {}-way", self.nv, self.num_way);
        }
        if self.grid.npv > self.nv {
            bail!("npv={} exceeds nv={}", self.grid.npv, self.nv);
        }
        if self.grid.npf > self.nf {
            bail!("npf={} exceeds nf={}", self.grid.npf, self.nf);
        }
        if self.num_stage == 0 {
            bail!("num_stage must be >= 1");
        }
        if let Some(s) = self.stage {
            if s >= self.num_stage {
                bail!("stage {} out of range (num_stage={})", s, self.num_stage);
            }
        }
        if self.num_way == 2 && self.num_stage != 1 {
            bail!("staging is a 3-way feature (num_way=2 requires num_stage=1)");
        }
        Ok(())
    }

    /// Build from a parsed TOML document.
    pub fn from_toml(doc: &toml::Doc) -> Result<Self> {
        let mut cfg = RunConfig::default();
        if let Some(v) = doc.get("run", "metric") {
            cfg.metric = MetricId::parse(v.as_str().context("run.metric")?)?;
        }
        if let Some(v) = doc.get("run", "num_way") {
            cfg.num_way = v.as_int().context("run.num_way")? as usize;
        }
        if let Some(v) = doc.get("run", "nv") {
            cfg.nv = v.as_int().context("run.nv")? as usize;
        }
        if let Some(v) = doc.get("run", "nf") {
            cfg.nf = v.as_int().context("run.nf")? as usize;
        }
        if let Some(v) = doc.get("run", "precision") {
            cfg.precision = Precision::parse(v.as_str().context("run.precision")?)?;
        }
        if let Some(v) = doc.get("run", "backend") {
            cfg.backend = BackendKind::parse(v.as_str().context("run.backend")?)?;
        }
        if let Some(v) = doc.get("run", "threads") {
            cfg.threads = v.as_int().context("run.threads")? as usize;
        }
        if let Some(v) = doc.get("run", "store_metrics") {
            cfg.store_metrics = v.as_bool().context("run.store_metrics")?;
        }
        if let Some(v) = doc.get("run", "output_dir") {
            cfg.output_dir = Some(v.as_str().context("run.output_dir")?.to_string());
        }
        if let Some(v) = doc.get("run", "output_threshold") {
            cfg.output_threshold = Some(v.as_float().context("run.output_threshold")?);
        }
        let npf = doc.get("decomp", "npf").map(|v| v.as_int()).transpose()?.unwrap_or(1) as usize;
        let npv = doc.get("decomp", "npv").map(|v| v.as_int()).transpose()?.unwrap_or(1) as usize;
        let npr = doc.get("decomp", "npr").map(|v| v.as_int()).transpose()?.unwrap_or(1) as usize;
        cfg.grid = Grid::new(npf, npv, npr);
        if let Some(v) = doc.get("decomp", "num_stage") {
            cfg.num_stage = v.as_int().context("decomp.num_stage")? as usize;
        }
        if let Some(v) = doc.get("decomp", "stage") {
            cfg.stage = Some(v.as_int().context("decomp.stage")? as usize);
        }
        match doc.get("input", "file") {
            Some(v) => {
                cfg.input = InputSource::File {
                    path: v.as_str().context("input.file")?.to_string(),
                };
            }
            None => {
                let kind = match doc.get("input", "synthetic").map(|v| v.as_str()).transpose()? {
                    Some("grid") | None => SyntheticKind::RandomGrid,
                    Some("verifiable") => SyntheticKind::Verifiable,
                    Some("phewas") => SyntheticKind::PhewasLike,
                    Some("alleles") => SyntheticKind::Alleles,
                    Some(other) => bail!("unknown input.synthetic {other:?}"),
                };
                let seed = doc
                    .get("input", "seed")
                    .map(|v| v.as_int())
                    .transpose()?
                    .unwrap_or(1) as u64;
                cfg.input = InputSource::Synthetic { kind, seed };
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn from_toml_str(text: &str) -> Result<Self> {
        Self::from_toml(&toml::parse(text)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        RunConfig::default().validate().unwrap();
    }

    #[test]
    fn parse_full_config() {
        let text = r#"
# A 3-way staged campaign.
[run]
num_way = 3
nv = 1536
nf = 385
precision = "f32"
backend = "pjrt"
store_metrics = false

[decomp]
npv = 4
npr = 3
num_stage = 16
stage = 15

[input]
synthetic = "phewas"
seed = 42
"#;
        let cfg = RunConfig::from_toml_str(text).unwrap();
        assert_eq!(cfg.num_way, 3);
        assert_eq!(cfg.nv, 1536);
        assert_eq!(cfg.precision, Precision::F32);
        assert_eq!(cfg.backend, BackendKind::Pjrt);
        assert_eq!(cfg.grid, Grid::new(1, 4, 3));
        assert_eq!(cfg.num_stage, 16);
        assert_eq!(cfg.stage, Some(15));
        assert!(matches!(
            cfg.input,
            InputSource::Synthetic { kind: SyntheticKind::PhewasLike, seed: 42 }
        ));
        assert!(!cfg.store_metrics);
    }

    #[test]
    fn file_input() {
        let cfg = RunConfig::from_toml_str(
            "[run]\nnv = 10\nnf = 5\n[input]\nfile = \"/data/v.bin\"\n",
        )
        .unwrap();
        assert_eq!(cfg.input, InputSource::File { path: "/data/v.bin".into() });
    }

    #[test]
    fn parses_threads_and_rejects_zero() {
        let cfg = RunConfig::from_toml_str("[run]\nthreads = 4\n").unwrap();
        assert_eq!(cfg.threads, 4);
        assert_eq!(RunConfig::default().threads, 1);
        let err = RunConfig::from_toml_str("[run]\nthreads = 0\n").unwrap_err();
        assert!(err.to_string().contains("threads"), "{err}");
        // Negative values must not wrap into astronomically large
        // thread counts through the usize cast.
        let err = RunConfig::from_toml_str("[run]\nthreads = -1\n").unwrap_err();
        assert!(err.to_string().contains("threads"), "{err}");
    }

    #[test]
    fn rejects_bad_numway() {
        let err = RunConfig::from_toml_str("[run]\nnum_way = 4\n").unwrap_err();
        assert!(err.to_string().contains("num_way"));
    }

    #[test]
    fn rejects_2way_staging() {
        let err =
            RunConfig::from_toml_str("[run]\nnum_way = 2\n[decomp]\nnum_stage = 4\n").unwrap_err();
        assert!(err.to_string().contains("staging"));
    }

    #[test]
    fn rejects_oversized_grid() {
        let err = RunConfig::from_toml_str("[run]\nnv = 4\n[decomp]\nnpv = 8\n").unwrap_err();
        assert!(err.to_string().contains("npv"));
    }

    #[test]
    fn parses_metric_and_alleles_input() {
        let cfg = RunConfig::from_toml_str(
            "[run]\nmetric = \"ccc\"\n[input]\nsynthetic = \"alleles\"\nseed = 9\n",
        )
        .unwrap();
        assert_eq!(cfg.metric, MetricId::Ccc);
        assert!(matches!(
            cfg.input,
            InputSource::Synthetic { kind: SyntheticKind::Alleles, seed: 9 }
        ));
    }

    #[test]
    fn default_metric_is_czekanowski() {
        assert_eq!(RunConfig::default().metric, MetricId::Czekanowski);
    }

    #[test]
    fn rejects_ccc_over_non_allele_synthetic() {
        // Defaulting to the grid generator under CCC would silently
        // compute meaningless frequencies — must be rejected.
        let err = RunConfig::from_toml_str("[run]\nmetric = \"ccc\"\n").unwrap_err();
        assert!(err.to_string().contains("alleles"), "{err}");
        // File inputs are the user's responsibility.
        RunConfig::from_toml_str("[run]\nmetric = \"ccc\"\n[input]\nfile = \"/d/v.bin\"\n")
            .unwrap();
        // Binary metrics threshold real inputs by design — grid is fine.
        RunConfig::from_toml_str("[run]\nmetric = \"sorenson\"\n").unwrap();
    }

    #[test]
    fn rejects_3way_for_2way_only_metrics() {
        for m in ["ccc", "sorenson"] {
            let err =
                RunConfig::from_toml_str(&format!("[run]\nmetric = \"{m}\"\nnum_way = 3\n"))
                    .unwrap_err();
            assert!(err.to_string().contains("3-way"), "{m}: {err}");
        }
        // Czekanowski keeps its 3-way form.
        RunConfig::from_toml_str("[run]\nmetric = \"czekanowski\"\nnum_way = 3\n").unwrap();
    }

    #[test]
    fn precision_aliases() {
        assert_eq!(Precision::parse("single").unwrap(), Precision::F32);
        assert_eq!(Precision::parse("dp").unwrap(), Precision::F64);
        assert!(Precision::parse("f16").is_err());
    }
}
