//! Run configuration: a validated [`RunConfig`] plus a TOML-subset
//! parser (offline environment — no serde/toml crates; see DESIGN.md).
//!
//! The supported TOML subset covers what launcher configs need:
//! `[section]` headers, `key = value` with string / integer / float /
//! boolean values, `#` comments, and blank lines.

pub mod toml;

use crate::decomp::Grid;
use crate::metrics::MetricId;
use crate::vecdata::SyntheticKind;
use anyhow::{bail, Context, Result};

/// Numeric precision of a run (the paper's compile-time SP/DP choice,
/// runtime-selected here).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Precision {
    F32,
    F64,
}

impl Precision {
    pub fn bytes(self) -> usize {
        match self {
            Precision::F32 => 4,
            Precision::F64 => 8,
        }
    }
    pub fn tag(self) -> &'static str {
        match self {
            Precision::F32 => "f32",
            Precision::F64 => "f64",
        }
    }
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "f32" | "single" | "sp" => Ok(Precision::F32),
            "f64" | "double" | "dp" => Ok(Precision::F64),
            other => bail!("unknown precision {other:?} (want f32|f64)"),
        }
    }
}

/// Which engine executes the mGEMM blocks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// AOT artifacts through the PJRT client — the "GPU" path.
    Pjrt,
    /// Native blocked CPU kernels — the paper's optimized CPU version.
    CpuOptimized,
    /// Native naive kernels — the paper's reference version.
    CpuReference,
}

impl BackendKind {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "pjrt" | "gpu" | "accelerator" => Ok(BackendKind::Pjrt),
            "cpu" | "cpu-optimized" => Ok(BackendKind::CpuOptimized),
            "reference" | "cpu-reference" => Ok(BackendKind::CpuReference),
            other => bail!("unknown backend {other:?} (want pjrt|cpu|reference)"),
        }
    }
    pub fn name(self) -> &'static str {
        match self {
            BackendKind::Pjrt => "pjrt",
            BackendKind::CpuOptimized => "cpu-optimized",
            BackendKind::CpuReference => "cpu-reference",
        }
    }
}

/// Where the input vectors come from. `Eq + Hash` because an input
/// source is two-thirds of a [`crate::session::DatasetSpec`] — the
/// session layer keys ingested-block caches by it.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum InputSource {
    /// Generate synthetically (kind, seed).
    Synthetic { kind: SyntheticKind, seed: u64 },
    /// Read the §6.8 column-major binary file.
    File { path: String },
    /// Read a variant-major PLINK `.bed` genotype file (2-bit calls;
    /// companion `.bim`/`.fam` cross-check the run dimensions).
    Bed { path: String },
    /// Read a GT-field VCF genotype file (diploid calls decoded in
    /// parallel chunks on the worker pool).
    Vcf { path: String },
}

impl InputSource {
    /// Parse a `format=` value naming how a `file=` path is read.
    pub fn from_format(format: &str, path: String) -> Result<Self> {
        match format {
            "raw" => Ok(InputSource::File { path }),
            "bed" => Ok(InputSource::Bed { path }),
            "vcf" => Ok(InputSource::Vcf { path }),
            other => bail!("unknown input format {other:?} (want raw|bed|vcf)"),
        }
    }

    /// The format name (`raw`/`bed`/`vcf`), if this source reads a file.
    pub fn format_name(&self) -> Option<&'static str> {
        match self {
            InputSource::Synthetic { .. } => None,
            InputSource::File { .. } => Some("raw"),
            InputSource::Bed { .. } => Some("bed"),
            InputSource::Vcf { .. } => Some("vcf"),
        }
    }
}

/// A fully validated run description.
#[derive(Debug, Clone)]
pub struct RunConfig {
    /// Which metric family the run computes (czekanowski|ccc|sorenson).
    pub metric: MetricId,
    /// 2 or 3 (the paper's `num_way`).
    pub num_way: usize,
    /// Total vectors n_v.
    pub nv: usize,
    /// Features per vector n_f.
    pub nf: usize,
    pub precision: Precision,
    pub backend: BackendKind,
    /// Host compute threads per node for the optimized CPU backend's
    /// row-panel-parallel kernels (1 = serial; grid-valued sums are
    /// bit-identical across any thread count). Ignored by the
    /// reference backend (single-core baseline) and PJRT (the
    /// accelerator owns its parallelism).
    pub threads: usize,
    pub grid: Grid,
    /// Stage count n_st (3-way only; 1 = no staging).
    pub num_stage: usize,
    /// Stage to compute, or None = all stages (§6.8 computes only the
    /// last stage of 220).
    pub stage: Option<usize>,
    pub input: InputSource,
    /// Keep computed metrics in memory (examples/tests) — large runs
    /// set false and stream to output files instead.
    pub store_metrics: bool,
    /// Output directory for per-node metric files (§6.8), if any.
    pub output_dir: Option<String>,
    /// Output threshold (§6.8 discussion: "methods to threshold …
    /// data"): metrics below it are dropped; files switch to
    /// (offset, byte) records since formulaic indexing no longer holds.
    pub output_threshold: Option<f64>,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            metric: MetricId::Czekanowski,
            num_way: 2,
            nv: 256,
            nf: 384,
            precision: Precision::F64,
            backend: BackendKind::CpuOptimized,
            threads: 1,
            grid: Grid::new(1, 1, 1),
            num_stage: 1,
            stage: None,
            input: InputSource::Synthetic {
                kind: SyntheticKind::RandomGrid,
                seed: 1,
            },
            store_metrics: true,
            output_dir: None,
            output_threshold: None,
        }
    }
}

impl RunConfig {
    /// Validate cross-field constraints.
    pub fn validate(&self) -> Result<()> {
        if !(self.num_way == 2 || self.num_way == 3) {
            bail!("num_way must be 2 or 3, got {}", self.num_way);
        }
        if !self.metric.supports_way(self.num_way) {
            bail!(
                "metric {} has no {}-way form",
                self.metric.name(),
                self.num_way
            );
        }
        // Strict element domains: pairing CCC with a non-allele
        // generator would silently compute meaningless frequencies.
        // (File inputs are the user's responsibility; Binary metrics
        // threshold real inputs by design.)
        if self.metric.domain() == crate::metrics::Domain::AlleleCounts {
            if let InputSource::Synthetic { kind, .. } = &self.input {
                if *kind != SyntheticKind::Alleles {
                    bail!(
                        "metric {} expects allele-count vectors (entries in {{0,1,2}}); \
                         use `--synthetic alleles` or a {{0,1,2}}-valued input file",
                        self.metric.name()
                    );
                }
            }
        }
        // Upper bound also catches negative TOML values wrapping
        // through the i64 → usize cast (e.g. threads = -1).
        if self.threads == 0 || self.threads > 1024 {
            bail!("threads must be in 1..=1024, got {}", self.threads);
        }
        if self.nv < self.num_way {
            bail!("nv={} too small for {}-way", self.nv, self.num_way);
        }
        if self.grid.npv > self.nv {
            bail!("npv={} exceeds nv={}", self.grid.npv, self.nv);
        }
        if self.grid.npf > self.nf {
            bail!("npf={} exceeds nf={}", self.grid.npf, self.nf);
        }
        if self.num_stage == 0 {
            bail!("num_stage must be >= 1");
        }
        if let Some(s) = self.stage {
            if s >= self.num_stage {
                bail!("stage {} out of range (num_stage={})", s, self.num_stage);
            }
        }
        if self.num_way == 2 && self.num_stage != 1 {
            bail!("staging is a 3-way feature (num_way=2 requires num_stage=1)");
        }
        Ok(())
    }

    /// Build from a parsed TOML document.
    pub fn from_toml(doc: &toml::Doc) -> Result<Self> {
        let mut cfg = RunConfig::default();
        cfg.apply_run_keys(doc, "run")?;
        cfg.apply_decomp_keys(doc, "decomp")?;
        cfg.apply_input(doc)?;
        cfg.validate()?;
        Ok(cfg)
    }

    /// Apply the flat run-level keys of `section` over the current
    /// values. Shared between the `[run]` table and the per-request
    /// `[request.<name>]` override tables of a batch file.
    fn apply_run_keys(&mut self, doc: &toml::Doc, section: &str) -> Result<()> {
        if let Some(v) = doc.get(section, "metric") {
            self.metric =
                MetricId::parse(v.as_str().with_context(|| format!("{section}.metric"))?)?;
        }
        if let Some(v) = doc.get(section, "num_way") {
            self.num_way = v.as_int().with_context(|| format!("{section}.num_way"))? as usize;
        }
        if let Some(v) = doc.get(section, "nv") {
            self.nv = v.as_int().with_context(|| format!("{section}.nv"))? as usize;
        }
        if let Some(v) = doc.get(section, "nf") {
            self.nf = v.as_int().with_context(|| format!("{section}.nf"))? as usize;
        }
        if let Some(v) = doc.get(section, "precision") {
            self.precision =
                Precision::parse(v.as_str().with_context(|| format!("{section}.precision"))?)?;
        }
        if let Some(v) = doc.get(section, "backend") {
            self.backend =
                BackendKind::parse(v.as_str().with_context(|| format!("{section}.backend"))?)?;
        }
        if let Some(v) = doc.get(section, "threads") {
            self.threads = v.as_int().with_context(|| format!("{section}.threads"))? as usize;
        }
        if let Some(v) = doc.get(section, "store_metrics") {
            self.store_metrics =
                v.as_bool().with_context(|| format!("{section}.store_metrics"))?;
        }
        if let Some(v) = doc.get(section, "output_dir") {
            self.output_dir = Some(
                v.as_str()
                    .with_context(|| format!("{section}.output_dir"))?
                    .to_string(),
            );
        }
        if let Some(v) = doc.get(section, "output_threshold") {
            self.output_threshold =
                Some(v.as_float().with_context(|| format!("{section}.output_threshold"))?);
        }
        Ok(())
    }

    /// Apply the decomposition keys of `section` over the current grid
    /// and staging values (absent keys keep their current value, so
    /// request tables override only what they name).
    fn apply_decomp_keys(&mut self, doc: &toml::Doc, section: &str) -> Result<()> {
        let npf =
            doc.get(section, "npf").map(|v| v.as_int()).transpose()?.unwrap_or(self.grid.npf as i64)
                as usize;
        let npv =
            doc.get(section, "npv").map(|v| v.as_int()).transpose()?.unwrap_or(self.grid.npv as i64)
                as usize;
        let npr =
            doc.get(section, "npr").map(|v| v.as_int()).transpose()?.unwrap_or(self.grid.npr as i64)
                as usize;
        self.grid = Grid::new(npf, npv, npr);
        if let Some(v) = doc.get(section, "num_stage") {
            self.num_stage = v.as_int().with_context(|| format!("{section}.num_stage"))? as usize;
        }
        if let Some(v) = doc.get(section, "stage") {
            self.stage = Some(v.as_int().with_context(|| format!("{section}.stage"))? as usize);
        }
        Ok(())
    }

    /// Apply the `[input]` table.
    fn apply_input(&mut self, doc: &toml::Doc) -> Result<()> {
        let format = doc
            .get("input", "format")
            .map(|v| v.as_str().context("input.format"))
            .transpose()?;
        match doc.get("input", "file") {
            Some(v) => {
                let path = v.as_str().context("input.file")?.to_string();
                self.input = InputSource::from_format(format.unwrap_or("raw"), path)?;
            }
            None => {
                if format.is_some() {
                    bail!("input.format requires input.file");
                }
                let kind = match doc.get("input", "synthetic").map(|v| v.as_str()).transpose()? {
                    Some(s) => SyntheticKind::parse(s)?,
                    None => SyntheticKind::RandomGrid,
                };
                let seed = doc
                    .get("input", "seed")
                    .map(|v| v.as_int())
                    .transpose()?
                    .unwrap_or(1) as u64;
                self.input = InputSource::Synthetic { kind, seed };
            }
        }
        Ok(())
    }

    pub fn from_toml_str(text: &str) -> Result<Self> {
        Self::from_toml(&toml::parse(text)?)
    }

    /// Parse a single-line request spec — the `comet serve` protocol:
    /// whitespace-separated `key=value` pairs over the same vocabulary
    /// as the TOML form (`metric=sorenson nv=96 nf=64 npv=2 seed=7`).
    /// Unknown keys are rejected like unknown TOML keys; the result is
    /// validated. `store_metrics` is always false — a served request
    /// streams tiles, nothing accumulates server-side.
    pub fn from_kv_line(line: &str) -> Result<Self> {
        fn num<T: std::str::FromStr>(key: &str, val: &str) -> Result<T>
        where
            T::Err: std::error::Error + Send + Sync + 'static,
        {
            val.parse::<T>().with_context(|| format!("request key {key}={val:?}"))
        }
        let mut cfg = RunConfig { store_metrics: false, ..RunConfig::default() };
        let (mut npf, mut npv, mut npr) = (1usize, 1usize, 1usize);
        let mut synthetic = SyntheticKind::RandomGrid;
        let mut seed = 1u64;
        let mut file: Option<String> = None;
        let mut format: Option<String> = None;
        for tok in line.split_whitespace() {
            let Some((key, val)) = tok.split_once('=') else {
                bail!("request token {tok:?} is not key=value");
            };
            match key {
                "metric" => cfg.metric = MetricId::parse(val)?,
                "num_way" => cfg.num_way = num(key, val)?,
                "nv" => cfg.nv = num(key, val)?,
                "nf" => cfg.nf = num(key, val)?,
                "precision" => cfg.precision = Precision::parse(val)?,
                "backend" => cfg.backend = BackendKind::parse(val)?,
                "threads" => cfg.threads = num(key, val)?,
                "npf" => npf = num(key, val)?,
                "npv" => npv = num(key, val)?,
                "npr" => npr = num(key, val)?,
                "num_stage" => cfg.num_stage = num(key, val)?,
                "stage" => cfg.stage = Some(num(key, val)?),
                "synthetic" => synthetic = SyntheticKind::parse(val)?,
                "seed" => seed = num(key, val)?,
                "file" => file = Some(val.to_string()),
                "format" => format = Some(val.to_string()),
                "output_threshold" => cfg.output_threshold = Some(num(key, val)?),
                other => bail!(
                    "unknown request key {other:?} (valid: metric|num_way|nv|nf|precision|\
                     backend|threads|npf|npv|npr|num_stage|stage|synthetic|seed|file|format|\
                     output_threshold)"
                ),
            }
        }
        // Grid::new asserts >= 1; turn a zero into an error instead.
        if npf == 0 || npv == 0 || npr == 0 {
            bail!("grid axes must be >= 1 (npf={npf} npv={npv} npr={npr})");
        }
        cfg.grid = Grid::new(npf, npv, npr);
        cfg.input = match file {
            Some(path) => InputSource::from_format(format.as_deref().unwrap_or("raw"), path)?,
            None if format.is_some() => bail!("request key format requires file"),
            None => InputSource::Synthetic { kind: synthetic, seed },
        };
        cfg.validate()?;
        Ok(cfg)
    }
}

/// One named request of a batch-campaign file.
#[derive(Debug, Clone)]
pub struct BatchEntry {
    pub name: String,
    pub cfg: RunConfig,
}

/// Parse a multi-request batch file (`comet batch`): the base
/// `[run]` / `[decomp]` / `[input]` tables are shared by every request,
/// and each `[request.<name>]` table overrides them (run-level and
/// decomp-level keys are accepted flat in a request table). Requests
/// keep the base `[input]` — the point of a batch is many runs over
/// one ingested dataset — and execute in file order.
pub fn batch_from_toml_str(text: &str) -> Result<Vec<BatchEntry>> {
    let doc = toml::parse(text)?;
    // Reject unknown sections outright: a bare `[request]`, a typo'd
    // `[reqest.b]`, or top-level keys would otherwise silently drop
    // requests/overrides from the campaign.
    for section in doc.sections_in_order() {
        let known = section == "run"
            || section == "decomp"
            || section == "input"
            || section.starts_with("request.");
        if !known {
            bail!(
                "unknown section [{section}] in batch file \
                 (want [run], [decomp], [input], or [request.<name>])"
            );
        }
    }
    // A re-opened section merges keys — a copy-pasted request left
    // unrenamed would silently collapse two runs into one.
    if let Some(section) = doc.reopened_sections().first() {
        bail!("duplicate section [{section}] in batch file");
    }
    let mut base = RunConfig::default();
    base.apply_run_keys(&doc, "run")?;
    base.apply_decomp_keys(&doc, "decomp")?;
    base.apply_input(&doc)?;
    // The base alone is not validated: it may be a partial template
    // (e.g. no metric) that only becomes a legal run once a request
    // table fills in the rest.
    let mut entries = Vec::new();
    // The full key vocabulary, enforced per table: typos (and
    // misplaced keys) must error rather than be silently ignored.
    // `store_metrics` is deliberately absent: batch runs stream through
    // session sinks, so the legacy flag would be a silent no-op here
    // (it remains valid for `comet run --config`).
    const RUN_KEYS: [&str; 9] = [
        "metric",
        "num_way",
        "nv",
        "nf",
        "precision",
        "backend",
        "threads",
        "output_dir",
        "output_threshold",
    ];
    const DECOMP_KEYS: [&str; 5] = ["npf", "npv", "npr", "num_stage", "stage"];
    const INPUT_KEYS: [&str; 4] = ["file", "format", "synthetic", "seed"];
    for (section, allowed) in
        [("run", &RUN_KEYS[..]), ("decomp", &DECOMP_KEYS[..]), ("input", &INPUT_KEYS[..])]
    {
        for key in doc.section_keys(section) {
            if !allowed.contains(&key) {
                bail!("batch file: unknown key {key:?} in [{section}]");
            }
        }
    }
    for section in doc.sections_in_order() {
        let Some(name) = section.strip_prefix("request.") else {
            continue;
        };
        if name.is_empty() {
            bail!("batch request section needs a name: [request.<name>]");
        }
        // Request tables accept the run + decomp vocabulary flat.
        // Input-family keys are deliberately absent — the shared
        // `[input]` table is the point of a batch.
        for key in doc.section_keys(section) {
            if !(RUN_KEYS.contains(&key) || DECOMP_KEYS.contains(&key)) {
                bail!(
                    "request {name:?}: unknown key {key:?} (input-family keys belong in the \
                     shared [input] table; valid request keys: {}|{})",
                    RUN_KEYS.join("|"),
                    DECOMP_KEYS.join("|")
                );
            }
        }
        let mut cfg = base.clone();
        cfg.apply_run_keys(&doc, section)?;
        cfg.apply_decomp_keys(&doc, section)?;
        cfg.validate().with_context(|| format!("request {name:?}"))?;
        entries.push(BatchEntry { name: name.to_string(), cfg });
    }
    if entries.is_empty() {
        bail!("batch file has no [request.<name>] sections");
    }
    Ok(entries)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kv_line_parses_the_toml_vocabulary() {
        let cfg = RunConfig::from_kv_line(
            "metric=sorenson num_way=2 nv=96 nf=64 precision=f32 backend=cpu threads=4 \
             npv=3 npr=2 synthetic=phewas seed=7 output_threshold=0.5",
        )
        .unwrap();
        assert_eq!(cfg.metric, MetricId::Sorenson);
        assert_eq!((cfg.nv, cfg.nf), (96, 64));
        assert_eq!(cfg.precision, Precision::F32);
        assert_eq!(cfg.backend, BackendKind::CpuOptimized);
        assert_eq!(cfg.threads, 4);
        assert_eq!((cfg.grid.npf, cfg.grid.npv, cfg.grid.npr), (1, 3, 2));
        assert_eq!(
            cfg.input,
            InputSource::Synthetic { kind: SyntheticKind::PhewasLike, seed: 7 }
        );
        assert_eq!(cfg.output_threshold, Some(0.5));
        assert!(!cfg.store_metrics, "served requests must not accumulate");
        // file= overrides the synthetic input family.
        let cfg = RunConfig::from_kv_line("nv=8 nf=16 file=/data/x.bin").unwrap();
        assert_eq!(cfg.input, InputSource::File { path: "/data/x.bin".into() });
    }

    #[test]
    fn kv_line_rejects_junk() {
        for (line, needle) in [
            ("metric=czekanowski bogus_key=1", "unknown request key"),
            ("metric czekanowski", "not key=value"),
            ("nv=twelve", "nv"),
            ("npv=0", ">= 1"),
            ("metric=ccc", "allele"),      // validation still applies
            ("num_way=3 metric=ccc synthetic=alleles", "3-way"),
        ] {
            let err = RunConfig::from_kv_line(line).unwrap_err();
            assert!(format!("{err:#}").contains(needle), "{line} -> {err:#}");
        }
    }

    #[test]
    fn defaults_validate() {
        RunConfig::default().validate().unwrap();
    }

    #[test]
    fn parse_full_config() {
        let text = r#"
# A 3-way staged campaign.
[run]
num_way = 3
nv = 1536
nf = 385
precision = "f32"
backend = "pjrt"
store_metrics = false

[decomp]
npv = 4
npr = 3
num_stage = 16
stage = 15

[input]
synthetic = "phewas"
seed = 42
"#;
        let cfg = RunConfig::from_toml_str(text).unwrap();
        assert_eq!(cfg.num_way, 3);
        assert_eq!(cfg.nv, 1536);
        assert_eq!(cfg.precision, Precision::F32);
        assert_eq!(cfg.backend, BackendKind::Pjrt);
        assert_eq!(cfg.grid, Grid::new(1, 4, 3));
        assert_eq!(cfg.num_stage, 16);
        assert_eq!(cfg.stage, Some(15));
        assert!(matches!(
            cfg.input,
            InputSource::Synthetic { kind: SyntheticKind::PhewasLike, seed: 42 }
        ));
        assert!(!cfg.store_metrics);
    }

    #[test]
    fn file_input() {
        let cfg = RunConfig::from_toml_str(
            "[run]\nnv = 10\nnf = 5\n[input]\nfile = \"/data/v.bin\"\n",
        )
        .unwrap();
        assert_eq!(cfg.input, InputSource::File { path: "/data/v.bin".into() });
    }

    #[test]
    fn input_format_selects_the_reader() {
        // TOML form: format= names how file= is read; raw is the default.
        let cfg = RunConfig::from_toml_str(
            "[run]\nnv = 10\nnf = 5\n[input]\nfile = \"/d/c.bed\"\nformat = \"bed\"\n",
        )
        .unwrap();
        assert_eq!(cfg.input, InputSource::Bed { path: "/d/c.bed".into() });
        assert_eq!(cfg.input.format_name(), Some("bed"));
        let cfg = RunConfig::from_toml_str(
            "[run]\nnv = 10\nnf = 5\n[input]\nfile = \"/d/c.vcf\"\nformat = \"vcf\"\n",
        )
        .unwrap();
        assert_eq!(cfg.input, InputSource::Vcf { path: "/d/c.vcf".into() });
        // kv-line form mirrors the TOML vocabulary.
        let cfg = RunConfig::from_kv_line("nv=8 nf=16 file=/d/c.bed format=bed").unwrap();
        assert_eq!(cfg.input, InputSource::Bed { path: "/d/c.bed".into() });
        // CCC accepts genotype-file inputs (allele domain by construction).
        RunConfig::from_kv_line("metric=ccc nv=8 nf=16 file=/d/c.bed format=bed").unwrap();
        // Junk formats and orphaned format keys are typed errors.
        let err = RunConfig::from_toml_str(
            "[input]\nfile = \"/d/c.bed\"\nformat = \"hdf5\"\n",
        )
        .unwrap_err();
        assert!(err.to_string().contains("raw|bed|vcf"), "{err}");
        let err = RunConfig::from_toml_str("[input]\nformat = \"bed\"\n").unwrap_err();
        assert!(err.to_string().contains("requires input.file"), "{err}");
        let err = RunConfig::from_kv_line("nv=8 format=bed").unwrap_err();
        assert!(err.to_string().contains("requires file"), "{err}");
    }

    #[test]
    fn parses_threads_and_rejects_zero() {
        let cfg = RunConfig::from_toml_str("[run]\nthreads = 4\n").unwrap();
        assert_eq!(cfg.threads, 4);
        assert_eq!(RunConfig::default().threads, 1);
        let err = RunConfig::from_toml_str("[run]\nthreads = 0\n").unwrap_err();
        assert!(err.to_string().contains("threads"), "{err}");
        // Negative values must not wrap into astronomically large
        // thread counts through the usize cast.
        let err = RunConfig::from_toml_str("[run]\nthreads = -1\n").unwrap_err();
        assert!(err.to_string().contains("threads"), "{err}");
    }

    #[test]
    fn rejects_bad_numway() {
        let err = RunConfig::from_toml_str("[run]\nnum_way = 4\n").unwrap_err();
        assert!(err.to_string().contains("num_way"));
    }

    #[test]
    fn rejects_2way_staging() {
        let err =
            RunConfig::from_toml_str("[run]\nnum_way = 2\n[decomp]\nnum_stage = 4\n").unwrap_err();
        assert!(err.to_string().contains("staging"));
    }

    #[test]
    fn rejects_oversized_grid() {
        let err = RunConfig::from_toml_str("[run]\nnv = 4\n[decomp]\nnpv = 8\n").unwrap_err();
        assert!(err.to_string().contains("npv"));
    }

    #[test]
    fn parses_metric_and_alleles_input() {
        let cfg = RunConfig::from_toml_str(
            "[run]\nmetric = \"ccc\"\n[input]\nsynthetic = \"alleles\"\nseed = 9\n",
        )
        .unwrap();
        assert_eq!(cfg.metric, MetricId::Ccc);
        assert!(matches!(
            cfg.input,
            InputSource::Synthetic { kind: SyntheticKind::Alleles, seed: 9 }
        ));
    }

    #[test]
    fn default_metric_is_czekanowski() {
        assert_eq!(RunConfig::default().metric, MetricId::Czekanowski);
    }

    #[test]
    fn rejects_ccc_over_non_allele_synthetic() {
        // Defaulting to the grid generator under CCC would silently
        // compute meaningless frequencies — must be rejected.
        let err = RunConfig::from_toml_str("[run]\nmetric = \"ccc\"\n").unwrap_err();
        assert!(err.to_string().contains("alleles"), "{err}");
        // File inputs are the user's responsibility.
        RunConfig::from_toml_str("[run]\nmetric = \"ccc\"\n[input]\nfile = \"/d/v.bin\"\n")
            .unwrap();
        // Binary metrics threshold real inputs by design — grid is fine.
        RunConfig::from_toml_str("[run]\nmetric = \"sorenson\"\n").unwrap();
    }

    #[test]
    fn rejects_3way_for_2way_only_metrics() {
        for m in ["ccc", "sorenson"] {
            let err =
                RunConfig::from_toml_str(&format!("[run]\nmetric = \"{m}\"\nnum_way = 3\n"))
                    .unwrap_err();
            assert!(err.to_string().contains("3-way"), "{m}: {err}");
        }
        // Czekanowski keeps its 3-way form.
        RunConfig::from_toml_str("[run]\nmetric = \"czekanowski\"\nnum_way = 3\n").unwrap();
    }

    #[test]
    fn batch_requests_override_base_in_file_order() {
        let text = r#"
[run]
nv = 64
nf = 32

[input]
synthetic = "alleles"
seed = 3

[request.ccc]
metric = "ccc"
npv = 2

[request.sorenson-wide]
metric = "sorenson"
npv = 4
threads = 2
"#;
        let entries = batch_from_toml_str(text).unwrap();
        assert_eq!(entries.len(), 2);
        assert_eq!(entries[0].name, "ccc");
        assert_eq!(entries[0].cfg.metric, MetricId::Ccc);
        assert_eq!(entries[0].cfg.grid, Grid::new(1, 2, 1));
        assert_eq!(entries[0].cfg.nv, 64);
        assert_eq!(entries[1].name, "sorenson-wide");
        assert_eq!(entries[1].cfg.metric, MetricId::Sorenson);
        assert_eq!(entries[1].cfg.grid, Grid::new(1, 4, 1));
        assert_eq!(entries[1].cfg.threads, 2);
        // Requests share the base input — the shared-dataset contract.
        assert_eq!(entries[0].cfg.input, entries[1].cfg.input);
    }

    #[test]
    fn batch_rejects_empty_and_invalid_requests() {
        let err = batch_from_toml_str("[run]\nnv = 4\n").unwrap_err();
        assert!(err.to_string().contains("no [request"), "{err}");
        // An invalid request names itself in the error chain.
        let err =
            batch_from_toml_str("[request.bad]\nmetric = \"ccc\"\nnum_way = 3\n").unwrap_err();
        assert!(format!("{err:#}").contains("bad"), "{err:#}");
        let err = batch_from_toml_str("[request.]\nmetric = \"sorenson\"\n").unwrap_err();
        assert!(err.to_string().contains("name"), "{err}");
        // Input-family keys (and typos) in a request table must error,
        // not silently run against the shared dataset anyway.
        let err = batch_from_toml_str("[request.r]\nmetric = \"sorenson\"\nseed = 9\n")
            .unwrap_err();
        assert!(err.to_string().contains("seed") && err.to_string().contains("[input]"), "{err}");
        let err = batch_from_toml_str("[request.r]\nmetrc = \"sorenson\"\n").unwrap_err();
        assert!(err.to_string().contains("metrc"), "{err}");
        // Misnamed sections must error, not silently drop requests.
        for bad in ["[request]\nmetric = \"ccc\"\n", "[reqest.b]\nnpv = 2\n", "top = 1\n"] {
            let err = batch_from_toml_str(bad).unwrap_err();
            assert!(err.to_string().contains("section"), "{bad:?}: {err}");
        }
        // Typos in the shared base tables must error too.
        let err = batch_from_toml_str("[run]\nthrads = 4\n[request.r]\nmetric = \"sorenson\"\n")
            .unwrap_err();
        assert!(err.to_string().contains("thrads"), "{err}");
        // The legacy store_metrics flag is a no-op on the session path
        // and must be rejected rather than silently ignored.
        let err = batch_from_toml_str("[request.r]\nmetric = \"sorenson\"\nstore_metrics = true\n")
            .unwrap_err();
        assert!(err.to_string().contains("store_metrics"), "{err}");
        // A copy-pasted request left unrenamed must not silently merge.
        let err = batch_from_toml_str(
            "[request.a]\nmetric = \"sorenson\"\n[request.b]\nnpv = 2\n[request.a]\nnpv = 4\n",
        )
        .unwrap_err();
        assert!(err.to_string().contains("duplicate section"), "{err}");
    }

    #[test]
    fn batch_accepts_every_request_key() {
        // Pins the RUN_KEYS/DECOMP_KEYS whitelist to the appliers: every
        // advertised request key must parse, apply, and validate.
        let text = r#"
[input]
synthetic = "grid"
seed = 2

[request.full]
metric = "czekanowski"
num_way = 3
nv = 30
nf = 24
precision = "f32"
backend = "reference"
threads = 2
output_dir = "/tmp/comet-batch-keys"
output_threshold = 0.5
npf = 1
npv = 3
npr = 2
num_stage = 4
stage = 3
"#;
        let entries = batch_from_toml_str(text).unwrap();
        let cfg = &entries[0].cfg;
        assert_eq!(cfg.num_way, 3);
        assert_eq!((cfg.nv, cfg.nf), (30, 24));
        assert_eq!(cfg.precision, Precision::F32);
        assert_eq!(cfg.backend, BackendKind::CpuReference);
        assert_eq!(cfg.threads, 2);
        assert_eq!(cfg.output_dir.as_deref(), Some("/tmp/comet-batch-keys"));
        assert_eq!(cfg.output_threshold, Some(0.5));
        assert_eq!(cfg.grid, Grid::new(1, 3, 2));
        assert_eq!(cfg.num_stage, 4);
        assert_eq!(cfg.stage, Some(3));
    }

    #[test]
    fn precision_aliases() {
        assert_eq!(Precision::parse("single").unwrap(), Precision::F32);
        assert_eq!(Precision::parse("dp").unwrap(), Precision::F64);
        assert!(Precision::parse("f16").is_err());
    }
}
