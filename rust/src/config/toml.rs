//! Minimal TOML-subset parser (see module docs in `config`): sections,
//! scalar key/values, comments. Enough for launcher configs without the
//! (unavailable) toml crate.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// A parsed scalar value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

impl Value {
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            other => bail!("expected string, got {other:?}"),
        }
    }
    pub fn as_int(&self) -> Result<i64> {
        match self {
            Value::Int(i) => Ok(*i),
            other => bail!("expected integer, got {other:?}"),
        }
    }
    pub fn as_float(&self) -> Result<f64> {
        match self {
            Value::Float(f) => Ok(*f),
            Value::Int(i) => Ok(*i as f64),
            other => bail!("expected float, got {other:?}"),
        }
    }
    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            other => bail!("expected bool, got {other:?}"),
        }
    }
}

/// A parsed document: (section, key) → value. Keys before any section
/// header live in section "".
#[derive(Debug, Default, Clone)]
pub struct Doc {
    entries: BTreeMap<(String, String), Value>,
    /// Section names in first-appearance order (batch request files are
    /// executed in file order, which a BTreeMap alone would lose).
    order: Vec<String>,
    /// Sections whose `[header]` appeared more than once. Re-opening
    /// merges keys (TOML-like), but strict consumers (batch files)
    /// reject it — a copy-pasted `[request.a]` left unrenamed would
    /// otherwise silently collapse two requests into one.
    reopened: Vec<String>,
}

impl Doc {
    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.entries.get(&(section.to_string(), key.to_string()))
    }

    pub fn sections(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.entries.keys().map(|(s, _)| s.as_str()).collect();
        v.dedup();
        v
    }

    /// Section names in the order they first appear in the document
    /// (including empty sections — a bare `[header]` with no keys).
    pub fn sections_in_order(&self) -> &[String] {
        &self.order
    }

    /// Sections whose header appeared more than once (merged keys).
    pub fn reopened_sections(&self) -> &[String] {
        &self.reopened
    }

    /// All keys of one section (sorted — BTreeMap order). Lets callers
    /// reject unknown keys instead of silently ignoring typos.
    pub fn section_keys(&self, section: &str) -> Vec<&str> {
        self.entries
            .keys()
            .filter(|(s, _)| s == section)
            .map(|(_, k)| k.as_str())
            .collect()
    }
}

/// Parse the TOML subset. Errors carry line numbers.
pub fn parse(text: &str) -> Result<Doc> {
    let mut doc = Doc::default();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(name) = line.strip_prefix('[') {
            let Some(name) = name.strip_suffix(']') else {
                bail!("line {}: malformed section header {raw:?}", lineno + 1);
            };
            section = name.trim().to_string();
            if !doc.order.contains(&section) {
                doc.order.push(section.clone());
            } else if !doc.reopened.contains(&section) {
                doc.reopened.push(section.clone());
            }
            continue;
        }
        let Some(eq) = line.find('=') else {
            bail!("line {}: expected key = value, got {raw:?}", lineno + 1);
        };
        let key = line[..eq].trim();
        let val = line[eq + 1..].trim();
        if key.is_empty() || val.is_empty() {
            bail!("line {}: empty key or value in {raw:?}", lineno + 1);
        }
        let value = parse_value(val)
            .map_err(|e| anyhow::anyhow!("line {}: {e} in {raw:?}", lineno + 1))?;
        if !doc.order.contains(&section) {
            doc.order.push(section.clone());
        }
        let prev = doc
            .entries
            .insert((section.clone(), key.to_string()), value);
        if prev.is_some() {
            bail!("line {}: duplicate key {key:?} in section {section:?}", lineno + 1);
        }
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a quoted string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value> {
    if let Some(body) = s.strip_prefix('"') {
        let Some(body) = body.strip_suffix('"') else {
            bail!("unterminated string");
        };
        return Ok(Value::Str(body.to_string()));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    bail!("cannot parse value {s:?}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_all_scalar_types() {
        let doc = parse(
            "a = 1\nb = 2.5\nc = \"hi\"\nd = true\n[s]\ne = false\n",
        )
        .unwrap();
        assert_eq!(doc.get("", "a").unwrap().as_int().unwrap(), 1);
        assert_eq!(doc.get("", "b").unwrap().as_float().unwrap(), 2.5);
        assert_eq!(doc.get("", "c").unwrap().as_str().unwrap(), "hi");
        assert!(doc.get("", "d").unwrap().as_bool().unwrap());
        assert!(!doc.get("s", "e").unwrap().as_bool().unwrap());
    }

    #[test]
    fn comments_and_blanks_ignored() {
        let doc = parse("# header\n\na = 1  # trailing\n").unwrap();
        assert_eq!(doc.get("", "a").unwrap().as_int().unwrap(), 1);
    }

    #[test]
    fn hash_inside_string_kept() {
        let doc = parse("p = \"/tmp/#1\"\n").unwrap();
        assert_eq!(doc.get("", "p").unwrap().as_str().unwrap(), "/tmp/#1");
    }

    #[test]
    fn int_float_coercion() {
        let doc = parse("x = 3\n").unwrap();
        assert_eq!(doc.get("", "x").unwrap().as_float().unwrap(), 3.0);
        assert!(doc.get("", "x").unwrap().as_str().is_err());
    }

    #[test]
    fn errors_carry_line_numbers() {
        let err = parse("a = 1\nbogus line\n").unwrap_err();
        assert!(err.to_string().contains("line 2"), "{err}");
        let err = parse("[unclosed\n").unwrap_err();
        assert!(err.to_string().contains("line 1"), "{err}");
    }

    #[test]
    fn duplicate_keys_rejected() {
        let err = parse("a = 1\na = 2\n").unwrap_err();
        assert!(err.to_string().contains("duplicate"), "{err}");
    }

    #[test]
    fn sections_in_order_preserves_file_order() {
        let doc = parse("top = 1\n[zeta]\nk = 1\n[alpha]\nk = 2\n[zeta]\n").unwrap();
        assert_eq!(doc.sections_in_order(), &["", "zeta", "alpha"]);
        // Re-opened headers are tracked (strict consumers reject them).
        assert_eq!(doc.reopened_sections(), &["zeta"]);
        let doc = parse("[a]\nk = 1\n[b]\nk = 2\n").unwrap();
        assert!(doc.reopened_sections().is_empty());
    }

    #[test]
    fn section_keys_lists_one_section() {
        let doc = parse("[a]\nx = 1\ny = 2\n[b]\nz = 3\n").unwrap();
        assert_eq!(doc.section_keys("a"), vec!["x", "y"]);
        assert_eq!(doc.section_keys("b"), vec!["z"]);
        assert!(doc.section_keys("c").is_empty());
    }

    #[test]
    fn sections_scope_keys() {
        let doc = parse("[x]\nk = 1\n[y]\nk = 2\n").unwrap();
        assert_eq!(doc.get("x", "k").unwrap().as_int().unwrap(), 1);
        assert_eq!(doc.get("y", "k").unwrap().as_int().unwrap(), 2);
        assert!(doc.get("z", "k").is_none());
    }
}
