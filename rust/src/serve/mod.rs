//! `comet serve` — the concurrent request scheduler over [`Session`].
//!
//! The paper's engine computes one campaign as fast as the hardware
//! allows; this module turns it into a **server**: many clients, one
//! long-lived session, bounded resources. Three mechanisms do the
//! work:
//!
//! * **Per-dataset sharding.** Requests are hashed by their dataset
//!   identity (input source + nv + nf) onto one of `workers` shard
//!   queues, each drained by a dedicated worker thread. Requests
//!   against the same dataset therefore serialize onto the same
//!   worker — they share one ingest and one `VirtualCluster` build at
//!   a time instead of racing duplicate ones — while requests against
//!   different datasets run genuinely in parallel.
//! * **Admission control.** Each shard queue is a bounded FIFO; a
//!   submission past its capacity is rejected *immediately* with the
//!   typed [`ServeError::Busy`] (no deadlock, no unbounded queueing),
//!   and a request whose estimated block bytes exceed
//!   [`ServeConfig::max_request_bytes`] is rejected with
//!   [`ServeError::TooLarge`] before it can OOM the session. Clients
//!   retry; the server never falls over.
//! * **Bounded caches.** The session's block-cache byte budget and
//!   executable-cache slot budget ([`SessionLimits`]) evict LRU
//!   entries under pressure; the resulting hit/miss/eviction counters
//!   ride each [`RunOutcome`]'s stats back to the client path.
//!
//! The wire protocol is line-in, frames-out: a client writes one
//! request spec per line ([`RunConfig::from_kv_line`] — the same
//! vocabulary as the TOML form), and the server streams the run's
//! tiles back as [`output::wire`](crate::output::wire) frames,
//! terminated by a `Done` frame (metric count + checksum digest, so
//! the client can diff against a one-shot run) or an `Error` frame.
//! [`serve_connection`] drives one such connection over any
//! `Read`/`Write` pair; [`serve_unix`] accepts them from a Unix
//! socket; [`request_over_stream`] is the matching client.
//!
//! Queueing behavior is priced by `perfmodel::predict_serve`
//! (queue-wait + eviction-refill terms); `tests/serve_concurrency.rs`
//! pins the contracts: bit-identity with one-shot runs under ≥ 8
//! concurrent mixed-metric clients, sharded ingest reuse, budget
//! adherence, and typed rejection + recovery.

use std::collections::hash_map::DefaultHasher;
use std::collections::VecDeque;
use std::hash::{Hash, Hasher};
use std::io::{BufRead, BufReader, Read, Write};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::config::RunConfig;
use crate::coordinator::RunOutcome;
use crate::output::sink::{ResultSink, Tile};
use crate::output::wire::{Frame, SocketSink};
use crate::session::Session;
use crate::vecdata::block::Repr;

/// Scheduler shape: how many shard workers drain requests and how much
/// queueing/size slack admission control allows.
#[derive(Debug, Clone, Copy)]
pub struct ServeConfig {
    /// Shard worker threads (>= 1). A dataset's requests always land
    /// on the same shard, so `workers` is also the number of datasets
    /// the server computes concurrently.
    pub workers: usize,
    /// Bounded per-shard FIFO depth (>= 1); submissions past it get
    /// [`ServeError::Busy`].
    pub queue_capacity: usize,
    /// Reject requests whose estimated resident block bytes
    /// ([`estimated_request_bytes`]) exceed this (None = unlimited).
    pub max_request_bytes: Option<u64>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig { workers: 2, queue_capacity: 8, max_request_bytes: None }
    }
}

/// Typed admission-control rejections. These are *flow control*, not
/// failures: a client that sees `Busy` backs off and retries; the
/// server keeps running.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// The request's shard queue is full.
    Busy { shard: usize, capacity: usize },
    /// The request's estimated block bytes exceed the admission limit.
    TooLarge { estimated_bytes: u64, limit: u64 },
    /// The request spec failed validation.
    Invalid(String),
    /// The server is shutting down.
    Shutdown,
    /// The shard worker running (or about to run) this request died —
    /// a panic unwound it mid-request. The server respawns the worker
    /// on the next submission to that shard; the failed request
    /// surfaces this typed error (an `Error` wire frame over a
    /// connection) instead of hanging its client forever.
    WorkerDied { shard: usize },
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Busy { shard, capacity } => write!(
                f,
                "busy: shard {shard} queue is at capacity ({capacity}); retry later"
            ),
            ServeError::TooLarge { estimated_bytes, limit } => write!(
                f,
                "too large: request needs ~{estimated_bytes} block bytes \
                 (admission limit {limit})"
            ),
            ServeError::Invalid(msg) => write!(f, "invalid request: {msg}"),
            ServeError::Shutdown => write!(f, "server is shutting down"),
            ServeError::WorkerDied { shard } => write!(
                f,
                "shard {shard} worker died mid-request (panic); \
                 the shard respawns on its next submission — retry"
            ),
        }
    }
}

impl std::error::Error for ServeError {}

/// Estimated resident bytes of the blocks a request will ingest —
/// the admission-control cost model. Matches
/// `Block::resident_bytes` summed over the whole dataset: packed
/// bit-domain metrics cost one u64 word per 64 features per plane
/// (one plane for Sorensen, two allele planes — budgeted three to
/// cover a missing-mask plane — for CCC), float metrics cost
/// nv × nf elements at run precision.
pub fn estimated_request_bytes(cfg: &RunConfig) -> u64 {
    let (nv, nf) = (cfg.nv as u64, cfg.nf as u64);
    match cfg.metric.preferred_repr() {
        Repr::Packed => nv * nf.div_ceil(64) * 8,
        Repr::Packed2 => nv * nf.div_ceil(64) * 8 * 3,
        Repr::Float => nv * nf * cfg.precision.bytes() as u64,
    }
}

struct Job {
    cfg: RunConfig,
    sink: Arc<dyn ResultSink>,
    reply: Sender<Result<RunOutcome>>,
    enqueued: Instant,
}

struct ShardState {
    jobs: VecDeque<Job>,
    open: bool,
}

struct ShardQueue {
    capacity: usize,
    state: Mutex<ShardState>,
    ready: Condvar,
}

#[derive(Default)]
struct Counters {
    submitted: AtomicU64,
    completed: AtomicU64,
    rejected_busy: AtomicU64,
    rejected_too_large: AtomicU64,
    queue_wait_nanos: AtomicU64,
    respawns: AtomicU64,
}

/// Poison-tolerant lock: a worker that panicked while holding a shard
/// or writer lock must not turn every later `lock().unwrap()` into a
/// cascading panic — the state these mutexes guard (job queues, wire
/// writers) stays consistent across an unwind, so we keep serving.
fn relock<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Point-in-time scheduler counters.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ServeStats {
    pub submitted: u64,
    pub completed: u64,
    pub rejected_busy: u64,
    pub rejected_too_large: u64,
    /// Total seconds requests spent queued before a worker picked
    /// them up (the perfmodel queue-wait term, measured).
    pub queue_wait_secs: f64,
    /// Shard workers respawned after dying to a panic (0 on a healthy
    /// server — the fault-tolerance signal).
    pub respawns: u64,
}

/// Handle to one submitted request; [`Ticket::wait`] blocks until its
/// shard worker finishes the run.
pub struct Ticket {
    rx: Receiver<Result<RunOutcome>>,
    shard: usize,
}

impl Ticket {
    /// Block until the shard worker finishes the run. If the worker
    /// dies (panics) with this request in flight or still queued, its
    /// reply channel drops and this surfaces the typed
    /// [`ServeError::WorkerDied`] — never a hang, never a poisoned
    /// lock: the client sees an error and can resubmit.
    pub fn wait(self) -> Result<RunOutcome> {
        match self.rx.recv() {
            Ok(result) => result,
            Err(_) => Err(anyhow::Error::new(ServeError::WorkerDied { shard: self.shard })),
        }
    }
}

/// The scheduler: shard queues + worker threads over one shared
/// [`Session`]. Dropping the server closes the queues, drains queued
/// work, and joins the workers.
pub struct Server {
    session: Arc<Session>,
    cfg: ServeConfig,
    shards: Vec<Arc<ShardQueue>>,
    /// One slot per shard; a dead (panicked) worker is reaped and
    /// respawned by the next submission to its shard.
    workers: Vec<Mutex<Option<JoinHandle<()>>>>,
    counters: Arc<Counters>,
}

fn spawn_worker(
    shard: usize,
    session: &Arc<Session>,
    queue: &Arc<ShardQueue>,
    counters: &Arc<Counters>,
) -> Result<JoinHandle<()>> {
    let session = Arc::clone(session);
    let queue = Arc::clone(queue);
    let counters = Arc::clone(counters);
    std::thread::Builder::new()
        .name(format!("serve-shard-{shard}"))
        .spawn(move || worker_main(session, queue, counters))
        .context("spawn serve worker")
}

impl Server {
    /// Spawn the shard workers. Misconfigurations (zero workers, zero
    /// queue capacity) error here, at startup — a zero-worker server
    /// would accept requests that nothing can ever drain.
    pub fn start(session: Arc<Session>, cfg: ServeConfig) -> Result<Server> {
        if cfg.workers == 0 {
            bail!("serve misconfiguration: workers must be >= 1 (nothing would drain the queues)");
        }
        if cfg.queue_capacity == 0 {
            bail!("serve misconfiguration: queue_capacity must be >= 1 (every submit would be Busy)");
        }
        let counters = Arc::new(Counters::default());
        let mut shards = Vec::with_capacity(cfg.workers);
        let mut workers = Vec::with_capacity(cfg.workers);
        for shard in 0..cfg.workers {
            let queue = Arc::new(ShardQueue {
                capacity: cfg.queue_capacity,
                state: Mutex::new(ShardState { jobs: VecDeque::new(), open: true }),
                ready: Condvar::new(),
            });
            shards.push(Arc::clone(&queue));
            workers.push(Mutex::new(Some(spawn_worker(shard, &session, &queue, &counters)?)));
        }
        Ok(Server { session, cfg, shards, workers, counters })
    }

    /// Reap-and-respawn a shard's worker if it died. A panicking run
    /// (e.g. a sink that panics on the worker thread) unwinds
    /// `worker_main`; the in-flight request's reply channel drops —
    /// its ticket surfaces [`ServeError::WorkerDied`] — and the next
    /// submission to the shard lands here, joins the corpse, and
    /// spawns a fresh worker over the same (still-consistent) queue.
    fn ensure_worker(&self, shard: usize) -> std::result::Result<(), ServeError> {
        let mut slot = relock(&self.workers[shard]);
        let dead = match slot.as_ref() {
            None => true,
            Some(h) => h.is_finished(),
        };
        if !dead {
            return Ok(());
        }
        // A worker that exited because its queue closed is shutdown,
        // not death — don't resurrect it.
        if !relock(&self.shards[shard].state).open {
            return Err(ServeError::Shutdown);
        }
        if let Some(h) = slot.take() {
            let _ = h.join();
        }
        match spawn_worker(shard, &self.session, &self.shards[shard], &self.counters) {
            Ok(h) => {
                *slot = Some(h);
                self.counters.respawns.fetch_add(1, Ordering::Relaxed);
                Ok(())
            }
            Err(_) => Err(ServeError::WorkerDied { shard }),
        }
    }

    pub fn session(&self) -> &Arc<Session> {
        &self.session
    }

    /// Which shard (and therefore which worker) `cfg`'s dataset maps
    /// to. Deterministic per (input, nv, nf) — the sharding contract.
    pub fn shard_of(&self, cfg: &RunConfig) -> usize {
        let mut h = DefaultHasher::new();
        cfg.input.hash(&mut h);
        cfg.nv.hash(&mut h);
        cfg.nf.hash(&mut h);
        (h.finish() % self.shards.len() as u64) as usize
    }

    /// Jobs currently queued (not yet picked up) on a shard.
    pub fn queue_depth(&self, shard: usize) -> usize {
        relock(&self.shards[shard].state).jobs.len()
    }

    /// Admit a request: validate, size-check, enqueue on its dataset's
    /// shard. Returns immediately — either a [`Ticket`] or a typed
    /// rejection. Tiles stream through `sink` from the worker thread.
    pub fn submit(
        &self,
        cfg: &RunConfig,
        sink: Arc<dyn ResultSink>,
    ) -> std::result::Result<Ticket, ServeError> {
        cfg.validate().map_err(|e| ServeError::Invalid(format!("{e:#}")))?;
        let estimated = estimated_request_bytes(cfg);
        if let Some(limit) = self.cfg.max_request_bytes {
            if estimated > limit {
                self.counters.rejected_too_large.fetch_add(1, Ordering::Relaxed);
                return Err(ServeError::TooLarge { estimated_bytes: estimated, limit });
            }
        }
        let shard = self.shard_of(cfg);
        // Respawn the shard's worker first if a panic killed it — the
        // queue itself survives an unwind, so queued work is preserved.
        self.ensure_worker(shard)?;
        let queue = &self.shards[shard];
        let mut state = relock(&queue.state);
        if !state.open {
            return Err(ServeError::Shutdown);
        }
        if state.jobs.len() >= queue.capacity {
            self.counters.rejected_busy.fetch_add(1, Ordering::Relaxed);
            return Err(ServeError::Busy { shard, capacity: queue.capacity });
        }
        let (reply, rx) = channel();
        state.jobs.push_back(Job {
            cfg: cfg.clone(),
            sink,
            reply,
            enqueued: Instant::now(),
        });
        drop(state);
        queue.ready.notify_one();
        self.counters.submitted.fetch_add(1, Ordering::Relaxed);
        Ok(Ticket { rx, shard })
    }

    pub fn stats(&self) -> ServeStats {
        ServeStats {
            submitted: self.counters.submitted.load(Ordering::Relaxed),
            completed: self.counters.completed.load(Ordering::Relaxed),
            rejected_busy: self.counters.rejected_busy.load(Ordering::Relaxed),
            rejected_too_large: self.counters.rejected_too_large.load(Ordering::Relaxed),
            queue_wait_secs: self.counters.queue_wait_nanos.load(Ordering::Relaxed) as f64 * 1e-9,
            respawns: self.counters.respawns.load(Ordering::Relaxed),
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        for shard in &self.shards {
            relock(&shard.state).open = false;
            shard.ready.notify_all();
        }
        for worker in &self.workers {
            if let Some(h) = relock(worker).take() {
                let _ = h.join();
            }
        }
    }
}

fn worker_main(session: Arc<Session>, queue: Arc<ShardQueue>, counters: Arc<Counters>) {
    loop {
        let job = {
            let mut state = relock(&queue.state);
            loop {
                if let Some(job) = state.jobs.pop_front() {
                    break job;
                }
                // Shutdown drains: queued jobs above still run; only an
                // empty closed queue exits.
                if !state.open {
                    return;
                }
                state = queue
                    .ready
                    .wait(state)
                    .unwrap_or_else(std::sync::PoisonError::into_inner);
            }
        };
        counters
            .queue_wait_nanos
            .fetch_add(job.enqueued.elapsed().as_nanos() as u64, Ordering::Relaxed);
        let result = session
            .request_from_config(&job.cfg)
            .and_then(|req| session.run(&req, job.sink.as_ref()));
        counters.completed.fetch_add(1, Ordering::Relaxed);
        // A dropped ticket (client gone) is fine — the run already
        // streamed its tiles through the sink.
        let _ = job.reply.send(result);
    }
}

// ---------------------------------------------------------------------------
// Line-protocol drivers (socket server, connection handler, client).

/// Serve one connection: line-delimited request specs in
/// ([`RunConfig::from_kv_line`]), wire frames out. Each request's
/// tiles are followed by a `Done` frame; a failed request (parse,
/// admission, run error) produces an `Error` frame and the connection
/// stays usable for the next line. Blank lines and `#` comments are
/// ignored. Requests on one connection run sequentially; concurrency
/// comes from many connections feeding the shard queues.
pub fn serve_connection<R, W>(server: &Server, reader: R, writer: W) -> Result<()>
where
    R: Read,
    W: Write + Send + 'static,
{
    let reader = BufReader::new(reader);
    let shared = Arc::new(Mutex::new(writer));
    for line in reader.lines() {
        let line = line.context("read request line")?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let frame = match handle_request(server, line, &shared) {
            Ok(done) => done,
            Err(e) => Frame::Error { message: format!("{e:#}") },
        };
        // Poison-tolerant: a worker panicking mid-frame must not take
        // the whole connection down with a lock-poison cascade — the
        // client gets this request's Error frame and keeps going.
        let mut w = relock(&shared);
        frame.write_to(&mut *w)?;
        w.flush().context("flush reply")?;
    }
    Ok(())
}

fn handle_request<W: Write + Send + 'static>(
    server: &Server,
    line: &str,
    shared: &Arc<Mutex<W>>,
) -> Result<Frame> {
    let cfg = RunConfig::from_kv_line(line)?;
    let sink: Arc<dyn ResultSink> = Arc::new(SocketSink::shared(Arc::clone(shared)));
    let ticket = server.submit(&cfg, sink).map_err(anyhow::Error::new)?;
    let outcome = ticket.wait()?;
    Ok(Frame::Done {
        metrics: outcome.stats.metrics,
        checksum: outcome.checksum.digest(),
    })
}

/// Accept loop over a Unix socket: one handler thread per connection.
/// `max_conns` bounds accepted connections (smoke jobs run-and-exit);
/// the loop joins every handler before returning, so accepted requests
/// always finish.
pub fn serve_unix(
    server: Arc<Server>,
    listener: std::os::unix::net::UnixListener,
    max_conns: Option<usize>,
) -> Result<()> {
    let mut handlers = Vec::new();
    let mut served = 0usize;
    for stream in listener.incoming() {
        let stream = stream.context("accept connection")?;
        let reader = stream.try_clone().context("clone connection stream")?;
        let server = Arc::clone(&server);
        handlers.push(
            std::thread::Builder::new()
                .name("serve-conn".into())
                .spawn(move || {
                    if let Err(e) = serve_connection(&server, reader, stream) {
                        eprintln!("comet serve: connection error: {e:#}");
                    }
                })
                .context("spawn connection handler")?,
        );
        served += 1;
        if max_conns.is_some_and(|max| served >= max) {
            break;
        }
    }
    for h in handlers {
        let _ = h.join();
    }
    Ok(())
}

/// One request's decoded reply, client side.
#[derive(Debug)]
pub struct ClientReply {
    pub tiles: Vec<Tile>,
    /// Metric values across the tiles (client-side count).
    pub values: u64,
    /// Server-reported metric count (from the `Done` frame).
    pub metrics: u64,
    /// Server-reported checksum digest — diff it against a one-shot
    /// `comet run` of the same spec.
    pub checksum: String,
}

/// Minimal line-protocol client: write one request line, read frames
/// until the terminating `Done` (returned as a [`ClientReply`]) or
/// `Error` (returned as an error).
pub fn request_over_stream<S: Read + Write>(stream: &mut S, line: &str) -> Result<ClientReply> {
    writeln!(stream, "{line}").context("send request line")?;
    stream.flush().context("flush request line")?;
    let mut tiles = Vec::new();
    loop {
        match Frame::read_from(stream)? {
            None => bail!("connection closed before a Done/Error frame"),
            Some(Frame::Tile(tile)) => tiles.push(tile),
            Some(Frame::Done { metrics, checksum }) => {
                let values = tiles.iter().map(|t| t.len() as u64).sum();
                return Ok(ClientReply { tiles, values, metrics, checksum });
            }
            Some(Frame::Error { message }) => bail!("server error: {message}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::MetricId;
    use crate::output::sink::DiscardSink;
    use crate::session::SessionLimits;

    fn small_cfg(seed: u64) -> RunConfig {
        RunConfig::from_kv_line(&format!("metric=czekanowski nv=12 nf=16 seed={seed}")).unwrap()
    }

    fn test_session() -> Arc<Session> {
        Arc::new(Session::with_limits("artifacts", SessionLimits::default()))
    }

    #[test]
    fn zero_worker_and_zero_queue_misconfigurations_error_at_startup() {
        let err = Server::start(
            test_session(),
            ServeConfig { workers: 0, ..Default::default() },
        )
        .unwrap_err();
        assert!(err.to_string().contains("workers"), "{err}");
        let err = Server::start(
            test_session(),
            ServeConfig { queue_capacity: 0, ..Default::default() },
        )
        .unwrap_err();
        assert!(err.to_string().contains("queue_capacity"), "{err}");
    }

    #[test]
    fn sharding_is_deterministic_per_dataset() {
        let server = Server::start(
            test_session(),
            ServeConfig { workers: 4, ..Default::default() },
        )
        .unwrap();
        let a = small_cfg(1);
        // Same dataset, different metric/grid: same shard.
        let mut a2 = small_cfg(1);
        a2.metric = MetricId::Sorenson;
        a2.grid = crate::decomp::Grid::new(1, 2, 1);
        assert_eq!(server.shard_of(&a), server.shard_of(&a2));
        // Shards stay in range over many datasets.
        for seed in 0..64 {
            assert!(server.shard_of(&small_cfg(seed)) < 4);
        }
    }

    #[test]
    fn size_admission_rejects_with_typed_too_large() {
        let server = Server::start(
            test_session(),
            ServeConfig { max_request_bytes: Some(16_384), ..Default::default() },
        )
        .unwrap();
        let big = RunConfig::from_kv_line("metric=czekanowski nv=256 nf=384").unwrap();
        let err = server.submit(&big, Arc::new(DiscardSink)).unwrap_err();
        match err {
            ServeError::TooLarge { estimated_bytes, limit } => {
                assert_eq!(limit, 16_384);
                assert_eq!(estimated_bytes, 256 * 384 * 8);
            }
            other => panic!("expected TooLarge, got {other:?}"),
        }
        // Packed metrics estimate 64× smaller: the same shape fits.
        let mut packed = big;
        packed.metric = MetricId::Sorenson;
        assert_eq!(estimated_request_bytes(&packed), 256 * 6 * 8);
        // CCC budgets three packed planes (lo, hi, missing mask).
        let mut geno = packed.clone();
        geno.metric = MetricId::Ccc;
        assert_eq!(estimated_request_bytes(&geno), 256 * 6 * 8 * 3);
        let ticket = server.submit(&packed, Arc::new(DiscardSink)).unwrap();
        ticket.wait().unwrap();
        assert_eq!(server.stats().rejected_too_large, 1);
    }

    #[test]
    fn invalid_requests_are_rejected_typed_not_run() {
        let server = Server::start(test_session(), ServeConfig::default()).unwrap();
        let mut cfg = small_cfg(1);
        cfg.num_way = 5;
        match server.submit(&cfg, Arc::new(DiscardSink)) {
            Err(ServeError::Invalid(msg)) => assert!(msg.contains("num_way"), "{msg}"),
            other => panic!("expected Invalid, got {:?}", other.map(|_| ())),
        }
    }
}
