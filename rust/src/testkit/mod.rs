//! Hand-rolled property-testing harness (proptest is not available
//! offline). [`forall`] runs a property over generated cases with
//! shrink-free but *reproducible* failures: the failing case's seed is
//! printed so the exact case can be replayed.

pub mod faults;

use crate::util::prng::Stream;

/// A generation context handed to case generators.
pub struct Gen {
    pub stream: Stream,
    pub seed: u64,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi_incl: usize) -> usize {
        lo + self.stream.below((hi_incl - lo + 1) as u64) as usize
    }
    pub fn f64_unit(&mut self) -> f64 {
        self.stream.next_f64()
    }
    pub fn bool(&mut self) -> bool {
        self.stream.next_u64() & 1 == 1
    }
    pub fn pick<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.usize_in(0, items.len() - 1)]
    }
}

/// Run `cases` property checks. `gen` builds a case from a [`Gen`];
/// `prop` returns `Err(msg)` to fail. Panics with the case seed on
/// failure so it can be replayed with [`replay`].
pub fn forall<C: std::fmt::Debug>(
    name: &str,
    cases: usize,
    mut gen: impl FnMut(&mut Gen) -> C,
    mut prop: impl FnMut(&C) -> Result<(), String>,
) {
    let base = base_seed();
    for idx in 0..cases {
        let seed = base.wrapping_add(idx as u64);
        let mut g = Gen {
            stream: Stream::new(seed),
            seed,
        };
        let case = gen(&mut g);
        if let Err(msg) = prop(&case) {
            panic!(
                "property {name:?} failed on case #{idx} (replay seed {seed}):\n  case: {case:?}\n  {msg}"
            );
        }
    }
}

/// Replay one case by seed (paste the seed from a failure message).
pub fn replay<C: std::fmt::Debug>(
    seed: u64,
    mut gen: impl FnMut(&mut Gen) -> C,
    mut prop: impl FnMut(&C) -> Result<(), String>,
) {
    let mut g = Gen {
        stream: Stream::new(seed),
        seed,
    };
    let case = gen(&mut g);
    if let Err(msg) = prop(&case) {
        panic!("replay seed {seed} failed:\n  case: {case:?}\n  {msg}");
    }
}

/// Base seed: override with COMET_PROPTEST_SEED for reproduction;
/// defaults to a fixed seed so CI is deterministic.
fn base_seed() -> u64 {
    std::env::var("COMET_PROPTEST_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC03E7)
}

/// Assert two f64s are within `tol` (absolute), with context.
pub fn assert_close(a: f64, b: f64, tol: f64, what: &str) -> Result<(), String> {
    if (a - b).abs() <= tol {
        Ok(())
    } else {
        Err(format!("{what}: {a} vs {b} (|Δ|={} > {tol})", (a - b).abs()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall(
            "sum-commutes",
            50,
            |g| (g.f64_unit(), g.f64_unit()),
            |(a, b)| {
                if a + b == b + a {
                    Ok(())
                } else {
                    Err("addition not commutative?!".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "replay seed")]
    fn forall_reports_seed_on_failure() {
        forall(
            "always-fails",
            1,
            |g| g.usize_in(0, 10),
            |_| Err("no".into()),
        );
    }

    #[test]
    fn gen_ranges_inclusive() {
        let mut g = Gen { stream: Stream::new(1), seed: 1 };
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..1000 {
            let x = g.usize_in(3, 5);
            assert!((3..=5).contains(&x));
            lo_seen |= x == 3;
            hi_seen |= x == 5;
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn assert_close_tolerates() {
        assert!(assert_close(1.0, 1.0 + 1e-12, 1e-9, "x").is_ok());
        assert!(assert_close(1.0, 2.0, 1e-9, "x").is_err());
    }
}
