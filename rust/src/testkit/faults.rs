//! Fault injection for the out-of-core spill store: a [`FailingStore`]
//! wrapper that scripts per-operation failures over any inner
//! [`BlockStore`], plus a poisoning helper that corrupts a spilled
//! payload in place.
//!
//! The rigs in `tests/ooc_ingest.rs` use this to pin the error
//! contract: transient faults are retried with backoff and recover
//! without checksum drift; permanent faults surface as typed
//! [`StoreError`]s through `Session::run` (and as an `Error` wire frame
//! through `comet serve`); a poisoned spill file is detected by the
//! codec checksum, never silently decoded.
//!
//! The comm fabric gets the same treatment: [`FaultPlan`] /
//! [`FaultKind`] (re-exported from [`crate::comm::faults`]) script
//! per-`(rank, send-op)` link faults, and [`script_comm_faults`] /
//! [`scripted_comm_plan`] place `n` of them at PRNG-chosen slots —
//! deterministic per seed, mirroring the "fail the next `n` ops"
//! shape of [`FailingStore`]. [`PanicSink`] rounds the kit out for the
//! serve layer: a result sink that panics on the shard worker's own
//! thread, driving the worker-death → typed-error → respawn path.

use std::collections::{HashSet, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

pub use crate::comm::faults::{FaultKind, FaultPlan};

use crate::output::sink::{NodeSink, ResultSink};
use crate::util::prng::Stream;
use crate::vecdata::oocstore::{BlockStore, StoreError};

/// A [`BlockStore`] wrapper with scripted fault queues. Each `get`/`put`
/// first consumes the next scripted fault for that operation (if any)
/// and returns it; otherwise the call passes through to the inner
/// store. Attempt counters include faulted calls, so retry budgets are
/// observable.
pub struct FailingStore {
    inner: Arc<dyn BlockStore>,
    get_faults: Mutex<VecDeque<StoreError>>,
    put_faults: Mutex<VecDeque<StoreError>>,
    get_attempts: AtomicU64,
    put_attempts: AtomicU64,
}

impl FailingStore {
    pub fn new(inner: Arc<dyn BlockStore>) -> Self {
        FailingStore {
            inner,
            get_faults: Mutex::new(VecDeque::new()),
            put_faults: Mutex::new(VecDeque::new()),
            get_attempts: AtomicU64::new(0),
            put_attempts: AtomicU64::new(0),
        }
    }

    /// Script the next `n` `get` calls to fail with (clones of) `err`.
    pub fn fail_next_gets(&self, n: usize, err: StoreError) {
        let mut q = self.get_faults.lock().unwrap();
        for _ in 0..n {
            q.push_back(err.clone());
        }
    }

    /// Script the next `n` `put` calls to fail with (clones of) `err`.
    pub fn fail_next_puts(&self, n: usize, err: StoreError) {
        let mut q = self.put_faults.lock().unwrap();
        for _ in 0..n {
            q.push_back(err.clone());
        }
    }

    /// Drop every scripted fault (both queues) — the "operator fixed
    /// the disk" transition in recovery tests.
    pub fn clear_faults(&self) {
        self.get_faults.lock().unwrap().clear();
        self.put_faults.lock().unwrap().clear();
    }

    /// Total `get` calls observed (faulted + passed-through) — the
    /// retry-budget pin.
    pub fn get_attempts(&self) -> u64 {
        self.get_attempts.load(Ordering::Relaxed)
    }

    /// Total `put` calls observed (faulted + passed-through).
    pub fn put_attempts(&self) -> u64 {
        self.put_attempts.load(Ordering::Relaxed)
    }

    /// Corrupt the spilled blob under `key` in the inner store by
    /// flipping one payload byte (the last byte — always payload, never
    /// header, for any non-empty block). Returns whether the key
    /// existed. The next reload of the key must fail the codec checksum
    /// as [`StoreErrorKind::Corrupt`](crate::vecdata::oocstore::StoreErrorKind).
    pub fn poison(&self, key: &str) -> bool {
        match self.inner.get(key) {
            Ok(Some(mut bytes)) if !bytes.is_empty() => {
                let last = bytes.len() - 1;
                bytes[last] ^= 0x01;
                self.inner.put(key, &bytes).is_ok()
            }
            _ => false,
        }
    }

    /// Whether `key` made it through to the inner store — convenience
    /// for confirming a spill landed before poisoning it.
    pub fn contains_inner(&self, key: &str) -> bool {
        self.inner.contains(key)
    }
}

impl BlockStore for FailingStore {
    fn put(&self, key: &str, bytes: &[u8]) -> Result<(), StoreError> {
        self.put_attempts.fetch_add(1, Ordering::Relaxed);
        if let Some(err) = self.put_faults.lock().unwrap().pop_front() {
            return Err(err);
        }
        self.inner.put(key, bytes)
    }

    fn get(&self, key: &str) -> Result<Option<Vec<u8>>, StoreError> {
        self.get_attempts.fetch_add(1, Ordering::Relaxed);
        if let Some(err) = self.get_faults.lock().unwrap().pop_front() {
            return Err(err);
        }
        self.inner.get(key)
    }

    fn contains(&self, key: &str) -> bool {
        self.inner.contains(key)
    }
}

// ---------------------------------------------------------------------------
// Comm-fabric fault scripting.

/// Place `n` faults of `kind` on `plan` at PRNG-chosen, distinct
/// `(rank, k)` slots over `np` ranks × `ops_per_rank` send steps.
/// Deterministic: the same `(seed, np, ops_per_rank, n, kind)` always
/// produces the same schedule (pinned by a testkit determinism test),
/// and the schedule never consults the wall clock — the retry module's
/// no-wall-clock rule extends to fault placement. `n` is clamped to
/// the slot count.
pub fn script_comm_faults(
    plan: &FaultPlan,
    seed: u64,
    np: usize,
    ops_per_rank: u64,
    n: usize,
    kind: FaultKind,
) {
    assert!(np > 0 && ops_per_rank > 0, "empty comm-fault domain");
    let slots = np as u64 * ops_per_rank;
    let n = n.min(slots as usize);
    let mut stream = Stream::new(seed);
    let mut used = HashSet::new();
    while used.len() < n {
        let slot = stream.below(slots);
        if !used.insert(slot) {
            continue;
        }
        let (rank, k) = ((slot / ops_per_rank) as usize, slot % ops_per_rank);
        match kind {
            FaultKind::Drop => plan.drop_at(rank, k),
            FaultKind::Corrupt => plan.corrupt_at(rank, k),
            FaultKind::Delay(d) => plan.delay_at(rank, k, d),
            FaultKind::Kill => plan.kill_at(rank, k),
        }
    }
}

/// A fresh [`FaultPlan`] with `n` PRNG-placed faults (see
/// [`script_comm_faults`]), ready for
/// [`VirtualCluster::with_faults`](crate::comm::VirtualCluster::with_faults).
pub fn scripted_comm_plan(
    seed: u64,
    np: usize,
    ops_per_rank: u64,
    n: usize,
    kind: FaultKind,
) -> Arc<FaultPlan> {
    let plan = Arc::new(FaultPlan::new());
    script_comm_faults(&plan, seed, np, ops_per_rank, n, kind);
    plan
}

// ---------------------------------------------------------------------------
// Serve-layer fault rig.

/// A [`ResultSink`] that panics when the run asks for its first node
/// sink — on the **serve shard worker's own thread** (node sinks are
/// created before node threads spawn), so the worker genuinely dies
/// instead of the coordinator supervisor catching the panic. Drives
/// `serve`'s worker-death path: the in-flight ticket surfaces the
/// typed `WorkerDied`, and the next submission respawns the shard.
pub struct PanicSink;

impl ResultSink for PanicSink {
    fn node_sink(&self, _rank: usize) -> anyhow::Result<Box<dyn NodeSink>> {
        panic!("scripted sink panic (testkit::faults::PanicSink)");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vecdata::oocstore::{with_retry, MemStore, StoreErrorKind, RETRY_ATTEMPTS};

    fn rig() -> Arc<FailingStore> {
        Arc::new(FailingStore::new(Arc::new(MemStore::new())))
    }

    #[test]
    fn faults_are_consumed_in_script_order_then_pass_through() {
        let store = rig();
        store.put("k", b"v").unwrap();
        store.fail_next_gets(2, StoreError::transient("scripted"));
        assert_eq!(store.get("k").unwrap_err().kind, StoreErrorKind::Transient);
        assert_eq!(store.get("k").unwrap_err().kind, StoreErrorKind::Transient);
        assert_eq!(store.get("k").unwrap().as_deref(), Some(&b"v"[..]));
        assert_eq!(store.get_attempts(), 3);
    }

    #[test]
    fn retry_policy_drains_scripted_transients() {
        let store = rig();
        store.put("k", b"v").unwrap();
        store.fail_next_gets(RETRY_ATTEMPTS as usize - 1, StoreError::transient("flaky"));
        let got = with_retry(|| store.get("k")).unwrap();
        assert_eq!(got.as_deref(), Some(&b"v"[..]));
        assert_eq!(store.get_attempts(), RETRY_ATTEMPTS as u64);
        // A permanent fault is not retried: one attempt, typed surface.
        store.fail_next_gets(1, StoreError::permanent("gone"));
        let before = store.get_attempts();
        assert_eq!(with_retry(|| store.get("k")).unwrap_err().kind, StoreErrorKind::Permanent);
        assert_eq!(store.get_attempts(), before + 1);
    }

    #[test]
    fn poison_flips_a_byte_in_place() {
        let store = rig();
        assert!(!store.poison("missing"));
        store.put("k", b"abc").unwrap();
        assert!(store.poison("k"));
        assert_eq!(store.get("k").unwrap().as_deref(), Some(&b"ab\x62"[..]));
    }

    #[test]
    fn scripted_comm_schedules_are_deterministic_per_seed() {
        let a = scripted_comm_plan(11, 4, 16, 6, FaultKind::Drop);
        let b = scripted_comm_plan(11, 4, 16, 6, FaultKind::Drop);
        assert_eq!(a.remaining_schedule(), b.remaining_schedule());
        assert_eq!(a.remaining_schedule().len(), 6);
        for (rank, k, kind) in a.remaining_schedule() {
            assert!(rank < 4 && k < 16);
            assert_eq!(kind, FaultKind::Drop);
        }
        // A different seed places at least one fault elsewhere.
        let c = scripted_comm_plan(12, 4, 16, 6, FaultKind::Drop);
        assert_ne!(a.remaining_schedule(), c.remaining_schedule());
        // Over-asking clamps to the slot count without looping forever.
        let full = scripted_comm_plan(3, 2, 3, 999, FaultKind::Corrupt);
        assert_eq!(full.remaining_schedule().len(), 6);
    }

    #[test]
    fn scripted_kinds_land_as_scheduled() {
        let plan = FaultPlan::new();
        script_comm_faults(&plan, 5, 2, 8, 3, FaultKind::Corrupt);
        script_comm_faults(
            &plan,
            6,
            2,
            8,
            1,
            FaultKind::Delay(std::time::Duration::from_millis(1)),
        );
        let sched = plan.remaining_schedule();
        // 3 corrupts + 1 delay, unless the two seeds collided on a slot
        // (the second insert overwrites) — either way every entry is
        // one of the scripted kinds.
        assert!(sched.len() >= 3 && sched.len() <= 4, "{sched:?}");
        for (_, _, kind) in sched {
            assert!(matches!(kind, FaultKind::Corrupt | FaultKind::Delay(_)));
        }
    }
}
