//! Fault injection for the out-of-core spill store: a [`FailingStore`]
//! wrapper that scripts per-operation failures over any inner
//! [`BlockStore`], plus a poisoning helper that corrupts a spilled
//! payload in place.
//!
//! The rigs in `tests/ooc_ingest.rs` use this to pin the error
//! contract: transient faults are retried with backoff and recover
//! without checksum drift; permanent faults surface as typed
//! [`StoreError`]s through `Session::run` (and as an `Error` wire frame
//! through `comet serve`); a poisoned spill file is detected by the
//! codec checksum, never silently decoded.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::vecdata::oocstore::{BlockStore, StoreError};

/// A [`BlockStore`] wrapper with scripted fault queues. Each `get`/`put`
/// first consumes the next scripted fault for that operation (if any)
/// and returns it; otherwise the call passes through to the inner
/// store. Attempt counters include faulted calls, so retry budgets are
/// observable.
pub struct FailingStore {
    inner: Arc<dyn BlockStore>,
    get_faults: Mutex<VecDeque<StoreError>>,
    put_faults: Mutex<VecDeque<StoreError>>,
    get_attempts: AtomicU64,
    put_attempts: AtomicU64,
}

impl FailingStore {
    pub fn new(inner: Arc<dyn BlockStore>) -> Self {
        FailingStore {
            inner,
            get_faults: Mutex::new(VecDeque::new()),
            put_faults: Mutex::new(VecDeque::new()),
            get_attempts: AtomicU64::new(0),
            put_attempts: AtomicU64::new(0),
        }
    }

    /// Script the next `n` `get` calls to fail with (clones of) `err`.
    pub fn fail_next_gets(&self, n: usize, err: StoreError) {
        let mut q = self.get_faults.lock().unwrap();
        for _ in 0..n {
            q.push_back(err.clone());
        }
    }

    /// Script the next `n` `put` calls to fail with (clones of) `err`.
    pub fn fail_next_puts(&self, n: usize, err: StoreError) {
        let mut q = self.put_faults.lock().unwrap();
        for _ in 0..n {
            q.push_back(err.clone());
        }
    }

    /// Drop every scripted fault (both queues) — the "operator fixed
    /// the disk" transition in recovery tests.
    pub fn clear_faults(&self) {
        self.get_faults.lock().unwrap().clear();
        self.put_faults.lock().unwrap().clear();
    }

    /// Total `get` calls observed (faulted + passed-through) — the
    /// retry-budget pin.
    pub fn get_attempts(&self) -> u64 {
        self.get_attempts.load(Ordering::Relaxed)
    }

    /// Total `put` calls observed (faulted + passed-through).
    pub fn put_attempts(&self) -> u64 {
        self.put_attempts.load(Ordering::Relaxed)
    }

    /// Corrupt the spilled blob under `key` in the inner store by
    /// flipping one payload byte (the last byte — always payload, never
    /// header, for any non-empty block). Returns whether the key
    /// existed. The next reload of the key must fail the codec checksum
    /// as [`StoreErrorKind::Corrupt`](crate::vecdata::oocstore::StoreErrorKind).
    pub fn poison(&self, key: &str) -> bool {
        match self.inner.get(key) {
            Ok(Some(mut bytes)) if !bytes.is_empty() => {
                let last = bytes.len() - 1;
                bytes[last] ^= 0x01;
                self.inner.put(key, &bytes).is_ok()
            }
            _ => false,
        }
    }

    /// Whether `key` made it through to the inner store — convenience
    /// for confirming a spill landed before poisoning it.
    pub fn contains_inner(&self, key: &str) -> bool {
        self.inner.contains(key)
    }
}

impl BlockStore for FailingStore {
    fn put(&self, key: &str, bytes: &[u8]) -> Result<(), StoreError> {
        self.put_attempts.fetch_add(1, Ordering::Relaxed);
        if let Some(err) = self.put_faults.lock().unwrap().pop_front() {
            return Err(err);
        }
        self.inner.put(key, bytes)
    }

    fn get(&self, key: &str) -> Result<Option<Vec<u8>>, StoreError> {
        self.get_attempts.fetch_add(1, Ordering::Relaxed);
        if let Some(err) = self.get_faults.lock().unwrap().pop_front() {
            return Err(err);
        }
        self.inner.get(key)
    }

    fn contains(&self, key: &str) -> bool {
        self.inner.contains(key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vecdata::oocstore::{with_retry, MemStore, StoreErrorKind, RETRY_ATTEMPTS};

    fn rig() -> Arc<FailingStore> {
        Arc::new(FailingStore::new(Arc::new(MemStore::new())))
    }

    #[test]
    fn faults_are_consumed_in_script_order_then_pass_through() {
        let store = rig();
        store.put("k", b"v").unwrap();
        store.fail_next_gets(2, StoreError::transient("scripted"));
        assert_eq!(store.get("k").unwrap_err().kind, StoreErrorKind::Transient);
        assert_eq!(store.get("k").unwrap_err().kind, StoreErrorKind::Transient);
        assert_eq!(store.get("k").unwrap().as_deref(), Some(&b"v"[..]));
        assert_eq!(store.get_attempts(), 3);
    }

    #[test]
    fn retry_policy_drains_scripted_transients() {
        let store = rig();
        store.put("k", b"v").unwrap();
        store.fail_next_gets(RETRY_ATTEMPTS as usize - 1, StoreError::transient("flaky"));
        let got = with_retry(|| store.get("k")).unwrap();
        assert_eq!(got.as_deref(), Some(&b"v"[..]));
        assert_eq!(store.get_attempts(), RETRY_ATTEMPTS as u64);
        // A permanent fault is not retried: one attempt, typed surface.
        store.fail_next_gets(1, StoreError::permanent("gone"));
        let before = store.get_attempts();
        assert_eq!(with_retry(|| store.get("k")).unwrap_err().kind, StoreErrorKind::Permanent);
        assert_eq!(store.get_attempts(), before + 1);
    }

    #[test]
    fn poison_flips_a_byte_in_place() {
        let store = rig();
        assert!(!store.poison("missing"));
        store.put("k", b"abc").unwrap();
        assert!(store.poison("k"));
        assert_eq!(store.get("k").unwrap().as_deref(), Some(&b"ab\x62"[..]));
    }
}
