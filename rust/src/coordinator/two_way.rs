//! Algorithm 1 — the 2-way metrics node program.
//!
//! Each parallel step Δ: exchange vector blocks around the ring
//! (send own block to pv−Δ, receive pv+Δ's), offload the numerator
//! block N to the backend through the run's metric (min-product mGEMM,
//! GEMM, or bit-packed AND+popcount), reduce partials across the npf
//! axis if present, then assemble denominators and quotients on the
//! coordinator side — again through the metric. The block-circulant
//! schedule (`decomp::two_way`) guarantees unique coverage and load
//! balance (Figure 2(c)); it is metric-independent, which is what lets
//! all three metric families share this one node program.
//!
//! Blocks come from the run's [`BlockProvider`] in the metric's
//! preferred representation (ingested **once per (dataset, repr)** for
//! session runs; fresh for one-shot runs) and travel on the wire in
//! that same representation — bit-domain metrics exchange packed u64
//! words (~64× less volume than f64 elements) and never re-pack inside
//! the step loop.
//!
//! Assembled metric values leave the node as [`Tile`]s through its
//! [`NodeSink`] — one tile per computed block, so downstream consumers
//! (stores, files, forwarding servers) never need more than a block's
//! worth of values in flight.

use std::sync::Arc;

use anyhow::Result;

use crate::checksum::Checksum;
use crate::comm::{Endpoint, Payload};
use crate::config::RunConfig;
use crate::coordinator::checkpoint::{self, RunCheckpoint};
use crate::coordinator::{backend::Backend, BlockProvider, NodeResult, ProvideBlocks, RunStats};
use crate::decomp::{partition::Partition, two_way, NodeCoord};
use crate::metrics::{store::PairEntry, Metric};
use crate::output::sink::{NodeSink, Tile};
use crate::util::{timer::Stopwatch, Scalar};
use crate::vecdata::block::Block;

/// Tag bases (unique per logical channel; see comm::Endpoint stash).
const TAG_BLOCK: u64 = 1_000;
const TAG_SUMS: u64 = 2_000;
const TAG_REDUCE: u64 = 10_000;

#[allow(clippy::too_many_arguments)]
pub(crate) fn node_main<T: Scalar + ProvideBlocks>(
    cfg: &RunConfig,
    coord: NodeCoord,
    mut ep: Endpoint,
    backend: Arc<dyn Backend<T>>,
    metric: Arc<dyn Metric<T>>,
    provider: Arc<dyn BlockProvider>,
    mut sink: Option<Box<dyn NodeSink>>,
    ckpt: Option<Arc<RunCheckpoint>>,
) -> Result<NodeResult> {
    let grid = cfg.grid;
    let (pv, pr, pf) = (coord.pv, coord.pr, coord.pf);
    let mut stats = RunStats::default();
    let mut checksum = Checksum::with_salt(metric.checksum_salt());
    let mut t_in = Stopwatch::new();
    let mut t_comp = Stopwatch::new();
    let mut t_out = Stopwatch::new();

    // --- Input phase -----------------------------------------------------
    t_in.start();
    // The provider hands back the block in the metric's working
    // representation: ingested once per (dataset, repr) when a session
    // cache sits behind it, loaded + ingested fresh otherwise. Either
    // way, the step loop below only ever touches the cached form.
    // Re-hint the node's own key (idempotent after the run-level
    // schedule hint; keeps serial/direct callers pipeline-friendly).
    provider.prefetch(cfg, &[(pv, pf)]);
    let block = T::provide(provider.as_ref(), cfg, metric.as_ref(), pv, pf)?;
    // Full-feature denominator ingredients (allreduced across the npf
    // axis — metric denominators are additive over feature slices).
    let local_sums = metric.denominators(&block)?;
    let own_sums = if grid.npf > 1 {
        let group = pf_group(&grid, pv, pr);
        ep.allreduce_sum(&group, TAG_REDUCE, local_sums)?
    } else {
        local_sums
    };
    t_in.stop();

    // Own block as wire payload, converted once: float metrics ship f64
    // elements, bit-domain metrics ship their cached packed words.
    // Each step clones the Arc inside — no per-step conversion.
    let wire = block.to_wire();
    let sums_wire = Arc::new(own_sums.clone());

    // --- Parallel step loop (Algorithm 1) ---------------------------------
    t_comp.start();
    for step in two_way::plan(grid.npv, grid.npr, pv, pr) {
        let active = step.dp % grid.npr == pr;
        if !active {
            continue;
        }
        // Exchange: all pv in this (pf, pr) plane run the same Δ, so the
        // ring sends/receives pair up.
        let (peer_block, peer_sums) = if step.dp == 0 {
            (None, None)
        } else {
            let to = grid.rank(NodeCoord { pf, pv: step.send_to_pv, pr });
            let from = grid.rank(NodeCoord { pf, pv: step.recv_from_pv, pr });
            let tag = TAG_BLOCK + step.dp as u64;
            let payload = Payload::Block {
                nf: block.nf(),
                nv: block.nv(),
                first_id: block.first_id(),
                data: wire.clone(),
            };
            let got = ep.sendrecv(to, from, tag, payload)?;
            let Payload::Block { nf, nv, first_id, data } = got else {
                anyhow::bail!("expected Block payload");
            };
            let peer = Block::<T>::from_wire(nf, nv, first_id, &data)?;
            let got_sums = ep.sendrecv(
                to,
                from,
                TAG_SUMS + step.dp as u64,
                Payload::Sums(Arc::clone(&sums_wire)),
            )?;
            let Payload::Sums(ps) = got_sums else {
                anyhow::bail!("expected Sums payload");
            };
            (Some(peer), Some(ps))
        };

        let Some(info) = step.compute else { continue };
        // The schedule pairs "no peer" with the diagonal block exactly
        // (Δ = 0) — the triangular kernel relies on this.
        debug_assert_eq!(peer_block.is_none(), info.diag, "diag blocks have no peer");

        // --- Checkpoint probe ------------------------------------------
        // Unit = this (pv, pr) plane's step Δ. The key is shared across
        // the npf axis, so every rank of a reduction group reaches the
        // same skip verdict (blobs are immutable once written — within
        // a run pf=0 only writes *after* its group's reduce, so a probe
        // can never observe a done-marker for work its own group has
        // not finished). The exchange above already ran: resumed runs
        // keep the full lockstep comm schedule and skip only compute +
        // emission, replaying the persisted tiles bit-identically.
        let unit = ckpt.as_deref().map(|c| (c, format!("v{pv}-r{pr}-u{}", step.dp)));
        if let Some((c, u)) = &unit {
            if c.is_done(u) {
                c.note_skip();
                if pf == 0 {
                    let tiles = c.load(u)?;
                    checkpoint::replay_tiles(tiles, &mut checksum, &mut stats, &mut sink)?;
                }
                continue;
            }
        }

        // Offload the numerator block through the metric's kernel —
        // cached representations in, zero re-packing. A diagonal block
        // (no peer) pairs the block with itself, and only its strict
        // upper triangle is read below — so it goes through the
        // symmetry-halved diag kernel (~2× fewer elementwise ops on
        // backends with triangular kernels, bit-identical entries).
        let (n_block, peer_first, peer_sums_ref): (_, usize, &[f64]) = match &peer_block {
            None => (
                metric.numerators2_diag(backend.as_ref(), &block)?,
                block.first_id(),
                &own_sums,
            ),
            Some(pb) => (
                metric.numerators2(backend.as_ref(), &block, pb)?,
                pb.first_id(),
                peer_sums.as_deref().unwrap(),
            ),
        };
        stats.mgemm2_calls += 1;

        // Reduce partial numerators across the npf axis.
        let n_block = if grid.npf > 1 {
            let group = pf_group(&grid, pv, pr);
            let reduced = ep.allreduce_sum(
                &group,
                TAG_REDUCE + 2 * (step.dp as u64 + 1),
                n_block.data,
            )?;
            crate::linalg::MatF64 {
                rows: block.nv(),
                cols: reduced.len() / block.nv(),
                data: reduced,
            }
        } else {
            n_block
        };

        // Only the pf=0 plane assembles metrics (others contributed via
        // the reduction).
        if pf != 0 {
            continue;
        }

        // --- Denominators + quotients on the coordinator side ---------
        // One result tile per computed block: entries in emission order
        // (the dense §6.8 file format is order-defined).
        let my_first = block.first_id();
        let want_tile = sink.is_some() || unit.is_some();
        let mut entries: Vec<PairEntry> = Vec::new();
        if info.diag {
            for j in 1..n_block.cols {
                for i in 0..j {
                    let value = metric.combine2(n_block.at(i, j), own_sums[i], own_sums[j]);
                    let (gi, gj) = (my_first + i, my_first + j);
                    checksum.add_pair(gi, gj, value);
                    stats.metrics += 1;
                    if want_tile {
                        entries.push(PairEntry { i: gi as u32, j: gj as u32, value });
                    }
                }
            }
        } else {
            for i in 0..n_block.rows {
                for j in 0..n_block.cols {
                    let value = metric.combine2(n_block.at(i, j), own_sums[i], peer_sums_ref[j]);
                    let (a, b) = canonical(my_first + i, peer_first + j);
                    checksum.add_pair(a, b, value);
                    stats.metrics += 1;
                    if want_tile {
                        entries.push(PairEntry { i: a as u32, j: b as u32, value });
                    }
                }
            }
        }
        if want_tile {
            let tile = Tile::Pairs { metric: metric.id(), entries };
            // Persist before handing the tile to the sink: a unit is
            // only marked done once its values are durable, and the
            // order-independent checksum makes replay-after-delivery
            // harmless if the run dies between the two.
            if let Some((c, u)) = &unit {
                t_out.start();
                c.save(u, std::slice::from_ref(&tile));
                t_out.stop();
            }
            if let Some(s) = sink.as_mut() {
                if !tile.is_empty() {
                    t_out.start();
                    s.tile(tile)?;
                    t_out.stop();
                    stats.tiles += 1;
                }
            }
        }
    }
    t_comp.stop();

    if let Some(mut s) = sink.take() {
        t_out.start();
        s.finish()?;
        t_out.stop();
    }

    stats.t_input = t_in.secs();
    stats.t_compute = t_comp.secs() - t_out.secs();
    stats.t_output = t_out.secs();
    // Per-node comm accounting: RunStats::absorb sums these across
    // nodes to reproduce the cluster totals. Retransmits/corruptions
    // ride along so the ledger prices fault recovery.
    (stats.comm_messages, stats.comm_bytes) = ep.sent();
    stats.comm_retries = ep.retransmits();
    stats.comm_corrupt = ep.corrupt_detected();
    Ok(NodeResult { checksum, stats })
}

#[inline]
fn canonical(a: usize, b: usize) -> (usize, usize) {
    debug_assert_ne!(a, b, "off-diagonal blocks cannot pair a vector with itself");
    if a < b {
        (a, b)
    } else {
        (b, a)
    }
}

/// Ranks sharing (pv, pr) across the npf axis (reduction group),
/// root (pf = 0) first.
fn pf_group(grid: &crate::decomp::Grid, pv: usize, pr: usize) -> Vec<usize> {
    (0..grid.npf)
        .map(|pf| grid.rank(NodeCoord { pf, pv, pr }))
        .collect()
}

/// Expected per-node mGEMM block count for a run (the §6.3 load ℓ).
pub fn load_for(cfg: &RunConfig, pv: usize, pr: usize) -> usize {
    two_way::blocks_per_node(cfg.grid.npv, cfg.grid.npr, pv, pr)
}

/// Partition helper shared with benches: the vector partition of a run.
pub fn vector_partition(cfg: &RunConfig) -> Partition {
    Partition::new(cfg.nv, cfg.grid.npv)
}
