//! Single-node convenience drivers: compute full metric sets directly
//! through a backend, without the cluster machinery. Used by examples,
//! tests (as the end-to-end oracle path) and kernel-level benches.
//!
//! The `*_into` variants stream [`Tile`]s into a caller-supplied
//! [`NodeSink`] (the same result path the coordinated node programs
//! use); the `*_with` variants collect into stores through a
//! [`CollectSink`]; the plain functions keep the historical
//! Czekanowski behavior.

use std::sync::Arc;

use anyhow::Result;

use crate::coordinator::backend::Backend;
use crate::metrics::engine::Czekanowski;
use crate::metrics::store::{PairEntry, PairStore, TripleEntry, TripleStore};
use crate::metrics::Metric;
use crate::output::sink::{CollectSink, NodeSink, ResultSink, Tile};
use crate::util::Scalar;
use crate::vecdata::VectorSet;

/// Stream all unique 2-way metrics of one vector set under `metric`
/// into `sink` as a single tile. The set is ingested into the metric's
/// preferred representation first (the same pack-once path the
/// coordinated runs use). Returns the number of values emitted.
pub fn all_pairs_into<T: Scalar>(
    backend: &Arc<dyn Backend<T>>,
    metric: &dyn Metric<T>,
    v: &VectorSet<T>,
    sink: &mut dyn NodeSink,
) -> Result<u64> {
    let block = metric.ingest(v.clone());
    // One set against itself — only i < j is read, so the
    // symmetry-halved diagonal kernel applies (same as the coordinated
    // runs' diag blocks).
    let n = metric.numerators2_diag(backend.as_ref(), &block)?;
    let dens = metric.denominators(&block)?;
    let mut entries = Vec::with_capacity(v.nv * v.nv.saturating_sub(1) / 2);
    for j in 1..v.nv {
        for i in 0..j {
            entries.push(PairEntry {
                i: (v.first_id + i) as u32,
                j: (v.first_id + j) as u32,
                value: metric.combine2(n.at(i, j), dens[i], dens[j]),
            });
        }
    }
    let count = entries.len() as u64;
    sink.tile(Tile::Pairs { metric: metric.id(), entries })?;
    Ok(count)
}

/// All unique 2-way metrics of one vector set under `metric`,
/// collected into a store.
pub fn all_pairs_with<T: Scalar>(
    backend: &Arc<dyn Backend<T>>,
    metric: &dyn Metric<T>,
    v: &VectorSet<T>,
) -> Result<PairStore> {
    let collect = CollectSink::for_metric(metric.id());
    let mut node = collect.node_sink(0)?;
    all_pairs_into(backend, metric, v, node.as_mut())?;
    node.finish()?;
    Ok(collect.take().0)
}

/// All unique 2-way Proportional Similarity metrics of one vector set.
pub fn all_pairs<T: Scalar>(
    backend: &Arc<dyn Backend<T>>,
    v: &VectorSet<T>,
) -> Result<PairStore> {
    all_pairs_with(backend, &Czekanowski, v)
}

/// Stream all unique 3-way metrics of one vector set under `metric`
/// into `sink`, one tile per pivot chunk (O(n_v³) values — small sets
/// only). Returns the number of values emitted.
pub fn all_triples_into<T: Scalar>(
    backend: &Arc<dyn Backend<T>>,
    metric: &dyn Metric<T>,
    v: &VectorSet<T>,
    sink: &mut dyn NodeSink,
) -> Result<u64> {
    let block = metric.ingest(v.clone());
    let n2 = metric.numerators2_diag(backend.as_ref(), &block)?;
    let dens = metric.denominators(&block)?;
    let jt = backend.pivot_batch_for(v.nf, v.nv);
    let pivot_ids: Vec<usize> = (0..v.nv).collect();
    let mut count = 0u64;
    for chunk in pivot_ids.chunks(jt) {
        let pivots = block.select_cols(chunk)?;
        // Only i < chunk[t] < k is read below — the diag-aware slab
        // kernel skips the rest.
        let slab = metric.numerators3_diag(backend.as_ref(), &block, &pivots, chunk)?;
        let mut entries = Vec::new();
        for (t, &j) in chunk.iter().enumerate() {
            for i in 0..j {
                for k in (j + 1)..v.nv {
                    let c3 = metric.combine3(
                        n2.at(i, j),
                        n2.at(i, k),
                        n2.at(j, k),
                        slab.at(t, i, k),
                        dens[i],
                        dens[j],
                        dens[k],
                    );
                    entries.push(TripleEntry {
                        i: (v.first_id + i) as u32,
                        j: (v.first_id + j) as u32,
                        k: (v.first_id + k) as u32,
                        value: c3,
                    });
                }
            }
        }
        count += entries.len() as u64;
        if !entries.is_empty() {
            sink.tile(Tile::Triples { metric: metric.id(), entries })?;
        }
    }
    Ok(count)
}

/// All unique 3-way metrics of one vector set under `metric`,
/// collected into a store (O(n_v³) output — small sets only).
pub fn all_triples_with<T: Scalar>(
    backend: &Arc<dyn Backend<T>>,
    metric: &dyn Metric<T>,
    v: &VectorSet<T>,
) -> Result<TripleStore> {
    let collect = CollectSink::for_metric(metric.id());
    let mut node = collect.node_sink(0)?;
    all_triples_into(backend, metric, v, node.as_mut())?;
    node.finish()?;
    Ok(collect.take().1)
}

/// All unique 3-way Proportional Similarity metrics of one vector set.
pub fn all_triples<T: Scalar>(
    backend: &Arc<dyn Backend<T>>,
    v: &VectorSet<T>,
) -> Result<TripleStore> {
    all_triples_with(backend, &Czekanowski, v)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::CpuOptimized;
    use crate::metrics;
    use crate::output::sink::StatsOnlySink;
    use crate::vecdata::SyntheticKind;

    #[test]
    fn all_pairs_matches_scalar_oracle() {
        let v: VectorSet<f64> = VectorSet::generate(SyntheticKind::RandomGrid, 1, 48, 10, 0);
        let backend: Arc<dyn Backend<f64>> = Arc::new(CpuOptimized::default());
        let store = all_pairs(&backend, &v).unwrap();
        assert_eq!(store.len(), 45);
        for e in store.iter() {
            let want = metrics::czekanowski2(v.col(e.i as usize), v.col(e.j as usize));
            assert!((e.value - want).abs() < 1e-12);
        }
    }

    #[test]
    fn all_triples_matches_scalar_oracle() {
        let v: VectorSet<f64> = VectorSet::generate(SyntheticKind::RandomGrid, 2, 32, 9, 0);
        let backend: Arc<dyn Backend<f64>> = Arc::new(CpuOptimized::default());
        let store = all_triples(&backend, &v).unwrap();
        assert_eq!(store.len(), 9 * 8 * 7 / 6);
        for e in store.iter() {
            let want = metrics::czekanowski3(
                v.col(e.i as usize),
                v.col(e.j as usize),
                v.col(e.k as usize),
            );
            assert!((e.value - want).abs() < 1e-12, "({},{},{})", e.i, e.j, e.k);
        }
    }

    #[test]
    fn all_pairs_with_ccc_matches_scalar_oracle() {
        let v: VectorSet<f64> = VectorSet::generate(SyntheticKind::Alleles, 4, 52, 10, 0);
        let backend: Arc<dyn Backend<f64>> = Arc::new(CpuOptimized::default());
        let metric = crate::metrics::engine::Ccc::new(v.nf);
        let store = all_pairs_with(&backend, &metric, &v).unwrap();
        assert_eq!(store.len(), 45);
        assert_eq!(store.metric, crate::metrics::MetricId::Ccc);
        for e in store.iter() {
            let want = metrics::ccc2(v.col(e.i as usize), v.col(e.j as usize));
            assert_eq!(e.value, want, "pair ({}, {})", e.i, e.j);
        }
    }

    #[test]
    fn all_pairs_with_sorenson_matches_bit_oracle() {
        let bits = crate::vecdata::bits::BitVectorSet::generate(6, 190, 8, 0.3);
        let v = bits.to_floats();
        let backend: Arc<dyn Backend<f64>> = Arc::new(CpuOptimized::default());
        let metric = crate::metrics::engine::Sorenson::default();
        let store = all_pairs_with(&backend, &metric, &v).unwrap();
        assert_eq!(store.len(), 28);
        for e in store.iter() {
            let want = bits.sorenson2(e.i as usize, e.j as usize);
            assert_eq!(e.value, want, "pair ({}, {})", e.i, e.j);
        }
    }

    #[test]
    fn streaming_variants_count_without_collecting() {
        // The `*_into` drivers push tiles without building any store —
        // the serving path in miniature.
        let v: VectorSet<f64> = VectorSet::generate(SyntheticKind::RandomGrid, 3, 24, 8, 0);
        let backend: Arc<dyn Backend<f64>> = Arc::new(CpuOptimized::default());
        let stats = StatsOnlySink::new();
        let mut node = stats.node_sink(0).unwrap();
        let n2 = all_pairs_into(&backend, &Czekanowski, &v, node.as_mut()).unwrap();
        let n3 = all_triples_into(&backend, &Czekanowski, &v, node.as_mut()).unwrap();
        node.finish().unwrap();
        assert_eq!(n2, 28);
        assert_eq!(n3, 8 * 7 * 6 / 6);
        assert_eq!(stats.values(), n2 + n3);
        assert!(stats.tiles() >= 2);
    }

    #[test]
    fn first_id_offsets_respected() {
        let v: VectorSet<f64> = {
            let mut s = VectorSet::generate(SyntheticKind::RandomGrid, 3, 16, 4, 100);
            s.first_id = 100;
            s
        };
        let backend: Arc<dyn Backend<f64>> = Arc::new(CpuOptimized::default());
        let store = all_pairs(&backend, &v).unwrap();
        for e in store.iter() {
            assert!(e.i >= 100 && e.j >= 100);
        }
    }
}
