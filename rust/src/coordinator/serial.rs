//! Single-node convenience drivers: compute full metric sets directly
//! through a backend, without the cluster machinery. Used by examples,
//! tests (as the end-to-end oracle path) and kernel-level benches.

use std::sync::Arc;

use anyhow::Result;

use crate::coordinator::backend::Backend;
use crate::metrics::c2_from_parts;
use crate::metrics::store::{PairStore, TripleStore};
use crate::util::Scalar;
use crate::vecdata::VectorSet;

/// All unique 2-way Proportional Similarity metrics of one vector set.
pub fn all_pairs<T: Scalar>(
    backend: &Arc<dyn Backend<T>>,
    v: &VectorSet<T>,
) -> Result<PairStore> {
    let n = backend.mgemm2(v, v)?;
    let sums = v.col_sums();
    let mut store = PairStore::new();
    for j in 1..v.nv {
        for i in 0..j {
            store.push(
                v.first_id + i,
                v.first_id + j,
                c2_from_parts(n.at(i, j), sums[i], sums[j]),
            );
        }
    }
    Ok(store)
}

/// All unique 3-way Proportional Similarity metrics of one vector set
/// (O(n_v³) output — small sets only).
pub fn all_triples<T: Scalar>(
    backend: &Arc<dyn Backend<T>>,
    v: &VectorSet<T>,
) -> Result<TripleStore> {
    let n2 = backend.mgemm2(v, v)?;
    let sums = v.col_sums();
    let mut store = TripleStore::new();
    let jt = backend.pivot_batch_for(v.nf, v.nv);
    let pivot_ids: Vec<usize> = (0..v.nv).collect();
    for chunk in pivot_ids.chunks(jt) {
        let pivots = v.select_cols(chunk);
        let slab = backend.mgemm3(v, &pivots, v)?;
        for (t, &j) in chunk.iter().enumerate() {
            for i in 0..j {
                for k in (j + 1)..v.nv {
                    let n3 = n2.at(i, j) + n2.at(i, k) + n2.at(j, k) - slab.at(t, i, k);
                    let c3 = 1.5 * n3 / (sums[i] + sums[j] + sums[k]);
                    store.push(v.first_id + i, v.first_id + j, v.first_id + k, c3);
                }
            }
        }
    }
    Ok(store)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::CpuOptimized;
    use crate::metrics;
    use crate::vecdata::SyntheticKind;

    #[test]
    fn all_pairs_matches_scalar_oracle() {
        let v: VectorSet<f64> = VectorSet::generate(SyntheticKind::RandomGrid, 1, 48, 10, 0);
        let backend: Arc<dyn Backend<f64>> = Arc::new(CpuOptimized);
        let store = all_pairs(&backend, &v).unwrap();
        assert_eq!(store.len(), 45);
        for e in store.iter() {
            let want = metrics::czekanowski2(v.col(e.i as usize), v.col(e.j as usize));
            assert!((e.value - want).abs() < 1e-12);
        }
    }

    #[test]
    fn all_triples_matches_scalar_oracle() {
        let v: VectorSet<f64> = VectorSet::generate(SyntheticKind::RandomGrid, 2, 32, 9, 0);
        let backend: Arc<dyn Backend<f64>> = Arc::new(CpuOptimized);
        let store = all_triples(&backend, &v).unwrap();
        assert_eq!(store.len(), 9 * 8 * 7 / 6);
        for e in store.iter() {
            let want = metrics::czekanowski3(
                v.col(e.i as usize),
                v.col(e.j as usize),
                v.col(e.k as usize),
            );
            assert!((e.value - want).abs() < 1e-12, "({},{},{})", e.i, e.j, e.k);
        }
    }

    #[test]
    fn first_id_offsets_respected() {
        let v: VectorSet<f64> = {
            let mut s = VectorSet::generate(SyntheticKind::RandomGrid, 3, 16, 4, 100);
            s.first_id = 100;
            s
        };
        let backend: Arc<dyn Backend<f64>> = Arc::new(CpuOptimized);
        let store = all_pairs(&backend, &v).unwrap();
        for e in store.iter() {
            assert!(e.i >= 100 && e.j >= 100);
        }
    }
}
