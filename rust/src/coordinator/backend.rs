//! Execution backends for the block kernels.
//!
//! Matches the paper's three code versions (§5): a reference CPU
//! version, an optimized CPU version, and the accelerator version
//! (PJRT artifacts here, CUDA/MAGMA there). The coordinator is generic
//! over the backend, which is what the Table 2 GPU-vs-CPU comparison
//! swaps. Each backend provides one kernel per numerator family
//! (min-product, dot-product, bitwise AND+popcount); the metric engine
//! (`metrics::engine`) picks which family a run drives.

use std::sync::Arc;

use anyhow::Result;

use crate::config::{BackendKind, Precision};
use crate::linalg::{optimized, reference, sorenson, MatF64, SlabF64};
use crate::runtime::ops::{BlockOps, KernelFamily};
use crate::runtime::RuntimeClient;
use crate::util::Scalar;
use crate::vecdata::bits::BitVectorSet;
use crate::vecdata::VectorSet;

/// Block-kernel provider at element type `T`.
pub trait Backend<T: Scalar>: Send + Sync {
    /// N = W^T ∘min V (min-product family — Czekanowski numerators).
    fn mgemm2(&self, w: &VectorSet<T>, v: &VectorSet<T>) -> Result<MatF64>;
    /// slab[t, i, k] = Σ_q min(pivot_t, w_i, v_k).
    fn mgemm3(&self, w: &VectorSet<T>, pivots: &VectorSet<T>, v: &VectorSet<T>)
        -> Result<SlabF64>;
    /// N = W^T V (dot-product family — CCC numerators).
    fn gemm2(&self, w: &VectorSet<T>, v: &VectorSet<T>) -> Result<MatF64>;
    /// N[i, j] = |w_i AND v_j| over packed words (bitwise family —
    /// Sorensen numerators).
    fn sorenson2(&self, w: &BitVectorSet, v: &BitVectorSet) -> Result<MatF64>;

    // --- Diagonal-block (symmetry-halved) kernels -----------------------
    // A diagonal block pairs a vector set with itself; the coordinator
    // only reads the strict upper triangle, so backends may compute a
    // triangular result (~2× fewer elementwise ops). Defaults fall back
    // to the full square kernel — correct everywhere, required for
    // backends whose kernels are shape-specialized (PJRT artifacts).

    /// Upper triangle of V^T ∘min V (entries elsewhere unspecified —
    /// the triangular impls leave them zero).
    fn mgemm2_diag(&self, v: &VectorSet<T>) -> Result<MatF64> {
        self.mgemm2(v, v)
    }
    /// Upper triangle of V^T V.
    fn gemm2_diag(&self, v: &VectorSet<T>) -> Result<MatF64> {
        self.gemm2(v, v)
    }
    /// Upper triangle of V AND V popcounts.
    fn sorenson2_diag(&self, v: &BitVectorSet) -> Result<MatF64> {
        self.sorenson2(v, v)
    }
    /// Diagonal 3-way slab: pivots are columns `pivot_locals` of `v`
    /// itself; only slab[t, i, k] with i < pivot_locals[t] < k is
    /// meaningful (the unique-triple region).
    fn mgemm3_diag(
        &self,
        v: &VectorSet<T>,
        pivots: &VectorSet<T>,
        _pivot_locals: &[usize],
    ) -> Result<SlabF64> {
        self.mgemm3(v, pivots, v)
    }
    /// Which kernel services **2-way** diagonal blocks: "triangular"
    /// (symmetry halved — all three numerator families) or "full"
    /// (square fallback). Reported in the CLI banner and the
    /// `run.meta` sidecar. 3-way diag slabs may independently fall
    /// back to [`Backend::mgemm3`].
    fn diag_kernel(&self) -> &'static str {
        "full"
    }

    fn name(&self) -> &'static str;
    /// Max pivot batch (jt) a single mgemm3 call should receive.
    fn pivot_batch(&self) -> usize {
        8
    }
    /// Shape-aware pivot batch: the jt of the artifact tier an
    /// (nf, nv) block will actually select — avoids forcing a large
    /// tier (and its padding waste) just to fit a big pivot batch.
    fn pivot_batch_for(&self, _nf: usize, _nv: usize) -> usize {
        self.pivot_batch()
    }
}

/// Naive scalar loops — the paper's "reference (CPU-only) version".
/// Stays single-core by design (it is the baseline the speedups are
/// measured against) but still serves triangular diagonal blocks, so
/// checksum comparisons against the optimized backend exercise the
/// same coverage.
pub struct CpuReference;

impl<T: Scalar> Backend<T> for CpuReference {
    fn mgemm2(&self, w: &VectorSet<T>, v: &VectorSet<T>) -> Result<MatF64> {
        Ok(reference::mgemm2(w, v))
    }
    fn mgemm3(
        &self,
        w: &VectorSet<T>,
        pivots: &VectorSet<T>,
        v: &VectorSet<T>,
    ) -> Result<SlabF64> {
        Ok(reference::mgemm3(w, pivots, v))
    }
    fn gemm2(&self, w: &VectorSet<T>, v: &VectorSet<T>) -> Result<MatF64> {
        Ok(reference::gemm(w, v))
    }
    fn sorenson2(&self, w: &BitVectorSet, v: &BitVectorSet) -> Result<MatF64> {
        Ok(sorenson::sorenson_mgemm_ref(w, v))
    }
    fn mgemm2_diag(&self, v: &VectorSet<T>) -> Result<MatF64> {
        Ok(reference::mgemm2_tri(v))
    }
    fn gemm2_diag(&self, v: &VectorSet<T>) -> Result<MatF64> {
        Ok(reference::gemm_tri(v))
    }
    fn sorenson2_diag(&self, v: &BitVectorSet) -> Result<MatF64> {
        Ok(sorenson::sorenson_mgemm_ref_tri(v))
    }
    // 3-way diag slabs keep the default full-square fallback: the
    // reference backend is the naive correctness baseline, and
    // `diag_kernel` only describes the 2-way diagonal-block family.
    fn diag_kernel(&self) -> &'static str {
        "triangular"
    }
    fn name(&self) -> &'static str {
        "cpu-reference"
    }
}

/// Blocked native kernels — the paper's optimized CPU version, with
/// symmetry-halved diagonal blocks and row-panel thread parallelism
/// (`threads` from the run config's `--threads`; 1 = serial, always
/// bit-identical to any other count).
pub struct CpuOptimized {
    pub threads: usize,
}

impl Default for CpuOptimized {
    fn default() -> Self {
        CpuOptimized { threads: 1 }
    }
}

impl CpuOptimized {
    pub fn with_threads(threads: usize) -> Self {
        CpuOptimized { threads: threads.max(1) }
    }
}

impl<T: Scalar> Backend<T> for CpuOptimized {
    fn mgemm2(&self, w: &VectorSet<T>, v: &VectorSet<T>) -> Result<MatF64> {
        Ok(optimized::mgemm2_mt(w, v, self.threads))
    }
    fn mgemm3(
        &self,
        w: &VectorSet<T>,
        pivots: &VectorSet<T>,
        v: &VectorSet<T>,
    ) -> Result<SlabF64> {
        Ok(optimized::mgemm3_mt(w, pivots, v, self.threads))
    }
    fn gemm2(&self, w: &VectorSet<T>, v: &VectorSet<T>) -> Result<MatF64> {
        Ok(optimized::gemm_mt(w, v, self.threads))
    }
    fn sorenson2(&self, w: &BitVectorSet, v: &BitVectorSet) -> Result<MatF64> {
        Ok(sorenson::sorenson_mgemm_mt(w, v, self.threads))
    }
    fn mgemm2_diag(&self, v: &VectorSet<T>) -> Result<MatF64> {
        Ok(optimized::mgemm2_tri_mt(v, self.threads))
    }
    fn gemm2_diag(&self, v: &VectorSet<T>) -> Result<MatF64> {
        Ok(optimized::gemm_tri_mt(v, self.threads))
    }
    fn sorenson2_diag(&self, v: &BitVectorSet) -> Result<MatF64> {
        Ok(sorenson::sorenson_mgemm_tri_mt(v, self.threads))
    }
    fn mgemm3_diag(
        &self,
        v: &VectorSet<T>,
        pivots: &VectorSet<T>,
        pivot_locals: &[usize],
    ) -> Result<SlabF64> {
        Ok(optimized::mgemm3_diag_mt(v, pivots, pivot_locals, self.threads))
    }
    fn diag_kernel(&self) -> &'static str {
        "triangular"
    }
    fn name(&self) -> &'static str {
        "cpu-optimized"
    }
}

/// AOT artifacts through the PJRT service — the accelerator version.
/// Default artifact kinds come from the metric engine's kernel
/// families ([`KernelFamily::artifact_kind`]); lowering sweeps
/// override the min-product kinds via [`PjrtBackend::with_kinds`] and
/// the dot/bitwise kinds via [`PjrtBackend::with_dot_kind`] /
/// [`PjrtBackend::with_bits_kind`].
pub struct PjrtBackend {
    ops: BlockOps,
    /// Artifact kind for 2-way min-product blocks ("mgemm2",
    /// "mgemm2pallas", …).
    pub kind2: String,
    /// Artifact kind for 3-way slabs ("mgemm3", "mgemm3pallas").
    pub kind3: String,
    /// Artifact kind for dot-product blocks ("gemm", "gemmpallas").
    pub kind_dot: String,
    /// Artifact kind for bitwise blocks ("sorenson2",
    /// "sorenson2pallas").
    pub kind_bits: String,
    /// jt tier used when batching pivots.
    jt: usize,
}

impl PjrtBackend {
    pub fn new(client: RuntimeClient, precision: Precision) -> Self {
        // Use the largest jt available for this precision (fewer calls).
        let jt = client
            .manifest()
            .entries
            .iter()
            .filter(|e| {
                e.kind == KernelFamily::MinProduct3.artifact_kind()
                    && e.precision == precision.into()
            })
            .map(|e| e.jt)
            .max()
            .unwrap_or(8);
        PjrtBackend {
            ops: BlockOps::new(client, precision),
            kind2: KernelFamily::MinProduct2.artifact_kind().to_string(),
            kind3: KernelFamily::MinProduct3.artifact_kind().to_string(),
            kind_dot: KernelFamily::Dot2.artifact_kind().to_string(),
            kind_bits: KernelFamily::BitAnd2.artifact_kind().to_string(),
            jt,
        }
    }

    /// Override the min-product artifact kinds ("mgemm2pallas", …).
    pub fn with_kinds(mut self, kind2: &str, kind3: &str) -> Self {
        self.kind2 = kind2.to_string();
        self.kind3 = kind3.to_string();
        self
    }

    /// Override the dot-product artifact kind ("gemmpallas", …).
    pub fn with_dot_kind(mut self, kind: &str) -> Self {
        self.kind_dot = kind.to_string();
        self
    }

    /// Override the bitwise artifact kind ("sorenson2pallas", …).
    pub fn with_bits_kind(mut self, kind: &str) -> Self {
        self.kind_bits = kind.to_string();
        self
    }
}

impl<T: Scalar> Backend<T> for PjrtBackend {
    fn mgemm2(&self, w: &VectorSet<T>, v: &VectorSet<T>) -> Result<MatF64> {
        self.ops.mgemm2(&self.kind2, w, v)
    }
    fn mgemm3(
        &self,
        w: &VectorSet<T>,
        pivots: &VectorSet<T>,
        v: &VectorSet<T>,
    ) -> Result<SlabF64> {
        self.ops.mgemm3(&self.kind3, w, pivots, v)
    }
    fn gemm2(&self, w: &VectorSet<T>, v: &VectorSet<T>) -> Result<MatF64> {
        self.ops.mgemm2(&self.kind_dot, w, v)
    }
    fn sorenson2(&self, w: &BitVectorSet, v: &BitVectorSet) -> Result<MatF64> {
        self.ops.sorenson2(&self.kind_bits, w, v)
    }
    fn name(&self) -> &'static str {
        "pjrt"
    }
    fn pivot_batch(&self) -> usize {
        self.jt
    }
    fn pivot_batch_for(&self, nf: usize, nv: usize) -> usize {
        // jt of the smallest tier covering (nf, nv): larger batches
        // would force a deeper/wider tier and pay padding quadratically.
        let manifest = self.ops.client.manifest();
        manifest
            .entries
            .iter()
            .filter(|e| {
                e.kind == self.kind3
                    && e.precision == crate::runtime::ElemKind::from(self.ops.precision)
                    && e.nf >= nf
                    && e.nv >= nv
                    && manifest.dir.join(&e.file).exists()
            })
            .min_by_key(|e| (e.nf, e.nv, e.jt))
            .map(|e| e.jt)
            .unwrap_or(self.jt)
    }
}

/// The diag-kernel report ([`Backend::diag_kernel`]) of the backend a
/// config names, without constructing it — for the CLI banner, which
/// prints before any backend (or PJRT service) exists. CPU arms
/// delegate to the real impls so they cannot drift; `run.meta` records
/// the constructed instance's own report.
pub fn diag_kernel_for(kind: BackendKind) -> &'static str {
    match kind {
        BackendKind::CpuReference => Backend::<f64>::diag_kernel(&CpuReference),
        BackendKind::CpuOptimized => Backend::<f64>::diag_kernel(&CpuOptimized::default()),
        // PJRT artifacts are shape-specialized full squares (trait
        // default); a triangular artifact tier is a ROADMAP follow-up.
        BackendKind::Pjrt => "full",
    }
}

/// Build the backend a config names. `runtime` must be Some for
/// [`BackendKind::Pjrt`]. `threads` drives the optimized CPU backend's
/// row-panel parallelism; the reference backend is single-core by
/// design and the PJRT path owns its own accelerator parallelism.
pub fn make_backend<T: Scalar>(
    kind: BackendKind,
    precision: Precision,
    runtime: Option<RuntimeClient>,
    threads: usize,
) -> Result<Arc<dyn Backend<T>>> {
    Ok(match kind {
        BackendKind::CpuReference => Arc::new(CpuReference),
        BackendKind::CpuOptimized => Arc::new(CpuOptimized::with_threads(threads)),
        BackendKind::Pjrt => {
            let client = runtime.ok_or_else(|| {
                anyhow::anyhow!("pjrt backend requires a running PjrtService (artifacts built?)")
            })?;
            Arc::new(PjrtBackend::new(client, precision))
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vecdata::SyntheticKind;

    #[test]
    fn cpu_backends_agree() {
        let w: VectorSet<f64> = VectorSet::generate(SyntheticKind::RandomGrid, 1, 32, 8, 0);
        let v: VectorSet<f64> = VectorSet::generate(SyntheticKind::RandomGrid, 1, 32, 8, 8);
        let a = Backend::<f64>::mgemm2(&CpuReference, &w, &v).unwrap();
        let b = Backend::<f64>::mgemm2(&CpuOptimized::default(), &w, &v).unwrap();
        assert_eq!(a.max_abs_diff(&b), 0.0);
    }

    #[test]
    fn cpu_backends_agree_on_dot_family() {
        let w: VectorSet<f64> = VectorSet::generate(SyntheticKind::Alleles, 2, 40, 6, 0);
        let v: VectorSet<f64> = VectorSet::generate(SyntheticKind::Alleles, 2, 40, 6, 6);
        let a = Backend::<f64>::gemm2(&CpuReference, &w, &v).unwrap();
        let b = Backend::<f64>::gemm2(&CpuOptimized::default(), &w, &v).unwrap();
        assert_eq!(a.max_abs_diff(&b), 0.0);
    }

    #[test]
    fn cpu_backends_agree_on_bitwise_family() {
        let bits = BitVectorSet::generate(5, 130, 9, 0.35);
        let a = Backend::<f64>::sorenson2(&CpuReference, &bits, &bits).unwrap();
        let b = Backend::<f64>::sorenson2(&CpuOptimized::default(), &bits, &bits).unwrap();
        assert_eq!(a.max_abs_diff(&b), 0.0);
    }

    #[test]
    fn diag_kernels_agree_across_cpu_backends_and_threads() {
        let v: VectorSet<f64> = VectorSet::generate(SyntheticKind::RandomGrid, 3, 48, 9, 0);
        let a = Backend::<f64>::mgemm2_diag(&CpuReference, &v).unwrap();
        for threads in [1, 2, 4] {
            let b = Backend::<f64>::mgemm2_diag(&CpuOptimized::with_threads(threads), &v).unwrap();
            assert_eq!(a.max_abs_diff(&b), 0.0, "threads={threads}");
        }
        assert_eq!(Backend::<f64>::diag_kernel(&CpuReference), "triangular");
        assert_eq!(Backend::<f64>::diag_kernel(&CpuOptimized::default()), "triangular");
        // The banner helper must agree with the instances it names.
        assert_eq!(diag_kernel_for(BackendKind::CpuReference), "triangular");
        assert_eq!(diag_kernel_for(BackendKind::CpuOptimized), "triangular");
        assert_eq!(diag_kernel_for(BackendKind::Pjrt), "full");
    }

    #[test]
    fn make_backend_pjrt_requires_runtime() {
        let err = match make_backend::<f64>(BackendKind::Pjrt, Precision::F64, None, 1) {
            Err(e) => e,
            Ok(_) => panic!("expected error without a runtime client"),
        };
        assert!(err.to_string().contains("artifacts"));
    }

    #[test]
    fn make_backend_threads_reach_cpu_optimized() {
        let b = make_backend::<f64>(BackendKind::CpuOptimized, Precision::F64, None, 4).unwrap();
        assert_eq!(b.name(), "cpu-optimized");
        // Thread count must not change values (bit-identity contract).
        let v: VectorSet<f64> = VectorSet::generate(SyntheticKind::RandomGrid, 7, 33, 7, 0);
        let serial = Backend::<f64>::mgemm2(&CpuOptimized::default(), &v, &v).unwrap();
        assert_eq!(serial.max_abs_diff(&b.mgemm2(&v, &v).unwrap()), 0.0);
    }

    #[test]
    fn backend_names() {
        assert_eq!(Backend::<f64>::name(&CpuReference), "cpu-reference");
        assert_eq!(Backend::<f32>::name(&CpuOptimized::default()), "cpu-optimized");
    }
}
