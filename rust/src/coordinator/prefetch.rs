//! Read-ahead block pipeline: the prefetch half of out-of-core
//! streaming ingest.
//!
//! [`ReadAhead`] wraps any [`BlockProvider`] (in practice a session
//! [`Dataset`](crate::session::Dataset), whose misses may be spill
//! reloads) and warms blocks **in step-schedule order** on a
//! `linalg::pool` worker before the node programs ask for them — the
//! double-buffered disk/compute overlap of Beyer & Bientinesi
//! (arXiv 1302.4332). The compute loop then blocks only on a genuinely
//! late read, and that wait is what [`ReadAhead::stall_secs`] measures
//! (surfaced as `RunStats::t_stall`).
//!
//! The prefetch contract:
//!
//! * **Hints, not fetches.** [`BlockProvider::prefetch`] is advisory:
//!   `run_typed` hints the whole run's block order up front
//!   ([`prefetch_order`] — each rank's `(pv, pf)` slice in rank order,
//!   which is exactly the order node threads enter their input phase),
//!   and each node program re-hints its own slice (a no-op after the
//!   run-level hint; keys are deduplicated). Providers without a
//!   pipeline ignore hints entirely — one-shot runs are unchanged.
//! * **Bounded in-flight budget.** At most `budget` warmed blocks are
//!   held ahead of the consumers (default [`DEFAULT_BUDGET`] — classic
//!   double buffering). The background task parks on a condvar when
//!   the buffer is full and resumes as consumers drain it; the
//!   high-water mark is observable ([`ReadAhead::max_ahead`]) and
//!   pinned ≤ budget by the scheduler tests.
//! * **Compute always wins.** A consumer that reaches a key before the
//!   prefetcher takes it from the inner provider directly and marks the
//!   key consumed; the task skips consumed keys instead of fetching
//!   dead blocks. Fetch errors abort the pipeline silently — the
//!   consumer's own fetch surfaces the identical (typed) error.
//! * **Bounded lifetime.** [`ReadAhead::finish`] stops the task and
//!   drops warmed blocks; `Session::run` calls it run-end, error or
//!   not, so a prefetch never outlives its run.

use std::collections::{HashMap, HashSet, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use anyhow::Result;

use crate::config::{Precision, RunConfig};
use crate::coordinator::{BlockProvider, ProvideBlocks};
use crate::metrics::Metric;
use crate::util::Scalar;
use crate::vecdata::block::Block;

/// Default in-flight block budget: one block being consumed, one in
/// flight — double buffering.
pub const DEFAULT_BUDGET: usize = 2;

/// A warmed block of either run precision (the pipeline is built
/// per-run, but the provider seam is precision-erased).
enum Warmed {
    F32(Block<f32>),
    F64(Block<f64>),
}

/// Precision bridge for the warmed-block buffer.
trait WarmedBlocks: Scalar + ProvideBlocks {
    fn wrap(block: Block<Self>) -> Warmed;
    fn unwrap(warmed: Warmed) -> Option<Block<Self>>;
}

impl WarmedBlocks for f32 {
    fn wrap(block: Block<f32>) -> Warmed {
        Warmed::F32(block)
    }
    fn unwrap(warmed: Warmed) -> Option<Block<f32>> {
        match warmed {
            Warmed::F32(b) => Some(b),
            Warmed::F64(_) => None,
        }
    }
}

impl WarmedBlocks for f64 {
    fn wrap(block: Block<f64>) -> Warmed {
        Warmed::F64(block)
    }
    fn unwrap(warmed: Warmed) -> Option<Block<f64>> {
        match warmed {
            Warmed::F64(b) => Some(b),
            Warmed::F32(_) => None,
        }
    }
}

#[derive(Default)]
struct State {
    /// Hinted keys not yet fetched, in hint (= schedule) order.
    planned: VecDeque<(usize, usize)>,
    /// Every key ever hinted — repeated hints (node programs re-hint
    /// their own slice) deduplicate here.
    seen: HashSet<(usize, usize)>,
    /// Warmed blocks awaiting their consumer (≤ budget entries).
    ready: HashMap<(usize, usize), Warmed>,
    /// Keys a consumer already took — the task skips these.
    consumed: HashSet<(usize, usize)>,
    /// Whether a background task currently owns `planned`.
    task_running: bool,
    /// Set by [`ReadAhead::finish`] (or a fetch error): drain and stop.
    aborted: bool,
}

struct Core {
    inner: Arc<dyn BlockProvider>,
    budget: usize,
    state: Mutex<State>,
    cv: Condvar,
    stall_ns: AtomicU64,
    stalls: AtomicU64,
    prefetched: AtomicU64,
    max_ahead: AtomicU64,
    /// Keys in the order the background task actually fetched them —
    /// the scheduler tests pin this against [`prefetch_order`].
    fetch_log: Mutex<Vec<(usize, usize)>>,
}

impl Core {
    /// Background task: drain `planned` in order under the in-flight
    /// budget. Runs on a `linalg::pool` worker via `submit` (which
    /// reserves head room so this task's condvar parks can never
    /// starve kernel scopes).
    fn drain_planned(self: &Arc<Self>, cfg: RunConfig) {
        match cfg.precision {
            Precision::F32 => self.drain_typed::<f32>(&cfg),
            Precision::F64 => self.drain_typed::<f64>(&cfg),
        }
    }

    fn drain_typed<T: WarmedBlocks>(self: &Arc<Self>, cfg: &RunConfig) {
        let metric = crate::metrics::make_metric::<T>(cfg.metric, cfg);
        loop {
            let key = {
                let mut st = self.state.lock().unwrap();
                loop {
                    if st.aborted {
                        st.task_running = false;
                        self.cv.notify_all();
                        return;
                    }
                    while let Some(&k) = st.planned.front() {
                        if st.consumed.contains(&k) || st.ready.contains_key(&k) {
                            st.planned.pop_front();
                        } else {
                            break;
                        }
                    }
                    if st.planned.is_empty() {
                        st.task_running = false;
                        self.cv.notify_all();
                        return;
                    }
                    if st.ready.len() < self.budget {
                        break st.planned.pop_front().expect("non-empty");
                    }
                    st = self.cv.wait(st).unwrap();
                }
            };
            // Fetch outside every lock (this is the disk/ingest work
            // the pipeline exists to overlap with compute).
            match T::provide(self.inner.as_ref(), cfg, metric.as_ref(), key.0, key.1) {
                Ok(block) => {
                    self.fetch_log.lock().unwrap().push(key);
                    self.prefetched.fetch_add(1, Ordering::Relaxed);
                    let mut st = self.state.lock().unwrap();
                    if !st.consumed.contains(&key) {
                        st.ready.insert(key, T::wrap(block));
                        self.max_ahead.fetch_max(st.ready.len() as u64, Ordering::Relaxed);
                    }
                    self.cv.notify_all();
                }
                Err(_) => {
                    // The consumer's own fetch of this key surfaces the
                    // identical typed error; prefetching further keys
                    // would only repeat it.
                    let mut st = self.state.lock().unwrap();
                    st.aborted = true;
                    st.task_running = false;
                    self.cv.notify_all();
                    return;
                }
            }
        }
    }
}

/// See the module docs. Create one per run, [`finish`](Self::finish) it
/// at run end.
pub struct ReadAhead {
    core: Arc<Core>,
}

impl ReadAhead {
    /// Wrap `inner` with the default double-buffer budget.
    pub fn new(inner: Arc<dyn BlockProvider>) -> Self {
        Self::with_budget(inner, DEFAULT_BUDGET)
    }

    /// Wrap `inner` with an explicit in-flight block budget (≥ 1).
    pub fn with_budget(inner: Arc<dyn BlockProvider>, budget: usize) -> Self {
        ReadAhead {
            core: Arc::new(Core {
                inner,
                budget: budget.max(1),
                state: Mutex::new(State::default()),
                cv: Condvar::new(),
                stall_ns: AtomicU64::new(0),
                stalls: AtomicU64::new(0),
                prefetched: AtomicU64::new(0),
                max_ahead: AtomicU64::new(0),
                fetch_log: Mutex::new(Vec::new()),
            }),
        }
    }

    /// Stop the pipeline: abort the background task, wait for it to
    /// park, drop warmed blocks. Idempotent.
    pub fn finish(&self) {
        let mut st = self.core.state.lock().unwrap();
        st.aborted = true;
        self.core.cv.notify_all();
        while st.task_running {
            st = self.core.cv.wait(st).unwrap();
        }
        st.ready.clear();
    }

    /// Block until every hinted key has been fetched or consumed and
    /// the task has parked (test introspection; deadlocks if the
    /// budget is smaller than the number of outstanding keys and
    /// nothing consumes).
    pub fn drain(&self) {
        let mut st = self.core.state.lock().unwrap();
        while st.task_running {
            st = self.core.cv.wait(st).unwrap();
        }
    }

    /// Seconds consumers spent blocked on a hinted-but-late block (the
    /// genuinely exposed read time).
    pub fn stall_secs(&self) -> f64 {
        self.core.stall_ns.load(Ordering::Relaxed) as f64 / 1e9
    }

    /// Number of consumer fetches that found their hinted block late.
    pub fn stalls(&self) -> u64 {
        self.core.stalls.load(Ordering::Relaxed)
    }

    /// Blocks fetched by the background task.
    pub fn prefetched(&self) -> u64 {
        self.core.prefetched.load(Ordering::Relaxed)
    }

    /// High-water mark of warmed blocks held ahead of consumers —
    /// never exceeds the budget.
    pub fn max_ahead(&self) -> u64 {
        self.core.max_ahead.load(Ordering::Relaxed)
    }

    /// The keys the background task fetched, in fetch order.
    pub fn fetch_log(&self) -> Vec<(usize, usize)> {
        self.core.fetch_log.lock().unwrap().clone()
    }

    fn take_or_fetch<T: WarmedBlocks>(
        &self,
        cfg: &RunConfig,
        metric: &dyn Metric<T>,
        pv: usize,
        pf: usize,
    ) -> Result<Block<T>> {
        let key = (pv, pf);
        let hinted_late = {
            let mut st = self.core.state.lock().unwrap();
            if let Some(w) = st.ready.remove(&key) {
                st.consumed.insert(key);
                self.core.cv.notify_all();
                if let Some(block) = T::unwrap(w) {
                    return Ok(block);
                }
                // A cross-precision stash is impossible within one run
                // (the pipeline is per-run); fall through defensively.
                false
            } else {
                // Mark consumed so the task skips the key; remember
                // whether the schedule had promised it (a late read).
                let late = st.seen.contains(&key) && !st.consumed.contains(&key);
                st.consumed.insert(key);
                self.core.cv.notify_all();
                late
            }
        };
        let t0 = Instant::now();
        let block = T::provide(self.core.inner.as_ref(), cfg, metric, pv, pf)?;
        if hinted_late {
            self.core
                .stall_ns
                .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
            self.core.stalls.fetch_add(1, Ordering::Relaxed);
        }
        Ok(block)
    }
}

impl Drop for ReadAhead {
    fn drop(&mut self) {
        self.finish();
    }
}

impl BlockProvider for ReadAhead {
    fn block_f32(
        &self,
        cfg: &RunConfig,
        metric: &dyn Metric<f32>,
        pv: usize,
        pf: usize,
    ) -> Result<Block<f32>> {
        self.take_or_fetch(cfg, metric, pv, pf)
    }

    fn block_f64(
        &self,
        cfg: &RunConfig,
        metric: &dyn Metric<f64>,
        pv: usize,
        pf: usize,
    ) -> Result<Block<f64>> {
        self.take_or_fetch(cfg, metric, pv, pf)
    }

    fn prefetch(&self, cfg: &RunConfig, keys: &[(usize, usize)]) {
        let mut st = self.core.state.lock().unwrap();
        if st.aborted {
            return;
        }
        let mut added = false;
        for &k in keys {
            if !st.consumed.contains(&k) && st.seen.insert(k) {
                st.planned.push_back(k);
                added = true;
            }
        }
        if added && !st.task_running {
            st.task_running = true;
            let core = Arc::clone(&self.core);
            let cfg = cfg.clone();
            crate::linalg::pool::global().submit(Box::new(move || core.drain_planned(cfg)));
        }
    }
}

/// The provider-visible projection of the step schedule: each rank's
/// own `(pv, pf)` slice, in rank order (deduplicated — npr-replicated
/// ranks share a slice). Node programs fetch from the provider exactly
/// once, at input phase, and node threads start in rank order — so this
/// *is* the order blocks are first needed; peer blocks then circulate
/// on the wire, not through the provider.
pub fn prefetch_order(cfg: &RunConfig) -> Vec<(usize, usize)> {
    let mut seen = HashSet::new();
    let mut order = Vec::new();
    for rank in 0..cfg.grid.np() {
        let c = cfg.grid.coords(rank);
        if seen.insert((c.pv, c.pf)) {
            order.push((c.pv, c.pf));
        }
    }
    order
}
