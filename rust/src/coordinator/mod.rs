//! The Layer-3 coordinator: the paper's parallel algorithms (§4) run
//! over the virtual cluster, with the mGEMM blocks offloaded through a
//! [`backend::Backend`].
//!
//! * [`two_way`] — Algorithm 1: block-circulant ring pipeline.
//! * [`three_way`] — Algorithms 2 + 3: tetrahedral slices, pivot
//!   pipeline, staging.
//! * [`serial`] — single-node convenience drivers (examples/tests).
//!
//! Division of labor matches §3.1: numerators (mGEMM/GEMM/popcount)
//! go to the backend/accelerator; denominators, quotients, checksums,
//! and output stay on the coordinator ("CPU") side. Both halves are
//! dispatched through the run's [`crate::metrics::Metric`], so the
//! node programs contain no metric-specific branches — swapping
//! `--metric` swaps the kernel family, the denominator precomputation,
//! and the quotient combination in one place.

pub mod backend;
pub mod checkpoint;
pub mod prefetch;
pub mod serial;
pub mod three_way;
pub mod two_way;

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::checksum::Checksum;
use crate::comm::faults::FaultPlan;
use crate::comm::VirtualCluster;
use crate::config::{BackendKind, InputSource, Precision, RunConfig};
use crate::decomp::partition::Partition;
use crate::metrics::store::{PairStore, TripleStore};
use crate::metrics::Metric;
use crate::output::sink::{CollectSink, FileSink, ResultSink, TeeRef};
use crate::runtime::{PjrtService, RuntimeClient};
use crate::util::Scalar;
use crate::vecdata::block::Block;
use crate::vecdata::{io as vio, VectorSet};

/// Per-run counters and timings, merged across nodes.
#[derive(Debug, Default, Clone)]
pub struct RunStats {
    /// mGEMM block executions (2-way kind).
    pub mgemm2_calls: u64,
    /// 3-way slab executions.
    pub mgemm3_calls: u64,
    /// Metric values produced.
    pub metrics: u64,
    /// Comm volume in bytes — float payloads at run precision, packed
    /// payloads at 8 B/word — and message count. Recorded per node from
    /// its endpoint's sent totals and summed by [`RunStats::absorb`]
    /// (the cluster-level counters are only a cross-check now).
    pub comm_bytes: u64,
    pub comm_messages: u64,
    /// Result tiles pushed through the run's [`ResultSink`] (0 when the
    /// sink is null — the `--no-store` fast path skips tile assembly).
    pub tiles: u64,
    /// Wall-clock phases (seconds; max across nodes = makespan).
    pub t_input: f64,
    pub t_compute: f64,
    pub t_output: f64,
    pub t_total: f64,
    /// Accelerator-side execution seconds (PJRT only).
    pub t_accel: f64,
    /// Worker-pool dispatch accounting for this run: parallel scopes
    /// entered (one per multi-threaded kernel call), tasks executed,
    /// and OS threads newly spawned while the run was in flight.
    /// Captured as deltas of the process-global `linalg::pool` counters
    /// around the run (concurrent runs in one process each see the
    /// combined activity). `pool_threads_spawned` staying 0 is the
    /// warm-pool signal: zero per-kernel-call thread spawns.
    pub pool_scopes: u64,
    pub pool_tasks: u64,
    pub pool_threads_spawned: u64,
    /// Session block-cache pressure during this run: hits, misses
    /// (load + ingest), and budget evictions — ledger deltas captured
    /// by `Session::run` (the one-shot path caches nothing, so they
    /// stay 0 there). `cache_bytes` is the resident total at run end.
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub cache_evictions: u64,
    pub cache_bytes: u64,
    /// Out-of-core pipeline activity during this run (ledger deltas
    /// captured by `Session::run`; zero without a spill store). Spills
    /// are evictions that landed in the on-disk block store
    /// (`spill_bytes` counts bytes actually written — a re-evicted
    /// block whose payload is already on disk spills without a write);
    /// reloads are misses served byte-identically from it.
    pub spills: u64,
    pub spill_bytes: u64,
    pub reloads: u64,
    pub reload_bytes: u64,
    /// Seconds compute spent blocked on a scheduled-but-late block
    /// read (`coordinator::prefetch::ReadAhead` stall clock) — the
    /// exposed, un-overlapped part of reload time.
    pub t_stall: f64,
    /// Comm-fabric resilience counters: link-layer retransmits this
    /// run's endpoints performed recovering from dropped/corrupted
    /// envelopes, envelopes discarded on checksum mismatch at receive,
    /// and scripted faults injected by an attached
    /// [`crate::comm::faults::FaultPlan`]. All zero on a healthy fabric
    /// — `tests/fault_tolerance.rs` pins that fault-free runs also add
    /// zero wire messages/bytes over the `tests/comm_accounting.rs`
    /// baselines.
    pub comm_retries: u64,
    pub comm_corrupt: u64,
    pub faults_injected: u64,
    /// Checkpoint/resume accounting (zero without a checkpoint store):
    /// units persisted (and their encoded bytes), units skipped on
    /// resume, metric values replayed from persisted tiles, and failed
    /// checkpoint writes (non-fatal — those units recompute on the
    /// next resume).
    pub ckpt_writes: u64,
    pub ckpt_bytes: u64,
    pub ckpt_skipped: u64,
    pub ckpt_replayed: u64,
    pub ckpt_errors: u64,
    /// Genotype-ingest accounting (zero for synthetic/raw-float
    /// inputs): calls decoded by the `.bed`/VCF readers, missing calls
    /// among them (imputed to dosage 0), and 2-bit plane packs
    /// performed (`Repr::Packed2` ingests — the pack-once contract pins
    /// one per node block). Captured as deltas of the process-global
    /// `vecdata::geno` counters around the run, with the same
    /// concurrent-runs caveat as the pool counters.
    pub geno_calls: u64,
    pub geno_missing: u64,
    pub pack2_calls: u64,
}

impl RunStats {
    /// Merge another run's (or node's) counters into this one: counts
    /// sum, wall-clock phases take the max (makespan). Public so batch
    /// drivers can aggregate per-request outcomes.
    pub fn absorb(&mut self, o: &RunStats) {
        self.mgemm2_calls += o.mgemm2_calls;
        self.mgemm3_calls += o.mgemm3_calls;
        self.metrics += o.metrics;
        self.tiles += o.tiles;
        // Counters sum across nodes; wall-clock phases take the max
        // (makespan). comm_* and t_accel previously fell through this
        // merge entirely; the comm totals of a run now flow exclusively
        // through this sum (per-node endpoint counts → absorb), with
        // the cluster-level counters kept as a debug cross-check.
        self.comm_bytes += o.comm_bytes;
        self.comm_messages += o.comm_messages;
        // Pool counters are captured once at run level (node results
        // carry zeros), but sum like the other counters so batch-style
        // aggregation over outcomes works.
        self.pool_scopes += o.pool_scopes;
        self.pool_tasks += o.pool_tasks;
        self.pool_threads_spawned += o.pool_threads_spawned;
        // Cache pressure: event counts sum; resident bytes is a level,
        // not a flow — a batch ledger reports the peak it saw.
        self.cache_hits += o.cache_hits;
        self.cache_misses += o.cache_misses;
        self.cache_evictions += o.cache_evictions;
        self.cache_bytes = self.cache_bytes.max(o.cache_bytes);
        // Spill traffic sums like the other event counters; stall time
        // also sums (it is already a per-run aggregate over nodes, and
        // a batch's total exposed read time is what the ledger wants).
        self.spills += o.spills;
        self.spill_bytes += o.spill_bytes;
        self.reloads += o.reloads;
        self.reload_bytes += o.reload_bytes;
        self.t_stall += o.t_stall;
        // Resilience + checkpoint counters are events: they sum, like
        // the comm counters they sit beside.
        self.comm_retries += o.comm_retries;
        self.comm_corrupt += o.comm_corrupt;
        self.faults_injected += o.faults_injected;
        self.ckpt_writes += o.ckpt_writes;
        self.ckpt_bytes += o.ckpt_bytes;
        self.ckpt_skipped += o.ckpt_skipped;
        self.ckpt_replayed += o.ckpt_replayed;
        self.ckpt_errors += o.ckpt_errors;
        self.geno_calls += o.geno_calls;
        self.geno_missing += o.geno_missing;
        self.pack2_calls += o.pack2_calls;
        self.t_input = self.t_input.max(o.t_input);
        self.t_compute = self.t_compute.max(o.t_compute);
        self.t_output = self.t_output.max(o.t_output);
        self.t_total = self.t_total.max(o.t_total);
        self.t_accel = self.t_accel.max(o.t_accel);
    }
}

/// Result of a coordinated run.
#[derive(Debug, Default)]
pub struct RunOutcome {
    pub stats: RunStats,
    pub checksum: Checksum,
    /// Present when `cfg.store_metrics` (2-way runs).
    pub pairs: Option<PairStore>,
    /// Present when `cfg.store_metrics` (3-way runs).
    pub triples: Option<TripleStore>,
}

/// What one node thread returns.
pub(crate) struct NodeResult {
    pub checksum: Checksum,
    pub stats: RunStats,
}

/// Typed abort of a coordinated run: one or more node programs failed
/// (panic, comm timeout, killed rank, dead peer, sink error). The
/// supervisor in [`run_streamed_opts`] joins **every** node thread
/// before surfacing this — a failing rank drops its endpoint, peers
/// time out on their bounded receives and unwind, and no thread is
/// left blocked mid-ring — so the error carries a diagnostic for each
/// rank that failed, not just the first.
#[derive(Debug)]
pub struct RunError {
    /// `(rank, diagnostic)` for every failed node, rank-ordered.
    pub ranks: Vec<(usize, String)>,
}

impl std::fmt::Display for RunError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "run aborted: {} rank(s) failed", self.ranks.len())?;
        for (rank, diag) in &self.ranks {
            write!(f, "; rank {rank}: {diag}")?;
        }
        Ok(())
    }
}

impl std::error::Error for RunError {}

/// Human-readable panic payload (the `&str`/`String` cases cover every
/// `panic!` in this crate; anything else gets a generic tag).
fn panic_message(payload: &(dyn std::any::Any + Send)) -> &str {
    payload
        .downcast_ref::<&str>()
        .copied()
        .or_else(|| payload.downcast_ref::<String>().map(|s| s.as_str()))
        .unwrap_or("opaque panic payload")
}

/// Optional run attachments: a scripted comm-fault plan (test rigs) and
/// a checkpoint store (campaign resume). `Default` is a plain run —
/// every existing call site goes through [`run_streamed`], which passes
/// exactly that.
#[derive(Default, Clone)]
pub struct RunOpts {
    /// Scripted comm faults injected into the run's fabric.
    pub faults: Option<Arc<FaultPlan>>,
    /// Persist completed work units; skip + replay them on resume.
    pub checkpoint: Option<Arc<checkpoint::CheckpointStore>>,
}

/// Supplies ingested node blocks to a run — the seam the session layer
/// uses to share a dataset's per-`(block, repr)` ingests across many
/// runs. One-shot runs use [`FreshIngest`] (load + ingest every time).
/// Non-generic (one method per run precision) so it can sit behind an
/// `Arc<dyn _>` in every run path; [`ProvideBlocks`] bridges back into
/// the generic node programs.
pub trait BlockProvider: Send + Sync {
    fn block_f32(
        &self,
        cfg: &RunConfig,
        metric: &dyn Metric<f32>,
        pv: usize,
        pf: usize,
    ) -> Result<Block<f32>>;

    fn block_f64(
        &self,
        cfg: &RunConfig,
        metric: &dyn Metric<f64>,
        pv: usize,
        pf: usize,
    ) -> Result<Block<f64>>;

    /// Advisory hint that the given `(pv, pf)` blocks will be fetched,
    /// in this order. Providers with a read-ahead pipeline
    /// ([`prefetch::ReadAhead`]) start warming them; everything else
    /// ignores the hint — the default is a no-op, and correctness never
    /// depends on it.
    fn prefetch(&self, _cfg: &RunConfig, _keys: &[(usize, usize)]) {}
}

/// The one-shot provider: load (or generate) the block and ingest it
/// into the metric's preferred representation, every time it is asked.
pub struct FreshIngest;

impl BlockProvider for FreshIngest {
    fn block_f32(
        &self,
        cfg: &RunConfig,
        metric: &dyn Metric<f32>,
        pv: usize,
        pf: usize,
    ) -> Result<Block<f32>> {
        Ok(metric.ingest(load_block::<f32>(cfg, pv, pf)?))
    }

    fn block_f64(
        &self,
        cfg: &RunConfig,
        metric: &dyn Metric<f64>,
        pv: usize,
        pf: usize,
    ) -> Result<Block<f64>> {
        Ok(metric.ingest(load_block::<f64>(cfg, pv, pf)?))
    }
}

/// Precision-dispatch bridge: implemented for exactly the two run
/// precisions, so the generic node programs can pull typed blocks out
/// of a non-generic [`BlockProvider`].
pub trait ProvideBlocks: Scalar {
    fn provide(
        provider: &dyn BlockProvider,
        cfg: &RunConfig,
        metric: &dyn Metric<Self>,
        pv: usize,
        pf: usize,
    ) -> Result<Block<Self>>;
}

impl ProvideBlocks for f32 {
    fn provide(
        provider: &dyn BlockProvider,
        cfg: &RunConfig,
        metric: &dyn Metric<f32>,
        pv: usize,
        pf: usize,
    ) -> Result<Block<f32>> {
        provider.block_f32(cfg, metric, pv, pf)
    }
}

impl ProvideBlocks for f64 {
    fn provide(
        provider: &dyn BlockProvider,
        cfg: &RunConfig,
        metric: &dyn Metric<f64>,
        pv: usize,
        pf: usize,
    ) -> Result<Block<f64>> {
        provider.block_f64(cfg, metric, pv, pf)
    }
}

/// Run a configured campaign end-to-end. Dispatches on precision; for
/// [`BackendKind::Pjrt`] a [`PjrtService`] is started for the run.
///
/// One-shot shim over the session-first core: blocks are loaded and
/// ingested fresh, results land in `RunOutcome::{pairs, triples}` /
/// per-node files per the config's `store_metrics` / `output_dir`.
/// Long-lived callers should hold a [`crate::session::Session`] instead
/// (ingest-once blocks, persistent executable cache, streaming sinks).
pub fn run(cfg: &RunConfig) -> Result<RunOutcome> {
    run_with_artifacts(cfg, std::path::Path::new("artifacts"))
}

/// As [`run`], with an explicit artifact directory. Starts (and tears
/// down) a fresh PJRT service — one-shot campaigns. Long-lived callers
/// (benches, servers) should start one [`PjrtService`] (or a
/// [`crate::session::Session`]) so compiled executables are reused
/// across runs.
pub fn run_with_artifacts(cfg: &RunConfig, artifact_dir: &std::path::Path) -> Result<RunOutcome> {
    let service = match cfg.backend {
        BackendKind::Pjrt => Some(PjrtService::start(artifact_dir).context("start PJRT service")?),
        _ => None,
    };
    run_with_client(cfg, service.as_ref().map(|s| s.client()))
}

/// Run against an existing PJRT service (None for native backends).
/// The service's executable cache persists across calls — the §Perf
/// fix for per-run artifact recompilation (~70 ms/run on this host).
///
/// Legacy sink assembly: `store_metrics` → a [`CollectSink`] drained
/// into the outcome, `output_dir` → a [`FileSink`]; both may be active
/// at once, neither means a null sink (tile assembly skipped).
pub fn run_with_client(cfg: &RunConfig, client: Option<RuntimeClient>) -> Result<RunOutcome> {
    run_with_legacy_sinks(cfg, cfg.store_metrics, true, |sink| {
        run_streamed(cfg, client, Arc::new(FreshIngest), sink)
    })
}

/// The legacy collection shape shared by [`run_with_client`] and
/// `session::Session::run_collect`: a [`CollectSink`] (when `collect`)
/// plus a [`FileSink`] (when `add_file` and the config names an output
/// directory — session paths pass false because `Session::run` already
/// rides the request's file sink), teed; afterwards the collected
/// stores are unpacked into `RunOutcome::{pairs, triples}` by
/// `num_way`.
pub(crate) fn run_with_legacy_sinks(
    cfg: &RunConfig,
    collect: bool,
    add_file: bool,
    run: impl FnOnce(&dyn ResultSink) -> Result<RunOutcome>,
) -> Result<RunOutcome> {
    let collect = collect.then(|| CollectSink::for_metric(cfg.metric));
    let file = if add_file {
        cfg.output_dir.as_ref().map(|dir| FileSink::new(dir, cfg.output_threshold))
    } else {
        None
    };
    let mut sinks: Vec<&dyn ResultSink> = Vec::new();
    if let Some(c) = &collect {
        sinks.push(c);
    }
    if let Some(f) = &file {
        sinks.push(f);
    }
    let tee = TeeRef::new(sinks);
    let mut outcome = run(&tee)?;
    if let Some(c) = collect {
        let (pairs, triples) = c.take();
        if cfg.num_way == 2 {
            outcome.pairs = Some(pairs);
        } else {
            outcome.triples = Some(triples);
        }
    }
    Ok(outcome)
}

/// The session-first core: run against an explicit ingested-block
/// provider and a streaming result sink. Everything else ([`run`],
/// [`run_with_client`], `session::Session::run`) is assembly around
/// this. The outcome carries stats and the §5 checksum; metric values
/// flow exclusively through `sink`.
pub fn run_streamed(
    cfg: &RunConfig,
    client: Option<RuntimeClient>,
    provider: Arc<dyn BlockProvider>,
    sink: &dyn ResultSink,
) -> Result<RunOutcome> {
    run_streamed_opts(cfg, client, provider, sink, &RunOpts::default())
}

/// [`run_streamed`] with explicit [`RunOpts`] — the supervised,
/// fault-injectable, checkpointable entry point. A failed run (panicked
/// node, exhausted retransmit budget, killed rank) surfaces as a typed
/// [`RunError`] with per-rank diagnostics after *all* node threads have
/// unwound.
pub fn run_streamed_opts(
    cfg: &RunConfig,
    client: Option<RuntimeClient>,
    provider: Arc<dyn BlockProvider>,
    sink: &dyn ResultSink,
    opts: &RunOpts,
) -> Result<RunOutcome> {
    cfg.validate()?;
    if cfg.num_way == 3 && cfg.grid.npf > 1 {
        bail!("npf > 1 is not supported for 3-way runs (the paper sets npf=1 there too)");
    }
    let accel_before = client.as_ref().map(|c| c.stats().1).unwrap_or(0.0);
    let mut outcome = match cfg.precision {
        Precision::F32 => run_typed::<f32>(cfg, client.clone(), provider, sink, opts),
        Precision::F64 => run_typed::<f64>(cfg, client.clone(), provider, sink, opts),
    }?;
    if let Some(c) = &client {
        let (_execs, secs) = c.stats();
        outcome.stats.t_accel = secs - accel_before;
    }
    Ok(outcome)
}

fn run_typed<T: Scalar + ProvideBlocks>(
    cfg: &RunConfig,
    client: Option<RuntimeClient>,
    provider: Arc<dyn BlockProvider>,
    sink: &dyn ResultSink,
    opts: &RunOpts,
) -> Result<RunOutcome> {
    let backend = backend::make_backend::<T>(cfg.backend, cfg.precision, client, cfg.threads)?;
    let metric = crate::metrics::make_metric::<T>(cfg.metric, cfg);
    let np = cfg.grid.np();
    let mut cluster = match &opts.faults {
        Some(plan) => VirtualCluster::with_faults(np, cfg.precision.bytes(), Arc::clone(plan)),
        None => VirtualCluster::new(np, cfg.precision.bytes()),
    };
    let counters = cluster.counters();
    let endpoints = cluster.endpoints();
    let null = sink.is_null();
    // Per-run checkpoint view (key prefix + fresh ledger counters),
    // shared by every node thread.
    let ckpt = opts
        .checkpoint
        .as_ref()
        .map(|store| Arc::new(store.for_run(cfg, metric.ingest_key())));
    let faults_before = opts.faults.as_ref().map(|p| p.injected()).unwrap_or(0);

    // Hint the whole run's block schedule up front (rank order = the
    // order node threads enter their input phase); a read-ahead
    // provider starts warming blocks before the first node asks.
    provider.prefetch(cfg, &prefetch::prefetch_order(cfg));

    let t0 = std::time::Instant::now();
    let pool_before = crate::linalg::pool::stats();
    let geno_before = (
        crate::vecdata::geno::calls_decoded(),
        crate::vecdata::geno::missing_calls(),
        crate::vecdata::geno::pack2_calls(),
    );
    let mut handles = Vec::new();
    for ep in endpoints {
        let coord = cfg.grid.coords(ep.rank);
        // Only ranks that assemble metrics get a node sink (2-way
        // assembly happens on the pf = 0 plane; other pf ranks feed the
        // npf reduction and emit nothing) — so e.g. a FileSink creates
        // exactly the per-node files the pre-sink coordinator did.
        let emits = cfg.num_way != 2 || coord.pf == 0;
        let node_sink = if emits && !null {
            Some(sink.node_sink(ep.rank)?)
        } else {
            None
        };
        let cfg = cfg.clone();
        let backend = Arc::clone(&backend);
        let metric = Arc::clone(&metric);
        let provider = Arc::clone(&provider);
        let ckpt = ckpt.clone();
        let rank = ep.rank;
        handles.push((
            rank,
            std::thread::Builder::new()
                .name(format!("node-{}", rank))
                .spawn(move || -> Result<NodeResult> {
                    if cfg.num_way == 2 {
                        two_way::node_main::<T>(
                            &cfg, coord, ep, backend, metric, provider, node_sink, ckpt,
                        )
                    } else {
                        three_way::node_main::<T>(
                            &cfg, coord, ep, backend, metric, provider, node_sink, ckpt,
                        )
                    }
                })
                .context("spawn node thread")?,
        ));
    }

    // Supervisor: drain EVERY join before judging the run. A failing
    // rank drops its endpoint; peers blocked on it hit their bounded
    // recv deadline and unwind with typed errors of their own — joining
    // sequentially-and-bailing-early would instead leave threads
    // orphaned mid-ring (the old deadlock-on-panic shape).
    let mut outcome = RunOutcome::default();
    let mut failures: Vec<(usize, String)> = Vec::new();
    for (rank, h) in handles {
        match h.join() {
            Ok(Ok(res)) => {
                outcome.checksum.merge(res.checksum);
                outcome.stats.absorb(&res.stats);
            }
            Ok(Err(e)) => failures.push((rank, format!("{e:#}"))),
            Err(payload) => failures.push((rank, format!("panicked: {}", panic_message(&*payload)))),
        }
    }
    if !failures.is_empty() {
        return Err(RunError { ranks: failures }.into());
    }
    if let Some(c) = &ckpt {
        outcome.stats.ckpt_writes += c.writes();
        outcome.stats.ckpt_bytes += c.bytes_written();
        outcome.stats.ckpt_skipped += c.skipped();
        outcome.stats.ckpt_replayed += c.replayed();
        outcome.stats.ckpt_errors += c.write_errors();
    }
    if let Some(p) = &opts.faults {
        outcome.stats.faults_injected += p.injected() - faults_before;
    }
    outcome.stats.t_total = t0.elapsed().as_secs_f64();
    // Worker-pool dispatch deltas for this run (see RunStats docs for
    // the concurrent-runs caveat). threads_spawned > 0 only while the
    // global pool is still growing to its high-water parallelism —
    // a warm process does zero spawns per kernel call.
    let pool_after = crate::linalg::pool::stats();
    outcome.stats.pool_scopes = pool_after.scopes - pool_before.scopes;
    outcome.stats.pool_tasks = pool_after.tasks - pool_before.tasks;
    outcome.stats.pool_threads_spawned = pool_after.threads_spawned - pool_before.threads_spawned;
    // Genotype-ingest deltas (decode happens inside the node threads'
    // input phase, between t0 and the joins above).
    outcome.stats.geno_calls = crate::vecdata::geno::calls_decoded() - geno_before.0;
    outcome.stats.geno_missing = crate::vecdata::geno::missing_calls() - geno_before.1;
    outcome.stats.pack2_calls = crate::vecdata::geno::pack2_calls() - geno_before.2;
    // The absorbed per-node sent totals must reproduce the fabric's own
    // accounting exactly — if they diverge, a node program forgot to
    // record its endpoint counts (see tests/comm_accounting.rs).
    debug_assert_eq!(
        outcome.stats.comm_bytes,
        counters.bytes.load(std::sync::atomic::Ordering::Relaxed)
    );
    debug_assert_eq!(
        outcome.stats.comm_messages,
        counters.messages.load(std::sync::atomic::Ordering::Relaxed)
    );
    // The sink owns result delivery end-to-end, including the run.meta
    // sidecar (FileSink writes it next to its metric files; everything
    // else no-ops).
    sink.on_run_complete(cfg, metric.preferred_repr(), backend.diag_kernel(), &outcome.stats)?;
    Ok(outcome)
}

/// Load or generate the vector block for slab `pv` (all its columns,
/// the node's feature slice if npf > 1).
pub(crate) fn load_block<T: Scalar>(
    cfg: &RunConfig,
    pv: usize,
    pf: usize,
) -> Result<VectorSet<T>> {
    let vparts = Partition::new(cfg.nv, cfg.grid.npv);
    let first = vparts.start(pv);
    let ncols = vparts.len(pv);
    let full = match &cfg.input {
        InputSource::Synthetic { kind, seed } => {
            VectorSet::<T>::generate(*kind, *seed, cfg.nf, ncols, first)
        }
        InputSource::File { path } => {
            vio::read_raw_cols::<T>(std::path::Path::new(path), cfg.nf, cfg.nv, first, ncols)?
        }
        // Genotype readers decode the node's column span to 2-bit codes
        // (missing → dosage 0) and expand to floats here; packed-repr
        // metrics re-pack once at ingest, float metrics use the floats
        // directly — load stays representation-agnostic either way.
        InputSource::Bed { path } => {
            let p = std::path::Path::new(path);
            crate::vecdata::geno::read_bed_cols(p, cfg.nf, cfg.nv, first, ncols)?.to_floats()
        }
        InputSource::Vcf { path } => {
            let p = std::path::Path::new(path);
            crate::vecdata::geno::read_vcf_cols(p, cfg.nf, cfg.nv, first, ncols)?.to_floats()
        }
    };
    if cfg.grid.npf > 1 {
        let fparts = Partition::new(cfg.nf, cfg.grid.npf);
        let mut sliced = full.feature_slice(fparts.start(pf), fparts.len(pf));
        sliced.first_id = first;
        Ok(sliced)
    } else {
        Ok(full)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vecdata::SyntheticKind;

    #[test]
    fn load_block_columns_match_global_generation() {
        let cfg = RunConfig {
            nv: 20,
            nf: 16,
            grid: crate::decomp::Grid::new(1, 4, 1),
            ..Default::default()
        };
        let all: VectorSet<f64> = VectorSet::generate(SyntheticKind::RandomGrid, 1, 16, 20, 0);
        for pv in 0..4 {
            let block: VectorSet<f64> = load_block(&cfg, pv, 0).unwrap();
            assert_eq!(block.nv, 5);
            assert_eq!(block.first_id, pv * 5);
            for c in 0..5 {
                assert_eq!(block.col(c), all.col(pv * 5 + c));
            }
        }
    }

    #[test]
    fn load_block_feature_slicing() {
        let cfg = RunConfig {
            nv: 8,
            nf: 10,
            grid: crate::decomp::Grid::new(2, 2, 1),
            ..Default::default()
        };
        let b0: VectorSet<f64> = load_block(&cfg, 0, 0).unwrap();
        let b1: VectorSet<f64> = load_block(&cfg, 0, 1).unwrap();
        assert_eq!(b0.nf, 5);
        assert_eq!(b1.nf, 5);
        let full: VectorSet<f64> = VectorSet::generate(SyntheticKind::RandomGrid, 1, 10, 4, 0);
        for c in 0..4 {
            assert_eq!(b0.col(c), &full.col(c)[..5]);
            assert_eq!(b1.col(c), &full.col(c)[5..]);
        }
    }
}
