//! Algorithms 2 + 3 — the 3-way metrics node program.
//!
//! Structure per the paper (§4.2): an outer communication pipeline
//! circulates vector blocks around the ring; owned slices (diagonal
//! edge / face / volume, `decomp::three_way`) then run the inner GPU
//! pipeline (Algorithm 3): three 2-way mGEMM tables + a pivot-batched
//! sequence of 3-way slabs, optionally cut into n_st stages. The
//! coordinator assembles c3 from Eq. (1):
//!   c3 = (3/2)(n2_ij + n2_ik + n2_jk − n3') / (Σv_i + Σv_j + Σv_k).
//!
//! Own blocks come from the run's
//! [`crate::coordinator::BlockProvider`]; assembled values leave as
//! [`Tile`]s through the node's [`NodeSink`], one tile per pivot chunk
//! (the natural "finished work" unit of Algorithm 3's inner pipeline).

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::checksum::Checksum;
use crate::comm::{Endpoint, Payload};
use crate::config::RunConfig;
use crate::coordinator::checkpoint::{self, RunCheckpoint};
use crate::coordinator::{backend::Backend, BlockProvider, NodeResult, ProvideBlocks, RunStats};
use crate::decomp::three_way::{stripe_pivots, Combo3};
use crate::decomp::{partition::Partition, three_way, NodeCoord};
use crate::linalg::MatF64;
use crate::metrics::{store::TripleEntry, Metric};
use crate::output::sink::{NodeSink, Tile};
use crate::util::{timer::Stopwatch, Scalar};
use crate::vecdata::block::Block;

const TAG_BLOCK3: u64 = 5_000;
const TAG_SUMS3: u64 = 6_000;

#[allow(clippy::too_many_arguments)]
pub(crate) fn node_main<T: Scalar + ProvideBlocks>(
    cfg: &RunConfig,
    coord: NodeCoord,
    mut ep: Endpoint,
    backend: Arc<dyn Backend<T>>,
    metric: Arc<dyn Metric<T>>,
    provider: Arc<dyn BlockProvider>,
    mut sink: Option<Box<dyn NodeSink>>,
    ckpt: Option<Arc<RunCheckpoint>>,
) -> Result<NodeResult> {
    let grid = cfg.grid;
    let (pv, pr) = (coord.pv, coord.pr);
    let npv = grid.npv;
    let mut stats = RunStats::default();
    let mut checksum = Checksum::with_salt(metric.checksum_salt());
    let mut t_in = Stopwatch::new();
    let mut t_comp = Stopwatch::new();
    let mut t_out = Stopwatch::new();

    // --- Input phase -----------------------------------------------------
    t_in.start();
    // Provider hands back the metric's preferred representation,
    // ingest-once when a session cache sits behind it (3-way metrics
    // are float families today, but the node program stays
    // representation-agnostic like the 2-way one).
    // Re-hint the node's own key (idempotent after the run-level
    // schedule hint; keeps serial/direct callers pipeline-friendly).
    provider.prefetch(cfg, &[(pv, 0)]);
    let own = T::provide(provider.as_ref(), cfg, metric.as_ref(), pv, 0)?;
    let own_sums = metric.denominators(&own)?;
    t_in.stop();

    // Which peer blocks this node's slices need.
    let slices = three_way::slices_for_node(npv, grid.npr, pv, pr);
    let mut needed: std::collections::HashSet<usize> = std::collections::HashSet::new();
    for s in &slices {
        match s.combo {
            Combo3::Diag => {}
            Combo3::Face { other } => {
                needed.insert(other);
            }
            Combo3::Volume { b, c } => {
                needed.insert(b);
                needed.insert(c);
            }
        }
    }

    // --- Outer communication pipeline (Algorithm 2's ring) ---------------
    // Circulate own block (in its wire representation, converted once);
    // keep the peers our slices reference. Sums are small and always
    // kept.
    t_comp.start();
    let wire = own.to_wire();
    let sums_wire = Arc::new(own_sums.clone());
    let mut blocks: HashMap<usize, Block<T>> = HashMap::new();
    let mut sums: HashMap<usize, Arc<Vec<f64>>> = HashMap::new();
    blocks.insert(pv, own);
    sums.insert(pv, Arc::new(own_sums));
    for d in 1..npv {
        let to = grid.rank(NodeCoord { pf: 0, pv: (pv + npv - d) % npv, pr });
        let from_pv = (pv + d) % npv;
        let from = grid.rank(NodeCoord { pf: 0, pv: from_pv, pr });
        let payload = Payload::Block {
            nf: cfg.nf,
            nv: blocks[&pv].nv(),
            first_id: blocks[&pv].first_id(),
            data: wire.clone(),
        };
        let got = ep.sendrecv(to, from, TAG_BLOCK3 + d as u64, payload)?;
        let Payload::Block { nf, nv, first_id, data } = got else {
            bail!("expected Block payload");
        };
        let got_sums =
            ep.sendrecv(to, from, TAG_SUMS3 + d as u64, Payload::Sums(Arc::clone(&sums_wire)))?;
        let Payload::Sums(ps) = got_sums else {
            bail!("expected Sums payload");
        };
        sums.insert(from_pv, ps);
        if needed.contains(&from_pv) {
            blocks.insert(from_pv, Block::<T>::from_wire(nf, nv, first_id, &data)?);
        }
    }

    // --- Inner pipeline per slice (Algorithm 3) ---------------------------
    let vparts = Partition::new(cfg.nv, npv);
    let stages: Vec<usize> = match cfg.stage {
        Some(s) => vec![s],
        None => (0..cfg.num_stage).collect(),
    };
    // Cache of 2-way numerator tables, keyed by ordered block pair.
    // Self-pair tables (a == b) are only ever read at i < j below
    // (Diag's i < j_local < k, Face's i1 < i2), so they go through the
    // metric's symmetry-halved diagonal kernel.
    let mut n2_cache: HashMap<(usize, usize), Arc<MatF64>> = HashMap::new();
    let mut n2_table = |a: usize,
                        b: usize,
                        blocks: &HashMap<usize, Block<T>>,
                        stats: &mut RunStats|
     -> Result<Arc<MatF64>> {
        let key = (a.min(b), a.max(b));
        if let Some(m) = n2_cache.get(&key) {
            return Ok(Arc::clone(m));
        }
        let m = Arc::new(if key.0 == key.1 {
            metric.numerators2_diag(backend.as_ref(), &blocks[&key.0])?
        } else {
            metric.numerators2(backend.as_ref(), &blocks[&key.0], &blocks[&key.1])?
        });
        stats.mgemm2_calls += 1;
        n2_cache.insert(key, Arc::clone(&m));
        Ok(m)
    };
    // n2 lookup with transpose handling: value for (block x, local i) vs
    // (block y, local j) from the ordered table.
    let n2_at = |tab: &MatF64, x: usize, i: usize, y: usize, j: usize| -> f64 {
        if x <= y {
            tab.at(i, j)
        } else {
            tab.at(j, i)
        }
    };

    // Checkpoint units: one per (slice, stage, pivot chunk), numbered
    // in this rank's deterministic traversal order (3-way runs pin
    // npf = 1, so units are rank-private — no cross-rank coupling).
    let mut unit_no: u64 = 0;
    for slice in &slices {
        let (b_pivot, b_right) = match slice.combo {
            Combo3::Diag => (pv, pv),
            Combo3::Face { other } => (other, pv),
            Combo3::Volume { b, c } => (b, c),
        };
        let a_blk = blocks[&pv].clone();
        let p_blk = blocks[&b_pivot].clone();
        let r_blk = blocks[&b_right].clone();
        let s_a = Arc::clone(&sums[&pv]);
        let s_p = Arc::clone(&sums[&b_pivot]);
        let s_r = Arc::clone(&sums[&b_right]);
        // The three 2-way tables of Algorithm 3 — built lazily on the
        // first *live* chunk, so a fully-checkpointed slice skips its
        // table mGEMMs along with its slabs.
        let mut tables: Option<(Arc<MatF64>, Arc<MatF64>, Arc<MatF64>)> = None;

        let jt_max = backend.pivot_batch_for(a_blk.nf(), a_blk.nv().max(r_blk.nv()));
        for &stage in &stages {
            let pivots: Vec<usize> =
                stripe_pivots(p_blk.nv(), slice.sub, cfg.num_stage, stage).collect();
            for chunk in pivots.chunks(jt_max) {
                let unit = ckpt.as_deref().map(|c| (c, format!("n{}-u{unit_no}", ep.rank)));
                unit_no += 1;
                if let Some((c, u)) = &unit {
                    if c.is_done(u) {
                        c.note_skip();
                        let tiles = c.load(u)?;
                        checkpoint::replay_tiles(tiles, &mut checksum, &mut stats, &mut sink)?;
                        continue;
                    }
                }
                let (t_ap, t_ar, t_pr) = match tables.as_ref() {
                    Some((a, b, c)) => (Arc::clone(a), Arc::clone(b), Arc::clone(c)),
                    None => {
                        let t = (
                            n2_table(pv, b_pivot, &blocks, &mut stats)?,
                            n2_table(pv, b_right, &blocks, &mut stats)?,
                            n2_table(b_pivot, b_right, &blocks, &mut stats)?,
                        );
                        tables = Some((Arc::clone(&t.0), Arc::clone(&t.1), Arc::clone(&t.2)));
                        t
                    }
                };
                let pivot_set = p_blk.select_cols(chunk)?;
                // Diag slices read only slab[t, i, k] with
                // i < chunk[t] < k, so the diag-aware slab kernel skips
                // the redundant sub-slices entirely.
                let slab = if matches!(slice.combo, Combo3::Diag) {
                    metric.numerators3_diag(backend.as_ref(), &a_blk, &pivot_set, chunk)?
                } else {
                    metric.numerators3(backend.as_ref(), &a_blk, &pivot_set, &r_blk)?
                };
                stats.mgemm3_calls += 1;
                // One result tile per pivot chunk, entries in emission
                // order.
                let want_tile = sink.is_some() || unit.is_some();
                let mut entries: Vec<TripleEntry> = Vec::new();
                for (t, &j_local) in chunk.iter().enumerate() {
                    let gj = vparts.start(b_pivot) + j_local;
                    match slice.combo {
                        Combo3::Volume { .. } => {
                            for i in 0..a_blk.nv() {
                                let gi = vparts.start(pv) + i;
                                for k in 0..r_blk.nv() {
                                    let gk = vparts.start(b_right) + k;
                                    let c3 = metric.combine3(
                                        n2_at(&t_ap, pv, i, b_pivot, j_local),
                                        n2_at(&t_ar, pv, i, b_right, k),
                                        n2_at(&t_pr, b_pivot, j_local, b_right, k),
                                        slab.at(t, i, k),
                                        s_a[i],
                                        s_p[j_local],
                                        s_r[k],
                                    );
                                    emit3(
                                        gi, gj, gk, c3, &mut checksum, &mut stats, want_tile,
                                        &mut entries,
                                    );
                                }
                            }
                        }
                        Combo3::Face { .. } => {
                            // (i1 < i2) ∈ own block, pivot j ∈ other.
                            for i1 in 0..a_blk.nv() {
                                let g1 = vparts.start(pv) + i1;
                                for i2 in (i1 + 1)..a_blk.nv() {
                                    let g2 = vparts.start(pv) + i2;
                                    let c3 = metric.combine3(
                                        n2_at(&t_ar, pv, i1, pv, i2),
                                        n2_at(&t_ap, pv, i1, b_pivot, j_local),
                                        n2_at(&t_ap, pv, i2, b_pivot, j_local),
                                        slab.at(t, i1, i2),
                                        s_a[i1],
                                        s_a[i2],
                                        s_p[j_local],
                                    );
                                    emit3(
                                        g1, g2, gj, c3, &mut checksum, &mut stats, want_tile,
                                        &mut entries,
                                    );
                                }
                            }
                        }
                        Combo3::Diag => {
                            // i < j_local < k, all in own block.
                            for i in 0..j_local {
                                let gi = vparts.start(pv) + i;
                                for k in (j_local + 1)..a_blk.nv() {
                                    let gk = vparts.start(pv) + k;
                                    let c3 = metric.combine3(
                                        t_ap.at(i, j_local),
                                        t_ap.at(i, k),
                                        t_ap.at(j_local, k),
                                        slab.at(t, i, k),
                                        s_a[i],
                                        s_a[j_local],
                                        s_a[k],
                                    );
                                    emit3(
                                        gi, gj, gk, c3, &mut checksum, &mut stats, want_tile,
                                        &mut entries,
                                    );
                                }
                            }
                        }
                    }
                }
                if want_tile {
                    let tile = Tile::Triples { metric: metric.id(), entries };
                    // Persist first (unit durable before delivery; the
                    // order-independent checksum makes replay-after-
                    // delivery harmless), then hand to the sink.
                    if let Some((c, u)) = &unit {
                        t_out.start();
                        c.save(u, std::slice::from_ref(&tile));
                        t_out.stop();
                    }
                    if let Some(s) = sink.as_mut() {
                        if !tile.is_empty() {
                            t_out.start();
                            s.tile(tile)?;
                            t_out.stop();
                            stats.tiles += 1;
                        }
                    }
                }
            }
        }
    }
    t_comp.stop();

    if let Some(mut s) = sink.take() {
        t_out.start();
        s.finish()?;
        t_out.stop();
    }
    stats.t_input = t_in.secs();
    stats.t_compute = t_comp.secs() - t_out.secs();
    stats.t_output = t_out.secs();
    // Per-node comm accounting: RunStats::absorb sums these across
    // nodes to reproduce the cluster totals. Retransmits/corruptions
    // ride along so the ledger prices fault recovery.
    (stats.comm_messages, stats.comm_bytes) = ep.sent();
    stats.comm_retries = ep.retransmits();
    stats.comm_corrupt = ep.corrupt_detected();
    Ok(NodeResult { checksum, stats })
}

/// Canonicalize and record one assembled 3-way value: checksum + stats
/// always; a tile entry only when a sink is listening.
#[allow(clippy::too_many_arguments)]
fn emit3(
    a: usize,
    b: usize,
    c: usize,
    value: f64,
    checksum: &mut Checksum,
    stats: &mut RunStats,
    want_tile: bool,
    entries: &mut Vec<TripleEntry>,
) {
    let mut t = [a, b, c];
    t.sort_unstable();
    let (i, j, k) = (t[0], t[1], t[2]);
    debug_assert!(i < j && j < k, "degenerate triple ({a},{b},{c})");
    checksum.add_triple(i, j, k, value);
    stats.metrics += 1;
    if want_tile {
        entries.push(TripleEntry {
            i: i as u32,
            j: j as u32,
            k: k as u32,
            value,
        });
    }
}
