//! Checkpoint/resume for coordinated runs.
//!
//! A [`CheckpointStore`] persists each node program's completed work
//! units — one blob per unit, holding the unit's emitted [`Tile`]s in
//! the bit-exact wire encoding — through the same [`BlockStore`]
//! abstraction the out-of-core spill path uses (`--checkpoint-dir` →
//! [`DirStore`], tests → [`MemStore`]). A resumed run re-executes its
//! communication schedule unconditionally (every rank takes the same
//! skip decisions, so the lockstep exchanges stay paired), skips the
//! numerator kernels and assembly of completed units, and **replays**
//! their persisted tiles through the checksum and sink — the §5
//! checksum is order-independent, so a resumed campaign is
//! bit-identical to an uninterrupted one.
//!
//! ## Key scheme
//!
//! Units are keyed `{run-prefix}-{unit}`, filename-safe, where the run
//! prefix spells out the full run identity in clear text —
//! `ck-<metric>-w<way>-<nv>x<nf>-<precision>-<backend>-t<threads>-`
//! `g<npf>x<npv>x<npr>-s<num_stage>.<stage|all>-i<hash>` — and
//! `<hash>` is an FNV-64 over the canonical input description
//! (synthetic kind + seed, or file path) and the metric's parameterized
//! ingest key. Everything that changes a run's results is in the key,
//! so two different campaigns can share one checkpoint directory
//! without collisions. The hash is [`fnv1a64`] over canonical strings —
//! **not** `DefaultHasher`, whose output is not stable across
//! processes, which would silently defeat resume. Unit suffixes:
//! `v<pv>-r<pr>-u<Δ>` for 2-way steps (shared across the npf axis, so
//! reduction groups skip consistently) and `n<rank>-u<seq>` for 3-way
//! pivot chunks.
//!
//! ## Blob format
//!
//! `"COMETCK1" · count:u64le · count wire frames · fnv1a64 trailer`
//! over everything before the trailer. [`DirStore`] writes are
//! temp-then-rename, so a crash mid-write never leaves a truncated
//! blob under a real key; a blob that fails validation anyway (external
//! tampering) surfaces as a typed error rather than silently
//! recomputing on one rank but not another.

use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{bail, ensure, Context, Result};

use crate::checksum::Checksum;
use crate::config::{InputSource, RunConfig};
use crate::coordinator::RunStats;
use crate::output::sink::{NodeSink, Tile};
use crate::vecdata::oocstore::{fnv1a64, with_retry, BlockStore, DirStore, MemStore};

/// Magic prefix of a checkpoint blob (8 bytes, versioned by rename).
pub const CKPT_MAGIC: &[u8; 8] = b"COMETCK1";

/// A campaign-scoped checkpoint area. Cheap to clone-share via `Arc`;
/// each run derives its own keyspace with [`CheckpointStore::for_run`].
pub struct CheckpointStore {
    store: Arc<dyn BlockStore>,
}

impl CheckpointStore {
    /// Checkpoints under `dir` (created on first write, never removed
    /// by this process — resume depends on it surviving).
    pub fn dir(dir: impl AsRef<Path>) -> Self {
        CheckpointStore { store: Arc::new(DirStore::new(dir.as_ref().to_path_buf())) }
    }

    /// In-memory checkpoints — tests and rigs.
    pub fn mem() -> Self {
        CheckpointStore { store: Arc::new(MemStore::new()) }
    }

    /// Over an arbitrary block store (fault rigs wrap `FailingStore`).
    pub fn with_store(store: Arc<dyn BlockStore>) -> Self {
        CheckpointStore { store }
    }

    /// This run's view of the checkpoint area: its key prefix plus
    /// fresh per-run counters for the ledger.
    pub fn for_run(&self, cfg: &RunConfig, ingest_key: u64) -> RunCheckpoint {
        RunCheckpoint {
            store: Arc::clone(&self.store),
            prefix: run_prefix(cfg, ingest_key),
            writes: AtomicU64::new(0),
            bytes: AtomicU64::new(0),
            skipped: AtomicU64::new(0),
            replayed: AtomicU64::new(0),
            write_errors: AtomicU64::new(0),
        }
    }
}

/// The cross-process-stable run identity (see module docs).
fn run_prefix(cfg: &RunConfig, ingest_key: u64) -> String {
    let input = match &cfg.input {
        InputSource::Synthetic { kind, seed } => format!("synthetic.{kind:?}.{seed}"),
        InputSource::File { path } => format!("file.{path}"),
    };
    let ident = fnv1a64(format!("{input}|ik{ingest_key:016x}").as_bytes());
    let stage = cfg.stage.map(|s| s.to_string()).unwrap_or_else(|| "all".into());
    format!(
        "ck-{}-w{}-{}x{}-{}-{}-t{}-g{}x{}x{}-s{}.{}-i{:016x}",
        cfg.metric.name(),
        cfg.num_way,
        cfg.nv,
        cfg.nf,
        cfg.precision.tag(),
        cfg.backend.name(),
        cfg.threads,
        cfg.grid.npf,
        cfg.grid.npv,
        cfg.grid.npr,
        cfg.num_stage,
        stage,
        ident,
    )
}

/// One run's checkpoint handle, shared (`Arc`) across its node threads.
pub struct RunCheckpoint {
    store: Arc<dyn BlockStore>,
    prefix: String,
    writes: AtomicU64,
    bytes: AtomicU64,
    skipped: AtomicU64,
    replayed: AtomicU64,
    write_errors: AtomicU64,
}

impl RunCheckpoint {
    fn key(&self, unit: &str) -> String {
        format!("{}-{}", self.prefix, unit)
    }

    /// Whether `unit` completed in a previous run. Stored blobs are
    /// immutable-once-written, so every rank probing the same unit key
    /// reaches the same verdict — the property that keeps coupled
    /// reduction groups from diverging into a deadlock.
    pub fn is_done(&self, unit: &str) -> bool {
        self.store.contains(&self.key(unit))
    }

    /// Count one unit skipped on resume (each skipping rank counts).
    pub fn note_skip(&self) {
        self.skipped.fetch_add(1, Ordering::Relaxed);
    }

    /// Persist a completed unit's tiles. Best-effort under the shared
    /// retry policy: a write that still fails is *counted*, not fatal —
    /// the run proceeds and that unit simply recomputes on resume.
    pub fn save(&self, unit: &str, tiles: &[Tile]) {
        let mut buf = Vec::with_capacity(32 + tiles.iter().map(|t| 16 * t.len()).sum::<usize>());
        buf.extend_from_slice(CKPT_MAGIC);
        buf.extend_from_slice(&(tiles.len() as u64).to_le_bytes());
        for t in tiles {
            buf.extend_from_slice(&t.encode());
        }
        let sum = fnv1a64(&buf);
        buf.extend_from_slice(&sum.to_le_bytes());
        let key = self.key(unit);
        match with_retry(|| self.store.put(&key, &buf)) {
            Ok(()) => {
                self.writes.fetch_add(1, Ordering::Relaxed);
                self.bytes.fetch_add(buf.len() as u64, Ordering::Relaxed);
            }
            Err(_) => {
                self.write_errors.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Load a completed unit's tiles for replay. A missing or invalid
    /// blob after [`RunCheckpoint::is_done`] said yes is a hard, typed
    /// error: treating it as "not done" on one rank while peers skipped
    /// would desynchronize coupled reduction groups.
    pub fn load(&self, unit: &str) -> Result<Vec<Tile>> {
        let key = self.key(unit);
        let bytes = with_retry(|| self.store.get(&key))
            .map_err(|e| anyhow::anyhow!("checkpoint read {key}: {e}"))?
            .with_context(|| format!("checkpoint unit {key} vanished between probe and load"))?;
        let tiles = decode_blob(&bytes).with_context(|| format!("checkpoint unit {key}"))?;
        let values: u64 = tiles.iter().map(|t| t.len() as u64).sum();
        self.replayed.fetch_add(values, Ordering::Relaxed);
        Ok(tiles)
    }

    pub fn writes(&self) -> u64 {
        self.writes.load(Ordering::Relaxed)
    }
    pub fn bytes_written(&self) -> u64 {
        self.bytes.load(Ordering::Relaxed)
    }
    pub fn skipped(&self) -> u64 {
        self.skipped.load(Ordering::Relaxed)
    }
    pub fn replayed(&self) -> u64 {
        self.replayed.load(Ordering::Relaxed)
    }
    pub fn write_errors(&self) -> u64 {
        self.write_errors.load(Ordering::Relaxed)
    }
}

fn decode_blob(bytes: &[u8]) -> Result<Vec<Tile>> {
    ensure!(bytes.len() >= CKPT_MAGIC.len() + 8 + 8, "blob truncated ({} bytes)", bytes.len());
    ensure!(&bytes[..8] == CKPT_MAGIC, "bad magic (not a checkpoint blob)");
    let (body, trailer) = bytes.split_at(bytes.len() - 8);
    let stored = u64::from_le_bytes(trailer.try_into().unwrap());
    ensure!(fnv1a64(body) == stored, "payload checksum mismatch (corrupt blob)");
    let count = u64::from_le_bytes(body[8..16].try_into().unwrap());
    let mut tiles = Vec::with_capacity(count as usize);
    let mut rest = &body[16..];
    for i in 0..count {
        ensure!(rest.len() >= 4, "tile {i}: missing frame length");
        let len = u32::from_le_bytes(rest[..4].try_into().unwrap()) as usize;
        ensure!(rest.len() >= 4 + len, "tile {i}: frame truncated");
        tiles.push(Tile::decode(&rest[..4 + len]).with_context(|| format!("tile {i}"))?);
        rest = &rest[4 + len..];
    }
    if !rest.is_empty() {
        bail!("{} trailing byte(s) after the last tile", rest.len());
    }
    Ok(tiles)
}

/// Replay persisted tiles exactly as the live path would have emitted
/// them: every value into the (order-independent) checksum and the
/// metric counter; non-empty tiles into the sink with the tile counter.
pub(crate) fn replay_tiles(
    tiles: Vec<Tile>,
    checksum: &mut Checksum,
    stats: &mut RunStats,
    sink: &mut Option<Box<dyn NodeSink>>,
) -> Result<()> {
    for tile in tiles {
        match &tile {
            Tile::Pairs { entries, .. } => {
                for e in entries {
                    checksum.add_pair(e.i as usize, e.j as usize, e.value);
                    stats.metrics += 1;
                }
            }
            Tile::Triples { entries, .. } => {
                for e in entries {
                    checksum.add_triple(e.i as usize, e.j as usize, e.k as usize, e.value);
                    stats.metrics += 1;
                }
            }
        }
        if let Some(s) = sink.as_mut() {
            if !tile.is_empty() {
                s.tile(tile)?;
                stats.tiles += 1;
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::store::PairEntry;
    use crate::metrics::MetricId;

    fn cfg() -> RunConfig {
        RunConfig::default()
    }

    #[test]
    fn save_then_load_round_trips_tiles_bit_exactly() {
        let store = CheckpointStore::mem();
        let run = store.for_run(&cfg(), 7);
        let tile = Tile::Pairs {
            metric: MetricId::Czekanowski,
            entries: vec![
                PairEntry { i: 0, j: 1, value: 0.25 },
                PairEntry { i: 3, j: 9, value: f64::from_bits(0x7ff8_0000_0000_1234) },
            ],
        };
        assert!(!run.is_done("v0-r0-u0"));
        run.save("v0-r0-u0", std::slice::from_ref(&tile));
        assert!(run.is_done("v0-r0-u0"));
        let back = run.load("v0-r0-u0").unwrap();
        assert_eq!(back.len(), 1);
        match (&back[0], &tile) {
            (Tile::Pairs { entries: a, .. }, Tile::Pairs { entries: b, .. }) => {
                assert_eq!(a.len(), b.len());
                for (x, y) in a.iter().zip(b) {
                    assert_eq!((x.i, x.j), (y.i, y.j));
                    assert_eq!(x.value.to_bits(), y.value.to_bits());
                }
            }
            _ => panic!("tile kind changed in round trip"),
        }
        assert_eq!(run.writes(), 1);
        assert_eq!(run.replayed(), 2);
        // Empty units persist as empty blobs, not absent keys.
        run.save("v0-r0-u1", &[]);
        assert!(run.is_done("v0-r0-u1"));
        assert!(run.load("v0-r0-u1").unwrap().is_empty());
    }

    #[test]
    fn keys_discriminate_everything_that_changes_results() {
        let store = CheckpointStore::mem();
        let base = store.for_run(&cfg(), 0).prefix;
        // Every field that changes a run's output must change its key.
        let variants = [
            RunConfig { nv: 512, ..cfg() },
            RunConfig { metric: MetricId::Sorenson, ..cfg() },
            RunConfig { precision: crate::config::Precision::F32, ..cfg() },
            RunConfig { threads: 2, ..cfg() },
            RunConfig { grid: crate::decomp::Grid::new(1, 2, 1), ..cfg() },
            RunConfig {
                input: InputSource::Synthetic {
                    kind: crate::vecdata::SyntheticKind::RandomGrid,
                    seed: 2,
                },
                ..cfg()
            },
            RunConfig { input: InputSource::File { path: "/data/x.bin".into() }, ..cfg() },
        ];
        for v in &variants {
            assert_ne!(store.for_run(v, 0).prefix, base, "{v:?}");
        }
        // Parameterized ingests (e.g. sparsity thresholds) key too.
        assert_ne!(store.for_run(&cfg(), 1).prefix, base);
        // Keys stay filename-safe for DirStore.
        for c in store.for_run(&cfg(), 0).key("v0-r0-u0").chars() {
            assert!(c.is_ascii_alphanumeric() || "._-".contains(c), "unsafe key char {c:?}");
        }
    }

    #[test]
    fn corrupt_blobs_surface_typed_errors_not_silent_recompute() {
        let mem = Arc::new(MemStore::new());
        let store = CheckpointStore::with_store(Arc::clone(&mem) as Arc<dyn BlockStore>);
        let run = store.for_run(&cfg(), 0);
        run.save("v0-r0-u0", &[Tile::Pairs { metric: MetricId::Ccc, entries: vec![] }]);
        let key = mem.keys().pop().unwrap();
        // Flip the last payload byte (the testkit poison idiom).
        let mut bytes = mem.get(&key).unwrap().unwrap();
        let last = bytes.len() - 9; // inside the body, not the trailer
        bytes[last] ^= 0xff;
        mem.put(&key, &bytes).unwrap();
        let err = run.load("v0-r0-u0").unwrap_err();
        assert!(format!("{err:#}").contains("checksum mismatch"), "{err:#}");
        // Truncation and bad magic are equally loud.
        mem.put(&key, CKPT_MAGIC).unwrap();
        assert!(run.load("v0-r0-u0").is_err());
        mem.put(&key, b"NOTMAGIC________________").unwrap();
        assert!(format!("{:#}", run.load("v0-r0-u0").unwrap_err()).contains("magic"));
    }

    #[test]
    fn replay_reproduces_live_emission_accounting() {
        let tiles = vec![
            Tile::Pairs {
                metric: MetricId::Czekanowski,
                entries: vec![PairEntry { i: 1, j: 2, value: 0.5 }],
            },
            Tile::Pairs { metric: MetricId::Czekanowski, entries: vec![] },
        ];
        // Live reference: same values pushed by hand.
        let mut live = Checksum::default();
        live.add_pair(1, 2, 0.5);
        let mut replayed = Checksum::default();
        let mut stats = RunStats::default();
        let mut sink: Option<Box<dyn NodeSink>> = None;
        replay_tiles(tiles, &mut replayed, &mut stats, &mut sink).unwrap();
        assert_eq!(replayed.digest(), live.digest());
        assert_eq!(stats.metrics, 1);
        assert_eq!(stats.tiles, 0, "no sink, no tile pushes");
    }
}
