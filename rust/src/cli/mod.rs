//! Minimal command-line parsing (no clap offline): `--key value` /
//! `--flag` options plus positionals, with typed accessors and
//! did-you-mean-free but precise error messages.

use std::collections::{HashMap, HashSet};

use anyhow::{anyhow, bail, Result};

/// Parsed argument list.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    values: HashMap<String, String>,
    switches: HashSet<String>,
    /// Keys consumed by accessors (for unknown-flag detection).
    seen: std::cell::RefCell<HashSet<String>>,
}

/// Parse `argv[1..]`. An option is `--key value` unless `value` starts
/// with `--` or is absent, in which case it is a boolean switch.
pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args> {
    let mut args = Args::default();
    let mut iter = argv.into_iter().peekable();
    while let Some(a) = iter.next() {
        if let Some(key) = a.strip_prefix("--") {
            if key.is_empty() {
                bail!("stray `--`");
            }
            let next_is_value = iter.peek().is_some_and(|n| !n.starts_with("--"));
            if next_is_value {
                let v = iter.next().unwrap();
                if args.values.insert(key.to_string(), v).is_some() {
                    bail!("duplicate option --{key}");
                }
            } else {
                args.switches.insert(key.to_string());
            }
        } else {
            args.positional.push(a);
        }
    }
    Ok(args)
}

impl Args {
    pub fn switch(&self, key: &str) -> bool {
        self.seen.borrow_mut().insert(key.to_string());
        self.switches.contains(key)
    }

    pub fn opt_str(&self, key: &str) -> Option<&str> {
        self.seen.borrow_mut().insert(key.to_string());
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.opt_str(key).unwrap_or(default).to_string()
    }

    pub fn require_str(&self, key: &str) -> Result<String> {
        self.opt_str(key)
            .map(|s| s.to_string())
            .ok_or_else(|| anyhow!("missing required option --{key}"))
    }

    pub fn opt_parse<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        match self.opt_str(key) {
            None => Ok(None),
            Some(s) => s
                .parse::<T>()
                .map(Some)
                .map_err(|e| anyhow!("--{key} {s:?}: {e}")),
        }
    }

    pub fn parse_or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        Ok(self.opt_parse(key)?.unwrap_or(default))
    }

    /// Error on options that no accessor consulted (typo protection).
    pub fn reject_unknown(&self) -> Result<()> {
        let seen = self.seen.borrow();
        let unknown: Vec<&String> = self
            .values
            .keys()
            .chain(self.switches.iter())
            .filter(|k| !seen.contains(*k))
            .collect();
        if !unknown.is_empty() {
            bail!("unknown option(s): {unknown:?}");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        parse(s.split_whitespace().map(str::to_string)).unwrap()
    }

    #[test]
    fn values_switches_positionals() {
        let a = args("run --nv 100 --verbose --backend pjrt input.bin");
        assert_eq!(a.positional, vec!["run", "input.bin"]);
        assert_eq!(a.opt_str("nv"), Some("100"));
        assert!(a.switch("verbose"));
        assert!(!a.switch("quiet"));
        assert_eq!(a.str_or("backend", "cpu"), "pjrt");
    }

    #[test]
    fn typed_parsing() {
        let a = args("--nv 128 --frac 0.5");
        assert_eq!(a.parse_or::<usize>("nv", 1).unwrap(), 128);
        assert_eq!(a.parse_or::<f64>("frac", 0.0).unwrap(), 0.5);
        assert_eq!(a.parse_or::<usize>("missing", 7).unwrap(), 7);
        let bad = args("--nv abc");
        assert!(bad.parse_or::<usize>("nv", 1).is_err());
    }

    #[test]
    fn duplicate_rejected() {
        assert!(parse(["--x", "1", "--x", "2"].map(String::from)).is_err());
    }

    #[test]
    fn unknown_flag_detection() {
        let a = args("--known 1 --typo 2");
        let _ = a.opt_str("known");
        let err = a.reject_unknown().unwrap_err();
        assert!(err.to_string().contains("typo"));
    }

    #[test]
    fn missing_required() {
        let a = args("run");
        assert!(a.require_str("config").is_err());
    }
}
