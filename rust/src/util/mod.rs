//! Small in-tree substrates the offline environment forces us to own:
//! deterministic PRNG streams, stopwatches, human-readable rate
//! formatting, the shared transient-retry policy, and a generic scalar
//! trait shared by the f32/f64 paths.

pub mod fmt;
pub mod prng;
pub mod retry;
pub mod timer;

/// Scalar abstraction over the two precisions the paper evaluates
/// (single and double; compile-time in CoMet, runtime-selected here).
pub trait Scalar:
    Copy
    + PartialOrd
    + std::ops::Add<Output = Self>
    + std::ops::Sub<Output = Self>
    + std::ops::Mul<Output = Self>
    + std::ops::Div<Output = Self>
    + std::ops::AddAssign
    + Send
    + Sync
    + std::fmt::Debug
    + 'static
{
    const ZERO: Self;
    const ONE: Self;
    /// Bytes per element (4 or 8) — used for literal construction and
    /// the communication-volume accounting.
    const BYTES: usize;
    fn from_f64(x: f64) -> Self;
    fn to_f64(self) -> f64;
    /// Scalar min — the paper's "min-product" inner operation.
    #[inline]
    fn min_s(self, other: Self) -> Self {
        if self <= other {
            self
        } else {
            other
        }
    }
    /// Raw little-endian bytes (literal construction + checksums).
    fn to_bits_u64(self) -> u64;
    /// Decode one element from its little-endian byte image (safe
    /// file-reader path; `bytes.len()` must be `BYTES`).
    fn from_le_bytes(bytes: &[u8]) -> Self;
}

impl Scalar for f32 {
    const ZERO: Self = 0.0;
    const ONE: Self = 1.0;
    const BYTES: usize = 4;
    #[inline]
    fn from_f64(x: f64) -> Self {
        x as f32
    }
    #[inline]
    fn to_f64(self) -> f64 {
        self as f64
    }
    #[inline]
    fn to_bits_u64(self) -> u64 {
        self.to_bits() as u64
    }
    #[inline]
    fn from_le_bytes(bytes: &[u8]) -> Self {
        f32::from_le_bytes(bytes.try_into().expect("4-byte f32 image"))
    }
}

impl Scalar for f64 {
    const ZERO: Self = 0.0;
    const ONE: Self = 1.0;
    const BYTES: usize = 8;
    #[inline]
    fn from_f64(x: f64) -> Self {
        x
    }
    #[inline]
    fn to_f64(self) -> f64 {
        self
    }
    #[inline]
    fn to_bits_u64(self) -> u64 {
        self.to_bits()
    }
    #[inline]
    fn from_le_bytes(bytes: &[u8]) -> Self {
        f64::from_le_bytes(bytes.try_into().expect("8-byte f64 image"))
    }
}

/// Ceiling division for schedule arithmetic (`⌈a/b⌉`, paper §6.6/§6.7).
#[inline]
pub const fn ceil_div(a: usize, b: usize) -> usize {
    a.div_ceil(b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_min_matches_partialord() {
        assert_eq!(2.0f64.min_s(3.0), 2.0);
        assert_eq!(3.0f32.min_s(2.0), 2.0);
        assert_eq!(2.0f32.min_s(2.0), 2.0);
        assert_eq!(0.0f64.min_s(-1.0), -1.0);
    }

    #[test]
    fn scalar_roundtrip() {
        assert_eq!(f32::from_f64(0.5).to_f64(), 0.5);
        assert_eq!(f64::from_f64(0.25).to_f64(), 0.25);
        assert_eq!(f32::BYTES, 4);
        assert_eq!(f64::BYTES, 8);
    }

    #[test]
    fn ceil_div_cases() {
        assert_eq!(ceil_div(10, 3), 4);
        assert_eq!(ceil_div(9, 3), 3);
        assert_eq!(ceil_div(0, 3), 0);
        assert_eq!(ceil_div(1, 1), 1);
    }
}
