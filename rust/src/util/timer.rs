//! Stopwatches and a tiny repeated-measurement harness (criterion is not
//! available offline; the bench binaries use [`bench_run`]).

use std::time::{Duration, Instant};

/// Cumulative stopwatch for pipeline-phase accounting (the paper reports
/// input / metrics-comp / output phases separately, Table 5).
#[derive(Debug, Default, Clone)]
pub struct Stopwatch {
    total: Duration,
    started: Option<Instant>,
}

impl Stopwatch {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn start(&mut self) {
        debug_assert!(self.started.is_none(), "stopwatch already running");
        self.started = Some(Instant::now());
    }

    pub fn stop(&mut self) {
        if let Some(t0) = self.started.take() {
            self.total += t0.elapsed();
        }
    }

    /// Time a closure, accumulating into this stopwatch.
    pub fn time<T>(&mut self, f: impl FnOnce() -> T) -> T {
        self.start();
        let out = f();
        self.stop();
        out
    }

    pub fn secs(&self) -> f64 {
        let mut t = self.total;
        if let Some(t0) = self.started {
            t += t0.elapsed();
        }
        t.as_secs_f64()
    }
}

/// One measurement series from [`bench_run`].
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub label: String,
    pub iters: usize,
    pub secs: Vec<f64>,
}

impl BenchStats {
    pub fn min(&self) -> f64 {
        self.secs.iter().copied().fold(f64::INFINITY, f64::min)
    }
    pub fn mean(&self) -> f64 {
        self.secs.iter().sum::<f64>() / self.secs.len() as f64
    }
    pub fn median(&self) -> f64 {
        let mut s = self.secs.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        s[s.len() / 2]
    }
}

/// Minimal bench harness: `warmup` unmeasured runs then `iters` timed runs.
pub fn bench_run(label: &str, warmup: usize, iters: usize, mut f: impl FnMut()) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut secs = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        secs.push(t0.elapsed().as_secs_f64());
    }
    BenchStats {
        label: label.to_string(),
        iters,
        secs,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_accumulates() {
        let mut sw = Stopwatch::new();
        sw.time(|| std::thread::sleep(Duration::from_millis(5)));
        sw.time(|| std::thread::sleep(Duration::from_millis(5)));
        assert!(sw.secs() >= 0.009, "{}", sw.secs());
    }

    #[test]
    fn bench_run_counts() {
        let mut n = 0;
        let stats = bench_run("x", 2, 5, || n += 1);
        assert_eq!(n, 7);
        assert_eq!(stats.secs.len(), 5);
        assert!(stats.min() <= stats.mean());
        assert!(stats.median() >= stats.min());
    }
}
