//! Shared transient-retry policy with deterministic backoff.
//!
//! Hoisted out of `vecdata::oocstore` so the spill store and the
//! simulated comm fabric retry with **one** policy instead of two
//! drifting copies. The shape is classic exponential backoff —
//! `base × 2^attempt` — plus a bounded jitter term.
//!
//! ## The no-wall-clock determinism rule
//!
//! The *schedule* (how many attempts, how long each sleep) is a pure
//! function of the policy's fields and the attempt index — never of
//! `Instant::now()`, thread IDs, or any other ambient state. Jitter is
//! derived from a caller-provided PRNG seed via
//! [`crate::util::prng::mix64`], so two runs with the same seed sleep
//! the exact same schedule. Wall clock enters only when the sleep is
//! *performed*; fault-injection tests can therefore pin the whole
//! schedule (attempt counts, per-attempt delays) without racing real
//! time.

use std::time::Duration;

use crate::util::prng::mix64;

/// Attempts a default [`Policy`] makes before surfacing a transient
/// error (shared with `vecdata::oocstore::RETRY_ATTEMPTS`).
pub const DEFAULT_ATTEMPTS: u32 = 4;

/// Default base backoff; doubles per attempt. Sub-millisecond so
/// scripted-fault tests stay fast while real interrupted syscalls
/// still get breathing room.
pub const DEFAULT_BASE: Duration = Duration::from_micros(200);

/// Maximum jitter as a fraction of the attempt's backoff (+25%).
const JITTER_FRAC: f64 = 0.25;

/// A deterministic exponential-backoff retry policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Policy {
    /// Total attempts (first try included) before the transient error
    /// surfaces.
    pub attempts: u32,
    /// Backoff before retry `n` (0-based) is `base × 2^n` plus jitter.
    pub base: Duration,
    /// Seed for the deterministic jitter stream. Same seed → same
    /// schedule; vary it per call site (rank, key hash) to decorrelate
    /// concurrent retriers without touching the wall clock.
    pub jitter_seed: u64,
}

impl Default for Policy {
    fn default() -> Self {
        Policy { attempts: DEFAULT_ATTEMPTS, base: DEFAULT_BASE, jitter_seed: 0 }
    }
}

impl Policy {
    /// The default policy reseeded for a specific call site.
    pub fn seeded(jitter_seed: u64) -> Self {
        Policy { jitter_seed, ..Policy::default() }
    }

    /// The sleep before retry `attempt` (0-based: the delay after the
    /// first failure is `delay(0)`). Pure function of the policy and
    /// the attempt index — see the module docs' no-wall-clock rule.
    pub fn delay(&self, attempt: u32) -> Duration {
        let backoff = self.base * (1u32 << attempt.min(20));
        // Jitter in [0, JITTER_FRAC) of the backoff, from a hash of
        // (seed, attempt) — deterministic, decorrelated across seeds.
        let bits = mix64(self.jitter_seed.wrapping_add(0x9E37_79B9).wrapping_add(attempt as u64));
        let frac = (bits >> 11) as f64 / (1u64 << 53) as f64;
        backoff + Duration::from_secs_f64(backoff.as_secs_f64() * JITTER_FRAC * frac)
    }

    /// Run `op` under the policy: errors for which `is_transient`
    /// returns true are retried (sleeping [`Policy::delay`] between
    /// attempts) until the attempt budget is spent; any other error —
    /// and a transient one past the budget — surfaces immediately.
    pub fn run<T, E>(
        &self,
        is_transient: impl Fn(&E) -> bool,
        mut op: impl FnMut() -> Result<T, E>,
    ) -> Result<T, E> {
        let mut attempt = 0;
        loop {
            match op() {
                Ok(v) => return Ok(v),
                Err(e) if is_transient(&e) && attempt + 1 < self.attempts.max(1) => {
                    std::thread::sleep(self.delay(attempt));
                    attempt += 1;
                }
                Err(e) => return Err(e),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_is_deterministic_and_exponential() {
        let p = Policy::seeded(42);
        let again = Policy::seeded(42);
        for a in 0..6 {
            // Same seed → bit-identical schedule (no wall clock).
            assert_eq!(p.delay(a), again.delay(a));
            // Monotone doubling envelope: base×2^a ≤ delay < base×2^a×(1+25%).
            let floor = p.base * (1 << a);
            assert!(p.delay(a) >= floor, "attempt {a}: {:?} < {floor:?}", p.delay(a));
            let ceil = floor + Duration::from_secs_f64(floor.as_secs_f64() * 0.25);
            assert!(p.delay(a) <= ceil, "attempt {a}: {:?} > {ceil:?}", p.delay(a));
        }
        // Different seeds decorrelate at least one attempt's jitter.
        let other = Policy::seeded(43);
        assert!((0..6).any(|a| other.delay(a) != p.delay(a)));
    }

    #[test]
    fn run_retries_transients_within_budget() {
        let p = Policy { base: Duration::from_micros(1), ..Policy::default() };
        // Succeeds on the last allowed attempt.
        let mut calls = 0;
        let out = p.run(
            |_: &&str| true,
            || {
                calls += 1;
                if calls < p.attempts { Err("flaky") } else { Ok(calls) }
            },
        );
        assert_eq!(out.unwrap(), p.attempts);
        // Budget exhausted: the error surfaces after exactly `attempts` calls.
        let mut calls = 0;
        let out: Result<(), _> = p.run(
            |_: &&str| true,
            || {
                calls += 1;
                Err("always")
            },
        );
        assert_eq!(out.unwrap_err(), "always");
        assert_eq!(calls, p.attempts);
        // Non-transient errors never retry.
        let mut calls = 0;
        let out: Result<(), _> = p.run(
            |_: &&str| false,
            || {
                calls += 1;
                Err("fatal")
            },
        );
        assert_eq!(out.unwrap_err(), "fatal");
        assert_eq!(calls, 1);
    }

    #[test]
    fn degenerate_budgets_still_run_once() {
        let p = Policy { attempts: 0, ..Policy::default() };
        let mut calls = 0;
        let _: Result<(), _> = p.run(
            |_: &&str| true,
            || {
                calls += 1;
                Err("x")
            },
        );
        assert_eq!(calls, 1);
    }
}
