//! Human-readable formatting for the bench tables (the paper reports
//! GOps/s per node and petacomparisons/s; we print the same units).

/// Format an operations-per-second rate with SI-style scaling
/// (the paper's "GOps" / "petacomparisons" vocabulary).
pub fn rate(ops_per_sec: f64) -> String {
    let (val, unit) = scale(ops_per_sec);
    format!("{val:.3} {unit}op/s")
}

/// Format a comparisons-per-second rate.
pub fn cmp_rate(cmps_per_sec: f64) -> String {
    let (val, unit) = scale(cmps_per_sec);
    format!("{val:.3} {unit}cmp/s")
}

fn scale(x: f64) -> (f64, &'static str) {
    const UNITS: [(f64, &str); 5] = [
        (1e15, "P"),
        (1e12, "T"),
        (1e9, "G"),
        (1e6, "M"),
        (1e3, "k"),
    ];
    for (f, u) in UNITS {
        if x >= f {
            return (x / f, u);
        }
    }
    (x, "")
}

/// Format seconds compactly.
pub fn secs(t: f64) -> String {
    if t >= 100.0 {
        format!("{t:.0} s")
    } else if t >= 1.0 {
        format!("{t:.2} s")
    } else if t >= 1e-3 {
        format!("{:.2} ms", t * 1e3)
    } else {
        format!("{:.1} µs", t * 1e6)
    }
}

/// Format a byte count.
pub fn bytes(b: u64) -> String {
    let b = b as f64;
    if b >= 1e9 {
        format!("{:.2} GB", b / 1e9)
    } else if b >= 1e6 {
        format!("{:.2} MB", b / 1e6)
    } else if b >= 1e3 {
        format!("{:.2} kB", b / 1e3)
    } else {
        format!("{b:.0} B")
    }
}

/// Fixed-width table printer for the bench binaries: prints a header row
/// and separator, then rows, all aligned to the widest cell per column.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for c in 0..ncol {
                widths[c] = widths[c].max(row[c].len());
            }
        }
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(c, s)| format!("{:>w$}", s, w = widths[c]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = String::new();
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncol - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rates() {
        assert_eq!(rate(5.0e15), "5.000 Pop/s");
        assert_eq!(rate(3.2e9), "3.200 Gop/s");
        assert_eq!(cmp_rate(1.7e15), "1.700 Pcmp/s");
        assert_eq!(rate(12.0), "12.000 op/s");
    }

    #[test]
    fn secs_ranges() {
        assert_eq!(secs(250.0), "250 s");
        assert_eq!(secs(1.5), "1.50 s");
        assert_eq!(secs(0.002), "2.00 ms");
        assert_eq!(secs(5e-6), "5.0 µs");
    }

    #[test]
    fn bytes_ranges() {
        assert_eq!(bytes(500), "500 B");
        assert_eq!(bytes(2_000_000), "2.00 MB");
        assert_eq!(bytes(3_000_000_000), "3.00 GB");
    }

    #[test]
    fn table_alignment() {
        let mut t = Table::new(&["a", "long_header"]);
        t.row(&["1".into(), "2".into()]);
        t.row(&["123456".into(), "x".into()]);
        let r = t.render();
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].contains("long_header"));
        assert!(lines[2].ends_with('2'));
    }

    #[test]
    #[should_panic(expected = "column count mismatch")]
    fn table_rejects_bad_row() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".into()]);
    }
}
