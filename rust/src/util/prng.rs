//! Deterministic PRNG streams (splitmix64 + xoshiro256++).
//!
//! The paper's synthetic test problems must produce "the exact same
//! bit-for-bit result for all code versions and for all parallel
//! decompositions" (§5). That requires every vector's entries to be a
//! pure function of (campaign seed, global vector id, feature index) —
//! never of which node generates them. [`Stream::for_vector`] derives an
//! independent, stable stream per vector for exactly this.
//!
//! (No `rand` crate offline; these are the standard public-domain
//! xoshiro/splitmix constructions.)

/// splitmix64 step — used for seeding and for one-shot hashing.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// One-shot 64-bit mix of a key (stateless hash built from splitmix64).
#[inline]
pub fn mix64(key: u64) -> u64 {
    let mut s = key;
    splitmix64(&mut s)
}

/// xoshiro256++ generator.
#[derive(Clone, Debug)]
pub struct Stream {
    s: [u64; 4],
}

impl Stream {
    /// Seed via splitmix64 (the reference seeding procedure).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        // xoshiro must not start from the all-zero state.
        let mut st = Stream { s };
        if st.s == [0; 4] {
            st.s = [0x9E3779B97F4A7C15, 1, 2, 3];
        }
        st
    }

    /// Stable per-vector stream: a pure function of (seed, vector id),
    /// independent of node assignment (see module docs).
    pub fn for_vector(campaign_seed: u64, vector_id: u64) -> Self {
        Stream::new(campaign_seed ^ mix64(vector_id.wrapping_add(0xC0FFEE)))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1) with 53 random bits.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n) (Lemire-style rejection-free for our
    /// non-cryptographic needs: 128-bit multiply-shift).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Random permutation of 0..n (Fisher–Yates) — used for the paper's
    /// MPICH_RANK_REORDER random node mapping experiment.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        for i in (1..n).rev() {
            let j = self.below(i as u64 + 1) as usize;
            p.swap(i, j);
        }
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Stream::new(42);
        let mut b = Stream::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Stream::new(1);
        let mut b = Stream::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut s = Stream::new(7);
        for _ in 0..10_000 {
            let x = s.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn f64_roughly_uniform() {
        let mut s = Stream::new(9);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| s.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut s = Stream::new(3);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let x = s.below(7) as usize;
            assert!(x < 7);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn vector_streams_are_node_independent() {
        // Same (seed, id) -> same stream, regardless of construction order.
        let mut x = Stream::for_vector(99, 12345);
        let _ = Stream::for_vector(99, 1); // unrelated interleaved stream
        let mut y = Stream::for_vector(99, 12345);
        for _ in 0..32 {
            assert_eq!(x.next_u64(), y.next_u64());
        }
    }

    #[test]
    fn permutation_is_a_permutation() {
        let mut s = Stream::new(5);
        let p = s.permutation(100);
        let mut sorted = p.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
