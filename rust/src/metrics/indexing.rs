//! Formulaic index ↔ linear-offset maps for unique pairs and triples.
//!
//! Paper §6.8: "No indexing information need be written explicitly since
//! this information can be computed formulaically offline." These are
//! those formulas: bijections between the strict upper-triangular pair
//! set {(i, j) : i < j} (resp. the tetrahedral triple set i < j < k) and
//! dense linear offsets, used by the output writers and readers.

/// Number of unique pairs among n vectors: n(n−1)/2.
pub const fn num_pairs(n: usize) -> usize {
    n * (n - 1) / 2
}

/// Number of unique triples among n vectors: n(n−1)(n−2)/6.
pub const fn num_triples(n: usize) -> usize {
    n * (n - 1) * (n - 2) / 6
}

/// Linear offset of pair (i, j), i < j: column-major triangular packing
/// (all pairs with second index j precede those with j+1).
pub fn pair_offset(i: usize, j: usize) -> usize {
    debug_assert!(i < j);
    j * (j - 1) / 2 + i
}

/// Inverse of [`pair_offset`].
pub fn pair_from_offset(off: usize) -> (usize, usize) {
    // Largest j with j(j-1)/2 <= off.
    let j = ((1.0 + (1.0 + 8.0 * off as f64).sqrt()) / 2.0).floor() as usize;
    let j = if j * (j - 1) / 2 > off { j - 1 } else { j };
    let i = off - j * (j - 1) / 2;
    (i, j)
}

/// Linear offset of triple (i, j, k), i < j < k: tetrahedral packing.
pub fn triple_offset(i: usize, j: usize, k: usize) -> usize {
    debug_assert!(i < j && j < k);
    k * (k - 1) * (k - 2) / 6 + j * (j - 1) / 2 + i
}

/// Inverse of [`triple_offset`].
pub fn triple_from_offset(off: usize) -> (usize, usize, usize) {
    // Largest k with C(k,3) <= off, found by float seed + local fixup.
    let mut k = ((6.0 * off as f64).cbrt() as usize).max(2);
    while k * (k - 1) * (k - 2) / 6 > off {
        k -= 1;
    }
    while (k + 1) * k * (k - 1) / 6 <= off {
        k += 1;
    }
    let rem = off - k * (k - 1) * (k - 2) / 6;
    let (i, j) = pair_from_offset(rem);
    (i, j, k)
}

/// Iterator over all unique pairs (i < j) for n vectors, in offset order.
pub fn pairs(n: usize) -> impl Iterator<Item = (usize, usize)> {
    (1..n).flat_map(move |j| (0..j).map(move |i| (i, j)))
}

/// Iterator over all unique triples (i < j < k), in offset order.
pub fn triples(n: usize) -> impl Iterator<Item = (usize, usize, usize)> {
    (2..n).flat_map(move |k| (1..k).flat_map(move |j| (0..j).map(move |i| (i, j, k))))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pair_counts() {
        assert_eq!(num_pairs(2), 1);
        assert_eq!(num_pairs(10), 45);
        // Paper §2.1: n_v(n_v−1)/2 distinct values.
        assert_eq!(num_pairs(10_240), 10_240 * 10_239 / 2);
    }

    #[test]
    fn triple_counts() {
        assert_eq!(num_triples(3), 1);
        assert_eq!(num_triples(6), 20);
    }

    #[test]
    fn pair_offset_is_dense_bijection() {
        let n = 50;
        let mut seen = vec![false; num_pairs(n)];
        for (i, j) in pairs(n) {
            let off = pair_offset(i, j);
            assert!(!seen[off], "duplicate offset {off}");
            seen[off] = true;
            assert_eq!(pair_from_offset(off), (i, j));
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn triple_offset_is_dense_bijection() {
        let n = 20;
        let mut seen = vec![false; num_triples(n)];
        for (i, j, k) in triples(n) {
            let off = triple_offset(i, j, k);
            assert!(!seen[off], "duplicate offset {off}");
            seen[off] = true;
            assert_eq!(triple_from_offset(off), (i, j, k));
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn offset_order_matches_iterator_order() {
        let offs: Vec<usize> = pairs(8).map(|(i, j)| pair_offset(i, j)).collect();
        assert_eq!(offs, (0..num_pairs(8)).collect::<Vec<_>>());
        let offs3: Vec<usize> = triples(8).map(|(i, j, k)| triple_offset(i, j, k)).collect();
        assert_eq!(offs3, (0..num_triples(8)).collect::<Vec<_>>());
    }

    #[test]
    fn large_offsets_roundtrip() {
        for off in [0usize, 1, 1000, 123_456, 98_765_432] {
            let (i, j) = pair_from_offset(off);
            assert!(i < j);
            assert_eq!(pair_offset(i, j), off);
            let (a, b, c) = triple_from_offset(off);
            assert!(a < b && b < c);
            assert_eq!(triple_offset(a, b, c), off);
        }
    }
}
