//! In-memory result containers for computed metrics.
//!
//! Production campaigns stream metrics straight to per-node output files
//! (paper §6.8); these containers serve the examples, tests, and the
//! discovery workflows (top-k similar pairs/triples), and accumulate the
//! run statistics every driver reports.

use super::indexing;
use super::MetricId;

/// One computed 2-way metric.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PairEntry {
    pub i: u32,
    pub j: u32,
    pub value: f64,
}

/// One computed 3-way metric.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TripleEntry {
    pub i: u32,
    pub j: u32,
    pub k: u32,
    pub value: f64,
}

/// Sparse store of unique-pair metrics (i < j enforced on insert),
/// tagged with the metric family that produced it.
#[derive(Debug, Default, Clone)]
pub struct PairStore {
    entries: Vec<PairEntry>,
    /// Which metric these values are (defaults to Czekanowski).
    pub metric: MetricId,
}

impl PairStore {
    pub fn new() -> Self {
        Self::default()
    }

    /// An empty store tagged with `metric`.
    pub fn for_metric(metric: MetricId) -> Self {
        PairStore { metric, ..Self::default() }
    }

    pub fn push(&mut self, i: usize, j: usize, value: f64) {
        debug_assert!(i < j, "pair must be canonical (i < j): ({i}, {j})");
        self.entries.push(PairEntry {
            i: i as u32,
            j: j as u32,
            value,
        });
    }

    /// Absorb already-canonical entries (the result-tile path: sinks
    /// collect whole [`crate::output::sink::Tile`]s of these).
    pub fn extend_entries(&mut self, entries: impl IntoIterator<Item = PairEntry>) {
        for e in entries {
            debug_assert!(e.i < e.j, "pair must be canonical (i < j): ({}, {})", e.i, e.j);
            self.entries.push(e);
        }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = &PairEntry> {
        self.entries.iter()
    }

    pub fn extend(&mut self, other: PairStore) {
        debug_assert!(
            self.entries.is_empty() || other.entries.is_empty() || self.metric == other.metric,
            "merging stores of different metrics ({:?} vs {:?})",
            self.metric,
            other.metric
        );
        self.entries.extend(other.entries);
    }

    /// Dense lookup table keyed by formulaic offset; None where absent.
    pub fn to_dense(&self, nv: usize) -> Vec<Option<f64>> {
        let mut dense = vec![None; indexing::num_pairs(nv)];
        for e in &self.entries {
            let off = indexing::pair_offset(e.i as usize, e.j as usize);
            assert!(dense[off].is_none(), "duplicate pair ({}, {})", e.i, e.j);
            dense[off] = Some(e.value);
        }
        dense
    }

    /// Top-k entries by metric value (descending) — the GWAS/PheWAS
    /// discovery question: which profiles share the most genetic signal.
    pub fn top_k(&self, k: usize) -> Vec<PairEntry> {
        let mut v = self.entries.clone();
        v.sort_by(|a, b| b.value.partial_cmp(&a.value).unwrap());
        v.truncate(k);
        v
    }
}

/// Sparse store of unique-triple metrics (i < j < k enforced),
/// tagged with the metric family that produced it.
#[derive(Debug, Default, Clone)]
pub struct TripleStore {
    entries: Vec<TripleEntry>,
    /// Which metric these values are (defaults to Czekanowski).
    pub metric: MetricId,
}

impl TripleStore {
    pub fn new() -> Self {
        Self::default()
    }

    /// An empty store tagged with `metric`.
    pub fn for_metric(metric: MetricId) -> Self {
        TripleStore { metric, ..Self::default() }
    }

    pub fn push(&mut self, i: usize, j: usize, k: usize, value: f64) {
        debug_assert!(i < j && j < k, "triple must be canonical: ({i},{j},{k})");
        self.entries.push(TripleEntry {
            i: i as u32,
            j: j as u32,
            k: k as u32,
            value,
        });
    }

    /// Absorb already-canonical entries (the result-tile path).
    pub fn extend_entries(&mut self, entries: impl IntoIterator<Item = TripleEntry>) {
        for e in entries {
            debug_assert!(
                e.i < e.j && e.j < e.k,
                "triple must be canonical: ({},{},{})",
                e.i,
                e.j,
                e.k
            );
            self.entries.push(e);
        }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = &TripleEntry> {
        self.entries.iter()
    }

    pub fn extend(&mut self, other: TripleStore) {
        debug_assert!(
            self.entries.is_empty() || other.entries.is_empty() || self.metric == other.metric,
            "merging stores of different metrics ({:?} vs {:?})",
            self.metric,
            other.metric
        );
        self.entries.extend(other.entries);
    }

    pub fn to_dense(&self, nv: usize) -> Vec<Option<f64>> {
        let mut dense = vec![None; indexing::num_triples(nv)];
        for e in &self.entries {
            let off = indexing::triple_offset(e.i as usize, e.j as usize, e.k as usize);
            assert!(
                dense[off].is_none(),
                "duplicate triple ({}, {}, {})",
                e.i,
                e.j,
                e.k
            );
            dense[off] = Some(e.value);
        }
        dense
    }

    pub fn top_k(&self, k: usize) -> Vec<TripleEntry> {
        let mut v = self.entries.clone();
        v.sort_by(|a, b| b.value.partial_cmp(&a.value).unwrap());
        v.truncate(k);
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pair_store_dense_roundtrip() {
        let mut s = PairStore::new();
        s.push(0, 1, 0.5);
        s.push(1, 3, 0.25);
        let d = s.to_dense(4);
        assert_eq!(d.len(), 6);
        assert_eq!(d[indexing::pair_offset(0, 1)], Some(0.5));
        assert_eq!(d[indexing::pair_offset(1, 3)], Some(0.25));
        assert_eq!(d[indexing::pair_offset(2, 3)], None);
    }

    #[test]
    #[should_panic(expected = "duplicate pair")]
    fn pair_store_rejects_duplicates_in_dense() {
        let mut s = PairStore::new();
        s.push(0, 1, 0.5);
        s.push(0, 1, 0.6);
        let _ = s.to_dense(4);
    }

    #[test]
    fn top_k_orders_descending() {
        let mut s = PairStore::new();
        s.push(0, 1, 0.1);
        s.push(0, 2, 0.9);
        s.push(1, 2, 0.5);
        let top = s.top_k(2);
        assert_eq!(top.len(), 2);
        assert_eq!((top[0].i, top[0].j), (0, 2));
        assert_eq!((top[1].i, top[1].j), (1, 2));
    }

    #[test]
    fn triple_store_dense() {
        let mut s = TripleStore::new();
        s.push(0, 1, 2, 0.7);
        s.push(1, 2, 3, 0.2);
        let d = s.to_dense(4);
        assert_eq!(d.len(), 4);
        assert_eq!(d[indexing::triple_offset(0, 1, 2)], Some(0.7));
    }

    #[test]
    fn extend_merges() {
        let mut a = PairStore::new();
        a.push(0, 1, 0.5);
        let mut b = PairStore::new();
        b.push(1, 2, 0.3);
        a.extend(b);
        assert_eq!(a.len(), 2);
    }

    #[test]
    fn stores_carry_metric_tags() {
        let s = PairStore::for_metric(MetricId::Ccc);
        assert_eq!(s.metric, MetricId::Ccc);
        assert_eq!(PairStore::new().metric, MetricId::Czekanowski);
        let t = TripleStore::for_metric(MetricId::Czekanowski);
        assert_eq!(t.metric, MetricId::Czekanowski);
    }

    #[test]
    fn extend_tolerates_empty_stores_of_other_metrics() {
        // The coordinator merges empty default-tagged stores from node
        // results into the run's tagged store; that must not trip the
        // same-metric guard.
        let mut a = PairStore::for_metric(MetricId::Sorenson);
        a.push(0, 1, 0.5);
        a.extend(PairStore::new());
        assert_eq!(a.len(), 1);
    }
}
