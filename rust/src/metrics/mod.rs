//! Metric definitions — scalar oracles, the pluggable metric
//! [`engine`], combinatorial indexing, and result containers.
//!
//! Paper §2 (Proportional Similarity / Czekanowski): for non-negative
//! vectors u, v, w of length n_f,
//!
//! ```text
//! n2(u,v)   = Σ_q min(u_q, v_q)            d2(u,v)   = Σ u + Σ v
//! c2(u,v)   = 2 n2 / d2
//! n3'(u,v,w)= Σ_q min(u_q, v_q, w_q)
//! n3        = n2(u,v) + n2(u,w) + n2(v,w) − n3'
//! d3        = Σ u + Σ v + Σ w
//! c3        = (3/2) n3 / d3
//! ```
//!
//! Companion paper (arXiv 1705.08213, CCC): for allele-count vectors
//! u, v ∈ {0, 1, 2}^n_f,
//!
//! ```text
//! n(u,v)  = Σ_q u_q v_q
//! ccc     = (9/2) · n/(4 n_f) · (1 − (2/3)·Σu/(2 n_f)) (1 − (2/3)·Σv/(2 n_f))
//! ```
//!
//! The scalar functions here are the *oracle* implementations used by
//! every test; the production paths are `linalg` (native blocked) and
//! `runtime` (PJRT artifacts), dispatched per-metric by
//! [`engine::Metric`].

pub mod counts;
pub mod engine;
pub mod indexing;
pub mod store;

pub use engine::{make_metric, Domain, Metric, MetricId};

use crate::util::Scalar;

/// Min-product numerator n2 (the mGEMM's scalar contract).
pub fn n2<T: Scalar>(u: &[T], v: &[T]) -> f64 {
    assert_eq!(u.len(), v.len());
    let mut acc = T::ZERO;
    for q in 0..u.len() {
        acc += u[q].min_s(v[q]);
    }
    acc.to_f64()
}

/// Triple min-product numerator n3'.
pub fn n3_prime<T: Scalar>(u: &[T], v: &[T], w: &[T]) -> f64 {
    assert_eq!(u.len(), v.len());
    assert_eq!(u.len(), w.len());
    let mut acc = T::ZERO;
    for q in 0..u.len() {
        acc += u[q].min_s(v[q]).min_s(w[q]);
    }
    acc.to_f64()
}

/// Vector sum Σ_q v_q (denominator ingredient).
pub fn vsum<T: Scalar>(v: &[T]) -> f64 {
    let mut acc = T::ZERO;
    for &x in v {
        acc += x;
    }
    acc.to_f64()
}

/// 2-way Proportional Similarity c2(u, v).
pub fn czekanowski2<T: Scalar>(u: &[T], v: &[T]) -> f64 {
    2.0 * n2(u, v) / (vsum(u) + vsum(v))
}

/// 3-way Proportional Similarity c3(u, v, w).
pub fn czekanowski3<T: Scalar>(u: &[T], v: &[T], w: &[T]) -> f64 {
    let n3 = n2(u, v) + n2(u, w) + n2(v, w) - n3_prime(u, v, w);
    1.5 * n3 / (vsum(u) + vsum(v) + vsum(w))
}

/// Assemble c2 from precomputed pieces — the exact arithmetic the
/// coordinator's "CPU side" performs after an mGEMM block (paper §3.1:
/// numerators on the GPU, denominators and quotients on the CPU).
#[inline]
pub fn c2_from_parts(n2: f64, sum_i: f64, sum_j: f64) -> f64 {
    2.0 * n2 / (sum_i + sum_j)
}

/// CCC weighting constants (companion paper): overall multiplier 9/2
/// and frequency weight 2/3.
pub const CCC_MULTIPLIER: f64 = 9.0 / 2.0;
pub const CCC_PARAM: f64 = 2.0 / 3.0;

/// Plain dot-product numerator n(u, v) = Σ_q u_q v_q — the CCC's GEMM
/// scalar contract.
pub fn n_dot<T: Scalar>(u: &[T], v: &[T]) -> f64 {
    assert_eq!(u.len(), v.len());
    let mut acc = T::ZERO;
    for q in 0..u.len() {
        acc += u[q] * v[q];
    }
    acc.to_f64()
}

/// Assemble a CCC value from precomputed pieces — the exact arithmetic
/// the coordinator performs after a GEMM block. `nf` is the global
/// feature depth (frequencies are normalized by the full campaign
/// depth even when numerators were accumulated from feature slices).
#[inline]
pub fn ccc_from_parts(n: f64, sum_i: f64, sum_j: f64, nf: usize) -> f64 {
    let nf = nf as f64;
    let f_ij = n / (4.0 * nf);
    let f_i = sum_i / (2.0 * nf);
    let f_j = sum_j / (2.0 * nf);
    CCC_MULTIPLIER * f_ij * (1.0 - CCC_PARAM * f_i) * (1.0 - CCC_PARAM * f_j)
}

/// 2-way Custom Correlation Coefficient ccc(u, v) — the scalar oracle
/// (companion paper §2). Frequencies are normalized by the vector
/// length.
pub fn ccc2<T: Scalar>(u: &[T], v: &[T]) -> f64 {
    ccc_from_parts(n_dot(u, v), vsum(u), vsum(v), u.len())
}

/// Assemble c3 from precomputed pieces (paper Eq. (1)).
#[inline]
pub fn c3_from_parts(
    n2_ij: f64,
    n2_ik: f64,
    n2_jk: f64,
    n3_prime: f64,
    sum_i: f64,
    sum_j: f64,
    sum_k: f64,
) -> f64 {
    1.5 * (n2_ij + n2_ik + n2_jk - n3_prime) / (sum_i + sum_j + sum_k)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Stream;

    fn rand_vec(seed: u64, n: usize) -> Vec<f64> {
        let mut s = Stream::new(seed);
        (0..n).map(|_| s.next_f64()).collect()
    }

    #[test]
    fn n2_small_case() {
        let u = [1.0, 2.0, 0.5];
        let v = [0.5, 3.0, 1.0];
        assert_eq!(n2(&u, &v), 0.5 + 2.0 + 0.5);
    }

    #[test]
    fn c2_self_similarity_is_one() {
        let u = rand_vec(1, 100);
        assert!((czekanowski2(&u, &u) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn c2_symmetric() {
        let u = rand_vec(2, 64);
        let v = rand_vec(3, 64);
        assert_eq!(czekanowski2(&u, &v), czekanowski2(&v, &u));
    }

    #[test]
    fn c2_bounds() {
        for s in 0..20 {
            let u = rand_vec(s, 32);
            let v = rand_vec(s + 100, 32);
            let c = czekanowski2(&u, &v);
            assert!((0.0..=1.0 + 1e-12).contains(&c), "c={c}");
        }
    }

    #[test]
    fn c2_disjoint_support_is_zero() {
        let mut u = vec![0.0; 64];
        let mut v = vec![0.0; 64];
        for q in 0..32 {
            u[q] = 1.0;
            v[q + 32] = 1.0;
        }
        assert_eq!(czekanowski2(&u, &v), 0.0);
    }

    #[test]
    fn c3_identical_triple_is_one() {
        let u = rand_vec(7, 50);
        assert!((czekanowski3(&u, &u, &u) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn c3_totally_symmetric() {
        let u = rand_vec(1, 32);
        let v = rand_vec(2, 32);
        let w = rand_vec(3, 32);
        let c = czekanowski3(&u, &v, &w);
        assert_eq!(c, czekanowski3(&u, &w, &v));
        assert_eq!(c, czekanowski3(&v, &u, &w));
        assert_eq!(c, czekanowski3(&w, &v, &u));
    }

    #[test]
    fn c3_from_parts_matches_direct() {
        let u = rand_vec(11, 48);
        let v = rand_vec(12, 48);
        let w = rand_vec(13, 48);
        let direct = czekanowski3(&u, &v, &w);
        let parts = c3_from_parts(
            n2(&u, &v),
            n2(&u, &w),
            n2(&v, &w),
            n3_prime(&u, &v, &w),
            vsum(&u),
            vsum(&v),
            vsum(&w),
        );
        assert!((direct - parts).abs() < 1e-14);
    }

    #[test]
    fn f32_path_agrees_with_f64_on_grid_values() {
        // On the k/64 grid all sums are exact in both precisions.
        let mut s = Stream::new(5);
        let u32v: Vec<f32> = (0..256).map(|_| (s.below(64) as f32) / 64.0).collect();
        let v32v: Vec<f32> = (0..256).map(|_| (s.below(64) as f32) / 64.0).collect();
        let u64v: Vec<f64> = u32v.iter().map(|&x| x as f64).collect();
        let v64v: Vec<f64> = v32v.iter().map(|&x| x as f64).collect();
        assert_eq!(n2(&u32v, &v32v), n2(&u64v, &v64v));
        assert_eq!(czekanowski2(&u32v, &v32v), czekanowski2(&u64v, &v64v));
    }

    #[test]
    fn n_dot_small_case() {
        let u = [1.0, 2.0, 0.0];
        let v = [2.0, 1.0, 2.0];
        assert_eq!(n_dot(&u, &v), 4.0);
    }

    #[test]
    fn ccc2_symmetric_and_bounded() {
        // Allele-count vectors: entries in {0, 1, 2}.
        let mut s = Stream::new(9);
        let u: Vec<f64> = (0..96).map(|_| s.below(3) as f64).collect();
        let v: Vec<f64> = (0..96).map(|_| s.below(3) as f64).collect();
        assert_eq!(ccc2(&u, &v), ccc2(&v, &u));
        let c = ccc2(&u, &v);
        assert!((0.0..=1.0 + 1e-12).contains(&c), "ccc = {c}");
    }

    #[test]
    fn ccc2_zero_vector_gives_zero() {
        let u = vec![0.0; 32];
        let v: Vec<f64> = (0..32).map(|q| (q % 3) as f64).collect();
        assert_eq!(ccc2(&u, &v), 0.0);
    }

    #[test]
    fn ccc_from_parts_matches_direct() {
        let mut s = Stream::new(21);
        let u: Vec<f64> = (0..50).map(|_| s.below(3) as f64).collect();
        let v: Vec<f64> = (0..50).map(|_| s.below(3) as f64).collect();
        let parts = ccc_from_parts(n_dot(&u, &v), vsum(&u), vsum(&v), 50);
        assert_eq!(parts, ccc2(&u, &v));
    }

    #[test]
    fn ccc_all_twos_saturates_to_half() {
        // f_ij = f_i = f_j = 1 → ccc = (9/2)(1/3)² = 1/2.
        let u = vec![2.0; 64];
        assert!((ccc2(&u, &u) - 0.5).abs() < 1e-12);
    }
}
