//! The Proportional Similarity (Czekanowski) metrics — definitions,
//! scalar oracles, combinatorial indexing, and result containers.
//!
//! Paper §2: for non-negative vectors u, v, w of length n_f,
//!
//! ```text
//! n2(u,v)   = Σ_q min(u_q, v_q)            d2(u,v)   = Σ u + Σ v
//! c2(u,v)   = 2 n2 / d2
//! n3'(u,v,w)= Σ_q min(u_q, v_q, w_q)
//! n3        = n2(u,v) + n2(u,w) + n2(v,w) − n3'
//! d3        = Σ u + Σ v + Σ w
//! c3        = (3/2) n3 / d3
//! ```
//!
//! The scalar functions here are the *oracle* implementations used by
//! every test; the production paths are `linalg` (native blocked) and
//! `runtime` (PJRT artifacts).

pub mod counts;
pub mod indexing;
pub mod store;

use crate::util::Scalar;

/// Which metric family a run computes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MetricKind {
    /// 2-way Proportional Similarity (Czekanowski).
    Czekanowski2,
    /// 3-way Proportional Similarity.
    Czekanowski3,
    /// Sorenson on 0/1 data (= Czekanowski restricted to bits, §2.3).
    Sorenson2,
}

impl MetricKind {
    pub fn num_way(self) -> usize {
        match self {
            MetricKind::Czekanowski2 | MetricKind::Sorenson2 => 2,
            MetricKind::Czekanowski3 => 3,
        }
    }
}

/// Min-product numerator n2 (the mGEMM's scalar contract).
pub fn n2<T: Scalar>(u: &[T], v: &[T]) -> f64 {
    assert_eq!(u.len(), v.len());
    let mut acc = T::ZERO;
    for q in 0..u.len() {
        acc += u[q].min_s(v[q]);
    }
    acc.to_f64()
}

/// Triple min-product numerator n3'.
pub fn n3_prime<T: Scalar>(u: &[T], v: &[T], w: &[T]) -> f64 {
    assert_eq!(u.len(), v.len());
    assert_eq!(u.len(), w.len());
    let mut acc = T::ZERO;
    for q in 0..u.len() {
        acc += u[q].min_s(v[q]).min_s(w[q]);
    }
    acc.to_f64()
}

/// Vector sum Σ_q v_q (denominator ingredient).
pub fn vsum<T: Scalar>(v: &[T]) -> f64 {
    let mut acc = T::ZERO;
    for &x in v {
        acc += x;
    }
    acc.to_f64()
}

/// 2-way Proportional Similarity c2(u, v).
pub fn czekanowski2<T: Scalar>(u: &[T], v: &[T]) -> f64 {
    2.0 * n2(u, v) / (vsum(u) + vsum(v))
}

/// 3-way Proportional Similarity c3(u, v, w).
pub fn czekanowski3<T: Scalar>(u: &[T], v: &[T], w: &[T]) -> f64 {
    let n3 = n2(u, v) + n2(u, w) + n2(v, w) - n3_prime(u, v, w);
    1.5 * n3 / (vsum(u) + vsum(v) + vsum(w))
}

/// Assemble c2 from precomputed pieces — the exact arithmetic the
/// coordinator's "CPU side" performs after an mGEMM block (paper §3.1:
/// numerators on the GPU, denominators and quotients on the CPU).
#[inline]
pub fn c2_from_parts(n2: f64, sum_i: f64, sum_j: f64) -> f64 {
    2.0 * n2 / (sum_i + sum_j)
}

/// Assemble c3 from precomputed pieces (paper Eq. (1)).
#[inline]
pub fn c3_from_parts(
    n2_ij: f64,
    n2_ik: f64,
    n2_jk: f64,
    n3_prime: f64,
    sum_i: f64,
    sum_j: f64,
    sum_k: f64,
) -> f64 {
    1.5 * (n2_ij + n2_ik + n2_jk - n3_prime) / (sum_i + sum_j + sum_k)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Stream;

    fn rand_vec(seed: u64, n: usize) -> Vec<f64> {
        let mut s = Stream::new(seed);
        (0..n).map(|_| s.next_f64()).collect()
    }

    #[test]
    fn n2_small_case() {
        let u = [1.0, 2.0, 0.5];
        let v = [0.5, 3.0, 1.0];
        assert_eq!(n2(&u, &v), 0.5 + 2.0 + 0.5);
    }

    #[test]
    fn c2_self_similarity_is_one() {
        let u = rand_vec(1, 100);
        assert!((czekanowski2(&u, &u) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn c2_symmetric() {
        let u = rand_vec(2, 64);
        let v = rand_vec(3, 64);
        assert_eq!(czekanowski2(&u, &v), czekanowski2(&v, &u));
    }

    #[test]
    fn c2_bounds() {
        for s in 0..20 {
            let u = rand_vec(s, 32);
            let v = rand_vec(s + 100, 32);
            let c = czekanowski2(&u, &v);
            assert!((0.0..=1.0 + 1e-12).contains(&c), "c={c}");
        }
    }

    #[test]
    fn c2_disjoint_support_is_zero() {
        let mut u = vec![0.0; 64];
        let mut v = vec![0.0; 64];
        for q in 0..32 {
            u[q] = 1.0;
            v[q + 32] = 1.0;
        }
        assert_eq!(czekanowski2(&u, &v), 0.0);
    }

    #[test]
    fn c3_identical_triple_is_one() {
        let u = rand_vec(7, 50);
        assert!((czekanowski3(&u, &u, &u) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn c3_totally_symmetric() {
        let u = rand_vec(1, 32);
        let v = rand_vec(2, 32);
        let w = rand_vec(3, 32);
        let c = czekanowski3(&u, &v, &w);
        assert_eq!(c, czekanowski3(&u, &w, &v));
        assert_eq!(c, czekanowski3(&v, &u, &w));
        assert_eq!(c, czekanowski3(&w, &v, &u));
    }

    #[test]
    fn c3_from_parts_matches_direct() {
        let u = rand_vec(11, 48);
        let v = rand_vec(12, 48);
        let w = rand_vec(13, 48);
        let direct = czekanowski3(&u, &v, &w);
        let parts = c3_from_parts(
            n2(&u, &v),
            n2(&u, &w),
            n2(&v, &w),
            n3_prime(&u, &v, &w),
            vsum(&u),
            vsum(&v),
            vsum(&w),
        );
        assert!((direct - parts).abs() < 1e-14);
    }

    #[test]
    fn f32_path_agrees_with_f64_on_grid_values() {
        // On the k/64 grid all sums are exact in both precisions.
        let mut s = Stream::new(5);
        let u32v: Vec<f32> = (0..256).map(|_| (s.below(64) as f32) / 64.0).collect();
        let v32v: Vec<f32> = (0..256).map(|_| (s.below(64) as f32) / 64.0).collect();
        let u64v: Vec<f64> = u32v.iter().map(|&x| x as f64).collect();
        let v64v: Vec<f64> = v32v.iter().map(|&x| x as f64).collect();
        assert_eq!(n2(&u32v, &v32v), n2(&u64v, &v64v));
        assert_eq!(czekanowski2(&u32v, &v32v), czekanowski2(&u64v, &v64v));
    }

    #[test]
    fn metric_kind_ways() {
        assert_eq!(MetricKind::Czekanowski2.num_way(), 2);
        assert_eq!(MetricKind::Sorenson2.num_way(), 2);
        assert_eq!(MetricKind::Czekanowski3.num_way(), 3);
    }
}
