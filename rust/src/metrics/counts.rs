//! Operation and comparison accounting — the units of the paper's
//! performance reporting (§2, §6.6–6.7).
//!
//! The paper counts scalar add, multiply, and min each as one operation,
//! and defines one "elementwise comparison" as the (min, add) pair for a
//! single feature of a single unique pair — so the operation rate is
//! (approximately) twice the 2-way comparison rate, which is exactly how
//! Figures 7–10 overlay the two series.

use super::indexing::{num_pairs, num_triples};

/// Exact op count for the 2-way numerators over all unique pairs
/// (paper §2.1): (n_f − 1)·C(n_v,2) adds + n_f·C(n_v,2) mins.
pub fn ops_2way_numerators(nf: usize, nv: usize) -> u64 {
    let p = num_pairs(nv) as u64;
    let nf = nf as u64;
    (nf - 1) * p + nf * p
}

/// Exact op count for the 2-way denominators: (n_f − 1)·n_v adds.
pub fn ops_2way_denominators(nf: usize, nv: usize) -> u64 {
    (nf as u64 - 1) * nv as u64
}

/// Unique elementwise comparisons for a full 2-way study: n_f·C(n_v,2).
pub fn cmp_2way(nf: usize, nv: usize) -> u64 {
    nf as u64 * num_pairs(nv) as u64
}

/// Exact op count for the 3-way n3' term (paper §2.2):
/// (n_f − 1)·C(n_v,3) adds + 2·n_f·C(n_v,3) mins.
pub fn ops_3way_n3prime(nf: usize, nv: usize) -> u64 {
    let t = num_triples(nv) as u64;
    let nf = nf as u64;
    (nf - 1) * t + 2 * nf * t
}

/// Total 3-way ops including the required 2-way numerator tables and
/// denominators (the paper counts the startup 2-way work as part of the
/// 3-way operation rate, §6.7).
pub fn ops_3way_total(nf: usize, nv: usize) -> u64 {
    ops_3way_n3prime(nf, nv) + ops_2way_numerators(nf, nv) + ops_2way_denominators(nf, nv)
}

/// Unique elementwise comparisons for a full 3-way study: n_f·C(n_v,3).
pub fn cmp_3way(nf: usize, nv: usize) -> u64 {
    nf as u64 * num_triples(nv) as u64
}

/// Ops for a single m×n mGEMM block with feature depth nf
/// (what one artifact execution performs): m·n·(2·n_f − 1).
pub fn ops_mgemm_block(nf: usize, m: usize, n: usize) -> u64 {
    (m * n) as u64 * (2 * nf as u64 - 1)
}

/// Ops for a single jt×m×n 3-way slab (two mins + one add per element).
pub fn ops_mgemm3_slab(nf: usize, jt: usize, m: usize, n: usize) -> u64 {
    (jt * m * n) as u64 * (3 * nf as u64 - 1)
}

/// Flops for a true GEMM block (the Table 1 comparator): m·n·(2·n_f − 1).
pub fn flops_gemm_block(nf: usize, m: usize, n: usize) -> u64 {
    ops_mgemm_block(nf, m, n)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn two_way_counts_match_paper_formulas() {
        let (nf, nv) = (100, 20);
        let pairs = (nv * (nv - 1) / 2) as u64;
        assert_eq!(
            ops_2way_numerators(nf, nv),
            (nf as u64 - 1) * pairs + nf as u64 * pairs
        );
        assert_eq!(cmp_2way(nf, nv), nf as u64 * pairs);
        // ops ≈ 2 × comparisons (paper overlays these two series).
        let ratio = ops_2way_numerators(nf, nv) as f64 / cmp_2way(nf, nv) as f64;
        assert!((ratio - 2.0).abs() < 0.02, "ratio={ratio}");
    }

    #[test]
    fn three_way_counts_match_paper_formulas() {
        let (nf, nv) = (64, 12);
        let t = (nv * (nv - 1) * (nv - 2) / 6) as u64;
        assert_eq!(
            ops_3way_n3prime(nf, nv),
            (nf as u64 - 1) * t + 2 * nf as u64 * t
        );
        assert_eq!(cmp_3way(nf, nv), nf as u64 * t);
        // n3' ops ≈ 3 × comparisons; the total including 2-way startup is
        // a bit higher (the paper's Table 4 ratio is ≈2.36 because their
        // comparison count uses the full triple as the unit).
        assert!(ops_3way_total(nf, nv) > ops_3way_n3prime(nf, nv));
    }

    #[test]
    fn block_ops() {
        assert_eq!(ops_mgemm_block(2, 3, 4), 3 * 4 * 3);
        assert_eq!(ops_mgemm3_slab(2, 2, 3, 4), 2 * 3 * 4 * 5);
    }
}
