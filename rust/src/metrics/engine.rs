//! The metric engine: a [`Metric`] abstraction threaded through
//! linalg → backend → coordinator → output.
//!
//! A metric is the bundle the coordinator is generic over:
//!
//! * a **numerator kernel family** — which block kernel the backend
//!   runs (min-product mGEMM for Czekanowski, plain GEMM for CCC,
//!   AND+popcount over packed words for Sorensen);
//! * a **denominator precomputation** — the per-vector ingredient
//!   (column sums, popcounts) assembled on the coordinator side and
//!   allreduced across the n_pf axis, so it must be additive over
//!   feature slices;
//! * a **quotient combination** — how one metric value is assembled
//!   from a numerator entry and two (or three) denominators;
//! * an **element domain** — what the input vectors must look like for
//!   the metric to be meaningful;
//! * a **checksum contribution** — a per-metric salt folded into the
//!   §5 bit-for-bit checksum so runs of different metrics can never
//!   collide.
//!
//! Metrics:
//! * [`Czekanowski`] — the source paper's Proportional Similarity
//!   (2-way and 3-way), via the min-product mGEMM.
//! * [`Ccc`] — the Custom Correlation Coefficient of the companion
//!   paper (arXiv 1705.08213, Joubert/Nance/Climer/Weighill/Jacobson):
//!   same decomposition/pipeline machinery, GEMM numerators over
//!   allele-count vectors, nonlinear frequency-weighted combination.
//! * [`Sorenson`] — the §2.3 bit-packed Sorensen metric, promoted from
//!   an orphaned kernel into a first-class coordinated 2-way run:
//!   vectors are binarized and packed into words, numerators are
//!   AND+popcount (64 elementwise comparisons per word op, the Table 6
//!   trick).

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::config::RunConfig;
use crate::coordinator::backend::Backend;
use crate::linalg::{MatF64, SlabF64};
use crate::util::prng::mix64;
use crate::util::Scalar;
use crate::vecdata::bits::BitVectorSet;
use crate::vecdata::block::{Block, Repr};
use crate::vecdata::geno::GenoBlock;
use crate::vecdata::VectorSet;

use super::{c2_from_parts, c3_from_parts, ccc_from_parts};

/// Binarization threshold for [`Sorenson`] over real-valued inputs
/// (bit = value > threshold; 0/1 data is preserved exactly).
pub const SORENSON_BIT_THRESHOLD: f64 = 0.5;

/// Registry key for a metric family.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum MetricId {
    /// Proportional Similarity (Czekanowski), 2-way and 3-way.
    #[default]
    Czekanowski,
    /// Custom Correlation Coefficient (companion paper), 2-way.
    Ccc,
    /// Bit-packed Sorensen (§2.3 / Table 6), 2-way.
    Sorenson,
}

impl MetricId {
    /// Every registered metric (the registry the CLI help prints).
    pub const ALL: [MetricId; 3] = [MetricId::Czekanowski, MetricId::Ccc, MetricId::Sorenson];

    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "czekanowski" | "proportional" | "ps" => Ok(MetricId::Czekanowski),
            "ccc" => Ok(MetricId::Ccc),
            "sorenson" | "sorensen" => Ok(MetricId::Sorenson),
            other => bail!("unknown metric {other:?} (want czekanowski|ccc|sorenson)"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            MetricId::Czekanowski => "czekanowski",
            MetricId::Ccc => "ccc",
            MetricId::Sorenson => "sorenson",
        }
    }

    /// One-line registry description (CLI help / run banners).
    pub fn describe(self) -> &'static str {
        match self {
            MetricId::Czekanowski => {
                "Proportional Similarity via min-product mGEMM (2-way and 3-way)"
            }
            MetricId::Ccc => {
                "Custom Correlation Coefficient via GEMM over allele counts (2-way)"
            }
            MetricId::Sorenson => {
                "Sorensen via AND+popcount over bit-packed vectors (2-way)"
            }
        }
    }

    /// Which metric orders this family defines.
    pub fn supports_way(self, num_way: usize) -> bool {
        match self {
            MetricId::Czekanowski => num_way == 2 || num_way == 3,
            MetricId::Ccc | MetricId::Sorenson => num_way == 2,
        }
    }

    /// Per-metric checksum salt. Czekanowski is 0 so its digests are
    /// unchanged from the single-metric era.
    pub fn checksum_salt(self) -> u64 {
        match self {
            MetricId::Czekanowski => 0,
            MetricId::Ccc => mix64(0x1705_0821_3),
            MetricId::Sorenson => mix64(0x5023_0000_6),
        }
    }

    /// Element domain of this family (config validation pairs strict
    /// domains with compatible input generators).
    pub fn domain(self) -> Domain {
        match self {
            MetricId::Czekanowski => Domain::NonNegative,
            MetricId::Ccc => Domain::AlleleCounts,
            MetricId::Sorenson => Domain::Binary,
        }
    }

    /// Block representation this family's kernels consume. Bit-domain
    /// metrics cache packed bit-planes at ingest and exchange packed
    /// words on the wire: Sorensen one plane, CCC two allele planes
    /// (the 2-bit genotype encoding). Float families keep dense
    /// `VectorSet`s.
    pub fn preferred_repr(self) -> Repr {
        match self {
            MetricId::Czekanowski => Repr::Float,
            MetricId::Ccc => Repr::Packed2,
            MetricId::Sorenson => Repr::Packed,
        }
    }
}

/// Element domain a metric is defined over. Inputs are not policed
/// element-by-element, but config validation rejects synthetic
/// generators that cannot produce a strict domain (CCC over
/// non-allele data would silently compute meaningless frequencies).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Domain {
    /// Non-negative reals (min-product metrics).
    NonNegative,
    /// Allele counts {0, 1, 2} (2-bit genomics encodings) — strict.
    AlleleCounts,
    /// Binary 0/1; real inputs are thresholded by design.
    Binary,
}

/// A metric family at element type `T`: everything the coordinator
/// needs that is not generic across metrics. The two-way and three-way
/// node programs contain **no** metric-specific branches — they only
/// call through this trait.
pub trait Metric<T: Scalar>: Send + Sync {
    fn id(&self) -> MetricId;

    fn name(&self) -> &'static str {
        self.id().name()
    }

    fn domain(&self) -> Domain {
        self.id().domain()
    }

    /// Which representation this metric wants blocks in. Defaults to
    /// the registry entry; metrics returning [`Repr::Packed`] must
    /// override [`Metric::ingest`] as well (it owns the packing
    /// parameters, e.g. the binarization threshold).
    fn preferred_repr(&self) -> Repr {
        self.id().preferred_repr()
    }

    /// Cache discriminator for ingested blocks: two metric *instances*
    /// may share ingested blocks iff they agree on
    /// ([`Metric::preferred_repr`], `ingest_key`). Float families all
    /// return 0 (their ingest is representation-identity, so e.g.
    /// Czekanowski and CCC runs over one dataset share blocks);
    /// parameterized ingests (Sorensen's binarization threshold) must
    /// fold their parameters in, or a session could serve blocks packed
    /// under someone else's threshold.
    fn ingest_key(&self) -> u64 {
        0
    }

    /// Convert a freshly loaded float block into this metric's working
    /// representation. Called **once per node block** in the input
    /// phase — never inside the parallel step loop (the pack-once
    /// contract; `tests/comm_accounting.rs` counts packing calls).
    fn ingest(&self, v: VectorSet<T>) -> Block<T> {
        debug_assert_eq!(
            self.preferred_repr(),
            Repr::Float,
            "metric {} declares a packed repr but does not override ingest()",
            self.name()
        );
        Block::Float(Arc::new(v))
    }

    /// 2-way numerator block N[i, j] through the backend's kernel for
    /// this metric's family. Operands arrive in the representation
    /// [`Metric::ingest`] produced — packed metrics consume cached
    /// bit-planes directly, with zero per-call re-packing.
    fn numerators2(
        &self,
        backend: &dyn Backend<T>,
        w: &Block<T>,
        v: &Block<T>,
    ) -> Result<MatF64>;

    /// Diagonal-block 2-way numerators: the block paired with itself.
    /// The coordinator only reads the strict upper triangle, so metrics
    /// route this to the backend's symmetry-halved (triangular) kernel
    /// for their family — ~2× fewer elementwise ops on every diagonal
    /// block, with computed entries bit-identical to
    /// [`Metric::numerators2`]. The default falls back to the full
    /// square kernel.
    fn numerators2_diag(&self, backend: &dyn Backend<T>, v: &Block<T>) -> Result<MatF64> {
        self.numerators2(backend, v, v)
    }

    /// 3-way numerator slab (only metrics with a 3-way form).
    fn numerators3(
        &self,
        _backend: &dyn Backend<T>,
        _w: &Block<T>,
        _pivots: &Block<T>,
        _v: &Block<T>,
    ) -> Result<SlabF64> {
        bail!("metric {:?} has no 3-way form", self.name())
    }

    /// Diagonal-block 3-way slab: pivots are columns `pivot_locals` of
    /// `v` itself; the coordinator only reads slab[t, i, k] with
    /// i < pivot_locals[t] < k, so 3-way metrics route this to the
    /// backend's diag-aware slab kernel (redundant sub-slices skipped).
    fn numerators3_diag(
        &self,
        backend: &dyn Backend<T>,
        v: &Block<T>,
        pivots: &Block<T>,
        _pivot_locals: &[usize],
    ) -> Result<SlabF64> {
        self.numerators3(backend, v, pivots, v)
    }

    /// Per-vector denominator ingredients (Σv, popcount, …), computed
    /// on the coordinator side. Must be **additive across feature
    /// slices**: the n_pf axis allreduces these with a plain sum.
    /// Errors (not panics) on a representation mismatch, like
    /// [`Metric::numerators2`].
    fn denominators(&self, v: &Block<T>) -> Result<Vec<f64>>;

    /// Assemble one 2-way metric value from a numerator and the two
    /// vectors' denominator ingredients.
    fn combine2(&self, n: f64, d_i: f64, d_j: f64) -> f64;

    /// Assemble one 3-way metric value (only metrics with a 3-way
    /// form; config validation keeps 2-way-only metrics away from the
    /// 3-way coordinator).
    #[allow(clippy::too_many_arguments)]
    fn combine3(
        &self,
        _n2_ij: f64,
        _n2_ik: f64,
        _n2_jk: f64,
        _n3_prime: f64,
        _d_i: f64,
        _d_j: f64,
        _d_k: f64,
    ) -> f64 {
        unreachable!("metric {:?} has no 3-way form", self.name())
    }

    /// Salt folded into every checksum item hash for this metric.
    fn checksum_salt(&self) -> u64 {
        self.id().checksum_salt()
    }
}

/// Extract the float operand a float-family kernel needs. Blocks always
/// come from the same metric's [`Metric::ingest`], so a representation
/// mismatch is a coordinator bug, not a user error.
fn float_operand<'a, T: Scalar>(b: &'a Block<T>, metric: &str) -> Result<&'a VectorSet<T>> {
    match b.as_float() {
        Some(v) => Ok(v),
        None => bail!("metric {metric} expects float blocks, got a packed block"),
    }
}

/// Extract the packed operand a bitwise kernel needs.
fn packed_operand<'a, T: Scalar>(b: &'a Block<T>, metric: &str) -> Result<&'a BitVectorSet> {
    match b.as_packed() {
        Some(bits) => Ok(bits),
        None => bail!("metric {metric} expects packed blocks, got a float block"),
    }
}

/// Extract the 2-bit allele-plane operand the CCC plane kernels need.
fn packed2_operand<'a, T: Scalar>(b: &'a Block<T>, metric: &str) -> Result<&'a GenoBlock> {
    match b.as_packed2() {
        Some(g) => Ok(g),
        None => bail!(
            "metric {metric} expects packed2 blocks, got a {} block",
            b.repr().name()
        ),
    }
}

/// Proportional Similarity (the source paper's metric):
/// c2 = 2 n2 / (Σv_i + Σv_j), c3 per Eq. (1).
#[derive(Debug, Default, Clone, Copy)]
pub struct Czekanowski;

impl<T: Scalar> Metric<T> for Czekanowski {
    fn id(&self) -> MetricId {
        MetricId::Czekanowski
    }

    fn numerators2(
        &self,
        backend: &dyn Backend<T>,
        w: &Block<T>,
        v: &Block<T>,
    ) -> Result<MatF64> {
        backend.mgemm2(float_operand(w, "czekanowski")?, float_operand(v, "czekanowski")?)
    }

    fn numerators2_diag(&self, backend: &dyn Backend<T>, v: &Block<T>) -> Result<MatF64> {
        backend.mgemm2_diag(float_operand(v, "czekanowski")?)
    }

    fn numerators3(
        &self,
        backend: &dyn Backend<T>,
        w: &Block<T>,
        pivots: &Block<T>,
        v: &Block<T>,
    ) -> Result<SlabF64> {
        backend.mgemm3(
            float_operand(w, "czekanowski")?,
            float_operand(pivots, "czekanowski")?,
            float_operand(v, "czekanowski")?,
        )
    }

    fn numerators3_diag(
        &self,
        backend: &dyn Backend<T>,
        v: &Block<T>,
        pivots: &Block<T>,
        pivot_locals: &[usize],
    ) -> Result<SlabF64> {
        backend.mgemm3_diag(
            float_operand(v, "czekanowski")?,
            float_operand(pivots, "czekanowski")?,
            pivot_locals,
        )
    }

    fn denominators(&self, v: &Block<T>) -> Result<Vec<f64>> {
        Ok(float_operand(v, "czekanowski")?.col_sums())
    }

    fn combine2(&self, n: f64, d_i: f64, d_j: f64) -> f64 {
        c2_from_parts(n, d_i, d_j)
    }

    fn combine3(
        &self,
        n2_ij: f64,
        n2_ik: f64,
        n2_jk: f64,
        n3_prime: f64,
        d_i: f64,
        d_j: f64,
        d_k: f64,
    ) -> f64 {
        c3_from_parts(n2_ij, n2_ik, n2_jk, n3_prime, d_i, d_j, d_k)
    }
}

/// Custom Correlation Coefficient (companion paper, arXiv 1705.08213):
/// over allele-count vectors u, v ∈ {0, 1, 2}^n_f,
///
/// ```text
/// n(u,v) = Σ_q u_q v_q            (dot-product numerator)
/// f_i    = Σu / (2 n_f)           (allele frequency)
/// f_ij   = n / (4 n_f)            (co-occurrence frequency)
/// ccc    = (9/2) f_ij (1 − (2/3) f_i)(1 − (2/3) f_j)
/// ```
///
/// Blocks are ingested as 2-bit allele planes ([`Repr::Packed2`]): with
/// u = lo + 2·hi the numerator expands into four AND+popcount kernels,
///
/// ```text
/// n(u,v) = |lo_u ∧ lo_v| + 2 |lo_u ∧ hi_v| + 2 |hi_u ∧ lo_v|
///        + 4 |hi_u ∧ hi_v|
/// ```
///
/// and Σu = pop(lo) + 2·pop(hi). Every part is an exact small integer,
/// so results are **bit-identical** to the float-GEMM path over the
/// same {0, 1, 2} data while blocks travel and spill at 2 bits per
/// genotype call instead of a full float.
///
/// `nf` is the **global** feature count of the campaign: feature-sliced
/// (n_pf > 1) nodes hold partial numerators/sums that are allreduced
/// before combination, so the frequencies must be normalized by the
/// full depth.
#[derive(Debug, Clone, Copy)]
pub struct Ccc {
    pub nf: usize,
}

impl Ccc {
    pub fn new(nf: usize) -> Self {
        Ccc { nf }
    }
}

impl<T: Scalar> Metric<T> for Ccc {
    fn id(&self) -> MetricId {
        MetricId::Ccc
    }

    fn ingest_key(&self) -> u64 {
        // Parameter-free, but deliberately distinct from the float
        // identity ingest (key 0): CCC blocks are 2-bit allele planes
        // and must never alias a float-family cache entry.
        mix64(0x2b17_0ccc_2)
    }

    fn ingest(&self, v: VectorSet<T>) -> Block<T> {
        // The pack-once site for CCC: floats in {0, 1, 2} become two
        // bit-planes per node block, in the input phase only
        // (`geno::pack2_calls` counts packs; tests pin one per block).
        Block::Packed2(Arc::new(GenoBlock::from_floats(&v)))
    }

    fn numerators2(
        &self,
        backend: &dyn Backend<T>,
        w: &Block<T>,
        v: &Block<T>,
    ) -> Result<MatF64> {
        let w = packed2_operand(w, "ccc")?;
        let v = packed2_operand(v, "ccc")?;
        let ll = backend.sorenson2(&w.lo, &v.lo)?;
        let lh = backend.sorenson2(&w.lo, &v.hi)?;
        let hl = backend.sorenson2(&w.hi, &v.lo)?;
        let hh = backend.sorenson2(&w.hi, &v.hi)?;
        // All four parts are exact integers ≤ n_f, so this f64
        // combination is exact — bit-identical to the float GEMM.
        let mut n = ll;
        for (i, x) in n.data.iter_mut().enumerate() {
            *x += 2.0 * (lh.data[i] + hl.data[i]) + 4.0 * hh.data[i];
        }
        Ok(n)
    }

    fn numerators2_diag(&self, backend: &dyn Backend<T>, v: &Block<T>) -> Result<MatF64> {
        let g = packed2_operand(v, "ccc")?;
        // The symmetric plane pairs route to the triangular kernel; the
        // lo×hi cross term is not entrywise-symmetric (only the sum
        // lh[i,j] + lh[j,i] is), so it runs the full square kernel.
        let ll = backend.sorenson2_diag(&g.lo)?;
        let hh = backend.sorenson2_diag(&g.hi)?;
        let lh = backend.sorenson2(&g.lo, &g.hi)?;
        let mut n = ll;
        for i in 0..n.rows {
            for j in (i + 1)..n.cols {
                let x = n.at(i, j) + 2.0 * (lh.at(i, j) + lh.at(j, i)) + 4.0 * hh.at(i, j);
                n.set(i, j, x);
            }
        }
        Ok(n)
    }

    fn denominators(&self, v: &Block<T>) -> Result<Vec<f64>> {
        // Σu per vector = pop(lo) + 2·pop(hi), served from the plane
        // popcount caches primed at ingest — exactly the float path's
        // column sums over {0, 1, 2}.
        Ok(packed2_operand(v, "ccc")?.dose_sums())
    }

    fn combine2(&self, n: f64, d_i: f64, d_j: f64) -> f64 {
        ccc_from_parts(n, d_i, d_j, self.nf)
    }
}

/// Bit-packed Sorensen (§2.3): inputs are binarized at
/// [`SORENSON_BIT_THRESHOLD`] and packed into words **once at ingest**;
/// numerators are AND+popcount over the cached bit-planes; denominators
/// are popcounts of the same; the quotient is the Czekanowski form
/// restricted to bits, with a 0/0 → 0 guard for empty vectors.
#[derive(Debug, Clone, Copy)]
pub struct Sorenson {
    pub threshold: f64,
}

impl Default for Sorenson {
    fn default() -> Self {
        Sorenson { threshold: SORENSON_BIT_THRESHOLD }
    }
}

impl<T: Scalar> Metric<T> for Sorenson {
    fn id(&self) -> MetricId {
        MetricId::Sorenson
    }

    fn ingest_key(&self) -> u64 {
        // Two Sorensen instances share packed blocks only at the same
        // binarization threshold.
        self.threshold.to_bits()
    }

    fn ingest(&self, v: VectorSet<T>) -> Block<T> {
        // The only packing site on the run path: one conversion per
        // node block, in the input phase.
        Block::Packed(Arc::new(BitVectorSet::from_threshold(&v, self.threshold)))
    }

    fn numerators2(
        &self,
        backend: &dyn Backend<T>,
        w: &Block<T>,
        v: &Block<T>,
    ) -> Result<MatF64> {
        backend.sorenson2(packed_operand(w, "sorenson")?, packed_operand(v, "sorenson")?)
    }

    fn numerators2_diag(&self, backend: &dyn Backend<T>, v: &Block<T>) -> Result<MatF64> {
        backend.sorenson2_diag(packed_operand(v, "sorenson")?)
    }

    fn denominators(&self, v: &Block<T>) -> Result<Vec<f64>> {
        // Served from the block's popcount cache (primed at ingest by
        // `from_threshold`): repeated denominator passes over a cached
        // block cost a memcpy, not a word re-sweep per call.
        Ok(packed_operand(v, "sorenson")?.popcounts())
    }

    fn combine2(&self, n: f64, d_i: f64, d_j: f64) -> f64 {
        if d_i + d_j == 0.0 {
            0.0
        } else {
            c2_from_parts(n, d_i, d_j)
        }
    }
}

/// The registry: instantiate a metric for a run. CCC binds the
/// campaign's global n_f; Sorensen binds its binarization threshold.
pub fn make_metric<T: Scalar>(id: MetricId, cfg: &RunConfig) -> Arc<dyn Metric<T>> {
    match id {
        MetricId::Czekanowski => Arc::new(Czekanowski),
        MetricId::Ccc => Arc::new(Ccc::new(cfg.nf)),
        MetricId::Sorenson => Arc::new(Sorenson::default()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::{CpuOptimized, CpuReference};
    use crate::metrics;
    use crate::vecdata::SyntheticKind;

    #[test]
    fn registry_parse_roundtrip() {
        for id in MetricId::ALL {
            assert_eq!(MetricId::parse(id.name()).unwrap(), id);
            assert!(!id.describe().is_empty());
        }
        assert_eq!(MetricId::parse("sorensen").unwrap(), MetricId::Sorenson);
        assert!(MetricId::parse("pearson").is_err());
    }

    #[test]
    fn way_support() {
        assert!(MetricId::Czekanowski.supports_way(2));
        assert!(MetricId::Czekanowski.supports_way(3));
        assert!(MetricId::Ccc.supports_way(2));
        assert!(!MetricId::Ccc.supports_way(3));
        assert!(MetricId::Sorenson.supports_way(2));
        assert!(!MetricId::Sorenson.supports_way(3));
    }

    #[test]
    fn domains_match_families() {
        assert_eq!(MetricId::Czekanowski.domain(), Domain::NonNegative);
        assert_eq!(MetricId::Ccc.domain(), Domain::AlleleCounts);
        assert_eq!(MetricId::Sorenson.domain(), Domain::Binary);
        let m: &dyn Metric<f64> = &Czekanowski;
        assert_eq!(m.domain(), Domain::NonNegative);
    }

    #[test]
    fn checksum_salts_distinct() {
        assert_eq!(MetricId::Czekanowski.checksum_salt(), 0);
        assert_ne!(MetricId::Ccc.checksum_salt(), MetricId::Sorenson.checksum_salt());
        assert_ne!(MetricId::Ccc.checksum_salt(), 0);
        assert_ne!(MetricId::Sorenson.checksum_salt(), 0);
    }

    #[test]
    fn czekanowski_engine_matches_scalar_oracle() {
        let v: VectorSet<f64> = VectorSet::generate(SyntheticKind::RandomGrid, 3, 48, 8, 0);
        let m: &dyn Metric<f64> = &Czekanowski;
        let b = m.ingest(v.clone());
        let n = m.numerators2(&CpuOptimized::default(), &b, &b).unwrap();
        let d = m.denominators(&b).unwrap();
        for i in 0..v.nv {
            for j in 0..v.nv {
                let got = m.combine2(n.at(i, j), d[i], d[j]);
                let want = metrics::czekanowski2(v.col(i), v.col(j));
                assert!((got - want).abs() < 1e-12, "({i},{j})");
            }
        }
    }

    #[test]
    fn ccc_engine_matches_scalar_oracle() {
        let v: VectorSet<f64> = VectorSet::generate(SyntheticKind::Alleles, 5, 60, 9, 0);
        let ccc = Ccc::new(v.nf);
        let m: &dyn Metric<f64> = &ccc;
        let b = m.ingest(v.clone());
        let n = m.numerators2(&CpuOptimized::default(), &b, &b).unwrap();
        let d = m.denominators(&b).unwrap();
        for i in 0..v.nv {
            for j in 0..v.nv {
                let got = m.combine2(n.at(i, j), d[i], d[j]);
                let want = metrics::ccc2(v.col(i), v.col(j));
                assert_eq!(got, want, "({i},{j})"); // integer-valued parts: exact
            }
        }
    }

    #[test]
    fn ccc_value_range_on_alleles() {
        let v: VectorSet<f64> = VectorSet::generate(SyntheticKind::Alleles, 7, 128, 12, 0);
        let ccc = Ccc::new(v.nf);
        let m: &dyn Metric<f64> = &ccc;
        let b = m.ingest(v.clone());
        let n = m.numerators2(&CpuReference, &b, &b).unwrap();
        let d = m.denominators(&b).unwrap();
        for i in 0..v.nv {
            for j in 0..v.nv {
                let c = m.combine2(n.at(i, j), d[i], d[j]);
                assert!((0.0..=1.0 + 1e-12).contains(&c), "ccc({i},{j}) = {c}");
            }
        }
    }

    #[test]
    fn sorenson_engine_matches_bit_oracle() {
        let bits = BitVectorSet::generate(9, 130, 10, 0.4);
        let v = bits.to_floats();
        let sor = Sorenson::default();
        let m: &dyn Metric<f64> = &sor;
        let b = m.ingest(v.clone());
        let n = m.numerators2(&CpuOptimized::default(), &b, &b).unwrap();
        let d = m.denominators(&b).unwrap();
        for i in 0..v.nv {
            for j in 0..v.nv {
                let got = m.combine2(n.at(i, j), d[i], d[j]);
                assert_eq!(got, bits.sorenson2(i, j), "({i},{j})");
            }
        }
    }

    #[test]
    fn sorenson_reference_and_optimized_backends_agree() {
        let bits = BitVectorSet::generate(11, 97, 8, 0.3);
        let v = bits.to_floats();
        let sor = Sorenson::default();
        let m: &dyn Metric<f64> = &sor;
        let b = m.ingest(v);
        let a = m.numerators2(&CpuReference, &b, &b).unwrap();
        let o = m.numerators2(&CpuOptimized::default(), &b, &b).unwrap();
        assert_eq!(a.max_abs_diff(&o), 0.0);
    }

    #[test]
    fn preferred_reprs_per_family() {
        use crate::vecdata::block::Repr;
        assert_eq!(MetricId::Czekanowski.preferred_repr(), Repr::Float);
        assert_eq!(MetricId::Ccc.preferred_repr(), Repr::Packed2);
        assert_eq!(MetricId::Sorenson.preferred_repr(), Repr::Packed);
        let m: &dyn Metric<f64> = &Sorenson::default();
        assert_eq!(m.preferred_repr(), Repr::Packed);
        assert_eq!(Repr::Float.name(), "float");
        assert_eq!(Repr::Packed.name(), "packed");
        assert_eq!(Repr::Packed2.name(), "packed2");
    }

    #[test]
    fn ingest_produces_the_preferred_repr() {
        let v: VectorSet<f64> = VectorSet::generate(SyntheticKind::RandomGrid, 2, 70, 4, 8);
        for id in MetricId::ALL {
            let cfg = RunConfig { nf: 70, ..Default::default() };
            let m = make_metric::<f64>(id, &cfg);
            let b = m.ingest(v.clone());
            assert_eq!(b.repr(), m.preferred_repr(), "{}", id.name());
            assert_eq!((b.nf(), b.nv(), b.first_id()), (70, 4, 8), "{}", id.name());
        }
    }

    #[test]
    fn repr_mismatch_is_rejected_not_miscomputed() {
        let v: VectorSet<f64> = VectorSet::generate(SyntheticKind::RandomGrid, 2, 64, 4, 0);
        let sor_metric = Sorenson::default();
        let sor: &dyn Metric<f64> = &sor_metric;
        let cz: &dyn Metric<f64> = &Czekanowski;
        let float_block = cz.ingest(v.clone());
        let packed_block = sor.ingest(v);
        let err = sor.numerators2(&CpuOptimized::default(), &float_block, &float_block).unwrap_err();
        assert!(err.to_string().contains("expects packed"), "{err}");
        let err = cz.numerators2(&CpuOptimized::default(), &packed_block, &packed_block).unwrap_err();
        assert!(err.to_string().contains("expects float"), "{err}");
        // Denominators fail the same way — an error, not a panic.
        assert!(sor.denominators(&float_block).is_err());
        assert!(cz.denominators(&packed_block).is_err());
        // CCC consumes neither floats nor single-plane packed blocks.
        let ccc_metric = Ccc::new(64);
        let ccc: &dyn Metric<f64> = &ccc_metric;
        let err = ccc
            .numerators2(&CpuOptimized::default(), &float_block, &float_block)
            .unwrap_err();
        assert!(err.to_string().contains("expects packed2"), "{err}");
        let err = ccc.denominators(&packed_block).unwrap_err();
        assert!(err.to_string().contains("expects packed2"), "{err}");
    }

    #[test]
    fn diag_numerators_match_full_upper_triangle_for_all_metrics() {
        let cfg = RunConfig { nf: 70, ..Default::default() };
        for id in MetricId::ALL {
            let kind = match id.domain() {
                Domain::AlleleCounts => SyntheticKind::Alleles,
                _ => SyntheticKind::RandomGrid,
            };
            let v: VectorSet<f64> = VectorSet::generate(kind, 6, 70, 11, 0);
            let m = make_metric::<f64>(id, &cfg);
            let b = m.ingest(v);
            for backend in [&CpuReference as &dyn Backend<f64>, &CpuOptimized::default()] {
                let full = m.numerators2(backend, &b, &b).unwrap();
                let diag = m.numerators2_diag(backend, &b).unwrap();
                for i in 0..11 {
                    for j in (i + 1)..11 {
                        assert_eq!(
                            diag.at(i, j).to_bits(),
                            full.at(i, j).to_bits(),
                            "{} ({i},{j})",
                            id.name()
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn ingest_keys_discriminate_parameterized_ingests_only() {
        // Float families use the identity ingest (key 0) …
        let cz: &dyn Metric<f64> = &Czekanowski;
        assert_eq!(cz.ingest_key(), 0);
        // … CCC's plane-packing ingest is parameter-free but keyed away
        // from the float identity (instances still share blocks) …
        let ccc_a = Ccc::new(10);
        let ccc_b = Ccc::new(99);
        let ccc: &dyn Metric<f64> = &ccc_a;
        assert_ne!(ccc.ingest_key(), 0);
        assert_eq!(
            Metric::<f64>::ingest_key(&ccc_a),
            Metric::<f64>::ingest_key(&ccc_b)
        );
        // … while Sorensen instances share only at equal thresholds.
        let a = Sorenson { threshold: 0.5 };
        let b = Sorenson { threshold: 0.25 };
        assert_eq!(
            Metric::<f64>::ingest_key(&a),
            Metric::<f64>::ingest_key(&Sorenson::default())
        );
        assert_ne!(Metric::<f64>::ingest_key(&a), Metric::<f64>::ingest_key(&b));
        assert_ne!(ccc.ingest_key(), Metric::<f64>::ingest_key(&a));
    }

    #[test]
    fn ccc_packed_numerators_match_float_gemm_bitwise() {
        // The plane-composed numerators and cached-popcount
        // denominators must be bit-identical to the float path they
        // replaced — not merely close.
        let v: VectorSet<f64> = VectorSet::generate(SyntheticKind::Alleles, 13, 70, 9, 0);
        let ccc = Ccc::new(v.nf);
        let m: &dyn Metric<f64> = &ccc;
        let b = m.ingest(v.clone());
        let backend = CpuOptimized::default();
        let packed = m.numerators2(&backend, &b, &b).unwrap();
        let float = backend.gemm2(&v, &v).unwrap();
        for i in 0..v.nv {
            for j in 0..v.nv {
                assert_eq!(
                    packed.at(i, j).to_bits(),
                    float.at(i, j).to_bits(),
                    "({i},{j})"
                );
            }
        }
        assert_eq!(m.denominators(&b).unwrap(), v.col_sums());
    }

    #[test]
    fn sorenson_empty_vectors_give_zero() {
        let sor = Sorenson::default();
        let m: &dyn Metric<f64> = &sor;
        assert_eq!(m.combine2(0.0, 0.0, 0.0), 0.0);
    }

    #[test]
    fn make_metric_binds_config() {
        let cfg = RunConfig { nf: 77, ..Default::default() };
        let m = make_metric::<f64>(MetricId::Ccc, &cfg);
        assert_eq!(m.id(), MetricId::Ccc);
        assert_eq!(m.name(), "ccc");
        // Frequencies must be normalized by the configured global nf:
        // a full numerator over nf features combines to the same value
        // as the scalar oracle on nf-long vectors.
        let v: VectorSet<f64> = VectorSet::generate(SyntheticKind::Alleles, 1, 77, 2, 0);
        let want = metrics::ccc2(v.col(0), v.col(1));
        let n = metrics::n_dot(v.col(0), v.col(1));
        let d = m.denominators(&m.ingest(v.clone())).unwrap();
        assert_eq!(m.combine2(n, d[0], d[1]), want);
    }

    #[test]
    fn numerators3_rejected_for_2way_metrics() {
        let v: VectorSet<f64> = VectorSet::generate(SyntheticKind::Alleles, 1, 16, 3, 0);
        let ccc = Ccc::new(16);
        let m: &dyn Metric<f64> = &ccc;
        let b = m.ingest(v);
        let err = m.numerators3(&CpuReference, &b, &b, &b).unwrap_err();
        assert!(err.to_string().contains("3-way"));
    }
}
