//! Native (host) mGEMM implementations — the paper's CPU comparators.
//!
//! The paper ships three versions of every method: "a reference
//! (CPU-only) version, a (possibly optimized) CPU version, and a GPU
//! version" (§5). Here:
//!
//! * [`reference`] — straight triple loop, no blocking: the correctness
//!   baseline and the "CPU" row of Table 2.
//! * [`optimized`] — cache-blocked, accumulator-tiled, autovectorizable:
//!   the optimized CPU comparator (and the fallback backend when no
//!   artifacts are built).
//! * [`sorenson`] — the bit-packed popcount path (§2.3 / Table 6).
//! * [`opcount`] — process-wide elementwise-operation accounting
//!   (proves the triangular diag-block halving in tests/benches).
//!
//! Every family ships a symmetry-halved `*_tri` variant (strict upper
//! triangle of a self-block, §4's redundancy elimination) and an `*_mt`
//! thread-parallel variant (row panels / slab planes partitioned over
//! independent output tiles — bit-identical across thread counts).
//!
//! All operate on column-major [`VectorSet`]s and produce row-major
//! outputs `out[i * n + j]` matching the artifact output layout.

pub mod opcount;
pub mod optimized;
pub mod reference;
pub mod sorenson;

use crate::util::Scalar;
use crate::vecdata::VectorSet;

/// Near-equal contiguous ranges covering `0..total` for `parts`
/// workers (empty ranges dropped) — the row/plane partition every
/// `*_mt` kernel shares.
pub(crate) fn split_rows(total: usize, parts: usize) -> Vec<std::ops::Range<usize>> {
    let parts = parts.clamp(1, total.max(1));
    let base = total / parts;
    let extra = total % parts;
    let mut ranges = Vec::with_capacity(parts);
    let mut start = 0;
    for p in 0..parts {
        let len = base + usize::from(p < extra);
        if len > 0 {
            ranges.push(start..start + len);
            start += len;
        }
    }
    ranges
}

/// Run `f` over contiguous chunks of `total` output rows (or slab
/// planes) of `unit` elements each, on up to `threads` scoped OS
/// threads. Each invocation owns a disjoint `&mut` slice of `data`, so
/// the parallelism needs no synchronization and cannot reorder any
/// element's accumulation — the substrate of the `*_mt` kernels'
/// bit-identity-across-thread-counts contract.
pub(crate) fn par_chunks<F>(data: &mut [f64], unit: usize, total: usize, threads: usize, f: F)
where
    F: Fn(std::ops::Range<usize>, &mut [f64]) + Sync,
{
    debug_assert_eq!(data.len(), unit * total, "chunk geometry mismatch");
    if threads <= 1 || total < 2 {
        f(0..total, data);
        return;
    }
    let ranges = split_rows(total, threads);
    std::thread::scope(|s| {
        let mut rest = data;
        for r in ranges {
            let (chunk, tail) = rest.split_at_mut((r.end - r.start) * unit);
            rest = tail;
            let f = &f;
            s.spawn(move || f(r, chunk));
        }
    });
}

/// Dense row-major result matrix from an mGEMM block: out[i, j] =
/// n2(w_i, v_j), dims m × n.
#[derive(Debug, Clone, PartialEq)]
pub struct MatF64 {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl MatF64 {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        MatF64 {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f64 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        self.data[i * self.cols + j] = v;
    }

    /// Max |a - b| over entries (test helper).
    pub fn max_abs_diff(&self, other: &MatF64) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }
}

/// Dense row-major jt × m × n slab from a 3-way block:
/// slab[t, i, k] = n3'(w_i, pivot_t, v_k).
#[derive(Debug, Clone, PartialEq)]
pub struct SlabF64 {
    pub jt: usize,
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl SlabF64 {
    pub fn zeros(jt: usize, rows: usize, cols: usize) -> Self {
        SlabF64 {
            jt,
            rows,
            cols,
            data: vec![0.0; jt * rows * cols],
        }
    }

    #[inline]
    pub fn at(&self, t: usize, i: usize, k: usize) -> f64 {
        self.data[(t * self.rows + i) * self.cols + k]
    }

    #[inline]
    pub fn set(&mut self, t: usize, i: usize, k: usize, v: f64) {
        self.data[(t * self.rows + i) * self.cols + k] = v;
    }

    pub fn max_abs_diff(&self, other: &SlabF64) -> f64 {
        assert_eq!(
            (self.jt, self.rows, self.cols),
            (other.jt, other.rows, other.cols)
        );
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }
}

/// Convenience: reference mGEMM2 over full sets (tests/benches).
pub fn mgemm2_ref<T: Scalar>(w: &VectorSet<T>, v: &VectorSet<T>) -> MatF64 {
    reference::mgemm2(w, v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mat_indexing() {
        let mut m = MatF64::zeros(2, 3);
        m.set(1, 2, 5.0);
        assert_eq!(m.at(1, 2), 5.0);
        assert_eq!(m.data[5], 5.0);
    }

    #[test]
    fn slab_indexing() {
        let mut s = SlabF64::zeros(2, 3, 4);
        s.set(1, 2, 3, 7.0);
        assert_eq!(s.at(1, 2, 3), 7.0);
        assert_eq!(s.data[(1 * 3 + 2) * 4 + 3], 7.0);
    }

    #[test]
    fn max_abs_diff_works() {
        let mut a = MatF64::zeros(2, 2);
        let mut b = MatF64::zeros(2, 2);
        a.set(0, 0, 1.0);
        b.set(0, 0, 1.5);
        assert_eq!(a.max_abs_diff(&b), 0.5);
    }

    #[test]
    fn split_rows_covers_everything_contiguously() {
        for (rows, parts) in [(10usize, 3usize), (1, 4), (0, 2), (7, 7), (64, 5)] {
            let ranges = split_rows(rows, parts);
            let mut next = 0;
            for r in &ranges {
                assert_eq!(r.start, next);
                assert!(r.end > r.start);
                next = r.end;
            }
            assert_eq!(next, rows);
        }
    }

    #[test]
    fn par_chunks_visits_disjoint_ranges_once() {
        let (unit, total) = (3usize, 10usize);
        let mut data = vec![0.0f64; unit * total];
        par_chunks(&mut data, unit, total, 4, |rows, chunk| {
            for (off, x) in chunk.iter_mut().enumerate() {
                *x += (rows.start * unit + off) as f64 + 1.0;
            }
        });
        // Every element written exactly once with its global index + 1.
        for (i, x) in data.iter().enumerate() {
            assert_eq!(*x, i as f64 + 1.0);
        }
    }
}
