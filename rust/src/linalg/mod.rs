//! Native (host) mGEMM implementations — the paper's CPU comparators.
//!
//! The paper ships three versions of every method: "a reference
//! (CPU-only) version, a (possibly optimized) CPU version, and a GPU
//! version" (§5). Here:
//!
//! * [`reference`] — straight triple loop, no blocking: the correctness
//!   baseline and the "CPU" row of Table 2.
//! * [`optimized`] — cache-blocked, accumulator-tiled, autovectorizable:
//!   the optimized CPU comparator (and the fallback backend when no
//!   artifacts are built).
//! * [`sorenson`] — the bit-packed popcount path (§2.3 / Table 6).
//! * [`opcount`] — process-wide elementwise-operation accounting
//!   (proves the triangular diag-block halving in tests/benches).
//!
//! * [`simd`] — lane-shaped inner kernels (wide u64 popcount sweeps,
//!   q-major tile packing) shared by the optimized/sorenson paths.
//! * [`pool`] — the persistent worker pool the `*_mt` drivers dispatch
//!   through (zero per-kernel-call thread spawns once warm).
//!
//! Every family ships a symmetry-halved `*_tri` variant (strict upper
//! triangle of a self-block, §4's redundancy elimination) and an `*_mt`
//! thread-parallel variant (row panels / slab planes partitioned over
//! independent output tiles — bit-identical across thread counts).
//!
//! All operate on column-major [`VectorSet`]s and produce row-major
//! outputs `out[i * n + j]` matching the artifact output layout.

pub mod opcount;
pub mod optimized;
pub mod pool;
pub mod reference;
pub mod simd;
pub mod sorenson;

use crate::util::Scalar;
use crate::vecdata::VectorSet;

/// Near-equal contiguous ranges covering `0..total` for `parts`
/// workers (empty ranges dropped) — the row/plane partition every
/// `*_mt` kernel shares.
pub(crate) fn split_rows(total: usize, parts: usize) -> Vec<std::ops::Range<usize>> {
    let parts = parts.clamp(1, total.max(1));
    let base = total / parts;
    let extra = total % parts;
    let mut ranges = Vec::with_capacity(parts);
    let mut start = 0;
    for p in 0..parts {
        let len = base + usize::from(p < extra);
        if len > 0 {
            ranges.push(start..start + len);
            start += len;
        }
    }
    ranges
}

/// Run `f` over contiguous chunks of `total` output rows (or slab
/// planes) of `unit` elements each, on up to `threads` workers of the
/// persistent [`pool`] (scoped OS threads before the pool existed —
/// every multi-threaded kernel call paid spawn + join). Each invocation
/// owns a disjoint `&mut` slice of `data`, so the parallelism needs no
/// synchronization and cannot reorder any element's accumulation — the
/// substrate of the `*_mt` kernels' bit-identity-across-thread-counts
/// contract, unchanged by the pool (the partition and the per-chunk
/// work are identical; only who executes them moved).
pub(crate) fn par_chunks<F>(data: &mut [f64], unit: usize, total: usize, threads: usize, f: F)
where
    F: Fn(std::ops::Range<usize>, &mut [f64]) + Sync,
{
    debug_assert_eq!(data.len(), unit * total, "chunk geometry mismatch");
    if threads <= 1 || total < 2 {
        f(0..total, data);
        return;
    }
    let ranges = split_rows(total, threads);
    let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(ranges.len());
    let mut rest = data;
    let f = &f;
    for r in ranges {
        let (chunk, tail) = rest.split_at_mut((r.end - r.start) * unit);
        rest = tail;
        tasks.push(Box::new(move || f(r, chunk)));
    }
    pool::global().scope(tasks);
}

/// The row bands backing [`tri_partition`] / [`par_chunks_tri`]:
/// `0..total` cut into (up to) `2 × workers` near-equal contiguous
/// bands, in row order.
fn tri_bands(total: usize, workers: usize) -> Vec<std::ops::Range<usize>> {
    split_rows(total, 2 * workers.max(1))
}

/// Load-balanced row partition for strict-upper-triangle work: worker
/// t gets band t **and** band 2T−1−t of [`tri_bands`]. Row i of a
/// strict upper triangle computes `total − 1 − i` entries, so the
/// plain contiguous partition leaves the first (lowest-row) worker
/// with ~(2T−1)× the last worker's ops; pairing the t-th cheapest band
/// with the t-th most expensive flattens every worker to ~1/T of the
/// triangle (exact to within one band's rows). Returned per worker in
/// row order; workers with an empty second half (odd band counts at
/// tiny `total`) get one range.
pub fn tri_partition(total: usize, workers: usize) -> Vec<Vec<std::ops::Range<usize>>> {
    let bands = tri_bands(total, workers);
    let b = bands.len();
    let mut out: Vec<Vec<std::ops::Range<usize>>> = vec![Vec::new(); b.div_ceil(2)];
    for (idx, r) in bands.into_iter().enumerate() {
        out[idx.min(b - 1 - idx)].push(r);
    }
    out
}

/// [`par_chunks`] for triangular (diagonal-block) kernels: same
/// disjoint-`&mut`-slice discipline and per-element bit-identity, but
/// each worker owns exactly the ranges [`tri_partition`] assigns it
/// (the low+high band pairing) instead of one contiguous chunk, so the
/// strict-upper-triangle op count is balanced across workers (pinned
/// analytically by `opcount::ops_tri_rows` in
/// tests/triangular_threads.rs — against the same `tri_partition` this
/// consumes, so the pinned partition and the executed one cannot
/// drift).
pub(crate) fn par_chunks_tri<F>(data: &mut [f64], unit: usize, total: usize, threads: usize, f: F)
where
    F: Fn(std::ops::Range<usize>, &mut [f64]) + Sync,
{
    debug_assert_eq!(data.len(), unit * total, "chunk geometry mismatch");
    if threads <= 1 || total < 2 {
        f(0..total, data);
        return;
    }
    let assignment = tri_partition(total, threads);
    // Cut the output into per-band chunks in row order (the bands are
    // the assignment's ranges), then hand each worker its own ranges.
    let mut bands: Vec<std::ops::Range<usize>> =
        assignment.iter().flatten().cloned().collect();
    bands.sort_by_key(|r| r.start);
    let mut chunks = Vec::with_capacity(bands.len());
    let mut rest = data;
    for r in bands {
        let (chunk, tail) = rest.split_at_mut((r.end - r.start) * unit);
        rest = tail;
        chunks.push(Some((r, chunk)));
    }
    let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(assignment.len());
    for ranges in &assignment {
        let mut own = Vec::with_capacity(ranges.len());
        for r in ranges {
            let idx = chunks
                .iter()
                .position(|c| c.as_ref().is_some_and(|(cr, _)| cr == r))
                .expect("assignment range has a band chunk");
            own.push(chunks[idx].take().expect("band taken once"));
        }
        let f = &f;
        tasks.push(Box::new(move || {
            for (r, chunk) in own {
                f(r, chunk);
            }
        }));
    }
    pool::global().scope(tasks);
}

/// Dense row-major result matrix from an mGEMM block: out[i, j] =
/// n2(w_i, v_j), dims m × n.
#[derive(Debug, Clone, PartialEq)]
pub struct MatF64 {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl MatF64 {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        MatF64 {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f64 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        self.data[i * self.cols + j] = v;
    }

    /// Max |a - b| over entries (test helper).
    pub fn max_abs_diff(&self, other: &MatF64) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }
}

/// Dense row-major jt × m × n slab from a 3-way block:
/// slab[t, i, k] = n3'(w_i, pivot_t, v_k).
#[derive(Debug, Clone, PartialEq)]
pub struct SlabF64 {
    pub jt: usize,
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl SlabF64 {
    pub fn zeros(jt: usize, rows: usize, cols: usize) -> Self {
        SlabF64 {
            jt,
            rows,
            cols,
            data: vec![0.0; jt * rows * cols],
        }
    }

    #[inline]
    pub fn at(&self, t: usize, i: usize, k: usize) -> f64 {
        self.data[(t * self.rows + i) * self.cols + k]
    }

    #[inline]
    pub fn set(&mut self, t: usize, i: usize, k: usize, v: f64) {
        self.data[(t * self.rows + i) * self.cols + k] = v;
    }

    pub fn max_abs_diff(&self, other: &SlabF64) -> f64 {
        assert_eq!(
            (self.jt, self.rows, self.cols),
            (other.jt, other.rows, other.cols)
        );
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }
}

/// Convenience: reference mGEMM2 over full sets (tests/benches).
pub fn mgemm2_ref<T: Scalar>(w: &VectorSet<T>, v: &VectorSet<T>) -> MatF64 {
    reference::mgemm2(w, v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mat_indexing() {
        let mut m = MatF64::zeros(2, 3);
        m.set(1, 2, 5.0);
        assert_eq!(m.at(1, 2), 5.0);
        assert_eq!(m.data[5], 5.0);
    }

    #[test]
    fn slab_indexing() {
        let mut s = SlabF64::zeros(2, 3, 4);
        s.set(1, 2, 3, 7.0);
        assert_eq!(s.at(1, 2, 3), 7.0);
        assert_eq!(s.data[(1 * 3 + 2) * 4 + 3], 7.0);
    }

    #[test]
    fn max_abs_diff_works() {
        let mut a = MatF64::zeros(2, 2);
        let mut b = MatF64::zeros(2, 2);
        a.set(0, 0, 1.0);
        b.set(0, 0, 1.5);
        assert_eq!(a.max_abs_diff(&b), 0.5);
    }

    #[test]
    fn split_rows_covers_everything_contiguously() {
        for (rows, parts) in [(10usize, 3usize), (1, 4), (0, 2), (7, 7), (64, 5)] {
            let ranges = split_rows(rows, parts);
            let mut next = 0;
            for r in &ranges {
                assert_eq!(r.start, next);
                assert!(r.end > r.start);
                next = r.end;
            }
            assert_eq!(next, rows);
        }
    }

    #[test]
    fn par_chunks_visits_disjoint_ranges_once() {
        let (unit, total) = (3usize, 10usize);
        let mut data = vec![0.0f64; unit * total];
        par_chunks(&mut data, unit, total, 4, |rows, chunk| {
            for (off, x) in chunk.iter_mut().enumerate() {
                *x += (rows.start * unit + off) as f64 + 1.0;
            }
        });
        // Every element written exactly once with its global index + 1.
        for (i, x) in data.iter().enumerate() {
            assert_eq!(*x, i as f64 + 1.0);
        }
    }

    #[test]
    fn tri_partition_covers_rows_exactly_once_and_balances_ops() {
        for (total, workers) in [(64usize, 4usize), (63, 4), (7, 4), (100, 3), (2, 8), (33, 1)] {
            let parts = tri_partition(total, workers);
            assert!(parts.len() <= workers.max(1));
            // Coverage: every row in exactly one worker's ranges.
            let mut seen = vec![0u32; total];
            for ranges in &parts {
                for r in ranges {
                    for i in r.clone() {
                        seen[i] += 1;
                    }
                }
            }
            assert!(seen.iter().all(|&c| c == 1), "({total},{workers}): {seen:?}");
            // Balance: per-worker strict-upper-triangle entry counts
            // within one band's worth of the ideal share.
            if parts.len() == workers && total >= 2 * workers {
                let ops: Vec<u64> = parts
                    .iter()
                    .map(|ranges| {
                        ranges
                            .iter()
                            .flat_map(|r| r.clone())
                            .map(|i| (total - 1 - i) as u64)
                            .sum()
                    })
                    .collect();
                let ideal = (total as u64 * (total as u64 - 1) / 2) as f64 / workers as f64;
                let band = total.div_ceil(2 * workers) as u64 * total as u64;
                for (w, &o) in ops.iter().enumerate() {
                    assert!(
                        (o as f64 - ideal).abs() <= band as f64,
                        "({total},{workers}) worker {w}: {o} vs ideal {ideal} (±{band})"
                    );
                }
                // And strictly better than the contiguous split's
                // heaviest worker for real shapes (1 worker: identical).
                if workers > 1 {
                    let contiguous_first: u64 = split_rows(total, workers)[0]
                        .clone()
                        .map(|i| (total - 1 - i) as u64)
                        .sum();
                    assert!(
                        ops.iter().copied().max().unwrap() < contiguous_first,
                        "({total},{workers}): paired max {:?} !< contiguous first {contiguous_first}",
                        ops.iter().copied().max().unwrap()
                    );
                }
            }
        }
    }

    #[test]
    fn par_chunks_tri_visits_disjoint_ranges_once() {
        for (total, threads) in [(11usize, 4usize), (64, 3), (5, 8), (2, 2)] {
            let unit = 2usize;
            let mut data = vec![0.0f64; unit * total];
            par_chunks_tri(&mut data, unit, total, threads, |rows, chunk| {
                for (off, x) in chunk.iter_mut().enumerate() {
                    *x += (rows.start * unit + off) as f64 + 1.0;
                }
            });
            for (i, x) in data.iter().enumerate() {
                assert_eq!(*x, i as f64 + 1.0, "({total},{threads})");
            }
        }
    }
}
