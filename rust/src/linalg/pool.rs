//! Persistent worker pool for the `*_mt` kernel drivers.
//!
//! Before this module every multi-threaded kernel call paid OS thread
//! spawn + join through `std::thread::scope` — fine for one big batch
//! kernel, hostile to the session/serving layer where many small
//! kernel calls arrive back-to-back (a 2-way ring step per node per
//! stage). Here the threads are spawned **once per process** and
//! parked on a condvar; a kernel call enqueues its row-panel closures
//! and blocks until they drain. Steady state does zero spawns: the
//! "zero per-kernel-call thread spawns" contract is pinned by
//! [`stats`] deltas in `tests/simd_pool.rs` and surfaced per run in
//! `coordinator::RunStats`.
//!
//! Design notes:
//!
//! * **std only** — a `Mutex<VecDeque>` + `Condvar` shared queue (not
//!   `Mutex<Receiver>`: holding a lock across `recv` would serialize
//!   wakeups), workers grown on demand to the largest parallelism any
//!   scope has asked for, never torn down (process-lifetime pool).
//! * **Borrowed closures** — kernel tasks borrow the caller's operands
//!   and disjoint `&mut` output panels. [`WorkerPool::scope`] erases
//!   their lifetime to hand them to the long-lived workers, and is
//!   sound because it *always* blocks until every submitted task has
//!   finished (a panicking task still decrements the pending count via
//!   its completion guard) — no task can outlive the borrows it
//!   captures.
//! * **Panic propagation** — worker panics are caught per task
//!   (`catch_unwind`) so a poisoned closure cannot kill a pool thread;
//!   the scope re-panics in the caller after draining, preserving the
//!   `std::thread::scope` failure surface the tests rely on.
//! * **No work-stealing, no caller execution** — tasks are coarse row
//!   panels already balanced by `linalg::{split_rows, tri_partition}`;
//!   the caller parks until completion, exactly like the scoped-spawn
//!   code it replaces.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

type Task = Box<dyn FnOnce() + Send + 'static>;

/// Cumulative pool counters (process-wide, monotone). Deltas across a
/// region of interest give per-run / per-call dispatch accounting.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct PoolStats {
    /// Parallel scopes entered ([`WorkerPool::scope`] calls that
    /// actually dispatched to workers).
    pub scopes: u64,
    /// Tasks executed by pool workers.
    pub tasks: u64,
    /// OS threads spawned (grows to the high-water parallelism, then
    /// stays flat — the amortization signal).
    pub threads_spawned: u64,
    /// Fire-and-forget tasks enqueued via [`WorkerPool::submit`]
    /// (read-ahead threads).
    pub submits: u64,
    /// Workers currently alive.
    pub workers: usize,
}

struct Shared {
    queue: VecDeque<Task>,
    workers: usize,
    /// Widest scope ever dispatched — the worker head-room
    /// [`WorkerPool::submit`] must preserve on top of async occupancy.
    scope_high_water: usize,
}

struct Counters {
    scopes: u64,
    tasks: u64,
    threads_spawned: u64,
    submits: u64,
}

/// A persistent pool of parked worker threads. One global instance
/// ([`global`]) serves every kernel call in the process; constructing
/// private pools is possible for tests.
pub struct WorkerPool {
    shared: Mutex<Shared>,
    work_cv: Condvar,
    counters: Mutex<Counters>,
    /// Fire-and-forget tasks currently alive ([`WorkerPool::submit`]) —
    /// long-lived occupants the worker count must stay ahead of so
    /// blocking kernel scopes can never be starved by them.
    async_active: AtomicUsize,
}

struct ScopeState {
    pending: Mutex<usize>,
    done_cv: Condvar,
    panicked: AtomicBool,
}

impl Default for WorkerPool {
    fn default() -> Self {
        Self::new()
    }
}

impl WorkerPool {
    pub fn new() -> Self {
        WorkerPool {
            shared: Mutex::new(Shared {
                queue: VecDeque::new(),
                workers: 0,
                scope_high_water: 0,
            }),
            work_cv: Condvar::new(),
            counters: Mutex::new(Counters {
                scopes: 0,
                tasks: 0,
                threads_spawned: 0,
                submits: 0,
            }),
            async_active: AtomicUsize::new(0),
        }
    }

    /// Grow the pool to at least `n` workers (no-op when already
    /// there). Called by [`WorkerPool::scope`] per dispatch and by
    /// warm-up paths (`session::Session` / CLI) that want the spawn
    /// cost paid before the first kernel call.
    pub fn ensure_workers(self: &Arc<Self>, n: usize) {
        let mut shared = self.shared.lock().unwrap();
        while shared.workers < n {
            let idx = shared.workers;
            let pool = Arc::clone(self);
            std::thread::Builder::new()
                .name(format!("comet-pool-{idx}"))
                .spawn(move || pool.worker_loop())
                .expect("spawn pool worker");
            shared.workers += 1;
            self.counters.lock().unwrap().threads_spawned += 1;
        }
    }

    fn worker_loop(&self) {
        loop {
            let task = {
                let mut shared = self.shared.lock().unwrap();
                loop {
                    if let Some(t) = shared.queue.pop_front() {
                        break t;
                    }
                    shared = self.work_cv.wait(shared).unwrap();
                }
            };
            task();
        }
    }

    /// Enqueue one `'static` fire-and-forget task (the out-of-core
    /// read-ahead thread rides this). Unlike [`WorkerPool::scope`] the
    /// caller does not wait — and unlike scope tasks, a submitted task
    /// may *block* (bounded-buffer condvars), so the pool is grown to
    /// `async_active + scope_high_water`: even with every async task
    /// parked on a worker, the widest kernel scope still has enough
    /// free workers to drain — the no-deadlock counting argument pinned
    /// by the scheduler tests.
    pub fn submit(self: &Arc<Self>, task: Task) {
        let live = self.async_active.fetch_add(1, Ordering::SeqCst) + 1;
        let head_room = self.shared.lock().unwrap().scope_high_water;
        self.ensure_workers(live + head_room.max(1));
        self.counters.lock().unwrap().submits += 1;
        let pool = Arc::clone(self);
        let mut shared = self.shared.lock().unwrap();
        shared.queue.push_back(Box::new(move || {
            let _ = catch_unwind(AssertUnwindSafe(task));
            pool.async_active.fetch_sub(1, Ordering::SeqCst);
        }));
        drop(shared);
        self.work_cv.notify_all();
    }

    /// Run borrowed tasks to completion on the pool. Blocks until
    /// every task has finished; panics (after draining) if any task
    /// panicked. A single task is run inline on the caller — no
    /// dispatch, mirroring the `threads <= 1` fast path of the
    /// chunk drivers.
    pub fn scope<'env>(self: &Arc<Self>, tasks: Vec<Box<dyn FnOnce() + Send + 'env>>) {
        match tasks.len() {
            0 => return,
            1 => {
                for t in tasks {
                    t();
                }
                return;
            }
            _ => {}
        }
        {
            let mut shared = self.shared.lock().unwrap();
            shared.scope_high_water = shared.scope_high_water.max(tasks.len());
        }
        // Reserve head room for parked async tasks (see `submit`).
        self.ensure_workers(self.async_active.load(Ordering::SeqCst) + tasks.len());
        {
            // Counted at dispatch: `scope` blocks until every task has
            // run, so by any observation point after a scope returns,
            // "dispatched" equals "executed".
            let mut c = self.counters.lock().unwrap();
            c.scopes += 1;
            c.tasks += tasks.len() as u64;
        }
        let state = Arc::new(ScopeState {
            pending: Mutex::new(tasks.len()),
            done_cv: Condvar::new(),
            panicked: AtomicBool::new(false),
        });
        {
            let mut shared = self.shared.lock().unwrap();
            for task in tasks {
                // SAFETY: the task borrows data living at least `'env`.
                // This scope blocks below until the pending count hits
                // zero, and a task's completion guard decrements that
                // count even on panic — so every task has fully run
                // (or unwound) before `scope` returns and the borrows
                // can expire. The erased closure never outlives `'env`.
                let task: Task = unsafe {
                    std::mem::transmute::<
                        Box<dyn FnOnce() + Send + 'env>,
                        Box<dyn FnOnce() + Send + 'static>,
                    >(task)
                };
                let st = Arc::clone(&state);
                shared.queue.push_back(Box::new(move || {
                    let guard = Completion { state: &st };
                    if catch_unwind(AssertUnwindSafe(task)).is_err() {
                        guard.state.panicked.store(true, Ordering::SeqCst);
                    }
                    // `guard` drops here, decrementing pending exactly
                    // once per task, panic or not.
                }));
            }
            self.work_cv.notify_all();
        }
        let mut pending = state.pending.lock().unwrap();
        while *pending > 0 {
            pending = state.done_cv.wait(pending).unwrap();
        }
        drop(pending);
        if state.panicked.load(Ordering::SeqCst) {
            panic!("worker pool task panicked");
        }
    }

    /// Cumulative counters (monotone; see [`PoolStats`]). The two
    /// locks are taken one after the other, never nested —
    /// `ensure_workers` holds `shared` while touching `counters`, so
    /// nesting them here in the opposite order could deadlock.
    pub fn stats(&self) -> PoolStats {
        let (scopes, tasks, threads_spawned, submits) = {
            let c = self.counters.lock().unwrap();
            (c.scopes, c.tasks, c.threads_spawned, c.submits)
        };
        let workers = self.shared.lock().unwrap().workers;
        PoolStats { scopes, tasks, threads_spawned, submits, workers }
    }
}

/// Completion guard: decrements the owning scope's pending count on
/// drop — the one per task, unwinding or not.
struct Completion<'a> {
    state: &'a Arc<ScopeState>,
}

impl Drop for Completion<'_> {
    fn drop(&mut self) {
        let mut pending = self.state.pending.lock().unwrap();
        *pending -= 1;
        if *pending == 0 {
            self.state.done_cv.notify_all();
        }
    }
}

/// The process-global kernel pool: every `*_mt` driver dispatches
/// through it, so worker threads are shared by all sessions, runs, and
/// node threads in the process.
pub fn global() -> &'static Arc<WorkerPool> {
    static POOL: OnceLock<Arc<WorkerPool>> = OnceLock::new();
    POOL.get_or_init(|| Arc::new(WorkerPool::new()))
}

/// Counters of the global pool ([`PoolStats`] — cumulative).
pub fn stats() -> PoolStats {
    global().stats()
}

/// Pre-spawn global-pool workers for a planned parallelism — lets
/// long-lived owners (sessions, the CLI) pay the one-time spawn cost
/// at construction instead of inside the first kernel call.
pub fn warm(threads: usize) {
    if threads > 1 {
        global().ensure_workers(threads);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn scope_runs_every_task_and_waits() {
        let pool = Arc::new(WorkerPool::new());
        let hits = AtomicU64::new(0);
        let mut out = vec![0u64; 8];
        {
            let chunks: Vec<&mut [u64]> = out.chunks_mut(2).collect();
            let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = chunks
                .into_iter()
                .enumerate()
                .map(|(i, c)| {
                    let hits = &hits;
                    Box::new(move || {
                        for (k, x) in c.iter_mut().enumerate() {
                            *x = (i * 2 + k) as u64 + 1;
                        }
                        hits.fetch_add(1, Ordering::SeqCst);
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            pool.scope(tasks);
        }
        assert_eq!(hits.load(Ordering::SeqCst), 4);
        assert_eq!(out, (1..=8).collect::<Vec<u64>>());
        let s = pool.stats();
        assert_eq!(s.scopes, 1);
        assert_eq!(s.tasks, 4);
        assert!(s.workers >= 4);
    }

    #[test]
    fn workers_are_reused_across_scopes() {
        let pool = Arc::new(WorkerPool::new());
        for _ in 0..5 {
            let tasks: Vec<Box<dyn FnOnce() + Send + '_>> =
                (0..3).map(|_| Box::new(|| {}) as Box<dyn FnOnce() + Send + '_>).collect();
            pool.scope(tasks);
        }
        let s = pool.stats();
        assert_eq!(s.scopes, 5);
        assert_eq!(s.tasks, 15);
        // Spawned once to the high-water mark, then flat.
        assert_eq!(s.threads_spawned, 3);
        assert_eq!(s.workers, 3);
    }

    #[test]
    fn single_task_runs_inline_without_dispatch() {
        let pool = Arc::new(WorkerPool::new());
        let mut x = 0u64;
        pool.scope(vec![Box::new(|| x += 1) as Box<dyn FnOnce() + Send + '_>]);
        assert_eq!(x, 1);
        let s = pool.stats();
        assert_eq!((s.scopes, s.tasks, s.threads_spawned), (0, 0, 0));
    }

    #[test]
    fn task_panic_propagates_after_drain() {
        let pool = Arc::new(WorkerPool::new());
        let ok = AtomicU64::new(0);
        let result = std::panic::catch_unwind(AssertUnwindSafe(|| {
            let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = vec![
                Box::new(|| panic!("boom")),
                Box::new(|| {
                    ok.fetch_add(1, Ordering::SeqCst);
                }),
            ];
            pool.scope(tasks);
        }));
        assert!(result.is_err(), "scope must re-panic");
        assert_eq!(ok.load(Ordering::SeqCst), 1, "sibling task still ran");
        // The pool survives: a later scope completes normally.
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..2)
            .map(|_| {
                Box::new(|| {
                    ok.fetch_add(1, Ordering::SeqCst);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.scope(tasks);
        assert_eq!(ok.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn submitted_tasks_never_starve_scopes() {
        // A fire-and-forget task parked on a condvar occupies a worker
        // indefinitely; the head-room accounting must still leave every
        // kernel scope enough free workers to drain.
        let pool = Arc::new(WorkerPool::new());
        let gate = Arc::new((Mutex::new(false), Condvar::new()));
        let g2 = Arc::clone(&gate);
        pool.submit(Box::new(move || {
            let (lock, cv) = &*g2;
            let mut open = lock.lock().unwrap();
            while !*open {
                open = cv.wait(open).unwrap();
            }
        }));
        let hits = AtomicU64::new(0);
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..3)
            .map(|_| {
                let hits = &hits;
                Box::new(move || {
                    hits.fetch_add(1, Ordering::SeqCst);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.scope(tasks);
        assert_eq!(hits.load(Ordering::SeqCst), 3);
        assert_eq!(pool.stats().submits, 1);
        let (lock, cv) = &*gate;
        *lock.lock().unwrap() = true;
        cv.notify_all();
    }

    #[test]
    fn submit_panic_does_not_kill_the_worker() {
        let pool = Arc::new(WorkerPool::new());
        pool.submit(Box::new(|| panic!("async boom")));
        // The pool still runs scopes afterwards on the same workers.
        let ok = AtomicU64::new(0);
        for _ in 0..50 {
            if pool.async_active.load(Ordering::SeqCst) == 0 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..2)
            .map(|_| {
                let ok = &ok;
                Box::new(move || {
                    ok.fetch_add(1, Ordering::SeqCst);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.scope(tasks);
        assert_eq!(ok.load(Ordering::SeqCst), 2);
    }

    #[test]
    fn global_pool_is_shared_and_warmable() {
        let before = stats();
        warm(2);
        let after = stats();
        assert!(after.workers >= 2);
        assert!(after.threads_spawned >= before.threads_spawned);
        // warm(1) and warm(0) never spawn.
        warm(1);
        warm(0);
        assert_eq!(stats().workers, after.workers);
    }
}
