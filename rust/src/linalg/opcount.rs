//! Elementwise-operation accounting for the native kernels.
//!
//! The paper's figure of merit is *elementwise comparisons* (Table 1):
//! one min (or multiply, or bit-AND) per feature of each output entry a
//! kernel computes. The counter mirrors [`crate::vecdata::bits::pack_calls`]:
//! a process-wide monotone total that tests and benches read as
//! before/after deltas — it exists to *prove* structural claims (a
//! triangular diagonal-block kernel performs ~half the ops of the full
//! square kernel) rather than to estimate time.
//!
//! Kernels record once per call/panel with an analytic count, so the
//! accounting adds no per-element cost to the hot loops.

use std::sync::atomic::{AtomicU64, Ordering};

static ELEM_OPS: AtomicU64 = AtomicU64::new(0);

/// Total elementwise kernel operations (min / multiply / bit-compare)
/// recorded so far, process-wide. Monotone; read deltas around the
/// region of interest.
pub fn elem_ops() -> u64 {
    ELEM_OPS.load(Ordering::Relaxed)
}

/// Record `n` elementwise operations (called by the native kernels,
/// once per panel — thread-safe, so parallel row panels just add up).
pub(crate) fn record(n: u64) {
    ELEM_OPS.fetch_add(n, Ordering::Relaxed);
}

/// Elementwise ops of a full m×n block at depth nf.
pub fn ops_full(nf: usize, m: usize, n: usize) -> u64 {
    nf as u64 * m as u64 * n as u64
}

/// Elementwise ops of a strict-upper-triangular nv×nv block at depth
/// nf — the diagonal-block cost after symmetry halving.
pub fn ops_tri(nf: usize, nv: usize) -> u64 {
    nf as u64 * (nv as u64 * nv.saturating_sub(1) as u64 / 2)
}

/// Elementwise ops of rows `rows` of a strict-upper-triangular nv×nv
/// block at depth nf (row i computes nv − 1 − i entries) — the
/// per-worker delta that pins the balanced triangular partition
/// ([`crate::linalg::tri_partition`]).
pub fn ops_tri_rows(nf: usize, rows: std::ops::Range<usize>, nv: usize) -> u64 {
    rows.map(|i| (nv - 1 - i) as u64).sum::<u64>() * nf as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_is_monotone() {
        let before = elem_ops();
        record(17);
        assert_eq!(elem_ops() - before, 17);
    }

    #[test]
    fn tri_is_under_half_of_full() {
        // The ~2× diag-block reduction: (nv-1)/(2 nv) < 1/2 always.
        for nv in [1usize, 2, 7, 64, 1000] {
            assert!(ops_tri(48, nv) * 2 <= ops_full(48, nv, nv));
        }
        assert_eq!(ops_tri(10, 4), 10 * 6);
        assert_eq!(ops_full(10, 4, 4), 160);
    }

    #[test]
    fn tri_rows_partition_the_triangle() {
        let (nf, nv) = (7usize, 20usize);
        assert_eq!(ops_tri_rows(nf, 0..nv, nv), ops_tri(nf, nv));
        assert_eq!(
            ops_tri_rows(nf, 0..8, nv) + ops_tri_rows(nf, 8..nv, nv),
            ops_tri(nf, nv)
        );
        assert_eq!(ops_tri_rows(nf, nv - 1..nv, nv), 0);
    }
}
