//! Reference (unoptimized) native kernels — the paper's "reference
//! (CPU-only) version" (§5): direct transcriptions of the definitions,
//! used as the correctness baseline for every other path and as the
//! "CPU" side of the Table 2 GPU-vs-CPU comparison.

use crate::linalg::{MatF64, SlabF64};
use crate::util::Scalar;
use crate::vecdata::VectorSet;

/// N[i, j] = Σ_q min(w_i[q], v_j[q]) — straight triple loop.
pub fn mgemm2<T: Scalar>(w: &VectorSet<T>, v: &VectorSet<T>) -> MatF64 {
    assert_eq!(w.nf, v.nf, "feature depth mismatch");
    let mut out = MatF64::zeros(w.nv, v.nv);
    for i in 0..w.nv {
        let wi = w.col(i);
        for j in 0..v.nv {
            let vj = v.col(j);
            let mut acc = T::ZERO;
            for q in 0..w.nf {
                acc += wi[q].min_s(vj[q]);
            }
            out.set(i, j, acc.to_f64());
        }
    }
    out
}

/// True GEMM comparator: G[i, j] = Σ_q w_i[q]·v_j[q].
pub fn gemm<T: Scalar>(w: &VectorSet<T>, v: &VectorSet<T>) -> MatF64 {
    assert_eq!(w.nf, v.nf);
    let mut out = MatF64::zeros(w.nv, v.nv);
    for i in 0..w.nv {
        let wi = w.col(i);
        for j in 0..v.nv {
            let vj = v.col(j);
            let mut acc = T::ZERO;
            for q in 0..w.nf {
                acc += wi[q] * vj[q];
            }
            out.set(i, j, acc.to_f64());
        }
    }
    out
}

/// Diagonal-block variant of [`mgemm2`]: strict upper triangle of
/// V^T ∘min V only (entries at and below the diagonal stay zero). The
/// reference transcription of §4's symmetry halving.
pub fn mgemm2_tri<T: Scalar>(v: &VectorSet<T>) -> MatF64 {
    let mut out = MatF64::zeros(v.nv, v.nv);
    for i in 0..v.nv {
        let wi = v.col(i);
        for j in (i + 1)..v.nv {
            let vj = v.col(j);
            let mut acc = T::ZERO;
            for q in 0..v.nf {
                acc += wi[q].min_s(vj[q]);
            }
            out.set(i, j, acc.to_f64());
        }
    }
    out
}

/// Diagonal-block variant of [`gemm`]: strict upper triangle of V^T V.
pub fn gemm_tri<T: Scalar>(v: &VectorSet<T>) -> MatF64 {
    let mut out = MatF64::zeros(v.nv, v.nv);
    for i in 0..v.nv {
        let wi = v.col(i);
        for j in (i + 1)..v.nv {
            let vj = v.col(j);
            let mut acc = T::ZERO;
            for q in 0..v.nf {
                acc += wi[q] * vj[q];
            }
            out.set(i, j, acc.to_f64());
        }
    }
    out
}

/// slab[t, i, k] = Σ_q min(pivots_t[q], w_i[q], v_k[q]).
pub fn mgemm3<T: Scalar>(w: &VectorSet<T>, pivots: &VectorSet<T>, v: &VectorSet<T>) -> SlabF64 {
    assert_eq!(w.nf, v.nf);
    assert_eq!(w.nf, pivots.nf);
    let mut out = SlabF64::zeros(pivots.nv, w.nv, v.nv);
    for t in 0..pivots.nv {
        let pt = pivots.col(t);
        for i in 0..w.nv {
            let wi = w.col(i);
            for k in 0..v.nv {
                let vk = v.col(k);
                let mut acc = T::ZERO;
                for q in 0..w.nf {
                    acc += pt[q].min_s(wi[q]).min_s(vk[q]);
                }
                out.set(t, i, k, acc.to_f64());
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics;
    use crate::vecdata::SyntheticKind;

    #[test]
    fn mgemm2_matches_scalar_oracle() {
        let w: VectorSet<f64> = VectorSet::generate(SyntheticKind::RandomGrid, 1, 23, 5, 0);
        let v: VectorSet<f64> = VectorSet::generate(SyntheticKind::RandomGrid, 1, 23, 7, 100);
        let n = mgemm2(&w, &v);
        for i in 0..5 {
            for j in 0..7 {
                assert_eq!(n.at(i, j), metrics::n2(w.col(i), v.col(j)));
            }
        }
    }

    #[test]
    fn mgemm2_diag_equals_colsum() {
        // n2(v, v) = Σ v — a cheap strong invariant.
        let v: VectorSet<f64> = VectorSet::generate(SyntheticKind::RandomGrid, 2, 31, 6, 0);
        let n = mgemm2(&v, &v);
        let sums = v.col_sums();
        for i in 0..6 {
            assert!((n.at(i, i) - sums[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn mgemm3_matches_scalar_oracle() {
        let w: VectorSet<f64> = VectorSet::generate(SyntheticKind::RandomGrid, 3, 17, 4, 0);
        let p: VectorSet<f64> = VectorSet::generate(SyntheticKind::RandomGrid, 3, 17, 3, 50);
        let v: VectorSet<f64> = VectorSet::generate(SyntheticKind::RandomGrid, 3, 17, 5, 90);
        let s = mgemm3(&w, &p, &v);
        for t in 0..3 {
            for i in 0..4 {
                for k in 0..5 {
                    assert_eq!(
                        s.at(t, i, k),
                        metrics::n3_prime(p.col(t), w.col(i), v.col(k))
                    );
                }
            }
        }
    }

    #[test]
    fn tri_variants_match_full_upper_triangle() {
        let v: VectorSet<f64> = VectorSet::generate(SyntheticKind::RandomGrid, 4, 19, 9, 0);
        let full_m = mgemm2(&v, &v);
        let tri_m = mgemm2_tri(&v);
        let full_g = gemm(&v, &v);
        let tri_g = gemm_tri(&v);
        for i in 0..9 {
            for j in 0..9 {
                if j > i {
                    assert_eq!(tri_m.at(i, j).to_bits(), full_m.at(i, j).to_bits());
                    assert_eq!(tri_g.at(i, j).to_bits(), full_g.at(i, j).to_bits());
                } else {
                    assert_eq!(tri_m.at(i, j), 0.0);
                    assert_eq!(tri_g.at(i, j), 0.0);
                }
            }
        }
    }

    #[test]
    fn gemm_small_case() {
        let mut w: VectorSet<f64> = VectorSet::zeros(2, 2);
        w.col_mut(0).copy_from_slice(&[1.0, 2.0]);
        w.col_mut(1).copy_from_slice(&[3.0, 4.0]);
        let g = gemm(&w, &w);
        assert_eq!(g.at(0, 0), 5.0);
        assert_eq!(g.at(0, 1), 11.0);
        assert_eq!(g.at(1, 1), 25.0);
    }
}
