//! Explicit SIMD-shaped inner kernels — the lane-level substrate of the
//! native compute tier.
//!
//! The paper's throughput headline (>5×10¹⁵ comparisons/sec, Table 6)
//! rests on inner loops that run at hardware rate. Two scalar patterns
//! kept ours from doing so:
//!
//! * the packed Sorensen sweep popcounted one `u64` per iteration — a
//!   single dependency chain through one accumulator, so the CPU's
//!   multiple popcount/ALU ports sat idle;
//! * the float panel kernel accumulated its `JT` register-tile columns
//!   through `JT` *separate column slices*, so the innermost tile loop
//!   was a gather the autovectorizer cannot turn into vector loads.
//!
//! This module fixes both shapes:
//!
//! * [`popcount`] / [`and_popcount`]: wide-lane word sweeps — `LANES`
//!   independent accumulators over `LANES`-word chunks (plus a scalar
//!   tail for partial trailing words). Integer addition is associative,
//!   so lane order cannot change any result: these are **bit-exact**
//!   drop-ins, and the independent chains let the hardware retire
//!   several `popcnt`s per cycle.
//! * [`pack_tile_qmajor`]: repack a `JT`-column tile of a column-major
//!   [`VectorSet`] into **q-major** layout (`buf[q * JT + t]`), so the
//!   panel kernel's tile loop reads `JT` *contiguous* elements per
//!   feature — a unit-stride vector load the compiler turns into
//!   min/add (or mul/add) vector ops. Packing changes only the memory
//!   walk; each output element's accumulation is still the same
//!   strictly sequential q sweep, so results stay bit-identical to the
//!   unpacked kernel. (No `mul_add`/FMA anywhere: fused rounding would
//!   break bitwise agreement with the reference backend.)
//!
//! Everything here is plain safe Rust — the "SIMD" is shaping loops so
//! LLVM's autovectorizer reliably emits vector instructions on any
//! target, rather than intrinsics tied to one ISA.

use crate::util::Scalar;
use crate::vecdata::VectorSet;

/// Word-sweep lane width: independent accumulator chains per iteration
/// of the popcount loops (4 × 64-bit words = a 256-bit stride, matching
/// the AVX2-class registers on typical hosts; on narrower targets the
/// independent chains still pipeline).
pub const LANES: usize = 4;

/// Population count of a word slice: `LANES` independent accumulators
/// over `LANES`-word chunks, scalar tail for the remainder. Bit-exact
/// vs. the naive single-accumulator sweep (integer sums are
/// order-free).
#[inline]
pub fn popcount(words: &[u64]) -> u64 {
    let mut lanes = [0u64; LANES];
    let mut chunks = words.chunks_exact(LANES);
    for c in &mut chunks {
        for (acc, w) in lanes.iter_mut().zip(c) {
            *acc += w.count_ones() as u64;
        }
    }
    let mut total: u64 = lanes.iter().sum();
    for w in chunks.remainder() {
        total += w.count_ones() as u64;
    }
    total
}

/// `|a AND b|` over two word slices — the packed Sorensen numerator
/// inner loop, `LANES` words per iteration with a scalar tail. Slices
/// must have equal length (the packed layout guarantees it).
#[inline]
pub fn and_popcount(a: &[u64], b: &[u64]) -> u64 {
    debug_assert_eq!(a.len(), b.len(), "packed operand length mismatch");
    let mut lanes = [0u64; LANES];
    let mut ca = a.chunks_exact(LANES);
    let mut cb = b.chunks_exact(LANES);
    for (wa, wb) in (&mut ca).zip(&mut cb) {
        for t in 0..LANES {
            lanes[t] += (wa[t] & wb[t]).count_ones() as u64;
        }
    }
    let mut total: u64 = lanes.iter().sum();
    for (x, y) in ca.remainder().iter().zip(cb.remainder()) {
        total += (x & y).count_ones() as u64;
    }
    total
}

/// Split a packed u64 word into its low/high u32 halves — the artifact
/// wire layout (`runtime::ops` ships packed operands to the u32
/// popcount artifacts as interleaved half-words).
#[inline]
pub fn word_halves(w: u64) -> (u32, u32) {
    ((w & 0xFFFF_FFFF) as u32, (w >> 32) as u32)
}

/// Repack columns `j0..j0+jt` of `v` into q-major tile layout:
/// `buf[q * jt + t] = v.col(j0 + t)[q]`. The panel kernels call this
/// once per column tile and then stream the tile with unit stride —
/// the transpose that turns the register-tile accumulation into
/// vectorizable contiguous loads. `buf` is resized to `nf * jt`.
#[inline]
pub fn pack_tile_qmajor<T: Scalar>(v: &VectorSet<T>, j0: usize, jt: usize, buf: &mut Vec<T>) {
    let nf = v.nf;
    buf.clear();
    buf.resize(nf * jt, T::ZERO);
    for t in 0..jt {
        let col = v.col(j0 + t);
        for q in 0..nf {
            buf[q * jt + t] = col[q];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vecdata::SyntheticKind;

    fn scalar_popcount(words: &[u64]) -> u64 {
        words.iter().map(|w| w.count_ones() as u64).sum()
    }

    fn scalar_and_popcount(a: &[u64], b: &[u64]) -> u64 {
        a.iter().zip(b).map(|(x, y)| (x & y).count_ones() as u64).sum()
    }

    fn words(seed: u64, n: usize) -> Vec<u64> {
        // Cheap deterministic word patterns with varied density.
        (0..n as u64)
            .map(|i| {
                let x = seed
                    .wrapping_mul(0x9E37_79B9_7F4A_7C15)
                    .wrapping_add(i.wrapping_mul(0xBF58_476D_1CE4_E5B9));
                x ^ (x >> 31) ^ (x << (i % 13))
            })
            .collect()
    }

    #[test]
    fn popcount_matches_scalar_all_lengths() {
        // Lengths straddling the LANES stride, including 0 and partial
        // trailing chunks.
        for n in 0..=(4 * LANES + 3) {
            for seed in 1..=5 {
                let w = words(seed, n);
                assert_eq!(popcount(&w), scalar_popcount(&w), "n={n} seed={seed}");
            }
        }
        assert_eq!(popcount(&[]), 0);
        assert_eq!(popcount(&[u64::MAX; 7]), 7 * 64);
    }

    #[test]
    fn and_popcount_matches_scalar_all_lengths() {
        for n in 0..=(4 * LANES + 3) {
            for seed in 1..=5 {
                let a = words(seed, n);
                let b = words(seed + 100, n);
                assert_eq!(
                    and_popcount(&a, &b),
                    scalar_and_popcount(&a, &b),
                    "n={n} seed={seed}"
                );
            }
        }
    }

    #[test]
    fn word_halves_roundtrip() {
        for w in [0u64, u64::MAX, 0xDEAD_BEEF_0123_4567] {
            let (lo, hi) = word_halves(w);
            assert_eq!((hi as u64) << 32 | lo as u64, w);
        }
    }

    #[test]
    fn qmajor_pack_is_a_transpose() {
        let v: VectorSet<f64> = VectorSet::generate(SyntheticKind::RandomGrid, 3, 17, 12, 0);
        let mut buf = Vec::new();
        pack_tile_qmajor(&v, 4, 5, &mut buf);
        assert_eq!(buf.len(), 17 * 5);
        for t in 0..5 {
            for q in 0..17 {
                assert_eq!(buf[q * 5 + t], v.col(4 + t)[q], "t={t} q={q}");
            }
        }
    }
}
