//! Bitwise Sorenson kernels (paper §2.3 + the Table 6 1-bit baselines).
//!
//! On 0/1 data the min-product is a logical AND, so the mGEMM becomes an
//! AND+popcount GEMM over packed words — the trick behind the very high
//! comparison rates of the 1-bit codes in Table 6 (Haque et al.): each
//! 64-bit word op performs 64 elementwise comparisons.

use crate::linalg::MatF64;
use crate::vecdata::bits::BitVectorSet;

/// Reference bit kernel: N[i, j] = |u_i AND v_j| counted bit-by-bit
/// through `get_bit` — no word-level tricks. The correctness baseline
/// for [`sorenson_mgemm`], mirroring the reference/optimized split of
/// the float kernels (§5).
pub fn sorenson_mgemm_ref(w: &BitVectorSet, v: &BitVectorSet) -> MatF64 {
    assert_eq!(w.nf, v.nf, "feature depth mismatch");
    let mut out = MatF64::zeros(w.nv, v.nv);
    for i in 0..w.nv {
        for j in 0..v.nv {
            let mut acc = 0u64;
            for q in 0..w.nf {
                acc += (w.get_bit(i, q) && v.get_bit(j, q)) as u64;
            }
            out.set(i, j, acc as f64);
        }
    }
    out
}

/// Full numerator matrix N[i, j] = |u_i AND v_j| over packed words.
pub fn sorenson_mgemm(w: &BitVectorSet, v: &BitVectorSet) -> MatF64 {
    assert_eq!(w.nf, v.nf, "feature depth mismatch");
    let mut out = MatF64::zeros(w.nv, v.nv);
    for i in 0..w.nv {
        let wi = w.words(i);
        for j in 0..v.nv {
            let vj = v.words(j);
            let mut acc = 0u64;
            for (a, b) in wi.iter().zip(vj) {
                acc += (a & b).count_ones() as u64;
            }
            out.set(i, j, acc as f64);
        }
    }
    out
}

/// Unique-pair Sorenson metric values for one set (upper triangle).
pub fn sorenson_all_pairs(v: &BitVectorSet) -> crate::metrics::store::PairStore {
    let pops: Vec<u64> = (0..v.nv).map(|i| v.popcount(i)).collect();
    let mut store = crate::metrics::store::PairStore::new();
    for i in 0..v.nv {
        for j in (i + 1)..v.nv {
            let d = pops[i] + pops[j];
            let c = if d == 0 {
                0.0
            } else {
                2.0 * v.and_popcount(i, j) as f64 / d as f64
            };
            store.push(i, j, c);
        }
    }
    store
}

/// Elementwise-comparison count for a bitwise all-pairs study — each
/// feature of each unique pair is one comparison (the Table 6 unit),
/// even though 64 of them ride in each word op.
pub fn cmp_count(nf: usize, nv: usize) -> u64 {
    nf as u64 * (nv as u64 * (nv as u64 - 1) / 2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn packed_kernel_matches_bitwise_reference() {
        // Widths straddling word boundaries exercise the partial
        // trailing word of the packed path.
        for nf in [1, 63, 64, 65, 127, 128, 129, 150] {
            let bits = BitVectorSet::generate(17, nf, 7, 0.4);
            let a = sorenson_mgemm(&bits, &bits);
            let b = sorenson_mgemm_ref(&bits, &bits);
            assert_eq!(a.max_abs_diff(&b), 0.0, "nf={nf}");
        }
    }

    #[test]
    fn matches_float_mgemm_on_bits() {
        let bits = BitVectorSet::generate(3, 150, 12, 0.35);
        let floats = bits.to_floats();
        let a = sorenson_mgemm(&bits, &bits);
        let b = crate::linalg::reference::mgemm2(&floats, &floats);
        assert_eq!(a.max_abs_diff(&b), 0.0);
    }

    #[test]
    fn all_pairs_matches_scalar() {
        let bits = BitVectorSet::generate(5, 100, 9, 0.4);
        let store = sorenson_all_pairs(&bits);
        assert_eq!(store.len(), 9 * 8 / 2);
        for e in store.iter() {
            let direct = bits.sorenson2(e.i as usize, e.j as usize);
            assert_eq!(e.value, direct);
        }
    }

    #[test]
    fn cmp_count_formula() {
        assert_eq!(cmp_count(100, 5), 100 * 10);
    }
}
