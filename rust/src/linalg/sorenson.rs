//! Bitwise Sorenson kernels (paper §2.3 + the Table 6 1-bit baselines).
//!
//! On 0/1 data the min-product is a logical AND, so the mGEMM becomes an
//! AND+popcount GEMM over packed words — the trick behind the very high
//! comparison rates of the 1-bit codes in Table 6 (Haque et al.): each
//! 64-bit word op performs 64 elementwise comparisons.

use crate::linalg::{opcount, simd, MatF64};
use crate::vecdata::bits::BitVectorSet;

/// Reference bit kernel: N[i, j] = |u_i AND v_j| counted bit-by-bit
/// through `get_bit` — no word-level tricks. The correctness baseline
/// for [`sorenson_mgemm`], mirroring the reference/optimized split of
/// the float kernels (§5).
pub fn sorenson_mgemm_ref(w: &BitVectorSet, v: &BitVectorSet) -> MatF64 {
    assert_eq!(w.nf, v.nf, "feature depth mismatch");
    let mut out = MatF64::zeros(w.nv, v.nv);
    for i in 0..w.nv {
        for j in 0..v.nv {
            let mut acc = 0u64;
            for q in 0..w.nf {
                acc += (w.get_bit(i, q) && v.get_bit(j, q)) as u64;
            }
            out.set(i, j, acc as f64);
        }
    }
    out
}

/// Reference diagonal-block kernel: strict upper triangle of
/// [`sorenson_mgemm_ref`], bit-by-bit — the naive transcription of the
/// §4 symmetry halving on the bit path (CpuReference's diag kernel).
pub fn sorenson_mgemm_ref_tri(v: &BitVectorSet) -> MatF64 {
    let mut out = MatF64::zeros(v.nv, v.nv);
    for i in 0..v.nv {
        for j in (i + 1)..v.nv {
            let mut acc = 0u64;
            for q in 0..v.nf {
                acc += (v.get_bit(i, q) && v.get_bit(j, q)) as u64;
            }
            out.set(i, j, acc as f64);
        }
    }
    out
}

/// One row panel of the packed AND+popcount kernel, written into
/// `out[(i - rows.start) * v.nv + j]`. `tri` restricts each row to
/// j > i (diagonal blocks — the §4 symmetry halving on the bit path).
/// The word sweep is [`simd::and_popcount`]: `simd::LANES` independent
/// popcount chains per iteration instead of the single scalar
/// accumulator this loop used to carry — bit-exact (integer sums), but
/// the hardware can retire several popcounts per cycle.
fn popcount_panel(
    w: &BitVectorSet,
    v: &BitVectorSet,
    rows: std::ops::Range<usize>,
    tri: bool,
    out: &mut [f64],
) {
    let n = v.nv;
    let mut elems: u64 = 0;
    for i in rows.start..rows.end {
        let wi = w.words(i);
        let row = (i - rows.start) * n;
        let j_lo = if tri { i + 1 } else { 0 };
        for j in j_lo..n {
            out[row + j] = simd::and_popcount(wi, v.words(j)) as f64;
        }
        elems += (n - j_lo) as u64;
    }
    // Table 6 unit: one elementwise comparison per feature of each
    // computed pair (64 of them ride in each word op).
    opcount::record(elems * w.nf as u64);
}

/// Full numerator matrix N[i, j] = |u_i AND v_j| over packed words.
pub fn sorenson_mgemm(w: &BitVectorSet, v: &BitVectorSet) -> MatF64 {
    sorenson_mgemm_mt(w, v, 1)
}

/// [`sorenson_mgemm`] with output rows partitioned over `threads`
/// threads (disjoint row panels — bit-identical for any count).
pub fn sorenson_mgemm_mt(w: &BitVectorSet, v: &BitVectorSet, threads: usize) -> MatF64 {
    assert_eq!(w.nf, v.nf, "feature depth mismatch");
    let mut out = MatF64::zeros(w.nv, v.nv);
    par_row_panels(w, v, false, threads, &mut out);
    out
}

/// Diagonal-block kernel: strict upper triangle of V AND V only
/// (~2× fewer word ops; computed entries identical to the full kernel).
pub fn sorenson_mgemm_tri(v: &BitVectorSet) -> MatF64 {
    sorenson_mgemm_tri_mt(v, 1)
}

/// [`sorenson_mgemm_tri`] on `threads` threads.
pub fn sorenson_mgemm_tri_mt(v: &BitVectorSet, threads: usize) -> MatF64 {
    let mut out = MatF64::zeros(v.nv, v.nv);
    par_row_panels(v, v, true, threads, &mut out);
    out
}

fn par_row_panels(w: &BitVectorSet, v: &BitVectorSet, tri: bool, threads: usize, out: &mut MatF64) {
    let (m, n) = (out.rows, out.cols);
    let run =
        |rows: std::ops::Range<usize>, chunk: &mut [f64]| popcount_panel(w, v, rows, tri, chunk);
    if tri {
        // Balanced low+high band pairing — triangular rows thin out
        // toward the bottom (see `linalg::tri_partition`).
        crate::linalg::par_chunks_tri(&mut out.data, n, m, threads, run);
    } else {
        crate::linalg::par_chunks(&mut out.data, n, m, threads, run);
    }
}

/// Unique-pair Sorenson metric values for one set (upper triangle).
pub fn sorenson_all_pairs(v: &BitVectorSet) -> crate::metrics::store::PairStore {
    let pops: Vec<u64> = (0..v.nv).map(|i| v.popcount(i)).collect();
    let mut store = crate::metrics::store::PairStore::new();
    for i in 0..v.nv {
        for j in (i + 1)..v.nv {
            let d = pops[i] + pops[j];
            let c = if d == 0 {
                0.0
            } else {
                2.0 * v.and_popcount(i, j) as f64 / d as f64
            };
            store.push(i, j, c);
        }
    }
    store
}

/// Elementwise-comparison count for a bitwise all-pairs study — each
/// feature of each unique pair is one comparison (the Table 6 unit),
/// even though 64 of them ride in each word op.
pub fn cmp_count(nf: usize, nv: usize) -> u64 {
    nf as u64 * (nv as u64 * (nv as u64 - 1) / 2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn packed_kernel_matches_bitwise_reference() {
        // Widths straddling word boundaries exercise the partial
        // trailing word of the packed path.
        for nf in [1, 63, 64, 65, 127, 128, 129, 150] {
            let bits = BitVectorSet::generate(17, nf, 7, 0.4);
            let a = sorenson_mgemm(&bits, &bits);
            let b = sorenson_mgemm_ref(&bits, &bits);
            assert_eq!(a.max_abs_diff(&b), 0.0, "nf={nf}");
        }
    }

    #[test]
    fn matches_float_mgemm_on_bits() {
        let bits = BitVectorSet::generate(3, 150, 12, 0.35);
        let floats = bits.to_floats();
        let a = sorenson_mgemm(&bits, &bits);
        let b = crate::linalg::reference::mgemm2(&floats, &floats);
        assert_eq!(a.max_abs_diff(&b), 0.0);
    }

    #[test]
    fn tri_and_threads_match_full_kernel() {
        for nf in [63, 64, 129] {
            let bits = BitVectorSet::generate(23, nf, 11, 0.45);
            let full = sorenson_mgemm(&bits, &bits);
            let tri = sorenson_mgemm_tri(&bits);
            let ref_tri = sorenson_mgemm_ref_tri(&bits);
            for i in 0..11 {
                for j in 0..11 {
                    if j > i {
                        assert_eq!(tri.at(i, j).to_bits(), full.at(i, j).to_bits(), "nf={nf}");
                        assert_eq!(ref_tri.at(i, j).to_bits(), full.at(i, j).to_bits(), "nf={nf}");
                    } else {
                        assert_eq!(tri.at(i, j), 0.0, "nf={nf}");
                        assert_eq!(ref_tri.at(i, j), 0.0, "nf={nf}");
                    }
                }
            }
            for threads in [2, 4] {
                assert_eq!(full, sorenson_mgemm_mt(&bits, &bits, threads), "nf={nf}");
                assert_eq!(tri, sorenson_mgemm_tri_mt(&bits, threads), "nf={nf}");
            }
        }
    }

    #[test]
    fn all_pairs_matches_scalar() {
        let bits = BitVectorSet::generate(5, 100, 9, 0.4);
        let store = sorenson_all_pairs(&bits);
        assert_eq!(store.len(), 9 * 8 / 2);
        for e in store.iter() {
            let direct = bits.sorenson2(e.i as usize, e.j as usize);
            assert_eq!(e.value, direct);
        }
    }

    #[test]
    fn cmp_count_formula() {
        assert_eq!(cmp_count(100, 5), 100 * 10);
    }
}
