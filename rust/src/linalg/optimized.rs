//! Optimized native kernels — the paper's "(possibly optimized) CPU
//! version" (§5), with symmetry-halved triangular variants and
//! row-panel thread parallelism.
//!
//! The optimization story mirrors what MAGMA does on the GPU, scaled to
//! the host cache hierarchy:
//! * **j-register-tiling**: each inner pass accumulates `JT` output
//!   columns at once into scalar accumulators, so each load of `w_i[q]`
//!   is reused JT times (the register-blocking that makes GEMM live).
//! * **q-major tile packing** ([`crate::linalg::simd`]): each cache
//!   block's columns are repacked once so the register-tile loop reads
//!   its JT operands as one contiguous unit-stride row per feature —
//!   vector loads + vector min/add (the paper's two ops per
//!   comparison) instead of a gather across JT column slices.
//! * **i×j cache blocking**: outer blocks sized so the working panels
//!   stay in L1/L2 (the host stand-in for VMEM/shared-memory tiling).
//! * **Triangular (`*_tri`) variants** (§4's "eliminating redundant
//!   calculations due to symmetries"): a diagonal block pairs a vector
//!   set with itself, so only the strict upper triangle is meaningful —
//!   these skip the diagonal and below, ~halving the elementwise ops
//!   while producing bit-identical upper-triangle entries (each output
//!   element's q-accumulation order is unchanged).
//! * **Thread parallelism (`*_mt` variants)**: output rows (or slab
//!   planes for mgemm3) are partitioned into contiguous panels, one per
//!   thread. Every output element is computed by exactly one thread
//!   with the identical sequential accumulation, so grid-valued sums
//!   are **bit-identical across thread counts**.

use std::ops::Range;

use crate::linalg::{opcount, simd, MatF64, SlabF64};
use crate::util::Scalar;
use crate::vecdata::VectorSet;

/// Output-column register tile. 8 f64 accumulators fit comfortably in
/// the 16 architectural vector registers alongside the streamed operand.
pub const JT: usize = 8;
/// Outer cache-block edge (vectors per block; panels of BI×n_f floats).
pub const BI: usize = 32;

#[inline(always)]
fn op_min<T: Scalar>(a: T, b: T) -> T {
    a.min_s(b)
}

#[inline(always)]
fn op_mul<T: Scalar>(a: T, b: T) -> T {
    a * b
}

/// The one blocked inner kernel every 2-way variant shares: compute
/// out rows `rows` × columns `cols` of W^T ∘f V, writing
/// `out[(i - rows.start) * ldo + j]` (absolute column indexing, so a
/// row panel of a larger matrix or a slab plane can be written in
/// place). `tri` restricts each row i to columns j > i (diagonal
/// blocks).
///
/// SIMD shape: each i×j cache block first repacks its column block
/// into a **q-major tile** ([`simd::pack_tile_qmajor`], amortized over
/// the block's BI rows), so the register-tile loop reads its JT
/// operands as one contiguous unit-stride row per feature — a vector
/// load + vector min/add (or mul/add) instead of the gather across JT
/// separate column slices the pre-SIMD kernel did. The per-element
/// accumulation is the same sequential q sweep regardless of blocking
/// or packing (and no `mul_add` fusion anywhere), so every variant
/// built on this kernel stays bit-identical per element.
#[allow(clippy::too_many_arguments)]
#[inline(always)]
fn panel<T: Scalar, F: Fn(T, T) -> T + Copy>(
    w: &VectorSet<T>,
    v: &VectorSet<T>,
    rows: Range<usize>,
    cols: Range<usize>,
    tri: bool,
    out: &mut [f64],
    ldo: usize,
    f: F,
) {
    debug_assert_eq!(w.nf, v.nf, "feature depth mismatch");
    let nf = w.nf;
    let mut elems: u64 = 0;
    let mut tile: Vec<T> = Vec::new(); // q-major packed column block, reused
    for i0 in (rows.start..rows.end).step_by(BI) {
        let i1 = (i0 + BI).min(rows.end);
        let mut j0 = cols.start;
        while j0 < cols.end {
            let j1 = (j0 + BI).min(cols.end);
            // A block entirely at or below the diagonal contributes
            // nothing in triangular mode.
            if !(tri && j1 <= i0 + 1) {
                let bw = j1 - j0;
                simd::pack_tile_qmajor(v, j0, bw, &mut tile);
                for i in i0..i1 {
                    let wi = w.col(i);
                    let row = (i - rows.start) * ldo;
                    let mut j = if tri { j0.max(i + 1) } else { j0 };
                    // Register-tiled main loop: JT columns at once,
                    // streamed from the q-major tile with unit stride.
                    while j + JT <= j1 {
                        let mut acc = [T::ZERO; JT];
                        let off = j - j0;
                        for (&wq, trow) in wi.iter().zip(tile.chunks_exact(bw)) {
                            let vrow: &[T; JT] =
                                trow[off..off + JT].try_into().expect("tile row width");
                            for t in 0..JT {
                                acc[t] += f(wq, vrow[t]);
                            }
                        }
                        for t in 0..JT {
                            out[row + j + t] = acc[t].to_f64();
                        }
                        elems += JT as u64;
                        j += JT;
                    }
                    // Remainder columns (straight from the source set —
                    // same q-sequential accumulation).
                    while j < j1 {
                        let vj = v.col(j);
                        let mut acc = T::ZERO;
                        for q in 0..nf {
                            acc += f(wi[q], vj[q]);
                        }
                        out[row + j] = acc.to_f64();
                        elems += 1;
                        j += 1;
                    }
                }
            }
            j0 = j1;
        }
    }
    opcount::record(elems * nf as u64);
}

/// Run `panel` over row panels on `threads` OS threads — disjoint
/// output tiles, bit-identical for any thread count. Full blocks use
/// the contiguous [`crate::linalg::par_chunks`] partition (uniform row
/// cost); triangular blocks use the load-balanced
/// [`crate::linalg::par_chunks_tri`] low+high band pairing (row i of a
/// strict upper triangle computes n−1−i entries, so contiguous chunks
/// would leave the first thread ~2× the average load).
fn par_panels<T: Scalar, F: Fn(T, T) -> T + Copy + Sync>(
    w: &VectorSet<T>,
    v: &VectorSet<T>,
    tri: bool,
    threads: usize,
    out: &mut MatF64,
    f: F,
) {
    let (m, n) = (out.rows, out.cols);
    let run = |rows: std::ops::Range<usize>, chunk: &mut [f64]| {
        panel(w, v, rows, 0..n, tri, chunk, n, f)
    };
    if tri {
        crate::linalg::par_chunks_tri(&mut out.data, n, m, threads, run);
    } else {
        crate::linalg::par_chunks(&mut out.data, n, m, threads, run);
    }
}

/// Blocked N = W^T ∘min V.
pub fn mgemm2<T: Scalar>(w: &VectorSet<T>, v: &VectorSet<T>) -> MatF64 {
    mgemm2_mt(w, v, 1)
}

/// [`mgemm2`] over row panels on `threads` threads (bit-identical to
/// the serial kernel for any thread count).
pub fn mgemm2_mt<T: Scalar>(w: &VectorSet<T>, v: &VectorSet<T>, threads: usize) -> MatF64 {
    assert_eq!(w.nf, v.nf, "feature depth mismatch");
    let mut out = MatF64::zeros(w.nv, v.nv);
    par_panels(w, v, false, threads, &mut out, op_min::<T>);
    out
}

/// Diagonal-block mGEMM: N = V^T ∘min V, strict upper triangle only
/// (entries at and below the diagonal stay zero). ~2× fewer
/// elementwise ops than [`mgemm2`] on the same block; computed entries
/// are bit-identical to the full kernel's.
pub fn mgemm2_tri<T: Scalar>(v: &VectorSet<T>) -> MatF64 {
    mgemm2_tri_mt(v, 1)
}

/// [`mgemm2_tri`] on `threads` threads.
pub fn mgemm2_tri_mt<T: Scalar>(v: &VectorSet<T>, threads: usize) -> MatF64 {
    let mut out = MatF64::zeros(v.nv, v.nv);
    par_panels(v, v, true, threads, &mut out, op_min::<T>);
    out
}

/// Blocked true GEMM (same schedule, multiply-add inner op) — the native
/// comparator for the Table 1 min-vs-FMA headroom measurement.
pub fn gemm<T: Scalar>(w: &VectorSet<T>, v: &VectorSet<T>) -> MatF64 {
    gemm_mt(w, v, 1)
}

/// [`gemm`] over row panels on `threads` threads.
pub fn gemm_mt<T: Scalar>(w: &VectorSet<T>, v: &VectorSet<T>, threads: usize) -> MatF64 {
    assert_eq!(w.nf, v.nf, "feature depth mismatch");
    let mut out = MatF64::zeros(w.nv, v.nv);
    par_panels(w, v, false, threads, &mut out, op_mul::<T>);
    out
}

/// Diagonal-block GEMM: strict upper triangle of V^T V only.
pub fn gemm_tri<T: Scalar>(v: &VectorSet<T>) -> MatF64 {
    gemm_tri_mt(v, 1)
}

/// [`gemm_tri`] on `threads` threads.
pub fn gemm_tri_mt<T: Scalar>(v: &VectorSet<T>, threads: usize) -> MatF64 {
    let mut out = MatF64::zeros(v.nv, v.nv);
    par_panels(v, v, true, threads, &mut out, op_mul::<T>);
    out
}

/// One 3-way plane: X_t = pivot ∘min W materialized into `x` (rows
/// `0..xm`), then a 2-way pass against V written **directly into the
/// slab plane** (`plane_out`, ldo = v.nv) — no per-pivot full-plane
/// element copy. `cols` restricts the written columns (diag-aware
/// callers pass `jl+1..n`).
fn mgemm3_plane<T: Scalar>(
    w: &VectorSet<T>,
    pivot: &[T],
    v: &VectorSet<T>,
    xm: usize,
    cols: Range<usize>,
    x: &mut VectorSet<T>,
    plane_out: &mut [f64],
) {
    let nf = w.nf;
    for i in 0..xm {
        let wi = w.col(i);
        let xc = x.col_mut(i);
        for q in 0..nf {
            xc[q] = pivot[q].min_s(wi[q]);
        }
    }
    opcount::record((xm * nf) as u64);
    panel(x, v, 0..xm, cols, false, plane_out, v.nv, op_min::<T>);
}

/// Blocked 3-way slab: slab[t, i, k] = Σ_q min(pivot_t, w_i, v_k).
/// Implemented as the paper's X_j construction (§3.2): materialize
/// X_t = pivot_t ∘min W once per pivot, then a 2-way pass against V —
/// this halves the min count vs. the naive triple loop. The 2-way pass
/// writes straight into the slab's row-major plane.
pub fn mgemm3<T: Scalar>(w: &VectorSet<T>, pivots: &VectorSet<T>, v: &VectorSet<T>) -> SlabF64 {
    mgemm3_mt(w, pivots, v, 1)
}

/// [`mgemm3`] with pivot planes distributed over `threads` threads
/// (planes are disjoint slab runs → bit-identical for any count).
pub fn mgemm3_mt<T: Scalar>(
    w: &VectorSet<T>,
    pivots: &VectorSet<T>,
    v: &VectorSet<T>,
    threads: usize,
) -> SlabF64 {
    assert_eq!(w.nf, v.nf, "feature depth mismatch");
    assert_eq!(w.nf, pivots.nf, "feature depth mismatch");
    let (m, n, nf, jt) = (w.nv, v.nv, w.nf, pivots.nv);
    let mut out = SlabF64::zeros(jt, m, n);
    let plane = m * n;
    crate::linalg::par_chunks(&mut out.data, plane, jt, threads, |ts, chunk| {
        let mut x = VectorSet::<T>::zeros(nf, m); // X_t panel, reused per pivot
        for (pi, t) in ts.enumerate() {
            mgemm3_plane(w, pivots.col(t), v, m, 0..n, &mut x, &mut chunk[pi * plane..(pi + 1) * plane]);
        }
    });
    out
}

/// Diagonal-block 3-way slab over one vector set: pivots are columns of
/// `v` itself (local indices `pivot_locals`), and the coordinator only
/// reads slab[t, i, k] for i < pivot_locals[t] < k (the unique-triple
/// region, §4.2). This computes exactly that region — rows above the
/// pivot, columns beyond it — and leaves the redundant sub-slices zero,
/// cutting the per-plane elementwise ops from nv² to ~nv²/4 on average.
/// Computed entries are bit-identical to [`mgemm3`]'s.
pub fn mgemm3_diag<T: Scalar>(
    v: &VectorSet<T>,
    pivots: &VectorSet<T>,
    pivot_locals: &[usize],
) -> SlabF64 {
    mgemm3_diag_mt(v, pivots, pivot_locals, 1)
}

/// [`mgemm3_diag`] with pivot planes distributed over `threads` threads.
pub fn mgemm3_diag_mt<T: Scalar>(
    v: &VectorSet<T>,
    pivots: &VectorSet<T>,
    pivot_locals: &[usize],
    threads: usize,
) -> SlabF64 {
    assert_eq!(v.nf, pivots.nf, "feature depth mismatch");
    assert_eq!(pivots.nv, pivot_locals.len(), "one local index per pivot");
    let (n, nf, jt) = (v.nv, v.nf, pivots.nv);
    let mut out = SlabF64::zeros(jt, n, n);
    let plane = n * n;
    crate::linalg::par_chunks(&mut out.data, plane, jt, threads, |ts, chunk| {
        let mut x = VectorSet::<T>::zeros(nf, n);
        for (pi, t) in ts.enumerate() {
            let jl = pivot_locals[t];
            debug_assert!(jl < n, "pivot local index out of block");
            // A pivot at the block edge has an empty (i < jl < k)
            // region — skip the X build entirely (its plane stays
            // zero) rather than paying jl·nf mins for no output.
            if jl + 1 >= n {
                continue;
            }
            mgemm3_plane(v, pivots.col(t), v, jl, jl + 1..n, &mut x, &mut chunk[pi * plane..(pi + 1) * plane]);
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::reference;
    use crate::vecdata::SyntheticKind;

    fn gen(nf: usize, nv: usize, seed: u64, first: usize) -> VectorSet<f64> {
        VectorSet::generate(SyntheticKind::RandomGrid, seed, nf, nv, first)
    }

    #[test]
    fn mgemm2_matches_reference_all_shapes() {
        // Exercise remainder paths: sizes straddling JT and BI multiples.
        for &(nf, m, n) in &[(7usize, 3usize, 5usize), (64, 8, 8), (33, 37, 41), (128, 32, 64)] {
            let w = gen(nf, m, 1, 0);
            let v = gen(nf, n, 1, 1000);
            let a = mgemm2(&w, &v);
            let b = reference::mgemm2(&w, &v);
            assert_eq!(a.max_abs_diff(&b), 0.0, "shape ({nf},{m},{n})");
        }
    }

    #[test]
    fn mgemm2_f32_matches_reference_bitwise() {
        // Grid-valued f32 inputs: blocked accumulation order differs but
        // sums are exact, so results are bit-identical (paper §5).
        let w: VectorSet<f32> = VectorSet::generate(SyntheticKind::RandomGrid, 2, 96, 20, 0);
        let v: VectorSet<f32> = VectorSet::generate(SyntheticKind::RandomGrid, 2, 96, 24, 50);
        let a = mgemm2(&w, &v);
        let b = reference::mgemm2(&w, &v);
        assert_eq!(a.max_abs_diff(&b), 0.0);
    }

    #[test]
    fn gemm_matches_reference() {
        let w = gen(48, 19, 3, 0);
        let v = gen(48, 23, 3, 500);
        let a = gemm(&w, &v);
        let b = reference::gemm(&w, &v);
        assert!(a.max_abs_diff(&b) < 1e-10);
    }

    #[test]
    fn mgemm3_matches_reference() {
        let w = gen(29, 9, 4, 0);
        let p = gen(29, 5, 4, 200);
        let v = gen(29, 11, 4, 400);
        let a = mgemm3(&w, &p, &v);
        let b = reference::mgemm3(&w, &p, &v);
        assert_eq!(a.max_abs_diff(&b), 0.0);
    }

    #[test]
    fn triangular_matches_full_upper_triangle_bitwise() {
        // Shapes straddling the JT (8) and BI (32) boundaries.
        for &(nf, nv) in &[(7usize, 3usize), (64, 8), (33, 37), (96, 33), (20, 64)] {
            let v = gen(nf, nv, 5, 0);
            let full = mgemm2(&v, &v);
            let tri = mgemm2_tri(&v);
            let gfull = gemm(&v, &v);
            let gtri = gemm_tri(&v);
            for i in 0..nv {
                for j in 0..nv {
                    if j > i {
                        assert!(
                            tri.at(i, j).to_bits() == full.at(i, j).to_bits()
                                && gtri.at(i, j).to_bits() == gfull.at(i, j).to_bits(),
                            "({nf},{nv}) upper ({i},{j})"
                        );
                    } else {
                        assert_eq!(tri.at(i, j), 0.0, "({nf},{nv}) lower ({i},{j})");
                        assert_eq!(gtri.at(i, j), 0.0, "({nf},{nv}) lower ({i},{j})");
                    }
                }
            }
        }
    }

    #[test]
    fn thread_count_is_bit_invariant() {
        let w = gen(50, 45, 9, 0);
        let v = gen(50, 39, 9, 100);
        let serial = mgemm2(&w, &v);
        let gserial = gemm(&w, &v);
        let tserial = mgemm2_tri(&w);
        for threads in [2, 3, 4, 8] {
            assert_eq!(serial, mgemm2_mt(&w, &v, threads), "mgemm2 x{threads}");
            assert_eq!(gserial, gemm_mt(&w, &v, threads), "gemm x{threads}");
            assert_eq!(tserial, mgemm2_tri_mt(&w, threads), "tri x{threads}");
        }
    }

    #[test]
    fn mgemm3_threads_and_diag() {
        let v = gen(21, 13, 6, 0);
        let locals = [0usize, 4, 7, 12];
        let pivots = {
            let mut p = VectorSet::<f64>::zeros(21, locals.len());
            for (t, &j) in locals.iter().enumerate() {
                p.col_mut(t).copy_from_slice(v.col(j));
            }
            p
        };
        let full = mgemm3(&v, &pivots, &v);
        assert_eq!(full, mgemm3_mt(&v, &pivots, &v, 3), "mgemm3 threads");
        let diag = mgemm3_diag(&v, &pivots, &locals);
        assert_eq!(diag, mgemm3_diag_mt(&v, &pivots, &locals, 4), "diag threads");
        for (t, &jl) in locals.iter().enumerate() {
            for i in 0..13 {
                for k in 0..13 {
                    if i < jl && k > jl {
                        assert_eq!(diag.at(t, i, k).to_bits(), full.at(t, i, k).to_bits());
                    } else {
                        assert_eq!(diag.at(t, i, k), 0.0, "redundant ({t},{i},{k})");
                    }
                }
            }
        }
    }

    #[test]
    fn kernels_record_elementwise_ops() {
        // The counter is process-global and other lib tests run kernels
        // concurrently, so only lower bounds are assertable here; the
        // exact ≤55% diag-reduction proof lives in
        // `tests/triangular_threads.rs` (serialized binary).
        let v = gen(40, 48, 7, 0);
        let before = opcount::elem_ops();
        let _ = mgemm2(&v, &v);
        assert!(opcount::elem_ops() - before >= opcount::ops_full(40, 48, 48));
        let before = opcount::elem_ops();
        let _ = mgemm2_tri(&v);
        assert!(opcount::elem_ops() - before >= opcount::ops_tri(40, 48));
    }

}
