//! Optimized native mGEMM — the paper's "(possibly optimized) CPU
//! version" (§5), adapted to one host core.
//!
//! The optimization story mirrors what MAGMA does on the GPU, scaled to
//! the host cache hierarchy:
//! * **j-register-tiling**: each inner pass accumulates `JT` output
//!   columns at once into scalar accumulators, so each load of `w_i[q]`
//!   is reused JT times (the register-blocking that makes GEMM live).
//! * **q-contiguity**: vectors are column-contiguous, so the inner loop
//!   is a pure sequential sweep that the compiler autovectorizes
//!   (min + add per lane — exactly the paper's two ops per comparison).
//! * **i×j cache blocking**: outer blocks sized so the working panels
//!   stay in L1/L2 (the host stand-in for VMEM/shared-memory tiling).

use crate::linalg::{MatF64, SlabF64};
use crate::util::Scalar;
use crate::vecdata::VectorSet;

/// Output-column register tile. 8 f64 accumulators fit comfortably in
/// the 16 architectural vector registers alongside the streamed operand.
const JT: usize = 8;
/// Outer cache-block edge (vectors per block; panels of BI×n_f floats).
const BI: usize = 32;

/// Blocked N = W^T ∘min V.
pub fn mgemm2<T: Scalar>(w: &VectorSet<T>, v: &VectorSet<T>) -> MatF64 {
    assert_eq!(w.nf, v.nf, "feature depth mismatch");
    let (m, n, nf) = (w.nv, v.nv, w.nf);
    let mut out = MatF64::zeros(m, n);
    for i0 in (0..m).step_by(BI) {
        let i1 = (i0 + BI).min(m);
        for j0 in (0..n).step_by(BI) {
            let j1 = (j0 + BI).min(n);
            for i in i0..i1 {
                let wi = w.col(i);
                let mut j = j0;
                // Register-tiled main loop: JT columns at once.
                while j + JT <= j1 {
                    let mut acc = [T::ZERO; JT];
                    let cols: [&[T]; JT] = std::array::from_fn(|t| v.col(j + t));
                    for q in 0..nf {
                        let wq = wi[q];
                        for t in 0..JT {
                            acc[t] += wq.min_s(cols[t][q]);
                        }
                    }
                    for t in 0..JT {
                        out.set(i, j + t, acc[t].to_f64());
                    }
                    j += JT;
                }
                // Remainder columns.
                while j < j1 {
                    let vj = v.col(j);
                    let mut acc = T::ZERO;
                    for q in 0..nf {
                        acc += wi[q].min_s(vj[q]);
                    }
                    out.set(i, j, acc.to_f64());
                    j += 1;
                }
            }
        }
    }
    out
}

/// Blocked true GEMM (same schedule, multiply-add inner op) — the native
/// comparator for the Table 1 min-vs-FMA headroom measurement.
pub fn gemm<T: Scalar>(w: &VectorSet<T>, v: &VectorSet<T>) -> MatF64 {
    assert_eq!(w.nf, v.nf);
    let (m, n, nf) = (w.nv, v.nv, w.nf);
    let mut out = MatF64::zeros(m, n);
    for i0 in (0..m).step_by(BI) {
        let i1 = (i0 + BI).min(m);
        for j0 in (0..n).step_by(BI) {
            let j1 = (j0 + BI).min(n);
            for i in i0..i1 {
                let wi = w.col(i);
                let mut j = j0;
                while j + JT <= j1 {
                    let mut acc = [T::ZERO; JT];
                    let cols: [&[T]; JT] = std::array::from_fn(|t| v.col(j + t));
                    for q in 0..nf {
                        let wq = wi[q];
                        for t in 0..JT {
                            acc[t] += wq * cols[t][q];
                        }
                    }
                    for t in 0..JT {
                        out.set(i, j + t, acc[t].to_f64());
                    }
                    j += JT;
                }
                while j < j1 {
                    let vj = v.col(j);
                    let mut acc = T::ZERO;
                    for q in 0..nf {
                        acc += wi[q] * vj[q];
                    }
                    out.set(i, j, acc.to_f64());
                    j += 1;
                }
            }
        }
    }
    out
}

/// Blocked 3-way slab: slab[t, i, k] = Σ_q min(pivot_t, w_i, v_k).
/// Implemented as the paper's X_j construction (§3.2): materialize
/// X_t = pivot_t ∘min W once per pivot, then a 2-way pass against V —
/// this halves the min count vs. the naive triple loop.
pub fn mgemm3<T: Scalar>(w: &VectorSet<T>, pivots: &VectorSet<T>, v: &VectorSet<T>) -> SlabF64 {
    assert_eq!(w.nf, v.nf);
    assert_eq!(w.nf, pivots.nf);
    let (m, n, nf, jt) = (w.nv, v.nv, w.nf, pivots.nv);
    let mut out = SlabF64::zeros(jt, m, n);
    let mut x = VectorSet::<T>::zeros(nf, m); // X_t panel, reused per pivot
    for t in 0..jt {
        let pt = pivots.col(t).to_vec(); // detach borrow
        for i in 0..m {
            let wi = w.col(i);
            let xc = x.col_mut(i);
            for q in 0..nf {
                xc[q] = pt[q].min_s(wi[q]);
            }
        }
        let plane = mgemm2(&x, v);
        for i in 0..m {
            for k in 0..n {
                out.set(t, i, k, plane.at(i, k));
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::reference;
    use crate::vecdata::SyntheticKind;

    fn gen(nf: usize, nv: usize, seed: u64, first: usize) -> VectorSet<f64> {
        VectorSet::generate(SyntheticKind::RandomGrid, seed, nf, nv, first)
    }

    #[test]
    fn mgemm2_matches_reference_all_shapes() {
        // Exercise remainder paths: sizes straddling JT and BI multiples.
        for &(nf, m, n) in &[(7usize, 3usize, 5usize), (64, 8, 8), (33, 37, 41), (128, 32, 64)] {
            let w = gen(nf, m, 1, 0);
            let v = gen(nf, n, 1, 1000);
            let a = mgemm2(&w, &v);
            let b = reference::mgemm2(&w, &v);
            assert_eq!(a.max_abs_diff(&b), 0.0, "shape ({nf},{m},{n})");
        }
    }

    #[test]
    fn mgemm2_f32_matches_reference_bitwise() {
        // Grid-valued f32 inputs: blocked accumulation order differs but
        // sums are exact, so results are bit-identical (paper §5).
        let w: VectorSet<f32> = VectorSet::generate(SyntheticKind::RandomGrid, 2, 96, 20, 0);
        let v: VectorSet<f32> = VectorSet::generate(SyntheticKind::RandomGrid, 2, 96, 24, 50);
        let a = mgemm2(&w, &v);
        let b = reference::mgemm2(&w, &v);
        assert_eq!(a.max_abs_diff(&b), 0.0);
    }

    #[test]
    fn gemm_matches_reference() {
        let w = gen(48, 19, 3, 0);
        let v = gen(48, 23, 3, 500);
        let a = gemm(&w, &v);
        let b = reference::gemm(&w, &v);
        assert!(a.max_abs_diff(&b) < 1e-10);
    }

    #[test]
    fn mgemm3_matches_reference() {
        let w = gen(29, 9, 4, 0);
        let p = gen(29, 5, 4, 200);
        let v = gen(29, 11, 4, 400);
        let a = mgemm3(&w, &p, &v);
        let b = reference::mgemm3(&w, &p, &v);
        assert_eq!(a.max_abs_diff(&b), 0.0);
    }
}
