//! Parallel decompositions (paper §4): the processor grid, the 2-way
//! block-circulant plan, and the 3-way tetrahedral plan.
//!
//! The paper's three axes of internode parallelism (§4.1–4.2):
//! * `npf` — vector-*elements* axis (rows of V split; partial numerators
//!   reduced across the axis),
//! * `npv` — vector-*number* axis (columns of V split; induces the block
//!   structure of the result matrix/cube),
//! * `npr` — extra parallelism: blocks/slices of one block row (slab)
//!   are round-robined over `npr` nodes.
//!
//! Total nodes n_p = npf · npv · npr.

pub mod partition;
pub mod three_way;
pub mod two_way;

/// The (npf, npv, npr) processor grid.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Grid {
    pub npf: usize,
    pub npv: usize,
    pub npr: usize,
}

impl Grid {
    pub fn new(npf: usize, npv: usize, npr: usize) -> Self {
        assert!(npf >= 1 && npv >= 1 && npr >= 1);
        Grid { npf, npv, npr }
    }

    /// Total node count n_p.
    pub fn np(&self) -> usize {
        self.npf * self.npv * self.npr
    }

    /// Rank → (pf, pv, pr) coordinates. Rank layout: pf slowest, then
    /// pv, then pr fastest.
    pub fn coords(&self, rank: usize) -> NodeCoord {
        assert!(rank < self.np());
        let pr = rank % self.npr;
        let pv = (rank / self.npr) % self.npv;
        let pf = rank / (self.npr * self.npv);
        NodeCoord { pf, pv, pr }
    }

    /// (pf, pv, pr) → rank.
    pub fn rank(&self, c: NodeCoord) -> usize {
        assert!(c.pf < self.npf && c.pv < self.npv && c.pr < self.npr);
        (c.pf * self.npv + c.pv) * self.npr + c.pr
    }
}

/// A node's position in the grid.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NodeCoord {
    pub pf: usize,
    pub pv: usize,
    pub pr: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rank_coord_bijection() {
        let g = Grid::new(2, 3, 4);
        assert_eq!(g.np(), 24);
        for r in 0..g.np() {
            let c = g.coords(r);
            assert_eq!(g.rank(c), r);
        }
    }

    #[test]
    fn pr_is_fastest_axis() {
        let g = Grid::new(1, 2, 3);
        assert_eq!(g.coords(0), NodeCoord { pf: 0, pv: 0, pr: 0 });
        assert_eq!(g.coords(1), NodeCoord { pf: 0, pv: 0, pr: 1 });
        assert_eq!(g.coords(3), NodeCoord { pf: 0, pv: 1, pr: 0 });
    }

    #[test]
    #[should_panic]
    fn out_of_range_rank_panics() {
        Grid::new(1, 2, 1).coords(2);
    }
}
