//! 2-way block-circulant decomposition (paper §4.1, Figure 2(c)).
//!
//! The result matrix M is tiled into npv × npv blocks by the vector
//! partition. A naive upper-triangular assignment (Figure 2(a)) leaves
//! block rows with unequal work — up to 2× imbalance (Figure 2(b)). The
//! paper's fix: compute the block-circulant subset
//!
//! ```text
//!   { (r, (r + Δ) mod npv) : Δ = 0 … ⌊npv/2⌋ }
//! ```
//!
//! which covers every unique vector pair exactly once (for even npv the
//! Δ = npv/2 band is computed by the lower half of the rows only) and
//! gives every block row identical work. Steps Δ are round-robined over
//! the npr axis: node (pv, pr) computes step Δ iff Δ ≡ pr (mod npr).

/// One block of 2-way work for a node: compare own slab (row block)
/// against `col_block`'s vectors.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Block2 {
    /// Own (row) vector block id.
    pub row_block: usize,
    /// Peer (column) vector block id; == row_block for the diagonal.
    pub col_block: usize,
    /// Diagonal block: only the strict upper triangle is unique.
    pub diag: bool,
}

/// One parallel step of Algorithm 1 on a given node: the ring exchange
/// plus (possibly) a block computation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Step2 {
    /// Circulant offset Δ of this step.
    pub dp: usize,
    /// pv of the node our V block is sent to: (pv − Δ) mod npv.
    pub send_to_pv: usize,
    /// pv of the node whose V block we receive: (pv + Δ) mod npv.
    pub recv_from_pv: usize,
    /// Block to compute this step, if this (pv, pr) node owns it.
    pub compute: Option<Block2>,
}

/// Full Algorithm 1 schedule for node (pv, pr). All nodes execute the
/// same ring exchanges (so sends/receives pair up); ownership of the
/// compute differs.
pub fn plan(npv: usize, npr: usize, pv: usize, pr: usize) -> Vec<Step2> {
    assert!(pv < npv && pr < npr);
    let mut steps = Vec::new();
    for dp in 0..=npv / 2 {
        let send_to_pv = (pv + npv - dp % npv) % npv;
        let recv_from_pv = (pv + dp) % npv;
        let owned = dp % npr == pr && covered(npv, pv, dp);
        let compute = owned.then_some(Block2 {
            row_block: pv,
            col_block: recv_from_pv,
            diag: dp == 0,
        });
        steps.push(Step2 {
            dp,
            send_to_pv,
            recv_from_pv,
            compute,
        });
    }
    steps
}

/// Coverage rule: for even npv the Δ = npv/2 band pairs each row r with
/// r + npv/2; computing it from both rows would duplicate, so only rows
/// r < npv/2 compute it.
fn covered(npv: usize, pv: usize, dp: usize) -> bool {
    if npv % 2 == 0 && dp == npv / 2 {
        pv < npv / 2
    } else {
        dp <= npv / 2
    }
}

/// The naive Figure 2(a) assignment (for the load-imbalance ablation):
/// row block r computes blocks (r, c) for all c ≥ r.
pub fn plan_naive(npv: usize, pv: usize) -> Vec<Block2> {
    (pv..npv)
        .map(|c| Block2 {
            row_block: pv,
            col_block: c,
            diag: c == pv,
        })
        .collect()
}

/// Block count per node for the circulant plan — the paper's "load" ℓ
/// (§6.3); equal across pv by construction.
pub fn blocks_per_node(npv: usize, npr: usize, pv: usize, pr: usize) -> usize {
    plan(npv, npr, pv, pr)
        .iter()
        .filter(|s| s.compute.is_some())
        .count()
}

/// The npr that assigns exactly one block per node for a given npv
/// (paper §6.6: npr = ⌈npv/2 + 1⌉ gives ℓ = 1, npr = ⌈(npv/2 + 1)/ℓ⌉
/// gives load ℓ).
pub fn npr_for_load(npv: usize, load: usize) -> usize {
    (npv / 2 + 1).div_ceil(load).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    /// Every unique block pair {a, b} (and each diagonal) is computed
    /// exactly once across all nodes.
    fn coverage_check(npv: usize, npr: usize) {
        let mut seen: Vec<(usize, usize)> = Vec::new();
        for pv in 0..npv {
            for pr in 0..npr {
                for s in plan(npv, npr, pv, pr) {
                    if let Some(b) = s.compute {
                        let key = (b.row_block.min(b.col_block), b.row_block.max(b.col_block));
                        seen.push(key);
                    }
                }
            }
        }
        let unique: HashSet<_> = seen.iter().copied().collect();
        assert_eq!(seen.len(), unique.len(), "duplicate blocks npv={npv} npr={npr}");
        // Expected: npv diagonals + C(npv, 2) off-diagonal unordered pairs.
        assert_eq!(
            unique.len(),
            npv + npv * (npv - 1) / 2,
            "missing blocks npv={npv} npr={npr}"
        );
    }

    #[test]
    fn unique_coverage_odd_even() {
        for npv in [1, 2, 3, 4, 5, 6, 7, 8, 12, 16] {
            for npr in [1, 2, 3] {
                coverage_check(npv, npr);
            }
        }
    }

    #[test]
    fn circulant_load_is_balanced() {
        // Figure 2(c): every block row has the same number of blocks
        // (within the ±1 of the even-npv half band).
        for npv in [4usize, 6, 8, 16] {
            let counts: Vec<usize> = (0..npv).map(|pv| blocks_per_node(npv, 1, pv, 0)).collect();
            let min = counts.iter().min().unwrap();
            let max = counts.iter().max().unwrap();
            assert!(max - min <= 1, "npv={npv} counts={counts:?}");
        }
    }

    #[test]
    fn naive_load_is_imbalanced() {
        // Figure 2(b): the naive plan's first row has npv blocks, the
        // last row has 1 — the 2× average imbalance the paper avoids.
        let npv = 8;
        let first = plan_naive(npv, 0).len();
        let last = plan_naive(npv, npv - 1).len();
        assert_eq!(first, npv);
        assert_eq!(last, 1);
    }

    #[test]
    fn naive_covers_everything_too() {
        let npv = 6;
        let mut seen = HashSet::new();
        for pv in 0..npv {
            for b in plan_naive(npv, pv) {
                assert!(seen.insert((b.row_block, b.col_block)));
            }
        }
        assert_eq!(seen.len(), npv + npv * (npv - 1) / 2);
    }

    #[test]
    fn ring_exchange_pairs_up() {
        // At each step, the set of (sender, receiver) pairs must be a
        // permutation: everyone sends exactly once and receives exactly
        // once, so blocking send/recv pairs match.
        let (npv, npr) = (6, 2);
        for dp in 0..=npv / 2 {
            let mut recv_counts = vec![0; npv];
            for pv in 0..npv {
                let steps = plan(npv, npr, pv, 0);
                let s = &steps[dp];
                assert_eq!(s.dp, dp);
                recv_counts[s.recv_from_pv] += 1;
            }
            assert!(recv_counts.iter().all(|&c| c == 1));
        }
    }

    #[test]
    fn npr_round_robin_partitions_steps() {
        let (npv, npr) = (9, 3);
        for pv in 0..npv {
            let mut dps = Vec::new();
            for pr in 0..npr {
                for s in plan(npv, npr, pv, pr) {
                    if s.compute.is_some() {
                        dps.push(s.dp);
                    }
                }
            }
            dps.sort_unstable();
            let all: Vec<usize> = (0..=npv / 2).collect();
            assert_eq!(dps, all);
        }
    }

    #[test]
    fn npr_for_load_matches_paper() {
        // §6.6: npr = ⌈npv/2 + 1⌉ -> one block per node.
        let npv = 8;
        let npr = npr_for_load(npv, 1);
        assert_eq!(npr, npv / 2 + 1);
        for pv in 0..npv {
            for pr in 0..npr {
                assert!(blocks_per_node(npv, npr, pv, pr) <= 1);
            }
        }
        // Load 13 (the paper's weak-scaling setting) with npv=26:
        // ⌈(13+1)/13⌉ = 2.
        assert_eq!(npr_for_load(26, 13), 2);
    }

    #[test]
    fn single_node_plan() {
        let steps = plan(1, 1, 0, 0);
        assert_eq!(steps.len(), 1);
        assert_eq!(
            steps[0].compute,
            Some(Block2 { row_block: 0, col_block: 0, diag: true })
        );
    }
}
