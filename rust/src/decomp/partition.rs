//! Balanced contiguous 1-D partitions — used for both decomposition
//! axes (vectors across npv slabs; features across npf groups).

/// Partition `n` items into `parts` contiguous spans whose sizes differ
/// by at most one (the first `n % parts` spans get the extra item).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Partition {
    pub n: usize,
    pub parts: usize,
}

impl Partition {
    pub fn new(n: usize, parts: usize) -> Self {
        assert!(parts >= 1, "need at least one part");
        Partition { n, parts }
    }

    pub fn len(&self, p: usize) -> usize {
        assert!(p < self.parts);
        let base = self.n / self.parts;
        let extra = self.n % self.parts;
        base + usize::from(p < extra)
    }

    pub fn start(&self, p: usize) -> usize {
        assert!(p < self.parts);
        let base = self.n / self.parts;
        let extra = self.n % self.parts;
        p * base + p.min(extra)
    }

    pub fn end(&self, p: usize) -> usize {
        self.start(p) + self.len(p)
    }

    pub fn range(&self, p: usize) -> std::ops::Range<usize> {
        self.start(p)..self.end(p)
    }

    /// Which part owns global index `i`.
    pub fn owner(&self, i: usize) -> usize {
        assert!(i < self.n);
        let base = self.n / self.parts;
        let extra = self.n % self.parts;
        let boundary = extra * (base + 1);
        if i < boundary {
            i / (base + 1)
        } else {
            extra + (i - boundary) / base
        }
    }

    /// Largest part size (the padded block edge the runtime allocates).
    pub fn max_len(&self) -> usize {
        self.len(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn covers_exactly_once() {
        for (n, parts) in [(10, 3), (7, 7), (100, 8), (5, 1), (0, 3), (3, 5)] {
            let p = Partition::new(n, parts);
            let mut covered = vec![0usize; n];
            for part in 0..parts {
                for i in p.range(part) {
                    covered[i] += 1;
                }
            }
            assert!(covered.iter().all(|&c| c == 1), "n={n} parts={parts}");
        }
    }

    #[test]
    fn sizes_differ_by_at_most_one() {
        let p = Partition::new(10, 3);
        let lens: Vec<usize> = (0..3).map(|i| p.len(i)).collect();
        assert_eq!(lens, vec![4, 3, 3]);
        assert_eq!(p.max_len(), 4);
    }

    #[test]
    fn owner_consistent_with_range() {
        for (n, parts) in [(10, 3), (17, 5), (64, 8), (3, 5)] {
            let p = Partition::new(n, parts);
            for i in 0..n {
                let o = p.owner(i);
                assert!(p.range(o).contains(&i), "n={n} parts={parts} i={i}");
            }
        }
    }

    #[test]
    fn empty_parts_when_more_parts_than_items() {
        let p = Partition::new(3, 5);
        assert_eq!(p.len(4), 0);
        assert_eq!(p.start(4), 3);
    }
}
