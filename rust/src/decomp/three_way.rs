//! 3-way tetrahedral decomposition (paper §4.2, Figures 3–5).
//!
//! The results cube is tiled into npv³ blocks by the vector partition;
//! only ~1/6 of the cube is unique. Blocks fall into three classes
//! (Figure 5): the **diagonal edge** block (all three ids equal), **face**
//! blocks (exactly two equal) and **volume** blocks (all distinct). Per
//! slab (block row) the paper's modified scheme yields
//! 6 + 6(npv−1) + (npv−1)(npv−2) = (npv+1)(npv+2) slices, round-robined
//! over the npr axis, with staging (n_st) subdividing each slice's pivot
//! pipeline.
//!
//! **Divergence note (DESIGN.md §4):** for volume blocks the paper
//! selects per-block 1/6-slices via a folding/reflection construction
//! that is only sketched in the text. We use a provably-correct
//! equivalent with identical slice counts and the same communication
//! pattern: each unordered distinct block triple {A,B,C} is assigned to
//! a canonical owner slab by *circular distance* (rotation-invariant, so
//! ownership counts are balanced across slabs), and the owner's work is
//! split into 6 pivot-stripe sub-slices for the npr round-robin.
//!
//! Unique coverage argument: vector blocks are contiguous id ranges, so
//! for A < B < C every (i ∈ A, j ∈ B, k ∈ C) is automatically i < j < k;
//! combos of class {A,A,B} enumerate (i1 < i2 ∈ A) × (j ∈ B); the diag
//! combo enumerates i < j < k within A. Every unique triple falls in
//! exactly one combo class instance, and each combo is owned by exactly
//! one slab.

use crate::decomp::partition::Partition;

/// A combo: the unordered multiset of vector blocks a slice draws from.
/// The owning slab id is carried alongside in [`Slice3`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Combo3 {
    /// {own, own, own} — the diagonal edge block (6 sub-slices).
    Diag,
    /// {own, own, other} — a face combo (6 sub-slices each).
    Face { other: usize },
    /// {own, b, c} with own, b, c all distinct and owned by circular
    /// canonical rule (6 pivot-stripe sub-slices each).
    Volume { b: usize, c: usize },
}

/// One schedulable slice of 3-way work.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Slice3 {
    /// Owning slab (vector block whose node computes this).
    pub slab: usize,
    pub combo: Combo3,
    /// Pivot stripe 0..6.
    pub sub: usize,
    /// Global slice sequence number within the slab (round-robin key).
    pub seq: usize,
}

/// Circular distance pair from `x` to the other two members.
fn dist_pair(npv: usize, x: usize, y: usize, z: usize) -> (usize, usize) {
    let dy = (y + npv - x) % npv;
    let dz = (z + npv - x) % npv;
    (dy.min(dz), dy.max(dz))
}

/// Canonical owner of a distinct block triple {a, b, c}: the member with
/// the lexicographically smallest circular-distance pair to the other
/// two; ties (rotationally symmetric combos) break to the smallest id.
pub fn volume_owner(npv: usize, a: usize, b: usize, c: usize) -> usize {
    debug_assert!(a != b && b != c && a != c);
    let mut best = a;
    let mut best_d = dist_pair(npv, a, b, c);
    for &x in &[b, c] {
        let (p, q) = match x {
            x if x == b => (a, c),
            _ => (a, b),
        };
        let d = dist_pair(npv, x, p, q);
        if d < best_d || (d == best_d && x < best) {
            best = x;
            best_d = d;
        }
    }
    best
}

/// All combos owned by slab `pv`, in the deterministic schedule order
/// (diag first, then faces by circular offset, then volumes by offset
/// pair) — the order the slice sequence counter follows.
pub fn combos_owned(npv: usize, pv: usize) -> Vec<Combo3> {
    let mut out = vec![Combo3::Diag];
    for d in 1..npv {
        out.push(Combo3::Face {
            other: (pv + d) % npv,
        });
    }
    for dj in 1..npv {
        for dk in (dj + 1)..npv {
            let b = (pv + dj) % npv;
            let c = (pv + dk) % npv;
            if volume_owner(npv, pv, b, c) == pv {
                out.push(Combo3::Volume { b, c });
            }
        }
    }
    out
}

/// All slices for node (pv, pr): each owned combo contributes 6
/// pivot-stripe sub-slices; slices are round-robined over npr by their
/// per-slab sequence number (Algorithm 2's `mod(s_b, npr) = p_r`).
pub fn slices_for_node(npv: usize, npr: usize, pv: usize, pr: usize) -> Vec<Slice3> {
    let mut out = Vec::new();
    let mut seq = 0usize;
    for combo in combos_owned(npv, pv) {
        for sub in 0..6 {
            if seq % npr == pr {
                out.push(Slice3 {
                    slab: pv,
                    combo,
                    sub,
                    seq,
                });
            }
            seq += 1;
        }
    }
    out
}

/// Slice count per slab. The paper's count is (npv+1)(npv+2) exactly;
/// ours matches for the diag + face classes (6 + 6(npv−1)) and averages
/// (npv−1)(npv−2) for volumes (exact when ownership divides evenly).
pub fn slices_per_slab(npv: usize, pv: usize) -> usize {
    combos_owned(npv, pv).len() * 6
}

/// npr that gives each node approximately `load` slices (§6.7:
/// npr = ⌈(npv+1)(npv+2)/ℓ⌉).
pub fn npr_for_load(npv: usize, load: usize) -> usize {
    ((npv + 1) * (npv + 2)).div_ceil(load).max(1)
}

/// The pivot indices (local to the pivot block) of one sub-stripe and
/// stage: pivots j with j ≡ sub (mod 6) restricted to the stage's range
/// of the stripe (staging divides each slice's pivot pipeline into
/// n_st parts, §4.2).
pub fn stripe_pivots(
    nvb: usize,
    sub: usize,
    nst: usize,
    stage: usize,
) -> impl Iterator<Item = usize> {
    assert!(sub < 6 && stage < nst);
    let stripe: Vec<usize> = (0..nvb).filter(|j| j % 6 == sub).collect();
    let part = Partition::new(stripe.len(), nst);
    let range = part.range(stage);
    stripe.into_iter().enumerate().filter_map(move |(idx, j)| range.contains(&idx).then_some(j))
}

/// Enumerate the canonical (i < j < k) *global* triples of one slice
/// (one stage thereof), given the three block id ranges.
///
/// `blocks` is the campaign-wide vector partition.
pub fn slice_triples(
    slice: &Slice3,
    blocks: &Partition,
    nst: usize,
    stage: usize,
) -> Vec<(usize, usize, usize)> {
    let own = blocks.range(slice.slab);
    let mut out = Vec::new();
    match slice.combo {
        Combo3::Diag => {
            // Unique triples i < j < k inside the slab; pivot = middle.
            let nvb = own.len();
            for j_local in stripe_pivots(nvb, slice.sub, nst, stage) {
                let j = own.start + j_local;
                for i in own.start..j {
                    for k in (j + 1)..own.end {
                        out.push((i, j, k));
                    }
                }
            }
        }
        Combo3::Face { other } => {
            // Pairs (i1 < i2) from own block × pivot j from other block.
            let ob = blocks.range(other);
            for j_local in stripe_pivots(ob.len(), slice.sub, nst, stage) {
                let j = ob.start + j_local;
                for i1 in own.clone() {
                    for i2 in (i1 + 1)..own.end {
                        let mut t = [i1, i2, j];
                        t.sort_unstable();
                        out.push((t[0], t[1], t[2]));
                    }
                }
            }
        }
        Combo3::Volume { b, c } => {
            // Full cross product A × B × C, pivot-striped on B.
            let bb = blocks.range(b);
            let cb = blocks.range(c);
            for j_local in stripe_pivots(bb.len(), slice.sub, nst, stage) {
                let j = bb.start + j_local;
                for i in own.clone() {
                    for k in cb.clone() {
                        let mut t = [i, j, k];
                        t.sort_unstable();
                        out.push((t[0], t[1], t[2]));
                    }
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap;

    /// The fundamental invariant: across all nodes, slices, and stages,
    /// every unique triple (i < j < k) appears exactly once.
    fn coverage_check(nv: usize, npv: usize, npr: usize, nst: usize) {
        let blocks = Partition::new(nv, npv);
        let mut counts: HashMap<(usize, usize, usize), usize> = HashMap::new();
        for pv in 0..npv {
            for pr in 0..npr {
                for slice in slices_for_node(npv, npr, pv, pr) {
                    for stage in 0..nst {
                        for t in slice_triples(&slice, &blocks, nst, stage) {
                            assert!(t.0 < t.1 && t.1 < t.2, "non-canonical {t:?}");
                            *counts.entry(t).or_insert(0) += 1;
                        }
                    }
                }
            }
        }
        let expected = nv * (nv - 1) * (nv - 2) / 6;
        assert_eq!(
            counts.len(),
            expected,
            "missing triples nv={nv} npv={npv} npr={npr} nst={nst}"
        );
        for (t, c) in counts {
            assert_eq!(c, 1, "triple {t:?} computed {c} times");
        }
    }

    #[test]
    fn unique_coverage_various_grids() {
        coverage_check(12, 1, 1, 1);
        coverage_check(12, 2, 1, 1);
        coverage_check(12, 3, 2, 1);
        coverage_check(12, 4, 3, 2);
        coverage_check(18, 6, 2, 1);
        coverage_check(15, 5, 4, 3);
    }

    #[test]
    fn volume_ownership_is_rotation_invariant() {
        let npv = 7;
        for a in 0..npv {
            for b in 0..npv {
                for c in 0..npv {
                    if a == b || b == c || a == c {
                        continue;
                    }
                    let o = volume_owner(npv, a, b, c);
                    // Rotating the whole triple rotates the owner.
                    let o2 = volume_owner(npv, (a + 1) % npv, (b + 1) % npv, (c + 1) % npv);
                    assert_eq!((o + 1) % npv, o2);
                }
            }
        }
    }

    #[test]
    fn volume_ownership_balanced() {
        // Rotation invariance implies near-equal combo ownership; allow
        // the symmetric-tie slack the paper also accepts.
        for npv in [5usize, 6, 7, 8, 9] {
            let counts: Vec<usize> = (0..npv)
                .map(|pv| {
                    combos_owned(npv, pv)
                        .iter()
                        .filter(|c| matches!(c, Combo3::Volume { .. }))
                        .count()
                })
                .collect();
            let total: usize = counts.iter().sum();
            assert_eq!(total * 6, npv * (npv - 1) * (npv - 2), "npv={npv}");
            let min = counts.iter().min().unwrap();
            let max = counts.iter().max().unwrap();
            assert!(
                max - min <= 1 + npv / 3,
                "npv={npv} counts={counts:?}"
            );
        }
    }

    #[test]
    fn slice_counts_match_paper_scaling() {
        // Paper: (npv+1)(npv+2) slices per slab. Our diag+face counts
        // are exact; volume counts average (npv−1)(npv−2) per slab.
        for npv in [4usize, 6, 8] {
            let total: usize = (0..npv).map(|pv| slices_per_slab(npv, pv)).sum();
            let paper_total = npv * (npv + 1) * (npv + 2);
            let diff = (total as i64 - paper_total as i64).unsigned_abs() as usize;
            assert!(
                diff <= npv * 6,
                "npv={npv}: ours={total} paper={paper_total}"
            );
        }
    }

    #[test]
    fn npr_round_robin_partitions_slices() {
        let (npv, npr) = (5, 4);
        for pv in 0..npv {
            let mut seqs = Vec::new();
            for pr in 0..npr {
                for s in slices_for_node(npv, npr, pv, pr) {
                    seqs.push(s.seq);
                }
            }
            seqs.sort_unstable();
            assert_eq!(seqs, (0..slices_per_slab(npv, pv)).collect::<Vec<_>>());
        }
    }

    #[test]
    fn stage_partition_covers_stripe() {
        let nvb = 26;
        for sub in 0..6 {
            let whole: Vec<usize> = stripe_pivots(nvb, sub, 1, 0).collect();
            let mut staged: Vec<usize> = (0..4).flat_map(|s| stripe_pivots(nvb, sub, 4, s)).collect();
            staged.sort_unstable();
            let mut expect = whole.clone();
            expect.sort_unstable();
            assert_eq!(staged, expect);
        }
    }

    #[test]
    fn npr_for_load_matches_paper_formula() {
        // §6.7 example shape: npv=30, npr=496 with nst=220 on 14,880
        // nodes — check the formula direction: load 6 → npr ≈ (31·32)/6.
        assert_eq!(npr_for_load(30, 6), (31 * 32usize).div_ceil(6));
    }

    #[test]
    fn diag_slice_triples_small() {
        let blocks = Partition::new(6, 1);
        let mut all = Vec::new();
        for sub in 0..6 {
            let s = Slice3 { slab: 0, combo: Combo3::Diag, sub, seq: sub };
            all.extend(slice_triples(&s, &blocks, 1, 0));
        }
        all.sort_unstable();
        let expect: Vec<_> = crate::metrics::indexing::triples(6).collect();
        let mut expect_sorted = expect.clone();
        expect_sorted.sort_unstable();
        assert_eq!(all, expect_sorted);
    }
}
