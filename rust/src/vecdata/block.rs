//! First-class block representations: float vector blocks and cached
//! packed bit-planes, plus their wire forms.
//!
//! The paper's bit-packed Sorensen path (§2.3 / Table 6) gets its
//! throughput from operating on 64-element words. Before this module,
//! `--metric sorenson` runs still circulated f64 blocks and re-packed
//! both operands inside the numerator kernel on every parallel step.
//! Here packing happens **once at ingest** ([`crate::metrics::Metric::ingest`])
//! and the packed words themselves travel on the simulated wire
//! (~64× communication-volume reduction vs f64 elements) — the same
//! keep-it-packed discipline PLINK 2 applies to genotype data.
//!
//! Two layers of representation:
//! * [`Block`] — a coordinator-resident block in the metric's preferred
//!   representation (cached; cheap to clone — `Arc` inside).
//! * [`BlockData`] — the representation-tagged wire form carried by
//!   `comm::Payload::Block`, with byte accounting per variant (f64
//!   elements at run-precision width, packed words at 8 B/word).

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::util::Scalar;
use crate::vecdata::bits::BitVectorSet;
use crate::vecdata::geno::GenoBlock;
use crate::vecdata::VectorSet;

/// Which block representation a metric wants its operands in
/// (`metrics::Metric::preferred_repr`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum Repr {
    /// Dense float elements (`VectorSet<T>`): min-product / dot-product
    /// metric families.
    #[default]
    Float,
    /// Packed bit-planes (`BitVectorSet`): bitwise AND+popcount
    /// families.
    Packed,
    /// Two-plane 2-bit genotype packing (`GenoBlock`): the CCC
    /// allele-count family — dosage = lo + 2·hi, plus an optional
    /// missing-call mask plane.
    Packed2,
}

impl Repr {
    pub fn name(self) -> &'static str {
        match self {
            Repr::Float => "float",
            Repr::Packed => "packed",
            Repr::Packed2 => "packed2",
        }
    }
}

/// Packed-word wire payload: `words_per_vec` = ⌈nf/64⌉ words per
/// vector, vector-contiguous. 8 bytes per word on the simulated wire,
/// independent of the run's float precision.
#[derive(Debug, Clone)]
pub struct PackedBlock {
    pub words_per_vec: usize,
    pub words: Arc<Vec<u64>>,
}

/// Two-plane packed wire payload: per plane, `words_per_vec` =
/// ⌈nf/64⌉ words per vector, vector-contiguous. The missing mask plane
/// travels only when the block actually has missing calls.
#[derive(Debug, Clone)]
pub struct Packed2Block {
    pub words_per_vec: usize,
    pub lo: Arc<Vec<u64>>,
    pub hi: Arc<Vec<u64>>,
    pub missing: Option<Arc<Vec<u64>>>,
}

impl Packed2Block {
    /// Total u64 words across all planes present.
    pub fn total_words(&self) -> usize {
        self.lo.len() + self.hi.len() + self.missing.as_ref().map_or(0, |m| m.len())
    }
}

/// Wire form of a vector block — what `comm::Payload::Block` carries.
#[derive(Debug, Clone)]
pub enum BlockData {
    /// Column-major f64 elements, charged at the run precision's width.
    F64(Arc<Vec<f64>>),
    /// Bit-packed u64 words, charged at 8 bytes per word.
    Packed(PackedBlock),
    /// Two allele bit-planes (+ optional missing mask), charged at
    /// 8 bytes per word across every plane present.
    Packed2(Packed2Block),
}

impl BlockData {
    /// Simulated wire size in bytes. `elem_bytes` is the run
    /// precision's element width and applies only to float payloads;
    /// packed words are precision-independent.
    pub fn wire_bytes(&self, elem_bytes: usize) -> u64 {
        match self {
            BlockData::F64(d) => (d.len() * elem_bytes) as u64,
            BlockData::Packed(p) => (p.words.len() * 8) as u64,
            BlockData::Packed2(p) => (p.total_words() * 8) as u64,
        }
    }
}

/// A coordinator-resident vector block in its metric-preferred
/// representation. Cloning is cheap (shared `Arc` payloads), which is
/// what lets the 3-way node program keep a whole ring of peer blocks
/// cached without copies.
#[derive(Debug, Clone)]
pub enum Block<T: Scalar> {
    Float(Arc<VectorSet<T>>),
    Packed(Arc<BitVectorSet>),
    Packed2(Arc<GenoBlock>),
}

impl<T: Scalar> Block<T> {
    pub fn repr(&self) -> Repr {
        match self {
            Block::Float(_) => Repr::Float,
            Block::Packed(_) => Repr::Packed,
            Block::Packed2(_) => Repr::Packed2,
        }
    }

    pub fn nf(&self) -> usize {
        match self {
            Block::Float(v) => v.nf,
            Block::Packed(b) => b.nf,
            Block::Packed2(g) => g.nf(),
        }
    }

    pub fn nv(&self) -> usize {
        match self {
            Block::Float(v) => v.nv,
            Block::Packed(b) => b.nv,
            Block::Packed2(g) => g.nv(),
        }
    }

    pub fn first_id(&self) -> usize {
        match self {
            Block::Float(v) => v.first_id,
            Block::Packed(b) => b.first_id,
            Block::Packed2(g) => g.first_id(),
        }
    }

    /// Resident (in-memory) payload size in bytes — what the session
    /// block-cache budget charges. Float blocks cost their element
    /// storage at `T`'s width; packed blocks cost their u64 words at
    /// 8 B/word (the same ~64× bit-domain advantage the wire format
    /// sees).
    pub fn resident_bytes(&self) -> u64 {
        match self {
            Block::Float(v) => (v.raw().len() * std::mem::size_of::<T>()) as u64,
            Block::Packed(b) => (b.raw_words().len() * 8) as u64,
            Block::Packed2(g) => g.resident_bytes(),
        }
    }

    pub fn as_float(&self) -> Option<&VectorSet<T>> {
        match self {
            Block::Float(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_packed(&self) -> Option<&BitVectorSet> {
        match self {
            Block::Packed(b) => Some(b),
            _ => None,
        }
    }

    pub fn as_packed2(&self) -> Option<&GenoBlock> {
        match self {
            Block::Packed2(g) => Some(g),
            _ => None,
        }
    }

    /// Wire payload of this block. Called once per node block (before
    /// the step loop); each exchange step then clones the `Arc`, so no
    /// per-step conversion or packing ever happens.
    pub fn to_wire(&self) -> BlockData {
        match self {
            Block::Float(v) => {
                BlockData::F64(Arc::new(v.raw().iter().map(|x| x.to_f64()).collect()))
            }
            Block::Packed(b) => BlockData::Packed(PackedBlock {
                words_per_vec: b.words_per_vec,
                words: Arc::new(b.raw_words().to_vec()),
            }),
            Block::Packed2(g) => BlockData::Packed2(Packed2Block {
                words_per_vec: g.words_per_vec(),
                lo: Arc::new(g.lo.raw_words().to_vec()),
                hi: Arc::new(g.hi.raw_words().to_vec()),
                missing: g.missing.as_ref().map(|m| Arc::new(m.raw_words().to_vec())),
            }),
        }
    }

    /// Rehydrate a received wire payload into a resident block. The
    /// packed arm never re-packs — it adopts the words as sent.
    pub fn from_wire(nf: usize, nv: usize, first_id: usize, data: &BlockData) -> Result<Self> {
        match data {
            BlockData::F64(d) => {
                if d.len() != nf * nv {
                    bail!("float payload shape mismatch: {} elements for nf={nf} nv={nv}", d.len());
                }
                let mut vs = VectorSet::<T>::zeros(nf, nv);
                vs.first_id = first_id;
                for (dst, src) in vs.raw_mut().iter_mut().zip(d.iter()) {
                    *dst = T::from_f64(*src);
                }
                Ok(Block::Float(Arc::new(vs)))
            }
            BlockData::Packed(p) => {
                if p.words_per_vec != nf.div_ceil(64) {
                    bail!(
                        "packed payload words_per_vec {} inconsistent with nf={nf}",
                        p.words_per_vec
                    );
                }
                Ok(Block::Packed(Arc::new(BitVectorSet::from_words(
                    nf,
                    nv,
                    first_id,
                    p.words.as_ref().clone(),
                ))))
            }
            BlockData::Packed2(p) => {
                let wpv = nf.div_ceil(64);
                if p.words_per_vec != wpv {
                    bail!(
                        "packed2 payload words_per_vec {} inconsistent with nf={nf}",
                        p.words_per_vec
                    );
                }
                let plane_len = wpv * nv;
                if p.lo.len() != plane_len
                    || p.hi.len() != plane_len
                    || p.missing.as_ref().is_some_and(|m| m.len() != plane_len)
                {
                    bail!(
                        "packed2 payload plane shape mismatch: lo={} hi={} for nf={nf} nv={nv}",
                        p.lo.len(),
                        p.hi.len()
                    );
                }
                Ok(Block::Packed2(Arc::new(GenoBlock::from_planes(
                    nf,
                    nv,
                    first_id,
                    p.lo.as_ref().clone(),
                    p.hi.as_ref().clone(),
                    p.missing.as_ref().map(|m| m.as_ref().clone()),
                ))))
            }
        }
    }

    /// Select a subset of columns into a new block (3-way pivot
    /// batching). Float-only: every registered 3-way metric is a float
    /// family, and config validation keeps 2-way-only metrics away from
    /// the 3-way coordinator.
    pub fn select_cols(&self, cols: &[usize]) -> Result<Self> {
        match self {
            Block::Float(v) => Ok(Block::Float(Arc::new(v.select_cols(cols)))),
            Block::Packed(_) | Block::Packed2(_) => {
                bail!("column selection is not defined for packed blocks")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vecdata::SyntheticKind;

    #[test]
    fn float_wire_roundtrip() {
        let v: VectorSet<f64> = VectorSet::generate(SyntheticKind::RandomGrid, 5, 33, 6, 18);
        let b = Block::Float(Arc::new(v.clone()));
        assert_eq!((b.nf(), b.nv(), b.first_id()), (33, 6, 18));
        assert_eq!(b.repr(), Repr::Float);
        let wire = b.to_wire();
        let back = Block::<f64>::from_wire(33, 6, 18, &wire).unwrap();
        let bv = back.as_float().unwrap();
        for c in 0..6 {
            assert_eq!(bv.col(c), v.col(c));
        }
        assert_eq!(bv.first_id, 18);
    }

    #[test]
    fn packed_wire_roundtrip_is_bit_exact() {
        // (Repack-freedom is asserted via the pack-call counter in
        // tests/comm_accounting.rs, where a mutex serializes access to
        // the process-global counter; lib tests run in parallel.)
        let mut bits = BitVectorSet::generate(7, 130, 5, 0.4);
        bits.first_id = 40;
        let b: Block<f64> = Block::Packed(Arc::new(bits.clone()));
        assert_eq!(b.repr(), Repr::Packed);
        let wire = b.to_wire();
        let back = Block::<f64>::from_wire(130, 5, 40, &wire).unwrap();
        let rb = back.as_packed().unwrap();
        assert_eq!(rb.first_id, 40);
        for v in 0..5 {
            assert_eq!(rb.words(v), bits.words(v));
        }
    }

    #[test]
    fn wire_bytes_per_variant() {
        let v: VectorSet<f64> = VectorSet::generate(SyntheticKind::RandomGrid, 1, 100, 3, 0);
        let f = Block::Float(Arc::new(v)).to_wire();
        assert_eq!(f.wire_bytes(8), 100 * 3 * 8);
        assert_eq!(f.wire_bytes(4), 100 * 3 * 4); // charged at run precision
        let bits = BitVectorSet::generate(1, 100, 3, 0.5);
        let p = Block::<f64>::Packed(Arc::new(bits)).to_wire();
        // ⌈100/64⌉ = 2 words per vector, 8 B each, precision-independent.
        assert_eq!(p.wire_bytes(8), 2 * 3 * 8);
        assert_eq!(p.wire_bytes(4), 2 * 3 * 8);
    }

    #[test]
    fn shape_mismatches_rejected() {
        let wire = BlockData::F64(Arc::new(vec![0.0; 10]));
        assert!(Block::<f64>::from_wire(3, 4, 0, &wire).is_err());
        let p = BlockData::Packed(PackedBlock { words_per_vec: 3, words: Arc::new(vec![0; 6]) });
        assert!(Block::<f64>::from_wire(64, 2, 0, &p).is_err());
    }

    #[test]
    fn packed_blocks_refuse_column_selection() {
        let bits = BitVectorSet::generate(2, 64, 4, 0.5);
        let b: Block<f64> = Block::Packed(Arc::new(bits));
        assert!(b.select_cols(&[0, 1]).is_err());
        let v: VectorSet<f64> = VectorSet::generate(SyntheticKind::Alleles, 2, 64, 4, 0);
        let g: Block<f64> = Block::Packed2(Arc::new(GenoBlock::from_floats(&v)));
        assert!(g.select_cols(&[0, 1]).is_err());
    }

    #[test]
    fn packed2_wire_roundtrip_is_bit_exact() {
        let mut v: VectorSet<f64> = VectorSet::generate(SyntheticKind::Alleles, 9, 130, 5, 0);
        v.first_id = 40;
        let geno = GenoBlock::from_floats(&v);
        let b: Block<f64> = Block::Packed2(Arc::new(geno.clone()));
        assert_eq!(b.repr(), Repr::Packed2);
        assert_eq!((b.nf(), b.nv(), b.first_id()), (130, 5, 40));
        let wire = b.to_wire();
        let back = Block::<f64>::from_wire(130, 5, 40, &wire).unwrap();
        let rg = back.as_packed2().unwrap();
        assert_eq!(rg.first_id(), 40);
        for c in 0..5 {
            assert_eq!(rg.lo.words(c), geno.lo.words(c));
            assert_eq!(rg.hi.words(c), geno.hi.words(c));
        }
        assert!(rg.missing.is_none());
        // ⌈130/64⌉ = 3 words/vec × 5 vecs × 2 planes × 8 B, no mask.
        assert_eq!(wire.wire_bytes(8), 3 * 5 * 2 * 8);
        assert_eq!(wire.wire_bytes(4), 3 * 5 * 2 * 8); // precision-independent
        assert_eq!(b.resident_bytes(), 3 * 5 * 2 * 8);
    }

    #[test]
    fn packed2_mask_travels_and_shape_mismatch_rejected() {
        use crate::vecdata::geno::{self, MISSING};
        let dir = std::env::temp_dir().join("comet-tests");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join(format!("blockmask-{}.bed", std::process::id()));
        geno::write_bed_codes(&p, 3, &[1, MISSING, 2, 0, 0, MISSING]).unwrap();
        let g = geno::read_bed_cols(&p, 3, 2, 0, 2).unwrap().pack2();
        std::fs::remove_file(&p).ok();
        let b: Block<f64> = Block::Packed2(Arc::new(g.clone()));
        let wire = b.to_wire();
        // Mask plane adds a third word plane on the wire.
        assert_eq!(wire.wire_bytes(8), 2 * 3 * 8);
        let back = Block::<f64>::from_wire(3, 2, 0, &wire).unwrap();
        let rg = back.as_packed2().unwrap();
        assert_eq!(rg.missing_calls, 2);
        assert!(rg.missing.as_ref().unwrap().get_bit(0, 1));
        // Inconsistent words_per_vec and short planes are rejected.
        if let BlockData::Packed2(p2) = &wire {
            let bad = BlockData::Packed2(Packed2Block { words_per_vec: 2, ..p2.clone() });
            assert!(Block::<f64>::from_wire(3, 2, 0, &bad).is_err());
            let bad = BlockData::Packed2(Packed2Block { lo: Arc::new(vec![0]), ..p2.clone() });
            assert!(Block::<f64>::from_wire(3, 2, 0, &bad).is_err());
        } else {
            panic!("expected a Packed2 wire payload");
        }
    }
}
