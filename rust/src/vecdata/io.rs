//! The paper's on-disk input format (§6.8): a single column-major raw
//! binary file of vector data, from which "each compute node reads the
//! required portion" — i.e. a contiguous span of columns. No header; the
//! dimensions travel in the run config, exactly as on Titan.

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Seek, SeekFrom, Write};
use std::path::Path;

use crate::util::Scalar;
use crate::vecdata::VectorSet;
use anyhow::{bail, Context, Result};

/// Write a full vector set as a raw column-major binary file.
pub fn write_raw<T: Scalar>(path: &Path, set: &VectorSet<T>) -> Result<()> {
    let f = File::create(path).with_context(|| format!("create {}", path.display()))?;
    let mut w = BufWriter::new(f);
    for v in 0..set.nv {
        for x in set.col(v) {
            w.write_all(&x.to_bits_u64().to_le_bytes()[..T::BYTES])?;
        }
    }
    w.flush()?;
    Ok(())
}

/// Read columns [first_col, first_col + ncols) of an n_f × n_v file —
/// the per-node portion read (§6.8).
pub fn read_raw_cols<T: Scalar>(
    path: &Path,
    nf: usize,
    nv: usize,
    first_col: usize,
    ncols: usize,
) -> Result<VectorSet<T>> {
    if first_col + ncols > nv {
        bail!("column range [{first_col}, {}) exceeds nv={nv}", first_col + ncols);
    }
    let f = File::open(path).with_context(|| format!("open {}", path.display()))?;
    let expected = (nf * nv * T::BYTES) as u64;
    let actual = f.metadata()?.len();
    if actual != expected {
        bail!(
            "{}: size {actual} != expected {expected} (nf={nf} nv={nv} elem={}B)",
            path.display(),
            T::BYTES
        );
    }
    let mut r = BufReader::new(f);
    r.seek(SeekFrom::Start((first_col * nf * T::BYTES) as u64))?;
    let mut set = VectorSet::<T>::zeros(nf, ncols);
    set.first_id = first_col;
    // Safe per-column decode: one checked read per column, elements
    // reassembled from their little-endian images (no byte-level
    // aliasing of the element buffer).
    let mut colbuf = vec![0u8; nf * T::BYTES];
    for c in 0..ncols {
        r.read_exact(&mut colbuf).with_context(|| {
            format!(
                "{}: short read at column {} (nf={nf} elem={}B)",
                path.display(),
                first_col + c,
                T::BYTES
            )
        })?;
        let col = set.col_mut(c);
        for (dst, src) in col.iter_mut().zip(colbuf.chunks_exact(T::BYTES)) {
            *dst = T::from_le_bytes(src);
        }
    }
    Ok(set)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vecdata::SyntheticKind;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("comet-tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(format!("{name}-{}", std::process::id()))
    }

    #[test]
    fn roundtrip_full() {
        let set: VectorSet<f64> = VectorSet::generate(SyntheticKind::RandomGrid, 1, 17, 9, 0);
        let p = tmp("roundtrip-f64");
        write_raw(&p, &set).unwrap();
        let back: VectorSet<f64> = read_raw_cols(&p, 17, 9, 0, 9).unwrap();
        assert_eq!(set.raw(), back.raw());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn partial_read_matches_columns() {
        let set: VectorSet<f32> = VectorSet::generate(SyntheticKind::RandomGrid, 2, 11, 8, 0);
        let p = tmp("partial-f32");
        write_raw(&p, &set).unwrap();
        let part: VectorSet<f32> = read_raw_cols(&p, 11, 8, 3, 4).unwrap();
        assert_eq!(part.nv, 4);
        assert_eq!(part.first_id, 3);
        for v in 0..4 {
            assert_eq!(part.col(v), set.col(3 + v));
        }
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn size_mismatch_rejected() {
        let set: VectorSet<f64> = VectorSet::generate(SyntheticKind::RandomGrid, 3, 5, 5, 0);
        let p = tmp("badsize");
        write_raw(&p, &set).unwrap();
        let err = read_raw_cols::<f64>(&p, 6, 5, 0, 5).unwrap_err();
        assert!(err.to_string().contains("size"), "{err}");
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn truncated_file_names_actual_and_expected_sizes() {
        let set: VectorSet<f64> = VectorSet::generate(SyntheticKind::RandomGrid, 4, 6, 4, 0);
        let p = tmp("truncated");
        write_raw(&p, &set).unwrap();
        let f = std::fs::OpenOptions::new().write(true).open(&p).unwrap();
        f.set_len(6 * 4 * 8 - 5).unwrap();
        drop(f);
        let err = read_raw_cols::<f64>(&p, 6, 4, 0, 4).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("size 187"), "{msg}");
        assert!(msg.contains("expected 192"), "{msg}");
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn oversized_file_names_actual_and_expected_sizes() {
        let set: VectorSet<f32> = VectorSet::generate(SyntheticKind::RandomGrid, 5, 6, 4, 0);
        let p = tmp("oversized");
        write_raw(&p, &set).unwrap();
        let f = std::fs::OpenOptions::new().write(true).open(&p).unwrap();
        f.set_len(6 * 4 * 4 + 9).unwrap();
        drop(f);
        let err = read_raw_cols::<f32>(&p, 6, 4, 0, 4).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("size 105"), "{msg}");
        assert!(msg.contains("expected 96"), "{msg}");
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn out_of_range_rejected() {
        let set: VectorSet<f64> = VectorSet::generate(SyntheticKind::RandomGrid, 3, 5, 5, 0);
        let p = tmp("range");
        write_raw(&p, &set).unwrap();
        assert!(read_raw_cols::<f64>(&p, 5, 5, 3, 4).is_err());
        std::fs::remove_file(p).ok();
    }
}
