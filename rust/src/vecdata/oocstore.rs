//! Out-of-core block store: the spill side of the streaming-ingest
//! pipeline.
//!
//! When a session's `block_cache_bytes` budget evicts an ingested
//! block, the block is not discarded — it is **spilled** to a
//! per-dataset on-disk store in its resident representation (packed u64
//! words for bit-domain blocks, raw float panels otherwise) and
//! **reloaded** byte-for-byte on next touch, skipping the load + ingest
//! path entirely. This is the graceful-degradation half of the
//! out-of-core pipeline described by Fabregat-Traver & Bientinesi
//! (arXiv 1210.7683) and Beyer & Bientinesi (arXiv 1302.4332): budget
//! exceeded means "trade disk bandwidth for memory", never "recompute"
//! and never "OOM".
//!
//! Three pieces live here:
//!
//! * [`BlockStore`] — the object-safe byte-blob store seam ([`DirStore`]
//!   is the filesystem implementation; `testkit::faults::FailingStore`
//!   wraps any store with scripted fault injection for the test rigs).
//! * [`encode`]/[`decode`] — the spill codec: a little-endian header
//!   (shape, representation, element width) plus the raw resident
//!   payload, guarded by an FNV-1a checksum so a poisoned spill file is
//!   **detected** ([`StoreErrorKind::Corrupt`]) instead of silently
//!   corrupting bit-identical results.
//! * [`with_retry`] — the retry policy: [`StoreErrorKind::Transient`]
//!   errors are retried with exponential backoff;
//!   [`StoreErrorKind::Permanent`] and `Corrupt` errors surface
//!   immediately as typed errors (downcastable through `anyhow`), never
//!   as panics.
//!
//! The codec round-trip is bit-exact for every [`Repr`] — pinned per
//! representation (including partial trailing packed words) by
//! proptests in `tests/ooc_ingest.rs`.

use std::collections::HashMap;
use std::fmt;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::util::Scalar;
use crate::vecdata::bits::BitVectorSet;
use crate::vecdata::block::{Block, Repr};
use crate::vecdata::geno::GenoBlock;
use crate::vecdata::VectorSet;

/// How a store operation failed — the axis the retry policy and the
/// fault-injection rig both key on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StoreErrorKind {
    /// Worth retrying (interrupted syscall, timeout, contention).
    Transient,
    /// Retrying cannot help (missing directory, permissions, full disk).
    Permanent,
    /// The bytes came back but fail the codec's checksum or shape
    /// validation — a poisoned spill file.
    Corrupt,
}

impl StoreErrorKind {
    pub fn name(self) -> &'static str {
        match self {
            StoreErrorKind::Transient => "transient",
            StoreErrorKind::Permanent => "permanent",
            StoreErrorKind::Corrupt => "corrupt",
        }
    }
}

/// Typed spill-store error: the kind drives retry-vs-surface, the
/// message carries the operation context. Travels through `anyhow`
/// chains (and from there into `comet serve`'s `Error` wire frame)
/// without losing its type — callers can `downcast_ref::<StoreError>()`.
#[derive(Debug, Clone)]
pub struct StoreError {
    pub kind: StoreErrorKind,
    pub message: String,
}

impl StoreError {
    pub fn new(kind: StoreErrorKind, message: impl Into<String>) -> Self {
        StoreError { kind, message: message.into() }
    }

    pub fn transient(message: impl Into<String>) -> Self {
        Self::new(StoreErrorKind::Transient, message)
    }

    pub fn permanent(message: impl Into<String>) -> Self {
        Self::new(StoreErrorKind::Permanent, message)
    }

    pub fn corrupt(message: impl Into<String>) -> Self {
        Self::new(StoreErrorKind::Corrupt, message)
    }
}

impl fmt::Display for StoreError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "spill store {} error: {}", self.kind.name(), self.message)
    }
}

impl std::error::Error for StoreError {}

/// An object-safe byte-blob store for spilled blocks. Implementations
/// must be safe to call from any thread (evictions run on whichever
/// thread overflowed the budget; reloads on node and prefetch threads).
///
/// Keys are flat strings (safe as file names: `[A-Za-z0-9._-]`). A key,
/// once written, is immutable — blocks are pure functions of their
/// (dataset, repr, ingest key, grid slice) identity, so a second spill
/// of the same key may be skipped entirely.
pub trait BlockStore: Send + Sync {
    /// Store `bytes` under `key` (overwrite allowed, never required).
    fn put(&self, key: &str, bytes: &[u8]) -> Result<(), StoreError>;
    /// Fetch the bytes under `key`; `Ok(None)` when never spilled.
    fn get(&self, key: &str) -> Result<Option<Vec<u8>>, StoreError>;
    /// Whether `key` is present (used to skip redundant re-spills).
    fn contains(&self, key: &str) -> bool;
}

/// Classify an I/O error for the retry policy.
fn classify_io(e: &std::io::Error) -> StoreErrorKind {
    use std::io::ErrorKind as K;
    match e.kind() {
        K::Interrupted | K::WouldBlock | K::TimedOut => StoreErrorKind::Transient,
        _ => StoreErrorKind::Permanent,
    }
}

/// Filesystem [`BlockStore`]: one file per key under a directory.
/// The directory is created lazily on first write; a store constructed
/// with [`DirStore::temp`] owns its directory and removes it on drop
/// (per-session spill areas must not outlive the session).
pub struct DirStore {
    dir: PathBuf,
    owned: bool,
}

impl DirStore {
    /// A store over an existing (or to-be-created) directory the caller
    /// owns.
    pub fn new(dir: impl Into<PathBuf>) -> Self {
        DirStore { dir: dir.into(), owned: false }
    }

    /// A fresh process-unique spill directory under the system temp
    /// dir, removed when the store drops — the default session spill
    /// area.
    pub fn temp(label: &str) -> Self {
        static COUNTER: AtomicU64 = AtomicU64::new(0);
        let n = COUNTER.fetch_add(1, Ordering::Relaxed);
        let dir = std::env::temp_dir().join(format!(
            "comet-spill-{}-{label}-{n}",
            std::process::id()
        ));
        DirStore { dir, owned: true }
    }

    pub fn dir(&self) -> &std::path::Path {
        &self.dir
    }

    fn path_for(&self, key: &str) -> PathBuf {
        self.dir.join(key)
    }
}

impl Drop for DirStore {
    fn drop(&mut self) {
        if self.owned {
            let _ = std::fs::remove_dir_all(&self.dir);
        }
    }
}

impl BlockStore for DirStore {
    fn put(&self, key: &str, bytes: &[u8]) -> Result<(), StoreError> {
        std::fs::create_dir_all(&self.dir).map_err(|e| {
            StoreError::new(classify_io(&e), format!("create {}: {e}", self.dir.display()))
        })?;
        let path = self.path_for(key);
        // Write-then-rename so a crash mid-write never leaves a
        // truncated file under the real key (truncation would read as
        // Corrupt, but a clean store should not manufacture it).
        let tmp = self.dir.join(format!(".{key}.tmp"));
        std::fs::write(&tmp, bytes)
            .map_err(|e| StoreError::new(classify_io(&e), format!("write {key}: {e}")))?;
        std::fs::rename(&tmp, &path)
            .map_err(|e| StoreError::new(classify_io(&e), format!("commit {key}: {e}")))
    }

    fn get(&self, key: &str) -> Result<Option<Vec<u8>>, StoreError> {
        match std::fs::read(self.path_for(key)) {
            Ok(bytes) => Ok(Some(bytes)),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(None),
            Err(e) => Err(StoreError::new(classify_io(&e), format!("read {key}: {e}"))),
        }
    }

    fn contains(&self, key: &str) -> bool {
        self.path_for(key).exists()
    }
}

/// In-memory [`BlockStore`] — tests and ephemeral sessions.
#[derive(Default)]
pub struct MemStore {
    map: Mutex<HashMap<String, Vec<u8>>>,
}

impl MemStore {
    pub fn new() -> Self {
        Self::default()
    }

    /// Every key currently stored (unordered) — lets test rigs pick a
    /// spilled blob to poison without knowing the session's key scheme.
    pub fn keys(&self) -> Vec<String> {
        self.map.lock().unwrap().keys().cloned().collect()
    }
}

impl BlockStore for MemStore {
    fn put(&self, key: &str, bytes: &[u8]) -> Result<(), StoreError> {
        self.map.lock().unwrap().insert(key.to_string(), bytes.to_vec());
        Ok(())
    }

    fn get(&self, key: &str) -> Result<Option<Vec<u8>>, StoreError> {
        Ok(self.map.lock().unwrap().get(key).cloned())
    }

    fn contains(&self, key: &str) -> bool {
        self.map.lock().unwrap().contains_key(key)
    }
}

/// Attempts [`with_retry`] makes before giving up on a transient
/// failure (the fault rig scripts `RETRY_ATTEMPTS - 1` transient
/// errors to pin "recovers on the last try").
pub const RETRY_ATTEMPTS: u32 = crate::util::retry::DEFAULT_ATTEMPTS;

/// Run a store operation under the shared transient-retry policy
/// ([`crate::util::retry::Policy`]): transient errors are retried up
/// to [`RETRY_ATTEMPTS`] times with deterministic exponential backoff;
/// permanent and corrupt errors (and transient errors past the attempt
/// budget) surface immediately as the typed error.
pub fn with_retry<T>(op: impl FnMut() -> Result<T, StoreError>) -> Result<T, StoreError> {
    crate::util::retry::Policy::default()
        .run(|e: &StoreError| e.kind == StoreErrorKind::Transient, op)
}

/// FNV-1a 64-bit — the per-block payload checksum. Not cryptographic;
/// it detects poisoned/truncated spill files, which is the contract.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

const MAGIC: &[u8; 8] = b"COMETOC1";
const VERSION: u32 = 1;
const HEADER_LEN: usize = 8 + 4 + 4 + 4 + 4 + 8 * 6;

fn push_u32(out: &mut Vec<u8>, x: u32) {
    out.extend_from_slice(&x.to_le_bytes());
}

fn push_u64(out: &mut Vec<u8>, x: u64) {
    out.extend_from_slice(&x.to_le_bytes());
}

fn read_u32(bytes: &[u8], at: usize) -> u32 {
    u32::from_le_bytes(bytes[at..at + 4].try_into().unwrap())
}

fn read_u64(bytes: &[u8], at: usize) -> u64 {
    u64::from_le_bytes(bytes[at..at + 8].try_into().unwrap())
}

fn as_raw_bytes<T>(slice: &[T]) -> &[u8] {
    // SAFETY: T is f32/f64/u64 plain-old-data here; reading its bytes
    // is always valid (same idiom as `vecdata::io`).
    unsafe {
        std::slice::from_raw_parts(slice.as_ptr() as *const u8, std::mem::size_of_val(slice))
    }
}

/// Serialize a resident block into its spill form: LE header + the raw
/// payload in the block's **resident representation** (float elements
/// at `T`'s width, packed u64 words at 8 B/word) + nothing else. The
/// payload bytes are exactly the resident bytes — a spill/reload cycle
/// is bit-identical by construction, and `encode(b).len()` tracks
/// `b.resident_bytes() + HEADER_LEN`.
pub fn encode<T: Scalar>(block: &Block<T>) -> Vec<u8> {
    use std::borrow::Cow;
    // `flags` is the former reserved u32 (always 0 before the packed2
    // tag): for packed2 blobs, bit 0 records whether the missing-mask
    // plane is part of the payload.
    let (repr_tag, elem_width, flags, words_per_vec, payload): (u32, u32, u32, u64, Cow<[u8]>) =
        match block {
            Block::Float(v) => (0, T::BYTES as u32, 0, 0, Cow::Borrowed(as_raw_bytes(v.raw()))),
            Block::Packed(b) => {
                (1, 8, 0, b.words_per_vec as u64, Cow::Borrowed(as_raw_bytes(b.raw_words())))
            }
            Block::Packed2(g) => {
                // The three planes spill concatenated: lo ‖ hi ‖ mask.
                let mut bytes = Vec::with_capacity(g.resident_bytes() as usize);
                bytes.extend_from_slice(as_raw_bytes(g.lo.raw_words()));
                bytes.extend_from_slice(as_raw_bytes(g.hi.raw_words()));
                if let Some(m) = &g.missing {
                    bytes.extend_from_slice(as_raw_bytes(m.raw_words()));
                }
                (2, 8, g.missing.is_some() as u32, g.words_per_vec() as u64, Cow::Owned(bytes))
            }
        };
    let mut out = Vec::with_capacity(HEADER_LEN + payload.len());
    out.extend_from_slice(MAGIC);
    push_u32(&mut out, VERSION);
    push_u32(&mut out, repr_tag);
    push_u32(&mut out, elem_width);
    push_u32(&mut out, flags);
    push_u64(&mut out, block.nf() as u64);
    push_u64(&mut out, block.nv() as u64);
    push_u64(&mut out, block.first_id() as u64);
    push_u64(&mut out, words_per_vec);
    push_u64(&mut out, payload.len() as u64);
    push_u64(&mut out, fnv1a64(&payload));
    out.extend_from_slice(&payload);
    out
}

/// Deserialize a spill file back into a resident block. Every header
/// field and the payload checksum are validated; any mismatch is a
/// [`StoreErrorKind::Corrupt`] error — a poisoned spill file is
/// detected, never silently decoded into wrong results.
pub fn decode<T: Scalar>(bytes: &[u8]) -> Result<Block<T>, StoreError> {
    if bytes.len() < HEADER_LEN {
        return Err(StoreError::corrupt(format!(
            "spill blob too short: {} bytes (header is {HEADER_LEN})",
            bytes.len()
        )));
    }
    if &bytes[..8] != MAGIC {
        return Err(StoreError::corrupt("bad spill magic"));
    }
    let version = read_u32(bytes, 8);
    if version != VERSION {
        return Err(StoreError::corrupt(format!("unsupported spill version {version}")));
    }
    let repr_tag = read_u32(bytes, 12);
    let elem_width = read_u32(bytes, 16) as usize;
    let nf = read_u64(bytes, 24) as usize;
    let nv = read_u64(bytes, 32) as usize;
    let first_id = read_u64(bytes, 40) as usize;
    let words_per_vec = read_u64(bytes, 48) as usize;
    let payload_len = read_u64(bytes, 56) as usize;
    let checksum = read_u64(bytes, 64);
    let payload = &bytes[HEADER_LEN..];
    if payload.len() != payload_len {
        return Err(StoreError::corrupt(format!(
            "spill payload length {} != header's {payload_len}",
            payload.len()
        )));
    }
    if fnv1a64(payload) != checksum {
        return Err(StoreError::corrupt("spill payload checksum mismatch (poisoned file)"));
    }
    match repr_tag {
        0 => {
            if elem_width != T::BYTES {
                return Err(StoreError::corrupt(format!(
                    "float spill element width {elem_width} != run precision {}",
                    T::BYTES
                )));
            }
            if payload_len != nf * nv * T::BYTES {
                return Err(StoreError::corrupt(format!(
                    "float spill payload {payload_len} B != nf={nf} × nv={nv} × {} B",
                    T::BYTES
                )));
            }
            let mut vs = VectorSet::<T>::zeros(nf, nv);
            vs.first_id = first_id;
            // SAFETY: same POD byte view as encode; lengths checked.
            let dst = unsafe {
                std::slice::from_raw_parts_mut(
                    vs.raw_mut().as_mut_ptr() as *mut u8,
                    payload_len,
                )
            };
            dst.copy_from_slice(payload);
            Ok(Block::Float(Arc::new(vs)))
        }
        1 => {
            if words_per_vec != nf.div_ceil(64) {
                return Err(StoreError::corrupt(format!(
                    "packed spill words_per_vec {words_per_vec} inconsistent with nf={nf}"
                )));
            }
            if payload_len != words_per_vec * nv * 8 {
                return Err(StoreError::corrupt(format!(
                    "packed spill payload {payload_len} B != {words_per_vec} × nv={nv} words"
                )));
            }
            let words: Vec<u64> = payload
                .chunks_exact(8)
                .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
                .collect();
            Ok(Block::Packed(Arc::new(BitVectorSet::from_words(nf, nv, first_id, words))))
        }
        2 => {
            let has_mask = read_u32(bytes, 20) & 1 != 0;
            if words_per_vec != nf.div_ceil(64) {
                return Err(StoreError::corrupt(format!(
                    "packed2 spill words_per_vec {words_per_vec} inconsistent with nf={nf}"
                )));
            }
            let plane = words_per_vec * nv * 8;
            let planes = if has_mask { 3 } else { 2 };
            if payload_len != plane * planes {
                return Err(StoreError::corrupt(format!(
                    "packed2 spill payload {payload_len} B != {planes} planes of {plane} B \
                     ({words_per_vec} × nv={nv} words each)"
                )));
            }
            let words_at = |at: usize| -> Vec<u64> {
                payload[at..at + plane]
                    .chunks_exact(8)
                    .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
                    .collect()
            };
            let lo = words_at(0);
            let hi = words_at(plane);
            let missing = has_mask.then(|| words_at(2 * plane));
            Ok(Block::Packed2(Arc::new(GenoBlock::from_planes(
                nf, nv, first_id, lo, hi, missing,
            ))))
        }
        t => Err(StoreError::corrupt(format!("unknown spill repr tag {t}"))),
    }
}

/// The expected decoded representation of a spill blob (header peek,
/// no payload validation) — introspection for tests and tooling.
pub fn peek_repr(bytes: &[u8]) -> Option<Repr> {
    if bytes.len() < HEADER_LEN || &bytes[..8] != MAGIC {
        return None;
    }
    match read_u32(bytes, 12) {
        0 => Some(Repr::Float),
        1 => Some(Repr::Packed),
        2 => Some(Repr::Packed2),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vecdata::SyntheticKind;

    fn float_block(nf: usize, nv: usize, first: usize) -> Block<f64> {
        Block::Float(Arc::new(VectorSet::generate(
            SyntheticKind::RandomGrid,
            3,
            nf,
            nv,
            first,
        )))
    }

    #[test]
    fn float_codec_roundtrips_bit_exactly() {
        let b = float_block(33, 6, 12);
        let blob = encode(&b);
        assert_eq!(blob.len() as u64, b.resident_bytes() + HEADER_LEN as u64);
        assert_eq!(peek_repr(&blob), Some(Repr::Float));
        let back = decode::<f64>(&blob).unwrap();
        assert_eq!((back.nf(), back.nv(), back.first_id()), (33, 6, 12));
        let (a, c) = (b.as_float().unwrap(), back.as_float().unwrap());
        for (x, y) in a.raw().iter().zip(c.raw()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn packed_codec_roundtrips_partial_trailing_words() {
        // nf = 130: two full words + a 2-bit trailing word per vector.
        let mut bits = BitVectorSet::generate(9, 130, 5, 0.4);
        bits.first_id = 40;
        let b: Block<f64> = Block::Packed(Arc::new(bits.clone()));
        let blob = encode(&b);
        assert_eq!(peek_repr(&blob), Some(Repr::Packed));
        let back = decode::<f64>(&blob).unwrap();
        let rb = back.as_packed().unwrap();
        assert_eq!((rb.nf, rb.nv, rb.first_id), (130, 5, 40));
        assert_eq!(rb.raw_words(), bits.raw_words());
    }

    #[test]
    fn packed2_codec_roundtrips_with_and_without_mask() {
        use crate::vecdata::geno::{self, MISSING};
        // No mask: pack a clean allele cohort (nf=130 → partial word).
        let mut v: VectorSet<f64> = VectorSet::generate(SyntheticKind::Alleles, 21, 130, 5, 0);
        v.first_id = 7;
        let g = GenoBlock::from_floats(&v);
        let b: Block<f64> = Block::Packed2(Arc::new(g.clone()));
        let blob = encode(&b);
        assert_eq!(blob.len() as u64, b.resident_bytes() + HEADER_LEN as u64);
        assert_eq!(peek_repr(&blob), Some(Repr::Packed2));
        let back = decode::<f64>(&blob).unwrap();
        let rg = back.as_packed2().unwrap();
        assert_eq!((rg.nf(), rg.nv(), rg.first_id()), (130, 5, 7));
        assert_eq!(rg.lo.raw_words(), g.lo.raw_words());
        assert_eq!(rg.hi.raw_words(), g.hi.raw_words());
        assert!(rg.missing.is_none());
        // With mask: missing calls force the third plane through the
        // codec (and an all-missing column must survive byte-exactly).
        let dir = std::env::temp_dir().join("comet-tests");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join(format!("oocmask-{}.bed", std::process::id()));
        geno::write_bed_codes(&p, 3, &[1, MISSING, 2, MISSING, MISSING, MISSING]).unwrap();
        let gm = geno::read_bed_cols(&p, 3, 2, 0, 2).unwrap().pack2();
        std::fs::remove_file(&p).ok();
        let bm: Block<f64> = Block::Packed2(Arc::new(gm.clone()));
        let blob = encode(&bm);
        assert_eq!(blob.len() as u64, bm.resident_bytes() + HEADER_LEN as u64);
        let back = decode::<f64>(&blob).unwrap();
        let rg = back.as_packed2().unwrap();
        assert_eq!(rg.missing_calls, 4);
        assert_eq!(
            rg.missing.as_ref().unwrap().raw_words(),
            gm.missing.as_ref().unwrap().raw_words()
        );
        // Mask-flag tampering changes the expected payload size →
        // Corrupt, never a mis-shaped decode.
        let mut bad = blob.clone();
        bad[20] = 0;
        assert_eq!(decode::<f64>(&bad).unwrap_err().kind, StoreErrorKind::Corrupt);
    }

    #[test]
    fn poisoned_payload_is_detected_not_decoded() {
        let b = float_block(16, 4, 0);
        let mut blob = encode(&b);
        let last = blob.len() - 1;
        blob[last] ^= 0x01;
        let err = decode::<f64>(&blob).unwrap_err();
        assert_eq!(err.kind, StoreErrorKind::Corrupt);
        assert!(err.message.contains("checksum"), "{err}");
    }

    #[test]
    fn header_tampering_is_corrupt() {
        let b = float_block(16, 4, 0);
        let blob = encode(&b);
        // Truncation.
        assert_eq!(decode::<f64>(&blob[..HEADER_LEN - 1]).unwrap_err().kind, StoreErrorKind::Corrupt);
        assert_eq!(decode::<f64>(&blob[..blob.len() - 3]).unwrap_err().kind, StoreErrorKind::Corrupt);
        // Bad magic.
        let mut bad = blob.clone();
        bad[0] = b'X';
        assert_eq!(decode::<f64>(&bad).unwrap_err().kind, StoreErrorKind::Corrupt);
        // Wrong precision: an f64 spill must not decode as f32.
        assert_eq!(decode::<f32>(&blob).unwrap_err().kind, StoreErrorKind::Corrupt);
    }

    #[test]
    fn dir_store_roundtrip_and_missing_key() {
        let store = DirStore::temp("unit");
        assert_eq!(store.get("k").unwrap(), None);
        assert!(!store.contains("k"));
        store.put("k", b"hello").unwrap();
        assert!(store.contains("k"));
        assert_eq!(store.get("k").unwrap().as_deref(), Some(&b"hello"[..]));
        // Overwrite is allowed.
        store.put("k", b"world").unwrap();
        assert_eq!(store.get("k").unwrap().as_deref(), Some(&b"world"[..]));
    }

    #[test]
    fn temp_store_removes_its_directory_on_drop() {
        let dir = {
            let store = DirStore::temp("drop");
            store.put("k", b"x").unwrap();
            assert!(store.dir().exists());
            store.dir().to_path_buf()
        };
        assert!(!dir.exists(), "owned spill dir must not outlive the store");
    }

    #[test]
    fn retry_recovers_from_transient_and_respects_the_budget() {
        // Succeeds on the last allowed attempt.
        let mut calls = 0;
        let out = with_retry(|| {
            calls += 1;
            if calls < RETRY_ATTEMPTS {
                Err(StoreError::transient("flaky"))
            } else {
                Ok(calls)
            }
        });
        assert_eq!(out.unwrap(), RETRY_ATTEMPTS);
        // One more transient than the budget: surfaces the typed error.
        let mut calls = 0;
        let out: Result<(), _> = with_retry(|| {
            calls += 1;
            Err(StoreError::transient("always"))
        });
        assert_eq!(out.unwrap_err().kind, StoreErrorKind::Transient);
        assert_eq!(calls, RETRY_ATTEMPTS);
        // Permanent errors never retry.
        let mut calls = 0;
        let out: Result<(), _> = with_retry(|| {
            calls += 1;
            Err(StoreError::permanent("gone"))
        });
        assert_eq!(out.unwrap_err().kind, StoreErrorKind::Permanent);
        assert_eq!(calls, 1);
    }

    #[test]
    fn fnv_vectors() {
        // Standard FNV-1a 64 test vectors.
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85dd_35c9_0d56_ab4b);
    }
}
