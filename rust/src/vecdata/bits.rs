//! Bit-packed binary vectors for the Sorenson metric (paper §2.3).
//!
//! "The computation can be made much faster … by representing vector
//! entries as bits packed into words and operated upon using binary
//! arithmetic, based on the coincidence of the min-product and the
//! bitwise logical AND" — this module is that representation, and the
//! substrate for the Table 6 bitwise-baseline comparisons (Haque-style
//! 1-bit popcount codes).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;

use crate::linalg::simd;
use crate::util::prng::Stream;

/// Global count of packing conversions ([`BitVectorSet::from_threshold`]
/// calls). Test instrumentation for the pack-once contract: packing must
/// happen once per block at ingest, never inside the parallel step loop
/// (see `tests/comm_accounting.rs`).
static PACK_CALLS: AtomicU64 = AtomicU64::new(0);

/// Number of packing conversions performed so far (process-wide).
pub fn pack_calls() -> u64 {
    PACK_CALLS.load(Ordering::Relaxed)
}

/// n_v binary vectors of n_f features, each packed into ⌈n_f/64⌉ words.
#[derive(Debug, Clone)]
pub struct BitVectorSet {
    pub nf: usize,
    pub nv: usize,
    pub words_per_vec: usize,
    /// First global vector id (block offset within the campaign-wide
    /// numbering — the packed analogue of `VectorSet::first_id`).
    pub first_id: usize,
    data: Vec<u64>,
    /// Per-vector popcounts, computed once and cached alongside the
    /// packed planes ([`BitVectorSet::popcounts`] used to allocate and
    /// re-sweep on every call — a per-step cost on the Sorensen
    /// denominator path). Filled lazily, primed at ingest
    /// ([`BitVectorSet::from_threshold`]), invalidated by
    /// [`BitVectorSet::set_bit`]. Resident-side only: the wire form
    /// ([`crate::vecdata::block::PackedBlock`]) still carries packed
    /// words alone, so comm byte accounting is unchanged.
    pops: OnceLock<Vec<f64>>,
}

impl BitVectorSet {
    pub fn zeros(nf: usize, nv: usize) -> Self {
        let words_per_vec = nf.div_ceil(64);
        BitVectorSet {
            nf,
            nv,
            words_per_vec,
            first_id: 0,
            data: vec![0; words_per_vec * nv],
            pops: OnceLock::new(),
        }
    }

    /// Rehydrate a packed set from raw words (the wire → block path:
    /// `comm::Payload` carries packed words, not floats, for bit-domain
    /// metrics). `words` must hold exactly ⌈nf/64⌉ × nv words.
    pub fn from_words(nf: usize, nv: usize, first_id: usize, words: Vec<u64>) -> Self {
        let words_per_vec = nf.div_ceil(64);
        assert_eq!(
            words.len(),
            words_per_vec * nv,
            "packed payload shape mismatch: {} words for nf={nf} nv={nv}",
            words.len()
        );
        BitVectorSet { nf, nv, words_per_vec, first_id, data: words, pops: OnceLock::new() }
    }

    /// Random binary vectors with the given bit density.
    pub fn generate(seed: u64, nf: usize, nv: usize, density: f64) -> Self {
        let mut set = Self::zeros(nf, nv);
        for v in 0..nv {
            let mut s = Stream::for_vector(seed, v as u64);
            for q in 0..nf {
                if s.next_f64() < density {
                    set.set_bit(v, q);
                }
            }
        }
        set
    }

    /// Quantize a non-negative float vector set: bit = (value > threshold).
    pub fn from_threshold<T: crate::util::Scalar>(
        set: &crate::vecdata::VectorSet<T>,
        threshold: f64,
    ) -> Self {
        PACK_CALLS.fetch_add(1, Ordering::Relaxed);
        let mut out = Self::zeros(set.nf, set.nv);
        out.first_id = set.first_id;
        for v in 0..set.nv {
            for (q, &x) in set.col(v).iter().enumerate() {
                if x.to_f64() > threshold {
                    out.set_bit(v, q);
                }
            }
        }
        // Prime the popcount cache at ingest: the Sorensen denominator
        // pass per block becomes a cached read instead of a re-sweep.
        let _ = out.popcounts_cached();
        out
    }

    #[inline]
    pub fn set_bit(&mut self, v: usize, q: usize) {
        debug_assert!(v < self.nv && q < self.nf);
        self.data[v * self.words_per_vec + q / 64] |= 1u64 << (q % 64);
        // Mutation invalidates the cached popcounts.
        self.pops.take();
    }

    #[inline]
    pub fn get_bit(&self, v: usize, q: usize) -> bool {
        (self.data[v * self.words_per_vec + q / 64] >> (q % 64)) & 1 == 1
    }

    #[inline]
    pub fn words(&self, v: usize) -> &[u64] {
        &self.data[v * self.words_per_vec..(v + 1) * self.words_per_vec]
    }

    /// All packed words, vector-contiguous (the wire layout).
    #[inline]
    pub fn raw_words(&self) -> &[u64] {
        &self.data
    }

    /// Population count of vector v (its Sorenson denominator half) —
    /// a wide-lane word sweep ([`simd::popcount`]).
    pub fn popcount(&self, v: usize) -> u64 {
        simd::popcount(self.words(v))
    }

    /// Popcounts of every vector as f64 — the Sorensen metric's
    /// denominator ingredients (the bit analogue of
    /// [`crate::vecdata::VectorSet::col_sums`]). Served from the
    /// per-set cache; see [`BitVectorSet::popcounts_cached`] for the
    /// allocation-free view.
    pub fn popcounts(&self) -> Vec<f64> {
        self.popcounts_cached().to_vec()
    }

    /// Cached per-vector popcounts, computed on first use (primed at
    /// ingest by [`BitVectorSet::from_threshold`]) and invalidated by
    /// [`BitVectorSet::set_bit`].
    pub fn popcounts_cached(&self) -> &[f64] {
        self.pops
            .get_or_init(|| (0..self.nv).map(|v| self.popcount(v) as f64).collect())
    }

    /// Sorenson numerator: |u AND v| — the bitwise min-product, wide
    /// popcount lanes ([`simd::and_popcount`]).
    pub fn and_popcount(&self, u: usize, v: usize) -> u64 {
        simd::and_popcount(self.words(u), self.words(v))
    }

    /// Sorenson metric c2 = 2|u∧v| / (|u| + |v|).
    pub fn sorenson2(&self, u: usize, v: usize) -> f64 {
        let d = self.popcount(u) + self.popcount(v);
        if d == 0 {
            return 0.0;
        }
        2.0 * self.and_popcount(u, v) as f64 / d as f64
    }

    /// Expand to a float VectorSet (for cross-checking the coincidence of
    /// Sorenson with the Proportional Similarity on 0/1 data, §2.3).
    pub fn to_floats(&self) -> crate::vecdata::VectorSet<f64> {
        let mut out = crate::vecdata::VectorSet::<f64>::zeros(self.nf, self.nv);
        out.first_id = self.first_id;
        for v in 0..self.nv {
            for q in 0..self.nf {
                if self.get_bit(v, q) {
                    out.col_mut(v)[q] = 1.0;
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_get_roundtrip() {
        let mut s = BitVectorSet::zeros(130, 3);
        s.set_bit(1, 0);
        s.set_bit(1, 63);
        s.set_bit(1, 64);
        s.set_bit(2, 129);
        assert!(s.get_bit(1, 0) && s.get_bit(1, 63) && s.get_bit(1, 64));
        assert!(s.get_bit(2, 129));
        assert!(!s.get_bit(0, 0));
        assert_eq!(s.popcount(1), 3);
    }

    #[test]
    fn tail_bits_stay_clear() {
        // nf=130 -> 3 words; bits 130..192 must never be set by generate.
        let s = BitVectorSet::generate(5, 130, 8, 0.5);
        for v in 0..8 {
            let manual: u64 = (0..130).filter(|&q| s.get_bit(v, q)).count() as u64;
            assert_eq!(s.popcount(v), manual);
        }
    }

    #[test]
    fn and_popcount_matches_direct() {
        let s = BitVectorSet::generate(7, 200, 6, 0.3);
        for u in 0..6 {
            for v in 0..6 {
                let direct = (0..200).filter(|&q| s.get_bit(u, q) && s.get_bit(v, q)).count();
                assert_eq!(s.and_popcount(u, v), direct as u64);
            }
        }
    }

    #[test]
    fn sorenson_equals_czekanowski_on_bits() {
        // Paper §2.3: the metrics coincide on 0/1 data.
        let s = BitVectorSet::generate(9, 96, 10, 0.4);
        let f = s.to_floats();
        for u in 0..10 {
            for v in (u + 1)..10 {
                let a = s.sorenson2(u, v);
                let b = crate::metrics::czekanowski2(f.col(u), f.col(v));
                assert!((a - b).abs() < 1e-12, "({u},{v}): {a} vs {b}");
            }
        }
    }

    #[test]
    fn from_words_roundtrip_preserves_bits_and_first_id() {
        let mut s = BitVectorSet::generate(3, 130, 4, 0.5);
        s.first_id = 12;
        let r = BitVectorSet::from_words(130, 4, 12, s.raw_words().to_vec());
        assert_eq!(r.first_id, 12);
        assert_eq!(r.words_per_vec, s.words_per_vec);
        for v in 0..4 {
            assert_eq!(r.words(v), s.words(v));
        }
        // first_id survives both representation conversions.
        let f = s.to_floats();
        assert_eq!(f.first_id, 12);
        assert_eq!(BitVectorSet::from_threshold(&f, 0.5).first_id, 12);
    }

    #[test]
    #[should_panic(expected = "packed payload shape mismatch")]
    fn from_words_rejects_wrong_shape() {
        let _ = BitVectorSet::from_words(130, 4, 0, vec![0u64; 5]);
    }

    #[test]
    fn pack_call_counter_increments() {
        let fs: crate::vecdata::VectorSet<f64> =
            crate::vecdata::VectorSet::generate(crate::vecdata::SyntheticKind::RandomGrid, 4, 64, 2, 0);
        let before = pack_calls();
        let _ = BitVectorSet::from_threshold(&fs, 0.5);
        assert!(pack_calls() > before);
    }

    #[test]
    fn popcount_cache_tracks_mutation() {
        let mut s = BitVectorSet::zeros(100, 2);
        assert_eq!(s.popcounts(), vec![0.0, 0.0]);
        s.set_bit(0, 5);
        s.set_bit(1, 64);
        assert_eq!(s.popcounts_cached(), &[1.0, 1.0]);
        // A mutation after the cache fills must invalidate it.
        s.set_bit(0, 99);
        assert_eq!(s.popcounts(), vec![2.0, 1.0]);
        // Clones carry (or refill) a consistent cache.
        assert_eq!(s.clone().popcounts_cached(), &[2.0, 1.0]);
    }

    #[test]
    fn threshold_quantization() {
        let fs: crate::vecdata::VectorSet<f64> =
            crate::vecdata::VectorSet::generate(crate::vecdata::SyntheticKind::RandomGrid, 3, 64, 4, 0);
        let bits = BitVectorSet::from_threshold(&fs, 0.5);
        for v in 0..4 {
            for q in 0..64 {
                assert_eq!(bits.get_bit(v, q), fs.col(v)[q] > 0.5);
            }
        }
    }
}
