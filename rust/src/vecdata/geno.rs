//! Real-data genotype front end (§6.8): PLINK `.bed` and VCF readers
//! feeding CCC's native two-plane packed representation.
//!
//! PLINK stores genotypes as 2-bit codes in variant-major rows — exactly
//! the packed form the companion CCC paper wants on the wire — so the
//! `.bed` reader's per-variant rows are literally the per-node column
//! spans `io::read_raw_cols` reads from the raw float format. The VCF
//! reader decodes GT fields from a streaming line parser, fanning chunk
//! decodes out over the `linalg::pool` workers.
//!
//! Both readers produce [`GenoCodes`] (one byte per call: 0/1/2 alt-allele
//! dosage, [`MISSING`]), which either expands to a float `VectorSet` (the
//! oracle path — missing imputes to 0, i.e. hom-ref) or packs once into a
//! [`GenoBlock`]: two allele bit-planes (`lo` = dosage bit 0, `hi` =
//! dosage bit 1) plus an optional missing-call mask. Dosage = `lo + 2·hi`
//! as exact small integers, so every CCC count computed on the planes is
//! bit-identical to the float path.

use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use anyhow::{bail, Context, Result};

use crate::linalg::pool;
use crate::util::Scalar;
use crate::vecdata::bits::BitVectorSet;
use crate::vecdata::VectorSet;

/// Variant-major PLINK `.bed` magic (the third byte selects the
/// variant-major layout; sample-major files are rejected).
pub const BED_MAGIC: [u8; 3] = [0x6c, 0x1b, 0x01];

/// Code for a missing genotype call in [`GenoCodes`].
pub const MISSING: u8 = 3;

/// Genotype calls decoded from real-format inputs (process-wide).
static GENO_CALLS: AtomicU64 = AtomicU64::new(0);
/// Missing calls among them (imputed to hom-ref at decode).
static GENO_MISSING: AtomicU64 = AtomicU64::new(0);
/// Two-plane packing conversions ([`GenoBlock`] constructions from
/// floats or codes) — the pack-once contract's counter, mirroring
/// [`crate::vecdata::bits::pack_calls`].
static PACK2_CALLS: AtomicU64 = AtomicU64::new(0);

/// Genotype calls decoded so far (process-wide).
pub fn calls_decoded() -> u64 {
    GENO_CALLS.load(Ordering::Relaxed)
}

/// Missing genotype calls decoded so far (process-wide).
pub fn missing_calls() -> u64 {
    GENO_MISSING.load(Ordering::Relaxed)
}

/// Two-plane packing conversions performed so far (process-wide).
pub fn pack2_calls() -> u64 {
    PACK2_CALLS.load(Ordering::Relaxed)
}

/// A decoded column span of genotype calls: one byte per call
/// (variant-contiguous, `nf` calls per variant), values 0/1/2 or
/// [`MISSING`]. The common output of both readers, one small step from
/// either representation the engine wants.
#[derive(Debug, Clone)]
pub struct GenoCodes {
    pub nf: usize,
    pub nv: usize,
    pub first_id: usize,
    codes: Vec<u8>,
    pub missing: u64,
}

impl GenoCodes {
    /// Wrap freshly decoded codes, counting calls into the process-wide
    /// ingest counters.
    fn finish(nf: usize, nv: usize, first_id: usize, codes: Vec<u8>) -> Self {
        debug_assert_eq!(codes.len(), nf * nv);
        let missing = codes.iter().filter(|&&c| c == MISSING).count() as u64;
        GENO_CALLS.fetch_add(codes.len() as u64, Ordering::Relaxed);
        GENO_MISSING.fetch_add(missing, Ordering::Relaxed);
        GenoCodes { nf, nv, first_id, codes, missing }
    }

    #[inline]
    pub fn code(&self, v: usize, q: usize) -> u8 {
        self.codes[v * self.nf + q]
    }

    /// Expand to the float representation the scalar oracle and the
    /// non-CCC metrics run on. Missing imputes to 0 (hom-ref) — the
    /// same value the packed planes carry, so both paths agree bit for
    /// bit.
    pub fn to_floats<T: Scalar>(&self) -> VectorSet<T> {
        let mut out = VectorSet::<T>::zeros(self.nf, self.nv);
        out.first_id = self.first_id;
        for v in 0..self.nv {
            let col = out.col_mut(v);
            for (q, &c) in self.codes[v * self.nf..(v + 1) * self.nf].iter().enumerate() {
                if c != MISSING && c != 0 {
                    col[q] = T::from_f64(c as f64);
                }
            }
        }
        out
    }

    /// Pack once into the two-plane block (counts toward
    /// [`pack2_calls`]). The missing mask plane is materialized only
    /// when the span actually has missing calls.
    pub fn pack2(&self) -> GenoBlock {
        PACK2_CALLS.fetch_add(1, Ordering::Relaxed);
        let mut lo = BitVectorSet::zeros(self.nf, self.nv);
        let mut hi = BitVectorSet::zeros(self.nf, self.nv);
        lo.first_id = self.first_id;
        hi.first_id = self.first_id;
        let mut miss = if self.missing > 0 {
            let mut m = BitVectorSet::zeros(self.nf, self.nv);
            m.first_id = self.first_id;
            Some(m)
        } else {
            None
        };
        for v in 0..self.nv {
            for (q, &c) in self.codes[v * self.nf..(v + 1) * self.nf].iter().enumerate() {
                match c {
                    0 => {}
                    1 => lo.set_bit(v, q),
                    2 => hi.set_bit(v, q),
                    _ => {
                        if let Some(m) = miss.as_mut() {
                            m.set_bit(v, q);
                        }
                    }
                }
            }
        }
        GenoBlock::assemble(lo, hi, miss, self.missing)
    }
}

/// A two-plane packed genotype block: `lo`/`hi` carry the alt-allele
/// dosage bits (dosage = `lo + 2·hi` ∈ {0, 1, 2}), `missing` marks
/// imputed calls (0 on both dosage planes, so CCC counts ignore them
/// exactly as the float path's missing→0 does). This is the resident
/// form behind `Block::Packed2` / `Repr::Packed2`.
#[derive(Debug, Clone)]
pub struct GenoBlock {
    pub lo: BitVectorSet,
    pub hi: BitVectorSet,
    pub missing: Option<BitVectorSet>,
    /// Missing calls in the span (mask popcount; survives even when the
    /// mask plane is omitted because it is empty).
    pub missing_calls: u64,
}

impl GenoBlock {
    fn assemble(
        lo: BitVectorSet,
        hi: BitVectorSet,
        missing: Option<BitVectorSet>,
        missing_calls: u64,
    ) -> Self {
        // Prime the plane popcount caches at ingest: the CCC
        // denominator pass becomes a cached read, like Sorenson's.
        let _ = lo.popcounts_cached();
        let _ = hi.popcounts_cached();
        GenoBlock { lo, hi, missing, missing_calls }
    }

    /// Pack a float allele-count block (values in {0, 1, 2}; anything
    /// else rounds and clamps into that domain). The `Ccc::ingest`
    /// path: one call per block, counted by [`pack2_calls`].
    pub fn from_floats<T: Scalar>(set: &VectorSet<T>) -> Self {
        PACK2_CALLS.fetch_add(1, Ordering::Relaxed);
        let mut lo = BitVectorSet::zeros(set.nf, set.nv);
        let mut hi = BitVectorSet::zeros(set.nf, set.nv);
        lo.first_id = set.first_id;
        hi.first_id = set.first_id;
        for v in 0..set.nv {
            for (q, &x) in set.col(v).iter().enumerate() {
                let d = x.to_f64().round().clamp(0.0, 2.0) as u8;
                if d & 1 != 0 {
                    lo.set_bit(v, q);
                }
                if d & 2 != 0 {
                    hi.set_bit(v, q);
                }
            }
        }
        Self::assemble(lo, hi, None, 0)
    }

    /// Rehydrate from raw plane words (the wire → block and spill →
    /// block paths; never re-packs). Word vectors must hold exactly
    /// ⌈nf/64⌉ × nv words each.
    pub fn from_planes(
        nf: usize,
        nv: usize,
        first_id: usize,
        lo: Vec<u64>,
        hi: Vec<u64>,
        missing: Option<Vec<u64>>,
    ) -> Self {
        let lo = BitVectorSet::from_words(nf, nv, first_id, lo);
        let hi = BitVectorSet::from_words(nf, nv, first_id, hi);
        let missing = missing.map(|m| BitVectorSet::from_words(nf, nv, first_id, m));
        let missing_calls = missing.as_ref().map_or(0, |m| (0..nv).map(|v| m.popcount(v)).sum());
        Self::assemble(lo, hi, missing, missing_calls)
    }

    #[inline]
    pub fn nf(&self) -> usize {
        self.lo.nf
    }

    #[inline]
    pub fn nv(&self) -> usize {
        self.lo.nv
    }

    #[inline]
    pub fn first_id(&self) -> usize {
        self.lo.first_id
    }

    #[inline]
    pub fn words_per_vec(&self) -> usize {
        self.lo.words_per_vec
    }

    /// Alt-allele dosage of call (v, q) — missing reads as 0, exactly
    /// what the compute planes carry.
    #[inline]
    pub fn dosage(&self, v: usize, q: usize) -> u8 {
        self.lo.get_bit(v, q) as u8 + 2 * self.hi.get_bit(v, q) as u8
    }

    /// Per-vector dosage sums — CCC's denominator ingredients, exact
    /// small integers (= `VectorSet::col_sums` of the decoded floats).
    pub fn dose_sums(&self) -> Vec<f64> {
        let lo = self.lo.popcounts_cached();
        let hi = self.hi.popcounts_cached();
        lo.iter().zip(hi).map(|(l, h)| l + 2.0 * h).collect()
    }

    /// Expand to floats (oracle cross-checks).
    pub fn to_floats<T: Scalar>(&self) -> VectorSet<T> {
        let mut out = VectorSet::<T>::zeros(self.nf(), self.nv());
        out.first_id = self.first_id();
        for v in 0..self.nv() {
            for q in 0..self.nf() {
                let d = self.dosage(v, q);
                if d != 0 {
                    out.col_mut(v)[q] = T::from_f64(d as f64);
                }
            }
        }
        out
    }

    /// Resident payload bytes: all planes at 8 B/word.
    pub fn resident_bytes(&self) -> u64 {
        let words = self.lo.raw_words().len()
            + self.hi.raw_words().len()
            + self.missing.as_ref().map_or(0, |m| m.raw_words().len());
        (words * 8) as u64
    }
}

// ---------------------------------------------------------------------------
// PLINK .bed
// ---------------------------------------------------------------------------

/// Bytes per variant-major `.bed` row: 4 calls per byte.
#[inline]
fn bed_row_bytes(nf: usize) -> usize {
    nf.div_ceil(4)
}

/// Cross-check a companion text file's line count against the
/// configured dimension (`.bim` lines = variants, `.fam` lines =
/// samples). Missing companions are tolerated — the dimensions travel
/// in the run config, as with the raw format — but a present companion
/// that disagrees is a hard error.
fn check_companion(path: &Path, expected: usize, what: &str) -> Result<()> {
    if !path.exists() {
        return Ok(());
    }
    let f = File::open(path).with_context(|| format!("open {}", path.display()))?;
    let lines = BufReader::new(f)
        .lines()
        .map_while(std::io::Result::ok)
        .filter(|l| !l.trim().is_empty())
        .count();
    if lines != expected {
        bail!(
            "{}: {lines} lines but the run config expects {expected} {what}",
            path.display()
        );
    }
    Ok(())
}

/// Read variants [first_col, first_col + ncols) of a variant-major
/// PLINK `.bed` — the per-node portion read, mirroring
/// [`crate::vecdata::io::read_raw_cols`]. `nf` = samples (`.fam`
/// lines), `nv` = variants (`.bim` lines); both are cross-checked
/// against the companion files when present, and the `.bed` byte size
/// must match the dimensions exactly.
pub fn read_bed_cols(
    path: &Path,
    nf: usize,
    nv: usize,
    first_col: usize,
    ncols: usize,
) -> Result<GenoCodes> {
    if first_col + ncols > nv {
        bail!("column range [{first_col}, {}) exceeds nv={nv}", first_col + ncols);
    }
    let f = File::open(path).with_context(|| format!("open {}", path.display()))?;
    let row_bytes = bed_row_bytes(nf);
    let expected = 3 + (nv * row_bytes) as u64;
    let actual = f.metadata()?.len();
    let mut r = BufReader::new(f);
    let mut magic = [0u8; 3];
    r.read_exact(&mut magic)
        .with_context(|| format!("{}: too short for the .bed magic", path.display()))?;
    if magic != BED_MAGIC {
        bail!(
            "{}: not a variant-major PLINK .bed (magic {:02x} {:02x} {:02x}, expected 6c 1b 01)",
            path.display(),
            magic[0],
            magic[1],
            magic[2]
        );
    }
    if actual != expected {
        bail!(
            "{}: .bed size {actual} != expected {expected} (3-byte magic + nv={nv} rows of {row_bytes} B at nf={nf})",
            path.display()
        );
    }
    check_companion(&path.with_extension("bim"), nv, "variants")?;
    check_companion(&path.with_extension("fam"), nf, "samples")?;
    r.seek(SeekFrom::Start(3 + (first_col * row_bytes) as u64))?;
    let mut rows = vec![0u8; ncols * row_bytes];
    r.read_exact(&mut rows)?;
    let mut codes = vec![0u8; ncols * nf];
    for c in 0..ncols {
        let row = &rows[c * row_bytes..(c + 1) * row_bytes];
        let col = &mut codes[c * nf..(c + 1) * nf];
        for (q, slot) in col.iter_mut().enumerate() {
            // 00 hom-ref, 01 missing, 10 het, 11 hom-alt; tail codes in
            // the last byte beyond nf are padding and ignored.
            *slot = match (row[q / 4] >> (2 * (q % 4))) & 3 {
                0b00 => 0,
                0b01 => MISSING,
                0b10 => 1,
                _ => 2,
            };
        }
    }
    Ok(GenoCodes::finish(nf, ncols, first_col, codes))
}

/// Write genotype codes (0/1/2/[`MISSING`], variant-contiguous, `nf`
/// per variant) as a variant-major `.bed`.
pub fn write_bed_codes(path: &Path, nf: usize, codes: &[u8]) -> Result<()> {
    if nf == 0 || codes.len() % nf != 0 {
        bail!("{} codes do not tile nf={nf} samples", codes.len());
    }
    let f = File::create(path).with_context(|| format!("create {}", path.display()))?;
    let mut w = BufWriter::new(f);
    w.write_all(&BED_MAGIC)?;
    let row_bytes = bed_row_bytes(nf);
    for col in codes.chunks(nf) {
        let mut row = vec![0u8; row_bytes];
        for (q, &c) in col.iter().enumerate() {
            let two = match c {
                0 => 0b00,
                1 => 0b10,
                2 => 0b11,
                _ => 0b01,
            };
            row[q / 4] |= two << (2 * (q % 4));
        }
        w.write_all(&row)?;
    }
    w.flush()?;
    Ok(())
}

/// Quantize a float allele-count set to genotype codes (no missing —
/// floats cannot express the distinction).
fn float_codes<T: Scalar>(set: &VectorSet<T>) -> Vec<u8> {
    let mut codes = vec![0u8; set.nf * set.nv];
    for v in 0..set.nv {
        for (q, &x) in set.col(v).iter().enumerate() {
            codes[v * set.nf + q] = x.to_f64().round().clamp(0.0, 2.0) as u8;
        }
    }
    codes
}

/// Emit a complete PLINK fileset (`stem.bed` + `stem.bim` + `stem.fam`)
/// for a float cohort with allele-count values — the fixture writer
/// behind `comet gen-data --format bed` (no binary blobs in-tree).
/// Returns the `.bed` path.
pub fn write_plink_fixture<T: Scalar>(
    dir: &Path,
    stem: &str,
    set: &VectorSet<T>,
) -> Result<PathBuf> {
    std::fs::create_dir_all(dir).with_context(|| format!("create {}", dir.display()))?;
    let bed = dir.join(format!("{stem}.bed"));
    write_bed_codes(&bed, set.nf, &float_codes(set))?;
    let f = File::create(dir.join(format!("{stem}.bim")))?;
    let mut w = BufWriter::new(f);
    for v in 0..set.nv {
        writeln!(w, "1\tsnp{v}\t0\t{}\tA\tG", v + 1)?;
    }
    w.flush()?;
    let f = File::create(dir.join(format!("{stem}.fam")))?;
    let mut w = BufWriter::new(f);
    for q in 0..set.nf {
        writeln!(w, "fam{q} ind{q} 0 0 0 -9")?;
    }
    w.flush()?;
    Ok(bed)
}

// ---------------------------------------------------------------------------
// VCF
// ---------------------------------------------------------------------------

/// Variant lines decoded per worker-pool task.
const VCF_CHUNK: usize = 64;

/// Alt-allele dosage of one GT value ("0/1", "1|1", "./.", …).
fn gt_dosage(gt: &str, line_no: usize) -> Result<u8> {
    let mut dose = 0u8;
    let mut alleles = 0;
    for a in gt.split(['/', '|']) {
        alleles += 1;
        match a {
            "." => return Ok(MISSING),
            "0" => {}
            s if !s.is_empty() && s.bytes().all(|b| b.is_ascii_digit()) => {
                dose = dose.saturating_add(1)
            }
            _ => bail!("line {line_no}: malformed GT value {gt:?}"),
        }
    }
    if alleles != 2 {
        bail!("line {line_no}: GT {gt:?} is not diploid");
    }
    Ok(dose)
}

/// Decode one chunk of data lines (each tagged with its 1-based file
/// line number) into codes — the per-task body the pool workers run.
fn decode_vcf_chunk(lines: &[(usize, String)], nf: usize) -> Result<Vec<u8>> {
    let mut codes = vec![0u8; lines.len() * nf];
    for (i, (line_no, line)) in lines.iter().enumerate() {
        let fields: Vec<&str> = line.split('\t').collect();
        if fields.len() != 9 + nf {
            bail!(
                "line {line_no}: short VCF line — {} fields, expected {} (9 fixed + {nf} samples)",
                fields.len(),
                9 + nf
            );
        }
        let gt_idx = fields[8]
            .split(':')
            .position(|k| k == "GT")
            .with_context(|| format!("line {line_no}: FORMAT {:?} has no GT field", fields[8]))?;
        for (s, slot) in codes[i * nf..(i + 1) * nf].iter_mut().enumerate() {
            let sample = fields[9 + s];
            let gt = sample
                .split(':')
                .nth(gt_idx)
                .with_context(|| format!("line {line_no}: sample {s} field {sample:?} lacks GT"))?;
            *slot = gt_dosage(gt, *line_no)?;
        }
    }
    Ok(codes)
}

/// Read variants [first_col, first_col + ncols) of a VCF: a streaming
/// line parser walks the whole file (validating the `#CHROM` sample
/// count against `nf` and the data-line count against `nv`), and the
/// span's GT decodes run chunked on the `linalg::pool` workers.
pub fn read_vcf_cols(
    path: &Path,
    nf: usize,
    nv: usize,
    first_col: usize,
    ncols: usize,
) -> Result<GenoCodes> {
    if first_col + ncols > nv {
        bail!("column range [{first_col}, {}) exceeds nv={nv}", first_col + ncols);
    }
    let f = File::open(path).with_context(|| format!("open {}", path.display()))?;
    let mut span: Vec<(usize, String)> = Vec::with_capacity(ncols);
    let mut saw_header = false;
    let mut variants = 0usize;
    for (i, line) in BufReader::new(f).lines().enumerate() {
        let line = line.with_context(|| format!("read {}", path.display()))?;
        let line_no = i + 1;
        if line.starts_with("##") {
            continue;
        }
        if let Some(rest) = line.strip_prefix("#CHROM") {
            let samples = rest.split('\t').filter(|s| !s.is_empty()).count().saturating_sub(8);
            if samples != nf {
                bail!(
                    "{}: header names {samples} samples but the run config expects nf={nf}",
                    path.display()
                );
            }
            saw_header = true;
            continue;
        }
        if line.trim().is_empty() {
            continue;
        }
        if !saw_header {
            bail!("{}: data line {line_no} before the #CHROM header", path.display());
        }
        if variants >= first_col && variants < first_col + ncols {
            span.push((line_no, line));
        }
        variants += 1;
    }
    if !saw_header {
        bail!("{}: no #CHROM header line", path.display());
    }
    if variants != nv {
        bail!("{}: {variants} variant lines but the run config expects nv={nv}", path.display());
    }
    // Fan the span's chunk decodes out over the worker pool; the
    // streaming parse above stays single-pass and sequential.
    let chunks: Vec<&[(usize, String)]> = span.chunks(VCF_CHUNK).collect();
    let results: Mutex<Vec<Option<Result<Vec<u8>>>>> =
        Mutex::new((0..chunks.len()).map(|_| None).collect());
    let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = chunks
        .iter()
        .enumerate()
        .map(|(ci, chunk)| {
            let results = &results;
            let chunk = *chunk;
            Box::new(move || {
                let r = decode_vcf_chunk(chunk, nf);
                results.lock().unwrap()[ci] = Some(r);
            }) as Box<dyn FnOnce() + Send + '_>
        })
        .collect();
    pool::global().scope(tasks);
    let mut codes = Vec::with_capacity(ncols * nf);
    for slot in results.into_inner().unwrap() {
        codes.extend(slot.expect("pool scope joins every chunk task")?);
    }
    Ok(GenoCodes::finish(nf, ncols, first_col, codes))
}

/// Write genotype codes as a minimal VCF (one `GT`-only FORMAT column
/// per sample; missing codes emit `./.`).
pub fn write_vcf_codes(path: &Path, nf: usize, codes: &[u8]) -> Result<()> {
    if nf == 0 || codes.len() % nf != 0 {
        bail!("{} codes do not tile nf={nf} samples", codes.len());
    }
    let f = File::create(path).with_context(|| format!("create {}", path.display()))?;
    let mut w = BufWriter::new(f);
    writeln!(w, "##fileformat=VCFv4.2")?;
    writeln!(w, "##source=comet gen-data")?;
    write!(w, "#CHROM\tPOS\tID\tREF\tALT\tQUAL\tFILTER\tINFO\tFORMAT")?;
    for q in 0..nf {
        write!(w, "\tind{q}")?;
    }
    writeln!(w)?;
    for (v, col) in codes.chunks(nf).enumerate() {
        write!(w, "1\t{}\tsnp{v}\tA\tG\t.\tPASS\t.\tGT", v + 1)?;
        for &c in col {
            let gt = match c {
                0 => "0/0",
                1 => "0/1",
                2 => "1/1",
                _ => "./.",
            };
            write!(w, "\t{gt}")?;
        }
        writeln!(w)?;
    }
    w.flush()?;
    Ok(())
}

/// Emit a VCF for a float cohort with allele-count values — the fixture
/// writer behind `comet gen-data --format vcf`.
pub fn write_vcf_fixture<T: Scalar>(path: &Path, set: &VectorSet<T>) -> Result<()> {
    write_vcf_codes(path, set.nf, &float_codes(set))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vecdata::SyntheticKind;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("comet-tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(format!("{name}-{}", std::process::id()))
    }

    fn cohort(nf: usize, nv: usize) -> VectorSet<f64> {
        VectorSet::generate(SyntheticKind::Alleles, 11, nf, nv, 0)
    }

    #[test]
    fn bed_fixture_roundtrips_full_and_partial() {
        let set = cohort(13, 9); // nf not divisible by 4: padded rows
        let dir = tmp("bed-rt");
        let bed = write_plink_fixture(&dir, "cohort", &set).unwrap();
        let full = read_bed_cols(&bed, 13, 9, 0, 9).unwrap();
        assert_eq!(full.missing, 0);
        let floats: VectorSet<f64> = full.to_floats();
        assert_eq!(floats.raw(), set.raw());
        let part = read_bed_cols(&bed, 13, 9, 3, 4).unwrap();
        assert_eq!(part.first_id, 3);
        let pf: VectorSet<f64> = part.to_floats();
        for v in 0..4 {
            assert_eq!(pf.col(v), set.col(3 + v));
        }
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn vcf_fixture_roundtrips_full_and_partial() {
        let set = cohort(7, 10);
        let p = tmp("vcf-rt.vcf");
        write_vcf_fixture(&p, &set).unwrap();
        let full = read_vcf_cols(&p, 7, 10, 0, 10).unwrap();
        let floats: VectorSet<f64> = full.to_floats();
        assert_eq!(floats.raw(), set.raw());
        let part = read_vcf_cols(&p, 7, 10, 4, 3).unwrap();
        assert_eq!(part.first_id, 4);
        let pf: VectorSet<f64> = part.to_floats();
        for v in 0..3 {
            assert_eq!(pf.col(v), set.col(4 + v));
        }
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn bed_and_vcf_agree_with_each_other() {
        let set = cohort(9, 6);
        let dir = tmp("bed-vs-vcf");
        let bed = write_plink_fixture(&dir, "c", &set).unwrap();
        let vcf = dir.join("c.vcf");
        write_vcf_codes(&vcf, 9, &float_codes(&set)).unwrap();
        let a = read_bed_cols(&bed, 9, 6, 0, 6).unwrap();
        let b = read_vcf_cols(&vcf, 9, 6, 0, 6).unwrap();
        assert_eq!(a.codes, b.codes);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn bad_magic_rejected() {
        let p = tmp("bad-magic.bed");
        std::fs::write(&p, [0x6c, 0x1b, 0x00, 0, 0, 0, 0]).unwrap();
        let err = read_bed_cols(&p, 4, 1, 0, 1).unwrap_err();
        assert!(err.to_string().contains("magic"), "{err}");
        // Shorter than the magic itself is its own typed error.
        std::fs::write(&p, [0x6c]).unwrap();
        assert!(read_bed_cols(&p, 4, 1, 0, 1).is_err());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn bed_size_mismatch_rejected() {
        let set = cohort(8, 4);
        let dir = tmp("bed-size");
        let bed = write_plink_fixture(&dir, "c", &set).unwrap();
        // Truncated: claim more variants than the file holds.
        let err = read_bed_cols(&bed, 8, 5, 0, 5).unwrap_err();
        assert!(err.to_string().contains("size"), "{err}");
        // Oversized: claim fewer.
        let err = read_bed_cols(&bed, 8, 3, 0, 3).unwrap_err();
        assert!(err.to_string().contains("size"), "{err}");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn companion_dimension_mismatch_rejected() {
        let set = cohort(8, 4);
        let dir = tmp("bed-companion");
        let bed = write_plink_fixture(&dir, "c", &set).unwrap();
        // A .bim disagreeing with nv is a hard error even though the
        // .bed size happens to parse under other dimensions.
        std::fs::write(dir.join("c.bim"), "1\tsnp0\t0\t1\tA\tG\n").unwrap();
        let err = read_bed_cols(&bed, 8, 4, 0, 4).unwrap_err();
        assert!(err.to_string().contains("4 variants"), "{err}");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn vcf_hostile_inputs_are_typed_errors() {
        let p = tmp("vcf-hostile.vcf");
        // Short data line (sample column missing).
        std::fs::write(
            &p,
            "##fileformat=VCFv4.2\n#CHROM\tPOS\tID\tREF\tALT\tQUAL\tFILTER\tINFO\tFORMAT\ta\tb\n\
             1\t1\ts\tA\tG\t.\t.\t.\tGT\t0/0\n",
        )
        .unwrap();
        let err = read_vcf_cols(&p, 2, 1, 0, 1).unwrap_err();
        assert!(err.to_string().contains("short VCF line"), "{err}");
        // No #CHROM header at all.
        std::fs::write(&p, "1\t1\ts\tA\tG\t.\t.\t.\tGT\t0/0\n").unwrap();
        assert!(read_vcf_cols(&p, 1, 1, 0, 1).is_err());
        // Header sample count disagreeing with nf.
        std::fs::write(
            &p,
            "#CHROM\tPOS\tID\tREF\tALT\tQUAL\tFILTER\tINFO\tFORMAT\ta\n\
             1\t1\ts\tA\tG\t.\t.\t.\tGT\t0/0\n",
        )
        .unwrap();
        let err = read_vcf_cols(&p, 2, 1, 0, 1).unwrap_err();
        assert!(err.to_string().contains("samples"), "{err}");
        // Malformed GT and non-diploid GT.
        for gt in ["x/0", "0/1/1", "1"] {
            std::fs::write(
                &p,
                format!(
                    "#CHROM\tPOS\tID\tREF\tALT\tQUAL\tFILTER\tINFO\tFORMAT\ta\n\
                     1\t1\ts\tA\tG\t.\t.\t.\tGT\t{gt}\n"
                ),
            )
            .unwrap();
            assert!(read_vcf_cols(&p, 1, 1, 0, 1).is_err(), "GT {gt:?} must fail");
        }
        // Variant count disagreeing with nv.
        std::fs::write(
            &p,
            "#CHROM\tPOS\tID\tREF\tALT\tQUAL\tFILTER\tINFO\tFORMAT\ta\n\
             1\t1\ts\tA\tG\t.\t.\t.\tGT\t0/0\n",
        )
        .unwrap();
        let err = read_vcf_cols(&p, 1, 2, 0, 1).unwrap_err();
        assert!(err.to_string().contains("variant lines"), "{err}");
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn missing_calls_impute_to_zero_and_are_counted() {
        // codes: variant 0 = [het, missing, hom-alt], variant 1 = all missing
        let codes = vec![1, MISSING, 2, MISSING, MISSING, MISSING];
        let p = tmp("missing.bed");
        write_bed_codes(&p, 3, &codes).unwrap();
        let before = missing_calls();
        let g = read_bed_cols(&p, 3, 2, 0, 2).unwrap();
        assert_eq!(g.missing, 4);
        assert!(missing_calls() >= before + 4);
        let f: VectorSet<f64> = g.to_floats();
        assert_eq!(f.col(0), &[1.0, 0.0, 2.0]);
        assert_eq!(f.col(1), &[0.0, 0.0, 0.0]);
        let packed = g.pack2();
        assert_eq!(packed.missing_calls, 4);
        let m = packed.missing.as_ref().unwrap();
        assert!(m.get_bit(0, 1) && m.get_bit(1, 0) && m.get_bit(1, 2));
        assert!(!m.get_bit(0, 0));
        // Dosage planes carry 0 where the mask is set.
        assert_eq!(packed.dosage(0, 1), 0);
        assert_eq!(packed.dose_sums(), vec![3.0, 0.0]);
        // The same cohort through the VCF writer decodes identically.
        let pv = tmp("missing.vcf");
        write_vcf_codes(&pv, 3, &codes).unwrap();
        let gv = read_vcf_cols(&pv, 3, 2, 0, 2).unwrap();
        assert_eq!(gv.codes, g.codes);
        std::fs::remove_file(p).ok();
        std::fs::remove_file(pv).ok();
    }

    #[test]
    fn pack_from_floats_matches_pack_from_codes() {
        let set = cohort(70, 5); // two words per plane vector
        let a = GenoBlock::from_floats(&set);
        let dir = tmp("packeq");
        let bed = write_plink_fixture(&dir, "c", &set).unwrap();
        let b = read_bed_cols(&bed, 70, 5, 0, 5).unwrap().pack2();
        for v in 0..5 {
            assert_eq!(a.lo.words(v), b.lo.words(v));
            assert_eq!(a.hi.words(v), b.hi.words(v));
        }
        assert!(a.missing.is_none() && b.missing.is_none());
        // Dosage sums are exactly the float column sums.
        assert_eq!(a.dose_sums(), set.col_sums());
        // And the float expansion is exactly the input.
        assert_eq!(a.to_floats::<f64>().raw(), set.raw());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn pack2_counter_increments_once_per_pack() {
        let set = cohort(16, 3);
        let before = pack2_calls();
        let _ = GenoBlock::from_floats(&set);
        assert!(pack2_calls() > before);
    }

    #[test]
    fn plane_roundtrip_through_raw_words() {
        let codes = vec![0, 1, 2, MISSING, 2, 2, 0, 1];
        let p = tmp("planes.bed");
        write_bed_codes(&p, 4, &codes).unwrap();
        let g = read_bed_cols(&p, 4, 2, 0, 2).unwrap().pack2();
        let r = GenoBlock::from_planes(
            4,
            2,
            0,
            g.lo.raw_words().to_vec(),
            g.hi.raw_words().to_vec(),
            g.missing.as_ref().map(|m| m.raw_words().to_vec()),
        );
        assert_eq!(r.missing_calls, 1);
        for v in 0..2 {
            for q in 0..4 {
                assert_eq!(r.dosage(v, q), g.dosage(v, q));
            }
        }
        std::fs::remove_file(p).ok();
    }
}
